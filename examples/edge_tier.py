"""End -> edge -> cloud (3-hop) collaborative serving scenario.

The full COACH stack on a three-tier deployment: the multi-hop offline
component picks an ordered multi-cut (Jetson end, AGX-Orin edge, A6000
cloud; WiFi uplink + metro-ethernet backhaul), the real JAX model runs as
three ``CollabRuntime`` segments with one quantized ``WirePacket`` per
hop, the online component decides early exit / adaptive precision per
task — including *hop-level* semantic exits: the edge tier runs its own
calibrated probe on its boundary activation and terminates confident
tasks there, releasing the backhaul and the cloud — and the
``2n+1``-resource pipeline accounts latency, throughput, and
per-resource bubbles.  A classic 2-tier (end -> cloud) run of the same
model/stream prints alongside for comparison; the ``exit_hops``
histogram line shows where tasks left the chain (segment 0 = end
device, 1 = edge tier).

  PYTHONPATH=src python examples/edge_tier.py \
      [--arch gemma2-2b] [--requests 64] [--bandwidth 50]
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.core.collab import CollabRuntime
from repro.core.costs import (A6000_SERVER, EDGE_AGX_ORIN, ETH_LAN,
                              JETSON_NX, WIFI_5GHZ, transformer_graph)
from repro.core.partitioner import coach_offline_multihop
from repro.data.pipeline import (CorrelatedTaskStream,
                                 make_hop_calibration_sets)
from repro.models import model as M
from repro.serving.async_engine import AsyncCoachEngine
from repro.serving.engine import CoachEngine


def group_cuts_from_frontiers(decision, cfg):
    """Map the layer-level multi-cut onto strictly increasing group
    boundaries of the scanned parameter stack (embed node is id 0)."""
    cuts = []
    lo = 1
    for k, frontier in enumerate(decision.cuts):
        n_layers = sum(1 for i in frontier if 0 < i <= cfg.num_layers)
        hi = cfg.num_groups - (decision.n_hops - k)
        cut = min(max(lo, round(n_layers / cfg.group_size)), hi)
        cuts.append(cut)
        lo = cut + 1
    return tuple(cuts)


def run_tier(cfg, params, graph, devices, links, stream, calib_sets,
             requests: int, seed: int):
    t0 = time.perf_counter()
    off = coach_offline_multihop(graph, devices, links)
    plan_s = time.perf_counter() - t0
    cuts = group_cuts_from_frontiers(off.decision, cfg)
    hop_bits = [int(np.mean(list(b.values()))) if b else 8
                for b in off.decision.all_hop_bits]
    rt = CollabRuntime(cfg, params, cuts, default_bits=hop_bits)
    feats, labels = calib_sets[0]
    # one calibration set per intermediate tier activates that tier's
    # semantic probe (hop-level early exit); the 2-tier run gets none
    mk_engine = lambda cls: cls(
        rt, off.times, devices[0], links[0], devices[-1],
        n_labels=16, calib_feats=feats, calib_labels=labels,
        boundary_elems=128 * cfg.d_model, links=list(links),
        hop_bits_offline=hop_bits, hop_calib=calib_sets[1:len(links)])

    def classify(task):
        toks = (np.abs((task.features[:8] * 1000).astype(np.int64))
                % cfg.vocab_size).astype(np.int32)
        inp = jnp.asarray(toks)[None]
        logits, _packets = rt.run(inp)
        return (task.hop_features, int(np.argmax(logits[0])
                                       % stream.n_labels))

    tasks = stream.tasks(requests)
    stats = mk_engine(CoachEngine).run_stream(
        list(tasks), arrival_period=off.times.max_stage, classify=classify)
    # same stream through the async hop-queue executor (fresh engine, so
    # the semantic cache sees an identical decision sequence)
    astats = mk_engine(AsyncCoachEngine).run_stream(
        list(tasks), arrival_period=off.times.max_stage, classify=classify)
    return off, cuts, stats, astats, plan_s


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="gemma2-2b")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--bandwidth", type=float, default=50.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(num_layers=4 * len(
        get_config(args.arch).pattern))  # >= 4 groups for a 3-segment split
    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key)
    graph = transformer_graph(cfg, batch=1, seq=128)
    # two probe depths: the end device's boundary and the edge tier's
    # (decay 0.9, matching benchmarks/multihop.py's cascade)
    stream = CorrelatedTaskStream(n_labels=16, dim=cfg.d_model,
                                  correlation="medium", seed=args.seed,
                                  n_probe_depths=2, depth_decay=0.9)
    calib_sets = make_hop_calibration_sets(stream, n=300)

    tiers = {
        "end->cloud": ((JETSON_NX, A6000_SERVER),
                       (WIFI_5GHZ(args.bandwidth),)),
        "end->edge->cloud": ((JETSON_NX, EDGE_AGX_ORIN, A6000_SERVER),
                             (WIFI_5GHZ(args.bandwidth), ETH_LAN())),
    }
    for name, (devices, links) in tiers.items():
        off, cuts, stats, astats, plan_s = run_tier(
            cfg, params, graph, devices, links, stream, calib_sets,
            args.requests, args.seed)
        pr = stats.pipeline
        bubbles = " ".join(
            f"c{k}={pr.bubble_fraction(('compute', k)):.2f}"
            for k in range(len(devices)))
        bubbles += " " + " ".join(
            f"l{k}={pr.bubble_fraction(('link', k)):.2f}"
            for k in range(len(links)))
        print(f"[{name}] arch={cfg.name} cuts={cuts}/{cfg.num_groups} "
              f"objective={off.objective * 1e3:.2f}ms")
        print(f"  planner: {off.candidates} candidates in "
              f"{plan_s * 1e3:.1f}ms "
              f"({off.candidates / max(plan_s, 1e-9):.0f} cand/s)")
        print(f"  exit_ratio={stats.exit_ratio:.2%} "
              f"exit_hops={stats.exit_hops or {}} "
              f"mean_bits={stats.mean_bits:.1f} "
              f"wire_kb/task={stats.wire_kb_per_task:.1f}")
        print(f"  latency mean={pr.mean_latency * 1e3:.2f}ms "
              f"p99={pr.p99_latency * 1e3:.2f}ms "
              f"thpt={pr.throughput:.1f} it/s bubbles: {bubbles}")
        pa = astats.pipeline
        same = (astats.exit_ratio == stats.exit_ratio
                and astats.mean_bits == stats.mean_bits
                and astats.accuracy == stats.accuracy)
        print(f"  [async] latency mean={pa.mean_latency * 1e3:.2f}ms "
              f"p99={pa.p99_latency * 1e3:.2f}ms "
              f"thpt={pa.throughput:.1f} it/s "
              f"decisions_match_sync={same}")


if __name__ == "__main__":
    main()
