"""Continuous micro-batching walkthrough: one overloaded stream, two runs.

ResNet101 is cut by the offline planner onto the 2-tier (Jetson-NX +
A6000; pass ``--tiers 3`` for the +AGX-Orin chain) deployment over
10 GbE, each segment's service time is split into its per-launch fixed
cost and per-sample marginal (``core.costs.segment_batch_split``), and
the auto batch-size finder (``serving.batching.auto_batch_caps``)
converts a staleness slack budget into per-tier batch caps.  The same
overloaded arrival stream then runs twice through both engines:

  unbatched  every compute tier serves one task per launch
  batched    workers drain their hop queue into dynamic micro-batches
             priced ``t_fixed + n * t_marginal``, capped by the finder
             and by each member's staleness deadline

Watch three things in the output: the realized batch sizes (dynamic —
the greedy drain takes what the backlog offers, so they sit well below
the caps), the throughput/p99 pair (batching on an overloaded stream is
a Pareto win: the backlog clears faster than it grows), and the
``pinned_to_sim`` flag (the asyncio executor's batched timeline stays
bit-identical to the arithmetic staged replay).

  PYTHONPATH=src python examples/batching.py \
      [--tiers 2|3] [--overload 2.0] [--slack-stages 2.0] [--tasks 300]
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

# deployment table is shared with the bench so this walkthrough always
# tells the same story the emitted BENCH_pipeline.json rows measure
from benchmarks.batching import CAP_LIMIT, DEPLOYMENTS
from repro.core.costs import segment_batch_split
from repro.core.partitioner import coach_offline_multihop
from repro.core.pipeline import plan_from_stage_times, run_pipeline
from repro.models.cnn import resnet101
from repro.serving.async_engine import run_pipeline_async
from repro.serving.batching import auto_batch_caps, realized_batch_sizes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiers", type=int, choices=(2, 3), default=2)
    ap.add_argument("--overload", type=float, default=2.0,
                    help="offered load as a multiple of the unbatched "
                         "service rate (arrivals every max_stage/overload)")
    ap.add_argument("--slack-stages", type=float, default=2.0,
                    help="staleness budget for the auto finder, in units "
                         "of the bottleneck stage time")
    ap.add_argument("--tasks", type=int, default=300)
    args = ap.parse_args()

    devices, links = DEPLOYMENTS[args.tiers]
    graph = resnet101()
    off = coach_offline_multihop(graph, devices, links)
    st = off.times
    t_fixed = tuple(
        segment_batch_split(devices[k],
                            [graph.node(i) for i in sorted(seg)])[0]
        for k, seg in enumerate(off.decision.segments(graph)))
    slack = st.max_stage * args.slack_stages
    caps = auto_batch_caps(st.compute, t_fixed, slack, CAP_LIMIT)
    period = st.max_stage / args.overload

    print(f"[deployment] {graph.name} {args.tiers}-tier over "
          f"{links[0].name}: single-task {st.latency * 1e3:.1f}ms, "
          f"bottleneck stage {st.max_stage * 1e3:.2f}ms")
    print("[split]      fixed fraction per tier: "
          + ", ".join(f"{f / c:.2f}" for f, c in zip(t_fixed, st.compute)))
    print(f"[finder]     slack {slack * 1e3:.1f}ms -> caps "
          + "/".join(str(c) for c in caps)
          + f" (limit {CAP_LIMIT})")
    print(f"[load]       {args.tasks} tasks arriving every "
          f"{period * 1e3:.2f}ms ({args.overload:.1f}x service rate)\n")

    for batched in (False, True):
        bc = list(caps) if batched else [1] * args.tiers
        plans = [plan_from_stage_times(st) for _ in range(args.tasks)]
        for p in plans:
            p.t_fixed = t_fixed
        pr = run_pipeline(plans, arrival_period=period, links=list(links),
                          batch_caps=bc)
        pa = run_pipeline_async(plans, arrival_period=period,
                                links=list(links), batch_caps=bc)
        pinned = all(abs(a.done - b.done) < 1e-6
                     for a, b in zip(pr.tasks, pa.tasks))
        label = "batched" if batched else "unbatched"
        print(f"[{label:<9}] caps " + "/".join(str(c) for c in bc)
              + " realized "
              + "/".join(f"{b:.2f}" for b in realized_batch_sizes(pr)))
        print(f"            throughput {pr.throughput:6.1f} it/s | "
              f"p99 {pr.p99_latency * 1e3:7.2f}ms | "
              f"makespan {pr.makespan * 1e3:.0f}ms | "
              f"pinned_to_sim={pinned}")


if __name__ == "__main__":
    main()
