"""Replicated-tier scale-out walkthrough: one overloaded stream, a
replicas-vs-p99 table.

ResNet101 is cut by the offline planner onto the 2-tier (Jetson-NX +
A6000; pass ``--tiers 3`` for the +AGX-Orin chain) deployment over fast
rack fabric, then every compute tier is replicated ``m``-fold
(``core.sim.PoolSpec``) behind a router policy (``serving.routing``)
and the same 4x-overloaded arrival stream is replayed per (policy, m).

Watch three things in the output: throughput scaling near-linearly in
``m`` until the serial wire binds, the p99 collapsing as queueing
drains (the scale-out Pareto win), and the informed policies (jsq, po2)
beating the random baseline — at ``m = 2`` po2 probes both replicas and
*is* JSQ; the gap opens at ``m = 4``.  The ``pinned_to_sim`` flag
confirms the per-replica asyncio executor's timeline matches the
arithmetic staged pool replay.

  PYTHONPATH=src python examples/replicated_tiers.py \
      [--tiers 2|3] [--overload 4.0] [--tasks 240] [--policies jsq,po2]
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

# deployment table is shared with the bench so this walkthrough always
# tells the same story the emitted BENCH_pipeline.json rows measure
from benchmarks.routing import DEPLOYMENTS, M_SWEEP, ROUTER_SEED
from repro.core.partitioner import coach_offline_multihop
from repro.core.pipeline import plan_from_stage_times, run_pipeline
from repro.models.cnn import resnet101
from repro.serving.async_engine import run_pipeline_async
from repro.serving.routing import make_router


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiers", type=int, choices=(2, 3), default=2)
    ap.add_argument("--overload", type=float, default=4.0,
                    help="offered load as a multiple of the m=1 "
                         "bottleneck rate (arrivals every "
                         "max_stage/overload)")
    ap.add_argument("--tasks", type=int, default=240)
    ap.add_argument("--policies", default="jsq,po2,random")
    args = ap.parse_args()

    graph = resnet101()
    devices, links = DEPLOYMENTS[args.tiers]
    off = coach_offline_multihop(graph, devices, links)
    st = off.times
    period = st.max_stage / args.overload
    plans = [plan_from_stage_times(st) for _ in range(args.tasks)]

    print(f"{graph.name} on {args.tiers} tiers | "
          f"stages {[round(c * 1e3, 2) for c in st.compute]} ms, "
          f"wire {[round(t * 1e3, 2) for t in st.link]} ms | "
          f"arrivals every {period * 1e3:.2f} ms "
          f"({args.overload:.1f}x overload)\n")
    hdr = (f"{'policy':>8} {'m':>3} {'throughput/s':>13} {'speedup':>8} "
           f"{'p99 ms':>9} {'mean ms':>9} {'pinned_to_sim':>14}")
    print(hdr)
    print("-" * len(hdr))
    for policy in args.policies.split(","):
        base = None
        for m in M_SWEEP:
            pools = [m] * args.tiers
            pr = run_pipeline(plans, arrival_period=period,
                              links=list(links), pools=pools,
                              router=make_router(policy, seed=ROUTER_SEED))
            pa = run_pipeline_async(plans, arrival_period=period,
                                    links=list(links), pools=pools,
                                    router=make_router(policy,
                                                       seed=ROUTER_SEED))
            pinned = abs(pr.makespan - pa.makespan) < 1e-6 and all(
                abs(a.done - b.done) < 1e-6
                for a, b in zip(pr.tasks, pa.tasks))
            base = base or pr.throughput
            print(f"{policy:>8} {m:>3} {pr.throughput:>13.1f} "
                  f"{pr.throughput / base:>7.2f}x "
                  f"{pr.p99_latency * 1e3:>9.2f} "
                  f"{pr.mean_latency * 1e3:>9.2f} {str(pinned):>14}")
        print()


if __name__ == "__main__":
    main()
