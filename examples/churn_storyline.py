"""Churn storyline walkthrough: ride a link fault with online re-planning.

A VGG16 stream runs the 2-tier end-cloud deployment while hop 0's WiFi
degrades mid-stream (50 -> 12 Mbps) and later recovers — the scripted
``degrade`` storyline of the resilience bench.  The scenario engine
executes it on *both* pipeline engines (the 1e-6 differential pin is
asserted inside the runner), the online re-planner detects the regime
shift from the bandwidth EMA at task arrivals, re-runs the offline
planner with warm tables, and migrates in-flight tasks at hop
boundaries with a precision drop on the degraded hop.

The printout slices the bubble attribution into before / during / after
the fault window, per cause — including the ``replanning`` cause the
migration spans introduce — and closes with the static-vs-replan p99
through the window.

  PYTHONPATH=src python examples/churn_storyline.py [--tasks 120]
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import numpy as np

from repro.core.costs import A6000_SERVER, JETSON_NX, WIFI_5GHZ
from repro.models.cnn import vgg16
from repro.obs.bubbles import CAUSES, attribute, chain_resources
from repro.scenarios import LinkShift, Timeline, run_chain_scenario
from repro.scenarios.replan import replan_timeline

DEVICES = (JETSON_NX, A6000_SERVER)
LINKS = (WIFI_5GHZ(50.0),)
DEGRADED_MBPS = 12.0
WINDOW = (25, 75)  # fault window, in arrival periods


def _phase_causes(att, lo: float, hi: float):
    """Cause -> seconds, for bubbles clipped to ``[lo, hi)``."""
    out = {}
    for b in att.bubbles:
        d = min(b.t1, hi) - max(b.t0, lo)
        if d > 0:
            out[b.cause] = out.get(b.cause, 0.0) + d
    return out


def _print_phase_table(att, t_deg: float, t_rec: float) -> None:
    phases = (("before", 0.0, t_deg), ("during", t_deg, t_rec),
              ("after", t_rec, att.horizon[1]))
    by_phase = {name: _phase_causes(att, lo, hi)
                for name, lo, hi in phases}
    causes = [c for c in CAUSES
              if any(c in p for p in by_phase.values())]
    print(f"  {'idle by cause (ms)':<22}"
          + "".join(f"{n:>12}" for n, _, _ in phases))
    for c in causes:
        row = "".join(f"{by_phase[n].get(c, 0.0) * 1e3:>12.1f}"
                      for n, _, _ in phases)
        print(f"  {c:<22}{row}")


def _p99_window(pr, lo: float, hi: float) -> float:
    lat = [t.latency for t in pr.tasks if lo <= t.arrival < hi]
    return float(np.percentile(lat, 99)) * 1e3


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks", type=int, default=120)
    args = ap.parse_args()

    graph = vgg16()
    versions, _ = replan_timeline(graph, DEVICES, list(LINKS),
                                  arrivals=[])
    period = versions[0].times.max_stage * 1.05
    t_deg, t_rec = WINDOW[0] * period, WINDOW[1] * period
    tl = Timeline([LinkShift(t_deg, 0, DEGRADED_MBPS),
                   LinkShift(t_rec, 0, 50.0)],
                  horizon=(args.tasks + 5) * period)
    print(f"{graph.name} on {DEVICES[0].name}->{DEVICES[1].name}, "
          f"hop 0 degrades 50->{DEGRADED_MBPS:.0f} Mbps over "
          f"[{t_deg * 1e3:.0f}, {t_rec * 1e3:.0f}] ms")

    print("\n== static plan rides through the fault ==")
    static = run_chain_scenario(graph, DEVICES, LINKS, tl, args.tasks,
                                replan=False)
    att_s = attribute(static.traces[0],
                      resources=chain_resources(static.sim.n_hops))
    _print_phase_table(att_s, t_deg, t_rec)

    print("\n== online re-planning (EMA detection + migration) ==")
    replan = run_chain_scenario(graph, DEVICES, LINKS, tl, args.tasks,
                                min_gap=10 * period,
                                degraded_tx_scale=0.5)
    att_r = attribute(replan.traces[0],
                      resources=chain_resources(replan.sim.n_hops))
    _print_phase_table(att_r, t_deg, t_rec)
    print(f"\n  re-plans: {replan.n_replans}, in-flight migrations: "
          f"{replan.n_migrations}, sim/async pin delta "
          f"{replan.max_done_delta:.2e} s")

    p99_s = _p99_window(static.sim, t_deg, t_rec)
    p99_r = _p99_window(replan.sim, t_deg, t_rec)
    print(f"\n  p99 through the fault window: static {p99_s:.1f} ms, "
          f"replanned {p99_r:.1f} ms ({p99_s / p99_r:.1f}x better)")


if __name__ == "__main__":
    main()
