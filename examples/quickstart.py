"""Quickstart: the whole COACH loop on a small model, in one script.

  PYTHONPATH=src python examples/quickstart.py

1. build a reduced gemma2 and its layer-cost graph
2. offline component: joint partition + quantization (Algorithm 1)
3. split the model at the chosen group boundary (CollabRuntime)
4. run a task: end segment -> UAQ-quantized wire packet (Pallas kernel
   semantics) -> cloud segment; compare against the monolithic model
5. online component: semantic-cache probe -> early exit / precision choice
"""

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import online as ON
from repro.core.collab import CollabRuntime
from repro.core.costs import A6000_SERVER, JETSON_NX, WIFI_5GHZ, transformer_graph
from repro.core.partitioner import coach_offline
from repro.models import model as M


def main():
    # 1. model + cost graph -------------------------------------------------
    cfg = get_config("gemma2-2b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    graph = transformer_graph(cfg, batch=1, seq=128)
    print(f"model: {cfg.name}  layers={cfg.num_layers}  "
          f"params={M.param_count(params):,}")

    # 2. offline component ---------------------------------------------------
    link = WIFI_5GHZ(50)
    t0 = time.perf_counter()
    off = coach_offline(graph, JETSON_NX, A6000_SERVER, link)
    plan_s = time.perf_counter() - t0
    t = off.times
    print(f"offline: |V_e|={len(off.decision.end_set)} of {len(graph)} "
          f"bits={sorted(set(off.decision.bits.values()))} "
          f"T_e={t.T_e*1e3:.2f}ms T_t={t.T_t*1e3:.2f}ms T_c={t.T_c*1e3:.2f}ms "
          f"B_c={t.B_c*1e3:.2f} B_t={t.B_t*1e3:.2f} obj={off.objective*1e3:.2f}")
    print(f"planner: {off.candidates} candidates in {plan_s*1e3:.1f}ms "
          f"({off.candidates/max(plan_s, 1e-9):.0f} cand/s, batched fast "
          f"scorer + event-sim rescoring)")

    # 3./4. collaborative execution ------------------------------------------
    rt = CollabRuntime(cfg, params, cut_group=1, default_bits=8)
    x = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)
    pkt, boundary = rt.end_step(x)
    logits = rt.cloud_step(pkt)
    ref = rt.monolithic(params, x)
    rel = float(jnp.max(jnp.abs(logits - ref)) / jnp.max(jnp.abs(ref)))
    print(f"collab: wire={pkt.wire_bytes}B (fp32 would be {boundary.size*4}B) "
          f"rel-err={rel:.4f}")

    # 5. online component -----------------------------------------------------
    centers = jax.random.normal(jax.random.PRNGKey(2), (8, cfg.d_model))
    sep, best, sims = rt.probe(boundary.astype(jnp.float32), centers)
    th = ON.Thresholds(s_ext=float(np.median(np.asarray(sep))),
                       s_adj=((1.0, 3), (0.5, 4), (0.1, 6)))
    for i in range(4):
        s = float(sep[i])
        if s > th.s_ext:
            print(f"task {i}: separability={s:.3f} -> EARLY EXIT "
                  f"label={int(best[i])} (Eq. 10)")
        else:
            b = ON.choose_bits(th.required_bits(s), boundary[i].size,
                               50e6, t.T_e, t.T_c)
            print(f"task {i}: separability={s:.3f} -> transmit at "
                  f"{b} bits (Eq. 11)")


if __name__ == "__main__":
    main()
