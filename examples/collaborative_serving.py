"""End-to-end driver: serve a small model with batched requests through the
full COACH system — offline partition, real JAX end/cloud segments with the
quantized wire, semantic cache, early exits, adaptive precision, pipeline
accounting.

  PYTHONPATH=src python examples/collaborative_serving.py \
      [--arch gemma2-2b] [--requests 200] [--correlation high]
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.launch.serve import main  # the launcher IS the driver

if __name__ == "__main__":
    main()
