"""Train a small LM for a few hundred steps on learnable synthetic data
(order-1 Markov stream) and watch the loss drop.

  PYTHONPATH=src python examples/train_small.py [--steps 200] [--arch ...]

The default ~10M-param gemma2-family variant fits a few-minute CPU budget;
pass --arch mamba2-130m --full for the real 130M config if you have time.
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="use the full (not reduced) config")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    _, losses = train(args.arch, smoke=not args.full, steps=args.steps,
                      batch=args.batch, seq=args.seq, lr=3e-3,
                      ckpt_dir=args.ckpt_dir, log_every=10)
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({'LEARNED' if last < first - 0.3 else 'check hyperparams'})")


if __name__ == "__main__":
    main()
