"""Multi-tenant serving walkthrough: three tenants, one hop chain.

Several end-device task streams share a single collaborative VGG16
deployment (Jetson-NX end -> A6000 cloud over WiFi; pass ``--tiers 3``
for the end -> AGX-Orin edge -> cloud chain).  Each tenant gets its own
COACH online state (semantic cache, thresholds, bandwidth EMAs) inside a
``MultiTenantCoachEngine``; a pluggable admission policy decides which
tenant's task enters the shared ``2n+1`` resource chain whenever the end
worker frees up:

  interactive   sparse arrivals, tight SLO, weight 4
  batch         bursts of back-to-back tasks, loose SLO, weight 1
  steady        medium periodic arrivals, medium SLO, weight 2

Run it and compare the per-tenant tables: under FIFO a batch burst
drags the interactive tenant ~3-4x outside its SLO; weighted deficit
round-robin (WDRR) keeps every tenant inside its own SLO at the price
of the batch tenant absorbing its own burst — while the shared chain's
bubble fractions barely move (admission interleaving keeps the pipeline
work-conserving).

  PYTHONPATH=src python examples/multi_tenant.py \
      [--tiers 2|3] [--policies fifo,rr,wdrr] [--scale 1.0]
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import numpy as np

# deployment table and tenant mix are shared with the bench so this
# walkthrough always tells the same story the emitted rows measure
from benchmarks.multitenant import DEPLOYMENTS, _tenants
from repro.core import sim
from repro.core.partitioner import coach_offline_multihop
from repro.data.pipeline import CorrelatedTaskStream, make_calibration_set
from repro.models.cnn import vgg16
from repro.serving.tenancy import make_policy, MultiTenantCoachEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiers", type=int, choices=(2, 3), default=2)
    ap.add_argument("--policies", default="fifo,wdrr")
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    devices, links = DEPLOYMENTS[args.tiers]
    graph = vgg16()
    off = coach_offline_multihop(graph, devices, links)
    st = off.times
    tenants = _tenants(st, args.scale)
    elems = max(1, int(st.link[0] * links[0].bandwidth_bps / 8))
    hop_bits = [int(np.mean(list(b.values()))) if b else 8
                for b in off.decision.all_hop_bits]

    stream = CorrelatedTaskStream(n_labels=30, dim=48,
                                  correlation="medium", seed=args.seed)
    feats, labels = make_calibration_set(stream, 400)

    def classify(task):
        d = np.linalg.norm(stream.mu - task.features[None], axis=1)
        return task.features, int(np.argmin(d))

    tasks = [stream.tasks(t.n_tasks) for t in tenants]
    print(f"[deployment] {graph.name} {args.tiers}-tier: "
          f"ingress {st.compute[0] * 1e3:.1f}ms, "
          f"single-task {st.latency * 1e3:.1f}ms, "
          f"objective {off.objective * 1e3:.1f}ms")
    for policy in args.policies.split(","):
        eng = MultiTenantCoachEngine(
            None, st, devices[0], links[0], devices[-1], 30, feats, labels,
            tenants, policy=policy, boundary_elems=elems, links=list(links),
            hop_bits_offline=hop_bits)
        mt = eng.run_streams([list(ts) for ts in tasks], classify)

        # differential sanity: the executor's timeline is pinned to the
        # multi-tenant event simulator replaying the same decided plans
        ref = sim.simulate_multitenant_stream(
            mt.plans, mt.arrivals,
            make_policy(policy, weights=[t.weight for t in tenants]),
            links=list(links))
        pinned = mt.order == ref.order and all(
            abs(a - b) < 1e-6 for a, b in zip(
                [r.done for r in mt.pipeline.tasks], ref.stream.done))

        pr = mt.pipeline
        print(f"\n[{policy}] worst-tenant p99 {mt.worst_tenant_p99 * 1e3:.0f}ms"
              f" | worst SLO-normalized p99 {mt.worst_tenant_norm_p99:.2f}"
              f" | min SLO attainment {mt.min_slo_attainment:.2%}"
              f" | pinned_to_sim={pinned}")
        print(f"  shared chain: makespan {pr.makespan * 1e3:.0f}ms, "
              f"end bubble {pr.bubble_fraction(('compute', 0)):.3f}, "
              f"cloud bubble {pr.bubble_fraction(('compute', args.tiers - 1)):.3f}")
        for rep in mt.reports:
            p = rep.stats.pipeline
            print(f"  {rep.spec.name:<12} w={rep.spec.weight:>3.0f} "
                  f"n={rep.spec.n_tasks:<4} "
                  f"p99 {p.p99_latency * 1e3:7.1f}ms "
                  f"(slo {rep.spec.slo_latency * 1e3:6.0f}ms, "
                  f"attained {rep.slo_attainment:7.2%}) "
                  f"thpt {p.throughput:6.1f}/s "
                  f"exits {rep.stats.exit_ratio:.2f}")


if __name__ == "__main__":
    main()
