"""Dynamic-network adaptation demo (Fig. 5): bandwidth drops mid-stream;
COACH's online component re-chooses precision per task and keeps the
pipeline near bubble-free while baselines degrade.

  PYTHONPATH=src python examples/dynamic_network.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.common import run_baseline, run_coach
from repro.models.cnn import resnet101


def main():
    g = resnet101()
    print("ResNet101 on Jetson-NX; bandwidth 100 -> 50 -> 20 Mbps")
    print(f"{'bw':>6} {'COACH tp':>9} {'COACH bits':>10} {'JPS tp':>7} "
          f"{'NS tp':>7}")
    for mbps in (100.0, 50.0, 20.0):
        rc = run_coach(g, "NX", mbps, "medium", n_tasks=300,
                       arrival_factor=0.0)
        rj = run_baseline("JPS", g, "NX", mbps, "medium", n_tasks=300,
                          arrival_factor=0.0)
        rn = run_baseline("NS", g, "NX", mbps, "medium", n_tasks=300,
                          arrival_factor=0.0)
        mean_bits = (8 * rc.wire_kb_per_task * 1e3 /
                     max(1 - rc.exit_ratio, 1e-9))
        print(f"{mbps:6.0f} {rc.throughput:9.1f} "
              f"{rc.wire_kb_per_task:7.1f}KB {rj.throughput:7.1f} "
              f"{rn.throughput:7.1f}")
    print("\nCOACH sheds wire volume (lower bits + exits) as bandwidth "
          "drops, holding throughput above the schedulers that cannot adapt.")


if __name__ == "__main__":
    main()
