"""Bubble-attribution walkthrough: trace a ResNet101 3-tier run, print
the per-cause idle table, and export a Perfetto/Chrome trace.

ResNet101 is partitioned by the real offline planner onto the 3-tier
deployment (Jetson-NX + AGX-Orin + A6000 — the same device/link table
the ``multihop`` bench uses), a steady stream with the hop-level
semantic-exit cascade runs through the event simulator with a live
``TraceRecorder``, and the observability layer (``repro.obs``) answers
the question ``bubble_fraction`` can't: not *how much* each resource
idled, but *why* — warmup, drain, upstream starvation, batch formation,
exit releases, and the rest of the closed cause enum, with the
conservation identity ``busy + sum(bubbles) = horizon`` checked per
resource.

The exported JSON opens in https://ui.perfetto.dev (or
``chrome://tracing``): one track per resource, busy spans on the main
row, waits and attributed bubbles on child rows.

  PYTHONPATH=src python examples/trace_viewer.py \
      [--tasks 160] [--out experiments/trace/resnet101_3tier.json]
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.multihop import DEPLOYMENTS, decide_exit_hops
from repro.core.partitioner import coach_offline_multihop
from repro.core.pipeline import plan_from_stage_times, run_pipeline
from repro.models.cnn import resnet101
from repro.obs.bubbles import attribute, chain_resources
from repro.obs.export import text_summary, write_chrome_trace
from repro.obs.trace import TraceRecorder, assert_traces_match
from repro.serving.async_engine import run_pipeline_async

N_TIERS = 3


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks", type=int, default=160)
    ap.add_argument("--out",
                    default="experiments/trace/resnet101_3tier.json")
    args = ap.parse_args()

    devices, links = DEPLOYMENTS[N_TIERS]
    off = coach_offline_multihop(resnet101(), devices, links)
    st = off.times
    period = st.max_stage * 1.05
    exit_hops = decide_exit_hops(N_TIERS - 1, args.tasks)
    plans = [plan_from_stage_times(st, exit_hop=eh) for eh in exit_hops]

    rec = TraceRecorder()
    pr = run_pipeline(plans, arrival_period=period, links=list(links),
                      sink=rec)
    # the differential pin extends to span timelines: the executor's
    # trace of the same stream is the same trace
    rec_a = TraceRecorder()
    run_pipeline_async(plans, arrival_period=period, links=list(links),
                       sink=rec_a)
    assert_traces_match(rec, rec_a, tol=1e-6)

    att = attribute(rec, resources=chain_resources(
        pr.n_hops, pr.pool_sizes or None))
    print(f"model=resnet101 tiers={N_TIERS} tasks={args.tasks} "
          f"exit_ratio={pr.exit_ratio:.2%} makespan={pr.makespan:.3f}s "
          f"spans={len(rec)} (sim == async at 1e-6)")
    print()
    print(text_summary(att))

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    write_chrome_trace(out, rec, att)
    print()
    print(f"wrote {out} — open in https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
