"""Bubble attribution: classify every idle gap on every resource.

The paper's objective (Eq. 5-6) is a sum of per-resource idle time
("bubbles").  ``PipelineResult.bubble_fraction`` reports *how much* a
resource idled; this module says *why*.  Given a span trace
(``repro.obs.trace``), ``attribute`` partitions each resource's horizon
into busy intervals and attributed gaps, assigning every gap exactly
one cause from the closed set ``CAUSES``:

``warmup`` / ``drain``
    before the resource's first busy interval / after its last — the
    pipeline fill/flush cost every stream pays.
``upstream_starvation``
    the next task's input was not ready until the gap's end and no more
    specific mechanism explains the delay (sparse arrivals, slow
    upstream service).
``downstream_backpressure``
    work *was* ready before the gap ended yet the resource stayed idle
    — the signature of a bounded-queue stall (the upstream worker sat
    blocked on a full queue after finishing service).  Always zero in
    simulator traces and in pinned unbounded-queue runs.
``batch_formation``
    the delivering upstream service interval was a multi-member
    micro-batch, so the head's data surfaced only when the whole batch
    finished.
``sequencer_reorder``
    a pool sequencer held the head's release to restore stream order
    (``seq_hold`` span overlapping the gap).
``ingress_credit``
    the multi-tenant admission gate withheld the head until a credit
    freed (``credit_wait`` span ending at the gap's end).
``exit_released``
    a semantic early exit upstream released this resource during the
    gap: tasks that would have occupied it never arrived.
``replanning``
    the head task was migrated to a new plan at an upstream hop
    boundary during the gap (``replan`` span): the idle time is the
    cost of switching cut/bits mid-stream, not steady-state starvation.

Classification precedence (first match wins, documented order):
``warmup``/``drain`` by position; then the two mechanisms that delay a
head task *past its own readiness* — ``ingress_credit`` (tier-0
compute) and ``sequencer_reorder`` (links); then, when the head was
not ready before the gap closed, ``batch_formation``,
``exit_released``, ``replanning``, ``upstream_starvation`` in that
order; otherwise ``downstream_backpressure``.  Gaps partition the horizon
minus the busy union by construction, so the conservation identity

    ``busy + sum(attributed bubbles) == horizon``        (per resource)

holds to float-summation error; ``Attribution.conservation_error``
recomputes both sides independently so tests can gate it at 1e-9.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs.trace import (CREDIT_WAIT, EXIT_RELEASE, REPLAN, SEQ_HOLD,
                             SERVICE, XFER, Resource, Span, TraceLike,
                             is_link, resource_label, spans_of, tier_of)

__all__ = [
    "WARMUP", "DRAIN", "UPSTREAM_STARVATION", "DOWNSTREAM_BACKPRESSURE",
    "BATCH_FORMATION", "SEQUENCER_REORDER", "INGRESS_CREDIT",
    "EXIT_RELEASED", "REPLANNING", "CAUSES", "Bubble", "Attribution",
    "attribute", "chain_resources",
]

WARMUP = "warmup"
DRAIN = "drain"
UPSTREAM_STARVATION = "upstream_starvation"
DOWNSTREAM_BACKPRESSURE = "downstream_backpressure"
BATCH_FORMATION = "batch_formation"
SEQUENCER_REORDER = "sequencer_reorder"
INGRESS_CREDIT = "ingress_credit"
EXIT_RELEASED = "exit_released"
REPLANNING = "replanning"

#: The closed cause set — every attributed gap carries exactly one.
CAUSES = (WARMUP, DRAIN, UPSTREAM_STARVATION, DOWNSTREAM_BACKPRESSURE,
          BATCH_FORMATION, SEQUENCER_REORDER, INGRESS_CREDIT,
          EXIT_RELEASED, REPLANNING)


@dataclass(frozen=True)
class Bubble:
    """One attributed idle interval on one resource."""

    resource: Resource
    t0: float
    t1: float
    cause: str
    task: Optional[int] = None  # head task whose start closed the gap

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


@dataclass
class Attribution:
    """Per-resource busy totals plus the attributed bubble list."""

    horizon: Tuple[float, float]
    busy: Dict[Resource, float]
    bubbles: List[Bubble] = field(default_factory=list)

    @property
    def horizon_s(self) -> float:
        return self.horizon[1] - self.horizon[0]

    def resources(self) -> List[Resource]:
        return sorted(self.busy)

    def seconds(self) -> Dict[Resource, Dict[str, float]]:
        """``{resource: {cause: seconds}}`` with every cause present."""
        out = {r: {c: 0.0 for c in CAUSES} for r in self.busy}
        for b in self.bubbles:
            out[b.resource][b.cause] += b.dur
        return out

    def total(self, resource: Optional[Resource] = None,
              cause: Optional[str] = None) -> float:
        return sum(b.dur for b in self.bubbles
                   if (resource is None or b.resource == resource)
                   and (cause is None or b.cause == cause))

    def conservation_error(self) -> Dict[Resource, float]:
        """``|busy + sum(bubbles) - horizon|`` per resource.

        ``busy`` comes from the busy-interval union and the bubbles
        from the gap walk — independent summations, so this is a real
        check of the partition, not an identity.
        """
        h = self.horizon_s
        return {r: abs(self.busy[r] + self.total(r) - h) for r in self.busy}

    def max_conservation_error(self) -> float:
        errs = self.conservation_error()
        return max(errs.values()) if errs else 0.0

    def by_label(self) -> Dict[str, Dict[str, float]]:
        """JSON-friendly view: ``{label: {cause: seconds}}``."""
        return {resource_label(r): cs for r, cs in self.seconds().items()}

    def busy_by_label(self) -> Dict[str, float]:
        return {resource_label(r): v for r, v in self.busy.items()}


def chain_resources(n_hops: int,
                    pool_sizes: Optional[Sequence[int]] = None
                    ) -> List[Resource]:
    """The full resource set of an ``n_hops``-hop pipeline, including
    resources a traced run may never have touched (so fully-idle
    replicas still get a conservation row)."""
    sizes = list(pool_sizes) if pool_sizes else [1] * (n_hops + 1)
    out: List[Resource] = []
    for k in range(n_hops + 1):
        out.extend(("compute", k, r) for r in range(sizes[k]))
        if k < n_hops:
            out.append(("link", k))
    return out


def _union_length(ivs: List[Tuple[float, float]]) -> float:
    total, end = 0.0, None
    for s, e in ivs:
        if end is None or s > end:
            total += e - s
            end = e
        elif e > end:
            total += e - end
            end = e
    return total


def _skips(resource: Resource, exit_hop: int) -> bool:
    """Did a task exiting at ``exit_hop`` skip ``resource``?  An exit at
    hop ``e`` occupies compute ``0..e`` and links ``0..e-1``."""
    k = tier_of(resource)
    return exit_hop <= k if is_link(resource) else exit_hop < k


def attribute(trace: TraceLike,
              resources: Optional[Sequence[Resource]] = None,
              horizon: Optional[Tuple[float, float]] = None,
              eps: float = 1e-9) -> Attribution:
    """Attribute every idle gap of every resource to one cause.

    ``resources`` defaults to those with busy spans in the trace; pass
    ``chain_resources(...)`` to account for never-touched replicas.
    ``horizon`` defaults to ``[min span t0, max span t1]`` — the
    stream's makespan window, matching ``PipelineResult.makespan``.
    ``eps`` is the instant-coincidence tolerance used by the
    classification predicates (the instants compared originate from one
    engine, so coincident events are exact-float equal in practice).
    """
    spans = spans_of(trace)
    if not spans:
        return Attribution((0.0, 0.0), {r: 0.0 for r in resources or ()})
    if horizon is None:
        horizon = (min(s.t0 for s in spans), max(s.t1 for s in spans))
    h0, h1 = horizon

    busy_spans: Dict[Resource, List[Span]] = {}
    seq_holds: Dict[int, List[Span]] = {}
    credits: Dict[int, Span] = {}
    exits: List[Tuple[float, int]] = []
    replans: Dict[int, List[Tuple[float, int]]] = {}
    member_batch: Dict[Tuple[int, int], int] = {}
    for s in spans:
        if s.kind in (SERVICE, XFER):
            busy_spans.setdefault(s.resource, []).append(s)
            if s.kind == SERVICE and s.tasks is not None:
                k, n = tier_of(s.resource), s.batch or len(s.tasks)
                for t in s.tasks:
                    member_batch[(k, t)] = n
        elif s.kind == SEQ_HOLD:
            seq_holds.setdefault(s.task, []).append(s)
        elif s.kind == CREDIT_WAIT:
            credits[s.task] = s
        elif s.kind == EXIT_RELEASE:
            exits.append((s.t0, s.hop))
        elif s.kind == REPLAN:
            replans.setdefault(s.task, []).append((s.t0, s.hop))

    if resources is None:
        resources = sorted(busy_spans)

    def classify(res: Resource, g0: float, g1: float,
                 head: Span) -> str:
        k = tier_of(res)
        link = is_link(res)
        ready = head.ready if head.ready is not None else g1
        if not link and k == 0:
            c = credits.get(head.task)
            if c is not None and c.t1 >= g1 - eps and c.t1 > ready + eps:
                return INGRESS_CREDIT
        if link:
            # a sequencer hold delays the head past its own release
            # (``ready`` = tx_ready < gap end), so check it before the
            # readiness gate — exactly like the ingress credit above
            for h in seq_holds.get(head.task, ()):
                if h.resource == res and h.t1 >= g1 - eps \
                        and h.t1 > ready + eps:
                    return SEQUENCER_REORDER
        if ready >= g1 - eps:
            src_tier = k if link else k - 1
            if src_tier >= 0 and member_batch.get(
                    (src_tier, head.task), 1) >= 2:
                return BATCH_FORMATION
            for t, hop in exits:
                if g0 - eps <= t <= g1 + eps and _skips(res, hop):
                    return EXIT_RELEASED
            # a migration at an upstream boundary during the gap: the
            # head's arrival was delayed by the plan switch (a replan at
            # hop j takes effect on link j, so it feeds link k >= j and
            # compute k > j)
            for t, hop in replans.get(head.task, ()):
                if g0 - eps <= t <= g1 + eps \
                        and (hop <= k if link else hop < k):
                    return REPLANNING
            return UPSTREAM_STARVATION
        return DOWNSTREAM_BACKPRESSURE

    busy: Dict[Resource, float] = {}
    bubbles: List[Bubble] = []
    for res in resources:
        ivs = sorted(busy_spans.get(res, []), key=lambda s: (s.t0, s.t1))
        busy[res] = _union_length([(s.t0, s.t1) for s in ivs])
        if not ivs:
            if h1 > h0 + eps:
                cause = EXIT_RELEASED if any(
                    _skips(res, hop) for _, hop in exits) else WARMUP
                bubbles.append(Bubble(res, h0, h1, cause))
            continue
        cur = h0
        first = True
        for sp in ivs:
            if sp.t0 > cur + eps:
                cause = WARMUP if first else classify(res, cur, sp.t0, sp)
                bubbles.append(Bubble(res, cur, sp.t0, cause, sp.task))
            if sp.t1 > cur:
                cur = sp.t1
            first = False
        if h1 > cur + eps:
            bubbles.append(Bubble(res, cur, h1, DRAIN))
    return Attribution(horizon, busy, bubbles)
