"""Counters / gauges / histograms registry the engines populate.

``MetricsRegistry`` is deliberately dependency-free (no numpy) and
flat-keyed: ``inc("tier0.route.r1")``, ``observe("tier1.batch_size",
4)``, ``set_gauge("makespan_s", 0.12)``.  The ``populate_from_*``
helpers derive the standard serving metrics from a finished run —
per-tier queue-wait and realized batch-size histograms, router-choice
counters, per-cause bubble seconds — so callers can hang one registry
on ``EngineConfig.metrics`` and read everything back after
``run_stream``.
"""

from __future__ import annotations

from typing import Dict, List, Optional

from repro.obs.bubbles import Attribution
from repro.obs.trace import (BATCH_FORM, CREDIT_WAIT, ENQUEUE, EXIT_RELEASE,
                             ROUTE, SEQ_HOLD, SERVICE, XFER, resource_label,
                             spans_of, tier_of)

__all__ = ["MetricsRegistry", "populate_from_trace",
           "populate_from_attribution", "populate_from_result"]


def _percentile(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    ys = sorted(xs)
    i = min(len(ys) - 1, max(0, int(round(q * (len(ys) - 1)))))
    return ys[i]


class MetricsRegistry:
    """Flat-keyed counters, gauges, and histograms."""

    def __init__(self) -> None:
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self._hists: Dict[str, List[float]] = {}

    # ------------------------------------------------------------ write
    def inc(self, name: str, v: float = 1.0) -> None:
        self.counters[name] = self.counters.get(name, 0.0) + v

    def set_gauge(self, name: str, v: float) -> None:
        self.gauges[name] = float(v)

    def observe(self, name: str, v: float) -> None:
        self._hists.setdefault(name, []).append(float(v))

    # ------------------------------------------------------------- read
    def counter(self, name: str) -> float:
        return self.counters.get(name, 0.0)

    def histogram(self, name: str) -> Dict[str, float]:
        xs = self._hists.get(name, [])
        return {"count": float(len(xs)),
                "sum": sum(xs),
                "mean": sum(xs) / len(xs) if xs else 0.0,
                "p50": _percentile(xs, 0.50),
                "p99": _percentile(xs, 0.99),
                "max": max(xs) if xs else 0.0}

    def snapshot(self) -> dict:
        return {"counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "histograms": {n: self.histogram(n) for n in self._hists}}

    def render(self) -> str:
        snap = self.snapshot()
        lines = []
        for name in sorted(snap["counters"]):
            lines.append(f"counter {name} = {snap['counters'][name]:g}")
        for name in sorted(snap["gauges"]):
            lines.append(f"gauge   {name} = {snap['gauges'][name]:g}")
        for name in sorted(snap["histograms"]):
            h = snap["histograms"][name]
            lines.append(f"hist    {name}: n={h['count']:g} "
                         f"mean={h['mean']:g} p50={h['p50']:g} "
                         f"p99={h['p99']:g} max={h['max']:g}")
        return "\n".join(lines)


def populate_from_trace(reg: MetricsRegistry, trace) -> None:
    """Standard span-derived metrics: queue waits, batch sizes, router
    choices, hold/credit waits, exit counts, per-resource busy."""
    for s in spans_of(trace):
        k = tier_of(s.resource)
        if s.kind == SERVICE:
            reg.inc(f"tier{k}.batches")
            if s.batch is not None:
                reg.observe(f"tier{k}.batch_size", s.batch)
            if s.ready is not None:
                reg.observe(f"tier{k}.queue_wait_s", s.t0 - s.ready)
            reg.inc(f"busy_s.{resource_label(s.resource)}", s.t1 - s.t0)
        elif s.kind == XFER:
            reg.inc(f"link{k}.xfers")
            reg.inc(f"busy_s.link{k}", s.t1 - s.t0)
            if s.ready is not None:
                reg.observe(f"link{k}.queue_wait_s", s.t0 - s.ready)
        elif s.kind == ROUTE:
            reg.inc(f"tier{k}.route.r{s.replica}")
        elif s.kind == BATCH_FORM:
            reg.observe(f"tier{k}.batch_form_wait_s", s.t1 - s.t0)
        elif s.kind == SEQ_HOLD:
            reg.observe(f"link{k}.seq_hold_s", s.t1 - s.t0)
        elif s.kind == CREDIT_WAIT:
            reg.observe("ingress.credit_wait_s", s.t1 - s.t0)
        elif s.kind == EXIT_RELEASE:
            reg.inc(f"exits.hop{s.hop}")
        elif s.kind == ENQUEUE:
            reg.inc(f"tier{k}.enqueues")


def populate_from_attribution(reg: MetricsRegistry,
                              att: Attribution) -> None:
    """Per-cause bubble seconds (``bubble_s.<resource>.<cause>``)."""
    reg.set_gauge("horizon_s", att.horizon_s)
    for res, causes in att.seconds().items():
        label = resource_label(res)
        for cause, secs in causes.items():
            if secs:
                reg.inc(f"bubble_s.{label}.{cause}", secs)


def populate_from_result(reg: MetricsRegistry, pr,
                         pool_sizes: Optional[List[int]] = None) -> None:
    """Gauges from a ``PipelineResult``: makespan, realized batch sizes,
    classic bubble fractions."""
    reg.set_gauge("makespan_s", pr.makespan)
    try:
        from repro.serving.batching import realized_batch_sizes
        for k, b in enumerate(realized_batch_sizes(pr)):
            reg.set_gauge(f"tier{k}.realized_batch", b)
    except Exception:
        pass
    n_tiers = len(pr.compute_intervals)
    for k in range(n_tiers):
        reg.set_gauge(f"bubble_frac.compute{k}",
                      pr.bubble_fraction(("compute", k)))
    for k in range(n_tiers - 1):
        reg.set_gauge(f"bubble_frac.link{k}",
                      pr.bubble_fraction(("link", k)))
