"""Observability: span tracing, bubble attribution, export, metrics.

The package decomposes a run's idle time ("bubbles", the paper's Eq. 5-6
objective) into *causes*.  ``trace`` records per-task / per-resource
spans emitted by both the arithmetic simulator (``repro.core.sim``) and
the async executor (``repro.serving.async_engine``) behind a
zero-cost-when-disabled sink hook; ``bubbles`` classifies every idle gap
on every resource into a closed cause set under a conservation identity
(``busy + sum(bubbles) == horizon`` per resource); ``export`` renders
Chrome/Perfetto ``trace_event`` JSON and text tables; ``metrics`` is the
counters/gauges/histograms registry the engines populate.
"""

from repro.obs import bubbles, export, metrics, trace  # noqa: F401

__all__ = ["trace", "bubbles", "export", "metrics"]
