"""Trace export: Chrome/Perfetto ``trace_event`` JSON + text summaries.

``to_chrome_trace`` maps a span trace onto the Chrome trace-event
format (https://ui.perfetto.dev loads it directly): one process per
pipeline, one thread row per resource in chain order, complete
(``"ph": "X"``) events for busy/wait spans and instant (``"ph": "i"``)
events for points.  When an ``Attribution`` is supplied, each
resource additionally gets a ``<label>/bubbles`` row whose events are
the attributed idle gaps named by cause — the "why is this row empty"
answer rendered right under the timeline.

Timestamps are converted from seconds to the format's microseconds.
"""

from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.obs.bubbles import Attribution
from repro.obs.trace import (BATCH_FORM, CREDIT_WAIT, SEQ_HOLD, SERVICE,
                             XFER, Resource, canonical, is_link,
                             resource_label, tier_of)

__all__ = ["to_chrome_trace", "write_chrome_trace", "text_summary"]

_US = 1e6
_DUR_KINDS = (SERVICE, XFER, SEQ_HOLD, CREDIT_WAIT, BATCH_FORM)
_WAIT_KINDS = (SEQ_HOLD, CREDIT_WAIT, BATCH_FORM)


def _resource_order(res: Resource):
    # chain order: compute0 replicas, link0, compute1 replicas, ...
    return (tier_of(res), 1 if is_link(res) else 0,
            res[2] if len(res) > 2 else -1)


def to_chrome_trace(trace, attribution: Optional[Attribution] = None,
                    pid: int = 1) -> dict:
    """Render a trace (and optional attribution) as a trace-event dict."""
    spans = canonical(trace)
    rows: Dict[str, int] = {}

    def tid_of(label: str) -> int:
        if label not in rows:
            rows[label] = len(rows) + 1
        return rows[label]

    # register busy rows first, in chain order, so the viewer lays the
    # pipeline out top-to-bottom
    for res in sorted({s.resource for s in spans
                       if s.kind in (SERVICE, XFER)}, key=_resource_order):
        tid_of(resource_label(res))

    events: List[dict] = []
    for s in spans:
        label = resource_label(s.resource)
        if s.kind in _WAIT_KINDS:
            label += "/waits"
        args = {k: v for k, v in (("task", s.task), ("tasks", s.tasks),
                                  ("ready", s.ready), ("batch", s.batch),
                                  ("hop", s.hop), ("replica", s.replica),
                                  ("seq", s.seq)) if v is not None}
        ev = {"name": s.kind if s.kind in _DUR_KINDS
              else f"{s.kind}#{s.task}",
              "cat": s.kind, "pid": pid, "tid": tid_of(label),
              "ts": s.t0 * _US, "args": args}
        if s.kind in _DUR_KINDS:
            ev["ph"] = "X"
            ev["dur"] = max(0.0, (s.t1 - s.t0) * _US)
        else:
            ev["ph"] = "i"
            ev["s"] = "t"
        events.append(ev)

    if attribution is not None:
        for b in attribution.bubbles:
            label = resource_label(b.resource) + "/bubbles"
            events.append({"name": b.cause, "cat": "bubble", "ph": "X",
                           "pid": pid, "tid": tid_of(label),
                           "ts": b.t0 * _US,
                           "dur": max(0.0, b.dur * _US),
                           "args": {} if b.task is None
                           else {"task": b.task}})

    meta = [{"name": "process_name", "ph": "M", "pid": pid,
             "args": {"name": "pipeline"}}]
    meta.extend({"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
                 "args": {"name": label}} for label, tid in rows.items())
    meta.extend({"name": "thread_sort_index", "ph": "M", "pid": pid,
                 "tid": tid, "args": {"sort_index": tid}}
                for tid in rows.values())
    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


def write_chrome_trace(path, trace,
                       attribution: Optional[Attribution] = None) -> str:
    """Write the trace-event JSON to ``path``; returns the path."""
    doc = to_chrome_trace(trace, attribution)
    with open(path, "w") as f:
        json.dump(doc, f)
    return str(path)


def text_summary(attribution: Attribution,
                 unit: float = 1e3, unit_name: str = "ms") -> str:
    """Per-resource, per-cause table (plus busy and conservation check).

    ``unit`` scales seconds into the displayed unit (default ms).
    """
    secs = attribution.seconds()
    causes = [c for c in next(iter(secs.values()), {})]
    if not causes:
        return "(empty trace)"
    active = [c for c in causes
              if any(cs[c] > 0.0 for cs in secs.values())]
    head = ["resource", f"busy_{unit_name}"] + \
        [f"{c}_{unit_name}" for c in active] + ["bubble_frac"]
    h = attribution.horizon_s
    lines = ["  ".join(f"{x:>22}" if i == 0 else f"{x:>15}"
                       for i, x in enumerate(head))]
    for res in attribution.resources():
        busy = attribution.busy[res]
        row = [resource_label(res), f"{busy * unit:.3f}"]
        row += [f"{secs[res][c] * unit:.3f}" for c in active]
        row.append(f"{(1.0 - busy / h) if h > 0 else 0.0:.3f}")
        lines.append("  ".join(f"{x:>22}" if i == 0 else f"{x:>15}"
                               for i, x in enumerate(row)))
    lines.append(f"horizon = {h * unit:.3f} {unit_name}; max "
                 f"|busy + bubbles - horizon| = "
                 f"{attribution.max_conservation_error():.2e} s")
    return "\n".join(lines)
