"""Lightweight span recorder shared by the simulator and the executors.

A *span* is one timed event on one resource of the ``2n+1``
compute/link pipeline (or its pooled generalization).  Both engines —
the arithmetic simulator in ``repro.core.sim`` and the asyncio executor
in ``repro.serving.async_engine`` — emit the *same* spans with the
*same* values, so the repo's differential-pin invariant extends to
traces: ``assert_traces_match(sim_trace, async_trace, tol=1e-6)``.

Span kinds (the closed vocabulary):

======================  ====================================================
``enqueue``             point: task entered a compute tier's input queue
``route``               point: pooled tier placed a task on a replica
``batch_form``          wait: a batch follower's input-ready -> batch start
``service``             busy: a compute interval (carries the batch)
``seq_hold``            wait: pool sequencer held a release to restore order
``xfer``                busy: a link transfer interval
``credit_wait``         wait: multi-tenant ingress arrival -> credit grant
``exit_release``        point: semantic exit freed all downstream resources
``replan``              point: task migrated to a new plan at a hop boundary
======================  ====================================================

Resources are tuples: ``("compute", k, r)`` for replica ``r`` of tier
``k`` (serial chains use ``r = 0``), ``("link", k)`` for hop ``k``'s
link; tier-level task events (``enqueue``, ``credit_wait``) use
``("compute", k)``.

The sink contract is *zero cost when disabled*: every emission site is
guarded by ``if sink is not None`` so the disabled path performs no
allocation and no call.  ``TraceRecorder`` is the default sink (an
append-only list); anything with a ``span(...)`` method works.  Hot
emitters (the executor's workers) pass *prefix tuples* of the Span
fields instead of constructed ``Span`` objects; ``TraceRecorder``
normalizes lazily — see its docstring.
"""

from __future__ import annotations

from typing import List, NamedTuple, Optional, Sequence, Tuple, Union

__all__ = [
    "ENQUEUE", "ROUTE", "BATCH_FORM", "SERVICE", "SEQ_HOLD", "XFER",
    "CREDIT_WAIT", "EXIT_RELEASE", "REPLAN", "SPAN_KINDS", "Span",
    "TraceRecorder", "spans_of", "canonical", "traces_match",
    "assert_traces_match", "resource_label", "tier_of", "is_link",
]

ENQUEUE = "enqueue"
ROUTE = "route"
BATCH_FORM = "batch_form"
SERVICE = "service"
SEQ_HOLD = "seq_hold"
XFER = "xfer"
CREDIT_WAIT = "credit_wait"
EXIT_RELEASE = "exit_release"
REPLAN = "replan"

SPAN_KINDS = (ENQUEUE, ROUTE, BATCH_FORM, SERVICE, SEQ_HOLD, XFER,
              CREDIT_WAIT, EXIT_RELEASE, REPLAN)

Resource = Tuple  # ("compute", k[, r]) | ("link", k)


class Span(NamedTuple):
    """One trace event.  ``t0 == t1`` for point events.

    ``task`` is the owning task (the batch head for ``service``);
    ``tasks`` the full batch membership; ``ready`` the head's
    input-ready instant (``tx_ready`` for ``xfer``); ``batch`` the
    realized batch size; ``hop`` the exit hop for ``exit_release`` (and
    the boundary a ``replan`` migration took effect at);
    ``replica``/``seq`` the routing decision for ``route``.
    """

    kind: str
    resource: Resource
    t0: float
    t1: float
    task: Optional[int] = None
    tasks: Optional[Tuple[int, ...]] = None
    ready: Optional[float] = None
    batch: Optional[int] = None
    hop: Optional[int] = None
    replica: Optional[int] = None
    seq: Optional[int] = None


class TraceRecorder:
    """Default ``TraceSink``: records spans, exposed as ``self.spans``.

    ``span`` accepts a full ``Span`` or a *prefix tuple* of its fields
    in declaration order (missing trailing fields default to ``None``).
    The prefix form is the executor's hot path: appending a plain tuple
    literal costs a fraction of a keyword ``Span(...)`` construction,
    which is what keeps enabled tracing inside the <5% overhead gate.
    Normalization to ``Span`` happens lazily (and is cached) when
    ``spans`` is first read.
    """

    __slots__ = ("_raw", "_spans")

    def __init__(self) -> None:
        self._raw: list = []
        self._spans: Optional[List[Span]] = None

    def span(self, s) -> None:
        self._raw.append(s)

    @property
    def spans(self) -> List[Span]:
        if self._spans is None or len(self._spans) != len(self._raw):
            self._spans = [s if type(s) is Span else Span(*s)
                           for s in self._raw]
        return self._spans

    def clear(self) -> None:
        self._raw.clear()
        self._spans = None

    def __len__(self) -> int:
        return len(self._raw)

    def __iter__(self):
        return iter(self.spans)


TraceLike = Union[TraceRecorder, Sequence[Span]]


def spans_of(trace: TraceLike) -> List[Span]:
    """Accept a ``TraceRecorder`` or a plain span sequence."""
    return list(getattr(trace, "spans", trace))


def tier_of(resource: Resource) -> int:
    return int(resource[1])


def is_link(resource: Resource) -> bool:
    return resource[0] == "link"


def resource_label(resource: Resource) -> str:
    """Stable human/JSON label: ``compute0/r1``, ``compute2``, ``link0``."""
    if resource[0] == "link":
        return f"link{resource[1]}"
    if len(resource) == 2:
        return f"compute{resource[1]}"
    return f"compute{resource[1]}/r{resource[2]}"


def _sort_key(s: Span):
    # Engines emit in different orders (the simulator replays stage by
    # stage, the executor interleaves in virtual time), so comparisons
    # sort canonically.  Discrete fields lead: float ties then cannot
    # reorder matched pairs across engines.
    return (s.kind, s.resource, -1 if s.task is None else s.task,
            -1 if s.seq is None else s.seq, s.t0, s.t1)


def canonical(trace: TraceLike) -> List[Span]:
    """Spans in the canonical (engine-independent) order."""
    return sorted(spans_of(trace), key=_sort_key)


def _span_diff(a: Span, b: Span, tol: float) -> Optional[str]:
    if (a.kind, a.resource, a.task, a.tasks, a.batch, a.hop, a.replica,
            a.seq) != (b.kind, b.resource, b.task, b.tasks, b.batch,
                       b.hop, b.replica, b.seq):
        return f"field mismatch: {a} != {b}"
    for name in ("t0", "t1", "ready"):
        x, y = getattr(a, name), getattr(b, name)
        if (x is None) != (y is None):
            return f"{name} presence mismatch: {a} != {b}"
        if x is not None and abs(x - y) > tol:
            return f"{name} off by {abs(x - y):.3e} (> {tol:g}): {a} != {b}"
    return None


def traces_match(a: TraceLike, b: TraceLike,
                 tol: float = 1e-6) -> Tuple[bool, str]:
    """Compare two traces after canonical sorting.

    Discrete fields must match exactly; instants (``t0``/``t1``/
    ``ready``) to ``tol``.  Returns ``(ok, first_difference)``.
    """
    ca, cb = canonical(a), canonical(b)
    if len(ca) != len(cb):
        return False, f"span count {len(ca)} != {len(cb)}"
    for sa, sb in zip(ca, cb):
        msg = _span_diff(sa, sb, tol)
        if msg is not None:
            return False, msg
    return True, ""


def assert_traces_match(a: TraceLike, b: TraceLike,
                        tol: float = 1e-6) -> None:
    ok, msg = traces_match(a, b, tol)
    assert ok, msg
