"""Architecture registry + assigned input shapes.

``get_config(arch_id)`` returns the full production ModelConfig;
``get_config(arch_id).reduced()`` is the CPU smoke variant.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Tuple

from repro.models.config import ModelConfig

ARCHS: Dict[str, str] = {
    "mamba2-130m": "mamba2_130m",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "gemma2-2b": "gemma2_2b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "gemma-7b": "gemma_7b",
    "mixtral-8x7b": "mixtral_8x7b",
    "hubert-xlarge": "hubert_xlarge",
    "qwen3-14b": "qwen3_14b",
}


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch_id]}")
    return mod.CONFIG


def shape_supported(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether (arch, shape) is a runnable pair; reason if not (DESIGN.md §4)."""
    if shape.kind == "decode" and not cfg.supports_decode:
        return False, "encoder-only: no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure global attention: long-context decode skipped"
    return True, ""


def all_pairs() -> List[Tuple[str, str, bool, str]]:
    out = []
    for a in ARCHS:
        cfg = get_config(a)
        for s in SHAPES.values():
            ok, why = shape_supported(cfg, s)
            out.append((a, s.name, ok, why))
    return out
