"""llama4-scout-17b-a16e [moe] — MoE 16e top-1 + shared expert, early fusion,
iRoPE-style chunked-local attention (3 of 4 layers, 8k chunks)
[hf:meta-llama/Llama-4-Scout-17B-16E].
"""
from repro.models.config import LayerSpec, ModelConfig

_chunk = LayerSpec(mixer="attn", attn_kind="chunked", moe=True)
_glob = LayerSpec(mixer="attn", attn_kind="global", moe=True)

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    arch_type="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,               # per-expert FFN width
    vocab_size=202048,
    pattern=(_chunk, _chunk, _chunk, _glob),
    attn_chunk=8192,
    rope_theta=500_000.0,
    num_experts=16,
    experts_per_token=1,     # top-1 routing
    shared_expert=True,      # always-on shared expert
    tie_embeddings=False,
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
)
