"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060]."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    arch_type="ssm",
    num_layers=24,
    d_model=768,
    num_heads=24,            # unused (attention-free); kept for cost model
    num_kv_heads=24,
    head_dim=64,
    d_ff=0,                  # pure mamba stack, no FFN
    vocab_size=50280,
    pattern=(LayerSpec(mixer="mamba"),),
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    use_rope=False,
    citation="arXiv:2405.21060",
)
