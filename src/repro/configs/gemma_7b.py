"""gemma-7b [dense] — GeGLU, head_dim=256, MHA (kv=16) [arXiv:2403.08295]."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    arch_type="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    pattern=(LayerSpec(mixer="attn", attn_kind="global"),),
    mlp_act="gelu",
    scale_embeddings=True,
    citation="arXiv:2403.08295",
)
