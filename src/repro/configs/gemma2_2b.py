"""gemma2-2b [dense] — alternating local(4k SWA)/global attention, logit
softcaps, GeGLU, sqrt(d) embedding scaling [arXiv:2408.00118]."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    arch_type="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    pattern=(LayerSpec(mixer="attn", attn_kind="local"),
             LayerSpec(mixer="attn", attn_kind="global")),
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    mlp_act="gelu",
    scale_embeddings=True,
    citation="arXiv:2408.00118",
)
