"""hubert-xlarge [audio] — encoder-only transformer (wav2vec2-style backbone)
[arXiv:2106.07447].

Backbone only: the mel-spectrogram + conv feature extractor is a stub —
``input_specs()`` feeds precomputed frame embeddings (B, S, d_model).
Encoder-only => no decode shapes (noted in DESIGN.md).
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    arch_type="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,          # masked-prediction cluster targets
    pattern=(LayerSpec(mixer="attn", attn_kind="global"),),
    causal=False,            # bidirectional encoder
    mlp_act="gelu",
    embed_inputs=True,
    tie_embeddings=False,
    citation="arXiv:2106.07447",
)
