"""qwen3-14b [dense] — qk_norm, GQA kv=8 [hf:Qwen/Qwen3-8B]."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    arch_type="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    pattern=(LayerSpec(mixer="attn", attn_kind="global"),),
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    citation="hf:Qwen/Qwen3-8B",
)
