"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e
top-2 on every second layer [arXiv:2403.19887].

Period-8 block: attention at offset 4 (attn_layer_period=8, offset=4), MoE at
odd offsets (e:2 stride).  We use Mamba2/SSD mixers (this repo's SSM
substrate) in place of Jamba's Mamba1 — noted in DESIGN.md; no explicit
positional encoding (Jamba relies on the SSM for position).
"""
from repro.models.config import LayerSpec, ModelConfig

def _spec(i: int) -> LayerSpec:
    mixer = "attn" if i == 4 else "mamba"
    return LayerSpec(mixer=mixer, attn_kind="global", moe=(i % 2 == 1))

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    pattern=tuple(_spec(i) for i in range(8)),
    use_rope=False,          # Jamba: no explicit PE
    num_experts=16,
    experts_per_token=2,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    tie_embeddings=False,
    citation="arXiv:2403.19887",
)
