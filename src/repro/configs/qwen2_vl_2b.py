"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].

Backbone only: the ViT/SigLIP frontend is a stub — ``input_specs()`` feeds
precomputed patch embeddings of shape (B, S, d_model).
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    arch_type="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,          # GQA kv=2
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    pattern=(LayerSpec(mixer="attn", attn_kind="global"),),
    mrope_sections=(16, 24, 24),  # M-RoPE (t,h,w) over head_dim/2=64
    rope_theta=1_000_000.0,
    embed_inputs=True,       # stub frontend provides embeddings
    tie_embeddings=False,
    citation="arXiv:2409.12191",
)
