"""Dual-engine scenario runners: every scenario run is a pin check.

:func:`run_dual` executes one compiled scenario on *both* engines —
``core.pipeline.run_pipeline`` (arithmetic replay) and
``serving.async_engine.run_pipeline_async`` (event-driven executor) —
with fresh trace recorders, fresh routers, and a reset migration hook
per run, then asserts the span traces match at the repo's 1e-6
differential tolerance.  The scenario layer never gets a result the two
engines disagree on; the pin is the API, not an optional test.

:func:`run_chain_scenario` is the end-to-end path for a serial-chain
deployment: compile the timeline's link shifts into traced profiles,
run the deterministic re-planning pass (``replan_timeline``), and
execute the versioned plan schedule with hop-boundary migration on both
engines.  :func:`run_churn_scenario` is the replicated-pool path:
compile replica down-windows into an :class:`AvailabilityRouter` and
pin the churn storyline (the chain ``migrate`` hook does not apply on
the pool path — the sim rejects it — so churn runs are static-plan).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple

from repro.core.costs import DeviceProfile, LinkProfile, ModelGraph
from repro.core.pipeline import PipelineResult, TaskPlan, run_pipeline
from repro.obs.trace import TraceRecorder, assert_traces_match
from repro.scenarios.churn import router_factory
from repro.scenarios.events import Timeline
from repro.scenarios.replan import PlanSchedule, PlanVersion, replan_timeline
from repro.serving.async_engine import run_pipeline_async

__all__ = ["ScenarioResult", "run_dual", "run_chain_scenario",
           "run_churn_scenario"]

#: Differential tolerance (seconds) pinned on every scenario run.
PIN_TOL = 1e-6

ARRIVAL_SLACK = 1.05


@dataclasses.dataclass
class ScenarioResult:
    """Both engines' results for one scenario run, pinned.

    ``sim``/``async_`` are the two :class:`PipelineResult`\\ s,
    ``traces`` the matching recorders, ``max_done_delta`` the largest
    per-task completion disagreement (bounded by :data:`PIN_TOL`),
    ``n_migrations`` the hook's migration count (identical across
    engines by construction — asserted), ``versions`` the plan versions
    the run executed (single base version for static runs)."""
    sim: PipelineResult
    async_: PipelineResult
    traces: Tuple[TraceRecorder, TraceRecorder]
    max_done_delta: float
    n_migrations: int = 0
    versions: Sequence[PlanVersion] = ()

    @property
    def n_replans(self) -> int:
        return max(0, len(self.versions) - 1)


def run_dual(plans: Sequence[TaskPlan],
             arrivals: Sequence[float],
             links: Optional[Sequence[Optional[LinkProfile]]] = None,
             pools=None,
             make_router: Optional[Callable[[], object]] = None,
             migrate=None,
             reset: Optional[Callable[[], None]] = None
             ) -> ScenarioResult:
    """Run one scenario on both engines and pin traces + completions.

    ``make_router`` is a zero-arg factory (fresh router per engine run —
    projection state must not leak across the pair); ``reset`` is called
    before each run (pass the migration hook's ``reset``).  Returns the
    pinned :class:`ScenarioResult`."""
    def one(runner):
        if reset is not None:
            reset()
        rec = TraceRecorder()
        router = make_router() if make_router is not None else None
        pr = runner(list(plans), arrivals=list(arrivals),
                    links=list(links) if links is not None else None,
                    pools=pools, router=router, sink=rec,
                    migrate=migrate)
        n_mig = getattr(migrate, "n_migrations", 0) if migrate else 0
        return pr, rec, n_mig

    pr_s, rec_s, mig_s = one(run_pipeline)
    pr_a, rec_a, mig_a = one(run_pipeline_async)
    assert mig_s == mig_a, \
        f"engines migrated differently: sim={mig_s} async={mig_a}"
    assert_traces_match(rec_s, rec_a, tol=PIN_TOL)
    delta = max((abs(s.done - a.done)
                 for s, a in zip(pr_s.tasks, pr_a.tasks)), default=0.0)
    assert delta <= PIN_TOL, f"completion delta {delta} exceeds {PIN_TOL}"
    return ScenarioResult(sim=pr_s, async_=pr_a, traces=(rec_s, rec_a),
                          max_done_delta=delta, n_migrations=mig_s)


def run_chain_scenario(graph: ModelGraph,
                       devices: Sequence[DeviceProfile],
                       nominal_links: Sequence[LinkProfile],
                       timeline: Timeline,
                       n_tasks: int,
                       slack: float = ARRIVAL_SLACK,
                       replan: bool = True,
                       eps: float = 0.005,
                       alpha: float = 0.5, threshold: float = 0.25,
                       min_gap: float = 0.0,
                       degraded_tx_scale: float = 1.0,
                       ) -> ScenarioResult:
    """Plan → compile → execute one chain storyline on both engines.

    With ``replan=False`` the base plan rides through the whole
    storyline unmigrated (the static baseline the resilience bench
    compares against); the dynamics themselves — the traced links — are
    identical in both variants, so the comparison isolates the online
    re-planner."""
    links = timeline.link_profiles(nominal_links)
    versions, _ = replan_timeline(
        graph, devices, links, arrivals=[], eps=eps)
    st0 = versions[0].times
    period = st0.max_stage * slack
    arrivals = timeline.arrivals(period, n_tasks)
    if replan:
        versions, _ = replan_timeline(
            graph, devices, links, arrivals, eps=eps, alpha=alpha,
            threshold=threshold, min_gap=min_gap,
            degraded_tx_scale=degraded_tx_scale)
    else:
        versions = versions[:1]
    sched = PlanSchedule(versions, arrivals, n_hops=len(links))
    migrate = sched if len(versions) > 1 else None
    res = run_dual(sched.task_plans(), arrivals, links=links,
                   migrate=migrate, reset=sched.reset)
    res.versions = versions
    return res


def run_churn_scenario(plans: Sequence[TaskPlan],
                       timeline: Timeline,
                       period: float,
                       pools,
                       links: Optional[Sequence[Optional[LinkProfile]]]
                       = None,
                       n_tasks: Optional[int] = None,
                       seed: int = 0) -> ScenarioResult:
    """Execute one replicated-pool churn storyline on both engines.

    Replica dropout manifests only through the availability-aware
    router; the plan set is static (the chain ``migrate`` hook is
    chain-path-only).  The pin covers placement: both engines must route
    around the same down-windows identically."""
    arrivals = timeline.arrivals(period, n_tasks)
    plan_list = [plans[i % len(plans)] for i in range(len(arrivals))]
    return run_dual(plan_list, arrivals, links=links, pools=pools,
                    make_router=router_factory(timeline.availability(),
                                               seed=seed))
