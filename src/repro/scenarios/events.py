"""Scenario dynamics events and the timeline that compiles them.

Every event is a frozen record anchored at an absolute instant ``at``
(seconds of modeled time).  A :class:`Timeline` holds one scripted
storyline and compiles it into the representations the two pipeline
engines already execute under the differential pin:

``LinkShift``
    Piecewise bandwidth change of one hop (degradation *and* recovery
    are just shifts).  Compiled by :meth:`Timeline.link_profiles` into a
    per-hop step trace (``core.pipeline.bandwidth_step_trace``); hops
    never shifted stay plain constant-bandwidth profiles, so the
    planner's vectorized fast paths still apply to them.

``ReplicaDown`` / ``ReplicaUp``
    A pool replica leaves / rejoins its tier.  Compiled by
    :meth:`Timeline.availability` into half-open down-windows
    ``[down, up)`` per ``(tier, replica)`` for the clock-free
    :class:`~repro.scenarios.churn.AvailabilityRouter`.

``TenantArrive`` / ``TenantDepart`` / ``LoadScale``
    Stream shape: tenants join with their own arrival period and leave;
    ``LoadScale`` rescales every period from its instant on (diurnal
    load).  Compiled into explicit arrival instants — the engines take
    arrival lists verbatim, so no new engine surface is needed.

All compilation is pure arithmetic over the event list: the same
timeline always produces the same traces, windows and arrivals, which
is what keeps a scenario run deterministic across both engines.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.costs import LinkProfile
from repro.core.pipeline import bandwidth_step_trace

__all__ = [
    "LinkShift", "ReplicaDown", "ReplicaUp", "TenantArrive",
    "TenantDepart", "LoadScale", "Timeline",
]


@dataclasses.dataclass(frozen=True)
class LinkShift:
    """Hop ``hop``'s bandwidth becomes ``mbps`` from instant ``at`` on."""
    at: float
    hop: int
    mbps: float


@dataclasses.dataclass(frozen=True)
class ReplicaDown:
    """Replica ``replica`` of tier ``tier`` drops out at ``at``."""
    at: float
    tier: int
    replica: int


@dataclasses.dataclass(frozen=True)
class ReplicaUp:
    """Replica ``replica`` of tier ``tier`` rejoins at ``at``."""
    at: float
    tier: int
    replica: int


@dataclasses.dataclass(frozen=True)
class TenantArrive:
    """Tenant ``tenant`` starts issuing tasks every ``period`` s at
    ``at`` (its first arrival is ``at`` itself)."""
    at: float
    tenant: int
    period: float


@dataclasses.dataclass(frozen=True)
class TenantDepart:
    """Tenant ``tenant`` issues no arrivals at or after ``at``."""
    at: float
    tenant: int


@dataclasses.dataclass(frozen=True)
class LoadScale:
    """Every stream's arrival period is multiplied by ``factor`` from
    ``at`` on (values < 1 mean more load).  Factors replace, they do not
    compound: the factor in effect at ``t`` is the last event's."""
    at: float
    factor: float


class Timeline:
    """One scripted storyline: a sorted event list plus the horizon the
    open-ended compilations (tenant streams, down-windows without a
    rejoin) run to."""

    def __init__(self, events: Sequence, horizon: float):
        assert horizon > 0.0
        self.events = sorted(events, key=lambda e: e.at)
        self.horizon = float(horizon)
        assert all(e.at >= 0.0 for e in self.events), \
            "events must be anchored at non-negative instants"

    def _of(self, cls) -> list:
        return [e for e in self.events if isinstance(e, cls)]

    # ----------------------------------------------------------- link events
    def link_profiles(self, nominal: Sequence[LinkProfile]
                      ) -> List[LinkProfile]:
        """Per-hop profiles with the storyline's shifts folded in as step
        traces.  ``nominal`` are the constant-bandwidth planning profiles;
        a hop with no ``LinkShift`` is returned unchanged (untraced), so
        static deployments compile to the exact static run."""
        shifts: Dict[int, List[Tuple[float, float]]] = {}
        for e in self._of(LinkShift):
            assert 0 <= e.hop < len(nominal), f"no hop {e.hop}"
            shifts.setdefault(e.hop, []).append((e.at, e.mbps))
        out = []
        for k, lk in enumerate(nominal):
            assert lk.trace is None, \
                "nominal profiles must be constant-bandwidth"
            if k not in shifts:
                out.append(lk)
                continue
            steps = [(0.0, lk.bandwidth_bps / 1e6)] + sorted(shifts[k])
            out.append(LinkProfile(f"{lk.name}+dyn", lk.bandwidth_bps,
                                   trace=bandwidth_step_trace(steps)))
        return out

    # -------------------------------------------------------- replica events
    def availability(self) -> Dict[Tuple[int, int],
                                   List[Tuple[float, float]]]:
        """Down-windows ``[down, up)`` per ``(tier, replica)``; a drop
        without a matching rejoin stays down to the horizon."""
        downs: Dict[Tuple[int, int], List[Tuple[float, float]]] = {}
        open_at: Dict[Tuple[int, int], float] = {}
        for e in self.events:
            if isinstance(e, ReplicaDown):
                key = (e.tier, e.replica)
                assert key not in open_at, f"replica {key} already down"
                open_at[key] = e.at
            elif isinstance(e, ReplicaUp):
                key = (e.tier, e.replica)
                assert key in open_at, f"replica {key} not down"
                downs.setdefault(key, []).append((open_at.pop(key), e.at))
        for key, t0 in open_at.items():
            downs.setdefault(key, []).append((t0, self.horizon))
        return downs

    # --------------------------------------------------------- load / tenants
    def load_factor(self, t: float) -> float:
        """The ``LoadScale`` factor in effect at ``t`` (1.0 before any)."""
        f = 1.0
        for e in self._of(LoadScale):
            if e.at <= t:
                f = e.factor
        return f

    def _stream(self, start: float, stop: float, period: float,
                n_max: Optional[int] = None) -> List[float]:
        out: List[float] = []
        t = start
        while t < stop and (n_max is None or len(out) < n_max):
            out.append(t)
            t += period * self.load_factor(t)
        return out

    def arrivals(self, period: float,
                 n_tasks: Optional[int] = None) -> List[float]:
        """Single-stream arrival instants from 0 at ``period`` (scaled by
        the load events), up to ``n_tasks`` or the horizon."""
        return self._stream(0.0, self.horizon, period, n_tasks)

    def tenant_arrivals(self) -> Dict[int, List[float]]:
        """Per-tenant arrival lists from the tenant events (keyed by
        tenant id; pass ``dict(sorted(...))`` values to the multi-tenant
        entry points in id order)."""
        departs = {e.tenant: e.at for e in self._of(TenantDepart)}
        out: Dict[int, List[float]] = {}
        for e in self._of(TenantArrive):
            assert e.tenant not in out, f"tenant {e.tenant} arrives twice"
            stop = min(departs.get(e.tenant, self.horizon), self.horizon)
            out[e.tenant] = self._stream(e.at, stop, e.period)
        return out
