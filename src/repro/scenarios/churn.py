"""Availability-aware replica routing for churn scenarios.

A replica's dropout is *modeled through routing*: the scenario compiles
``ReplicaDown``/``ReplicaUp`` events into down-windows, and this router
simply refuses to place tasks on a replica whose window covers the
task's input-ready instant.  That keeps the repo-wide determinism
invariant intact — availability is evaluated against the task-carried
``ready`` instant, never a clock, so the arithmetic simulator and the
event-driven executor reach identical placements and the differential
pin extends to churn storylines for free.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

from repro.serving.routing import RouterPolicy

__all__ = ["AvailabilityRouter", "router_factory"]


class AvailabilityRouter(RouterPolicy):
    """JSQ over the replicas available at the task's ready instant.

    ``windows`` maps ``(tier, replica)`` to sorted half-open down
    intervals ``[down, up)`` (from ``Timeline.availability()``).  A task
    whose input is ready inside a replica's down-window is never placed
    there; when *every* replica of a tier is down the router falls back
    to the full pool (the fleet would rather queue on a dead tier than
    drop tasks — the bubble attribution shows the resulting idle time).
    """

    def __init__(self, windows: Dict[Tuple[int, int],
                                     List[Tuple[float, float]]],
                 seed: int = 0):
        super().__init__(seed=seed)
        self.windows = {k: sorted(v) for k, v in windows.items()}

    def available(self, k: int, r: int, t: float) -> bool:
        for (t0, t1) in self.windows.get((k, r), ()):
            if t0 <= t < t1:
                return False
        return True

    def pick(self, k, ready, compute, tenant):
        up = [r for r in range(self.pools[k].m)
              if self.available(k, r, ready)]
        return self._shortest(k, ready, compute, among=up or None)

    def down_spans(self, k: int) -> Sequence[Tuple[int, float, float]]:
        """Tier ``k``'s down-windows as (replica, down, up) — report
        helper for benches and examples."""
        return [(r, t0, t1) for (kk, r), ws in sorted(self.windows.items())
                if kk == k for (t0, t1) in ws]


def router_factory(windows, seed: int = 0):
    """Fresh-instance factory: each engine run gets its own router so no
    projection state leaks across the differential pair."""
    return lambda: AvailabilityRouter(windows, seed=seed)
