"""Fleet-scale dynamics: scripted churn/failure scenarios over the
COACH pipeline, with online re-planning.

A scenario is a :class:`~repro.scenarios.events.Timeline` of first-class
dynamics events — piecewise link degradation/recovery, replica
dropout/rejoin, tenant arrival/departure, diurnal load scaling —
compiled into inputs both engines already consume under the
differential pin:

* link events become per-hop bandwidth **step traces** (``core.sim``
  re-integrates each transfer at its start instant; the async executor
  is pinned to the same integration),
* replica events become availability windows consumed by the clock-free
  :class:`~repro.scenarios.churn.AvailabilityRouter`,
* tenant/load events become explicit per-tenant arrival schedules.

On top of the compiled scenario, :mod:`repro.scenarios.replan` re-runs
the offline planner at detected regime shifts (bandwidth-EMA drift)
with warm-started ``plan_fast`` tables and migrates in-flight tasks at
hop boundaries through the engines' ``migrate`` hook — the 1e-6
sim/async differential pin extends across mid-stream plan switches
(``repro.scenarios.runner`` asserts it on every run).
"""

from repro.scenarios.churn import AvailabilityRouter
from repro.scenarios.events import (LinkShift, LoadScale, ReplicaDown,
                                    ReplicaUp, TenantArrive, TenantDepart,
                                    Timeline)
from repro.scenarios.replan import (PlanSchedule, PlanVersion,
                                    RegimeDetector, replan_timeline)
from repro.scenarios.runner import (ScenarioResult, run_chain_scenario,
                                    run_churn_scenario, run_dual)

__all__ = [
    "LinkShift", "ReplicaDown", "ReplicaUp", "TenantArrive",
    "TenantDepart", "LoadScale", "Timeline",
    "AvailabilityRouter",
    "RegimeDetector", "PlanVersion", "PlanSchedule", "replan_timeline",
    "ScenarioResult", "run_dual", "run_chain_scenario",
    "run_churn_scenario",
]
