"""Online re-planning: regime detection, versioned plans, migration.

The offline planner prices a partition for one bandwidth regime; a
fleet storyline moves through several.  This module closes the loop
while preserving the repo's determinism invariant — both engines must
reach identical decisions — by splitting re-planning into two phases:

**Deterministic planning pass** (:func:`replan_timeline`).  Walks the
arrival schedule in modeled time, sampling each traced hop's bandwidth
at every arrival instant (information available online at that instant)
into a per-hop EMA (:class:`RegimeDetector`).  When the EMA drifts past
the threshold, the offline planner re-runs against the *effective*
constant-bandwidth profiles with warm-started tables
(``plan_fast.retime_tables`` — the Eq. 1 oracle pricing is never paid
again), producing a new :class:`PlanVersion` activated at the detection
instant.  Because the pass reads only the timeline (no engine state),
both engines consume the identical version list.

**Hop-boundary migration** (:class:`PlanSchedule`).  The engines'
``migrate(idx, k, tx_ready)`` hook is consulted once per task per hop
at the boundary-ready instant — a task-carried instant, identical
across engines.  New admissions get the full new plan (new cut + bits);
an in-flight task keeps its cut (its upstream compute already ran) and
only its remaining transmissions are re-scaled to the new version's
precision (the Eq. 11 lever).  The sim emits a ``replan`` span at each
migration and the bubble attribution charges the induced idle to the
``replanning`` cause.

Replanned transmission durations are priced at the *nominal* bandwidth:
the stream engines interpret ``plan.tx[k]`` as a bit volume at hop
``k``'s nominal rate and re-integrate it under the live trace, so a
plan computed for effective rate ``eff`` must carry
``tx[k] = st.link[k] * eff / nominal`` (same bits, nominal pricing).
"""

from __future__ import annotations

import dataclasses
import math
from bisect import bisect_right
from typing import List, Optional, Sequence, Tuple

from repro.core import plan_fast
from repro.core.costs import DeviceProfile, LinkProfile, ModelGraph
from repro.core.partitioner import (AccOracle, OfflineResult, QuantCache,
                                    analytic_acc_loss, chain_prefixes,
                                    coach_offline_multihop)
from repro.core.pipeline import TaskPlan
from repro.core.schedule import StageTimes

__all__ = ["RegimeDetector", "PlanVersion", "PlanSchedule",
           "plan_for_regime", "replan_timeline"]


class RegimeDetector:
    """Per-hop bandwidth EMA with relative drift detection.

    ``observe(hop, bps)`` folds one sample into hop ``hop``'s EMA and
    reports whether the EMA has drifted more than ``threshold``
    (relative) from the reference rate the current plan was computed
    for; ``rebase()`` moves the reference to the current EMA after a
    re-plan.  Clock-free: callers sample ``links[k].bps_at(arrival)`` at
    task arrival instants, so detection depends only on the timeline.
    """

    def __init__(self, nominal_bps: Sequence[float], alpha: float = 0.5,
                 threshold: float = 0.25):
        self.nominal = tuple(float(b) for b in nominal_bps)
        self.alpha = float(alpha)
        self.threshold = float(threshold)
        self.reset()

    def reset(self) -> None:
        self.ema = list(self.nominal)
        self.ref = list(self.nominal)

    def observe(self, hop: int, bps: float) -> bool:
        e = self.alpha * float(bps) + (1.0 - self.alpha) * self.ema[hop]
        self.ema[hop] = e
        return abs(e - self.ref[hop]) > self.threshold * self.ref[hop]

    def rebase(self) -> None:
        self.ref = list(self.ema)


@dataclasses.dataclass(frozen=True)
class PlanVersion:
    """One activated plan regime.

    ``plan`` is the full new-admission plan (new cut + bits, priced at
    nominal bandwidth); ``tx_scale[k]`` the precision scale (<= 1 drops
    bits) its regime applies to hop ``k`` transmissions — in-flight
    tasks migrate by re-scaling their own plan's volumes between the
    admission version's and the active version's scales, since their
    cut is already committed upstream."""
    activate_at: float
    plan: TaskPlan
    tx_scale: Tuple[float, ...]
    times: Optional[StageTimes] = None
    eff_bps: Tuple[float, ...] = ()


class PlanSchedule:
    """Versioned plan store + the engines' ``migrate`` hook.

    ``versions`` must be sorted by strictly increasing ``activate_at``
    with the base version first (its instant at or before the first
    arrival).  Each task's *admission version* is the one active at its
    arrival — ``task_plans()`` returns the per-task admission plans to
    hand the engine.  As the hook sees later hop boundaries fall past a
    newer version's activation, it returns the spliced plan once per
    (task, version) transition.

    All hook state is per-task and every decision input (``tx_ready``,
    the version table) is engine-independent, so the sim's sequential
    replay and the executor's interleaved workers migrate identically —
    call :meth:`reset` before each engine run of a differential pair.
    """

    def __init__(self, versions: Sequence[PlanVersion],
                 arrivals: Sequence[float], n_hops: int):
        assert versions, "need at least the base version"
        self.versions = list(versions)
        self.acts = [v.activate_at for v in self.versions]
        assert all(a < b for a, b in zip(self.acts, self.acts[1:])), \
            "versions must be sorted by strictly increasing activate_at"
        self.n_hops = int(n_hops)
        self.arrivals = [float(a) for a in arrivals]
        self.admit_v = [bisect_right(self.acts, a) - 1
                        for a in self.arrivals]
        assert all(w >= 0 for w in self.admit_v), \
            "base version must activate at or before the first arrival"
        self.sim_plans = [self.versions[w].plan.as_sim_plan(self.n_hops)
                          for w in self.admit_v]
        self.reset()

    # ------------------------------------------------------------- plumbing
    def task_plans(self) -> List[TaskPlan]:
        """Per-task admission plans (what the engine runs)."""
        return [self.versions[w].plan for w in self.admit_v]

    def version_at(self, t: float) -> int:
        return bisect_right(self.acts, t) - 1

    def reset(self) -> None:
        """Clear per-run migration state (between engine runs)."""
        self._applied = {}
        self.n_migrations = 0

    # ------------------------------------------------------------- the hook
    def __call__(self, idx: int, k: int, tx_ready: float):
        v = self.version_at(tx_ready)
        w = self._applied.get(idx, self.admit_v[idx])
        if v <= w:  # versions only move forward
            return None
        self._applied[idx] = v
        base = self.sim_plans[idx]
        num = self.versions[v].tx_scale
        den = self.versions[self.admit_v[idx]].tx_scale
        self.n_migrations += 1
        # hops past the version's scale vector are engine padding
        # (zero-volume) and ride through unscaled
        return dataclasses.replace(base, tx=tuple(
            x * (num[j] / den[j]) if j < len(num) else x
            for j, x in enumerate(base.tx)))


# ========================================================== planning passes
def _nominal_plan(st: StageTimes, eff_bps: Sequence[float],
                  nominal_bps: Sequence[float],
                  tx_scale: Sequence[float]) -> TaskPlan:
    """Plan from stage times computed at effective rates, re-priced at
    nominal (same bits) and scaled to the regime's precision."""
    return TaskPlan.multihop(
        compute=st.compute,
        tx=tuple(st.link[k] * eff_bps[k] / nominal_bps[k] * tx_scale[k]
                 for k in range(st.n_hops)),
        tx_offsets=tuple(min(st.tx_offsets[k], st.compute[k])
                         for k in range(st.n_hops)),
        rx_offsets=st.rx_offsets)


def plan_for_regime(graph: ModelGraph, devices: Sequence[DeviceProfile],
                    eff_links: Sequence[LinkProfile],
                    nominal_bps: Sequence[float],
                    tx_scale: Sequence[float],
                    tables: Optional[plan_fast.PlannerTables] = None,
                    eps: float = 0.005,
                    oracle: AccOracle = analytic_acc_loss
                    ) -> Tuple[TaskPlan, OfflineResult]:
    """One (re-)plan: run the offline search against the regime's
    effective constant-bandwidth profiles (warm ``tables`` skip the
    oracle pricing) and price the winning plan at nominal bandwidth."""
    off = coach_offline_multihop(graph, devices, eff_links, eps=eps,
                                 oracle=oracle, tables=tables)
    eff = tuple(lk.bandwidth_bps for lk in eff_links)
    return _nominal_plan(off.times, eff, nominal_bps, tx_scale), off


def replan_timeline(graph: ModelGraph, devices: Sequence[DeviceProfile],
                    links: Sequence[LinkProfile],
                    arrivals: Sequence[float],
                    eps: float = 0.005,
                    oracle: AccOracle = analytic_acc_loss,
                    alpha: float = 0.5, threshold: float = 0.25,
                    min_gap: float = 0.0,
                    degraded_tx_scale: float = 1.0,
                    max_replans: int = 8
                    ) -> Tuple[List[PlanVersion], List[OfflineResult]]:
    """Deterministic online planning pass over one storyline.

    ``links`` are the scenario's (possibly traced) execution profiles;
    their nominal rates are the planning reference.  Returns the sorted
    version list (base version first, activated at ``-inf``) plus the
    per-version :class:`OfflineResult`.  ``degraded_tx_scale`` (< 1) is
    the precision drop applied to hops whose effective rate fell below
    the drift threshold — COACH's online precision adaptation, the lever
    that buys p99 through a degradation window; hops at or above nominal
    keep scale 1.  ``max_replans`` bounds planner work over a storyline
    (re-plans past the cap are skipped, not queued).
    """
    n_hops = len(links)
    nominal = [lk.bandwidth_bps for lk in links]
    qcache = QuantCache(graph, eps, oracle)
    prefixes = chain_prefixes(graph)
    base_links = [LinkProfile(lk.name, lk.bandwidth_bps) for lk in links]
    tables = plan_fast.build_tables(
        graph, devices, base_links, qcache.node_bits,
        pref_counts=[len(p) for p in prefixes])
    plan0, off0 = plan_for_regime(graph, devices, base_links, nominal,
                                  (1.0,) * n_hops, tables=tables,
                                  eps=eps, oracle=oracle)
    versions = [PlanVersion(-math.inf, plan0, (1.0,) * n_hops,
                            times=off0.times, eff_bps=tuple(nominal))]
    results = [off0]
    if all(lk.trace is None for lk in links):
        return versions, results  # static storyline: nothing to detect

    det = RegimeDetector(nominal, alpha=alpha, threshold=threshold)
    last = -math.inf
    for t in arrivals:
        drift = False
        for k, lk in enumerate(links):
            if lk.trace is not None:
                drift |= det.observe(k, lk.bps_at(t))
        if not drift or t - last < min_gap:
            continue
        if len(results) > max_replans:
            break
        eff_links = [LinkProfile(f"{lk.name}@{len(versions)}",
                                 max(det.ema[k], 1.0))
                     for k, lk in enumerate(links)]
        scale = tuple(
            degraded_tx_scale
            if det.ema[k] < nominal[k] * (1.0 - threshold) else 1.0
            for k in range(n_hops))
        plan, off = plan_for_regime(
            graph, devices, eff_links, nominal, scale,
            tables=plan_fast.retime_tables(tables, eff_links),
            eps=eps, oracle=oracle)
        versions.append(PlanVersion(t, plan, scale, times=off.times,
                                    eff_bps=tuple(l.bandwidth_bps
                                                  for l in eff_links)))
        results.append(off)
        det.rebase()
        last = t
    return versions, results
