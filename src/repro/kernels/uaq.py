"""Pallas TPU kernel: fused UAQ quantize (+int4 pack) / dequantize.

This is the transmission hot-spot of COACH: every boundary activation is
quantized on the end pod before the cross-pod transfer and dequantized on
the cloud pod.  Fusing min/max -> scale -> round/clip -> nibble-pack into
one VMEM pass avoids three HBM round-trips of the fp32 tensor.

TPU adaptation (vs the paper's GPU/CPU quantizer):
  - rows are tiled in blocks of ``block_m``; the full channel dim N stays
    resident in VMEM (lane-aligned, N % 128 == 0 for production shapes);
  - reductions run on the VPU over the 128-lane axis;
  - int4 values are packed two-per-byte with shift/or on int32 then cast,
    halving ICI/DCN bytes (the roofline's collective term).

Validated against ``ref.uaq_*`` in interpret mode (tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, out_ref, scale_ref, zp_ref, *, bits: int):
    x = x_ref[...].astype(jnp.float32)  # (bm, N)
    qmax = float((1 << bits) - 1)
    lo = jnp.min(x, axis=1, keepdims=True)
    hi = jnp.max(x, axis=1, keepdims=True)
    scale = jnp.maximum(hi - lo, 1e-8) / qmax
    zp = jnp.round(-lo / scale)
    q = jnp.clip(jnp.round(x / scale + zp), 0.0, qmax).astype(jnp.int32)
    if bits == 4:
        if q.shape[1] % 2:
            # odd channel count: pad one zero *nibble* (quantized domain),
            # so scale/zp — computed on the true N values above — are
            # untouched; the consumer slices back with the true N
            q = jnp.concatenate([q, jnp.zeros_like(q[:, :1])], axis=1)
        lo_nib = q[:, 0::2]
        hi_nib = q[:, 1::2]
        out_ref[...] = (lo_nib | (hi_nib << 4)).astype(jnp.uint8)
    else:
        out_ref[...] = q.astype(jnp.uint8)
    scale_ref[...] = scale
    zp_ref[...] = zp


def _dequant_kernel(p_ref, scale_ref, zp_ref, out_ref, *, bits: int,
                    out_dtype, n: int):
    p = p_ref[...].astype(jnp.int32)
    if bits == 4:
        lo = p & 0xF
        hi = p >> 4
        bm, half = p.shape
        q = jnp.stack([lo, hi], axis=-1).reshape(bm, half * 2)[:, :n]
    else:
        q = p
    x = (q.astype(jnp.float32) - zp_ref[...]) * scale_ref[...]
    out_ref[...] = x.astype(out_dtype)


def uaq_quantize(x: jnp.ndarray, bits: int, block_m: int = 256,
                 interpret: bool | None = None):
    """x: (M, N) -> (packed (M, ceil(N*bits/8)) uint8, scale (M,1),
    zp (M,1)).  An odd N at 4 bits is zero-nibble padded in the packed
    payload; pass ``n=N`` to ``uaq_dequantize`` to slice back exactly."""
    assert bits in (4, 8), "wire format supports int4 (packed) and int8"
    M, N = x.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bm = min(block_m, M)
    assert M % bm == 0, f"M={M} % block_m={bm}"
    n_out = (N + 1) // 2 if bits == 4 else N
    grid = (M // bm,)
    return pl.pallas_call(
        functools.partial(_quant_kernel, bits=bits),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, N), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, n_out), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, n_out), jnp.uint8),
            jax.ShapeDtypeStruct((M, 1), jnp.float32),
            jax.ShapeDtypeStruct((M, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)


def uaq_dequantize(packed: jnp.ndarray, scale: jnp.ndarray, zp: jnp.ndarray,
                   bits: int, out_dtype=jnp.float32, block_m: int = 256,
                   interpret: bool | None = None, n: int | None = None):
    """``n`` is the true channel count when the 4-bit payload carries an
    odd-N zero-nibble pad (defaults to the payload's full width)."""
    assert bits in (4, 8)
    M, n_in = packed.shape
    N = n if n is not None else n_in * 8 // bits
    assert N <= n_in * 8 // bits
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bm = min(block_m, M)
    assert M % bm == 0
    grid = (M // bm,)
    return pl.pallas_call(
        functools.partial(_dequant_kernel, bits=bits, out_dtype=out_dtype,
                          n=N),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n_in), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        interpret=interpret,
    )(packed, scale, zp)
