"""Pallas TPU kernel: single-pass fused boundary hop
(quantize -> int4 pack -> semantic probe).

COACH's per-boundary hot path executes three ops on the same (B, S, D)
activation: UAQ row-quantize it for the wire (Eq. 1), pack the nibbles,
and probe the GAP feature against the semantic-cache centers (Eq. 8-9).
Run separately, the fp32 tensor crosses HBM once per op.  This kernel
fuses all of them so the activation is read exactly once per hop:

  grid (B blocks, S blocks); per step the (bb, bs, D) tile is
    1. row-quantized (per-token min/max -> scale/zp -> round/clip) and
       nibble-packed straight into the payload/scale/zp output blocks,
    2. summed over its sequence slice into a VMEM scratch accumulator
       (the ``semantic_cache.py`` idiom);
  the epilogue on the last S step finishes GAP -> L2-normalize ->
  cosine-vs-centers (MXU) -> top-2 -> separability and writes
  feat/sep/best/sims.

The GAP feature comes out alongside the wire packet, so the online
component (Eq. 7 center updates) needs no second read either.

Validated bit-for-bit against ``ref.fused_boundary_ref`` and against the
unfused (``uaq_quantize`` o ``semantic_probe``) composition in interpret
mode (tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _boundary_kernel(x_ref, c_ref, payload_ref, scale_ref, zp_ref,
                     feat_ref, sep_ref, best_ref, sims_ref, acc_ref, *,
                     bits: int, n_s_blocks: int, seq_len: int):
    sj = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)  # (bb, bs, D)

    # ---- per-token UAQ quantize + pack (writes this tile's wire blocks)
    qmax = float((1 << bits) - 1)
    lo = jnp.min(x, axis=2, keepdims=True)
    hi = jnp.max(x, axis=2, keepdims=True)
    scale = jnp.maximum(hi - lo, 1e-8) / qmax
    zp = jnp.round(-lo / scale)
    q = jnp.clip(jnp.round(x / scale + zp), 0.0, qmax).astype(jnp.int32)
    if bits == 4:
        if q.shape[2] % 2:
            # odd channel count: zero-nibble pad in the quantized domain
            # (scale/zp computed on the true D values stay exact)
            q = jnp.concatenate([q, jnp.zeros_like(q[..., :1])], axis=2)
        payload_ref[...] = (q[..., 0::2] | (q[..., 1::2] << 4)
                            ).astype(jnp.uint8)
    else:
        payload_ref[...] = q.astype(jnp.uint8)
    scale_ref[...] = scale
    zp_ref[...] = zp

    # ---- GAP accumulation over the sequence axis (VMEM scratch)
    @pl.when(sj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.sum(x, axis=1)

    # ---- probe epilogue on the last S step (Eq. 8-9)
    @pl.when(sj == n_s_blocks - 1)
    def _epilogue():
        f = acc_ref[...] / seq_len  # GAP   (bb, D); true S, pad-exact
        fn = f / jnp.maximum(
            jnp.sqrt(jnp.sum(f * f, axis=1, keepdims=True)), 1e-12)
        c = c_ref[...].astype(jnp.float32)  # (L, D)
        cn = c / jnp.maximum(
            jnp.sqrt(jnp.sum(c * c, axis=1, keepdims=True)), 1e-12)
        sims = (jnp.dot(fn, cn.T, preferred_element_type=jnp.float32)
                + 1.0) * 0.5  # Eq. 8 -> [0,1]
        L = sims.shape[1]
        t_h = jnp.max(sims, axis=1)
        best = jnp.argmax(sims, axis=1).astype(jnp.int32)
        onehot = best[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, L), 1)
        t_sh = jnp.max(jnp.where(onehot, -jnp.inf, sims), axis=1)
        norm = jnp.sqrt(jnp.sum(sims * sims, axis=1))
        sep = norm * (t_h - t_sh) * t_h / jnp.maximum(t_sh, 1e-12)  # Eq. 9
        feat_ref[...] = f
        sep_ref[...] = sep[:, None]
        best_ref[...] = best[:, None]
        sims_ref[...] = sims


def fused_boundary(x: jnp.ndarray, centers: jnp.ndarray, bits: int,
                   block_b: int = 8, block_s: int = 512,
                   interpret: bool | None = None):
    """x: (B,S,D), centers: (L,D) -> (payload (B,S,P) uint8,
    scale (B,S,1), zp (B,S,1), feat (B,D), sep (B,), best (B,),
    sims (B,L)); P = ceil(D * bits / 8).

    ``B``/``S`` need not divide the block sizes (zero-padded up to block
    multiples, pad rows sliced off; the GAP epilogue divides by the true
    ``S``, so padding is exact — see ``semantic_cache.semantic_probe``).
    An odd ``D`` at 4 bits is zero-nibble padded in the payload; the
    consumer slices back with the true channel count."""
    assert bits in (4, 8), "wire format supports int4 (packed) and int8"
    B, S, D = x.shape
    L = centers.shape[0]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bb = min(block_b, B)
    bs = min(block_s, S)
    pad_b = -B % bb
    pad_s = -S % bs
    if pad_b or pad_s:
        x = jnp.pad(x, ((0, pad_b), (0, pad_s), (0, 0)))
    Bp, Sp = B + pad_b, S + pad_s
    P = (D + 1) // 2 if bits == 4 else D
    grid = (Bp // bb, Sp // bs)
    payload, scale, zp, feat, sep, best, sims = pl.pallas_call(
        functools.partial(_boundary_kernel, bits=bits,
                          n_s_blocks=Sp // bs, seq_len=S),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bs, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((L, D), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, bs, P), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bb, bs, 1), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bb, bs, 1), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bb, D), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, L), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, Sp, P), jnp.uint8),
            jax.ShapeDtypeStruct((Bp, Sp, 1), jnp.float32),
            jax.ShapeDtypeStruct((Bp, Sp, 1), jnp.float32),
            jax.ShapeDtypeStruct((Bp, D), jnp.float32),
            jax.ShapeDtypeStruct((Bp, 1), jnp.float32),
            jax.ShapeDtypeStruct((Bp, 1), jnp.int32),
            jax.ShapeDtypeStruct((Bp, L), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bb, D), jnp.float32)],
        interpret=interpret,
    )(x, centers)
    return (payload[:B, :S], scale[:B, :S], zp[:B, :S], feat[:B],
            sep[:B, 0], best[:B, 0], sims[:B])
