"""Jit'd public wrappers around the Pallas kernels.

``quantize_activation`` / ``dequantize_activation`` handle arbitrary-rank
boundary tensors (flattened to (tokens, channels)), and fall back to the
pure-jnp reference for bit-widths outside the packed wire formats (the cost
model still prices those; only 4/8-bit have a TPU wire kernel).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.uaq import uaq_dequantize, uaq_quantize
from repro.kernels.semantic_cache import semantic_probe

KERNEL_BITS = (4, 8)


def _as2d(x):
    return x.reshape(-1, x.shape[-1]), x.shape


@functools.partial(jax.jit, static_argnames=("bits", "use_kernel"))
def quantize_activation(x, bits: int = 8, use_kernel: bool = True):
    """(..., N) -> (packed (..., N*bits//8) uint8, scale, zp)."""
    x2, shape = _as2d(x)
    if use_kernel and bits in KERNEL_BITS:
        p, s, z = uaq_quantize(x2, bits)
    else:
        p, s, z = ref.uaq_quantize_ref(x2, bits)
    lead = shape[:-1]
    return (p.reshape(*lead, -1), s.reshape(*lead, 1), z.reshape(*lead, 1))


@functools.partial(jax.jit, static_argnames=("bits", "out_dtype", "use_kernel"))
def dequantize_activation(packed, scale, zp, bits: int = 8,
                          out_dtype=jnp.float32, use_kernel: bool = True):
    p2, shape = _as2d(packed)
    s2 = scale.reshape(-1, 1)
    z2 = zp.reshape(-1, 1)
    if use_kernel and bits in KERNEL_BITS:
        x = uaq_dequantize(p2, s2, z2, bits, out_dtype)
    else:
        x = ref.uaq_dequantize_ref(p2, s2, z2, bits, out_dtype)
    return x.reshape(*shape[:-1], -1)


@jax.jit
def probe_cache(x, centers) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused GAP+cosine+separability.  x: (B,S,D); centers: (L,D)."""
    return semantic_probe(x, centers)
