"""Jit'd public wrappers around the Pallas kernels.

``quantize_activation`` / ``dequantize_activation`` handle arbitrary-rank
boundary tensors (flattened to (tokens, channels)), and fall back to the
pure-jnp reference for bit-widths outside the packed wire formats (the cost
model still prices those; only 4/8-bit have a TPU wire kernel).

``boundary_pass`` is the fused single-pass boundary hop (quantize + pack +
probe in one HBM read, ``kernels.boundary``); off-TPU it dispatches to the
exact jnp reference, and on accelerator backends the activation buffer is
donated (the fused pass consumes it — nothing downstream reads the fp32
tensor again).

``wire_quantize`` / ``wire_dequantize`` are the *trace-safe* shared wire
entry points: plain functions (no jit wrapper) that pick the Pallas kernel
on TPU and the jnp reference elsewhere, so they can be traced inside
``shard_map`` regions where interpret-mode Pallas cannot compile (see
``core.collab.make_collab_pipeline_step``).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.boundary import fused_boundary
from repro.kernels.uaq import uaq_dequantize, uaq_quantize
from repro.kernels.semantic_cache import semantic_probe

KERNEL_BITS = (4, 8)


def _as2d(x):
    return x.reshape(-1, x.shape[-1]), x.shape


@functools.partial(jax.jit, static_argnames=("bits", "use_kernel"))
def quantize_activation(x, bits: int = 8, use_kernel: bool = True):
    """(..., N) -> (packed (..., ceil(N*bits/8)) uint8, scale, zp).  An
    odd N at 4 bits carries a zero-nibble pad; dequantize with
    ``channels=N`` to slice back exactly."""
    x2, shape = _as2d(x)
    if use_kernel and bits in KERNEL_BITS:
        p, s, z = uaq_quantize(x2, bits)
    else:
        p, s, z = ref.uaq_quantize_ref(x2, bits)
    lead = shape[:-1]
    return (p.reshape(*lead, -1), s.reshape(*lead, 1), z.reshape(*lead, 1))


@functools.partial(jax.jit, static_argnames=("bits", "out_dtype",
                                             "use_kernel", "channels"))
def dequantize_activation(packed, scale, zp, bits: int = 8,
                          out_dtype=jnp.float32, use_kernel: bool = True,
                          channels: Optional[int] = None):
    """``channels`` is the true channel count when the 4-bit payload was
    packed from an odd N (defaults to the payload's full width)."""
    p2, shape = _as2d(packed)
    s2 = scale.reshape(-1, 1)
    z2 = zp.reshape(-1, 1)
    if use_kernel and bits in KERNEL_BITS:
        x = uaq_dequantize(p2, s2, z2, bits, out_dtype, n=channels)
    else:
        x = ref.uaq_dequantize_ref(p2, s2, z2, bits, out_dtype, n=channels)
    return x.reshape(*shape[:-1], -1)


@jax.jit
def probe_cache(x, centers) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused GAP+cosine+separability.  x: (B,S,D); centers: (L,D)."""
    return semantic_probe(x, centers)


# ------------------------------------------------- fused boundary pass
@functools.lru_cache(maxsize=None)
def _boundary_fn(bits: int, use_kernel: bool):
    """Jitted fused-boundary entry, cached per (bits, path).  The
    activation argument is donated on accelerator backends only: on CPU
    XLA cannot alias the buffers and jit would warn on every call."""
    def f(x, centers):
        if use_kernel and bits in KERNEL_BITS \
                and jax.default_backend() == "tpu":
            return fused_boundary(x, centers, bits)
        return ref.fused_boundary_ref(x, centers, bits)
    donate = (0,) if jax.default_backend() in ("tpu", "gpu") else ()
    return jax.jit(f, donate_argnums=donate)


def boundary_pass(x, centers, bits: int = 8, use_kernel: bool = True):
    """Single-pass fused boundary hop: x (B,S,D), centers (L,D) ->
    (payload, scale, zp, feat, sep, best, sims).  One HBM read of ``x``
    produces the wire packet fields *and* the semantic-probe outputs;
    ``x`` is donated on TPU/GPU (do not reuse it after this call)."""
    return _boundary_fn(int(bits), bool(use_kernel))(x, centers)


# ------------------------------------------- trace-safe wire entry points
def wire_quantize(x, bits: int):
    """Shared wire quantize entry: Pallas kernel on TPU, exact jnp
    reference elsewhere.  Plain function — safe to trace inside
    ``shard_map``/``jit`` regions on any backend (interpret-mode Pallas
    cannot compile there), so the runtime, the SPMD pipeline, and the
    bench all measure the same code path."""
    if jax.default_backend() == "tpu" and bits in KERNEL_BITS:
        return uaq_quantize(x, bits)
    return ref.uaq_quantize_ref(x, bits)


def wire_dequantize(packed, scale, zp, bits: int, out_dtype=jnp.float32,
                    channels: Optional[int] = None):
    if jax.default_backend() == "tpu" and bits in KERNEL_BITS:
        return uaq_dequantize(packed, scale, zp, bits, out_dtype,
                              n=channels)
    return ref.uaq_dequantize_ref(packed, scale, zp, bits, out_dtype,
                                  n=channels)
