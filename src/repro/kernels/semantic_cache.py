"""Pallas TPU kernel: fused semantic-cache probe (COACH online hot-spot).

Fuses GAP over the sequence axis -> L2-normalize -> cosine similarity
against all label semantic centers (MXU matmul) -> top-2 -> task
separability (Eq. 9) in a single kernel, so the (B,S,D) activation is read
from HBM exactly once and the (B,L) similarity matrix never round-trips.

Grid: (B blocks, S blocks).  The S axis is accumulated into a VMEM scratch
(f32) across grid steps; the similarity/top-2 epilogue runs on the last S
step.  Centers stay fully resident in VMEM (L x D; L=#labels is small).

Validated against ``ref.semantic_probe_ref`` in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _probe_kernel(x_ref, c_ref, sep_ref, best_ref, sims_ref, acc_ref, *,
                  n_s_blocks: int, seq_len: int):
    sj = pl.program_id(1)

    @pl.when(sj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.sum(x_ref[...].astype(jnp.float32), axis=1)

    @pl.when(sj == n_s_blocks - 1)
    def _epilogue():
        f = acc_ref[...] / seq_len  # GAP   (bb, D)
        fn = f / jnp.maximum(
            jnp.sqrt(jnp.sum(f * f, axis=1, keepdims=True)), 1e-12)
        c = c_ref[...].astype(jnp.float32)  # (L, D)
        cn = c / jnp.maximum(
            jnp.sqrt(jnp.sum(c * c, axis=1, keepdims=True)), 1e-12)
        sims = (jnp.dot(fn, cn.T, preferred_element_type=jnp.float32)
                + 1.0) * 0.5  # Eq. 8 -> [0,1]
        L = sims.shape[1]
        t_h = jnp.max(sims, axis=1)
        best = jnp.argmax(sims, axis=1).astype(jnp.int32)
        onehot = best[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, L), 1)
        t_sh = jnp.max(jnp.where(onehot, -jnp.inf, sims), axis=1)
        norm = jnp.sqrt(jnp.sum(sims * sims, axis=1))
        sep = norm * (t_h - t_sh) * t_h / jnp.maximum(t_sh, 1e-12)  # Eq. 9
        sep_ref[...] = sep[:, None]
        best_ref[...] = best[:, None]
        sims_ref[...] = sims


def semantic_probe(x: jnp.ndarray, centers: jnp.ndarray,
                   block_b: int = 8, block_s: int = 512,
                   interpret: bool | None = None):
    """x: (B,S,D), centers: (L,D) -> (sep (B,), best (B,), sims (B,L)).

    ``B``/``S`` need not divide the block sizes: the batch and sequence
    axes are zero-padded up to block multiples and the pad rows sliced
    off.  The GAP epilogue divides the VMEM accumulator by the *true*
    ``S``, so sequence padding contributes exactly zero to the mean and
    the padded result is bit-identical to the unpadded one."""
    B, S, D = x.shape
    L = centers.shape[0]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bb = min(block_b, B)
    bs = min(block_s, S)
    pad_b = -B % bb
    pad_s = -S % bs
    if pad_b or pad_s:
        x = jnp.pad(x, ((0, pad_b), (0, pad_s), (0, 0)))
    Bp, Sp = B + pad_b, S + pad_s
    grid = (Bp // bb, Sp // bs)
    sep, best, sims = pl.pallas_call(
        functools.partial(_probe_kernel, n_s_blocks=Sp // bs, seq_len=S),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bs, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((L, D), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, L), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, 1), jnp.float32),
            jax.ShapeDtypeStruct((Bp, 1), jnp.int32),
            jax.ShapeDtypeStruct((Bp, L), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bb, D), jnp.float32)],
        interpret=interpret,
    )(x, centers)
    return sep[:B, 0], best[:B, 0], sims[:B]
