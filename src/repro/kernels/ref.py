"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

Every kernel in this package is validated against these references across
shape/dtype/bit sweeps in tests/test_kernels.py (interpret mode on CPU).
"""

from __future__ import annotations

import jax.numpy as jnp


# ----------------------------------------------------------------- UAQ ref
def uaq_rowwise_ref(x: jnp.ndarray, bits: int):
    """Row-wise UAQ: x (M, N) -> (q (M,N) uint8, scale (M,1), zp (M,1)).

    q in [0, 2^bits - 1]; scale/zp per row (the boundary-tensor layout used
    by the collaborative executor: rows = tokens, cols = channels)."""
    qmax = (1 << bits) - 1
    xf = x.astype(jnp.float32)  # contract: all quant math in f32
    lo = jnp.min(xf, axis=1, keepdims=True)
    hi = jnp.max(xf, axis=1, keepdims=True)
    scale = jnp.maximum(hi - lo, 1e-8) / qmax
    zp = jnp.round(-lo / scale)
    q = jnp.clip(jnp.round(xf / scale + zp), 0, qmax)
    return q.astype(jnp.uint8), scale, zp


def pack4_ref(q: jnp.ndarray) -> jnp.ndarray:
    """Pack uint4 values (M, N even) -> (M, N//2) bytes, little-nibble first."""
    lo = q[:, 0::2].astype(jnp.uint8)
    hi = q[:, 1::2].astype(jnp.uint8)
    return lo | (hi << 4)


def unpack4_ref(p: jnp.ndarray) -> jnp.ndarray:
    lo = p & 0xF
    hi = p >> 4
    return jnp.stack([lo, hi], axis=-1).reshape(p.shape[0], -1)


def uaq_quantize_ref(x, bits: int):
    q, scale, zp = uaq_rowwise_ref(x, bits)
    if bits == 4:
        return pack4_ref(q), scale, zp
    return q, scale, zp


def uaq_dequantize_ref(packed, scale, zp, bits: int, out_dtype=jnp.float32):
    q = unpack4_ref(packed) if bits == 4 else packed
    return ((q.astype(jnp.float32) - zp) * scale).astype(out_dtype)


# ------------------------------------------------------- semantic cache ref
def semantic_probe_ref(x: jnp.ndarray, centers: jnp.ndarray):
    """Fused GAP + cosine similarity + top-2 separability (Eq. 8-10).

    x: (B, S, D) intermediate activations; centers: (L, D) label semantic
    centers.  Returns (sep (B,), best (B,) int32, sims (B, L) in [0,1]).
    """
    f = jnp.mean(x.astype(jnp.float32), axis=1)  # GAP over sequence
    fn = f / jnp.maximum(jnp.linalg.norm(f, axis=1, keepdims=True), 1e-12)
    cn = centers.astype(jnp.float32)
    cn = cn / jnp.maximum(jnp.linalg.norm(cn, axis=1, keepdims=True), 1e-12)
    sims = (fn @ cn.T + 1.0) * 0.5  # Eq. 8, mapped to [0,1]
    t_h = jnp.max(sims, axis=1)
    best = jnp.argmax(sims, axis=1).astype(jnp.int32)
    masked = jnp.where(
        jax_one_hot(best, sims.shape[1], dtype=bool), -jnp.inf, sims)
    t_sh = jnp.max(masked, axis=1)
    norm = jnp.linalg.norm(sims, axis=1)
    sep = norm * (t_h - t_sh) * t_h / jnp.maximum(t_sh, 1e-12)  # Eq. 9
    return sep, best, sims


def jax_one_hot(idx, n, dtype=bool):
    return (idx[:, None] == jnp.arange(n)[None, :]).astype(dtype)
