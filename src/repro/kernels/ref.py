"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

Every kernel in this package is validated against these references across
shape/dtype/bit sweeps in tests/test_kernels.py (interpret mode on CPU).
"""

from __future__ import annotations

import jax.numpy as jnp


# ----------------------------------------------------------------- UAQ ref
def uaq_rowwise_ref(x: jnp.ndarray, bits: int):
    """Row-wise UAQ: x (M, N) -> (q (M,N) uint8, scale (M,1), zp (M,1)).

    q in [0, 2^bits - 1]; scale/zp per row (the boundary-tensor layout used
    by the collaborative executor: rows = tokens, cols = channels)."""
    qmax = (1 << bits) - 1
    xf = x.astype(jnp.float32)  # contract: all quant math in f32
    lo = jnp.min(xf, axis=1, keepdims=True)
    hi = jnp.max(xf, axis=1, keepdims=True)
    scale = jnp.maximum(hi - lo, 1e-8) / qmax
    zp = jnp.round(-lo / scale)
    q = jnp.clip(jnp.round(xf / scale + zp), 0, qmax)
    return q.astype(jnp.uint8), scale, zp


def pack4_ref(q: jnp.ndarray) -> jnp.ndarray:
    """Pack uint4 values (..., N) -> (..., ceil(N/2)) bytes, little-nibble
    first.  An odd channel count is zero-nibble padded: the pad lives in
    the *quantized* domain (a spare high nibble of the last byte), so the
    row's scale/zero-point — computed on the true N values — are untouched
    and ``unpack4_ref(..., n=N)`` recovers the row exactly."""
    if q.shape[-1] % 2:
        q = jnp.concatenate(
            [q, jnp.zeros_like(q[..., :1])], axis=-1)
    lo = q[..., 0::2].astype(jnp.uint8)
    hi = q[..., 1::2].astype(jnp.uint8)
    return lo | (hi << 4)


def unpack4_ref(p: jnp.ndarray, n: int | None = None) -> jnp.ndarray:
    """Unpack nibbles (..., P) -> (..., 2P), sliced to the true channel
    count ``n`` when the producer zero-padded an odd N."""
    lo = p & 0xF
    hi = p >> 4
    q = jnp.stack([lo, hi], axis=-1).reshape(*p.shape[:-1], -1)
    return q if n is None else q[..., :n]


def uaq_quantize_ref(x, bits: int):
    q, scale, zp = uaq_rowwise_ref(x, bits)
    if bits == 4:
        return pack4_ref(q), scale, zp
    return q, scale, zp


def uaq_dequantize_ref(packed, scale, zp, bits: int, out_dtype=jnp.float32,
                       n: int | None = None):
    q = unpack4_ref(packed, n=n) if bits == 4 else packed
    return ((q.astype(jnp.float32) - zp) * scale).astype(out_dtype)


# ------------------------------------------------------ fused boundary ref
def fused_boundary_ref(x: jnp.ndarray, centers: jnp.ndarray, bits: int):
    """Exact jnp mirror of ``boundary.fused_boundary`` (the single-pass
    quantize -> pack -> probe kernel): same expression sequence, so the
    kernel is pinned bit-for-bit in interpret mode.

    x: (B, S, D) boundary activation; centers: (L, D).  Returns
    (payload (B,S,P) uint8, scale (B,S,1), zp (B,S,1), feat (B,D),
    sep (B,), best (B,) int32, sims (B,L)) — the per-token wire packet
    fields plus the per-task GAP feature and probe outputs, from one
    logical read of ``x``."""
    B, S, D = x.shape
    qmax = float((1 << bits) - 1)
    xf = x.astype(jnp.float32)
    lo = jnp.min(xf, axis=2, keepdims=True)
    hi = jnp.max(xf, axis=2, keepdims=True)
    scale = jnp.maximum(hi - lo, 1e-8) / qmax
    zp = jnp.round(-lo / scale)
    q = jnp.clip(jnp.round(xf / scale + zp), 0.0, qmax).astype(jnp.int32)
    if bits == 4:
        if D % 2:
            q = jnp.concatenate([q, jnp.zeros_like(q[..., :1])], axis=-1)
        payload = ((q[..., 0::2] | (q[..., 1::2] << 4))).astype(jnp.uint8)
    else:
        payload = q.astype(jnp.uint8)
    f = jnp.sum(xf, axis=1) / S  # GAP (sum-then-divide, like the kernel)
    fn = f / jnp.maximum(
        jnp.sqrt(jnp.sum(f * f, axis=1, keepdims=True)), 1e-12)
    c = centers.astype(jnp.float32)
    cn = c / jnp.maximum(
        jnp.sqrt(jnp.sum(c * c, axis=1, keepdims=True)), 1e-12)
    sims = (jnp.dot(fn, cn.T, preferred_element_type=jnp.float32)
            + 1.0) * 0.5  # Eq. 8 -> [0,1]
    L = sims.shape[1]
    t_h = jnp.max(sims, axis=1)
    best = jnp.argmax(sims, axis=1).astype(jnp.int32)
    onehot = best[:, None] == jnp.arange(L, dtype=jnp.int32)[None, :]
    t_sh = jnp.max(jnp.where(onehot, -jnp.inf, sims), axis=1)
    norm = jnp.sqrt(jnp.sum(sims * sims, axis=1))
    sep = norm * (t_h - t_sh) * t_h / jnp.maximum(t_sh, 1e-12)  # Eq. 9
    return payload, scale, zp, f, sep, best, sims


# ------------------------------------------------------- semantic cache ref
def semantic_probe_ref(x: jnp.ndarray, centers: jnp.ndarray):
    """Fused GAP + cosine similarity + top-2 separability (Eq. 8-10).

    x: (B, S, D) intermediate activations; centers: (L, D) label semantic
    centers.  Returns (sep (B,), best (B,) int32, sims (B, L) in [0,1]).
    """
    f = jnp.mean(x.astype(jnp.float32), axis=1)  # GAP over sequence
    fn = f / jnp.maximum(jnp.linalg.norm(f, axis=1, keepdims=True), 1e-12)
    cn = centers.astype(jnp.float32)
    cn = cn / jnp.maximum(jnp.linalg.norm(cn, axis=1, keepdims=True), 1e-12)
    sims = (fn @ cn.T + 1.0) * 0.5  # Eq. 8, mapped to [0,1]
    t_h = jnp.max(sims, axis=1)
    best = jnp.argmax(sims, axis=1).astype(jnp.int32)
    masked = jnp.where(
        jax_one_hot(best, sims.shape[1], dtype=bool), -jnp.inf, sims)
    t_sh = jnp.max(masked, axis=1)
    norm = jnp.linalg.norm(sims, axis=1)
    sep = norm * (t_h - t_sh) * t_h / jnp.maximum(t_sh, 1e-12)  # Eq. 9
    return sep, best, sims


def jax_one_hot(idx, n, dtype=bool):
    return (idx[:, None] == jnp.arange(n)[None, :]).astype(dtype)
