"""Pytree checkpointing: flattened-path .npz shards + a JSON manifest.

No external deps (no orbax); handles arbitrary pytrees (dict/tuple/list/
NamedTuple leaves), bfloat16 (stored as uint16 views), and atomic writes
(tmp + rename) so a crashed writer never corrupts the latest checkpoint.
"""

from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np

_BF16 = "bfloat16"


def _flatten(tree) -> Tuple[dict, Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    d = Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    arrays, _ = _flatten(tree)
    manifest = {}
    tmp = tempfile.mkdtemp(dir=d)
    npz = {}
    for k, a in arrays.items():
        if a.dtype.name == _BF16:
            npz[k] = a.view(np.uint16)
            manifest[k] = _BF16
        else:
            npz[k] = a
            manifest[k] = a.dtype.name
    np.savez(os.path.join(tmp, "arrays.npz"), **npz)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "dtypes": manifest}, f)
    final = d / f"step_{step:08d}"
    if final.exists():
        import shutil
        shutil.rmtree(final)
    os.rename(tmp, final)
    return str(final)


def latest_step(ckpt_dir: str) -> Optional[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in d.glob("step_*")]
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, step: int, like_tree):
    """Restore into the structure of ``like_tree`` (shapes must match)."""
    import jax.numpy as jnp
    d = Path(ckpt_dir) / f"step_{step:08d}"
    with open(d / "manifest.json") as f:
        manifest = json.load(f)
    data = np.load(d / "arrays.npz")
    arrays, treedef = _flatten(like_tree)
    leaves = []
    for k in arrays:
        a = data[k]
        if manifest["dtypes"][k] == _BF16:
            a = a.view(jnp.bfloat16)
        leaves.append(jnp.asarray(a))
    return jax.tree_util.tree_unflatten(treedef, leaves)
