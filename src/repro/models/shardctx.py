"""Activation-sharding context: lets launchers annotate model internals
with PartitionSpecs without the model code depending on any mesh.

The model calls ``constrain(x, name)`` at layer boundaries; outside a
sharding context these are no-ops (CPU smoke tests), inside the dry-run /
launchers they become ``with_sharding_constraint``s that pin down SPMD
propagation (without them XLA falls back to "involuntary full
rematerialization" reshards on the scanned layer bodies — measured at
2.5x temp memory on the mamba2 train dry-run; see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax

_state = threading.local()


def _specs() -> Optional[Dict]:
    return getattr(_state, "specs", None)


@contextlib.contextmanager
def activation_sharding(specs: Dict):
    prev = getattr(_state, "specs", None)
    _state.specs = specs
    try:
        yield
    finally:
        _state.specs = prev


def constrain(x, name: str):
    specs = _specs()
    if specs is None:
        return x
    spec = specs.get(name)
    if spec is None:
        return x
    if len(spec) != x.ndim:
        return x
    return jax.lax.with_sharding_constraint(x, spec)
