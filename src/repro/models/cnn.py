"""ResNet101 / VGG16 layer-cost DAG builders — the paper's evaluation models.

These produce ``ModelGraph``s with analytically derived per-layer FLOPs and
activation sizes (batch=1 inference task, 224x224x3 input).  ResNet101's
bottleneck blocks carry real skip-edge DAG structure, exercising the
virtual-block clustering of Algorithm 1; VGG16 is the chain-topology case.
"""

from __future__ import annotations

from typing import List

from repro.core.costs import LayerNode, ModelGraph


def _conv_flops(h, w, cin, cout, k, stride=1):
    ho, wo = h // stride, w // stride
    return 2.0 * ho * wo * cin * cout * k * k, ho, wo


def _sens(depth_frac: float) -> float:
    """Per-layer quantization sensitivity: early layers carry raw-signal
    detail and need more bits (§II-B spatial-locality observation)."""
    return 0.04 * (1.0 - 0.75 * depth_frac)


VGG_CONV_UTIL = 0.6   # dense 3x3 stacks (TensorRT-class; keeps VGG link-bound like the paper)
VGG_FC_UTIL = 0.1     # fc layers: memory bound
RESNET_UTIL = 0.11    # 1x1-dominated bottlenecks: memory bound end-to-end


def vgg16(input_hw: int = 224) -> ModelGraph:
    cfg = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
           512, 512, 512, "M", 512, 512, 512, "M"]
    nodes: List[LayerNode] = []
    h = w = input_hw
    cin, nid = 3, 0
    n_layers = sum(1 for c in cfg if c != "M") + 3
    for i, c in enumerate(cfg):
        if c == "M":
            h, w = h // 2, w // 2
            continue
        fl, ho, wo = _conv_flops(h, w, cin, c, 3)
        # a partition point after this conv transfers the *pooled* tensor
        # when a maxpool follows (the natural cut sits after pooling)
        pooled = (i + 1 < len(cfg) and cfg[i + 1] == "M")
        oe = (ho // 2) * (wo // 2) * c if pooled else ho * wo * c
        nodes.append(LayerNode(nid, f"conv{nid}", fl, oe,
                               (nid - 1,) if nid else (),
                               sensitivity=_sens(nid / n_layers),
                               util=VGG_CONV_UTIL))
        cin, h, w, nid = c, ho, wo, nid + 1
    feat = h * w * cin
    for i, f in enumerate([4096, 4096, 1000]):
        nodes.append(LayerNode(nid, f"fc{i}", 2.0 * feat * f, f, (nid - 1,),
                               sensitivity=_sens(nid / n_layers),
                               util=VGG_FC_UTIL))
        feat, nid = f, nid + 1
    return ModelGraph("vgg16", nodes, input_elems=input_hw * input_hw * 3)


def resnet101(input_hw: int = 224) -> ModelGraph:
    nodes: List[LayerNode] = []
    nid = 0
    stages = [(3, 64, 256, 1), (4, 128, 512, 2), (23, 256, 1024, 2),
              (3, 512, 2048, 2)]
    total_blocks = sum(s[0] for s in stages)

    def add(name, flops, out_elems, deps, frac):
        nonlocal nid
        nodes.append(LayerNode(nid, name, flops, int(out_elems), tuple(deps),
                               sensitivity=_sens(frac), util=RESNET_UTIL))
        nid += 1
        return nid - 1

    h = w = input_hw // 2  # conv1 stride 2
    fl, h, w = _conv_flops(input_hw, input_hw, 3, 64, 7, 2)
    prev = add("conv1", fl, h * w * 64, (), 0.0)
    h, w = h // 2, w // 2  # maxpool
    cin = 64
    done = 0
    for (blocks, mid, cout, stride) in stages:
        for b in range(blocks):
            frac = done / total_blocks
            done += 1
            s = stride if b == 0 else 1
            entry = prev
            f1, h1, w1 = _conv_flops(h, w, cin, mid, 1, s)
            c1 = add(f"c{done}a", f1, h1 * w1 * mid, (entry,), frac)
            f2, _, _ = _conv_flops(h1, w1, mid, mid, 3)
            c2 = add(f"c{done}b", f2, h1 * w1 * mid, (c1,), frac)
            f3, _, _ = _conv_flops(h1, w1, mid, cout, 1)
            c3 = add(f"c{done}c", f3, h1 * w1 * cout, (c2,), frac)
            if b == 0:  # projection shortcut branch
                fp, _, _ = _conv_flops(h, w, cin, cout, 1, s)
                proj = add(f"c{done}p", fp, h1 * w1 * cout, (entry,), frac)
                skip_dep = proj
            else:  # identity skip edge (entry -> add)
                skip_dep = entry
            prev = add(f"add{done}", h1 * w1 * cout * 2.0, h1 * w1 * cout,
                       (c3, skip_dep), frac)
            h, w, cin = h1, w1, cout
    add("fc", 2.0 * cin * 1000, 1000, (prev,), 1.0)
    return ModelGraph("resnet101", nodes, input_elems=input_hw * input_hw * 3)
