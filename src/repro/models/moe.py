"""Mixture-of-Experts FFN with capacity-based token dispatch.

Dispatch avoids the classic (T, E, C) one-hot blow-up: slots are computed
with a running per-expert cumsum, tokens are scattered into a
(G, E, C+1, D) buffer (overflow tokens land in the sacrificial last slot),
expert FFNs run as one batched einsum over E (active FLOPs only), and
results are gathered back and gate-combined.

All ops are explicitly G-batched (no vmap) so the launcher's activation
sharding constraints apply: token groups G shard over the data axes, the
expert FFN dim F over the model axis — the buffers stay O(tokens/device).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.shardctx import constrain


def init_moe(cfg: ModelConfig, key, dtype=jnp.float32):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    s, so = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {
        "router": (jax.random.normal(k1, (d, e)) * s).astype(jnp.float32),
        "w_gate": (jax.random.normal(k2, (e, d, f)) * s).astype(dtype),
        "w_up": (jax.random.normal(k3, (e, d, f)) * s).astype(dtype),
        "w_down": (jax.random.normal(k4, (e, f, d)) * so).astype(dtype),
    }
    if cfg.shared_expert:
        ks = jax.random.split(k5, 3)
        p["shared"] = {
            "w_gate": (jax.random.normal(ks[0], (d, f)) * s).astype(dtype),
            "w_up": (jax.random.normal(ks[1], (d, f)) * s).astype(dtype),
            "w_down": (jax.random.normal(ks[2], (f, d)) * so).astype(dtype),
        }
    return p


def capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(tokens_per_group * cfg.experts_per_token
                      * cfg.capacity_factor / cfg.num_experts))
    return max(4, min(c, tokens_per_group * cfg.experts_per_token))


def moe_ffn(params, x, cfg: ModelConfig):
    """x: (G, T, D) token groups.  Returns (y, aux_loss)."""
    G, T, D = x.shape
    k, E = cfg.experts_per_token, cfg.num_experts
    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    cap = capacity(T, cfg)

    logits = x.astype(jnp.float32) @ params["router"]  # (G,T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, k)  # (G,T,k)
    gates = gates / jnp.clip(jnp.sum(gates, -1, keepdims=True), 1e-9)
    gates = gates.astype(x.dtype)

    # Switch-style load-balance auxiliary loss
    me = jnp.mean(probs, axis=(0, 1))  # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(ids, E, dtype=jnp.float32), axis=2), axis=(0, 1))
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce / k)

    # slots: running per-(group, expert) assignment count
    flat_ids = ids.reshape(G, T * k)
    oh = constrain(jax.nn.one_hot(flat_ids, E, dtype=jnp.int32), "moe_oh")
    slot = jnp.cumsum(oh, axis=1) - 1  # (G,Tk,E)
    slot = jnp.take_along_axis(slot, flat_ids[..., None], axis=2)[..., 0]
    slot = jnp.where(slot < cap, slot, cap)  # overflow -> sacrificial slot

    gi = jnp.broadcast_to(jnp.arange(G)[:, None], (G, T * k))
    tok = jnp.broadcast_to(jnp.repeat(jnp.arange(T), k)[None], (G, T * k))
    # dispatch via an int32 token-index map + gather instead of scattering
    # the activations directly: JAX upcasts bf16 scatter-adds to f32, which
    # made the (G,E,C,D) buffers the dominant HBM traffic of MoE prefill
    # (§Perf pair 3 it4).  Slots are unique per (g,e) so set() semantics
    # match add(); the sentinel row T gathers zeros.
    tok_map = jnp.full((G, E, cap + 1), T, jnp.int32)
    tok_map = tok_map.at[gi, flat_ids, slot].set(tok)
    x_pad = jnp.concatenate([x, jnp.zeros((G, 1, D), x.dtype)], axis=1)
    buf = x_pad[jnp.arange(G)[:, None, None], tok_map]  # (G,E,C+1,D) gather
    buf = constrain(buf, "moe_buf")

    # expert FFN (active FLOPs only: G * E * cap * D * F)
    h = constrain(jnp.einsum("gecd,edf->gecf", buf, params["w_gate"]), "moe_h")
    u = constrain(jnp.einsum("gecd,edf->gecf", buf, params["w_up"]), "moe_h")
    yb = jnp.einsum("gecf,efd->gecd", (act(h) * u).astype(x.dtype),
                    params["w_down"])
    yb = constrain(yb.astype(x.dtype), "moe_buf")

    # gather back + gate combine; overflow slot contributes zero via mask
    out_k = yb[gi, flat_ids, slot]  # (G,Tk,D)
    valid = (slot < cap).astype(gates.dtype).reshape(G, T, k)
    y = jnp.sum(out_k.reshape(G, T, k, D) * (gates * valid)[..., None], axis=2)
    y = constrain(y, "hidden")

    if cfg.shared_expert:
        sh = params["shared"]
        y = y + (act(x @ sh["w_gate"]) * (x @ sh["w_up"])) @ sh["w_down"]
    return y, aux
