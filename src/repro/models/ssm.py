"""Mamba2 / SSD (state-space duality) mixer [arXiv:2405.21060].

Chunked SSD: intra-chunk quadratic attention-like term + inter-chunk
recurrence over per-chunk states (``lax.scan``), giving O(S * Q) compute,
O(1)-state decode, and exact equivalence with the sequential recurrence
(property-tested in tests/test_ssm.py).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.models.shardctx import constrain

SSM_GROUPS = 1  # n_groups for the B/C projections


def conv_dim(cfg: ModelConfig) -> int:
    return cfg.ssm_inner + 2 * SSM_GROUPS * cfg.ssm_state


def init_mamba(cfg: ModelConfig, key, dtype=jnp.float32):
    """Projections are stored as separate matrices (z / x / B / C / dt and
    per-stream conv kernels) rather than one fused in_proj: fused layouts
    force activation splits at non-shard-aligned offsets on the 16-way model
    axis, which SPMD resolves with full-tensor reshards (measured; see
    EXPERIMENTS.md §Perf)."""
    d, di, n, h = cfg.d_model, cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    gn = SSM_GROUPS * n
    keys = jax.random.split(key, 10)
    s = 1.0 / math.sqrt(d)
    rnd = lambda k, shp, sc: (jax.random.normal(k, shp) * sc).astype(dtype)
    return {
        "in_z": rnd(keys[0], (d, di), s),
        "in_x": rnd(keys[1], (d, di), s),
        "in_B": rnd(keys[2], (d, gn), s),
        "in_C": rnd(keys[3], (d, gn), s),
        "in_dt": rnd(keys[4], (d, h), s),
        "conv_x": rnd(keys[5], (cfg.ssm_conv, di), 0.1),
        "conv_B": rnd(keys[6], (cfg.ssm_conv, gn), 0.1),
        "conv_C": rnd(keys[7], (cfg.ssm_conv, gn), 0.1),
        "conv_bx": jnp.zeros((di,), dtype),
        "conv_bB": jnp.zeros((gn,), dtype),
        "conv_bC": jnp.zeros((gn,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(keys[8], (h,), jnp.float32) *
                    (math.log(0.1) - math.log(1e-3)) + math.log(1e-3)))),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": rnd(keys[9], (di, d), 1.0 / math.sqrt(di)),
    }


def _causal_conv(xc, w, b):
    """Depthwise causal conv.  xc: (B,S,Dc); w: (K,Dc)."""
    K = w.shape[0]
    pad = jnp.pad(xc, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xc.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def _gated_norm(y, z, scale, eps):
    y = y * jax.nn.silu(z)
    dt = y.dtype
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def _segsum_decay(dA_cum):
    """dA_cum: (..., Q, H) within-chunk inclusive cumsum of dt*A.
    Returns L: (..., H, Q, Q) with L[i,j] = exp(cum_i - cum_j) for i>=j else 0.
    """
    ci = dA_cum[..., :, None, :]  # (...,Q,1,H)
    cj = dA_cum[..., None, :, :]  # (...,1,Q,H)
    Q = dA_cum.shape[-2]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    diff = jnp.where(mask[..., None], ci - cj, -jnp.inf)
    return jnp.exp(jnp.moveaxis(diff, -1, -3))  # (...,H,Q,Q)


def ssd_chunked(cfg: ModelConfig, x, dt, A, Bm, Cm, h0=None):
    """Chunked SSD scan.

    x: (B,S,H,P)  dt: (B,S,H)  A: (H,)  Bm/Cm: (B,S,G,N)
    Returns y: (B,S,H,P), final state (B,H,P,N).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(cfg.ssm_chunk, S)
    S_real = S
    if S % Q != 0:
        # pad with dt=0 steps: decay exp(0)=1 and zero input leave the state
        # recurrence unchanged; padded outputs are discarded below.
        pad = Q - S % Q
        z2 = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        x, dt, Bm, Cm = z2(x), z2(dt), z2(Bm), z2(Cm)
        S = S + pad
    nc = S // Q
    rep = H // (Bm.shape[2])
    Bh = jnp.repeat(Bm, rep, axis=2)  # (B,S,H,N)
    Ch = jnp.repeat(Cm, rep, axis=2)

    # the chunk axis nc is the shardable batch-like dim of every intra-chunk
    # tensor (model axis; see launch.sharding.activation_specs) — without
    # this the (B,nc,H,Q,Q) decay/score matrices replicate per device
    r = lambda t, n: constrain(t.reshape((Bsz, nc, Q) + t.shape[2:]), n)
    xc, dtc = r(x, "ssm_chunk_x"), r(dt, "ssm_chunk_dt")
    Bc, Cc = r(Bh, "ssm_chunk_bc"), r(Ch, "ssm_chunk_bc")
    dA = dtc * A  # (B,nc,Q,H)
    cum = jnp.cumsum(dA, axis=2)
    xdt = xc * dtc[..., None]

    # intra-chunk (diagonal blocks)
    L = constrain(_segsum_decay(cum), "ssm_chunk_l")  # (B,nc,H,Q,Q)
    CB = constrain(jnp.einsum("bcihn,bcjhn->bchij", Cc, Bc), "ssm_chunk_l")
    Yd = constrain(jnp.einsum("bchij,bcjhp->bcihp", CB * L, xdt), "ssm_chunk_x")

    # per-chunk state contributions
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,Q,H)
    Sc = jnp.einsum("bcjhn,bcjhp->bchpn", Bc, xdt * decay_out[..., None])
    Sc = constrain(Sc, "ssm_chunk_s")

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nc,H)
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), x.dtype)

    def body(h, inp):
        s_c, dec = inp  # (B,H,P,N), (B,H)
        h_in = h
        h = h * dec[..., None, None] + s_c
        return h, h_in

    hT, h_in = lax.scan(body, h0,
                        (jnp.moveaxis(Sc, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_in = jnp.moveaxis(h_in, 0, 1)  # (B,nc,H,P,N) state entering each chunk

    Yo = constrain(
        jnp.einsum("bcihn,bchpn->bcihp", Cc * jnp.exp(cum)[..., None], h_in),
        "ssm_chunk_x")
    y = (Yd + Yo).reshape(Bsz, S, H, P)[:, :S_real]
    return y, hT


def mamba_forward(params, x, cfg: ModelConfig, h0=None,
                  return_cache: bool = False):
    """Full-sequence mamba2 block.  x: (B,S,D)."""
    Bsz, S, _ = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z = constrain(x @ params["in_z"], "ssm_inner")
    xr = constrain(x @ params["in_x"], "ssm_inner")
    Br = x @ params["in_B"]
    Cr = x @ params["in_C"]
    dt = x @ params["in_dt"]
    xs = constrain(_causal_conv(xr, params["conv_x"], params["conv_bx"]),
                   "ssm_inner")
    Bm = _causal_conv(Br, params["conv_B"], params["conv_bB"])
    Cm = _causal_conv(Cr, params["conv_C"], params["conv_bC"])
    xs = constrain(xs.reshape(Bsz, S, H, P), "ssm_heads")
    Bm = Bm.reshape(Bsz, S, SSM_GROUPS, N)
    Cm = Cm.reshape(Bsz, S, SSM_GROUPS, N)
    A = -jnp.exp(params["A_log"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    y, hT = ssd_chunked(cfg, xs, dt.astype(xs.dtype), A.astype(xs.dtype), Bm, Cm, h0)
    y = y + params["D"].astype(y.dtype)[:, None] * xs
    y = constrain(y.reshape(Bsz, S, -1), "ssm_inner")
    out = _gated_norm(y, z, params["norm_scale"], cfg.norm_eps) @ params["out_proj"]
    if return_cache:
        K = cfg.ssm_conv
        conv_cache = {
            "x": _left_pad_tail(xr, K - 1),
            "B": _left_pad_tail(Br, K - 1),
            "C": _left_pad_tail(Cr, K - 1),
        }
        return out, {"state": hT, "conv": conv_cache}
    return out


def _left_pad_tail(xc, n):
    """Last n steps of xc, left-padded with zeros if S < n."""
    S = xc.shape[1]
    if S >= n:
        return xc[:, -n:]
    return jnp.pad(xc, ((0, 0), (n - S, 0), (0, 0)))


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    K = cfg.ssm_conv
    gn = SSM_GROUPS * N
    return {
        "state": jnp.zeros((batch, H, P, N), dtype),
        "conv": {
            "x": jnp.zeros((batch, K - 1, cfg.ssm_inner), dtype),
            "B": jnp.zeros((batch, K - 1, gn), dtype),
            "C": jnp.zeros((batch, K - 1, gn), dtype),
        },
    }


def mamba_decode(params, x, cache, cfg: ModelConfig):
    """One-token decode.  x: (B,1,D).  O(1) state update."""
    Bsz = x.shape[0]
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    x0 = x[:, 0]
    z = x0 @ params["in_z"]
    xr = x0 @ params["in_x"]
    Br = x0 @ params["in_B"]
    Cr = x0 @ params["in_C"]
    dt = x0 @ params["in_dt"]

    def dconv(hist_prev, cur, w, b):
        hist = jnp.concatenate([hist_prev, cur[:, None]], axis=1)  # (B,K,·)
        return jax.nn.silu(jnp.einsum("bkd,kd->bd", hist, w) + b), hist[:, 1:]

    xs, cx = dconv(cache["conv"]["x"], xr, params["conv_x"], params["conv_bx"])
    Bm, cB = dconv(cache["conv"]["B"], Br, params["conv_B"], params["conv_bB"])
    Cm, cC = dconv(cache["conv"]["C"], Cr, params["conv_C"], params["conv_bC"])
    xs = xs.reshape(Bsz, H, P)
    Bm = jnp.repeat(Bm.reshape(Bsz, SSM_GROUPS, N), H // SSM_GROUPS, axis=1)
    Cm = jnp.repeat(Cm.reshape(Bsz, SSM_GROUPS, N), H // SSM_GROUPS, axis=1)
    # (conv caches already rolled by dconv above)
    A = -jnp.exp(params["A_log"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    dA = jnp.exp(dt * A).astype(xs.dtype)  # (B,H)
    dBx = jnp.einsum("bh,bhn,bhp->bhpn", dt.astype(xs.dtype), Bm, xs)
    h = cache["state"] * dA[..., None, None] + dBx
    y = jnp.einsum("bhn,bhpn->bhp", Cm, h) + params["D"].astype(xs.dtype)[:, None] * xs
    y = y.reshape(Bsz, -1)
    out = _gated_norm(y, z, params["norm_scale"], cfg.norm_eps) @ params["out_proj"]
    new_cache = {"state": h, "conv": {"x": cx, "B": cB, "C": cC}}
    return out[:, None], new_cache
