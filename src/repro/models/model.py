"""Unified model: embedding -> scanned layer groups -> norm -> lm head.

The layer stack is executed as ``lax.scan`` over ``cfg.num_groups`` stacked
parameter groups (each group = one period of ``cfg.pattern``), keeping HLO
size independent of depth.  Three entry points:

  forward_train(params, cfg, batch)            -> loss, metrics
  prefill(params, cfg, inputs)                 -> logits_last, cache
  decode_step(params, cfg, cache, token, pos)  -> logits, cache
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.config import LayerSpec, ModelConfig
from repro.models.shardctx import constrain


# ------------------------------------------------------------------------ init
def _init_block(cfg: ModelConfig, spec: LayerSpec, key, dtype):
    ks = jax.random.split(key, 3)
    p: Dict[str, Any] = {"norm1": L.init_rmsnorm(cfg.d_model, dtype)}
    if spec.mixer == "attn":
        p["attn"] = L.init_attention(cfg, ks[0], dtype)
    else:
        p["mamba"] = SSM.init_mamba(cfg, ks[0], dtype)
    if cfg.d_ff > 0:
        p["norm2"] = L.init_rmsnorm(cfg.d_model, dtype)
        if spec.moe:
            p["moe"] = MOE.init_moe(cfg, ks[1], dtype)
        else:
            p["mlp"] = L.init_mlp(cfg.d_model, cfg.d_ff, ks[1], dtype)
    return p


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    ke, kg, kh = jax.random.split(key, 3)
    params: Dict[str, Any] = {}
    if not cfg.embed_inputs:
        params["embed"] = (jax.random.normal(ke, (cfg.vocab_size, cfg.d_model))
                           * 0.02).astype(dtype)

    def init_group(gkey):
        keys = jax.random.split(gkey, cfg.group_size)
        return tuple(_init_block(cfg, spec, k, dtype)
                     for spec, k in zip(cfg.pattern, keys))

    gkeys = jax.random.split(kg, cfg.num_groups)
    params["groups"] = jax.vmap(init_group)(gkeys)
    params["final_norm"] = L.init_rmsnorm(cfg.d_model, dtype)
    if cfg.embed_inputs or not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(kh, (cfg.d_model, cfg.vocab_size))
                             * 0.02).astype(dtype)
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# --------------------------------------------------------------------- block fwd
def _block_full(p, h, cfg: ModelConfig, spec: LayerSpec, positions,
                want_cache: bool, max_seq: int):
    """Full-sequence block. Returns (h, cache_or_None, aux)."""
    aux = jnp.zeros((), jnp.float32)
    x = L.rms_norm(h, p["norm1"], cfg.norm_eps)
    cache = None
    if spec.mixer == "attn":
        y, (k, v) = L.attention_full(p["attn"], x, cfg, spec, positions)
        if want_cache:
            cache = L.prefill_to_cache(cfg, spec, k, v, max_seq)
    else:
        if want_cache:
            y, cache = SSM.mamba_forward(p["mamba"], x, cfg, return_cache=True)
        else:
            y = SSM.mamba_forward(p["mamba"], x, cfg)
    h = h + y
    if cfg.d_ff > 0:
        x = L.rms_norm(h, p["norm2"], cfg.norm_eps)
        if spec.moe:
            y, aux = MOE.moe_ffn(p["moe"], x, cfg)
        else:
            y = L.mlp(p["mlp"], x, cfg.mlp_act)
        h = h + y
    return h, cache, aux


def _block_decode(p, h, cache, pos, cfg: ModelConfig, spec: LayerSpec):
    x = L.rms_norm(h, p["norm1"], cfg.norm_eps)
    if spec.mixer == "attn":
        y, cache = L.attention_decode(p["attn"], x, cache, pos, cfg, spec)
    else:
        y, cache = SSM.mamba_decode(p["mamba"], x, cache, cfg)
    h = h + y
    if cfg.d_ff > 0:
        x = L.rms_norm(h, p["norm2"], cfg.norm_eps)
        if spec.moe:
            # (B,1,D): each decode token is its own dispatch group, keeping
            # the batch axis shardable over data
            y, _ = MOE.moe_ffn(p["moe"], x, cfg)
        else:
            y = L.mlp(p["mlp"], x, cfg.mlp_act)
        h = h + y
    return h, cache


# ------------------------------------------------------------------- embeddings
def _embed(params, cfg: ModelConfig, inputs):
    if cfg.embed_inputs:
        h = inputs  # (B,S,D) precomputed frontend embeddings
    else:
        h = jnp.take(params["embed"], inputs, axis=0)
    if cfg.scale_embeddings:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    return h


def _lm_head(params, cfg: ModelConfig, h):
    if "lm_head" in params:
        logits = h @ params["lm_head"]
    else:
        logits = h @ params["embed"].T
    if cfg.final_logit_softcap:
        c = cfg.final_logit_softcap
        logits = (c * jnp.tanh(logits.astype(jnp.float32) / c)).astype(logits.dtype)
    return constrain(logits, "logits")


# ------------------------------------------------------------------ full forward
def forward(params, cfg: ModelConfig, inputs, *, want_cache: bool = False,
            max_seq: Optional[int] = None, remat: bool = False):
    """Returns (logits, cache_groups_or_None, aux_loss)."""
    B = inputs.shape[0]
    S = inputs.shape[1]
    max_seq = max_seq or S
    h = constrain(_embed(params, cfg, inputs), "hidden")
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    # nested remat: the outer checkpoint makes the group scan O(1)-residual;
    # the inner per-block checkpoints keep the group-body backward peak at
    # ~one block's temps (jamba groups span 8 heterogeneous layers)
    def block(p, h, spec):
        return _block_full(p, h, cfg, spec, positions, want_cache, max_seq)

    # (nested per-block remat was tried and REGRESSED temp memory 99->141GB
    # on jamba train_4k — XLA duplicates recompute buffers; see §Perf log)

    def group_body(h, gp):
        caches, auxs = [], jnp.zeros((), jnp.float32)
        for i, spec in enumerate(cfg.pattern):
            h, c, a = block(gp[i], h, spec)
            h = constrain(h, "hidden")
            caches.append(c)
            auxs = auxs + a
        return h, (tuple(caches), auxs)

    body = jax.checkpoint(group_body) if remat else group_body
    h, (caches, auxs) = lax.scan(body, h, params["groups"])
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    aux = jnp.sum(auxs)
    return h, caches, aux


# sequence-chunked cross-entropy: full (B,S,V) float32 logits never exist
# (with 256k vocabs they would dominate per-chip HBM — see EXPERIMENTS.md)
LOSS_CHUNK = 512


def _chunked_xent(params, cfg: ModelConfig, h, labels, mask):
    """h: (B,S,D); labels/mask: (B,S).  Mean NLL over masked positions."""
    B, S, D = h.shape
    C = min(LOSS_CHUNK, S)
    if S % C:
        pad = C - S % C
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
        S += pad
    n = S // C
    hs = jnp.moveaxis(h.reshape(B, n, C, D), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, n, C), 1, 0)
    ms = jnp.moveaxis(mask.reshape(B, n, C), 1, 0)

    @jax.checkpoint
    def body(carry, xs):
        tot, cnt = carry
        hc, lc, mc = xs
        logits = _lm_head(params, cfg, hc).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        tot = tot + jnp.sum(jnp.where(mc, lse - gold, 0.0))
        cnt = cnt + jnp.sum(mc)
        return (tot, cnt), None

    (tot, cnt), _ = lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


def forward_train(params, cfg: ModelConfig, batch, remat: bool = True):
    """batch: {"tokens"|"embeds", "labels"}.  Returns (loss, metrics)."""
    inputs = batch["embeds"] if cfg.embed_inputs else batch["tokens"]
    labels = batch["labels"]
    h, _, aux = forward(params, cfg, inputs, remat=remat)
    if not cfg.embed_inputs:  # next-token LM: shift
        h, labels = h[:, :-1], labels[:, 1:]
    mask = jnp.ones(labels.shape, bool)
    nll = _chunked_xent(params, cfg, h, labels, mask)
    loss = nll + aux
    return loss, {"nll": nll, "aux": aux}


def prefill(params, cfg: ModelConfig, inputs, max_seq: int):
    """Returns (last-position logits, cache)."""
    h, caches, _ = forward(params, cfg, inputs, want_cache=True, max_seq=max_seq)
    logits = _lm_head(params, cfg, h[:, -1])
    return logits, caches


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.float32):
    """Empty decode cache, structure matching prefill output: a tuple (per
    pattern position) of arrays stacked over groups."""
    def one(spec: LayerSpec):
        if spec.mixer == "attn":
            c = L.init_kv_cache(cfg, spec, batch, max_seq, dtype)
        else:
            c = SSM.init_mamba_cache(cfg, batch, dtype)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.num_groups,) + x.shape), c)
    return tuple(one(spec) for spec in cfg.pattern)


def decode_step(params, cfg: ModelConfig, cache, inputs, pos):
    """inputs: (B,1) tokens or (B,1,D) embeds; pos: scalar position.
    Returns (logits (B,V), new cache)."""
    h = _embed(params, cfg, inputs)

    def group_body(h, xs):
        gp, gc = xs
        new = []
        for i, spec in enumerate(cfg.pattern):
            h, c = _block_decode(gp[i], h, gc[i], pos, cfg, spec)
            h = constrain(h, "hidden")
            new.append(c)
        return h, tuple(new)

    h, new_cache = lax.scan(group_body, h, (params["groups"], cache))
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    return _lm_head(params, cfg, h[:, 0]), new_cache
