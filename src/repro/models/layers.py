"""Core NN layers: norms, rotary embeddings (incl. M-RoPE), GQA attention
(global / sliding-window / chunked, softcap, qk-norm), and gated MLPs.

Pure JAX, explicit parameter pytrees (dicts).  Attention over long sequences
uses a query-chunked ``lax.scan`` so (S x S) score matrices are never
materialized — required for the 32k prefill shapes on the dry-run mesh.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import LayerSpec, ModelConfig
from repro.models.shardctx import constrain

# Query-chunk length for memory-efficient full-sequence attention.
Q_CHUNK = 1024


# --------------------------------------------------------------------------- norm
def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(x, params, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------- rope
def rope_angles(positions, head_dim: int, theta: float,
                mrope_sections: Optional[Tuple[int, int, int]] = None):
    """positions: (..., S) int32, or (3, ..., S) for M-RoPE.

    Returns cos, sin with shape (..., S, head_dim // 2), float32.
    """
    half = head_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    if mrope_sections is not None:
        # Each frequency index is driven by one of the (t, h, w) position
        # streams [arXiv:2409.12191].  Text-only inputs use identical streams.
        if positions.ndim == 2:  # plain (B,S) text positions -> broadcast
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        import numpy as np
        sec_id = jnp.asarray(np.repeat(np.arange(3), np.asarray(mrope_sections)))  # (half,)
        pos = jnp.take(positions, sec_id, axis=0)  # (half, ..., S)
        ang = jnp.moveaxis(pos, 0, -1).astype(jnp.float32) * inv_freq  # (...,S,half)
    else:
        ang = positions[..., None].astype(jnp.float32) * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, hd); cos/sin: (B, S, half) or (S, half)."""
    half = x.shape[-1] // 2
    if cos.ndim == 2:
        cos_, sin_ = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos_, sin_ = cos[:, :, None, :], sin[:, :, None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., :half], xf[..., half:]
    out = jnp.concatenate([x1 * cos_ - x2 * sin_, x2 * cos_ + x1 * sin_], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------- attention
def init_attention(cfg: ModelConfig, key, dtype=jnp.float32):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": (jax.random.normal(k1, (d, h * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (d, kv * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (d, kv * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (h * hd, d)) * (1.0 / math.sqrt(h * hd))).astype(dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    return p


def _qkv(params, x, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(B, S, h, hd)
    k = (x @ params["wk"]).reshape(B, S, kv, hd)
    v = (x @ params["wv"]).reshape(B, S, kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    if cfg.use_rope:
        cos, sin = rope_angles(positions, hd, cfg.rope_theta, cfg.mrope_sections)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return (constrain(q, "q_heads"), constrain(k, "kv_heads"),
            constrain(v, "kv_heads"))


def _scores_mask(q_pos, k_pos, cfg: ModelConfig, spec: LayerSpec, causal: bool):
    """(Q, K) boolean mask from absolute positions."""
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    m = kp >= 0  # invalid (unwritten ring slots) carry negative positions
    if causal:
        m &= kp <= qp
    if spec.attn_kind == "local":
        m &= kp > qp - cfg.sliding_window
    elif spec.attn_kind == "chunked":
        m &= (kp // cfg.attn_chunk) == (qp // cfg.attn_chunk)
    return m


def _attend(q, k, v, mask, cfg: ModelConfig):
    """q: (B,Q,H,hd)  k/v: (B,K,KV,hd)  mask: (Q,K) or (B,Q,K)."""
    B, Q, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    scale = cfg.attn_scale if cfg.attn_scale is not None else 1.0 / math.sqrt(hd)
    qr = q.reshape(B, Q, KV, rep, hd)
    logits = jnp.einsum("bqkrd,bskd->bkrqs", qr, k).astype(jnp.float32) * scale
    if cfg.attn_logit_softcap:
        c = cfg.attn_logit_softcap
        logits = c * jnp.tanh(logits / c)
    if mask.ndim == 2:
        mask = mask[None, None, None]
    else:
        mask = mask[:, None, None]
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkrqs,bskd->bqkrd", w, v)
    return constrain(out.reshape(B, Q, H * hd), "attn_out")


def attention_full(params, x, cfg: ModelConfig, spec: LayerSpec, positions=None):
    """Full-sequence attention (train / prefill), query-chunked over S."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    q, k, v = _qkv(params, x, cfg, positions)
    causal = cfg.causal
    kpos = jnp.arange(S, dtype=jnp.int32)

    if S <= Q_CHUNK:
        mask = _scores_mask(kpos, kpos, cfg, spec, causal)
        out = _attend(q, k, v, mask, cfg)
    else:
        assert S % Q_CHUNK == 0, f"S={S} not divisible by Q_CHUNK={Q_CHUNK}"
        n = S // Q_CHUNK
        qc = q.reshape(B, n, Q_CHUNK, *q.shape[2:]).transpose(1, 0, 2, 3, 4)

        def body(carry, inp):
            i, qi = inp
            qpos = i * Q_CHUNK + jnp.arange(Q_CHUNK, dtype=jnp.int32)
            mask = _scores_mask(qpos, kpos, cfg, spec, causal)
            return carry, _attend(qi, k, v, mask, cfg)

        _, outs = lax.scan(body, None, (jnp.arange(n), qc))
        out = outs.transpose(1, 0, 2, 3).reshape(B, S, -1)
    return out @ params["wo"], (k, v)


# ------------------------------------------------------------------ KV cache utils
def cache_len(cfg: ModelConfig, spec: LayerSpec, max_seq: int) -> int:
    if spec.attn_kind == "local":
        return min(max_seq, cfg.sliding_window)
    if spec.attn_kind == "chunked":
        return min(max_seq, cfg.attn_chunk)
    return max_seq


def init_kv_cache(cfg: ModelConfig, spec: LayerSpec, batch: int, max_seq: int,
                  dtype=jnp.float32):
    L = cache_len(cfg, spec, max_seq)
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, L, kv, hd), dtype),
        "v": jnp.zeros((batch, L, kv, hd), dtype),
        # absolute position held by each slot; -1 => empty
        "pos": jnp.full((L,), -1, jnp.int32),
    }


def prefill_to_cache(cfg, spec, k, v, max_seq: int):
    """Convert full-sequence rope'd k/v (B,S,KV,hd) into a decode cache of
    length ``cache_len`` (ring layout: slot = pos % L)."""
    B, S, KV, hd = k.shape
    L = cache_len(cfg, spec, max_seq)
    if L == max_seq and S <= L:
        pad = L - S
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos = jnp.concatenate([jnp.arange(S, dtype=jnp.int32),
                               jnp.full((pad,), -1, jnp.int32)])
        return {"k": kc, "v": vc, "pos": pos}
    # keep last L positions, ring-ordered
    start = S - L
    ppos = start + jnp.arange(L, dtype=jnp.int32)
    slots = ppos % L
    kc = jnp.zeros((B, L, KV, hd), k.dtype).at[:, slots].set(k[:, start:])
    vc = jnp.zeros((B, L, KV, hd), v.dtype).at[:, slots].set(v[:, start:])
    pos = jnp.zeros((L,), jnp.int32).at[slots].set(ppos)
    return {"k": kc, "v": vc, "pos": pos}


def attention_decode(params, x, cache, pos, cfg: ModelConfig, spec: LayerSpec):
    """One-token decode.  x: (B,1,D); pos: scalar int32 (position of x)."""
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    q, k, v = _qkv(params, x, cfg, positions)  # (B,1,·,hd), rope'd at abs pos
    L = cache["k"].shape[1]
    slot = pos % L
    kc = lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    vc = lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    cpos = cache["pos"].at[slot].set(pos)
    mask = _scores_mask(positions[0], cpos, cfg, spec, causal=True)  # (1,L)
    out = _attend(q, kc, vc, mask, cfg)
    return out @ params["wo"], {"k": kc, "v": vc, "pos": cpos}


# --------------------------------------------------------------------------- MLP
def init_mlp(d: int, f: int, key, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    s, so = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    return {
        "w_gate": (jax.random.normal(k1, (d, f)) * s).astype(dtype),
        "w_up": (jax.random.normal(k2, (d, f)) * s).astype(dtype),
        "w_down": (jax.random.normal(k3, (f, d)) * so).astype(dtype),
    }


def mlp(params, x, act: str = "silu"):
    a = jax.nn.silu if act == "silu" else jax.nn.gelu
    h = constrain(a(x @ params["w_gate"]) * (x @ params["w_up"]), "ffn")
    return h @ params["w_down"]
