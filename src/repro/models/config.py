"""Model configuration for every assigned architecture family.

One frozen dataclass covers dense / MoE / SSM / hybrid / VLM-backbone /
audio-encoder families.  Layer heterogeneity (gemma2 local/global
alternation, jamba 1:7 mamba:attn interleave, MoE strides) is expressed as a
repeating *group pattern* so the layer stack can be executed with a single
``lax.scan`` over stacked parameter groups — essential to keep HLO size and
compile time bounded for 72-layer models on the 512-chip dry-run mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer inside the repeating group pattern."""

    mixer: str = "attn"  # "attn" | "mamba"
    attn_kind: str = "global"  # "global" | "local" (sliding window) | "chunked"
    moe: bool = False

    def __post_init__(self):
        assert self.mixer in ("attn", "mamba")
        assert self.attn_kind in ("global", "local", "chunked")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # defaults to d_model // num_heads

    # --- layer pattern -----------------------------------------------------
    # The stack is ``num_layers / len(pattern)`` repetitions of ``pattern``.
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)

    # --- attention variants -------------------------------------------------
    causal: bool = True  # False => encoder-only (hubert)
    use_rope: bool = True
    rope_theta: float = 10_000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    sliding_window: int = 4096  # used by "local" layers
    attn_chunk: int = 8192  # used by "chunked" layers (llama4 iRoPE-style)
    attn_logit_softcap: Optional[float] = None  # gemma2
    final_logit_softcap: Optional[float] = None  # gemma2
    qk_norm: bool = False  # qwen3
    attn_scale: Optional[float] = None  # override 1/sqrt(head_dim)

    # --- MLP variants -------------------------------------------------------
    mlp_act: str = "silu"  # "silu" (SwiGLU) | "gelu" (GeGLU)

    # --- MoE ------------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    shared_expert: bool = False  # llama4: always-on shared expert
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25

    # --- SSM (mamba2 / SSD) ---------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # --- embeddings / io --------------------------------------------------------
    embed_inputs: bool = False  # vlm/audio: inputs are (B,S,D) embeddings
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # gemma-style sqrt(d) embedding scaling
    scale_embeddings: bool = False

    citation: str = ""

    # ------------------------------------------------------------------ utils
    def __post_init__(self):
        assert self.num_layers % len(self.pattern) == 0, (
            f"{self.name}: num_layers={self.num_layers} not divisible by "
            f"pattern period {len(self.pattern)}"
        )
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def group_size(self) -> int:
        return len(self.pattern)

    @property
    def num_groups(self) -> int:
        return self.num_layers // len(self.pattern)

    @property
    def ssm_inner(self) -> int:
        return self.d_model * self.ssm_expand

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    def has_mixer(self, mixer: str) -> bool:
        return any(s.mixer == mixer for s in self.pattern)

    @property
    def supports_decode(self) -> bool:
        return self.causal

    @property
    def sub_quadratic(self) -> bool:
        """True if no layer needs an unbounded full-attention KV cache, or the
        full-attention layers are sparse enough for 500k decode (hybrid /
        alternating patterns keep O(S) global layers bounded)."""
        kinds = {
            (s.mixer, s.attn_kind if s.mixer == "attn" else "-") for s in self.pattern
        }
        full = ("attn", "global") in kinds
        non_full = len(kinds - {("attn", "global")}) > 0
        return (not full) or non_full  # pure-global-attention stacks excluded

    def reduced(self, **over) -> "ModelConfig":
        """A small same-family variant for CPU smoke tests."""
        period = len(self.pattern)
        d_model = min(self.d_model, 256)
        head_dim = 32 if self.head_dim >= 32 else self.head_dim
        n_heads = max(2, min(4, d_model // head_dim))
        kv = max(1, min(self.num_kv_heads, n_heads // 2)) if self.num_kv_heads < self.num_heads else n_heads
        kw = dict(
            num_layers=2 * period if period <= 4 else period,
            d_model=d_model,
            num_heads=n_heads,
            num_kv_heads=kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=min(self.ssm_head_dim, 32),
            ssm_chunk=16,
            sliding_window=64,
            attn_chunk=64,
        )
        if self.mrope_sections is not None:
            half = (32 if self.head_dim >= 32 else self.head_dim) // 2
            t = half // 4
            kw["mrope_sections"] = (t, (half - t) // 2, half - t - (half - t) // 2)
        kw.update(over)
        return dataclasses.replace(self, name=self.name + "-smoke", **kw)
