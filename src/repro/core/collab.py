"""COACH collaborative execution in JAX: the model's scanned group stack is
split at one or more partition points; segment 0 runs on the "end" device,
each boundary activation is UAQ-quantized (Pallas kernel), transferred over
its hop as a ``WirePacket``, dequantized and continued on the next tier —
the last segment (the "cloud") finishes with norm + head.  The classic
end->cloud deployment is the single-cut case of the same machinery.

Two realizations:

  1. ``CollabRuntime`` — ``n_hops + 1`` jitted stage functions with an
     explicit wire format between them (one ``WirePacket`` per hop).  Runs
     anywhere (CPU tests/examples); the wire bytes are exactly what the
     cost model prices, and the online component consumes the GAP features
     computed by the fused semantic-probe kernel on the first boundary.

  2. ``make_collab_pipeline_step`` — the multi-pod SPMD form: layer groups
     sharded over the "pod" mesh axis, microbatched software pipeline where
     pod 1 completes microbatch i while pod 0 computes i+1 (Fig. 2 scheme 2),
     boundary tensors moved by ``ppermute`` after quantization.  Lowered and
     compiled in the dry-run.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops as KOPS
from repro.models import layers as L
from repro.models import model as M
from repro.models.config import ModelConfig


# ---------------------------------------------------------------- splitting
def split_params_multi(params, cfg: ModelConfig,
                       cut_groups: Sequence[int]) -> List[Dict]:
    """Split stacked group params at each cut in ``cut_groups`` (strictly
    increasing group indices) into ``len(cut_groups) + 1`` per-device
    segments: segment k runs groups ``[cut_{k-1}, cut_k)``.  Segment 0 owns
    the embedding; the last segment owns final norm + head (and the tied
    embedding when the head is tied)."""
    cuts = list(cut_groups)
    assert all(0 < c < cfg.num_groups for c in cuts), cuts
    assert all(a < b for a, b in zip(cuts, cuts[1:])), "cuts must increase"
    take = lambda t, sl: jax.tree.map(lambda x: x[sl], t)
    bounds = [0] + cuts + [cfg.num_groups]
    segs: List[Dict] = [
        {"groups": take(params["groups"], slice(bounds[k], bounds[k + 1]))}
        for k in range(len(bounds) - 1)]
    segs[-1]["final_norm"] = params["final_norm"]
    if "embed" in params:
        segs[0]["embed"] = params["embed"]
        if "lm_head" not in params:  # tied head lives on the cloud too
            segs[-1]["embed"] = params["embed"]
    if "lm_head" in params:
        segs[-1]["lm_head"] = params["lm_head"]
    return segs


def split_params(params, cfg: ModelConfig, cut_group: int):
    """Classic 2-device split at ``cut_group`` (end gets [0, cut))."""
    end, cloud = split_params_multi(params, cfg, (cut_group,))
    return end, cloud


def _run_groups(groups, h, cfg: ModelConfig, positions):
    def group_body(hh, gp):
        for i, spec in enumerate(cfg.pattern):
            hh, _, _ = M._block_full(gp[i], hh, cfg, spec, positions,
                                     False, hh.shape[1])
        return hh, None
    h, _ = lax.scan(group_body, h, groups)
    return h


# ---------------------------------------------------------------- runtime
@dataclasses.dataclass
class WirePacket:
    """Quantized boundary activation as transmitted over one hop."""
    payload: jnp.ndarray  # uint8 (B,S,ceil(D*bits/8))
    scale: jnp.ndarray
    zp: jnp.ndarray
    bits: int
    hop: int = 0  # which link this packet crosses (0 = end's uplink)
    # true channel count when the 4-bit payload carries an odd-D
    # zero-nibble pad (None = the payload width is exact)
    channels: Optional[int] = None

    @property
    def wire_bytes(self) -> int:
        return (self.payload.size + self.scale.size * 4 + self.zp.size * 4)

    def dequantize(self, out_dtype=jnp.float32) -> jnp.ndarray:
        return KOPS.dequantize_activation(
            self.payload, self.scale, self.zp, self.bits,
            out_dtype=out_dtype, channels=self.channels)


@dataclasses.dataclass
class BoundaryProbe:
    """Semantic-probe outputs of one fused boundary pass (Eq. 8-9 on the
    GAP feature, computed in the same HBM read that quantized the wire
    packet).  ``best`` indexes into the ``centers`` matrix the pass was
    given (the caller's trained-center view, not the full label space)."""
    feat: jnp.ndarray  # (B, D) GAP features (feeds Eq. 7 center updates)
    sep: jnp.ndarray   # (B,)  task separability (Eq. 9)
    best: jnp.ndarray  # (B,)  int32 argmax similarity (Eq. 10)
    sims: jnp.ndarray  # (B, L) similarity degrees in [0, 1] (Eq. 8)


class CollabRuntime:
    """Staged executor for one model + (multi-)partition decision.

    ``cut_group`` may be a single group index (classic end->cloud split)
    or an increasing sequence of indices (end -> edge tiers -> cloud, one
    ``WirePacket`` per hop).  ``default_bits`` is likewise an int or a
    per-hop sequence."""

    def __init__(self, cfg: ModelConfig, params,
                 cut_group: Union[int, Sequence[int]],
                 default_bits: Union[int, Sequence[int]] = 8):
        self.cfg = cfg
        self.cuts: Tuple[int, ...] = tuple(cut_group) \
            if isinstance(cut_group, (tuple, list)) else (int(cut_group),)
        self.cut = self.cuts[0]
        bits = tuple(default_bits) \
            if isinstance(default_bits, (tuple, list)) else \
            (int(default_bits),) * self.n_hops
        assert len(bits) == self.n_hops, "need one default_bits per hop"
        self.default_bits_per_hop = bits
        self.default_bits = bits[0]
        self.p_segments = split_params_multi(params, cfg, self.cuts)
        self._seg_fns = (
            [jax.jit(self._first_forward)]
            + [jax.jit(self._mid_forward)] * (self.n_hops - 1)
            + [jax.jit(self._last_forward)])
        self._probe = KOPS.probe_cache

    @property
    def n_hops(self) -> int:
        return len(self.cuts)

    @property
    def n_segments(self) -> int:
        return self.n_hops + 1

    # classic 2-segment views
    @property
    def p_end(self):
        return self.p_segments[0]

    @property
    def p_cloud(self):
        return self.p_segments[-1]

    @property
    def _end_fn(self):
        return self._seg_fns[0]

    @property
    def _cloud_fn(self):
        return self._seg_fns[-1]

    # ---- per-segment forwards (jitted)
    @staticmethod
    def _positions(B: int, S: int) -> jnp.ndarray:
        return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def _first_forward(self, p, inputs):
        cfg = self.cfg
        B, S = inputs.shape[:2]
        h = M._embed({**p}, cfg, inputs)
        return _run_groups(p["groups"], h, cfg, self._positions(B, S))

    def _mid_forward(self, p, h):
        B, S = h.shape[:2]
        return _run_groups(p["groups"], h, self.cfg, self._positions(B, S))

    def _last_forward(self, p, h):
        cfg = self.cfg
        B, S = h.shape[:2]
        h = _run_groups(p["groups"], h, cfg, self._positions(B, S))
        h = L.rms_norm(h, p["final_norm"], cfg.norm_eps)
        return M._lm_head(p, cfg, h[:, -1])

    def _quantize(self, h, hop: int, bits: Optional[int]) -> WirePacket:
        bits = bits or self.default_bits_per_hop[hop]
        payload, scale, zp = KOPS.quantize_activation(h, bits)
        return WirePacket(payload, scale, zp, bits, hop=hop,
                          channels=h.shape[-1])

    def segment_step(self, k: int, x, bits: Optional[int] = None,
                     centers=None):
        """Run segment ``k``.  ``x`` is the raw model input for ``k = 0``,
        else the ``WirePacket`` delivered over hop ``k-1``.  Intermediate
        segments return ``(WirePacket for hop k, boundary activation)``;
        the last segment returns the logits.

        ``centers`` (an (L, D) trained-center matrix) switches an
        intermediate segment to the *fused* boundary path: quantize +
        pack + semantic probe in a single HBM read of the boundary
        activation (``kernels.boundary``), returning ``(WirePacket,
        BoundaryProbe)`` instead — the probe outputs replace the raw
        activation, so nothing re-reads the fp32 tensor (which is donated
        to the fused pass on accelerator backends)."""
        if k > 0:
            assert isinstance(x, WirePacket) and x.hop == k - 1, \
                f"segment {k} consumes the hop-{k - 1} packet"
            x = x.dequantize()
        h = self._seg_fns[k](self.p_segments[k], x)
        if k == self.n_hops:
            return h
        if centers is not None:
            bits = bits or self.default_bits_per_hop[k]
            payload, scale, zp, feat, sep, best, sims = \
                KOPS.boundary_pass(h, centers, bits)
            pkt = WirePacket(payload, scale, zp, bits, hop=k,
                             channels=self.cfg.d_model)
            return pkt, BoundaryProbe(feat, sep, best, sims)
        return self._quantize(h, k, bits), h

    def segment_handle(self, k: int, probe_centers=None, on_probe=None):
        """Bound per-segment callable for hop-queue workers.

        Worker ``k`` applies the handle to the payload it dequeued (the
        raw model input for ``k = 0``, else the hop-``k-1`` ``WirePacket``)
        and forwards the result: intermediate segments yield the hop-``k``
        packet, the last segment yields the logits.

        ``probe_centers`` (a zero-arg callable returning the current
        trained-center matrix for this boundary) switches intermediate
        segments to the fused single-read path; each pass's
        ``BoundaryProbe`` is delivered through ``on_probe(k, probe)`` —
        the forwarded payload stays the plain ``WirePacket`` the next
        hop-queue worker expects."""
        assert 0 <= k <= self.n_hops, k

        def handle(x, bits: Optional[int] = None):
            if probe_centers is not None and k < self.n_hops:
                pkt, probe = self.segment_step(k, x, bits=bits,
                                               centers=probe_centers())
                if on_probe is not None:
                    on_probe(k, probe)
                return pkt
            out = self.segment_step(k, x, bits=bits)
            return out[0] if isinstance(out, tuple) else out

        return handle

    # ---- stage A (end device / pod 0)
    def end_step(self, inputs, bits: Optional[int] = None
                 ) -> Tuple[WirePacket, jnp.ndarray]:
        """Returns (hop-0 wire packet, boundary activation pre-quant)."""
        return self.segment_step(0, inputs, bits=bits)

    def end_step_fused(self, inputs, centers, bits: Optional[int] = None
                       ) -> Tuple[WirePacket, BoundaryProbe]:
        """Fused end step: forward + quantize + pack + semantic probe
        with a single HBM read of the boundary activation.  Returns the
        hop-0 wire packet and the probe outputs (GAP feature included),
        instead of the raw activation the classic ``end_step`` hands
        back for a second probe read."""
        return self.segment_step(0, inputs, bits=bits, centers=centers)

    def probe(self, h, centers):
        """Fused GAP+cosine+separability on the boundary activation."""
        return self._probe(h, centers)

    # ---- stage B (cloud / last segment); classic path keeps working for
    # single-cut runtimes, and for multi-cut ones this relays the packet
    # through the remaining tiers.
    def cloud_step(self, packet: WirePacket) -> jnp.ndarray:
        out = packet
        for k in range(packet.hop + 1, self.n_segments):
            out = self.segment_step(k, out)
            if isinstance(out, tuple):
                out = out[0]
        return out

    def run(self, inputs, bits: Optional[Sequence[Optional[int]]] = None):
        """Full multi-hop forward: returns (logits, per-hop packets)."""
        bits = tuple(bits) if bits is not None else (None,) * self.n_hops
        assert len(bits) == self.n_hops
        packets: List[WirePacket] = []
        pkt, _ = self.segment_step(0, inputs, bits=bits[0])
        packets.append(pkt)
        for k in range(1, self.n_hops):
            pkt, _ = self.segment_step(k, pkt, bits=bits[k])
            packets.append(pkt)
        logits = self.segment_step(self.n_segments - 1, pkt)
        return logits, packets

    # ---- reference: monolithic forward (accuracy-loss measurement)
    def monolithic(self, params, inputs):
        h, _, _ = M.forward(params, self.cfg, inputs)
        return M._lm_head(params, self.cfg, h[:, -1])


# ------------------------------------------------------- multi-pod pipeline
def make_collab_pipeline_step(cfg: ModelConfig, mesh, *, bits: int = 8,
                              n_micro: int = 2):
    """SPMD two-pod software pipeline (dry-run artifact).

    params["groups"] leaves are sharded P("pod", ...) — the end pod owns the
    first half of the layer groups, the cloud pod the second half.  Each
    pipeline tick: every pod runs its local groups on its current
    microbatch, then the boundary activation is UAQ-quantized and
    ``ppermute``d pod0 -> pod1 while pod 0 starts the next microbatch
    (near bubble-free: the transfer overlaps compute, Fig. 2 scheme 3).
    """
    from jax.sharding import PartitionSpec as P

    assert "pod" in mesh.axis_names, "multi-pod mesh required"
    auto = frozenset(a for a in mesh.axis_names if a != "pod")

    def local_groups_fwd(groups, h, positions):
        return _run_groups(groups, h, cfg, positions)

    def step(params, tokens):
        """tokens: (n_micro, B_mb, S) int32 (or embeds (..., D))."""
        B_mb, S = tokens.shape[1], tokens.shape[2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B_mb, S))

        dt = jax.tree.leaves(params["groups"])[0].dtype

        def spmd(groups, tok):
            pod = lax.axis_index("pod")
            n_ticks = n_micro + 1
            h_buf = jnp.zeros((B_mb, S, cfg.d_model), dt)
            outs = jnp.zeros((n_micro, B_mb, S, cfg.d_model), dt)

            def tick(t, carry):
                h_recv, outs = carry
                mb = jnp.clip(t, 0, n_micro - 1)
                tok_mb = tok[mb]
                # pod 0 embeds its (current) microbatch; pod 1 continues
                # from the dequantized boundary activation it received
                h0 = M._embed(params, cfg, tok_mb).astype(dt)
                h_in = jnp.where(pod == 0, h0, h_recv)
                h = local_groups_fwd(groups[0], h_in, positions)
                # quantize boundary + move across the pod axis through
                # the shared trace-safe wire entry (KOPS.wire_*): the
                # Pallas kernel on TPU, the exact jnp reference on
                # backends where interpret-mode Pallas cannot compile
                # inside a manual shard_map region — so the runtime,
                # this SPMD pipeline, and the bench measure one path
                flat = h.reshape(-1, cfg.d_model)
                q, sc, zp = KOPS.wire_quantize(flat, bits)
                q, sc, zp = [lax.ppermute(x, "pod", [(0, 1)])
                             for x in (q, sc, zp)]
                h_next = KOPS.wire_dequantize(
                    q, sc, zp, bits, out_dtype=dt, channels=cfg.d_model
                ).reshape(B_mb, S, cfg.d_model)
                done = jnp.where(pod == 1, h, jnp.zeros_like(h))
                outs = lax.dynamic_update_index_in_dim(
                    outs, done, jnp.clip(t - 1, 0, n_micro - 1), 0)
                return (h_next, outs)

            h_recv, outs = lax.fori_loop(0, n_ticks, tick, (h_buf, outs))
            # pod 0 holds zeros; reduce so the (replicated) output is pod 1's
            return lax.psum(outs, "pod")

        if hasattr(jax, "shard_map"):  # jax >= 0.6 API
            fn = jax.shard_map(
                spmd, mesh=mesh,
                in_specs=(P("pod"), P()),
                out_specs=P(),
                check_vma=False,
                axis_names=frozenset({"pod"}),
            )
        else:  # jax 0.4.x: experimental API (check_rep, auto)
            from jax.experimental.shard_map import shard_map as _shard_map
            fn = _shard_map(
                spmd, mesh=mesh,
                in_specs=(P("pod"), P()),
                out_specs=P(),
                check_rep=False,
                auto=auto,
            )
        # final norm + head on the pipeline output (cloud side)
        h = fn((params["groups"],), tokens)
        h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
        return M._lm_head(params, cfg, h[:, :, -1])

    return step
