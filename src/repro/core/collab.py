"""COACH collaborative execution in JAX: the model's scanned group stack is
split at a partition point; the end segment runs on the "end" (pod 0), the
boundary activation is UAQ-quantized (Pallas kernel), transferred, dequantized
and completed on the "cloud" (pod 1).

Two realizations:

  1. ``CollabRuntime`` — two jitted stage functions with an explicit wire
     format between them.  Runs anywhere (CPU tests/examples); the wire
     bytes are exactly what the cost model prices, and the online component
     consumes the GAP features computed by the fused semantic-probe kernel.

  2. ``make_collab_pipeline_step`` — the multi-pod SPMD form: layer groups
     sharded over the "pod" mesh axis, microbatched software pipeline where
     pod 1 completes microbatch i while pod 0 computes i+1 (Fig. 2 scheme 2),
     boundary tensors moved by ``ppermute`` after quantization.  Lowered and
     compiled in the dry-run.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops as KOPS
from repro.kernels import ref as REF
from repro.models import layers as L
from repro.models import model as M
from repro.models.config import ModelConfig


# ---------------------------------------------------------------- splitting
def split_params(params, cfg: ModelConfig, cut_group: int):
    """Split stacked group params at ``cut_group`` (end gets [0, cut))."""
    take = lambda t, sl: jax.tree.map(lambda x: x[sl], t)
    end = {"groups": take(params["groups"], slice(0, cut_group))}
    cloud = {"groups": take(params["groups"], slice(cut_group, None)),
             "final_norm": params["final_norm"]}
    if "embed" in params:
        end["embed"] = params["embed"]
        if "lm_head" not in params:  # tied head lives on the cloud too
            cloud["embed"] = params["embed"]
    if "lm_head" in params:
        cloud["lm_head"] = params["lm_head"]
    return end, cloud


def _run_groups(groups, h, cfg: ModelConfig, positions):
    def group_body(hh, gp):
        for i, spec in enumerate(cfg.pattern):
            hh, _, _ = M._block_full(gp[i], hh, cfg, spec, positions,
                                     False, hh.shape[1])
        return hh, None
    h, _ = lax.scan(group_body, h, groups)
    return h


# ---------------------------------------------------------------- runtime
@dataclasses.dataclass
class WirePacket:
    """Quantized boundary activation as transmitted end -> cloud."""
    payload: jnp.ndarray  # uint8 (B,S,D*bits/8)
    scale: jnp.ndarray
    zp: jnp.ndarray
    bits: int

    @property
    def wire_bytes(self) -> int:
        return (self.payload.size + self.scale.size * 4 + self.zp.size * 4)


class CollabRuntime:
    """End/cloud staged executor for one model + partition decision."""

    def __init__(self, cfg: ModelConfig, params, cut_group: int,
                 default_bits: int = 8):
        self.cfg = cfg
        self.cut = cut_group
        self.default_bits = default_bits
        self.p_end, self.p_cloud = split_params(params, cfg, cut_group)
        self._end_fn = jax.jit(self._end_forward)
        self._cloud_fn = jax.jit(self._cloud_forward)
        self._probe = KOPS.probe_cache

    # ---- stage A (end device / pod 0)
    def _end_forward(self, p_end, inputs):
        cfg = self.cfg
        B, S = inputs.shape[:2]
        h = M._embed({**p_end}, cfg, inputs)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
        return _run_groups(p_end["groups"], h, cfg, positions)

    def end_step(self, inputs, bits: Optional[int] = None
                 ) -> Tuple[WirePacket, jnp.ndarray]:
        """Returns (wire packet, boundary activation pre-quant)."""
        h = self._end_fn(self.p_end, inputs)
        bits = bits or self.default_bits
        payload, scale, zp = KOPS.quantize_activation(h, bits)
        return WirePacket(payload, scale, zp, bits), h

    def probe(self, h, centers):
        """Fused GAP+cosine+separability on the boundary activation."""
        return self._probe(h, centers)

    # ---- stage B (cloud / pod 1)
    def _cloud_forward(self, p_cloud, h):
        cfg = self.cfg
        B, S = h.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
        h = _run_groups(p_cloud["groups"], h, cfg, positions)
        h = L.rms_norm(h, p_cloud["final_norm"], cfg.norm_eps)
        return M._lm_head(p_cloud, cfg, h[:, -1])

    def cloud_step(self, packet: WirePacket) -> jnp.ndarray:
        h = KOPS.dequantize_activation(
            packet.payload, packet.scale, packet.zp, packet.bits,
            out_dtype=jnp.float32)
        return self._cloud_fn(self.p_cloud, h)

    # ---- reference: monolithic forward (accuracy-loss measurement)
    def monolithic(self, params, inputs):
        h, _, _ = M.forward(params, self.cfg, inputs)
        return M._lm_head(params, self.cfg, h[:, -1])


# ------------------------------------------------------- multi-pod pipeline
def make_collab_pipeline_step(cfg: ModelConfig, mesh, *, bits: int = 8,
                              n_micro: int = 2):
    """SPMD two-pod software pipeline (dry-run artifact).

    params["groups"] leaves are sharded P("pod", ...) — the end pod owns the
    first half of the layer groups, the cloud pod the second half.  Each
    pipeline tick: every pod runs its local groups on its current
    microbatch, then the boundary activation is UAQ-quantized and
    ``ppermute``d pod0 -> pod1 while pod 0 starts the next microbatch
    (near bubble-free: the transfer overlaps compute, Fig. 2 scheme 3).
    """
    from jax.sharding import PartitionSpec as P

    assert "pod" in mesh.axis_names, "multi-pod mesh required"
    auto = frozenset(a for a in mesh.axis_names if a != "pod")

    def local_groups_fwd(groups, h, positions):
        return _run_groups(groups, h, cfg, positions)

    def step(params, tokens):
        """tokens: (n_micro, B_mb, S) int32 (or embeds (..., D))."""
        B_mb, S = tokens.shape[1], tokens.shape[2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B_mb, S))

        dt = jax.tree.leaves(params["groups"])[0].dtype

        def spmd(groups, tok):
            pod = lax.axis_index("pod")
            n_ticks = n_micro + 1
            h_buf = jnp.zeros((B_mb, S, cfg.d_model), dt)
            outs = jnp.zeros((n_micro, B_mb, S, cfg.d_model), dt)

            def tick(t, carry):
                h_recv, outs = carry
                mb = jnp.clip(t, 0, n_micro - 1)
                tok_mb = tok[mb]
                # pod 0 embeds its (current) microbatch; pod 1 continues
                # from the dequantized boundary activation it received
                h0 = M._embed(params, cfg, tok_mb).astype(dt)
                h_in = jnp.where(pod == 0, h0, h_recv)
                h = local_groups_fwd(groups[0], h_in, positions)
                # quantize boundary + move across the pod axis (jnp
                # reference semantics here: the Pallas interpret kernel
                # cannot compile inside a manual shard_map region on the
                # CPU dry-run backend; on TPU swap KOPS.quantize_activation
                # back in — identical math, tested against it)
                flat = h.reshape(-1, cfg.d_model)
                q, sc, zp = REF.uaq_quantize_ref(flat, bits)
                q, sc, zp = [lax.ppermute(x, "pod", [(0, 1)])
                             for x in (q, sc, zp)]
                h_next = REF.uaq_dequantize_ref(
                    q, sc, zp, bits, out_dtype=dt
                ).reshape(B_mb, S, cfg.d_model)
                done = jnp.where(pod == 1, h, jnp.zeros_like(h))
                outs = lax.dynamic_update_index_in_dim(
                    outs, done, jnp.clip(t - 1, 0, n_micro - 1), 0)
                return (h_next, outs)

            h_recv, outs = lax.fori_loop(0, n_ticks, tick, (h_buf, outs))
            # pod 0 holds zeros; reduce so the (replicated) output is pod 1's
            return lax.psum(outs, "pod")

        fn = jax.shard_map(
            spmd, mesh=mesh,
            in_specs=(P("pod"), P()),
            out_specs=P(),
            check_vma=False,
            axis_names=frozenset({"pod"}),
        )
        # final norm + head on the pipeline output (cloud side)
        h = fn((params["groups"],), tokens)
        h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
        return M._lm_head(params, cfg, h[:, :, -1])

    return step
