"""The paper's four baselines, implemented on the same cost substrate and
scored by the same event simulator — so Table I / Figs. 5-7 comparisons are
apples-to-apples.

  NS    (Neurosurgeon [5])  min single-task latency, chain cut, no quant.
  DADS  [2]                 min-cut style partition for pipeline load,
                            optimizes max(T_e, T_c); no quantization.
  SPINN [25]                partition + fixed 8-bit quantization + early
                            exit at a fixed confidence threshold.
  JPS   [10]                layer-level pipeline schedule balancing the end
                            computation and transmission stages (cloud stage
                            neglected — the paper's critique of it).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from repro.core.costs import DeviceProfile, LinkProfile, ModelGraph
from repro.core.partitioner import chain_flow
from repro.core.schedule import PartitionDecision, StageTimes, evaluate_partition


@dataclasses.dataclass
class BaselineResult:
    decision: PartitionDecision
    times: StageTimes
    extra: Dict = dataclasses.field(default_factory=dict)


def _chain_cuts(graph: ModelGraph):
    """Candidate end-sets from chain-level cuts (incl. empty / full)."""
    elems = chain_flow(graph)
    prefix, cuts = [], [frozenset()]
    for e in elems:
        prefix.extend(e.ids())
        cuts.append(frozenset(prefix))
    return cuts


def _eval(graph, end_set, bits_all, end_dev, cloud_dev, link, name):
    bits = {e: bits_all for e in graph.boundary_edges(end_set) if e[0] >= 0}
    dec = PartitionDecision(end_set, bits, name=name)
    return dec, evaluate_partition(graph, dec, end_dev, cloud_dev, link)


def neurosurgeon(graph: ModelGraph, end_dev: DeviceProfile,
                 cloud_dev: DeviceProfile, link: LinkProfile) -> BaselineResult:
    """Min end-to-end single-task latency; fp32 transfers."""
    best = None
    for cut in _chain_cuts(graph):
        dec, st = _eval(graph, cut, 32, end_dev, cloud_dev, link, "ns")
        if best is None or st.latency < best[1].latency:
            best = (dec, st)
    return BaselineResult(*best)


def dads(graph: ModelGraph, end_dev, cloud_dev, link) -> BaselineResult:
    """Heavy-load mode: min max stage (pipeline throughput) over all three
    stages, fp32 transfers (no quantization), latency tie-break."""
    best = None
    for cut in _chain_cuts(graph):
        dec, st = _eval(graph, cut, 32, end_dev, cloud_dev, link, "dads")
        key = (st.max_stage, st.latency)
        if best is None or key < best[2]:
            best = (dec, st, key)
    return BaselineResult(best[0], best[1])


def spinn(graph: ModelGraph, end_dev, cloud_dev, link,
          exit_ratio_hint: float = 0.0) -> BaselineResult:
    """Latency-min partition with fixed 8-bit quantization; early exit at a
    fixed threshold (its exit ratio is data-dependent and supplied by the
    driver as ``exit_ratio_hint``).  Progressive device-first inference =>
    non-empty end segment."""
    best = None
    for cut in _chain_cuts(graph):
        if not cut:
            continue
        dec, st = _eval(graph, cut, 8, end_dev, cloud_dev, link, "spinn")
        if best is None or st.latency < best[1].latency:
            best = (dec, st)
    return BaselineResult(best[0], best[1], {"exit_ratio": exit_ratio_hint})


def jps(graph: ModelGraph, end_dev, cloud_dev, link) -> BaselineResult:
    """Near-optimal end/transmission pipeline schedule: min max(T_e, T_t)
    with 8-bit transfers; the cloud stage is not balanced (per the paper's
    critique, it may become the pipeline bottleneck)."""
    best = None
    for cut in _chain_cuts(graph):
        dec, st = _eval(graph, cut, 8, end_dev, cloud_dev, link, "jps")
        key = (max(st.T_e, st.T_t), st.latency)
        if best is None or key < best[2]:
            best = (dec, st, key)
    return BaselineResult(best[0], best[1])


BASELINES = {
    "NS": neurosurgeon,
    "DADS": dads,
    "SPINN": spinn,
    "JPS": jps,
}
