"""The paper's four baselines, implemented on the same cost substrate and
scored by the same event simulator — so Table I / Figs. 5-7 comparisons are
apples-to-apples.

  NS    (Neurosurgeon [5])  min single-task latency, chain cut, no quant.
  DADS  [2]                 min-cut style partition for pipeline load,
                            optimizes max(T_e, T_c); no quantization.
  SPINN [25]                partition + fixed 8-bit quantization + early
                            exit at a fixed confidence threshold.
  JPS   [10]                layer-level pipeline schedule balancing the end
                            computation and transmission stages (cloud stage
                            neglected — the paper's critique of it).

Every baseline is expressed over the generalized multi-hop machinery
(``baseline_multihop``): the classic 2-device form is the ``n_hops = 1``
case, and the same selection criteria extend to end->edge->cloud chains
(each baseline sweeps ordered multi-cut tuples with its own objective).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Callable, Dict, Optional, Sequence, Tuple

from repro.core.costs import DeviceProfile, LinkProfile, ModelGraph
from repro.core.partitioner import (chain_flow, chain_prefixes,
                                    strided_positions)
from repro.core.schedule import (PartitionDecision, StageTimes,
                                 evaluate_multihop, evaluate_partition)


@dataclasses.dataclass
class BaselineResult:
    decision: PartitionDecision
    times: StageTimes
    extra: Dict = dataclasses.field(default_factory=dict)


def _eval_multi(graph, frontiers: Sequence[frozenset], bits_all: int,
                devices, links, name: str):
    hop_bits = [{e: bits_all for e in graph.boundary_edges(f) if e[0] >= 0}
                for f in frontiers]
    dec = PartitionDecision.multihop(frontiers, hop_bits, name=name)
    return dec, evaluate_multihop(graph, dec, devices, links)


# selection key per baseline: smaller is better, evaluated per candidate.
# JPS balances every stage *except* the cloud (the paper's critique).
_CRITERIA: Dict[str, Tuple[int, Callable[[StageTimes], tuple], bool]] = {
    # name -> (wire bits, key fn, require non-empty end segment)
    "ns": (32, lambda st: (st.latency,), False),
    "dads": (32, lambda st: (st.max_stage, st.latency), False),
    "spinn": (8, lambda st: (st.latency,), True),
    "jps": (8, lambda st: (max(st.compute[:-1] + st.link), st.latency),
            False),
}


def baseline_multihop(name: str, graph: ModelGraph,
                      devices: Sequence[DeviceProfile],
                      links: Sequence[LinkProfile],
                      chain_stride: int = 1) -> BaselineResult:
    """Run one baseline's selection rule over ordered multi-cut chains on
    an ``len(links)``-hop deployment (shared event core)."""
    tag = name.lower()
    bits, key_fn, nonempty = _CRITERIA[tag]
    n_hops = len(links)
    assert len(devices) == n_hops + 1
    prefixes = chain_prefixes(graph)
    positions = strided_positions(len(prefixes), chain_stride)
    best = None
    for combo in itertools.combinations_with_replacement(positions, n_hops):
        frontiers = [frozenset(prefixes[i]) for i in combo]
        if nonempty and not frontiers[0]:
            continue
        dec, st = _eval_multi(graph, frontiers, bits, devices, links, tag)
        key = key_fn(st)
        if best is None or key < best[2]:
            best = (dec, st, key)
    return BaselineResult(best[0], best[1])


def neurosurgeon(graph: ModelGraph, end_dev: DeviceProfile,
                 cloud_dev: DeviceProfile, link: LinkProfile) -> BaselineResult:
    """Min end-to-end single-task latency; fp32 transfers."""
    return baseline_multihop("ns", graph, (end_dev, cloud_dev), (link,))


def dads(graph: ModelGraph, end_dev, cloud_dev, link) -> BaselineResult:
    """Heavy-load mode: min max stage (pipeline throughput) over all three
    stages, fp32 transfers (no quantization), latency tie-break."""
    return baseline_multihop("dads", graph, (end_dev, cloud_dev), (link,))


def spinn(graph: ModelGraph, end_dev, cloud_dev, link,
          exit_ratio_hint: float = 0.0) -> BaselineResult:
    """Latency-min partition with fixed 8-bit quantization; early exit at a
    fixed threshold (its exit ratio is data-dependent and supplied by the
    driver as ``exit_ratio_hint``).  Progressive device-first inference =>
    non-empty end segment."""
    r = baseline_multihop("spinn", graph, (end_dev, cloud_dev), (link,))
    return BaselineResult(r.decision, r.times,
                          {"exit_ratio": exit_ratio_hint})


def jps(graph: ModelGraph, end_dev, cloud_dev, link) -> BaselineResult:
    """Near-optimal end/transmission pipeline schedule: min max(T_e, T_t)
    with 8-bit transfers; the cloud stage is not balanced (per the paper's
    critique, it may become the pipeline bottleneck)."""
    return baseline_multihop("jps", graph, (end_dev, cloud_dev), (link,))


BASELINES = {
    "NS": neurosurgeon,
    "DADS": dads,
    "SPINN": spinn,
    "JPS": jps,
}
