"""Uniform Affine Quantization (UAQ) of intermediate tensors [34] and
accuracy oracles for the dichotomous precision search (Eq. 1).

``uaq_quantize``/``uaq_dequantize`` are the pure-jnp reference semantics;
the TPU Pallas kernel in ``repro.kernels.uaq`` implements the same math
(validated against these in tests).
"""

from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def uaq_params(x: jnp.ndarray, bits: int, axis=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor (axis=None) or per-axis scale/zero-point."""
    qmax = (1 << bits) - 1
    if axis is None:
        lo = jnp.min(x)
        hi = jnp.max(x)
    else:
        red = tuple(i for i in range(x.ndim) if i != axis % x.ndim)
        lo = jnp.min(x, axis=red, keepdims=True)
        hi = jnp.max(x, axis=red, keepdims=True)
    scale = jnp.maximum(hi - lo, 1e-8) / qmax
    zp = jnp.round(-lo / scale)
    return scale.astype(jnp.float32), zp.astype(jnp.float32)


def uaq_quantize(x, bits: int, axis=None):
    scale, zp = uaq_params(x, bits, axis)
    qmax = (1 << bits) - 1
    q = jnp.clip(jnp.round(x / scale + zp), 0, qmax)
    return q.astype(jnp.uint8 if bits <= 8 else jnp.uint16), scale, zp


def uaq_dequantize(q, scale, zp):
    return (q.astype(jnp.float32) - zp) * scale


def uaq_roundtrip(x, bits: int, axis=None):
    q, s, z = uaq_quantize(x, bits, axis)
    return uaq_dequantize(q, s, z).astype(x.dtype)


def quant_error(x, bits: int) -> float:
    """Relative L2 error of the UAQ roundtrip."""
    y = uaq_roundtrip(x, bits)
    return float(jnp.linalg.norm((x - y).ravel()) /
                 (jnp.linalg.norm(x.ravel()) + 1e-12))


# ------------------------------------------------------- measured oracle
def measured_acc_oracle(apply_tail: Callable, calib_inputs, calib_labels,
                        base_acc: float) -> Callable[[int], float]:
    """Accuracy-loss oracle measured on a calibration set: quantize the
    intermediate activation, run the remaining model (``apply_tail``), and
    compare top-1 accuracy against ``base_acc``.  Used with small real
    models in examples/tests; big configs use the analytic proxy."""

    def loss(bits: int) -> float:
        xq = uaq_roundtrip(calib_inputs, bits)
        logits = apply_tail(xq)
        acc = float(jnp.mean(jnp.argmax(logits, -1) == calib_labels))
        return max(0.0, base_acc - acc)

    return loss


def packed_bytes(n_elems: int, bits: int) -> int:
    """Wire bytes for n_elems UAQ values plus per-tensor scale/zp."""
    return (n_elems * bits + 7) // 8 + 8
