"""COACH offline component — Algorithm 1, generalized to multi-hop chains.

Recursive divide-and-conquer over the model DAG:

  1. cluster parallel branches into *virtual blocks*, reducing the DAG to a
     chain flow  B = {b_1 .. b_n}  (Fig. 4);
  2. sweep chain-level cuts — for an ``n_hops``-link deployment, ordered
     multi-cut tuples (non-decreasing chain positions, one frontier per
     hop); per boundary tensor and hop, pick quantization precision by
     dichotomous search against the accuracy oracle (Eq. 1) and then relax
     bits upward if that lowers the bubble objective;
  3. recurse into virtual blocks crossing the best cuts: per hop, each
     internal branch is cut independently at a shared flop-ratio grid
     (this is what turns the O(c^n) joint branch search into O(c·n));
  4. keep the argmin of Eq. 6 subject to Eq. 1/3/4.

Every *returned* strategy is scored with the executable event semantics
in ``repro.core.schedule`` / ``repro.core.sim`` (no closed-form
approximations), so the chosen strategy is exactly what the pipeline
executor will see.  By default the sweep itself runs through the batched
incremental scorer of ``repro.core.plan_fast`` — an exact O(boundary
events) reformulation of the same event semantics, differentially pinned
to the simulator — and only the shortlisted top-K candidates are
rescored with the full simulation, so the argmin is identical to the
naive per-candidate search at a fraction of the cost (``fast=False``
recovers the naive path).  The classic end->cloud search
(``coach_offline``) is the ``n_hops = 1`` case of
``coach_offline_multihop``.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import plan_fast
from repro.core.costs import DeviceProfile, LinkProfile, LayerNode, ModelGraph
from repro.core.schedule import (Edge, PartitionDecision, StageTimes,
                                 evaluate_multihop, evaluate_partition)

AccOracle = Callable[[LayerNode, int], float]  # (node, bits) -> accuracy loss


def analytic_acc_loss(node: LayerNode, bits: int) -> float:
    """Default oracle: UAQ error decays ~2x per extra bit (§II-B clusters at
    3–5 bits for eps=0.5%); per-layer sensitivity scales it."""
    return node.sensitivity * (2.0 ** (-(bits - 2)))


def dichotomous_bits(node: LayerNode, eps: float, oracle: AccOracle,
                     lo: int = 2, hi: int = 16) -> int:
    """Minimal precision meeting Eq. 1, by dichotomous (binary) search —
    valid because oracle loss is monotone non-increasing in bits."""
    if oracle(node, hi) > eps:
        return hi
    while lo < hi:
        mid = (lo + hi) // 2
        if oracle(node, mid) <= eps:
            hi = mid
        else:
            lo = mid + 1
    return hi


# ------------------------------------------------------------ virtual blocks
@dataclasses.dataclass
class ChainElem:
    """Either a single node or a virtual block [entry..join) of parallel
    branches (branch = list of node ids)."""
    node: Optional[int] = None
    block_nodes: Tuple[int, ...] = ()
    branches: Tuple[Tuple[int, ...], ...] = ()

    @property
    def is_block(self) -> bool:
        return bool(self.block_nodes)

    def ids(self) -> Tuple[int, ...]:
        return self.block_nodes if self.is_block else (self.node,)


def _reachable(graph: ModelGraph, src: int) -> set:
    seen, stack = set(), [src]
    while stack:
        u = stack.pop()
        for w in graph.children(u):
            if w not in seen:
                seen.add(w)
                stack.append(w)
    return seen


def chain_flow(graph: ModelGraph,
               ids: Optional[Sequence[int]] = None) -> List[ChainElem]:
    """Cluster parallel layers into virtual blocks (Alg. 1 line 3).

    Assumes series-parallel structure with topologically contiguous ids
    (true of our CNN/transformer graph builders).
    """
    ids = list(ids) if ids is not None else [n.id for n in graph.nodes]
    elems: List[ChainElem] = []
    i = 0
    idset = set(ids)
    pos = {nid: j for j, nid in enumerate(ids)}  # id -> chain position
    while i < len(ids):
        u = ids[i]
        kids = [c for c in graph.children(u) if c in idset]
        if len(kids) <= 1:
            elems.append(ChainElem(node=u))
            i += 1
            continue
        # parallel region opens at u: find the join = smallest node reachable
        # from (or equal to) every child
        reach = [({k} | _reachable(graph, k)) & idset for k in kids]
        common = set.intersection(*reach)
        join = min(common)
        block_ids = tuple(x for x in ids if u < x < join)
        blockset = set(block_ids)
        # branches: connected chains inside the block starting at each child
        branches = []
        for k in kids:
            if k == join:
                continue  # skip-edge branch (no layers)
            br, cur = [], k
            while cur != join and cur in blockset:
                br.append(cur)
                nxt = [c for c in graph.children(cur) if c in idset]
                cur = nxt[0] if nxt else join
            branches.append(tuple(br))
        elems.append(ChainElem(node=u))
        if block_ids:
            elems.append(ChainElem(block_nodes=block_ids,
                                   branches=tuple(branches)))
        i = pos[join]
    return elems


def chain_prefixes(graph: ModelGraph,
                   elems: Optional[List[ChainElem]] = None
                   ) -> List[Tuple[int, ...]]:
    """Cumulative node-id prefixes after each chain element (first entry is
    the empty prefix = everything downstream)."""
    elems = elems if elems is not None else chain_flow(graph)
    prefixes: List[Tuple[int, ...]] = [()]
    cur: List[int] = []
    for e in elems:
        cur.extend(e.ids())
        prefixes.append(tuple(cur))
    return prefixes


def strided_positions(n_prefixes: int, stride: int) -> List[int]:
    """Chain-cut grid subsampled at ``stride``, always keeping the full
    (all-nodes) prefix so degenerate cuts stay reachable."""
    positions = list(range(0, n_prefixes, max(1, stride)))
    if positions[-1] != n_prefixes - 1:
        positions.append(n_prefixes - 1)
    return positions


# ---------------------------------------------------------------- optimizer
@dataclasses.dataclass
class OfflineResult:
    decision: PartitionDecision
    times: StageTimes
    objective: float
    candidates: int
    feasible: bool


class QuantCache:
    """Memoized Eq. 1 quantization search.

    The dichotomous precision of a boundary tensor depends only on its
    *producer* node, and the same frontier recurs across every multi-cut
    tuple containing it — so both layers are cached: per-node minimal
    bits (one oracle search per producer, ever) and per-frontier
    boundary-bit maps (one dict per distinct frontier).  One instance is
    scoped to one (eps, oracle, hi_bits) search."""

    def __init__(self, graph: ModelGraph, eps: float, oracle: AccOracle,
                 hi_bits: int = 16):
        self.graph = graph
        self.eps = eps
        self.oracle = oracle
        self.hi_bits = hi_bits
        self._node: Dict[int, int] = {}
        self._frontier: Dict[frozenset, Dict[Edge, int]] = {}

    def node_bits(self, u: int) -> int:
        b = self._node.get(u)
        if b is None:
            b = dichotomous_bits(self.graph.node(u), self.eps, self.oracle,
                                 hi=self.hi_bits)
            self._node[u] = b
        return b

    def boundary_bits(self, end_set: frozenset) -> Dict[Edge, int]:
        """Eq. 1 minimal precisions of a frontier's boundary tensors.
        Returns the cached dict — callers must copy before mutating."""
        got = self._frontier.get(end_set)
        if got is None:
            got = {(u, v): self.node_bits(u)
                   for (u, v) in self.graph.boundary_edges(end_set)
                   if u >= 0}  # raw input: fixed input precision
            self._frontier[end_set] = got
        return got


def _quantize_boundary(graph: ModelGraph, end_set: frozenset, eps: float,
                       oracle: AccOracle, hi_bits: int = 16,
                       cache: Optional[QuantCache] = None) -> Dict[Edge, int]:
    if cache is not None:
        # a cache answers for exactly one search configuration — reject a
        # mismatched one instead of silently returning wrong precisions
        assert (cache.graph is graph and cache.eps == eps
                and cache.oracle is oracle and cache.hi_bits == hi_bits), \
            "QuantCache built for a different (graph, eps, oracle, hi_bits)"
        return cache.boundary_bits(end_set)
    bits: Dict[Edge, int] = {}
    for (u, v) in graph.boundary_edges(end_set):
        if u < 0:
            continue  # raw input edge: transmitted at fixed input precision
        bits[(u, v)] = dichotomous_bits(graph.node(u), eps, oracle, hi=hi_bits)
    return bits


def _score(graph, frontiers: Sequence[frozenset],
           hop_bits: Sequence[Dict[Edge, int]], devices, links, T_max):
    dec = PartitionDecision.multihop(frontiers, hop_bits)
    st = evaluate_multihop(graph, dec, devices, links)
    feasible = (st.stage_sum <= T_max) and st.satisfies_parallel_constraint()
    return dec, st, st.objective(), feasible


def _relax_bits(graph, frontiers, bits_min, devices, links, T_max,
                hi_bits=16):
    """Offline Eq.11 analogue: raising precision above the Eq.1 minimum is
    free accuracy margin whenever transmission is not the bottleneck."""
    best = _score(graph, frontiers, [dict(b) for b in bits_min],
                  devices, links, T_max)
    cands = 1
    if any(bits_min):
        for extra in (1, 2, 4, 8):
            trial = [{e: min(hi_bits, b + extra) for e, b in bm.items()}
                     for bm in bits_min]
            cand = _score(graph, frontiers, trial, devices, links, T_max)
            cands += 1
            # extra precision may only fill *idle* link time: it must not
            # raise the pipeline ceiling (else Eq.5's B_t is being gamed)
            if cand[2] < best[2] and cand[3] >= best[3] \
                    and cand[1].max_stage <= best[1].max_stage * (1 + 1e-9):
                best = cand
    return best, cands


def _branch_ratio_cut(graph: ModelGraph, branches, r: float) -> List[int]:
    """Cut every branch of a virtual block at flop-ratio ``r`` (shared grid
    point: the O(c·n) joint branch search of Alg. 1 l.13-14)."""
    take_ids: List[int] = []
    for br in branches:
        if not br:
            continue
        total = sum(graph.node(x).flops for x in br)
        acc = 0.0
        for x in br:
            if total == 0 or (acc + graph.node(x).flops) / max(total, 1e-12) \
                    <= r + 1e-12:
                take_ids.append(x)
                acc += graph.node(x).flops
            else:
                break
    return take_ids


def coach_offline_multihop(graph: ModelGraph,
                           devices: Sequence[DeviceProfile],
                           links: Sequence[LinkProfile],
                           eps: float = 0.005, T_max: float = math.inf,
                           oracle: AccOracle = analytic_acc_loss,
                           ratio_grid: int = 8,
                           min_end_nodes: int = 1,
                           chain_stride: int = 1,
                           fast: bool = True,
                           shortlist_k: int = 16,
                           tables: Optional[plan_fast.PlannerTables] = None
                           ) -> OfflineResult:
    """Algorithm 1 offline component over an ``len(links)``-hop chain of
    devices (end, edge tiers..., cloud).

    ``min_end_nodes``: COACH's workflow (Fig. 3) requires the end device to
    produce intermediate data — both for privacy and because the online
    component's task features F are GAP'd from it — so the degenerate
    all-cloud partition is excluded by default.  ``chain_stride``
    subsamples the chain-cut grid for large graphs × many hops (the block
    recursion still refines around the best coarse cuts; the default
    ``fast`` batched scorer makes full-stride sweeps cheap, so ``1`` is
    the normal setting).

    ``fast`` routes candidate scoring through ``repro.core.plan_fast``:
    all chain-cut tuples are scored at once from numpy prefix-sum tables
    (exact O(boundary-events) reformulation of the event semantics) and
    only the top-``shortlist_k`` candidates per phase are rescored with
    the full event simulator — the returned decision and objective are
    identical to ``fast=False``, which keeps the naive per-candidate
    simulation sweep.  Links carrying a bandwidth trace stay on the fast
    path: the batched scorer re-prices every boundary transfer at its
    actual start instant (exhaustive exact sweep, no vectorized bounds).

    ``tables`` warm-starts the fast path with previously built
    ``PlannerTables`` — they must come from ``plan_fast.build_tables``
    (with chain prefixes) or ``plan_fast.retime_tables`` over this same
    graph, device tuple and quantization search, and their bandwidths
    must match ``links``.  Online re-planning passes retimed tables so a
    regime shift never re-runs the Eq. 1 oracle pricing.
    """
    n_hops = len(links)
    assert len(devices) == n_hops + 1, "need one device per segment"
    elems = chain_flow(graph)
    prefixes = chain_prefixes(graph, elems)
    qcache = QuantCache(graph, eps, oracle)
    n_cands = 0
    best: Optional[Tuple] = None
    use_fast = fast and len(graph) > 0
    if tables is not None:
        assert (tables.graph is graph and len(tables.links) == n_hops
                and tables.pref_cnt is not None
                and tables.bw == tuple(lk.bandwidth_bps for lk in links)), \
            "warm tables must be built/retimed for this graph and links"

    def get_tables() -> plan_fast.PlannerTables:
        nonlocal tables
        if tables is None:
            tables = plan_fast.build_tables(
                graph, devices, links, qcache.node_bits,
                pref_counts=[len(p) for p in prefixes])
        return tables

    def consider(frontier_ids: Sequence[Tuple[int, ...]]):
        nonlocal best, n_cands
        frontiers = [frozenset(f) for f in frontier_ids]
        if len(frontiers[0]) < min_end_nodes:
            return
        prev: frozenset = frozenset()
        for f in frontiers:
            if not prev <= f or not graph.valid_end_set(f):
                return
            prev = f
        bits_min = [_quantize_boundary(graph, f, eps, oracle, cache=qcache)
                    for f in frontiers]
        (dec, st, obj, feas), c = _relax_bits(
            graph, frontiers, bits_min, devices, links, T_max)
        n_cands += c
        key = (not feas, obj)
        if best is None or key < (not best[3], best[2]):
            best = (dec, st, obj, feas)

    # ---- chain-level multi-cuts: non-decreasing tuples of chain positions
    # (cut after element i; position 0 => nothing upstream of that hop)
    positions = strided_positions(len(prefixes), chain_stride)
    n_combos = math.comb(len(positions) + n_hops - 1, n_hops)
    if use_fast and n_combos > shortlist_k:
        # batched scoring of the whole sweep; exact event-sim rescoring of
        # the shortlist, in sweep order (first-seen tie-break preserved)
        short, n_fast = plan_fast.chain_shortlist(
            get_tables(), positions, n_hops, min_end_nodes, T_max,
            shortlist_k)
        n_cands += n_fast
        for combo in short:
            consider([prefixes[i] for i in combo])
    else:
        for combo in itertools.combinations_with_replacement(
                positions, n_hops):
            consider([prefixes[i] for i in combo])

    assert best is not None, "no valid partition candidate"
    chain_best_cuts: Tuple[frozenset, ...] = best[0].cuts

    # ---- recurse into virtual blocks: refine each hop's cut inside the
    # blocks at a shared flop-ratio grid, holding the other hops at their
    # best chain-level frontiers (Alg.1 l.13-14)
    refined_cands: List[List[frozenset]] = []
    for k in range(n_hops):
        prefix: List[int] = []
        for e in elems:
            if e.is_block and e.branches:
                base = tuple(prefix)  # everything before the block upstream
                for g in range(1, ratio_grid):
                    r = g / ratio_grid
                    cut_ids = list(base) + _branch_ratio_cut(
                        graph, e.branches, r)
                    refined = [frozenset(c) for c in chain_best_cuts]
                    refined[k] = frozenset(cut_ids)
                    refined_cands.append(refined)
            prefix.extend(e.ids())
    if use_fast and len(refined_cands) > shortlist_k:
        picks, n_fast = plan_fast.frontier_shortlist(
            get_tables(), refined_cands, min_end_nodes, T_max, shortlist_k)
        n_cands += n_fast
        for i in picks:
            consider(refined_cands[i])
    else:
        for refined in refined_cands:
            consider(refined)

    dec, st, obj, feas = best
    return OfflineResult(decision=dec, times=st, objective=obj,
                         candidates=n_cands, feasible=feas)


def coach_offline(graph: ModelGraph, end_dev: DeviceProfile,
                  cloud_dev: DeviceProfile, link: LinkProfile,
                  eps: float = 0.005, T_max: float = math.inf,
                  oracle: AccOracle = analytic_acc_loss,
                  ratio_grid: int = 8,
                  min_end_nodes: int = 1,
                  fast: bool = True) -> OfflineResult:
    """Classic end->cloud offline search: ``n_hops = 1`` of the multi-hop
    divide-and-conquer."""
    return coach_offline_multihop(
        graph, (end_dev, cloud_dev), (link,), eps=eps, T_max=T_max,
        oracle=oracle, ratio_grid=ratio_grid, min_end_nodes=min_end_nodes,
        fast=fast)


# ------------------------------------------------------- brute-force oracle
def brute_force(graph: ModelGraph, end_dev, cloud_dev, link,
                eps: float = 0.005, T_max: float = math.inf,
                oracle: AccOracle = analytic_acc_loss,
                min_end_nodes: int = 1,
                fast: bool = True,
                shortlist_k: int = 16) -> OfflineResult:
    """Exponential reference for tests: all downward-closed end sets.

    ``fast`` ranks the (exponentially many) end sets with the batched
    scorer and rescores the shortlist with the event simulator — the
    same pure-speedup funnel as ``coach_offline_multihop``."""
    n = len(graph)
    assert n <= 18, "brute force limited to small graphs"
    qcache = QuantCache(graph, eps, oracle)
    best = None
    cands = 0
    end_sets = []
    for mask in range(1 << n):
        end_ids = frozenset(i for i in range(n) if mask >> i & 1)
        if len(end_ids) < min_end_nodes:
            continue
        if not graph.valid_end_set(end_ids):
            continue
        end_sets.append(end_ids)

    def score(end_ids: frozenset):
        nonlocal best, cands
        bits = _quantize_boundary(graph, end_ids, eps, oracle, cache=qcache)
        (dec, st, obj, feas), c = _relax_bits(
            graph, [end_ids], [bits], (end_dev, cloud_dev), (link,), T_max)
        cands += c
        key = (not feas, obj)
        if best is None or key < (not best[3], best[2]):
            best = (dec, st, obj, feas)

    if fast and len(end_sets) > shortlist_k:
        tables = plan_fast.build_tables(
            graph, (end_dev, cloud_dev), (link,), qcache.node_bits)
        picks, n_fast = plan_fast.frontier_shortlist(
            tables, [[s] for s in end_sets], min_end_nodes, T_max,
            shortlist_k)
        cands += n_fast
        for i in picks:
            score(end_sets[i])
    else:
        for end_ids in end_sets:
            score(end_ids)
    dec, st, obj, feas = best
    return OfflineResult(dec, st, obj, cands, feas)
