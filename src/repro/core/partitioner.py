"""COACH offline component — Algorithm 1.

Recursive divide-and-conquer over the model DAG:

  1. cluster parallel branches into *virtual blocks*, reducing the DAG to a
     chain flow  B = {b_1 .. b_n}  (Fig. 4);
  2. sweep chain-level cuts; per boundary tensor, pick quantization
     precision by dichotomous search against the accuracy oracle (Eq. 1)
     and then relax bits upward if that lowers the bubble objective;
  3. recurse into virtual blocks crossing the best cuts: each internal
     branch is cut independently at a shared flop-ratio grid (this is what
     turns the O(c^n) joint branch search into O(c·n));
  4. keep the argmin of Eq. 6 subject to Eq. 1/3/4.

Every candidate is scored with the executable event semantics in
``repro.core.schedule`` (no closed-form approximations), so the chosen
strategy is exactly what the pipeline executor will see.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.costs import DeviceProfile, LinkProfile, LayerNode, ModelGraph
from repro.core.schedule import Edge, PartitionDecision, StageTimes, evaluate_partition

AccOracle = Callable[[LayerNode, int], float]  # (node, bits) -> accuracy loss


def analytic_acc_loss(node: LayerNode, bits: int) -> float:
    """Default oracle: UAQ error decays ~2x per extra bit (§II-B clusters at
    3–5 bits for eps=0.5%); per-layer sensitivity scales it."""
    return node.sensitivity * (2.0 ** (-(bits - 2)))


def dichotomous_bits(node: LayerNode, eps: float, oracle: AccOracle,
                     lo: int = 2, hi: int = 16) -> int:
    """Minimal precision meeting Eq. 1, by dichotomous (binary) search —
    valid because oracle loss is monotone non-increasing in bits."""
    if oracle(node, hi) > eps:
        return hi
    while lo < hi:
        mid = (lo + hi) // 2
        if oracle(node, mid) <= eps:
            hi = mid
        else:
            lo = mid + 1
    return hi


# ------------------------------------------------------------ virtual blocks
@dataclasses.dataclass
class ChainElem:
    """Either a single node or a virtual block [entry..join) of parallel
    branches (branch = list of node ids)."""
    node: Optional[int] = None
    block_nodes: Tuple[int, ...] = ()
    branches: Tuple[Tuple[int, ...], ...] = ()

    @property
    def is_block(self) -> bool:
        return bool(self.block_nodes)

    def ids(self) -> Tuple[int, ...]:
        return self.block_nodes if self.is_block else (self.node,)


def _reachable(graph: ModelGraph, src: int) -> set:
    seen, stack = set(), [src]
    while stack:
        u = stack.pop()
        for w in graph.children(u):
            if w not in seen:
                seen.add(w)
                stack.append(w)
    return seen


def chain_flow(graph: ModelGraph,
               ids: Optional[Sequence[int]] = None) -> List[ChainElem]:
    """Cluster parallel layers into virtual blocks (Alg. 1 line 3).

    Assumes series-parallel structure with topologically contiguous ids
    (true of our CNN/transformer graph builders).
    """
    ids = list(ids) if ids is not None else [n.id for n in graph.nodes]
    elems: List[ChainElem] = []
    i = 0
    idset = set(ids)
    while i < len(ids):
        u = ids[i]
        kids = [c for c in graph.children(u) if c in idset]
        if len(kids) <= 1:
            elems.append(ChainElem(node=u))
            i += 1
            continue
        # parallel region opens at u: find the join = smallest node reachable
        # from (or equal to) every child
        reach = [({k} | _reachable(graph, k)) & idset for k in kids]
        common = set.intersection(*reach)
        join = min(common)
        block_ids = tuple(x for x in ids if u < x < join)
        # branches: connected chains inside the block starting at each child
        branches = []
        for k in kids:
            if k == join:
                continue  # skip-edge branch (no layers)
            br, cur = [], k
            while cur != join and cur in set(block_ids):
                br.append(cur)
                nxt = [c for c in graph.children(cur) if c in idset]
                cur = nxt[0] if nxt else join
            branches.append(tuple(br))
        elems.append(ChainElem(node=u))
        if block_ids:
            elems.append(ChainElem(block_nodes=block_ids,
                                   branches=tuple(branches)))
        i = ids.index(join)
    return elems


# ---------------------------------------------------------------- optimizer
@dataclasses.dataclass
class OfflineResult:
    decision: PartitionDecision
    times: StageTimes
    objective: float
    candidates: int
    feasible: bool


def _quantize_boundary(graph: ModelGraph, end_set: frozenset, eps: float,
                       oracle: AccOracle, hi_bits: int = 16) -> Dict[Edge, int]:
    bits: Dict[Edge, int] = {}
    for (u, v) in graph.boundary_edges(end_set):
        if u < 0:
            continue  # raw input edge: transmitted at fixed input precision
        bits[(u, v)] = dichotomous_bits(graph.node(u), eps, oracle, hi=hi_bits)
    return bits


def _score(graph, end_set, bits, end_dev, cloud_dev, link, T_max):
    dec = PartitionDecision(end_set=frozenset(end_set), bits=bits)
    st = evaluate_partition(graph, dec, end_dev, cloud_dev, link)
    feasible = (st.T_e + st.T_t + st.T_c <= T_max) and \
        st.satisfies_parallel_constraint()
    return dec, st, st.objective(), feasible


def _relax_bits(graph, end_set, bits_min, end_dev, cloud_dev, link, T_max,
                hi_bits=16):
    """Offline Eq.11 analogue: raising precision above the Eq.1 minimum is
    free accuracy margin whenever transmission is not the bottleneck."""
    best = _score(graph, end_set, dict(bits_min), end_dev, cloud_dev, link, T_max)
    cands = 1
    if bits_min:
        for extra in (1, 2, 4, 8):
            trial = {e: min(hi_bits, b + extra) for e, b in bits_min.items()}
            cand = _score(graph, end_set, trial, end_dev, cloud_dev, link, T_max)
            cands += 1
            # extra precision may only fill *idle* link time: it must not
            # raise the pipeline ceiling (else Eq.5's B_t is being gamed)
            if cand[2] < best[2] and cand[3] >= best[3] \
                    and cand[1].max_stage <= best[1].max_stage * (1 + 1e-9):
                best = cand
    return best, cands


def coach_offline(graph: ModelGraph, end_dev: DeviceProfile,
                  cloud_dev: DeviceProfile, link: LinkProfile,
                  eps: float = 0.005, T_max: float = math.inf,
                  oracle: AccOracle = analytic_acc_loss,
                  ratio_grid: int = 8,
                  min_end_nodes: int = 1) -> OfflineResult:
    """Algorithm 1 offline component.

    ``min_end_nodes``: COACH's workflow (Fig. 3) requires the end device to
    produce intermediate data — both for privacy and because the online
    component's task features F are GAP'd from it — so the degenerate
    all-cloud partition is excluded by default.
    """
    elems = chain_flow(graph)
    n_cands = 0
    best: Optional[Tuple] = None

    def consider(end_ids):
        nonlocal best, n_cands
        end_set = frozenset(end_ids)
        if len(end_set) < min_end_nodes:
            return
        if not graph.valid_end_set(end_set):
            return
        bits_min = _quantize_boundary(graph, end_set, eps, oracle)
        (dec, st, obj, feas), c = _relax_bits(
            graph, end_set, bits_min, end_dev, cloud_dev, link, T_max)
        n_cands += c
        key = (not feas, obj)
        if best is None or key < (not best[3], best[2]):
            best = (dec, st, obj, feas)

    # ---- chain-level cuts (cut after element i; i = -1 => all on cloud)
    prefix: List[int] = []
    consider(())
    for i, e in enumerate(elems):
        prefix.extend(e.ids())
        consider(tuple(prefix))

    # ---- recurse into virtual blocks: cut inside the block (Alg.1 l.13-14)
    prefix = []
    for e in elems:
        if e.is_block and e.branches:
            base = tuple(prefix)  # everything before the block on the end
            for g in range(1, ratio_grid):
                r = g / ratio_grid
                cut_ids = list(base)
                for br in e.branches:
                    if not br:
                        continue
                    total = sum(graph.node(x).flops for x in br)
                    acc, take = 0.0, []
                    for x in br:
                        if total == 0 or (acc + graph.node(x).flops) / max(total, 1e-12) <= r + 1e-12:
                            take.append(x)
                            acc += graph.node(x).flops
                        else:
                            break
                    cut_ids.extend(take)
                consider(tuple(cut_ids))
        prefix.extend(e.ids())

    dec, st, obj, feas = best
    return OfflineResult(decision=dec, times=st, objective=obj,
                         candidates=n_cands, feasible=feas)


# ------------------------------------------------------- brute-force oracle
def brute_force(graph: ModelGraph, end_dev, cloud_dev, link,
                eps: float = 0.005, T_max: float = math.inf,
                oracle: AccOracle = analytic_acc_loss,
                min_end_nodes: int = 1) -> OfflineResult:
    """Exponential reference for tests: all downward-closed end sets."""
    n = len(graph)
    assert n <= 18, "brute force limited to small graphs"
    best = None
    cands = 0
    for mask in range(1 << n):
        end_ids = frozenset(i for i in range(n) if mask >> i & 1)
        if len(end_ids) < min_end_nodes:
            continue
        if not graph.valid_end_set(end_ids):
            continue
        bits = _quantize_boundary(graph, end_ids, eps, oracle)
        (dec, st, obj, feas), c = _relax_bits(
            graph, end_ids, bits, end_dev, cloud_dev, link, T_max)
        cands += c
        key = (not feas, obj)
        if best is None or key < (not best[3], best[2]):
            best = (dec, st, obj, feas)
    dec, st, obj, feas = best
    return OfflineResult(dec, st, obj, cands, feas)
