"""COACH online component (§III-C): label semantic centers with a caching
mechanism, task separability, early exit, and adaptive quantization
adjustment under dynamic bandwidth.

All math follows the paper:
  Eq. 7  running-mean center update
  Eq. 8  cosine similarity degrees  T = {t_j}
  Eq. 9  task separability          S = ||T||_2 (t_H - t_SH) t_H / t_SH
  Eq. 10 early-exit result          R = argmax_j t_j
  Eq. 11 bubble-minimizing precision Q_c >= Q_r
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def gap_features(x: np.ndarray, layout: Optional[str] = None) -> np.ndarray:
    """Global Average Pooling: (C,H,W) -> (C,)  or (S,D) -> (D,)  or batched
    (B,...) -> (B,C|D).  Concentrates intermediate data into task features F.

    ``layout`` names the channel axis of rank-3/4 maps explicitly:
    ``"CHW"`` (channels first, batched form ``(B,C,H,W)``) or ``"HWC"``
    (channels last, ``(B,H,W,C)``).  ``None`` falls back to the legacy
    shape heuristic — smaller leading axis means channels-first — which is
    only a guess: a deep channels-first map like ``(512, 7, 7)`` has
    ``shape[0] > shape[-1]`` and gets pooled over its *channel* axis,
    returning 7 spatial means instead of 512 channel means.  Callers that
    know their runtime's layout should always pass it."""
    x = np.asarray(x)
    if layout is not None and layout not in ("CHW", "HWC"):
        raise ValueError(f"layout must be 'CHW' or 'HWC', got {layout!r}")
    if x.ndim == 2:
        return x.mean(axis=0)
    if x.ndim == 3:
        if layout is None:  # legacy heuristic (documented fallback)
            layout = "CHW" if x.shape[0] < x.shape[-1] else "HWC"
        return x.mean(axis=(1, 2)) if layout == "CHW" else x.mean(axis=(0, 1))
    if x.ndim == 4:
        if layout is None:  # batched maps historically assumed (B,C,H,W)
            layout = "CHW"
        return x.mean(axis=(2, 3)) if layout == "CHW" else x.mean(axis=(1, 2))
    raise ValueError(f"unsupported feature rank {x.ndim}")


def cosine(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    num = a @ b.T if b.ndim == 2 else a @ b
    den = (np.linalg.norm(a, axis=-1, keepdims=b.ndim == 2) *
           np.linalg.norm(b, axis=-1))
    sim = num / np.maximum(den, 1e-12)
    return (sim + 1.0) / 2.0  # map [-1,1] -> [0,1] per Eq. 8 range


def separability(sims: np.ndarray,
                 counts: Optional[np.ndarray] = None) -> float:
    """Eq. 9 on one similarity-degree vector T.

    ``counts`` (the cache's per-label update counts) restricts the
    statistic to *trained* centers: ``SemanticCache.similarities`` emits
    exactly 0.0 for an untrained center, so with a single warmed label
    the second-highest degree t_SH is an artificial 0 and Eq. 9 blows up
    through ``t_H / max(t_SH, 1e-12)`` — every warm-up task looks
    maximally separable and exits spuriously.  Fewer than two trained
    centers have no genuine second-highest degree at all, so the
    separability is 0 (never exit-eligible)."""
    sims = np.asarray(sims, dtype=float)
    if counts is not None:
        sims = sims[np.asarray(counts) > 0]
    if len(sims) < 2:
        return 0.0
    t = np.sort(sims)[::-1]
    t_h, t_sh = float(t[0]), float(t[1])
    return float(np.linalg.norm(sims) * (t_h - t_sh) * t_h / max(t_sh, 1e-12))


@dataclasses.dataclass
class OnlineDecision:
    """One task's online outcome.

    ``early_exit`` keeps its classic meaning — the probe on the *end
    device* exited the task, nothing is ever transmitted.  ``exit_hop``
    generalizes it to hop-level semantic exits: ``exit_hop = k >= 1``
    means the task was transmitted (``bits`` chosen by Eq. 11 for the
    uplink), probes at boundaries ``1..k-1`` declined, and the probe at
    boundary ``k`` (an intermediate tier) exited it with ``result`` —
    the task occupies compute ``0..k`` / links ``0..k-1`` only.
    ``early_exit`` is True iff ``exit_hop == 0``."""
    early_exit: bool
    result: Optional[int]       # label if early-exited (Eq. 10)
    separability: float
    bits: Optional[int]         # chosen Q_c if transmitted
    required_bits: Optional[int]  # Q_r from separability thresholds
    exit_hop: Optional[int] = None

    def __post_init__(self):
        if self.early_exit and self.exit_hop is None:
            self.exit_hop = 0


class SemanticCache:
    """Label semantic centers T_c = {T_j^c} with running-mean updates.

    ``max_count`` bounds m_j in Eq. 7, turning the running mean into a
    sliding semantic window so centers keep tracking non-stationary task
    streams (video scenes drift); max_count=None is the paper's literal
    unbounded mean."""

    def __init__(self, n_labels: int, dim: int, max_count: Optional[int] = 16):
        self.centers = np.zeros((n_labels, dim), np.float64)
        self.counts = np.zeros((n_labels,), np.int64)
        self.max_count = max_count

    def warm_up(self, feats: np.ndarray, labels: np.ndarray):
        for f, j in zip(feats, labels):
            self.update(f, int(j))

    def update(self, feat: np.ndarray, label: int):
        m = self.counts[label]
        if self.max_count is not None:
            m = min(m, self.max_count)
        self.centers[label] = (m * self.centers[label] + feat) / (m + 1)  # Eq. 7
        self.counts[label] += 1

    @property
    def n_warm(self) -> int:
        """Labels whose center has seen at least one update.  Separability
        (Eq. 9) needs a genuine second-highest degree, so exit decisions
        are only eligible once ``n_warm >= 2``."""
        return int(np.count_nonzero(self.counts > 0))

    def similarities(self, feat: np.ndarray) -> np.ndarray:
        valid = self.counts > 0
        sims = np.zeros(len(self.centers))
        if valid.any():
            sims[valid] = cosine(feat[None], self.centers[valid])[0]
        return sims

    def trained_view(self) -> Tuple[np.ndarray, np.ndarray]:
        """``(centers[counts > 0], their label indices)`` — the matrix a
        fused boundary pass (``kernels.boundary``) probes against.  An
        untrained center is all-zeros; its cosine against anything would
        read ~0.5 after the [0, 1] mapping whereas ``similarities``
        defines it as exactly 0, so the kernel only ever sees trained
        centers and ``ProbeResult.from_fused`` scatters the results back
        into the full label space."""
        valid = np.flatnonzero(self.counts > 0)
        return self.centers[valid], valid


@dataclasses.dataclass
class ProbeResult:
    """One task's precomputed semantic-probe outputs (Eq. 8-10), e.g.
    from the fused boundary pass, in the *full* label space of the cache
    that will consume it.  ``OnlineScheduler.step`` / ``probe_hop``
    accept it in place of recomputing similarities from the feature —
    the decision math (thresholds, Eq. 11) is unchanged."""
    sims: np.ndarray   # (n_labels,) similarity degrees; untrained = 0.0
    sep: float         # Eq. 9 over the trained centers
    best: int          # Eq. 10 argmax label (full label space)

    @classmethod
    def from_fused(cls, sims, sep, best, valid: np.ndarray,
                   n_labels: int) -> "ProbeResult":
        """Lift one task's fused-kernel outputs (computed against
        ``cache.trained_view()`` centers) back into the full label
        space.  ``valid`` is the trained-label index map; with fewer
        than two trained centers there is no genuine second-highest
        degree, so the separability is forced to 0 (never
        exit-eligible), matching ``separability``."""
        full = np.zeros(n_labels)
        valid = np.asarray(valid)
        if valid.size:
            full[valid] = np.asarray(sims, dtype=float)
        b = int(valid[int(best)]) if valid.size else 0
        s = float(sep) if valid.size >= 2 else 0.0
        return cls(sims=full, sep=s, best=b)


@dataclasses.dataclass
class Thresholds:
    s_ext: float                       # early-exit threshold
    s_adj: Tuple[Tuple[float, int], ...]  # (separability floor, Q_r bits), desc

    def required_bits(self, s: float, default: int = 8) -> int:
        for floor, bits in self.s_adj:
            if s >= floor:
                return bits
        return default


def calibrate_thresholds(cache: SemanticCache, feats: np.ndarray,
                         labels: np.ndarray, eps: float = 0.005,
                         bit_levels: Sequence[int] = (3, 4, 5, 6, 8)) -> Thresholds:
    """One-time threshold calibration on the calibration set D (§III-C).

    s_ext: smallest separability quantile whose early-exit error <= eps.
    s_adj: separability floors assigning lower bits to more separable tasks
    (spatial-locality observation, Fig. 1b)."""
    seps, correct = [], []
    for f, y in zip(feats, labels):
        sims = cache.similarities(f)
        seps.append(separability(sims, cache.counts))
        correct.append(int(np.argmax(sims)) == int(y))
    seps = np.asarray(seps)
    correct = np.asarray(correct, bool)

    order = np.argsort(-seps)  # most separable first
    s_ext = float("inf")
    errs = np.cumsum(~correct[order])
    for k in range(len(order), 0, -1):
        if errs[k - 1] <= eps * k:
            s_ext = float(seps[order[k - 1]])
            break

    qs = np.quantile(seps, np.linspace(0.9, 0.1, len(bit_levels)))
    s_adj = tuple((float(q), int(b)) for q, b in zip(qs, bit_levels))
    return Thresholds(s_ext=s_ext, s_adj=s_adj)


@dataclasses.dataclass
class HopProbe:
    """Semantic probe state of one intermediate tier: its own label
    centers and calibrated thresholds, keyed by that boundary's
    activations (deeper boundaries are more discriminative, so their
    calibrated exit thresholds admit more of the stream)."""
    cache: SemanticCache
    thresholds: Thresholds


def build_hop_probes(calib_sets: Sequence[Tuple[np.ndarray, np.ndarray]],
                     n_labels: int, eps: float = 0.005,
                     bit_levels: Sequence[int] = (3, 4, 5, 6, 8),
                     max_count: Optional[int] = 16) -> List[HopProbe]:
    """Calibrate one ``HopProbe`` per boundary from per-boundary
    calibration sets ``[(feats, labels), ...]`` (§III-C run once per
    tier: warm the centers, then pick the eps-bounded exit threshold on
    that boundary's own separability distribution)."""
    probes = []
    for feats, labels in calib_sets:
        cache = SemanticCache(n_labels, feats.shape[1], max_count=max_count)
        cache.warm_up(feats, labels)
        th = calibrate_thresholds(cache, feats, labels, eps=eps,
                                  bit_levels=bit_levels)
        probes.append(HopProbe(cache=cache, thresholds=th))
    return probes


def choose_bits(required: int, elems: int, bandwidth_bps: float,
                T_e: float, T_c: float,
                levels: Sequence[int] = (3, 4, 5, 6, 8, 12, 16)) -> int:
    """Eq. 11: among Q_c >= Q_r, minimize |T_t' - max{T_e, T_t', T_c}|.

    Read non-degenerately: once T_t' itself becomes the max the paper's
    expression is 0 for *any* larger precision, which would let the link
    saturate; the intent is to fill idle link time up to the other stages'
    bound.  So we minimize the distance to target = max(T_e, T_c),
    preferring not to exceed it, and break ties toward higher precision
    (free accuracy margin)."""
    target = max(T_e, T_c)
    best = None
    for b in levels:
        if b < required:
            continue
        t_t = elems * b / bandwidth_bps
        key = (abs(t_t - target), t_t > target, -b)
        if best is None or key < best[0]:
            best = (key, b)
    return best[1] if best is not None else max(required, levels[-1])


class OnlineScheduler:
    """Per-task online decision pipeline (Alg. 1 online component).

    ``hop_elems`` / ``stage_compute`` activate the per-hop view of the
    adaptive-precision rule: hop ``k`` carries ``hop_elems[k]`` boundary
    elements between compute stages ``k`` and ``k+1``, and Eq. 11 is
    applied per hop against that pair's busy times, each hop chasing its
    own bandwidth EMA.  Omitting them keeps the classic single-uplink
    scheduler (hop 0 = the end device's uplink)."""

    def __init__(self, cache: SemanticCache, thresholds: Thresholds,
                 boundary_elems: int, T_e: float, T_c: float,
                 update_centers: bool = True,
                 hop_elems: Optional[Sequence[int]] = None,
                 stage_compute: Optional[Sequence[float]] = None,
                 hop_probes: Optional[Sequence[HopProbe]] = None):
        self.cache = cache
        self.th = thresholds
        self.elems = boundary_elems
        self.T_e, self.T_c = T_e, T_c
        self.update_centers = update_centers
        self.bw_ema: Optional[float] = None
        # semantic probes of the intermediate tiers (segment k >= 1 maps
        # to hop_probes[k - 1]); empty = probe only on the end device
        self.hop_probes: Tuple[HopProbe, ...] = tuple(hop_probes or ())
        self.hop_elems: Tuple[int, ...] = tuple(int(e) for e in hop_elems) \
            if hop_elems else (int(boundary_elems),)
        sc = tuple(stage_compute) if stage_compute else (T_e, T_c)
        assert len(sc) == len(self.hop_elems) + 1, \
            "need one compute stage per hop endpoint"
        self.stage_compute: Tuple[float, ...] = sc
        # per-hop bandwidth EMAs for hops >= 1 (hop 0 is ``bw_ema``)
        self.hop_bw_ema: Dict[int, float] = {}

    @property
    def n_hops(self) -> int:
        return len(self.hop_elems)

    def observe_bandwidth(self, bps: float, alpha: float = 0.5):
        self.bw_ema = bps if self.bw_ema is None else \
            alpha * bps + (1 - alpha) * self.bw_ema

    def observe_hop_bandwidth(self, hop: int, bps: float, alpha: float = 0.5):
        """Per-hop bandwidth measurement (hop 0 feeds the classic EMA)."""
        assert 0 <= hop < self.n_hops, hop
        if hop == 0:
            self.observe_bandwidth(bps, alpha)
            return
        cur = self.hop_bw_ema.get(hop)
        self.hop_bw_ema[hop] = bps if cur is None else \
            alpha * bps + (1 - alpha) * cur

    def hop_bandwidth(self, hop: int) -> Optional[float]:
        """Best bandwidth estimate for ``hop``; a hop whose EMA is missing
        degrades gracefully to the end uplink's EMA (the only measurement
        the classic engine takes)."""
        if hop == 0:
            return self.bw_ema
        return self.hop_bw_ema.get(hop, self.bw_ema)

    def choose_hop_bits(self, required: int,
                        levels: Sequence[int] = (3, 4, 5, 6, 8, 12, 16)
                        ) -> Tuple[int, ...]:
        """Eq. 11 per hop: each ``WirePacket`` hop fills its link's idle
        time up to the ceiling of its adjacent compute stages, using that
        hop's own bandwidth EMA."""
        out = []
        for k in range(self.n_hops):
            bw = self.hop_bandwidth(k) or 1e6
            out.append(choose_bits(required, self.hop_elems[k], bw,
                                   self.stage_compute[k],
                                   self.stage_compute[k + 1], levels=levels))
        return tuple(out)

    def probe_centers(self, segment: int = 0
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """Trained-center view of the probe at ``segment`` (0 = the end
        device's cache): the ``(centers, label index map)`` a fused
        boundary pass runs against; lift its outputs back with
        ``ProbeResult.from_fused``."""
        cache = self.cache if segment == 0 \
            else self.hop_probes[segment - 1].cache
        return cache.trained_view()

    def step(self, feat: np.ndarray, bandwidth_bps: Optional[float] = None,
             probe: Optional[ProbeResult] = None) -> OnlineDecision:
        """``probe`` supplies precomputed Eq. 8-10 outputs (the fused
        boundary pass): the similarity/separability math is skipped —
        one HBM read served both the wire packet and this decision —
        while threshold logic and Eq. 7/11 run unchanged (``feat`` still
        feeds the center updates)."""
        if bandwidth_bps is not None:
            self.observe_bandwidth(bandwidth_bps)
        if probe is not None:
            sims, s = probe.sims, probe.sep
        else:
            sims = self.cache.similarities(feat)
            s = separability(sims, self.cache.counts)
        # exit eligibility needs >= 2 warmed labels: with a single warm
        # center the separability statistic has no second-highest degree
        # and a cold cache must never terminate tasks (Eq. 9 over trained
        # centers only; see ``separability``)
        if self.cache.n_warm >= 2 and s > self.th.s_ext:
            j = probe.best if probe is not None else int(np.argmax(sims))
            if self.update_centers:
                self.cache.update(feat, j)
            return OnlineDecision(True, j, s, None, None)
        q_r = self.th.required_bits(s)
        bw = self.bw_ema or 1e6
        q_c = choose_bits(q_r, self.elems, bw, self.T_e, self.T_c)
        return OnlineDecision(False, None, s, q_c, q_r)

    # -------------------------------------------------- hop-level probes
    def probe_hop(self, segment: int, feat: np.ndarray,
                  probe: Optional[ProbeResult] = None) -> OnlineDecision:
        """Run the semantic probe of intermediate tier ``segment`` (>= 1)
        on its boundary activation: Eq. 8-10 against that tier's own
        centers and calibrated exit threshold.  On exit, the tier's
        centers refresh with the probe's own result (Eq. 7), exactly like
        the end device's classic exit path.  ``probe`` supplies the
        tier's fused-pass outputs in place of the recompute."""
        assert 1 <= segment <= len(self.hop_probes), \
            f"no probe calibrated for segment {segment}"
        hp = self.hop_probes[segment - 1]
        if probe is not None:
            sims, s = probe.sims, probe.sep
        else:
            sims = hp.cache.similarities(feat)
            s = separability(sims, hp.cache.counts)
        if hp.cache.n_warm >= 2 and s > hp.thresholds.s_ext:
            j = probe.best if probe is not None else int(np.argmax(sims))
            if self.update_centers:
                hp.cache.update(feat, j)
            return OnlineDecision(False, j, s, None, None,
                                  exit_hop=segment)
        return OnlineDecision(False, None, s, None,
                              hp.thresholds.required_bits(s))

    def step_cascade(self, hop_feats: Sequence[np.ndarray],
                     bandwidth_bps: Optional[float] = None,
                     probes: Optional[Sequence[Optional[ProbeResult]]]
                     = None) -> OnlineDecision:
        """Full hop-level decision cascade (SPINN-style progressive
        inference on the COACH probe): the classic end-device step first
        (exit / Eq. 11 uplink precision), then the intermediate tiers'
        probes in chain order — the first tier whose probe clears its own
        threshold terminates the task there (``exit_hop``).  The merged
        decision keeps the uplink ``bits``: a task exiting at tier k >= 1
        was still transmitted over hops ``0..k-1``.

        ``hop_feats[k]`` is the boundary activation feeding the probe at
        segment ``k``; a shorter list reuses its last entry.  ``probes``
        optionally carries one precomputed ``ProbeResult`` per segment
        (fused boundary passes); a shorter list (or ``None`` entries)
        falls back to recomputing from the features."""
        feat0 = hop_feats[0]
        p0 = probes[0] if probes else None
        dec = self.step(feat0, bandwidth_bps=bandwidth_bps, probe=p0)
        if dec.early_exit or not self.hop_probes:
            return dec
        for seg in range(1, len(self.hop_probes) + 1):
            feat = hop_feats[min(seg, len(hop_feats) - 1)]
            pk = probes[seg] if probes is not None \
                and seg < len(probes) else None
            hd = self.probe_hop(seg, feat, probe=pk)
            if hd.exit_hop is not None:
                return dataclasses.replace(
                    dec, result=hd.result, exit_hop=hd.exit_hop,
                    separability=hd.separability)
        return dec

    def report_label(self, feat: np.ndarray, label: int):
        """Cloud returned the true result: refresh the semantic center."""
        if self.update_centers:
            self.cache.update(feat, label)

    def report_label_hops(self, hop_feats: Sequence[np.ndarray], label: int,
                          upto: Optional[int] = None):
        """A result label flowed back down the chain: refresh the end
        device's centers *and* every intermediate tier's that the task
        passed (each saw its boundary activation and declined to exit).
        ``upto = k`` limits the refresh to segments ``< k`` (the tiers a
        task exiting at segment ``k`` actually crossed — the exiting
        tier itself already self-updated in ``probe_hop``); ``None``
        refreshes the whole cascade (full-pipeline task, true label)."""
        if not self.update_centers:
            return
        last = len(self.hop_probes) if upto is None \
            else min(upto - 1, len(self.hop_probes))
        if upto is None or upto > 0:
            self.cache.update(np.asarray(hop_feats[0]), label)
        for seg in range(1, last + 1):
            feat = hop_feats[min(seg, len(hop_feats) - 1)]
            self.hop_probes[seg - 1].cache.update(np.asarray(feat), label)
