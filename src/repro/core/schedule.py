"""Single-task stage-time evaluation (grounds Eq. 2–5 in an executable
event semantics).

Given a partition (end set + per-boundary-edge quant bits), simulate one
task through: serial end-device execution -> FIFO link transfers (each
boundary tensor becomes transmissible when its producer finishes) -> serial
cloud execution gated on received tensors.  From the resulting timeline we
extract the paper's quantities:

  T_e, T_t, T_c        stage busy times (Eq. 2)
  T_t_par              transmission overlapped with end compute   (Fig. 4)
  T_c_par              cloud compute overlapped with transmission (Fig. 4)
  B_c, B_t             bubble functions (Eq. 5)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Tuple

from repro.core.costs import DeviceProfile, LinkProfile, ModelGraph

Edge = Tuple[int, int]


@dataclasses.dataclass(frozen=True)
class PartitionDecision:
    end_set: FrozenSet[int]
    bits: Dict[Edge, int]  # quantization precision per boundary edge
    name: str = "coach"

    def boundary_bits_total(self, graph: ModelGraph) -> float:
        total = 0.0
        for (u, v), b in self.bits.items():
            elems = graph.node(v).out_elems if u < 0 else graph.node(u).out_elems
            total += elems * b
        return total


@dataclasses.dataclass
class StageTimes:
    T_e: float
    T_t: float
    T_c: float
    T_t_par: float
    T_c_par: float
    latency: float           # single-task end-to-end
    first_tx_offset: float   # end-start -> first boundary tensor ready
    cloud_start_offset: float  # first tx start -> cloud can begin

    @property
    def B_c(self) -> float:
        return abs(self.T_e - self.T_c)

    @property
    def B_t(self) -> float:
        m = max(self.T_e, self.T_t - self.T_t_par, self.T_c - self.T_c_par)
        return abs(self.T_t - m)

    @property
    def max_stage(self) -> float:
        return max(self.T_e, self.T_t, self.T_c)

    def objective(self) -> float:
        """Eq. 6: B_c + B_t + max{T_e, T_t, T_c}."""
        return self.B_c + self.B_t + self.max_stage

    def satisfies_parallel_constraint(self) -> bool:
        """Eq. 4 (tolerance for float noise)."""
        return self.T_t_par + self.T_c_par <= self.max_stage * (1 + 1e-9)


def _overlap(intervals_a: List[Tuple[float, float]],
             intervals_b: List[Tuple[float, float]]) -> float:
    tot, j = 0.0, 0
    for (a0, a1) in intervals_a:
        for (b0, b1) in intervals_b:
            lo, hi = max(a0, b0), min(a1, b1)
            if hi > lo:
                tot += hi - lo
    return tot


def evaluate_partition(graph: ModelGraph, decision: PartitionDecision,
                       end_dev: DeviceProfile, cloud_dev: DeviceProfile,
                       link: LinkProfile,
                       input_bits_per_elem: int = 8) -> StageTimes:
    end_set = decision.end_set
    assert graph.valid_end_set(end_set), "end set not downward-closed"

    # ---------------- end device: serial, topological (id) order ----------
    t = 0.0
    end_done: Dict[int, float] = {}
    end_intervals: List[Tuple[float, float]] = []
    for n in graph.nodes:
        if n.id in end_set:
            dt = end_dev.layer_time(n.flops, n.util)
            end_intervals.append((t, t + dt))
            t += dt
            end_done[n.id] = t
    T_e = t

    # ---------------- link: FIFO over boundary tensors --------------------
    edges = graph.boundary_edges(end_set)
    ready: List[Tuple[float, Edge, float]] = []
    for (u, v) in edges:
        when = 0.0 if u < 0 else end_done[u]
        if u < 0:
            # raw task input (uint8 image / token ids)
            bits = graph.input_elems * input_bits_per_elem
        else:
            bits = graph.node(u).out_elems * decision.bits.get((u, v), 32)
        ready.append((when, (u, v), bits))
    ready.sort(key=lambda r: (r[0], r[1]))

    link_free = 0.0
    recv: Dict[int, float] = {}
    link_intervals: List[Tuple[float, float]] = []
    T_t = 0.0
    first_tx_start = None
    for (when, (u, v), bits) in ready:
        start = max(when, link_free)
        dur = link.transfer_time(bits, start)
        link_intervals.append((start, start + dur))
        if first_tx_start is None:
            first_tx_start = start
        link_free = start + dur
        T_t += dur
        recv[u] = link_free  # tensor u (or input -1) fully received

    # ---------------- cloud: serial, id order, gated on deps --------------
    t = 0.0
    cloud_done: Dict[int, float] = {}
    cloud_intervals: List[Tuple[float, float]] = []
    T_c = 0.0
    for n in graph.nodes:
        if n.id in end_set:
            continue
        ready_at = 0.0
        for d in n.deps:
            ready_at = max(ready_at,
                           recv[d] if d in end_set else cloud_done[d])
        if not n.deps:
            ready_at = recv.get(-1, 0.0)
        dt = cloud_dev.layer_time(n.flops, n.util)
        start = max(t, ready_at)
        cloud_intervals.append((start, start + dt))
        t = start + dt
        cloud_done[n.id] = t
        T_c += dt

    finish = max([T_e] + list(cloud_done.values()) + [link_free])
    T_t_par = _overlap(link_intervals, end_intervals)
    T_c_par = _overlap(cloud_intervals, link_intervals)
    first_tx = first_tx_start if first_tx_start is not None else T_e
    cloud_first = min((s for s, _ in cloud_intervals), default=first_tx)
    return StageTimes(
        T_e=T_e, T_t=T_t, T_c=T_c, T_t_par=T_t_par, T_c_par=T_c_par,
        latency=finish,
        first_tx_offset=first_tx,
        cloud_start_offset=max(0.0, cloud_first - first_tx),
    )
