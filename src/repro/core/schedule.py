"""Single-task stage-time evaluation (grounds Eq. 2-5 in an executable
event semantics).

Given a partition — classically an end set + per-boundary-edge quant bits,
generally an ordered multi-cut over ``n_hops + 1`` devices — simulate one
task through the alternating compute/link resources of
``repro.core.sim`` and extract the paper's quantities:

  T_e, T_t, T_c        stage busy times (Eq. 2); per-hop in ``compute``/``link``
  T_t_par              transmission overlapped with upstream compute (Fig. 4)
  T_c_par              downstream compute overlapped with transmission
  B_c, B_t             bubble functions (Eq. 5), summed over hops

The classic end->link->cloud evaluation (``evaluate_partition``) is the
``n_hops = 1`` case of ``evaluate_multihop``; both delegate to the shared
event core in ``repro.core.sim``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Sequence, Tuple

from repro.core import sim
from repro.core.costs import DeviceProfile, LinkProfile, ModelGraph

Edge = Tuple[int, int]


@dataclasses.dataclass(frozen=True)
class PartitionDecision:
    """A partition of the model DAG across ``n_hops + 1`` devices.

    The classic 2-device form sets ``end_set``/``bits`` only.  The general
    form is an ordered multi-cut: ``frontiers`` is a nested chain of
    downward-closed node sets ``F_1 ⊆ F_2 ⊆ ...`` (device ``k`` runs
    ``F_{k+1} - F_k``; the last device runs the rest), and ``hop_bits[k]``
    holds the quantization precision of every boundary tensor crossing
    link ``k``.  ``end_set``/``bits`` always mirror the first frontier/hop
    for backward compatibility."""
    end_set: FrozenSet[int]
    bits: Dict[Edge, int]  # quantization precision per hop-0 boundary edge
    name: str = "coach"
    frontiers: Tuple[FrozenSet[int], ...] = ()
    hop_bits: Tuple[Dict[Edge, int], ...] = ()

    @classmethod
    def multihop(cls, frontiers: Sequence[FrozenSet[int]],
                 hop_bits: Sequence[Dict[Edge, int]],
                 name: str = "coach") -> "PartitionDecision":
        frontiers = tuple(frozenset(f) for f in frontiers)
        hop_bits = tuple(dict(b) for b in hop_bits)
        assert len(frontiers) == len(hop_bits) >= 1
        return cls(end_set=frontiers[0], bits=hop_bits[0], name=name,
                   frontiers=frontiers, hop_bits=hop_bits)

    @property
    def cuts(self) -> Tuple[FrozenSet[int], ...]:
        return self.frontiers if self.frontiers else (self.end_set,)

    @property
    def all_hop_bits(self) -> Tuple[Dict[Edge, int], ...]:
        return self.hop_bits if self.hop_bits else (self.bits,)

    @property
    def n_hops(self) -> int:
        return len(self.cuts)

    def segments(self, graph: ModelGraph) -> List[frozenset]:
        """Ordered per-device node sets (length ``n_hops + 1``)."""
        cuts = self.cuts
        segs, prev = [], frozenset()
        for f in cuts:
            assert prev <= f, "frontiers not nested"
            segs.append(f - prev)
            prev = f
        segs.append(frozenset(n.id for n in graph.nodes) - prev)
        return segs

    def boundary_bits_total(self, graph: ModelGraph) -> float:
        total = 0.0
        for (u, v), b in self.bits.items():
            elems = graph.node(v).out_elems if u < 0 else graph.node(u).out_elems
            total += elems * b
        return total


@dataclasses.dataclass
class StageTimes:
    """Stage busy times / overlaps of one simulated task.

    The first eight fields are the classic 3-resource view (and remain
    exact for ``n_hops = 1``); the tuple fields carry the generalized
    per-resource view.  For multi-hop timelines ``T_t`` is the total link
    busy time and ``T_c`` the last (cloud) segment."""
    T_e: float
    T_t: float
    T_c: float
    T_t_par: float
    T_c_par: float
    latency: float           # single-task end-to-end
    first_tx_offset: float   # end-start -> first boundary tensor ready
    cloud_start_offset: float  # first tx start -> cloud can begin
    # ---- generalized N-hop view (empty tuples => classic 2-segment case)
    compute: Tuple[float, ...] = ()
    link: Tuple[float, ...] = ()
    link_par: Tuple[float, ...] = ()
    compute_par: Tuple[float, ...] = ()
    tx_offsets: Tuple[float, ...] = ()   # per hop, relative to its segment start
    rx_offsets: Tuple[float, ...] = ()   # per hop, relative to its tx start

    def __post_init__(self):
        if not self.compute:
            self.compute = (self.T_e, self.T_c)
            self.link = (self.T_t,)
            self.link_par = (self.T_t_par,)
            self.compute_par = (self.T_c_par,)
            self.tx_offsets = (self.first_tx_offset,)
            self.rx_offsets = (self.cloud_start_offset,)

    @classmethod
    def from_timeline(cls, tl: sim.TaskTimeline) -> "StageTimes":
        tx_rel = tuple(max(0.0, tl.first_tx[k] - tl.seg_start[k])
                       for k in range(tl.n_hops))
        rx_rel = tuple(max(0.0, tl.next_start[k] - tl.first_tx[k])
                       for k in range(tl.n_hops))
        return cls(
            T_e=tl.compute_busy[0], T_t=sum(tl.link_busy),
            T_c=tl.compute_busy[-1],
            T_t_par=sum(tl.link_par), T_c_par=sum(tl.compute_par),
            latency=tl.latency,
            first_tx_offset=tl.first_tx[0],
            cloud_start_offset=rx_rel[0],
            compute=tl.compute_busy, link=tl.link_busy,
            link_par=tl.link_par, compute_par=tl.compute_par,
            tx_offsets=tx_rel, rx_offsets=rx_rel)

    @property
    def n_hops(self) -> int:
        return len(self.link)

    @property
    def B_c(self) -> float:
        """Eq. 5 compute bubble, summed over adjacent compute pairs."""
        return sum(abs(self.compute[k] - self.compute[k + 1])
                   for k in range(self.n_hops))

    @property
    def B_t(self) -> float:
        """Eq. 5 transmission bubble, per hop against its effective ceiling."""
        tot = 0.0
        for k in range(self.n_hops):
            m = max(self.compute[k],
                    self.link[k] - self.link_par[k],
                    self.compute[k + 1] - self.compute_par[k])
            tot += abs(self.link[k] - m)
        return tot

    @property
    def max_stage(self) -> float:
        return max(self.compute + self.link)

    @property
    def stage_sum(self) -> float:
        """Serial sum of all stage times (Eq. 3 latency budget input)."""
        return sum(self.compute) + sum(self.link)

    def objective(self) -> float:
        """Eq. 6: B_c + B_t + max stage (bubble sums over hops)."""
        return self.B_c + self.B_t + self.max_stage

    def satisfies_parallel_constraint(self) -> bool:
        """Eq. 4 per hop (tolerance for float noise)."""
        m = self.max_stage * (1 + 1e-9)
        return all(self.link_par[k] + self.compute_par[k] <= m
                   for k in range(self.n_hops))


def evaluate_multihop(graph: ModelGraph, decision: PartitionDecision,
                      devices: Sequence[DeviceProfile],
                      links: Sequence[LinkProfile],
                      input_bits_per_elem: int = 8) -> StageTimes:
    """Simulate one task through an ordered multi-cut partition over
    ``len(links) + 1`` devices (shared event core: ``repro.core.sim``)."""
    cuts = decision.cuts
    assert len(links) == len(cuts), \
        f"decision has {len(cuts)} hops but {len(links)} links given"
    assert len(devices) == len(links) + 1
    prev = frozenset()
    for f in cuts:
        assert graph.valid_end_set(f), "frontier not downward-closed"
        assert prev <= f, "frontiers not nested"
        prev = f
    segments = decision.segments(graph)
    tl = sim.simulate_partitioned_task(
        graph, segments, decision.all_hop_bits, devices, links,
        input_bits_per_elem=input_bits_per_elem)
    return StageTimes.from_timeline(tl)


def evaluate_partition(graph: ModelGraph, decision: PartitionDecision,
                       end_dev: DeviceProfile, cloud_dev: DeviceProfile,
                       link: LinkProfile,
                       input_bits_per_elem: int = 8) -> StageTimes:
    """Classic end->link->cloud evaluation: ``n_hops = 1`` of the general
    machinery."""
    assert decision.n_hops == 1, "multi-cut decision needs evaluate_multihop"
    return evaluate_multihop(graph, decision, (end_dev, cloud_dev), (link,),
                             input_bits_per_elem=input_bits_per_elem)
