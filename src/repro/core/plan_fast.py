"""Batched incremental scorer for the offline planner (pure speedup).

``partitioner.coach_offline_multihop`` sweeps ordered multi-cut tuples
over the chain flow, and historically paid a full Python event
simulation (``sim.simulate_partitioned_task``) — times the 5-level relax
ladder — for *every* candidate, plus a fresh dichotomous quantization
search per frontier per tuple.  This module makes candidate evaluation
O(boundary events) arithmetic instead of O(graph) simulation while
keeping the event simulator as ground truth:

``build_tables``
    Precomputes, once per (graph, devices, links, eps): per-device
    cumulative compute times over the chain prefixes (numpy prefix
    sums), the boundary-edge set of every chain-cut position with
    per-relax-level bit volumes (each producer's Eq. 1 minimum priced
    once, via the caller's memoized dichotomous search), and the
    *serial-cut* flags of the vectorized fast path.

``chain_sweep`` / ``chain_shortlist``
    Score **all** chain-cut tuples at once: numpy prefix-sum lookups
    give every (tuple, relax level) its per-segment compute busy,
    per-hop link busy, compute bubble ``B_c``, ``max_stage`` and the
    Eq. 3 stage-time sum.  Tuples whose cuts are provably serial (a
    single tail→head boundary tensor per hop, so no Fig. 4 overlap) get
    exact objectives fully vectorized; the rest replay only their
    boundary events — gate stalls, FIFO transfers, overlap windows — in
    O(edges) per candidate (``_replay_chain``).

``stage_times_chain`` / ``stage_times_frontiers``
    Exact fast evaluation of a single candidate: reproduces
    ``schedule.evaluate_multihop`` field-for-field at 1e-9
    (differentially pinned by ``tests/test_plan_fast.py``).  The
    frontier form accepts arbitrary nested downward-closed cuts (block
    recursion refinement, brute force) and explicit per-hop bit maps.

The planner rescores the shortlisted top-K candidates with the real
event simulator and returns *that* argmin, so the fast path is a pure
speedup: the chosen ``PartitionDecision`` and objective are identical
to the naive per-candidate simulation search (argmin-equality tested).

Links carrying a bandwidth *trace* are first-class: every boundary
transfer is re-priced at its actual start instant through
``LinkProfile.transfer_time`` inside the sparse replay — exactly the
event simulator's integration — so ``stage_times_chain`` /
``stage_times_frontiers`` stay trace-exact.  The vectorized closed
forms of ``chain_sweep`` are only valid at constant bandwidth, so a
traced sweep scores every candidate through the replay instead
(exhaustive and exact, hence the shortlist trivially contains the
naive argmin).  ``retime_tables`` rebinds existing tables to new link
profiles without re-running the Eq. 1 pricing — the warm-start used by
online re-planning (``repro.scenarios.replan``).
"""

from __future__ import annotations

import dataclasses
import itertools
from bisect import bisect_right
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import sim
from repro.core.costs import DeviceProfile, LinkProfile, ModelGraph
from repro.core.schedule import Edge, StageTimes

#: Relax ladder of the offline search: the Eq. 1 minimum plus the extra
#: precision trials of ``partitioner._relax_bits`` (kept in lockstep).
RELAX_EXTRAS: Tuple[int, ...] = (0, 1, 2, 4, 8)
HI_BITS = 16
#: Relative tolerance of ``_relax_bits``'s pipeline-ceiling acceptance.
CEIL_TOL = 1e-9

#: Per-hop pricer type: (bit volume, start instant) -> transfer duration.
HopPricer = Callable[[float, float], float]


def _hop_pricers(links: Sequence[LinkProfile]
                 ) -> Optional[List[Optional[HopPricer]]]:
    """Per-hop start-time pricers for traced links; ``None`` when every
    hop is constant-bandwidth (the vectorized closed forms apply)."""
    if all(lk.trace is None for lk in links):
        return None
    return [(lambda vol, start, lk=lk: lk.transfer_time(vol, start))
            if lk.trace is not None else None
            for lk in links]


# ==================================================================== tables
@dataclasses.dataclass
class PlannerTables:
    """Precomputed per-(graph, devices, links, eps) scoring substrate."""
    graph: ModelGraph
    devices: Tuple[DeviceProfile, ...]
    links: Tuple[LinkProfile, ...]
    input_bits_per_elem: int
    dt: np.ndarray         # [n_dev, V] per-node compute time per device
    cum: np.ndarray        # [n_dev, V+1] cumulative node time per device (id order)
    bw: Tuple[float, ...]  # per-hop bandwidth (bits/s)
    node_bits: Callable[[int], int]  # Eq. 1 minimal precision of a producer
    # global edge table: graph edges + raw-input pseudo edges (-1, v)
    edge_u: np.ndarray     # [E] producer id (-1 = raw model input)
    edge_v: np.ndarray     # [E] consumer id
    edge_elems: np.ndarray  # [E] elements carried by the edge
    edge_vol: np.ndarray   # [L, E] bit volume per relax level (elems * bits);
                           # priced lazily — see ``ensure_priced``
    priced: np.ndarray     # [E] bool: edge_vol column is valid
    # chain-cut structure (None when built without chain prefixes)
    pref_cnt: Optional[np.ndarray] = None      # [P] ids in each chain prefix
    pos_edges: Optional[List[list]] = None     # [P] -> [(u, v, vols tuple)]
    pos_vol: Optional[np.ndarray] = None       # [L, P] total crossing volume
    pos_has_bits: Optional[np.ndarray] = None  # [P] any quantized (u>=0) edge
    pos_serial: Optional[np.ndarray] = None    # [P] single tail->head edge
    # per-hop start-time pricers (None everywhere constant-bandwidth)
    hop_price: Optional[List[Optional[HopPricer]]] = None

    @property
    def n_hops(self) -> int:
        return len(self.links)

    def ensure_priced(self, idx: np.ndarray) -> None:
        """Run the (possibly expensive) Eq. 1 oracle search only for the
        producers of edges a candidate actually exposes — edges that never
        cross a swept cut never pay for it (matching the naive search's
        on-demand quantization)."""
        for i in idx:
            if self.priced[i]:
                continue
            u = int(self.edge_u[i])
            if u < 0:
                bits = float(self.input_bits_per_elem)
                self.edge_vol[:, i] = self.edge_elems[i] * bits
            else:
                b = self.node_bits(u)
                for li, extra in enumerate(RELAX_EXTRAS):
                    self.edge_vol[li, i] = self.edge_elems[i] \
                        * min(HI_BITS, b + extra)
            self.priced[i] = True


def graph_edges(graph: ModelGraph) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """All data edges incl. raw-input pseudo edges, as (u, v, elems) arrays
    (mirrors the per-edge arrival bookkeeping of the event simulator)."""
    eu: List[int] = []
    ev: List[int] = []
    elems: List[float] = []
    for n in graph.nodes:
        if n.deps:
            for d in n.deps:
                eu.append(d)
                ev.append(n.id)
                elems.append(float(graph.node(d).out_elems))
        else:
            eu.append(-1)
            ev.append(n.id)
            elems.append(float(graph.input_elems))
    return (np.asarray(eu, dtype=np.int64), np.asarray(ev, dtype=np.int64),
            np.asarray(elems, dtype=np.float64))


def build_tables(graph: ModelGraph, devices: Sequence[DeviceProfile],
                 links: Sequence[LinkProfile],
                 node_bits: Callable[[int], int],
                 pref_counts: Optional[Sequence[int]] = None,
                 input_bits_per_elem: int = 8) -> PlannerTables:
    """``node_bits(u)`` must return the Eq. 1 minimal precision of node
    ``u``'s output (the planner passes its memoized dichotomous search —
    boundary bits depend only on the producer, so each node is priced
    exactly once across every frontier that exposes it)."""
    n_dev = len(devices)
    assert n_dev == len(links) + 1
    dt = np.array([[d.layer_time(n.flops, n.util) for n in graph.nodes]
                   for d in devices], dtype=np.float64)
    cum = np.zeros((n_dev, len(graph) + 1))
    np.cumsum(dt, axis=1, out=cum[:, 1:])

    eu, ev, elems = graph_edges(graph)
    n_lvl = len(RELAX_EXTRAS)
    tables = PlannerTables(
        graph=graph, devices=tuple(devices), links=tuple(links),
        input_bits_per_elem=input_bits_per_elem, dt=dt, cum=cum,
        bw=tuple(lk.bandwidth_bps for lk in links), node_bits=node_bits,
        edge_u=eu, edge_v=ev, edge_elems=elems,
        edge_vol=np.zeros((n_lvl, len(eu))),
        priced=np.zeros(len(eu), dtype=bool),
        hop_price=_hop_pricers(links))

    if pref_counts is not None:
        pref_cnt = np.asarray(pref_counts, dtype=np.int64)
        n_pos = len(pref_cnt)
        pos_edges: List[list] = []
        pos_vol = np.zeros((n_lvl, n_pos))
        pos_has = np.zeros(n_pos, dtype=bool)
        pos_serial = np.zeros(n_pos, dtype=bool)
        for p in range(n_pos):
            cnt = int(pref_cnt[p])
            mask = (eu < cnt) & (ev >= cnt)
            idx = np.nonzero(mask)[0]
            order = np.lexsort((ev[idx], eu[idx]))
            idx = idx[order]
            tables.ensure_priced(idx)  # only grid-crossing edges pay Eq. 1
            pos_edges.append([(int(eu[i]), int(ev[i]),
                               tuple(tables.edge_vol[:, i])) for i in idx])
            pos_vol[:, p] = tables.edge_vol[:, idx].sum(axis=1)
            pos_has[p] = bool((eu[idx] >= 0).any())
            pos_serial[p] = (len(idx) == 1 and int(eu[idx[0]]) == cnt - 1
                             and int(ev[idx[0]]) == cnt)
        tables.pref_cnt = pref_cnt
        tables.pos_edges = pos_edges
        tables.pos_vol = pos_vol
        tables.pos_has_bits = pos_has
        tables.pos_serial = pos_serial
    return tables


def retime_tables(tables: PlannerTables,
                  links: Sequence[LinkProfile]) -> PlannerTables:
    """Rebind existing tables to new link profiles (warm start).

    Everything bandwidth-independent is shared by reference: the compute
    prefix sums, the chain-cut structure and the Eq. 1 edge *volumes*
    (``edge_vol`` is bits — pricing an edge under one link set prices it
    for all).  Only the per-hop bandwidths and traced-link pricers are
    replaced, so an online re-plan after a regime shift skips the whole
    oracle/table build (``repro.scenarios.replan``).
    """
    assert len(links) == len(tables.links), \
        "retimed links must keep the hop count"
    return dataclasses.replace(
        tables, links=tuple(links),
        bw=tuple(lk.bandwidth_bps for lk in links),
        hop_price=_hop_pricers(links))


# ============================================================= event replay
# replay interval lists are sorted & disjoint by construction, so the
# simulator's merge scan applies directly (one shared implementation)
_overlap_sorted = sim.overlap_sorted_disjoint


def _replay(n_seg: int,
            seg_pos: Sequence[Callable[[int], int]],
            seg_cum: Sequence[Callable[[int], float]],
            seg_size: Sequence[int],
            hop_edges: Sequence[Sequence[Tuple[int, int, float]]],
            in_seg: Callable[[int, int], bool],
            hop_price: Optional[Sequence[Optional[HopPricer]]] = None
            ) -> sim.TaskTimeline:
    """Shared sparse event core: replay only the boundary events of one
    candidate partition, exactly as ``sim.simulate_partitioned_task``.

    ``seg_pos[k](id)`` maps a node id to its execution position inside
    segment ``k`` (nodes run serially in id order), ``seg_cum[k](pos)``
    is the cumulative compute time of the segment's first ``pos`` nodes,
    ``hop_edges[k]`` the boundary tensors crossing link ``k`` as
    ``(u, v, duration)``, and ``in_seg(k, u)`` whether producer ``u``
    lives in segment ``k``.  With ``hop_price`` set, a hop whose pricer
    is non-``None`` carries *bit volumes* instead of durations and each
    transfer is priced at its actual FIFO start instant (bandwidth
    traces — the simulator's re-integration).
    """
    n_hops = n_seg - 1
    recv: Dict[Edge, float] = {}
    seg_fin = [0.0] * n_seg
    link_fin = [0.0] * n_hops
    compute_busy = [0.0] * n_seg
    link_busy = [0.0] * n_hops
    first_tx: List[Optional[float]] = [None] * n_hops
    comp_runs: List[List[Tuple[float, float]]] = [[] for _ in range(n_seg)]
    link_iv: List[List[Tuple[float, float]]] = [[] for _ in range(n_hops)]
    gates_next: Dict[int, float] = {}

    for k in range(n_seg):
        pos_of, cum_at, size = seg_pos[k], seg_cum[k], seg_size[k]
        compute_busy[k] = cum_at(size) - cum_at(0)
        gates = sorted(gates_next.items())
        runs = comp_runs[k]
        cur: Optional[List[float]] = None
        t = 0.0
        last = 0  # segment-local position: nodes [0, last) processed
        gate_pos: List[int] = []
        gate_t: List[float] = []
        for v, ready in gates:
            pv = pos_of(v)
            if pv > last:  # ungated run before the gate
                e = t + cum_at(pv) - cum_at(last)
                if cur is None:
                    cur = [t, e]
                else:
                    cur[1] = e  # contiguous with the open run
                t = e
            s = ready if ready > t else t
            e = s + cum_at(pv + 1) - cum_at(pv)
            if cur is None:
                cur = [s, e]
            elif s == cur[1]:
                cur[1] = e
            else:
                runs.append((cur[0], cur[1]))
                cur = [s, e]
            t = e
            last = pv + 1
            gate_pos.append(pv)
            gate_t.append(t)
        if size > last:
            e = t + cum_at(size) - cum_at(last)
            if cur is None:
                cur = [t, e]
            else:
                cur[1] = e
            t = e
        if cur is not None:
            runs.append((cur[0], cur[1]))
        seg_fin[k] = t

        if k == n_hops:
            break

        def done(u: int) -> float:
            pu = pos_of(u)
            j = bisect_right(gate_pos, pu) - 1
            if j < 0:
                return cum_at(pu + 1) - cum_at(0)
            return gate_t[j] + cum_at(pu + 1) - cum_at(gate_pos[j] + 1)

        entries = []
        for (u, v, dur) in hop_edges[k]:
            if u < 0:
                when = 0.0 if k == 0 else recv[(u, v)]
            elif in_seg(k, u):
                when = done(u)
            else:  # relayed from an earlier hop
                when = recv[(u, v)]
            entries.append((when, u, v, dur))
        entries.sort(key=lambda r: (r[0], r[1], r[2]))
        price = hop_price[k] if hop_price is not None else None
        free = 0.0
        for (when, u, v, dur) in entries:
            start = when if when > free else free
            if price is not None:  # entry carried a bit volume
                dur = price(dur, start)
            if first_tx[k] is None:
                first_tx[k] = start
            free = start + dur
            link_busy[k] += dur
            link_iv[k].append((start, free))
            recv[(u, v)] = free
        link_fin[k] = free
        gates_next = {}
        for (_, u, v, _) in entries:
            if in_seg(k + 1, v):
                r = recv[(u, v)]
                if r > gates_next.get(v, -1.0):
                    gates_next[v] = r

    latency = max(seg_fin + link_fin)
    # fallback mirrors the simulator: a hop with nothing to transmit
    # collapses "first tx" to the upstream finish time
    ftx: List[float] = []
    upstream = 0.0
    for k in range(n_hops):
        upstream = max(upstream, seg_fin[k])
        ftx.append(first_tx[k] if first_tx[k] is not None else upstream)
        upstream = max(upstream, link_fin[k])
    seg_start = tuple(
        comp_runs[k][0][0] if comp_runs[k] else (ftx[k - 1] if k else 0.0)
        for k in range(n_seg))
    next_start = tuple(
        comp_runs[k + 1][0][0] if comp_runs[k + 1] else ftx[k]
        for k in range(n_hops))
    link_par = tuple(_overlap_sorted(link_iv[k], comp_runs[k])
                     for k in range(n_hops))
    compute_par = tuple(_overlap_sorted(comp_runs[k + 1], link_iv[k])
                        for k in range(n_hops))
    return sim.TaskTimeline(
        compute_busy=tuple(compute_busy), link_busy=tuple(link_busy),
        link_par=link_par, compute_par=compute_par, latency=latency,
        first_tx=tuple(ftx), seg_start=seg_start, next_start=next_start)


def _replay_chain(tables: PlannerTables, positions: Sequence[int],
                  level: int) -> sim.TaskTimeline:
    """Exact boundary-event replay of one chain-cut tuple: segments are
    contiguous id ranges, so position/cumsum lookups hit the global
    prefix tables directly (no per-candidate O(graph) work)."""
    cnts = [int(tables.pref_cnt[p]) for p in positions]
    bounds = [0] + cnts + [len(tables.graph)]
    n_seg = len(bounds) - 1
    seg_pos, seg_cum, seg_size = [], [], []
    for k in range(n_seg):
        lo = bounds[k]
        cum_k = tables.cum[k]
        seg_pos.append(lambda u, lo=lo: u - lo)
        seg_cum.append(lambda pos, cum_k=cum_k, lo=lo: cum_k[lo + pos])
        seg_size.append(bounds[k + 1] - lo)
    hp = tables.hop_price
    hop_edges = [[(u, v, vols[level] if hp is not None and hp[k] is not None
                   else vols[level] / tables.bw[k])
                  for (u, v, vols) in tables.pos_edges[positions[k]]]
                 for k in range(n_seg - 1)]
    return _replay(n_seg, seg_pos, seg_cum, seg_size, hop_edges,
                   lambda k, u: bounds[k] <= u < bounds[k + 1],
                   hop_price=hp)


def _chain_overlaps(tables: PlannerTables, positions: Sequence[int],
                    level: int) -> Tuple[List[float], List[float]]:
    """Lean inner loop of the batched sweep: the per-hop
    ``(link_par, compute_par)`` overlap windows of one chain-cut tuple,
    with the same event semantics as ``_replay`` but none of its
    timeline bookkeeping (every other ``StageTimes`` field of the sweep
    comes from the vectorized prefix-sum arrays)."""
    pref = tables.pref_cnt
    pos_edges = tables.pos_edges
    bw = tables.bw
    n = len(positions)
    bounds = [0] + [int(pref[p]) for p in positions] + [len(tables.graph)]
    recv: Dict[Edge, float] = {}
    gates: List[Tuple[int, float]] = []
    link_pars: List[float] = []
    compute_pars: List[float] = []
    prev_link_iv: List[Tuple[float, float]] = []
    for k in range(n + 1):
        lo, hi = bounds[k], bounds[k + 1]
        cum_k = tables.cum[k]
        runs: List[Tuple[float, float]] = []
        cs = ce = 0.0
        has_run = False
        t = 0.0
        last = lo - 1
        gate_ids: List[int] = []
        gate_t: List[float] = []
        for (v, r) in gates:
            if v > last + 1:
                e = t + cum_k[v] - cum_k[last + 1]
                if not has_run:
                    cs, has_run = t, True
                ce = e
                t = e
            s = r if r > t else t
            e = s + cum_k[v + 1] - cum_k[v]
            if not has_run:
                cs, ce, has_run = s, e, True
            elif s == ce:
                ce = e
            else:
                runs.append((cs, ce))
                cs, ce = s, e
            t = e
            last = v
            gate_ids.append(v)
            gate_t.append(t)
        if hi > last + 1:
            e = t + cum_k[hi] - cum_k[last + 1]
            if not has_run:
                cs, has_run = t, True
            ce = e
            t = e
        if has_run:
            runs.append((cs, ce))
        if k:
            compute_pars.append(_overlap_sorted(runs, prev_link_iv))
        if k == n:
            break
        entries = []
        for (u, v, vols) in pos_edges[positions[k]]:
            if u < 0:
                when = 0.0 if k == 0 else recv[(u, v)]
            elif u >= lo:  # produced in this segment (u < hi by crossing)
                j = bisect_right(gate_ids, u) - 1
                when = (cum_k[u + 1] - cum_k[lo]) if j < 0 \
                    else gate_t[j] + cum_k[u + 1] - cum_k[gate_ids[j] + 1]
            else:  # relayed from an earlier hop
                when = recv[(u, v)]
            entries.append((when, u, v, vols[level]))
        entries.sort()
        free = 0.0
        ivs: List[Tuple[float, float]] = []
        nb = bw[k]
        nlo, nhi = bounds[k + 1], bounds[k + 2]
        ngates: Dict[int, float] = {}
        for (when, u, v, vol) in entries:
            s = when if when > free else free
            free = s + vol / nb
            ivs.append((s, free))
            if nlo <= v < nhi:
                if free > ngates.get(v, -1.0):
                    ngates[v] = free
            else:
                recv[(u, v)] = free
        link_pars.append(_overlap_sorted(ivs, runs))
        prev_link_iv = ivs
        gates = sorted(ngates.items())
    return link_pars, compute_pars


def stage_times_chain(tables: PlannerTables, positions: Sequence[int],
                      extra: int = 0) -> StageTimes:
    """Fast exact ``StageTimes`` of a chain-cut tuple at relax level
    ``extra`` (an entry of ``RELAX_EXTRAS``)."""
    return StageTimes.from_timeline(
        _replay_chain(tables, positions, RELAX_EXTRAS.index(extra)))


def _crossing_idx(tables: PlannerTables, frontier: frozenset,
                  cache: Optional[Dict[frozenset, np.ndarray]] = None
                  ) -> np.ndarray:
    """Edge indices crossing one frontier: produced inside, consumed
    outside (raw input counts as upstream)."""
    if cache is not None:
        got = cache.get(frontier)
        if got is not None:
            return got
    eu, ev = tables.edge_u, tables.edge_v
    inside = np.zeros(len(tables.graph) + 1, dtype=bool)
    inside[list(frontier)] = True
    um = np.where(eu >= 0, inside[eu], True)
    idx = np.nonzero(um & ~inside[ev])[0]
    if cache is not None:
        cache[frontier] = idx
    return idx


class _FrontierScorer:
    """Per-candidate replay substrate for arbitrary nested multi-cuts
    (block-refined cuts, brute-force end sets): the segment layout and
    sorted boundary-edge lists are built once, then replayed per relax
    level (or per explicit bit map)."""

    def __init__(self, tables: PlannerTables,
                 frontiers: Sequence[frozenset],
                 crossing_cache: Optional[Dict[frozenset, np.ndarray]] = None,
                 level_pricing: bool = True):
        self.tables = tables
        self.frontiers = [frozenset(f) for f in frontiers]
        n = len(self.frontiers)
        seg_id = np.full(len(tables.graph), n, dtype=np.int64)
        for k in range(n - 1, -1, -1):
            seg_id[list(self.frontiers[k])] = k
        self.seg_id = seg_id
        members = [np.nonzero(seg_id == k)[0] for k in range(n + 1)]
        self.seg_pos, self.seg_cum, self.seg_size = [], [], []
        self.compute = np.empty(n + 1)
        for k in range(n + 1):
            mem = members[k]
            local = np.zeros(len(mem) + 1)
            if len(mem):
                np.cumsum(tables.dt[k][mem], out=local[1:])
            self.compute[k] = local[-1]
            self.seg_pos.append(
                lambda u, mem=mem: int(np.searchsorted(mem, u)))
            self.seg_cum.append(lambda pos, local=local: local[pos])
            self.seg_size.append(len(mem))
        eu, ev = tables.edge_u, tables.edge_v
        self.hop_idx = []
        for k in range(n):
            idx = _crossing_idx(tables, self.frontiers[k], crossing_cache)
            order = np.lexsort((ev[idx], eu[idx]))
            idx = idx[order]
            if level_pricing:
                tables.ensure_priced(idx)
            self.hop_idx.append(idx)
        self.hop_uv = [[(int(eu[i]), int(ev[i])) for i in idx]
                       for idx in self.hop_idx]
        self.has_bits = any((eu[idx] >= 0).any() for idx in self.hop_idx)
        # per-level, per-hop link busy (vectorized volume sums); only
        # meaningful when the Eq. 1 level pricing ran
        self.link = np.stack(
            [tables.edge_vol[:, idx].sum(axis=1) / tables.bw[k]
             for k, idx in enumerate(self.hop_idx)], axis=1) \
            if level_pricing else None  # [L, n]

    def timeline(self, level: Optional[int] = None,
                 hop_bits: Optional[Sequence[Dict[Edge, int]]] = None
                 ) -> sim.TaskTimeline:
        t = self.tables
        hp = t.hop_price
        hop_edges = []
        for k, idx in enumerate(self.hop_idx):
            if hop_bits is None:
                vols = t.edge_vol[level, idx]
            else:
                vols = [t.edge_elems[i]
                        * (t.input_bits_per_elem if u < 0
                           else hop_bits[k].get((u, v), 32))
                        for i, (u, v) in zip(idx, self.hop_uv[k])]
            if hp is not None and hp[k] is not None:
                durs = vols  # priced at start time inside the replay
            else:
                durs = [v / t.bw[k] for v in vols]
            hop_edges.append([(u, v, float(d))
                              for (u, v), d in zip(self.hop_uv[k], durs)])
        return _replay(len(self.frontiers) + 1, self.seg_pos, self.seg_cum,
                       self.seg_size, hop_edges,
                       lambda k, u: self.seg_id[u] == k,
                       hop_price=hp)


def stage_times_frontiers(tables: PlannerTables,
                          frontiers: Sequence[frozenset],
                          hop_bits: Optional[Sequence[Dict[Edge, int]]] = None,
                          extra: int = 0) -> StageTimes:
    """Fast exact ``StageTimes`` of an arbitrary nested multi-cut.

    With ``hop_bits`` the per-hop boundary precisions are taken from the
    given maps (missing edges default to fp32, raw input to the fixed
    input precision — the simulator's pricing); otherwise each edge is
    priced at its Eq. 1 minimum plus ``extra`` (clipped to 16)."""
    scorer = _FrontierScorer(tables, frontiers,
                             level_pricing=hop_bits is None)
    level = None if hop_bits is not None else RELAX_EXTRAS.index(extra)
    return StageTimes.from_timeline(
        scorer.timeline(level=level, hop_bits=hop_bits))


# ====================================================== batched chain sweep
@dataclasses.dataclass
class SweepResult:
    """Per-tuple relax-ladder representatives over the whole chain sweep.

    With pruning on, a tuple whose boundary-event replay was provably
    unnecessary carries its *lower bound* ``B_c + min max_stage`` as the
    objective and its stage-sum feasibility upper bound — both
    conservative for ranking (every pruned tuple is strictly dominated
    by the exactly-scored incumbent), so the shortlist still provably
    contains the naive argmin."""
    combos: List[Tuple[int, ...]]    # scored tuples, in naive (lex) order
    objective: np.ndarray            # [T] representative Eq. 6 objective
    feasible: np.ndarray             # [T] representative feasibility
    n_scored: int                    # candidate evaluations performed
    n_pruned: int = 0                # non-serial replays skipped via bound


def _chain_sweep_traced(tables: PlannerTables, positions: Sequence[int],
                        n_hops: int, min_end_nodes: int,
                        T_max: float) -> SweepResult:
    """Traced-link chain sweep: the vectorized closed forms assume
    constant bandwidth, so every tuple is scored *exactly* through the
    boundary-event replay (start-time pricing) and the ladder replicates
    ``partitioner._relax_bits`` verbatim.  Exact representatives mean
    the shortlist trivially contains the naive argmin — no pruning
    bounds are attempted (a trace invalidates them too)."""
    combos = [c for c in itertools.combinations_with_replacement(
        positions, n_hops)
        if tables.pref_cnt[c[0]] >= min_end_nodes]
    if not combos:
        return SweepResult([], np.empty(0), np.empty(0, bool), 0, 0)
    n_lvl = len(RELAX_EXTRAS)
    rep_obj = np.empty(len(combos))
    rep_feas = np.empty(len(combos), dtype=bool)
    n_scored = 0
    for ti, combo in enumerate(combos):
        has_bits = bool(tables.pos_has_bits[list(combo)].any())

        def exact(li):
            st = StageTimes.from_timeline(_replay_chain(tables, combo, li))
            fe = (st.stage_sum <= T_max) \
                and st.satisfies_parallel_constraint()
            return st.objective(), fe, st.max_stage

        r_obj, r_feas, r_ms = exact(0)
        n_scored += 1
        if has_bits:
            for li in range(1, n_lvl):
                o, fe, ms = exact(li)
                n_scored += 1
                if o < r_obj and fe >= r_feas \
                        and ms <= r_ms * (1 + CEIL_TOL):
                    r_obj, r_feas, r_ms = o, fe, ms
        rep_obj[ti], rep_feas[ti] = r_obj, r_feas
    return SweepResult(combos, rep_obj, rep_feas, n_scored, 0)


def chain_sweep(tables: PlannerTables, positions: Sequence[int],
                n_hops: int, min_end_nodes: int = 1,
                T_max: float = float("inf"),
                prune: bool = False) -> SweepResult:
    """Score every ordered chain-cut tuple at every relax level.

    Vectorized numpy prefix-sum lookups produce each (tuple, level)'s
    per-segment compute, per-hop link busy, ``B_c``, ``max_stage`` and
    stage sum in one shot; serial tuples finish fully vectorized, the
    rest replay their O(edges) boundary events.  The per-tuple
    representative replicates ``partitioner._relax_bits``'s acceptance
    rule exactly, so ranking matches the naive search.

    With ``prune=True`` the non-serial replays run in ascending
    lower-bound order (``B_c + min-over-levels max_stage``, with
    possibly-feasible tuples first) against an exactly-scored incumbent;
    once every remaining tuple is provably dominated — it cannot be
    feasible while the incumbent is, and its bound already exceeds the
    incumbent's near-tie band — the tail is skipped wholesale.  Skipped
    tuples keep their bound as representative, which by construction
    sorts strictly after the incumbent, so ``_shortlist``'s best /
    near-tie selection (and hence the rescored argmin) is unchanged.
    Representative *values* for pruned tuples differ from the
    ``prune=False`` sweep, which is why the exhaustive form stays the
    default.

    Tables built over traced links route to the exhaustive exact replay
    sweep (``_chain_sweep_traced``); ``prune`` is ignored there."""
    if tables.hop_price is not None:
        return _chain_sweep_traced(tables, positions, n_hops,
                                   min_end_nodes, T_max)
    combos = [c for c in itertools.combinations_with_replacement(
        positions, n_hops)
        if tables.pref_cnt[c[0]] >= min_end_nodes]
    if not combos:
        return SweepResult([], np.empty(0), np.empty(0, bool), 0, 0)
    P = np.asarray(combos, dtype=np.int64)          # [T, n]
    T = len(combos)
    cnt = tables.pref_cnt[P]                        # [T, n]
    n_lvl = len(RELAX_EXTRAS)
    lo = np.concatenate([np.zeros((T, 1), np.int64), cnt], axis=1)
    hi = np.concatenate([cnt, np.full((T, 1), len(tables.graph))], axis=1)
    compute = np.stack([tables.cum[k][hi[:, k]] - tables.cum[k][lo[:, k]]
                        for k in range(n_hops + 1)], axis=1)   # [T, n+1]
    link = np.stack([tables.pos_vol[:, P[:, k]] / tables.bw[k]
                     for k in range(n_hops)], axis=2)          # [L, T, n]
    B_c = np.abs(np.diff(compute, axis=1)).sum(axis=1)         # [T]
    max_stage = np.maximum(compute.max(axis=1)[None, :], link.max(axis=2))
    stage_sum = compute.sum(axis=1)[None, :] + link.sum(axis=2)
    has_bits = tables.pos_has_bits[P].any(axis=1)              # [T]
    serial = (tables.pos_serial[P].all(axis=1)
              & (np.diff(cnt, axis=1) > 0).all(axis=1))

    # serial tuples: no Fig. 4 overlap is possible, so B_t (and Eq. 4)
    # close vectorized — build every tuple's relax-ladder representative
    # from the closed form first (``_relax_bits`` acceptance, vectorized)
    obj = np.empty((n_lvl, T))
    feas = np.empty((n_lvl, T), dtype=bool)
    ceiling = np.maximum(np.maximum(compute[:, :-1], compute[:, 1:])[None],
                         link)                                 # [L, T, n]
    B_t = np.abs(link - ceiling).sum(axis=2)                   # [L, T]
    obj[:] = B_c[None, :] + B_t + max_stage
    feas[:] = stage_sum <= T_max
    rep_obj = obj[0].copy()
    rep_feas = feas[0].copy()
    rep_ms = max_stage[0].copy()
    for li in range(1, n_lvl):
        acc = (has_bits & (obj[li] < rep_obj) & (feas[li] >= rep_feas)
               & (max_stage[li] <= rep_ms * (1 + CEIL_TOL)))
        rep_obj = np.where(acc, obj[li], rep_obj)
        rep_feas = np.where(acc, feas[li], rep_feas)
        rep_ms = np.where(acc, max_stage[li], rep_ms)

    # non-serial tuples: replay their boundary events for the exact
    # overlap windows; levels that provably cannot be accepted (Eq. 6
    # objective >= its bound B_c + max_stage, or the ceiling rule) skip
    # the replay without changing the representative
    nonserial = list(np.nonzero(~serial)[0])
    n_pruned = 0
    inc_obj, inc_feas = np.inf, False
    if prune and nonserial:
        # B_t >= 0, so every level's objective >= B_c + max_stage and
        # the ladder representative >= B_c + min over scored levels;
        # stage-sum feasibility is replay-independent, so feas.any is a
        # true upper bound on any level's exact (ceiling-rule) outcome
        lb = B_c + np.where(has_bits, max_stage.min(axis=0), max_stage[0])
        pfeas = feas.any(axis=0)
        nonserial.sort(key=lambda ti: (not pfeas[ti], lb[ti]))
        ser = np.nonzero(serial)[0]
        if len(ser):
            si = min(ser, key=lambda ti: (not rep_feas[ti], rep_obj[ti]))
            inc_obj, inc_feas = float(rep_obj[si]), bool(rep_feas[si])
    for pos, ti in enumerate(nonserial):
        if prune:
            can_f = bool(pfeas[ti])
            bound = float(lb[ti])
            # the (~pfeas, lb) order makes both conditions monotone: the
            # first dominated tuple dominates the whole tail.  Dominated
            # means it can never rank at or near the incumbent under the
            # naive (infeasible, objective) order, whatever its replay
            # would have said
            if (inc_feas and not can_f) or (
                    (inc_feas or not can_f)
                    and bound > inc_obj * (1 + 1e-9) + 1e-300):
                for tj in nonserial[pos:]:
                    rep_obj[tj] = lb[tj]
                    rep_feas[tj] = pfeas[tj]
                n_pruned = len(nonserial) - pos
                break
        combo = combos[ti]
        bc = B_c[ti]

        def exact(li):
            lp, cp = _chain_overlaps(tables, combo, li)
            bt = 0.0
            for k in range(n_hops):
                m = max(compute[ti, k], link[li, ti, k] - lp[k],
                        compute[ti, k + 1] - cp[k])
                d = link[li, ti, k] - m
                bt += d if d >= 0 else -d
            ms = max_stage[li, ti]
            ok = bool(stage_sum[li, ti] <= T_max) and all(
                lp[k] + cp[k] <= ms * (1 + CEIL_TOL)
                for k in range(n_hops))
            return bc + bt + ms, ok

        r_obj, r_feas = exact(0)
        r_ms = max_stage[0, ti]
        if has_bits[ti]:
            for li in range(1, n_lvl):
                ms = max_stage[li, ti]
                if ms > r_ms * (1 + CEIL_TOL) or bc + ms >= r_obj:
                    continue  # acceptance impossible: obj >= B_c + max_stage
                o, fe = exact(li)
                if o < r_obj and fe >= r_feas:
                    r_obj, r_feas, r_ms = o, fe, ms
        rep_obj[ti], rep_feas[ti], rep_ms[ti] = r_obj, r_feas, r_ms
        if r_feas > inc_feas or (r_feas == inc_feas and r_obj < inc_obj):
            inc_obj, inc_feas = float(r_obj), bool(r_feas)
    n_scored = int(np.where(has_bits, n_lvl, 1).sum())
    return SweepResult(combos, rep_obj, rep_feas, n_scored, n_pruned)


def _shortlist(objective: np.ndarray, feasible: np.ndarray,
               top_k: int) -> np.ndarray:
    """Indices of the ``top_k`` best representatives by (infeasible,
    objective, sequence), plus every exact near-tie of the best — so the
    event-sim rescoring pass provably contains the naive argmin (and its
    first-seen tie-break).  Returned in sequence order."""
    order = np.lexsort((np.arange(len(objective)), objective, ~feasible))
    pick = list(order[:top_k])
    best = order[0]
    ties = np.nonzero((feasible == feasible[best])
                      & (objective <= objective[best]
                         * (1 + 1e-9) + 1e-300))[0]
    pick.extend(ties[:256])
    return np.unique(np.asarray(pick, dtype=np.int64))


def chain_shortlist(tables: PlannerTables, positions: Sequence[int],
                    n_hops: int, min_end_nodes: int, T_max: float,
                    top_k: int) -> Tuple[List[Tuple[int, ...]], int]:
    """Fast-score the whole chain sweep and return the tuples worth an
    exact event-sim rescore, in naive sweep order.  Runs the sweep with
    lower-bound pruning: dominated non-serial replays are skipped.  The
    shortlist's *tail* may then differ from the exhaustive sweep's (a
    pruned tuple ranks by its bound), but the best candidate and its
    near-tie band are always exactly scored, so the event-sim rescore
    still returns the naive argmin (see ``chain_sweep``)."""
    res = chain_sweep(tables, positions, n_hops, min_end_nodes, T_max,
                      prune=True)
    if not res.combos:
        return [], 0
    pick = _shortlist(res.objective, res.feasible, top_k)
    return [res.combos[i] for i in pick], res.n_scored


def frontier_shortlist(tables: PlannerTables,
                       candidates: Sequence[Sequence[frozenset]],
                       min_end_nodes: int, T_max: float,
                       top_k: int) -> Tuple[List[int], int]:
    """Fast-score arbitrary nested-frontier candidates (block recursion
    refinement, brute force) and return the indices worth an exact
    event-sim rescore, in candidate order."""
    graph = tables.graph
    seqs: List[int] = []
    objs: List[float] = []
    feats: List[bool] = []
    n_scored = 0
    valid_memo: Dict[frozenset, bool] = {}
    xcache: Dict[frozenset, np.ndarray] = {}
    n_lvl = len(RELAX_EXTRAS)
    for seq, fr in enumerate(candidates):
        frontiers = [frozenset(f) for f in fr]
        if len(frontiers[0]) < min_end_nodes:
            continue
        prev: frozenset = frozenset()
        ok = True
        for f in frontiers:
            valid = valid_memo.get(f)
            if valid is None:
                valid = graph.valid_end_set(f)
                valid_memo[f] = valid
            if not prev <= f or not valid:
                ok = False
                break
            prev = f
        if not ok:
            continue
        sc = _FrontierScorer(tables, frontiers, crossing_cache=xcache)
        n_hops = len(frontiers)
        if tables.hop_price is not None:
            # traced links: nominal-bandwidth busy vectors are invalid,
            # so score every level exactly from the replayed timeline
            # (ladder acceptance identical to ``_relax_bits``)
            def exact_traced(li):
                st = StageTimes.from_timeline(sc.timeline(level=li))
                fe = (st.stage_sum <= T_max) \
                    and st.satisfies_parallel_constraint()
                return st.objective(), fe, st.max_stage

            best_obj, best_feas, best_ms = exact_traced(0)
            n_scored += 1
            if sc.has_bits:
                for li in range(1, n_lvl):
                    o, fe, ms = exact_traced(li)
                    n_scored += 1
                    if o < best_obj and fe >= best_feas \
                            and ms <= best_ms * (1 + CEIL_TOL):
                        best_obj, best_feas, best_ms = o, fe, ms
            seqs.append(seq)
            objs.append(best_obj)
            feats.append(best_feas)
            continue
        max_stage = np.maximum(sc.compute.max(), sc.link.max(axis=1))  # [L]
        stage_sum = sc.compute.sum() + sc.link.sum(axis=1)             # [L]

        def exact(li):
            tl = sc.timeline(level=li)
            bc = bt = 0.0
            for k in range(n_hops):
                bc += abs(sc.compute[k] - sc.compute[k + 1])
                m = max(sc.compute[k], sc.link[li, k] - tl.link_par[k],
                        sc.compute[k + 1] - tl.compute_par[k])
                bt += abs(sc.link[li, k] - m)
            ms = max_stage[li]
            fe = bool(stage_sum[li] <= T_max) and all(
                tl.link_par[k] + tl.compute_par[k] <= ms * (1 + CEIL_TOL)
                for k in range(n_hops))
            return bc + bt + ms, fe

        best_obj, best_feas = exact(0)
        best_ms = max_stage[0]
        n_scored += n_lvl if sc.has_bits else 1
        if sc.has_bits:
            bc0 = sum(abs(sc.compute[k] - sc.compute[k + 1])
                      for k in range(n_hops))
            for li in range(1, n_lvl):
                ms = max_stage[li]
                if ms > best_ms * (1 + CEIL_TOL) or bc0 + ms >= best_obj:
                    continue  # acceptance impossible (obj >= B_c + max_stage)
                o, fe = exact(li)
                if o < best_obj and fe >= best_feas:
                    best_obj, best_feas, best_ms = o, fe, ms
        seqs.append(seq)
        objs.append(best_obj)
        feats.append(best_feas)
    if not seqs:
        return [], n_scored
    pick = _shortlist(np.asarray(objs), np.asarray(feats), top_k)
    return [seqs[i] for i in pick], n_scored
