"""Unified N-stage resource-timeline simulator.

This module is the single discrete-event core behind both the offline
partition scorer (``repro.core.schedule.evaluate_partition``) and the
task-stream executor (``repro.core.pipeline.run_pipeline``).  A
collaborative deployment is modelled as ``2n+1`` alternating *serial FIFO
resources*

    compute_0, link_0, compute_1, link_1, ..., link_{n-1}, compute_n

where ``compute_0`` is the end device, ``compute_n`` the cloud, and the
``compute_k`` in between are edge tiers; the paper's end->link->cloud
testbed is the ``n = 1`` special case.  Mapping onto the paper's
quantities (Eq. 2-6, generalized per hop ``k``):

  T_e, T_t, T_c      Eq. 2 stage busy times -> ``compute[0]``, ``link[k]``,
                     ``compute[k+1]`` (per-resource busy-interval sums).
  Eq. 3              latency budget: the serial stage-time sum must not
                     exceed T_max (checked by the partitioner).
  Eq. 4              parallel constraint: within one hop, the transmission
                     time overlapped with upstream compute (``link_par[k]``)
                     plus the downstream compute overlapped with the
                     transmission (``compute_par[k]``) cannot exceed the
                     pipeline ceiling ``max_stage``.
  Eq. 5              bubbles: B_c is the per-hop compute imbalance
                     ``|compute[k] - compute[k+1]|``; B_t the per-hop link
                     imbalance against the effective ceiling
                     ``max(compute[k], link[k]-link_par[k],
                     compute[k+1]-compute_par[k])``.
  Eq. 6              objective = sum of bubbles + max stage, computed by
                     ``repro.core.schedule.StageTimes`` from this timeline.

Two entry points:

``simulate_partitioned_task``
    One task through a partitioned ``ModelGraph``: each segment executes
    its nodes serially in topological (id) order; every edge whose
    producer and consumer live in different segments becomes a boundary
    tensor that crosses each intervening link in FIFO order (ready when
    the producer finishes, or when the previous hop delivered it).
    Arrivals are recorded **per edge** ``(u, v)`` — not per producer — so
    a producer feeding several boundary edges gates each consumer on the
    transfer it actually consumes.

``simulate_stream``
    A stream of tasks, each a ``SimPlan`` of per-segment compute
    durations and per-hop transmission durations (with optional
    intra-task overlap offsets measured by the single-task simulation),
    replayed over the same ``2n+1`` serial resources.  Per-hop links with
    a bandwidth trace re-integrate each transfer at its actual start
    time (dynamic networks, Fig. 5).

Both entry points share the same event semantics, so the partitioner
scores candidates with exactly the timeline the stream executor replays.

``simulate_multitenant_stream`` extends the stream view to *tagged*
multi-tenant arrivals: several per-tenant task streams are merged into
one admission sequence by a pluggable admission policy (FIFO /
round-robin / weighted deficit round-robin, implemented in
``repro.serving.tenancy``), gated by the shared ingress resource
(``compute_0``), and the merged stream replays over the same ``2n+1``
serial resources.  The async multi-tenant executor
(``repro.serving.tenancy.MultiTenantHopPipeline``) realizes the same
gate with event-driven ingress credits, so the two admission orders —
and therefore the two timelines — are differentially pinned by
``tests/test_tenancy.py``.

``simulate_pool_stream`` generalizes the chain to a DAG of *resource
pools*: tier ``k`` becomes ``PoolSpec`` — ``m`` replica resources with
heterogeneous speed multipliers — behind a pluggable router policy
(join-shortest-queue / power-of-two-choices / tenant-affinity, in
``repro.serving.routing``) that places each task at enqueue time in
per-stream order; a per-pool sequencer restores admission order toward
each serial hop link.  ``m = 1`` unit pools on every tier reduce
bit-identically to ``simulate_stream``, and the async pool executor
(``repro.serving.async_engine.AsyncHopPipeline`` with ``pools=``) is
differentially pinned to it by ``tests/test_pools.py``.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.costs import DeviceProfile, LinkProfile, ModelGraph
from repro.obs.trace import (BATCH_FORM, CREDIT_WAIT, ENQUEUE, EXIT_RELEASE,
                             REPLAN, ROUTE, SEQ_HOLD, SERVICE, XFER, Span)

Edge = Tuple[int, int]
Interval = Tuple[float, float]


# --------------------------------------------------------- hop-exit helpers
def occupies_compute(exit_hop: Optional[int], k: int) -> bool:
    """Does a task with this ``exit_hop`` occupy compute resource ``k``?
    ``exit_hop = e`` means the task terminates at segment ``e`` (a hop-level
    semantic probe early-exited it there); ``None`` runs the full chain."""
    return exit_hop is None or k <= exit_hop


def occupies_link(exit_hop: Optional[int], k: int) -> bool:
    """Does a task with this ``exit_hop`` occupy link resource ``k``?  A
    task exiting at segment ``e`` crosses links ``0..e-1`` only — every
    downstream link (and compute tier) is released at the exit instant."""
    return exit_hop is None or k < exit_hop


def _sorted_disjoint(iv: Sequence[Interval]) -> bool:
    return all(iv[i][0] <= iv[i][1] and
               (i + 1 == len(iv) or iv[i][1] <= iv[i + 1][0])
               for i in range(len(iv)))


def overlap_sorted_disjoint(intervals_a: Sequence[Interval],
                            intervals_b: Sequence[Interval]) -> float:
    """O(a + b) total overlap of two *sorted disjoint* interval lists —
    the shape every serial-FIFO resource timeline has (also the workhorse
    of the batched planner scorer in ``repro.core.plan_fast``)."""
    i = j = 0
    tot = 0.0
    while i < len(intervals_a) and j < len(intervals_b):
        lo = max(intervals_a[i][0], intervals_b[j][0])
        hi = min(intervals_a[i][1], intervals_b[j][1])
        if hi > lo:
            tot += hi - lo
        if intervals_a[i][1] <= intervals_b[j][1]:
            i += 1
        else:
            j += 1
    return tot


def overlap_total(intervals_a: Sequence[Interval],
                  intervals_b: Sequence[Interval]) -> float:
    """Total overlap between two lists of (start, end) busy intervals.

    The serial-FIFO resources of both simulators emit sorted disjoint
    interval lists, which take the O(a + b) merge scan; anything else
    falls back to the exact pairwise sum."""
    if _sorted_disjoint(intervals_a) and _sorted_disjoint(intervals_b):
        return overlap_sorted_disjoint(intervals_a, intervals_b)
    tot = 0.0
    for (a0, a1) in intervals_a:
        for (b0, b1) in intervals_b:
            lo, hi = max(a0, b0), min(a1, b1)
            if hi > lo:
                tot += hi - lo
    return tot


# ===================================================================== task
@dataclasses.dataclass
class TaskTimeline:
    """Resource timeline of one task through an N-segment partition.

    All per-hop tuples have length ``n_hops``; per-segment tuples have
    length ``n_hops + 1``.  Times are absolute (task starts at 0).
    """
    compute_busy: Tuple[float, ...]       # Eq. 2 per-segment busy time
    link_busy: Tuple[float, ...]          # Eq. 2 per-hop busy time
    link_par: Tuple[float, ...]           # hop tx overlapped w/ upstream compute
    compute_par: Tuple[float, ...]        # downstream compute overlapped w/ tx
    latency: float                        # end-to-end finish
    first_tx: Tuple[float, ...]           # absolute first transfer start / hop
    seg_start: Tuple[float, ...]          # absolute first compute start / segment
    next_start: Tuple[float, ...]         # absolute first downstream compute
                                          # start per hop (= seg_start[k+1])
    # raw per-resource busy intervals (one per node / transfer, in exec order)
    compute_intervals: Tuple[Tuple[Interval, ...], ...] = ()
    link_intervals: Tuple[Tuple[Interval, ...], ...] = ()

    @property
    def n_hops(self) -> int:
        return len(self.link_busy)


def simulate_partitioned_task(
        graph: ModelGraph,
        segments: Sequence[frozenset],
        hop_bits: Sequence[Dict[Edge, int]],
        devices: Sequence[DeviceProfile],
        links: Sequence[LinkProfile],
        input_bits_per_elem: int = 8) -> TaskTimeline:
    """Event-simulate one task through an ordered N-segment partition.

    ``segments`` partitions the node ids into ``n_hops + 1`` ordered sets
    (data flows strictly forward: every dependency lives in the same or an
    earlier segment).  ``hop_bits[k]`` prices the tensors crossing link
    ``k`` (missing edges default to fp32; the raw model input is priced at
    ``input_bits_per_elem`` on every hop it crosses).
    """
    n_seg = len(segments)
    assert len(devices) == n_seg and len(links) == n_seg - 1
    seg_of: Dict[int, int] = {}
    for k, seg in enumerate(segments):
        for i in seg:
            seg_of[i] = k
    seg_of[-1] = 0  # raw input lives on the end device
    for n in graph.nodes:
        assert n.id in seg_of, f"node {n.id} unassigned"
        for d in n.deps:
            assert seg_of[d] <= seg_of[n.id], \
                f"backward edge {d}->{n.id} across segments"

    compute_busy: List[float] = [0.0] * n_seg
    link_busy: List[float] = [0.0] * (n_seg - 1)
    compute_intervals: List[List[Interval]] = [[] for _ in range(n_seg)]
    link_intervals: List[List[Interval]] = [[] for _ in range(n_seg - 1)]
    first_tx: List[Optional[float]] = [None] * (n_seg - 1)
    done: Dict[int, float] = {}
    # recv[k][(u, v)]: edge (u, v) fully delivered over link k (per-edge,
    # not per-producer — see module docstring)
    recv: List[Dict[Edge, float]] = [{} for _ in range(n_seg - 1)]
    seg_finish: List[float] = [0.0] * n_seg
    link_finish: List[float] = [0.0] * (n_seg - 1)

    def edge_bits(k: int, u: int, v: int) -> float:
        if u < 0:
            return float(graph.input_elems) * input_bits_per_elem
        return float(graph.node(u).out_elems) * hop_bits[k].get((u, v), 32)

    # edges crossing each hop: produced at or before segment k, consumed after
    crossing: List[List[Edge]] = [[] for _ in range(n_seg - 1)]
    for n in graph.nodes:
        sv = seg_of[n.id]
        srcs = n.deps if n.deps else ((-1,) if sv > 0 else ())
        for d in srcs:
            for k in range(seg_of[d], sv):
                crossing[k].append((d, n.id))

    for k in range(n_seg):
        # -------- compute segment k: serial, topological (id) order --------
        t = 0.0
        for n in graph.nodes:
            if seg_of[n.id] != k:
                continue
            if k == 0:
                ready_at = 0.0
            else:
                ready_at = 0.0
                for d in n.deps:
                    ready_at = max(ready_at,
                                   done[d] if seg_of[d] == k
                                   else recv[k - 1][(d, n.id)])
                if not n.deps:
                    ready_at = recv[k - 1].get((-1, n.id), 0.0)
            dt = devices[k].layer_time(n.flops, n.util)
            start = max(t, ready_at)
            compute_intervals[k].append((start, start + dt))
            t = start + dt
            done[n.id] = t
            compute_busy[k] += dt
        seg_finish[k] = t

        # -------- link k: FIFO over the tensors crossing this hop ----------
        if k == n_seg - 1:
            break
        ready: List[Tuple[float, Edge, float]] = []
        for (u, v) in crossing[k]:
            if seg_of[u] == k:
                when = done[u] if u >= 0 else 0.0
            else:  # relayed from an earlier hop
                when = recv[k - 1][(u, v)]
            ready.append((when, (u, v), edge_bits(k, u, v)))
        ready.sort(key=lambda r: (r[0], r[1]))
        link_free = 0.0
        for (when, (u, v), bits) in ready:
            start = max(when, link_free)
            dur = links[k].transfer_time(bits, start)
            link_intervals[k].append((start, start + dur))
            if first_tx[k] is None:
                first_tx[k] = start
            link_free = start + dur
            link_busy[k] += dur
            recv[k][(u, v)] = link_free
        link_finish[k] = link_free

    latency = max(seg_finish + link_finish) if graph.nodes else 0.0
    link_par = tuple(overlap_total(link_intervals[k], compute_intervals[k])
                     for k in range(n_seg - 1))
    compute_par = tuple(overlap_total(compute_intervals[k + 1],
                                      link_intervals[k])
                        for k in range(n_seg - 1))
    # fallbacks mirror the classic semantics: with nothing to transmit on a
    # hop, "first tx" collapses to the time everything upstream finished
    ftx: List[float] = []
    upstream = 0.0
    for k in range(n_seg - 1):
        upstream = max(upstream, seg_finish[k])
        ftx.append(first_tx[k] if first_tx[k] is not None else upstream)
        upstream = max(upstream, link_finish[k])
    seg_start = tuple(min((s for s, _ in compute_intervals[k]),
                          default=(ftx[k - 1] if k else 0.0))
                      for k in range(n_seg))
    next_start = tuple(min((s for s, _ in compute_intervals[k + 1]),
                           default=ftx[k])
                       for k in range(n_seg - 1))
    return TaskTimeline(
        compute_busy=tuple(compute_busy), link_busy=tuple(link_busy),
        link_par=link_par, compute_par=compute_par, latency=latency,
        first_tx=tuple(ftx), seg_start=seg_start, next_start=next_start,
        compute_intervals=tuple(tuple(iv) for iv in compute_intervals),
        link_intervals=tuple(tuple(iv) for iv in link_intervals))


# =================================================================== stream
@dataclasses.dataclass
class SimPlan:
    """Per-task resource occupation for the stream simulator.

    ``compute`` has one duration per segment, ``tx`` one per hop.
    ``tx_offset[k]`` (if set, and smaller than ``compute[k]``) lets hop
    ``k``'s transmission start that long after segment ``k``'s compute
    started (Fig. 4 virtual-block overlap); ``rx_offset[k]`` lets segment
    ``k+1`` start that long after hop ``k``'s transmission started.

    ``exit_hop = e`` terminates the task at segment ``e`` (a hop-level
    semantic probe exited it on that tier): the task occupies compute
    resources ``0..e`` and links ``0..e-1`` and never touches anything
    downstream.  ``early_exit`` is the legacy boolean spelling of
    ``exit_hop = 0`` (task runs only segment 0) and is kept in sync:
    after normalization it is True iff the task exits before the last
    segment.

    ``t_fixed[k]`` splits segment ``k``'s service time into a per-launch
    fixed part and a per-task marginal part for continuous micro-batching
    (calibrated from the per-layer utilization attainment gap in
    ``repro.core.costs.segment_batch_split``): a batch of ``m >= 2``
    tasks occupies the tier for ``max_i t_fixed_i + sum_i t_marginal_i``
    where ``t_marginal = compute - t_fixed``.  A singleton batch costs
    exactly ``compute[k]``, so ``batch_cap = 1`` timelines are
    bit-identical to the unbatched replay by construction.  ``deadline``
    is the task's absolute staleness deadline (tenant SLO): batch
    formation never admits a follower that would push any member's
    finish past the tightest deadline in the batch."""
    compute: Tuple[float, ...]
    tx: Tuple[float, ...]
    tx_offset: Tuple[Optional[float], ...] = ()
    rx_offset: Tuple[Optional[float], ...] = ()
    early_exit: bool = False
    exit_hop: Optional[int] = None
    t_fixed: Tuple[float, ...] = ()
    deadline: Optional[float] = None

    def __post_init__(self):
        n_hops = len(self.tx)
        assert len(self.compute) == n_hops + 1, "need n_hops+1 compute stages"
        if not self.tx_offset:
            self.tx_offset = (None,) * n_hops
        if not self.rx_offset:
            self.rx_offset = (None,) * n_hops
        if not self.t_fixed:
            self.t_fixed = (0.0,) * (n_hops + 1)
        assert len(self.t_fixed) == n_hops + 1, "need n_hops+1 fixed costs"
        assert all(0.0 <= f <= c + 1e-12
                   for f, c in zip(self.t_fixed, self.compute)), \
            "t_fixed must stay within each segment's compute time"
        if self.early_exit and self.exit_hop is None:
            self.exit_hop = 0
        if self.exit_hop is not None:
            assert 0 <= self.exit_hop <= n_hops, \
                f"exit_hop {self.exit_hop} outside [0, {n_hops}]"
            if self.exit_hop == n_hops:   # "exit" at the cloud = full run
                self.exit_hop = None
        self.early_exit = self.exit_hop is not None

    @property
    def n_stages(self) -> int:
        """Number of compute segments the task actually runs."""
        return (self.exit_hop + 1) if self.exit_hop is not None \
            else len(self.compute)

    @property
    def t_marginal(self) -> Tuple[float, ...]:
        """Per-segment marginal (per-batch-member) service time."""
        return tuple(c - f for c, f in zip(self.compute, self.t_fixed))


# -------------------------------------------------- micro-batching semantics
def batched_service_time(plans: Sequence[SimPlan], k: int) -> float:
    """Tier occupancy of one micro-batch at segment ``k``.

    A singleton costs exactly its ``compute[k]`` (bit-identity with the
    unbatched replay); ``m >= 2`` members amortize the launch cost:
    ``max_i t_fixed_i[k] + sum_i (compute_i[k] - t_fixed_i[k])``.  Both
    the arithmetic simulator and the event-driven executor price batches
    through this one helper, so their float arithmetic is identical."""
    if len(plans) == 1:
        return plans[0].compute[k]
    return (max(p.t_fixed[k] for p in plans)
            + sum(p.compute[k] - p.t_fixed[k] for p in plans))


def greedy_batch_size(k: int, cap: int, s: float,
                      plans: Sequence[SimPlan],
                      ready: Sequence[float],
                      speed: float = 1.0) -> int:
    """Greedy drain-up-to-cap-or-deadline batch formation rule.

    ``plans[0]`` is the head task the worker woke up for; ``plans[1:]``
    are the tasks queued behind it in FIFO order, *snapshotted at the
    worker's wake instant* (items enqueued later never join this batch —
    the executor and the simulator must agree on the candidate set).
    ``s`` is the batch's service start; ``ready[i]`` is when task ``i``'s
    input data is ready at this tier.  Followers are admitted in FIFO
    order while (a) the cap is not exceeded, (b) the follower's data is
    ready by ``s``, and (c) the grown batch still finishes by the
    tightest deadline among its members (the head itself is never
    deadline-gated — it must run regardless).  The first failure stops
    formation, so a batch is always a FIFO prefix: batching never
    reorders tasks.

    ``speed`` scales the batch's service time for heterogeneous pool
    replicas (``PoolSpec.speeds``); the default 1.0 keeps the chain
    path's float arithmetic bit-identical (``s + 1.0 * t == s + t``)."""
    inf = float("inf")
    d0 = plans[0].deadline
    dmin = d0 if d0 is not None else inf
    n = 1
    while n < len(plans) and n < cap:
        p = plans[n]
        if ready[n] > s:
            break
        nd = min(dmin, p.deadline if p.deadline is not None else inf)
        if s + speed * batched_service_time(plans[:n + 1], k) > nd:
            break
        dmin = nd
        n += 1
    return n


@dataclasses.dataclass
class StreamResult:
    """Per-resource accounting of a simulated task stream.

    ``compute_intervals[k]`` / ``link_intervals[k]`` are the per-resource
    busy intervals (one ``(start, end)`` per task that occupied the
    resource, in admission order) — the raw timeline, exposed so an
    executor's recorded schedule can be compared against the simulator's
    interval by interval.

    ``early_exit[i]`` is True iff task ``i`` exited before the last
    segment; ``exit_hop[i]`` names the segment it terminated at (``None``
    = full pipeline).  Downstream of the exit, the task occupies nothing
    — use ``occupies_compute``/``occupies_link`` to map a resource's
    interval list back to the tasks that produced it.

    Under micro-batching a compute interval may serve several tasks at
    once: ``compute_batch_sizes[k][b]`` counts the occupying tasks served
    by ``compute_intervals[k][b]`` (consecutive in admission order).
    Empty means every interval is a singleton — the unbatched 1:1
    task-to-interval mapping.  Link transfers are never batched, so link
    intervals always stay 1:1."""
    arrivals: List[float]
    done: List[float]
    early_exit: List[bool]
    makespan: float
    compute_busy: Tuple[float, ...]
    link_busy: Tuple[float, ...]
    compute_intervals: Tuple[Tuple[Interval, ...], ...] = ()
    link_intervals: Tuple[Tuple[Interval, ...], ...] = ()
    exit_hop: List[Optional[int]] = dataclasses.field(default_factory=list)
    compute_batch_sizes: Tuple[Tuple[int, ...], ...] = ()

    def __post_init__(self):
        if not self.exit_hop:
            self.exit_hop = [0 if e else None for e in self.early_exit]


def simulate_stream(plans: Sequence[SimPlan],
                    arrivals: Sequence[float],
                    links: Optional[Sequence[Optional[LinkProfile]]] = None,
                    batch_caps: Optional[Sequence[int]] = None,
                    sink=None,
                    ingress_enqueues: Optional[Sequence[float]] = None,
                    migrate=None
                    ) -> StreamResult:
    """Replay a task stream over the ``2n+1`` serial resources.

    Tasks are admitted in order; every resource is serial FIFO.  If
    ``links[k]`` carries a bandwidth trace, hop ``k``'s transfers are
    re-integrated at their actual start times (the planned duration is
    interpreted as a bit volume at the link's nominal bandwidth).

    A task with ``exit_hop = e`` terminates at segment ``e``: it runs
    compute ``0..e`` and links ``0..e-1`` and releases every downstream
    resource at the exit instant (hop-level semantic early exit).

    ``batch_caps[k]`` (one per compute segment) enables continuous
    micro-batching on tier ``k``: a free worker drains the tasks queued
    at its wake instant into one batch, bounded by the cap and by the
    members' staleness deadlines (``greedy_batch_size``), and the tier
    is occupied once for ``batched_service_time``.  ``None`` — or caps
    of all ones — replays the classic one-task-per-slot timeline.

    ``sink`` is an optional trace sink (``repro.obs.trace``): when set,
    every enqueue/service/transfer/exit event is emitted as a span with
    exactly the instants of this timeline — the async executor emits the
    same spans, so traces are differentially pinned like results.  Every
    emission is guarded by ``sink is not None`` (zero cost disabled).
    ``ingress_enqueues[i]`` overrides the *reported* tier-0 enqueue
    instant of task ``i`` (the multi-tenant gate dispatches at ``t_d >=
    arrival``; the chain timeline is unaffected because the credit gate
    never delays a task past ``max(arrival, free_0)``).

    ``migrate(i, k, tx_ready) -> Optional[SimPlan]`` is the online
    re-planning hook (``repro.scenarios``): it is consulted once per
    task per hop, at the instant the task's hop-``k`` boundary data is
    ready to transmit.  Returning a plan switches task ``i`` to it for
    the remainder of its pipeline — the hop-``k`` transfer (volume,
    offsets) and all downstream segments are priced under the new plan,
    while everything already replayed stays charged to the old one (a
    task completes its current segment under the old plan, then
    continues under the new cut/bits).  The hook's arguments depend
    only on the task's own timeline, never on a clock, so the async
    executor reaches identical migration decisions; the switch is
    recorded as a ``replan`` span.  The returned plan must preserve
    the task's hop count and exit hop.  Migration composes with the
    unbatched chain path only (no micro-batching, no pools)."""
    assert plans, "empty stream"
    if batch_caps is not None and any(c > 1 for c in batch_caps):
        assert migrate is None, \
            "plan migration composes with the unbatched chain path only"
        return _simulate_stream_batched(plans, arrivals, links, batch_caps,
                                        sink=sink,
                                        ingress_enqueues=ingress_enqueues)
    n_hops = len(plans[0].tx)
    n_seg = n_hops + 1
    compute_free = [0.0] * n_seg
    link_free = [0.0] * n_hops
    compute_busy = [0.0] * n_seg
    link_busy = [0.0] * n_hops
    compute_iv: List[List[Interval]] = [[] for _ in range(n_seg)]
    link_iv: List[List[Interval]] = [[] for _ in range(n_hops)]
    done: List[float] = []
    exits: List[bool] = []
    exit_hops: List[Optional[int]] = []
    enq_acc = 0.0
    for i, (p, arr) in enumerate(zip(plans, arrivals)):
        assert len(p.tx) == n_hops, "mixed hop counts in one stream"
        e = p.exit_hop if p.exit_hop is not None else n_hops
        s = max(arr, compute_free[0])
        d = s + p.compute[0]
        compute_free[0] = d
        compute_busy[0] += p.compute[0]
        compute_iv[0].append((s, d))
        exits.append(p.exit_hop is not None)
        exit_hops.append(p.exit_hop)
        if sink is not None:
            enq_acc = arr if arr > enq_acc else enq_acc
            e0 = ingress_enqueues[i] if ingress_enqueues is not None \
                else enq_acc
            sink.span(Span(ENQUEUE, ("compute", 0), e0, e0, task=i))
            sink.span(Span(SERVICE, ("compute", 0, 0), s, d, task=i,
                           tasks=(i,), ready=arr, batch=1))
        if e == 0:
            done.append(d)
            if sink is not None:
                sink.span(Span(EXIT_RELEASE, ("compute", 0, 0), d, d,
                               task=i, hop=0))
            continue
        prev_start, prev_done = s, d
        for k in range(e):
            off = p.tx_offset[k]
            tx_ready = prev_done if off is None or off >= p.compute[k] \
                else prev_start + off
            if migrate is not None:
                newp = migrate(i, k, tx_ready)
                if newp is not None:
                    assert len(newp.tx) == n_hops \
                        and newp.exit_hop == p.exit_hop, \
                        "migrated plan must preserve hop count and exit hop"
                    p = newp
                    if sink is not None:
                        sink.span(Span(REPLAN, ("link", k), tx_ready,
                                       tx_ready, task=i, hop=k))
            t_start = max(tx_ready, link_free[k])
            t_dur = p.tx[k]
            lk = links[k] if links is not None and k < len(links) else None
            if lk is not None and lk.trace is not None and t_dur > 0:
                # re-integrate the same bit volume under the live trace
                bits = t_dur * lk.bandwidth_bps
                t_dur = lk.transfer_time(bits, t_start)
            t_done = t_start + t_dur
            link_free[k] = t_done
            link_busy[k] += t_dur
            link_iv[k].append((t_start, t_done))
            roff = p.rx_offset[k]
            c_ready = t_done if roff is None \
                else max(t_start + roff, tx_ready)
            c_start = max(c_ready, compute_free[k + 1])
            # downstream compute cannot finish before all data has arrived
            c_done = max(c_start + p.compute[k + 1], t_done)
            compute_free[k + 1] = c_done
            compute_busy[k + 1] += p.compute[k + 1]
            compute_iv[k + 1].append((c_start, c_start + p.compute[k + 1]))
            if sink is not None:
                sink.span(Span(XFER, ("link", k), t_start, t_done,
                               task=i, ready=tx_ready))
                # next-tier enqueue = the executor link worker's put
                # instant (partial-forward under an rx offset)
                tq = t_start + min(max(c_ready - t_start, 0.0), t_dur)
                sink.span(Span(ENQUEUE, ("compute", k + 1), tq, tq, task=i))
                sink.span(Span(SERVICE, ("compute", k + 1, 0), c_start,
                               c_start + p.compute[k + 1], task=i,
                               tasks=(i,), ready=c_ready, batch=1))
            prev_start, prev_done = c_start, c_done
        done.append(prev_done)
        if sink is not None and p.exit_hop is not None:
            sink.span(Span(EXIT_RELEASE, ("compute", e, 0), prev_done,
                           prev_done, task=i, hop=e))
    arrivals = list(arrivals[:len(done)])
    makespan = max(done) - min(arrivals)
    return StreamResult(arrivals=arrivals, done=done, early_exit=exits,
                        makespan=makespan,
                        compute_busy=tuple(compute_busy),
                        link_busy=tuple(link_busy),
                        compute_intervals=tuple(tuple(iv) for iv in compute_iv),
                        link_intervals=tuple(tuple(iv) for iv in link_iv),
                        exit_hop=exit_hops)


def _simulate_stream_batched(
        plans: Sequence[SimPlan],
        arrivals: Sequence[float],
        links: Optional[Sequence[Optional[LinkProfile]]],
        batch_caps: Sequence[int],
        sink=None,
        ingress_enqueues: Optional[Sequence[float]] = None) -> StreamResult:
    """Staged replay of ``simulate_stream`` with per-tier micro-batching.

    Tiers are replayed one at a time (tier 0, link 0, tier 1, ...) —
    legal because tasks flow strictly forward, so a tier's inputs are
    fully determined by the previous link's outputs.  Each compute tier
    drains its pending tasks with the same greedy
    drain-up-to-cap-or-deadline rule the event-driven workers in
    ``repro.serving.async_engine`` apply: batch membership is decided
    against the queue state at the worker's *wake* instant, service is
    priced by ``batched_service_time``, and exit-hop members leave the
    batch at their tier.  Members of a multi-task batch forward serially
    (the batch launch owns the tier until it completes, so the Fig. 4
    intra-task overlap offsets only apply to singleton batches).  With
    every cap at 1 the replay uses the same float expressions as the
    classic interleaved loop."""
    n_hops = len(plans[0].tx)
    n_seg = n_hops + 1
    caps = [int(batch_caps[k]) if k < len(batch_caps) else 1
            for k in range(n_seg)]
    assert all(c >= 1 for c in caps), "batch caps must be >= 1"
    for p in plans:
        assert len(p.tx) == n_hops, "mixed hop counts in one stream"
    # tier-0 batches gather same-instant arrivals, so batching the ingress
    # tier needs arrival order = admission order (deeper tiers see
    # monotone hand-off instants by construction, any arrival order)
    assert caps[0] <= 1 or all(
        a0 <= a1 for a0, a1 in zip(arrivals, arrivals[1:])), \
        "batching tier 0 needs non-decreasing arrivals (admission order)"
    compute_busy = [0.0] * n_seg
    link_busy = [0.0] * n_hops
    compute_iv: List[List[Interval]] = [[] for _ in range(n_seg)]
    comp_bs: List[List[int]] = [[] for _ in range(n_seg)]
    link_iv: List[List[Interval]] = [[] for _ in range(n_hops)]
    done: List[float] = [0.0] * len(plans)
    link_free = [0.0] * n_hops

    # pending task state entering the current tier, FIFO by admission:
    # (task index, queue-enqueue instant, input-ready instant, data-done)
    pend: List[Tuple[int, float, float, float]] = []
    enq = 0.0
    for i, arr in enumerate(arrivals):
        enq = arr if arr > enq else enq   # the admitter is serial
        pend.append((i, enq, float(arr), float(arr)))
        if sink is not None:
            e0 = ingress_enqueues[i] if ingress_enqueues is not None else enq
            sink.span(Span(ENQUEUE, ("compute", 0), e0, e0, task=i))

    for k in range(n_seg):
        cap = caps[k]
        free = 0.0
        nxt: List[Tuple[int, float]] = []   # (task index, tx_ready) -> link k
        i = 0
        while i < len(pend):
            idx0, enq0, ready0, dd0 = pend[i]
            wake = max(enq0, free)
            s = max(ready0, wake)
            n_b = 1
            if cap > 1:
                # candidate set = FIFO queue snapshot at the wake instant
                # (enqueue instants are non-decreasing, so it is a prefix)
                j = i + 1
                while j < len(pend) and pend[j][1] <= wake:
                    j += 1
                cand = pend[i:j]
                n_b = greedy_batch_size(
                    k, cap, s, [plans[e[0]] for e in cand],
                    [e[2] for e in cand])
            batch = pend[i:i + n_b]
            i += n_b
            if n_b == 1:
                p = plans[idx0]
                comp = p.compute[k]
                compute_busy[k] += comp
                compute_iv[k].append((s, s + comp))
                comp_bs[k].append(1)
                if sink is not None:
                    sink.span(Span(SERVICE, ("compute", k, 0), s, s + comp,
                                   task=idx0, tasks=(idx0,), ready=ready0,
                                   batch=1))
                fin = max(s + comp, dd0)
                free = fin
                if k == n_hops or (p.exit_hop is not None
                                   and k >= p.exit_hop):
                    done[idx0] = fin
                    if sink is not None and p.exit_hop is not None:
                        sink.span(Span(EXIT_RELEASE, ("compute", k, 0),
                                       fin, fin, task=idx0, hop=p.exit_hop))
                else:
                    off = p.tx_offset[k]
                    tx_ready = fin if off is None or off >= comp else s + off
                    nxt.append((idx0, tx_ready))
                continue
            dur = batched_service_time([plans[e[0]] for e in batch], k)
            compute_busy[k] += dur
            compute_iv[k].append((s, s + dur))
            comp_bs[k].append(n_b)
            if sink is not None:
                sink.span(Span(SERVICE, ("compute", k, 0), s, s + dur,
                               task=idx0,
                               tasks=tuple(e[0] for e in batch),
                               ready=ready0, batch=n_b))
                for (idx_m, _, ready_m, _) in batch[1:]:
                    if s > ready_m:
                        sink.span(Span(BATCH_FORM, ("compute", k, 0),
                                       ready_m, s, task=idx_m))
            end = s + dur
            fin = end
            for (idx_m, _, _, dd_m) in batch:
                p = plans[idx_m]
                fin = max(end, dd_m)   # data-done gates each completion
                if k == n_hops or (p.exit_hop is not None
                                   and k >= p.exit_hop):
                    done[idx_m] = fin
                    if sink is not None and p.exit_hop is not None:
                        sink.span(Span(EXIT_RELEASE, ("compute", k, 0),
                                       fin, fin, task=idx_m, hop=p.exit_hop))
                else:
                    nxt.append((idx_m, fin))
            free = fin

        if k == n_hops:
            break
        new_pend: List[Tuple[int, float, float, float]] = []
        for (idx, tx_ready) in nxt:
            p = plans[idx]
            t_start = max(tx_ready, link_free[k])
            t_dur = p.tx[k]
            lk = links[k] if links is not None and k < len(links) else None
            if lk is not None and lk.trace is not None and t_dur > 0:
                bits = t_dur * lk.bandwidth_bps
                t_dur = lk.transfer_time(bits, t_start)
            t_done = t_start + t_dur
            link_free[k] = t_done
            link_busy[k] += t_dur
            link_iv[k].append((t_start, t_done))
            roff = p.rx_offset[k]
            c_ready = t_done if roff is None \
                else max(t_start + roff, tx_ready)
            # the task reaches the next tier's queue the moment enough of
            # the tensor is across — the same instant (same float
            # expression) the executor's link worker performs its put
            fwd = min(max(c_ready - t_start, 0.0), t_dur)
            new_pend.append((idx, t_start + fwd, c_ready, t_done))
            if sink is not None:
                sink.span(Span(XFER, ("link", k), t_start, t_done,
                               task=idx, ready=tx_ready))
                sink.span(Span(ENQUEUE, ("compute", k + 1), t_start + fwd,
                               t_start + fwd, task=idx))
        pend = new_pend

    arr_list = list(arrivals)
    makespan = max(done) - min(arr_list)
    return StreamResult(arrivals=arr_list, done=done,
                        early_exit=[p.exit_hop is not None for p in plans],
                        makespan=makespan,
                        compute_busy=tuple(compute_busy),
                        link_busy=tuple(link_busy),
                        compute_intervals=tuple(tuple(iv) for iv in compute_iv),
                        link_intervals=tuple(tuple(iv) for iv in link_iv),
                        exit_hop=[p.exit_hop for p in plans],
                        compute_batch_sizes=tuple(tuple(b)
                                                  for b in comp_bs))


# ============================================================ multi-tenant
TenantSlot = Tuple[int, int]  # (tenant index, per-tenant task index)


def multitenant_admission_order(
        plans: Sequence[Sequence[SimPlan]],
        arrivals: Sequence[Sequence[float]],
        policy,
        sink=None,
        return_enqueues: bool = False):
    """Merge per-tenant FIFO streams into one global admission sequence.

    Admission is gated by the shared ingress resource (``compute_0``):
    each dispatch decision happens at ``t_d = max(free_0, earliest
    pending arrival)``, the *candidates* are the tenants whose head task
    has arrived by ``t_d``, and ``policy.pick(candidates, heads)``
    chooses among them (``heads[t] = (arrival, per-tenant index,
    SimPlan)``).  Within a tenant, tasks are admitted strictly in
    arrival (index) order — the policy only interleaves *across*
    tenants.

    ``policy`` is any object with ``reset(n_tenants)`` and
    ``pick(candidates, heads) -> tenant`` (the admission schedulers live
    in ``repro.serving.tenancy``; the policy state machine is shared
    between this arithmetic gate and the executor's event-driven ingress
    credits, so the differential harness pins the *gating semantics*,
    not the policy code).

    ``sink`` emits a ``credit_wait`` span per dispatch held past its
    task's arrival (the executor's dispatcher emits the same span at its
    put instant).  ``return_enqueues=True`` additionally returns the
    per-slot dispatch instants ``t_d`` (the true tier-0 enqueue times,
    fed to ``simulate_stream(ingress_enqueues=...)`` for tracing).
    """
    n_t = len(plans)
    assert len(arrivals) == n_t
    for t in range(n_t):
        assert len(plans[t]) == len(arrivals[t]), f"tenant {t} length mismatch"
        assert all(a0 <= a1 for a0, a1 in zip(arrivals[t], arrivals[t][1:])), \
            f"tenant {t} arrivals must be non-decreasing"
    total = sum(len(p) for p in plans)
    heads = [0] * n_t
    free0 = 0.0
    order: List[TenantSlot] = []
    enqueues: List[float] = []
    policy.reset(n_t)
    while len(order) < total:
        pend = [t for t in range(n_t) if heads[t] < len(plans[t])]
        t_min = min(arrivals[t][heads[t]] for t in pend)
        t_d = max(free0, t_min)
        cands = [t for t in pend if arrivals[t][heads[t]] <= t_d]
        info = {t: (arrivals[t][heads[t]], heads[t], plans[t][heads[t]])
                for t in cands}
        t = policy.pick(cands, info)
        assert t in info, f"policy picked non-candidate tenant {t}"
        i = heads[t]
        heads[t] += 1
        if sink is not None and t_d > arrivals[t][i]:
            sink.span(Span(CREDIT_WAIT, ("compute", 0), arrivals[t][i],
                           t_d, task=len(order)))
        order.append((t, i))
        enqueues.append(t_d)
        free0 = max(arrivals[t][i], free0) + plans[t][i].compute[0]
    return (order, enqueues) if return_enqueues else order


@dataclasses.dataclass
class MultiTenantStreamResult:
    """A merged multi-tenant timeline plus its tenant tagging.

    ``stream`` is the merged-stream result in admission order;
    ``order[j]`` names the tenant and per-tenant task index occupying
    global slot ``j``.  ``n_tenants`` is the declared tenant count (not
    derived from ``order`` — a tenant that admitted zero tasks still
    counts).  Per-resource busy intervals follow the same slot order
    (a resource's interval list only contains the slots that occupy it —
    a task exiting at segment ``e`` occupies compute ``0..e`` and links
    ``0..e-1``; see ``occupies_compute``/``occupies_link``), so an
    executor's recorded multi-tenant schedule can be compared per tenant
    as well as per resource."""
    stream: StreamResult
    order: Tuple[TenantSlot, ...]
    n_tenants: int = 0

    def tenant_slots(self, tenant: int) -> List[int]:
        """Global slot indices occupied by ``tenant``, in admission
        (= per-tenant FIFO) order."""
        return [j for j, (t, _) in enumerate(self.order) if t == tenant]

    def tenant_view(self, tenant: int
                    ) -> Tuple[List[float], List[float], List[bool]]:
        """``(arrivals, done, early_exit)`` of one tenant's tasks, in
        per-tenant order."""
        s = self.stream
        slots = self.tenant_slots(tenant)
        return ([s.arrivals[j] for j in slots], [s.done[j] for j in slots],
                [s.early_exit[j] for j in slots])

    def tenant_exit_hops(self, tenant: int) -> List[Optional[int]]:
        """Per-task exit hops of one tenant, in per-tenant order."""
        return [self.stream.exit_hop[j] for j in self.tenant_slots(tenant)]

    def tenant_latencies(self, tenant: int) -> List[float]:
        arr, done, _ = self.tenant_view(tenant)
        return [d - a for a, d in zip(arr, done)]


def simulate_multitenant_stream(
        plans: Sequence[Sequence[SimPlan]],
        arrivals: Sequence[Sequence[float]],
        policy,
        links: Optional[Sequence[Optional[LinkProfile]]] = None,
        batch_caps: Optional[Sequence[int]] = None,
        sink=None,
        migrate=None
        ) -> MultiTenantStreamResult:
    """Replay tagged multi-tenant task streams over the shared ``2n+1``
    resources: compute the policy's admission order (gated by the
    ingress resource), then replay the merged stream with
    ``simulate_stream``.  This is the reference timeline the async
    multi-tenant executor is pinned to.

    ``batch_caps`` enables per-tier micro-batching on the merged stream.
    The ingress tier's cap is forced to 1: multi-tenant admission is
    credit-gated one task at a time (the dispatcher holds the next task
    until ``compute_0`` frees), so the ingress queue never holds more
    than one task and batching there would diverge from the admission
    gate both engines implement."""
    order, enqueues = multitenant_admission_order(plans, arrivals, policy,
                                                  sink=sink,
                                                  return_enqueues=True)
    assert order, "empty multi-tenant stream"
    merged_plans = [plans[t][i] for (t, i) in order]
    merged_arr = [arrivals[t][i] for (t, i) in order]
    if batch_caps is not None:
        batch_caps = [1] + [int(c) for c in batch_caps[1:]]
    res = simulate_stream(merged_plans, merged_arr, links=links,
                          batch_caps=batch_caps, sink=sink,
                          ingress_enqueues=enqueues, migrate=migrate)
    return MultiTenantStreamResult(stream=res, order=tuple(order),
                                   n_tenants=len(plans))


# ============================================================ resource pools
@dataclasses.dataclass(frozen=True)
class PoolSpec:
    """One tier as a pool of ``m = len(speeds)`` replica resources.

    ``speeds[r]`` is replica ``r``'s service-time multiplier: a plan's
    segment-``k`` occupation on replica ``r`` costs
    ``speeds[r] * plan.compute[k]`` (so 1.0 is the chain's reference
    device, 2.0 a half-speed replica, 0.5 a double-speed one).  A pool
    of one unit-speed replica is exactly the chain's serial resource —
    ``simulate_pool_stream`` over all-``m=1`` unit pools is bit-identical
    to ``simulate_stream`` (tested)."""
    speeds: Tuple[float, ...] = (1.0,)

    def __post_init__(self):
        object.__setattr__(self, "speeds",
                           tuple(float(s) for s in self.speeds))
        assert self.speeds, "a pool needs at least one replica"
        assert all(s > 0.0 for s in self.speeds), \
            "replica speed multipliers must be positive"

    @property
    def m(self) -> int:
        return len(self.speeds)


def as_pools(pools, n_seg: int) -> Tuple[PoolSpec, ...]:
    """Normalize a per-tier pool description into ``PoolSpec`` tuples.

    Each entry may be a ``PoolSpec``, an ``int`` replica count (unit
    speeds), or a sequence of speed multipliers.  Missing tail entries
    default to a single unit-speed replica (the chain resource)."""
    out: List[PoolSpec] = []
    for k in range(n_seg):
        p = pools[k] if pools is not None and k < len(pools) else 1
        if isinstance(p, PoolSpec):
            out.append(p)
        elif isinstance(p, int):
            assert p >= 1, "replica count must be >= 1"
            out.append(PoolSpec(speeds=(1.0,) * p))
        else:
            out.append(PoolSpec(speeds=tuple(float(s) for s in p)))
    return tuple(out)


@dataclasses.dataclass
class PoolStreamResult:
    """Per-replica accounting of a stream replayed over resource pools.

    The tier-level lists of ``StreamResult`` split per replica:
    ``replica_intervals[k][r]`` / ``replica_busy[k][r]`` /
    ``replica_batch_sizes[k][r]`` describe replica ``r`` of tier ``k``
    (links stay serial, one per hop).  ``routes[i][k]`` names the replica
    task ``i`` ran on at tier ``k`` (``None`` = the task never reached
    that tier, i.e. it exited upstream).  ``as_stream_result()`` merges
    the per-replica timelines back into the tier-level ``StreamResult``
    shape for metric code that does not care about placement."""
    arrivals: List[float]
    done: List[float]
    early_exit: List[bool]
    exit_hop: List[Optional[int]]
    makespan: float
    link_busy: Tuple[float, ...]
    link_intervals: Tuple[Tuple[Interval, ...], ...]
    replica_busy: Tuple[Tuple[float, ...], ...]
    replica_intervals: Tuple[Tuple[Tuple[Interval, ...], ...], ...]
    replica_batch_sizes: Tuple[Tuple[Tuple[int, ...], ...], ...]
    routes: Tuple[Tuple[Optional[int], ...], ...]
    pools: Tuple[PoolSpec, ...] = ()

    @property
    def compute_busy(self) -> Tuple[float, ...]:
        """Tier-level busy time: sum over the tier's replicas."""
        return tuple(sum(rb) for rb in self.replica_busy)

    def as_stream_result(self) -> StreamResult:
        """Tier-level view: per-tier intervals merged across replicas in
        start-time order (stable by replica index), batch sizes carried
        along; emitted batch sizes only when some batch held > 1 task,
        matching ``simulate_stream``'s empty-means-singletons convention."""
        comp_iv: List[Tuple[Interval, ...]] = []
        comp_bs: List[Tuple[int, ...]] = []
        for k in range(len(self.replica_intervals)):
            tagged = []
            for r, ivs in enumerate(self.replica_intervals[k]):
                bss = self.replica_batch_sizes[k][r]
                for iv, bs in zip(ivs, bss):
                    tagged.append((iv[0], iv[1], r, bs))
            tagged.sort(key=lambda t: (t[0], t[1], t[2]))
            comp_iv.append(tuple((t[0], t[1]) for t in tagged))
            comp_bs.append(tuple(t[3] for t in tagged))
        batched = any(b > 1 for bs in comp_bs for b in bs)
        return StreamResult(
            arrivals=list(self.arrivals), done=list(self.done),
            early_exit=list(self.early_exit), makespan=self.makespan,
            compute_busy=self.compute_busy, link_busy=self.link_busy,
            compute_intervals=tuple(comp_iv),
            link_intervals=self.link_intervals,
            exit_hop=list(self.exit_hop),
            compute_batch_sizes=tuple(comp_bs) if batched else ())


def simulate_pool_stream(plans: Sequence[SimPlan],
                         arrivals: Sequence[float],
                         pools,
                         router,
                         links: Optional[Sequence[Optional[LinkProfile]]] = None,
                         batch_caps: Optional[Sequence[int]] = None,
                         tenants: Optional[Sequence[Optional[int]]] = None,
                         enqueues: Optional[Sequence[float]] = None,
                         sink=None
                         ) -> PoolStreamResult:
    """Replay a task stream over a DAG of per-tier *resource pools*.

    Generalizes ``simulate_stream``: tier ``k`` is ``pools[k].m`` replica
    resources (heterogeneous ``speeds`` allowed) behind a router; links
    stay serial FIFO.  ``router`` is any object with ``reset(pools)`` and
    ``route(k, ready, compute, tenant) -> replica`` (the policies live in
    ``repro.serving.routing``; like the admission policies, the state
    machine is shared with the executor so the differential harness pins
    the routing *semantics*).  Routing decisions are made at
    enqueue/arrival time in per-stream order, and router state is kept
    strictly per tier, so the executor's interleaving of tiers in wall
    time reaches identical decisions to this tier-by-tier staged replay.

    Per tier the staged replay is: (1) *dispatch* — route every pending
    task, in admission order, to a replica; (2) *replica replay* — each
    replica drains its own FIFO sub-queue under the chain's batching rule
    (``greedy_batch_size`` with the replica's ``speed``); (3)
    *sequencer* — completed tasks are forwarded to the hop link in
    admission order, each at the running max of the release instants so
    far (the executor's per-pool sequencer worker realizes the same
    merge); (4) *link* — the serial hop link replays exactly as in
    ``simulate_stream``.  With every pool at ``m = 1`` and unit speed,
    every expression reduces to the chain path's — bit-identical
    timelines (tested).

    ``tenants[i]`` tags task ``i`` for tenant-affinity routing;
    ``enqueues[i]`` overrides task ``i``'s tier-0 enqueue instant (used
    by the credit-gated multi-tenant admission; both must be
    non-decreasing — admission order)."""
    assert plans, "empty stream"
    n_hops = len(plans[0].tx)
    n_seg = n_hops + 1
    pools = as_pools(pools, n_seg)
    caps = [int(batch_caps[k]) if batch_caps is not None
            and k < len(batch_caps) else 1 for k in range(n_seg)]
    assert all(c >= 1 for c in caps), "batch caps must be >= 1"
    for p in plans:
        assert len(p.tx) == n_hops, "mixed hop counts in one stream"
    if tenants is None:
        tenants = [None] * len(plans)
    assert len(tenants) == len(plans)
    if enqueues is None:
        assert all(a0 <= a1 for a0, a1 in zip(arrivals, arrivals[1:])), \
            "pool streams need non-decreasing arrivals (admission order)"
    else:
        assert len(enqueues) == len(plans)
        assert all(e0 <= e1 for e0, e1 in zip(enqueues, enqueues[1:])), \
            "enqueue instants must be non-decreasing (admission order)"
    router.reset(pools)

    replica_busy: List[List[float]] = [[0.0] * p.m for p in pools]
    replica_iv: List[List[List[Interval]]] = \
        [[[] for _ in range(p.m)] for p in pools]
    replica_bs: List[List[List[int]]] = \
        [[[] for _ in range(p.m)] for p in pools]
    link_busy = [0.0] * n_hops
    link_iv: List[List[Interval]] = [[] for _ in range(n_hops)]
    link_free = [0.0] * n_hops
    done: List[float] = [0.0] * len(plans)
    routes: List[List[Optional[int]]] = [[None] * n_seg for _ in plans]

    # pending task state entering the current tier, FIFO by admission:
    # (task index, queue-enqueue instant, input-ready instant, data-done)
    pend: List[Tuple[int, float, float, float]] = []
    enq = 0.0
    for i, arr in enumerate(arrivals):
        if enqueues is not None:
            enq = float(enqueues[i])
        else:
            enq = arr if arr > enq else enq   # the admitter is serial
        pend.append((i, enq, float(arr), float(arr)))
        if sink is not None:
            sink.span(Span(ENQUEUE, ("compute", 0), enq, enq, task=i))

    for k in range(n_seg):
        cap = caps[k]
        m = pools[k].m
        speeds = pools[k].speeds
        # ---- dispatch: the pool's router assigns every pending task to a
        # replica, in admission order (the executor's dispatcher worker
        # makes the same calls, in the same order, on the same state)
        assign: List[List[Tuple[int, float, float, float]]] = \
            [[] for _ in range(m)]
        for seq_j, ent in enumerate(pend):
            r = router.route(k, ent[2], plans[ent[0]].compute[k],
                             tenants[ent[0]])
            assert 0 <= r < m, f"router placed task on replica {r} of {m}"
            routes[ent[0]][k] = r
            assign[r].append(ent)
            if sink is not None:
                sink.span(Span(ROUTE, ("compute", k, r), ent[2], ent[2],
                               task=ent[0], ready=ent[2], replica=r,
                               seq=seq_j))
        # ---- replica replay: each replica drains its own FIFO sub-queue
        # under the chain's drain-up-to-cap-or-deadline batching rule
        # release[idx] = (release instant, tx_ready | None if terminal)
        release: Dict[int, Tuple[float, Optional[float]]] = {}
        for r in range(m):
            speed = speeds[r]
            sub = assign[r]
            free = 0.0
            i = 0
            while i < len(sub):
                idx0, enq0, ready0, dd0 = sub[i]
                wake = max(enq0, free)
                s = max(ready0, wake)
                n_b = 1
                if cap > 1:
                    j = i + 1
                    while j < len(sub) and sub[j][1] <= wake:
                        j += 1
                    cand = sub[i:j]
                    n_b = greedy_batch_size(
                        k, cap, s, [plans[e[0]] for e in cand],
                        [e[2] for e in cand], speed=speed)
                batch = sub[i:i + n_b]
                i += n_b
                if n_b == 1:
                    p = plans[idx0]
                    comp = speed * p.compute[k]
                    replica_busy[k][r] += comp
                    replica_iv[k][r].append((s, s + comp))
                    replica_bs[k][r].append(1)
                    if sink is not None:
                        sink.span(Span(SERVICE, ("compute", k, r), s,
                                       s + comp, task=idx0, tasks=(idx0,),
                                       ready=ready0, batch=1))
                    fin = max(s + comp, dd0)
                    free = fin
                    if k == n_hops or (p.exit_hop is not None
                                       and k >= p.exit_hop):
                        done[idx0] = fin
                        release[idx0] = (fin, None)
                        if sink is not None and p.exit_hop is not None:
                            sink.span(Span(EXIT_RELEASE, ("compute", k, r),
                                           fin, fin, task=idx0,
                                           hop=p.exit_hop))
                    else:
                        off = p.tx_offset[k]
                        tx_ready = fin if off is None or off >= comp \
                            else s + off
                        release[idx0] = (tx_ready, tx_ready)
                    continue
                dur = speed * batched_service_time(
                    [plans[e[0]] for e in batch], k)
                replica_busy[k][r] += dur
                replica_iv[k][r].append((s, s + dur))
                replica_bs[k][r].append(n_b)
                if sink is not None:
                    sink.span(Span(SERVICE, ("compute", k, r), s, s + dur,
                                   task=idx0,
                                   tasks=tuple(e[0] for e in batch),
                                   ready=ready0, batch=n_b))
                    for (idx_m, _, ready_m, _) in batch[1:]:
                        if s > ready_m:
                            sink.span(Span(BATCH_FORM, ("compute", k, r),
                                           ready_m, s, task=idx_m))
                end = s + dur
                fin = end
                for (idx_m, _, _, dd_m) in batch:
                    p = plans[idx_m]
                    fin = max(end, dd_m)   # data-done gates each completion
                    if k == n_hops or (p.exit_hop is not None
                                       and k >= p.exit_hop):
                        done[idx_m] = fin
                        release[idx_m] = (fin, None)
                        if sink is not None and p.exit_hop is not None:
                            sink.span(Span(EXIT_RELEASE, ("compute", k, r),
                                           fin, fin, task=idx_m,
                                           hop=p.exit_hop))
                    else:
                        release[idx_m] = (fin, fin)
                free = fin

        if k == n_hops:
            break
        # ---- sequencer: restore admission order toward the serial link.
        # A task can go on the wire only once every earlier task has been
        # released by its replica (forwarded or declared terminal), so its
        # hand-off instant is the running max of release instants — on an
        # m=1 unit pool releases are already monotone and this is the
        # identity (bitwise chain equivalence).
        fwd = 0.0
        nxt: List[Tuple[int, float, float]] = []
        for ent in pend:
            rel, tx_ready = release[ent[0]]
            fwd = rel if rel > fwd else fwd
            if tx_ready is not None:
                nxt.append((ent[0], tx_ready, fwd))
                if sink is not None and fwd > rel:
                    sink.span(Span(SEQ_HOLD, ("link", k), rel, fwd,
                                   task=ent[0]))
        # ---- link k: serial FIFO, same expressions as simulate_stream
        new_pend: List[Tuple[int, float, float, float]] = []
        for (idx, tx_ready, fwd_j) in nxt:
            p = plans[idx]
            t_start = max(tx_ready, fwd_j, link_free[k])
            t_dur = p.tx[k]
            lk = links[k] if links is not None and k < len(links) else None
            if lk is not None and lk.trace is not None and t_dur > 0:
                bits = t_dur * lk.bandwidth_bps
                t_dur = lk.transfer_time(bits, t_start)
            t_done = t_start + t_dur
            link_free[k] = t_done
            link_busy[k] += t_dur
            link_iv[k].append((t_start, t_done))
            roff = p.rx_offset[k]
            c_ready = t_done if roff is None \
                else max(t_start + roff, tx_ready)
            fwd_frac = min(max(c_ready - t_start, 0.0), t_dur)
            new_pend.append((idx, t_start + fwd_frac, c_ready, t_done))
            if sink is not None:
                sink.span(Span(XFER, ("link", k), t_start, t_done,
                               task=idx, ready=tx_ready))
                sink.span(Span(ENQUEUE, ("compute", k + 1),
                               t_start + fwd_frac, t_start + fwd_frac,
                               task=idx))
        pend = new_pend

    arr_list = list(arrivals)
    makespan = max(done) - min(arr_list)
    return PoolStreamResult(
        arrivals=arr_list, done=done,
        early_exit=[p.exit_hop is not None for p in plans],
        exit_hop=[p.exit_hop for p in plans],
        makespan=makespan,
        link_busy=tuple(link_busy),
        link_intervals=tuple(tuple(iv) for iv in link_iv),
        replica_busy=tuple(tuple(rb) for rb in replica_busy),
        replica_intervals=tuple(tuple(tuple(iv) for iv in tier)
                                for tier in replica_iv),
        replica_batch_sizes=tuple(tuple(tuple(bs) for bs in tier)
                                  for tier in replica_bs),
        routes=tuple(tuple(rt) for rt in routes),
        pools=pools)


def multitenant_pool_admission(
        plans: Sequence[Sequence[SimPlan]],
        arrivals: Sequence[Sequence[float]],
        policy,
        pools,
        router,
        sink=None) -> Tuple[List[TenantSlot], List[float]]:
    """Pool-ingress admission gate: merge per-tenant streams gated by
    *pool* ingress credits.

    Generalizes ``multitenant_admission_order`` from one ingress resource
    to a tier-0 pool of ``m`` replicas: a credit is a token issued the
    moment *any* tier-0 replica frees, so up to ``m`` tasks are in flight
    at the ingress at once.  Arithmetically the credit pool is a min-heap
    of completion instants seeded with ``m`` zeros (the executor's
    replicas each put one credit before their first get and one at every
    completion): each dispatch pops the earliest credit ``c`` and happens
    at ``t_d = max(c, earliest pending arrival)``; the admitted head is
    routed (``router.route`` on tier 0 — the same call sequence the
    replay and the executor's dispatcher make), and the task's completion
    on its replica is pushed back as the next credit.

    Returns ``(order, enqueues)``: the admission sequence plus each
    task's dispatch instant ``t_d`` — the replay needs it because under
    affinity-style routing a task can be held by the credit gate past its
    routed replica's free instant."""
    n_t = len(plans)
    assert len(arrivals) == n_t
    for t in range(n_t):
        assert len(plans[t]) == len(arrivals[t]), f"tenant {t} length mismatch"
        assert all(a0 <= a1 for a0, a1 in zip(arrivals[t], arrivals[t][1:])), \
            f"tenant {t} arrivals must be non-decreasing"
    n_seg = len(plans[0][0].compute) if plans and plans[0] else 1
    pools = as_pools(pools, n_seg)
    router.reset(pools)
    speeds = pools[0].speeds
    credits = [0.0] * pools[0].m
    heapq.heapify(credits)
    free0 = [0.0] * pools[0].m
    total = sum(len(p) for p in plans)
    heads = [0] * n_t
    order: List[TenantSlot] = []
    enqueues: List[float] = []
    policy.reset(n_t)
    while len(order) < total:
        pend = [t for t in range(n_t) if heads[t] < len(plans[t])]
        t_min = min(arrivals[t][heads[t]] for t in pend)
        c = heapq.heappop(credits)
        t_d = max(c, t_min)
        cands = [t for t in pend if arrivals[t][heads[t]] <= t_d]
        info = {t: (arrivals[t][heads[t]], heads[t], plans[t][heads[t]])
                for t in cands}
        t = policy.pick(cands, info)
        assert t in info, f"policy picked non-candidate tenant {t}"
        i = heads[t]
        heads[t] += 1
        if sink is not None and t_d > arrivals[t][i]:
            sink.span(Span(CREDIT_WAIT, ("compute", 0), arrivals[t][i],
                           t_d, task=len(order)))
        order.append((t, i))
        enqueues.append(t_d)
        arr = arrivals[t][i]
        p = plans[t][i]
        r = router.route(0, arr, p.compute[0], t)
        # same float expressions as the replay's tier-0 replica:
        # wake = max(enq, free), s = max(ready, wake), fin = s + speed*c
        s = max(arr, max(t_d, free0[r]))
        fin = s + speeds[r] * p.compute[0]
        free0[r] = fin
        heapq.heappush(credits, fin)
    return order, enqueues


@dataclasses.dataclass
class MultiTenantPoolStreamResult(MultiTenantStreamResult):
    """Multi-tenant result over pooled tiers: the tenant-tagged
    tier-level view (``stream`` is the merged ``as_stream_result()``)
    plus the per-replica pool timeline in ``pool``."""
    pool: Optional[PoolStreamResult] = None


def simulate_multitenant_pool_stream(
        plans: Sequence[Sequence[SimPlan]],
        arrivals: Sequence[Sequence[float]],
        policy,
        pools,
        router,
        links: Optional[Sequence[Optional[LinkProfile]]] = None,
        batch_caps: Optional[Sequence[int]] = None,
        sink=None
        ) -> MultiTenantPoolStreamResult:
    """Replay tagged multi-tenant streams over pooled tiers: compute the
    pool-credit admission order, then replay the merged tenant-tagged
    stream with ``simulate_pool_stream``.  The ingress tier's batch cap
    is forced to 1 — admission stays credit-gated one task per credit —
    but every tier-0 *replica* still admits independently, so ingress
    throughput scales with the pool."""
    order, enqueues = multitenant_pool_admission(
        plans, arrivals, policy, pools, router, sink=sink)
    assert order, "empty multi-tenant stream"
    merged_plans = [plans[t][i] for (t, i) in order]
    merged_arr = [arrivals[t][i] for (t, i) in order]
    merged_tenants = [t for (t, _) in order]
    if batch_caps is not None:
        batch_caps = [1] + [int(c) for c in batch_caps[1:]]
    res = simulate_pool_stream(merged_plans, merged_arr, pools, router,
                               links=links, batch_caps=batch_caps,
                               tenants=merged_tenants, enqueues=enqueues,
                               sink=sink)
    return MultiTenantPoolStreamResult(stream=res.as_stream_result(),
                                       order=tuple(order),
                                       n_tenants=len(plans), pool=res)
