"""Cost substrate for the COACH offline component.

A model is a ``ModelGraph`` of ``LayerNode``s (DAG; chain is the special
case).  Device/link profiles turn FLOPs/bytes into stage times — exactly the
role of the paper's system-profile measurement step (§III-B, Alg. 1 line 2).

Profiles include the paper's own testbed (Jetson NX / TX2 + A6000 server,
WiFi link) and the TPU-adaptation profiles used by the collaborative
executor (pod-of-v5e as "end", pod as "cloud", ICI/DCN link).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    name: str
    flops_per_s: float
    efficiency: float = 1.0  # device-level attainable fraction

    def layer_time(self, flops: float, util: float = 1.0) -> float:
        """``util`` is the per-layer attainable fraction (profiled): dense
        3x3 convs hit ~0.8 of effective peak on a Jetson, 1x1-conv/memory-
        bound residual layers ~0.1 — an order of magnitude apart, which is
        what makes the paper's VGG/ResNet latencies non-proportional to
        their FLOPs."""
        return flops / (self.flops_per_s * self.efficiency * util)


@dataclasses.dataclass
class LinkProfile:
    """Transmission link.  ``bandwidth`` in bits/s; can be a trace function
    of absolute time for dynamic-network experiments."""

    name: str
    bandwidth_bps: float
    trace: Optional[Callable[[float], float]] = None  # t -> bps

    def bps_at(self, t: float) -> float:
        return self.trace(t) if self.trace is not None else self.bandwidth_bps

    def transfer_time(self, bits: float, start: float = 0.0) -> float:
        """Time to push ``bits`` starting at ``start`` (integrates a
        piecewise-constant trace with 1 ms resolution)."""
        if self.trace is None:
            return bits / self.bandwidth_bps
        t, left, dt = start, bits, 1e-3
        while left > 0:
            bw = max(self.bps_at(t), 1.0)
            sent = bw * dt
            if sent >= left:
                return (t - start) + left / bw
            left -= sent
            t += dt
        return t - start


# ------------------------------------------------------------------ profiles
# Paper testbed (Table I setting): Jetson Xavier NX / TX2 ends, A6000 cloud.
# flops_per_s = dense-kernel effective peak (TensorRT-class); per-LAYER
# attainment enters through LayerNode.util, profiled per layer kind.
JETSON_NX = DeviceProfile("jetson-nx", 3.5e12)
JETSON_TX2 = DeviceProfile("jetson-tx2", 2.0e12)
# per-stream effective cloud throughput (the server is shared by many end
# devices; Fig. 2 shows cloud stage times comparable to the end stage)
A6000_SERVER = DeviceProfile("a6000", 25e12)
WIFI_5GHZ = lambda mbps=100.0: LinkProfile("wifi", mbps * 1e6)

# Mid-tier edge server for end->edge->cloud (3-hop) scenarios: an AGX-Orin
# class box between the Jetson ends and the A6000 cloud, reached over WiFi
# and wired to the cloud over metro ethernet.
EDGE_AGX_ORIN = DeviceProfile("agx-orin", 10e12)
ETH_LAN = lambda mbps=940.0: LinkProfile("eth-lan", mbps * 1e6)

# TPU adaptation: a v5e slice as the weak "end", a pod as the "cloud".
TPU_V5E_CHIP = DeviceProfile("v5e-chip", 197e12, efficiency=0.5)
TPU_POD_256 = DeviceProfile("v5e-pod", 197e12 * 256, efficiency=0.4)
ICI_LINK = lambda gbps=400.0: LinkProfile("ici", gbps * 1e9)


# ------------------------------------------------------------------- graph
@dataclasses.dataclass
class LayerNode:
    id: int
    name: str
    flops: float             # forward FLOPs for the whole (batched) task
    out_elems: int           # elements of the output activation
    deps: Tuple[int, ...] = ()
    # per-layer quantization sensitivity: acc_loss ~= sensitivity * 2^-(bits-2)
    sensitivity: float = 0.02
    # attainable compute fraction for this layer (profiled; see DeviceProfile)
    util: float = 1.0

    def out_bits(self, bits: int) -> float:
        return float(self.out_elems) * bits


class ModelGraph:
    """DAG of layers, ids topologically ordered (deps have smaller ids)."""

    def __init__(self, name: str, nodes: Sequence[LayerNode],
                 input_elems: Optional[int] = None):
        self.name = name
        self.nodes: List[LayerNode] = list(nodes)
        # raw model input size (uint8 image / token ids); defaults to the
        # first node's output as a proxy
        self.input_elems = int(input_elems if input_elems is not None
                               else (nodes[0].out_elems if nodes else 0))
        for n in self.nodes:
            assert all(d < n.id for d in n.deps), f"non-topological dep at {n.id}"
        self._children: Dict[int, List[int]] = {n.id: [] for n in self.nodes}
        for n in self.nodes:
            for d in n.deps:
                self._children[d].append(n.id)

    def __len__(self):
        return len(self.nodes)

    def children(self, i: int) -> List[int]:
        return self._children[i]

    def node(self, i: int) -> LayerNode:
        return self.nodes[i]

    @property
    def total_flops(self) -> float:
        return sum(n.flops for n in self.nodes)

    def is_chain(self) -> bool:
        return all(len(n.deps) <= 1 and len(self._children[n.id]) <= 1
                   for n in self.nodes)

    # -------------------------------------------------- partition semantics
    def boundary_edges(self, end_set: frozenset) -> List[Tuple[int, int]]:
        """Edges (u -> v) with u on the end device and v on the cloud.
        These carry the intermediate tensors of the partition layer set V_p."""
        out = []
        for n in self.nodes:
            if n.id in end_set:
                continue
            for d in n.deps:
                if d in end_set:
                    out.append((d, n.id))
        # model input consumed by a cloud node with no end parents: the raw
        # input is on the end device, so id -1 (input) edges appear when the
        # first node is on the cloud.
        for n in self.nodes:
            if n.id not in end_set and not n.deps:
                out.append((-1, n.id))
        return out

    def valid_end_set(self, end_set: frozenset) -> bool:
        """V_e must be downward-closed (no cloud->end dependency)."""
        return all(all(d in end_set for d in self.nodes[i].deps)
                   for i in end_set)


def segment_batch_split(device: DeviceProfile,
                        nodes: Sequence[LayerNode]
                        ) -> Tuple[float, float]:
    """Per-segment ``(t_fixed, t_marginal)`` for continuous micro-batching.

    A layer's profiled service time ``layer_time(flops, util)`` exceeds
    its compute-bound floor ``layer_time(flops, 1.0)`` by the attainment
    gap — for memory-bound layers (``util << 1``) that gap is weight /
    activation streaming and kernel-launch overhead, which a batched
    launch pays once, not per sample.  So the batchable decomposition of
    a segment is ``fixed = sum(gap)``, ``marginal = sum(compute floor)``;
    by construction ``fixed + marginal`` equals the segment's unbatched
    service time exactly, which is what keeps singleton batches
    bit-identical to the unbatched pipeline (``sim.batched_service_time``).
    """
    fixed = 0.0
    marginal = 0.0
    for n in nodes:
        floor = device.layer_time(n.flops, 1.0)
        fixed += device.layer_time(n.flops, n.util) - floor
        marginal += floor
    return fixed, marginal


def chain_graph(name: str, flops: Sequence[float], out_elems: Sequence[int],
                sensitivities: Optional[Sequence[float]] = None) -> ModelGraph:
    sens = sensitivities or [0.02] * len(flops)
    nodes = [LayerNode(i, f"l{i}", f, int(o), (i - 1,) if i else (),
                       sensitivity=s)
             for i, (f, o, s) in enumerate(zip(flops, out_elems, sens))]
    return ModelGraph(name, nodes)


def transformer_graph(cfg, batch: int, seq: int) -> ModelGraph:
    """Export an assigned architecture as a layer-cost chain for the COACH
    offline component (one node per transformer/ssm block + embed + head)."""
    d, f, V = cfg.d_model, cfg.d_ff, cfg.vocab_size
    tok = batch * seq
    nodes: List[LayerNode] = []
    nid = 0

    def add(name, flops, out_elems, dep_prev=True):
        nonlocal nid
        deps = (nid - 1,) if (dep_prev and nid > 0) else ()
        nodes.append(LayerNode(nid, name, flops, int(out_elems), deps,
                               util=0.45))
        nid += 1

    add("embed", 0.0, tok * d)
    for li in range(cfg.num_layers):
        spec = cfg.pattern[li % len(cfg.pattern)]
        if spec.mixer == "attn":
            hd, H, KV = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
            qkvo = 2 * tok * d * (H * hd + 2 * KV * hd + H * hd)
            if spec.attn_kind == "local":
                ctx = min(seq, cfg.sliding_window)
            elif spec.attn_kind == "chunked":
                ctx = min(seq, cfg.attn_chunk)
            else:
                ctx = seq
            attn = 2 * 2 * batch * H * seq * ctx * hd  # qk + av
            mix = qkvo + attn
        else:
            di, N = cfg.ssm_inner, cfg.ssm_state
            proj = 2 * tok * d * (2 * di + 2 * N + cfg.ssm_heads) + 2 * tok * di * d
            ssd = 2 * tok * di * N * 2  # state update + readout
            mix = proj + ssd
        if cfg.d_ff > 0:
            k = cfg.experts_per_token if spec.moe else 1
            ffn = 2 * tok * 3 * d * f * k
            if spec.moe and cfg.shared_expert:
                ffn += 2 * tok * 3 * d * f
        else:
            ffn = 0
        add(f"block{li}", mix + ffn, tok * d)
    add("head", 2 * tok * d * V, tok * V)
    return ModelGraph(cfg.name, nodes, input_elems=tok * 4)  # int32 token ids
