"""Continuous-task pipeline executor (discrete-event).

``2n+1`` serial resources — end device, per-hop links, intermediate edge
tiers, cloud — process a stream of tasks (Fig. 2); the paper's 3-resource
testbed is ``n_hops = 1``.  Per task the stage durations come from the
offline partition's ``StageTimes``; the online component may override
transmission bits (adaptive quantization) or skip everything past the end
device (early exit).  Intra-task layer parallelism is honoured through
per-hop tx/rx offsets measured by the single-task event simulation (Fig. 4
virtual-block overlap).  The event loop itself lives in
``repro.core.sim.simulate_stream`` — the same core that scores offline
partitions — so planning and replay share one semantics.

Outputs latency, throughput, and explicit per-resource bubble accounting
(idle time within the active window) — the quantities COACH is designed to
minimize.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import sim
from repro.core.costs import LinkProfile
from repro.core.schedule import StageTimes


@dataclasses.dataclass
class TaskPlan:
    """Per-task pipeline occupation.

    The classic 3-stage form sets ``t_end``/``t_tx``/``t_cloud`` (+
    optional overlap offsets); ``multihop`` builds the general form whose
    per-segment/per-hop durations live in ``compute``/``tx``.  Offsets
    express intra-task overlap measured by the single-task event
    simulation (Fig. 4); ``None`` means strictly serial stages.

    ``exit_hop = e`` marks a hop-level semantic early exit at segment
    ``e`` (the task runs compute ``0..e`` and links ``0..e-1`` only);
    ``early_exit`` is the legacy boolean spelling of ``exit_hop = 0``.

    ``t_fixed`` (one per segment the plan declares) is the per-launch
    fixed part of each segment's service time for continuous
    micro-batching, and ``deadline`` the task's absolute staleness
    deadline — both forwarded to ``sim.SimPlan`` (see its docstring)."""
    t_end: float
    t_tx: float
    t_cloud: float
    early_exit: bool = False
    tx_offset: Optional[float] = None    # end-start -> tx can start
    cloud_offset: Optional[float] = None  # tx-start  -> cloud can start
    # ---- generalized N-hop form (empty => classic 3-stage)
    compute: Tuple[float, ...] = ()
    tx: Tuple[float, ...] = ()
    tx_offsets: Tuple[Optional[float], ...] = ()
    rx_offsets: Tuple[Optional[float], ...] = ()
    exit_hop: Optional[int] = None
    # ---- continuous micro-batching (empty / None = unbatched semantics)
    t_fixed: Tuple[float, ...] = ()
    deadline: Optional[float] = None

    @classmethod
    def multihop(cls, compute: Sequence[float], tx: Sequence[float],
                 tx_offsets: Optional[Sequence[Optional[float]]] = None,
                 rx_offsets: Optional[Sequence[Optional[float]]] = None,
                 early_exit: bool = False,
                 exit_hop: Optional[int] = None,
                 t_fixed: Optional[Sequence[float]] = None,
                 deadline: Optional[float] = None) -> "TaskPlan":
        compute, tx = tuple(compute), tuple(tx)
        assert len(compute) == len(tx) + 1
        return cls(t_end=compute[0], t_tx=tx[0] if tx else 0.0,
                   t_cloud=compute[-1], early_exit=early_exit,
                   compute=compute, tx=tx,
                   tx_offsets=tuple(tx_offsets) if tx_offsets else (None,) * len(tx),
                   rx_offsets=tuple(rx_offsets) if rx_offsets else (None,) * len(tx),
                   exit_hop=exit_hop,
                   t_fixed=tuple(t_fixed) if t_fixed else (),
                   deadline=deadline)

    @property
    def n_hops(self) -> int:
        return len(self.tx) if self.tx else 1

    def as_sim_plan(self, n_hops: int) -> sim.SimPlan:
        """Normalize to ``n_hops`` stages (shorter plans pad with zeros —
        an early-exited or shallower task simply never occupies the extra
        resources)."""
        if self.compute:
            comp, tx = list(self.compute), list(self.tx)
            txo, rxo = list(self.tx_offsets), list(self.rx_offsets)
        else:
            comp, tx = [self.t_end, self.t_cloud], [self.t_tx]
            txo, rxo = [self.tx_offset], [self.cloud_offset]
        fixed = list(self.t_fixed[:len(comp)]) if self.t_fixed else []
        if fixed:
            fixed += [0.0] * (len(comp) - len(fixed))
        while len(tx) < n_hops:
            tx.append(0.0)
            comp.append(0.0)
            txo.append(None)
            rxo.append(None)
            if fixed:
                fixed.append(0.0)
        return sim.SimPlan(compute=tuple(comp), tx=tuple(tx),
                           tx_offset=tuple(txo), rx_offset=tuple(rxo),
                           early_exit=self.early_exit,
                           exit_hop=self.exit_hop,
                           t_fixed=tuple(fixed),
                           deadline=self.deadline)


@dataclasses.dataclass
class TaskRecord:
    id: int
    arrival: float
    done: float
    latency: float
    early_exit: bool                      # exited before the last segment
    exit_hop: Optional[int] = None        # segment it terminated at


@dataclasses.dataclass
class PipelineResult:
    tasks: List[TaskRecord]
    makespan: float
    compute_busy: Tuple[float, ...]
    link_busy_hops: Tuple[float, ...]
    # per-resource busy intervals (from sim.StreamResult / the async
    # executor's recorded timeline) — empty tuples when not recorded
    compute_intervals: Tuple[Tuple[sim.Interval, ...], ...] = ()
    link_intervals: Tuple[Tuple[sim.Interval, ...], ...] = ()
    # replicas per compute tier when the run used replicated pools
    # (() = classic single-replica chain); compute_busy[k] then sums the
    # tier's replicas, so utilization is against m * makespan
    pool_sizes: Tuple[int, ...] = ()

    # ---- classic 3-resource views
    @property
    def end_busy(self) -> float:
        return self.compute_busy[0]

    @property
    def link_busy(self) -> float:
        return float(sum(self.link_busy_hops))

    @property
    def cloud_busy(self) -> float:
        return self.compute_busy[-1]

    @property
    def n_hops(self) -> int:
        return len(self.link_busy_hops)

    @property
    def mean_latency(self) -> float:
        return float(np.mean([t.latency for t in self.tasks]))

    @property
    def p99_latency(self) -> float:
        return float(np.percentile([t.latency for t in self.tasks], 99))

    @property
    def throughput(self) -> float:
        return len(self.tasks) / self.makespan if self.makespan > 0 else 0.0

    @property
    def exit_ratio(self) -> float:
        return float(np.mean([t.early_exit for t in self.tasks]))

    def exit_hop_counts(self) -> dict:
        """Histogram of hop-level exits: ``{segment: task count}`` over
        the tasks that exited before the last segment."""
        counts: dict = {}
        for t in self.tasks:
            if t.exit_hop is not None:
                counts[t.exit_hop] = counts.get(t.exit_hop, 0) + 1
        return dict(sorted(counts.items()))

    def stage_busy(self, stage: Union[str, Tuple[str, int]]) -> float:
        """Busy time of one resource: "end"/"link"/"cloud" (classic view)
        or ("compute", k) / ("link", k) for the general pipeline."""
        if isinstance(stage, tuple):
            kind, k = stage
            return self.compute_busy[k] if kind == "compute" \
                else self.link_busy_hops[k]
        return {"end": self.end_busy, "link": self.link_busy,
                "cloud": self.cloud_busy}[stage]

    def _capacity(self, stage: Union[str, Tuple[str, int]]) -> float:
        """Busy-time capacity of a resource over the run: ``makespan``
        for a serial resource, ``m * makespan`` for a replicated compute
        tier, ``n_hops * makespan`` for the aggregate ``"link"`` view
        (``link_busy`` sums every hop) — so ``bubble_fraction`` stays in
        ``[0, 1]`` with pools and with multi-hop chains alike.

        Replica *speeds* need no extra normalization: busy time is
        measured in wall seconds on each replica (a slow replica is busy
        longer for the same task), so ``m * makespan`` is the correct
        wall-clock capacity of a heterogeneous pool too.  This matches
        the per-resource conservation identity of
        ``repro.obs.bubbles.attribute`` — ``sum_r busy_r + sum_r
        bubbles_r = m * horizon`` per tier."""
        if stage == "link":
            return self.n_hops * self.makespan
        if not self.pool_sizes:
            return self.makespan
        if isinstance(stage, tuple):
            kind, k = stage
            return self.pool_sizes[k] * self.makespan \
                if kind == "compute" else self.makespan
        if stage == "end":
            return self.pool_sizes[0] * self.makespan
        if stage == "cloud":
            return self.pool_sizes[-1] * self.makespan
        return self.makespan

    def bubble_fraction(self, stage: Union[str, Tuple[str, int]] = "cloud"
                        ) -> float:
        busy = self.stage_busy(stage)
        cap = self._capacity(stage)
        return 1.0 - busy / cap if self.makespan > 0 else 0.0


def plan_from_stage_times(st: StageTimes, early_exit: bool = False,
                          bits_scale: float = 1.0,
                          exit_hop: Optional[int] = None) -> TaskPlan:
    """bits_scale rescales transmission time (online quant adjustment);
    ``exit_hop`` marks a hop-level semantic exit at that segment."""
    if early_exit or exit_hop == 0:
        return TaskPlan(st.T_e, 0.0, 0.0, True)
    if st.n_hops == 1:
        return TaskPlan(st.T_e, st.T_t * bits_scale, st.T_c,
                        tx_offset=min(st.first_tx_offset, st.T_e),
                        cloud_offset=st.cloud_start_offset)
    return TaskPlan.multihop(
        compute=st.compute,
        tx=tuple(t * bits_scale for t in st.link),
        tx_offsets=tuple(min(st.tx_offsets[k], st.compute[k])
                         for k in range(st.n_hops)),
        rx_offsets=st.rx_offsets, exit_hop=exit_hop)


def result_from_stream(res: sim.StreamResult) -> PipelineResult:
    """Wrap a raw resource timeline (from ``sim.simulate_stream`` or the
    async hop-queue executor) into the engine-facing result type."""
    recs = [TaskRecord(i, arr, d, d - arr, ee, eh)
            for i, (arr, d, ee, eh) in enumerate(zip(res.arrivals, res.done,
                                                     res.early_exit,
                                                     res.exit_hop))]
    return PipelineResult(recs, res.makespan, res.compute_busy,
                          res.link_busy,
                          compute_intervals=res.compute_intervals,
                          link_intervals=res.link_intervals)


def result_from_pool_stream(res: sim.PoolStreamResult) -> PipelineResult:
    """Wrap a replicated-tier timeline (``sim.simulate_pool_stream`` or
    the async pool executor) into the engine-facing result type.  The
    tier view merges each pool's replica intervals; ``pool_sizes`` keeps
    the replica counts so utilization is judged against
    ``m * makespan``."""
    pr = result_from_stream(res.as_stream_result())
    pr.pool_sizes = tuple(p.m for p in res.pools)
    return pr


def run_pipeline(plans: Sequence[TaskPlan],
                 arrivals: Optional[Sequence[float]] = None,
                 arrival_period: float = 0.0,
                 link: Optional[LinkProfile] = None,
                 links: Optional[Sequence[Optional[LinkProfile]]] = None,
                 batch_caps: Optional[Sequence[int]] = None,
                 pools: Optional[Sequence] = None,
                 router=None, sink=None, migrate=None) -> PipelineResult:
    """Execute the task stream.  ``link`` (classic) or ``links`` (one per
    hop) with a bandwidth trace re-integrates each task's transmission
    time at its actual start time (dynamic networks, Fig. 5).
    ``batch_caps`` enables per-tier continuous micro-batching (see
    ``sim.simulate_stream``).  ``pools`` (per-tier replica pools, see
    ``sim.PoolSpec``) with a ``router`` (``serving.routing`` policy,
    duck-typed here so the core stays serving-free) runs the replicated
    DAG path instead of the serial chain.  ``sink`` (a
    ``repro.obs.trace`` span sink) records the timeline as spans; the
    async executor emits the same spans, so traces are differentially
    pinned like results.  ``migrate`` is the online re-planning hook of
    ``sim.simulate_stream`` (chain path only)."""
    n = len(plans)
    if arrivals is None:
        arrivals = [i * arrival_period for i in range(n)]
    if links is None:
        links = [link]
    # the deployment's links set the resource count floor: a stream of
    # early-exited (1-hop) plans on a 3-tier deployment still accounts
    # every tier's (idle) resources
    n_hops = max(max(p.n_hops for p in plans), len(links))
    sim_plans = [p.as_sim_plan(n_hops) for p in plans]
    if pools is not None:
        assert router is not None, "replicated tiers need a router policy"
        assert migrate is None, \
            "plan migration composes with the unbatched chain path only"
        pres = sim.simulate_pool_stream(sim_plans, arrivals, pools, router,
                                        links=links, batch_caps=batch_caps,
                                        sink=sink)
        return result_from_pool_stream(pres)
    res = sim.simulate_stream(sim_plans, arrivals, links=links,
                              batch_caps=batch_caps, sink=sink,
                              migrate=migrate)
    return result_from_stream(res)


def bandwidth_step_trace(steps: Sequence[tuple]) -> Callable[[float], float]:
    """[(t_from, mbps), ...] -> bps trace function."""
    steps = sorted(steps)

    def trace(t: float) -> float:
        bw = steps[0][1]
        for (t0, m) in steps:
            if t >= t0:
                bw = m
        return bw * 1e6

    return trace
