"""Continuous-task pipeline executor (discrete-event).

Three serial resources — end device, link, cloud — process a stream of
tasks (Fig. 2).  Per task the stage durations come from the offline
partition's ``StageTimes``; the online component may override transmission
bits (adaptive quantization) or skip transmission+cloud entirely (early
exit).  Intra-task layer parallelism is honoured through the
``first_tx_offset`` / ``cloud_start_offset`` offsets measured by the
single-task event simulation, i.e. a task's transmission can begin before
its end-compute finishes (Fig. 4 virtual-block overlap).

Outputs latency, throughput, and explicit bubble accounting (idle time on
the link and cloud within the active window) — the quantities COACH is
designed to minimize.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.costs import LinkProfile
from repro.core.schedule import StageTimes


@dataclasses.dataclass
class TaskPlan:
    """Per-task pipeline occupation.

    ``tx_offset``/``cloud_offset`` express intra-task overlap measured by the
    single-task event simulation (Fig. 4).  None (default) means strictly
    serial stages: transmission starts after end compute, cloud after the
    transmission completes."""
    t_end: float
    t_tx: float
    t_cloud: float
    early_exit: bool = False
    tx_offset: Optional[float] = None    # end-start -> tx can start
    cloud_offset: Optional[float] = None  # tx-start  -> cloud can start


@dataclasses.dataclass
class TaskRecord:
    id: int
    arrival: float
    done: float
    latency: float
    early_exit: bool


@dataclasses.dataclass
class PipelineResult:
    tasks: List[TaskRecord]
    makespan: float
    end_busy: float
    link_busy: float
    cloud_busy: float

    @property
    def mean_latency(self) -> float:
        return float(np.mean([t.latency for t in self.tasks]))

    @property
    def p99_latency(self) -> float:
        return float(np.percentile([t.latency for t in self.tasks], 99))

    @property
    def throughput(self) -> float:
        return len(self.tasks) / self.makespan if self.makespan > 0 else 0.0

    @property
    def exit_ratio(self) -> float:
        return float(np.mean([t.early_exit for t in self.tasks]))

    def bubble_fraction(self, stage: str = "cloud") -> float:
        busy = {"end": self.end_busy, "link": self.link_busy,
                "cloud": self.cloud_busy}[stage]
        return 1.0 - busy / self.makespan if self.makespan > 0 else 0.0


def plan_from_stage_times(st: StageTimes, early_exit: bool = False,
                          bits_scale: float = 1.0) -> TaskPlan:
    """bits_scale rescales transmission time (online quant adjustment)."""
    if early_exit:
        return TaskPlan(st.T_e, 0.0, 0.0, True)
    return TaskPlan(st.T_e, st.T_t * bits_scale, st.T_c,
                    tx_offset=min(st.first_tx_offset, st.T_e),
                    cloud_offset=st.cloud_start_offset)


def run_pipeline(plans: Sequence[TaskPlan],
                 arrivals: Optional[Sequence[float]] = None,
                 arrival_period: float = 0.0,
                 link: Optional[LinkProfile] = None) -> PipelineResult:
    """Execute the task stream.  If ``link`` has a bandwidth trace, each
    task's transmission time is re-integrated at its actual start time
    (dynamic networks, Fig. 5)."""
    n = len(plans)
    if arrivals is None:
        arrivals = [i * arrival_period for i in range(n)]
    end_free = link_free = cloud_free = 0.0
    end_busy = link_busy = cloud_busy = 0.0
    recs: List[TaskRecord] = []
    for i, (p, arr) in enumerate(zip(plans, arrivals)):
        e_start = max(arr, end_free)
        e_done = e_start + p.t_end
        end_free = e_done
        end_busy += p.t_end
        if p.early_exit:
            recs.append(TaskRecord(i, arr, e_done, e_done - arr, True))
            continue
        tx_ready = e_done if p.tx_offset is None or p.tx_offset >= p.t_end \
            else e_start + p.tx_offset
        t_start = max(tx_ready, link_free)
        t_dur = p.t_tx
        if link is not None and link.trace is not None and p.t_tx > 0:
            # re-integrate the same bit volume under the live trace
            bits = p.t_tx * link.bandwidth_bps
            t_dur = link.transfer_time(bits, t_start)
        t_done = t_start + t_dur
        link_free = t_done
        link_busy += t_dur
        c_ready = t_done if p.cloud_offset is None \
            else max(t_start + p.cloud_offset, tx_ready)
        c_start = max(c_ready, cloud_free)
        # cloud cannot finish before all data has arrived
        c_done = max(c_start + p.t_cloud, t_done)
        cloud_free = c_done
        cloud_busy += p.t_cloud
        recs.append(TaskRecord(i, arr, c_done, c_done - arr, False))
    makespan = max(r.done for r in recs) - min(r.arrival for r in recs)
    return PipelineResult(recs, makespan, end_busy, link_busy, cloud_busy)


def bandwidth_step_trace(steps: Sequence[tuple]) -> Callable[[float], float]:
    """[(t_from, mbps), ...] -> bps trace function."""
    steps = sorted(steps)

    def trace(t: float) -> float:
        bw = steps[0][1]
        for (t0, m) in steps:
            if t >= t0:
                bw = m
        return bw * 1e6

    return trace
