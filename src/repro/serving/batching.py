"""Auto batch-size finder for continuous micro-batching.

The compute workers of ``repro.serving.async_engine`` drain their hop
queue into dynamic micro-batches (``sim.greedy_batch_size``); the knob
that matters is the per-tier ``batch_cap``.  This module picks it the
way Lightning's ``batch_size_finder`` picks a training batch size:
probe geometrically (1, 2, 4, ...) against a measured batched segment
time until the constraint breaks, then binary-search the boundary.

The constraint here is latency, not memory: a batch of ``n`` holds its
head task for ``measure(n) - measure(1)`` longer than unbatched service
would, so the largest admissible cap is the largest ``n`` whose marginal
latency cost still fits inside the tier's share of the SLO slack.  With
the calibrated service model ``measure(n) = t_fixed + n * t_marginal``
(``repro.core.costs.segment_batch_split``) the cost is
``(n - 1) * t_marginal`` — but ``find_batch_cap`` only assumes
``measure`` is non-decreasing, so measured wall-time probes of a real
deployment plug in unchanged.
"""

from __future__ import annotations

from typing import Callable, List, Optional, Sequence

from repro.core import sim

__all__ = ["find_batch_cap", "auto_batch_caps", "realized_batch_sizes"]


def find_batch_cap(measure: Callable[[int], float], slack: float,
                   cap_limit: int = 32) -> int:
    """Largest ``n in [1, cap_limit]`` with
    ``measure(n) - measure(1) <= slack``.

    ``measure(n)`` is the tier's batched segment service time at batch
    size ``n`` (calibrated model or wall-clock probe) and must be
    non-decreasing in ``n``.  Geometric doubling finds the first
    power-of-two that breaks the budget, binary search pins the exact
    boundary — O(log cap_limit) probes, never an exhaustive sweep.
    """
    assert cap_limit >= 1
    base = measure(1)

    def fits(n: int) -> bool:
        return measure(n) - base <= slack

    if cap_limit == 1 or not fits(2):
        return 1
    lo = 2
    while lo * 2 <= cap_limit and fits(lo * 2):
        lo *= 2
    hi = min(lo * 2, cap_limit)
    # invariant: fits(lo); first failure (if any) lies in (lo, hi]
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if fits(mid):
            lo = mid
        else:
            hi = mid - 1
    return lo


def auto_batch_caps(compute: Sequence[float], t_fixed: Sequence[float],
                    slack: float, cap_limit: int = 32,
                    ingress_cap: Optional[int] = None) -> List[int]:
    """Per-tier batch caps from the calibrated service split.

    ``compute[k]`` / ``t_fixed[k]`` are the offline plan's segment times
    and their per-launch fixed parts; ``slack`` is the end-to-end
    staleness budget (e.g. ``slo_latency - single_task_latency``), split
    evenly across the tiers so the chain's total added latency stays
    inside it.  ``ingress_cap`` clamps tier 0 (the multi-tenant engines
    force it to 1 — credit-gated admission keeps the ingress queue at
    depth <= 1, so batching there is meaningless).

    A tier clamped to cap <= 1 can never *spend* staleness slack —
    batching is off there — so it is excluded from the even split and
    its share is redistributed over the tiers that can batch (giving a
    hard-clamped ingress a full ``1/n`` share would silently waste it;
    downstream caps under the redistribution are always >= the naive
    even-split caps, since ``find_batch_cap`` is monotone in its budget).
    """
    n_seg = len(compute)
    assert len(t_fixed) == n_seg
    clamped = [ingress_cap is not None and int(ingress_cap) <= 1 and k == 0
               for k in range(n_seg)]
    n_eligible = sum(1 for c in clamped if not c)
    per_tier = max(0.0, slack) / n_eligible if n_eligible else 0.0
    caps = []
    for k in range(n_seg):
        if clamped[k]:
            caps.append(1)
            continue
        marginal = compute[k] - t_fixed[k]
        caps.append(find_batch_cap(
            lambda n, f=t_fixed[k], m=marginal: f + n * m,
            per_tier, cap_limit))
    if ingress_cap is not None and caps:
        caps[0] = min(caps[0], int(ingress_cap))
    return caps


def realized_batch_sizes(pr, metrics=None) -> List[float]:
    """Mean realized batch size per compute tier of a finished run.

    Each micro-batch occupies its tier for one busy interval, so the
    realized mean batch size at tier ``k`` is (tasks that ran on tier k)
    / (busy intervals on tier k).  ``pr`` is a ``PipelineResult`` (or
    anything with ``tasks`` carrying ``exit_hop`` and
    ``compute_intervals``).  ``metrics`` (an
    ``obs.metrics.MetricsRegistry``) additionally gets one
    ``tier{k}.realized_batch`` gauge per tier."""
    out: List[float] = []
    for k, iv in enumerate(pr.compute_intervals):
        n_tasks = sum(1 for t in pr.tasks
                      if sim.occupies_compute(t.exit_hop, k))
        out.append(n_tasks / len(iv) if iv else 0.0)
        if metrics is not None:
            metrics.set_gauge(f"tier{k}.realized_batch", out[-1])
    return out
