"""Async hop-queue serving engine: real per-resource workers, pinned to
``core/sim``.

``core.sim.simulate_stream`` models a collaborative deployment as
``2n+1`` alternating serial FIFO resources.  This module *executes* that
model instead of replaying it: one asyncio worker per resource, chained
by bounded ``HopQueue``s, so segment ``k`` of task ``i`` genuinely runs
concurrently with segment ``k-1`` of task ``i+1`` and every hop's
transmission is an awaitable priced by its ``LinkProfile``.

Resource-worker <-> ``core/sim`` correspondence (the invariant the
differential test ``tests/test_async_engine.py`` pins):

  =====================  ==========================================
  ``simulate_stream``    ``AsyncHopPipeline``
  =====================  ==========================================
  ``compute_free[k]``    compute worker ``k``'s position in virtual
                         time (a serial worker is "free" exactly when
                         its loop returns to ``HopQueue.get``)
  ``link_free[k]``       link worker ``k``'s position in virtual time
  task admission order   FIFO order of the queue chain (each worker
                         processes and forwards in order)
  ``tx_ready``           ``_Msg.ready_at`` of the message the compute
                         worker forwards to its link queue (``prev_done``
                         for a serial plan, ``prev_start + tx_offset``
                         for an overlapped one)
  ``c_ready``            ``_Msg.ready_at`` the link worker stamps for
                         the downstream compute worker
                         (``t_done``, or ``t_start + rx_offset``)
  ``c_done = max(...)``  the downstream worker sleeps its compute time,
                         then ``sleep_until(data_done)`` — it cannot
                         finish before all data has arrived
  trace re-integration   the link worker reprices the planned bit
                         volume with ``LinkProfile.transfer_time`` at
                         the transfer's actual virtual start
  boundary quantize +    the fused single-pass boundary kernel
  semantic probe         (``kernels.boundary`` via ``CollabRuntime.
  (priced inside         segment_handle(probe_centers=)``): worker
  ``compute[k]``)        ``k``'s segment forward emits the hop-``k``
                         wire packet *and* the ``BoundaryProbe`` in one
                         HBM read of the activation; the lifted
                         ``ProbeResult`` feeds the enqueue-time
                         decision in place of the scheduler's recompute
  =====================  ==========================================

With ``pools=`` the chain generalizes to *replicated tiers*
(``core.sim.simulate_pool_stream``; pinned by ``tests/test_pools.py``):

  ==========================  =====================================
  ``simulate_pool_stream``    ``AsyncHopPipeline(pools=...)``
  ==========================  =====================================
  dispatch (router placing    one *dispatcher* worker per tier: gets
  each pending task, in       from the pool input queue, calls
  admission order)            ``router.route`` per task in seq order,
                              forwards to the chosen replica's queue
  replica replay (per-        one worker per replica: the chain
  replica FIFO + batching,    compute worker with its service times
  ``speed * compute[k]``)     scaled by ``PoolSpec.speeds[r]``
  sequencer (running max of   one *sequencer* worker per hop: buffers
  release instants restores   ``(seq, msg)`` releases and forwards to
  admission order)            the serial link strictly in seq order
  pool ingress credits        every tier-0 replica puts one credit
  (min-heap of completion     before each ``get`` — a token the
  instants, ``m`` zeros)      moment *any* ingress replica frees
  ==========================  =====================================

Router state is strictly per tier and never reads the clock, so the
executor's wall-time interleaving of tiers reaches the same placements
as the simulator's tier-by-tier staged replay (see
``repro.serving.routing``).

Timing comes from a pluggable clock: ``VirtualClock`` is a deterministic
discrete-event driver (timers fire only when every worker is blocked, so
a run is a bit-reproducible event simulation — this is what makes the
executor directly comparable to ``simulate_stream``); ``WallClock`` maps
the same awaits onto real ``asyncio.sleep``.  With unbounded queues the
virtual-clock timeline reproduces ``simulate_stream`` exactly; bounded
queues add admission/backpressure (an upstream worker stalls on ``put``
when its hop queue is full), which the pure simulator does not model.

``AsyncCoachEngine`` rides the online component on top: ``OnlineScheduler``
decisions (early exit Eq. 10, adaptive precision Eq. 11) are made at
enqueue time on the end worker, in task order — concurrency never changes
*decisions*, only timing — and per-hop adaptive bits pick a precision per
``WirePacket`` hop from per-hop bandwidth EMAs
(``OnlineScheduler.choose_hop_bits``).  ``classify`` may return a
3-tuple ``(features, pred, probes)`` carrying the fused boundary pass's
precomputed ``ProbeResult``(s); the cascade consumes them directly
(``EngineBase.decide``), so no engine re-reads the boundary activation.

Multi-tenant admission lives one layer up in ``repro.serving.tenancy``:
``AsyncHopPipeline.run`` accepts a pluggable admitter (``admit_fn``)
which is released by *ingress credits* — a token issued each time the
end worker is about to block on its input queue — so a policy scheduler
can gate per-tenant streams on the shared ingress resource.
"""

from __future__ import annotations

import asyncio
import collections
import contextlib
import dataclasses
import heapq
import itertools
from typing import Any, Callable, List, Optional, Sequence, Tuple

from repro.core import sim
from repro.core.costs import LinkProfile
from repro.core.pipeline import (PipelineResult, TaskPlan,
                                 result_from_stream)
from repro.obs.trace import (BATCH_FORM, ENQUEUE, EXIT_RELEASE, REPLAN,
                             ROUTE, SEQ_HOLD, SERVICE, XFER)
from repro.serving.base import EngineBase, EngineStats

__all__ = ["VirtualClock", "WallClock", "HopQueue", "AsyncHopPipeline",
           "run_pipeline_async", "AsyncCoachEngine"]


# ==================================================================== clocks
class VirtualClock:
    """Deterministic virtual-time driver for a set of asyncio workers.

    Every blocking point of the executor (timed sleeps, queue gets/puts)
    registers with the clock.  A driver coroutine fires the earliest
    pending timer only when *all* registered workers are blocked, so the
    run is a discrete-event simulation: virtual time jumps from event to
    event and the interleaving is reproducible.
    """

    def __init__(self):
        self.now = 0.0
        # (when, seq, future, is_settle_sentinel); ordered by (when, seq)
        self._timers: List[Tuple[float, int, asyncio.Future, bool]] = []
        self._seq = itertools.count()
        self._blocked = 0   # workers suspended in a clock primitive
        self._live = 0      # workers spawned and not yet finished
        self._idle: Optional[asyncio.Event] = None

    # ---- bookkeeping shared with HopQueue
    def _maybe_idle(self):
        if self._idle is not None and self._blocked >= self._live:
            self._idle.set()

    async def _wait(self, fut: asyncio.Future):
        """Suspend the calling worker until ``_wake(fut)``."""
        self._blocked += 1
        self._maybe_idle()
        return await fut

    def _wake(self, fut: asyncio.Future, value: Any = None):
        self._blocked -= 1
        if not fut.done():
            fut.set_result(value)

    # ---- public interface
    async def sleep(self, dt: float):
        await self.sleep_until(self.now + dt)

    async def sleep_until(self, when: float):
        if when <= self.now:
            return
        fut = asyncio.get_event_loop().create_future()
        heapq.heappush(self._timers, (when, next(self._seq), fut, False))
        await self._wait(fut)

    async def settle(self):
        """Suspend until every event scheduled for the *current* virtual
        instant has fired.  A worker woken by a direct queue handoff may
        run while timers for the same instant are still pending in the
        heap; a sentinel timer pushed at ``now`` sorts after them (same
        ``when``, later seq), so awaiting it yields until the instant
        has fully played out.  Admission dispatchers use this before
        sampling queue state (``repro.serving.tenancy``), batching
        compute workers before snapshotting their hop queue.

        Only *real* timers count: another worker's settle sentinel is
        not pending work, and honouring it would livelock two settles
        at the same instant (each re-arming against the other's
        sentinel forever)."""
        while any(when <= self.now and not sentinel
                  for (when, _, _, sentinel) in self._timers):
            fut = asyncio.get_event_loop().create_future()
            heapq.heappush(self._timers,
                           (self.now, next(self._seq), fut, True))
            await self._wait(fut)

    def spawn(self, coro) -> "asyncio.Task":
        """Register + start a worker; only spawned workers count toward
        the quiescence check that gates timer firing."""
        self._live += 1

        async def wrapped():
            try:
                return await coro
            finally:
                self._live -= 1
                self._maybe_idle()

        return asyncio.ensure_future(wrapped())

    async def _drive(self):
        while True:
            await self._idle.wait()
            self._idle.clear()
            if self._live == 0:
                return
            if not self._timers:
                raise RuntimeError(
                    "virtual-clock deadlock: all workers blocked with no "
                    "pending timer")
            when, _, fut, _sentinel = heapq.heappop(self._timers)
            self.now = max(self.now, when)
            self._wake(fut)

    def run(self, main):
        """Run ``main`` (which spawns workers via ``spawn``) to completion
        under virtual time; returns its result."""
        return asyncio.run(self._run(main))

    async def _run(self, main):
        self._idle = asyncio.Event()
        driver = asyncio.ensure_future(self._drive())
        main_t = asyncio.ensure_future(main)
        try:
            await asyncio.wait({driver, main_t},
                               return_when=asyncio.FIRST_COMPLETED)
            if driver.done() and driver.exception() is not None:
                main_t.cancel()
                with contextlib.suppress(asyncio.CancelledError):
                    await main_t
                raise driver.exception()
            return await main_t
        finally:
            if not driver.done():
                driver.cancel()
                with contextlib.suppress(asyncio.CancelledError):
                    await driver


class WallClock:
    """Real-time realization of the same clock interface: sleeps map to
    ``asyncio.sleep`` and ``now`` is the loop clock relative to the start
    of the run (best effort — scheduling jitter is real here)."""

    def __init__(self):
        self._t0: Optional[float] = None

    @property
    def now(self) -> float:
        loop = asyncio.get_event_loop()
        if self._t0 is None:
            self._t0 = loop.time()
        return loop.time() - self._t0

    async def sleep(self, dt: float):
        if dt > 0:
            await asyncio.sleep(dt)

    async def sleep_until(self, when: float):
        await self.sleep(when - self.now)

    async def settle(self):
        """Best-effort wall-clock counterpart of ``VirtualClock.settle``:
        yield to the scheduler a few times so same-instant callbacks run."""
        for _ in range(4):
            await asyncio.sleep(0)

    async def _wait(self, fut: asyncio.Future):
        return await fut

    def _wake(self, fut: asyncio.Future, value: Any = None):
        if not fut.done():
            fut.set_result(value)

    def spawn(self, coro) -> "asyncio.Task":
        return asyncio.ensure_future(coro)

    def run(self, main):
        return asyncio.run(main)


# ==================================================================== queue
class HopQueue:
    """Bounded FIFO channel between two pipeline resources.

    Like ``asyncio.Queue`` but clock-aware: a worker blocked in ``get``
    (empty) or ``put`` (full) is registered with the clock so the virtual
    driver knows the pipeline is quiescent.  ``maxsize = 0`` means
    unbounded (the waiting room of ``core/sim``'s serial resources)."""

    def __init__(self, clock, maxsize: int = 0):
        self._clock = clock
        self._max = maxsize
        self._items = collections.deque()
        self._getters = collections.deque()
        self._putters = collections.deque()  # (future, item)

    def __len__(self):
        return len(self._items)

    async def put(self, item):
        if self._getters:                       # direct handoff
            self._clock._wake(self._getters.popleft(), item)
            return
        if self._max and len(self._items) >= self._max:
            fut = asyncio.get_event_loop().create_future()
            self._putters.append((fut, item))
            await self._clock._wait(fut)        # backpressure: stall upstream
            return
        self._items.append(item)

    def _admit_putter(self):
        if self._putters:                       # a slot freed up
            fut, pitem = self._putters.popleft()
            self._items.append(pitem)
            self._clock._wake(fut)

    async def get(self):
        if self._items:
            item = self._items.popleft()
            self._admit_putter()
            return item
        fut = asyncio.get_event_loop().create_future()
        self._getters.append(fut)
        return await self._clock._wait(fut)

    def get_nowait(self):
        """Pop the head item without blocking; raises
        ``asyncio.QueueEmpty`` when nothing is queued."""
        if not self._items:
            raise asyncio.QueueEmpty
        item = self._items.popleft()
        self._admit_putter()
        return item

    def drain(self, n: int) -> list:
        """Pop up to ``n`` items (FIFO), admitting one blocked putter per
        freed slot.  Never blocks; returns what was there.

        A batching worker must decide *membership* from ``snapshot()``
        taken at its wake instant, then ``drain`` exactly that many: the
        naive pattern of sizing the drain from ``len(queue)`` at drain
        time races with same-timeline producers — a worker that slept
        between waking and draining would observe items enqueued *after*
        its wake instant, diverging from the simulator's arithmetic rule
        (which gathers the queue state at the wake instant only)."""
        out = []
        while len(out) < n and self._items:
            out.append(self._items.popleft())
            self._admit_putter()
        return out

    def snapshot(self) -> tuple:
        """The queued items at this instant, in FIFO order, not removed.
        Take this at the wake instant (after ``clock.settle()`` so every
        same-instant put has landed) to fix a batch's candidate set."""
        return tuple(self._items)


# ================================================================= executor
@dataclasses.dataclass
class _Msg:
    """One task's in-flight state between two adjacent resources."""
    idx: int
    plan: sim.SimPlan
    ready_at: float     # earliest time the receiving resource may start it
    data_done: float    # when the upstream transfer fully lands (c_done gate)
    payload: Any = None
    tenant: Optional[int] = None   # tag for tenant-affinity routing
    seq: int = 0                   # per-tier dispatch order (pool sequencer)


_STOP = object()


class AsyncHopPipeline:
    """``2n+1`` resource workers chained by hop queues (see module doc).

    ``segment_fn(k, idx, payload) -> payload`` optionally runs real
    compute (e.g. ``CollabRuntime.segment_handle(k)``) on each compute
    worker; the last segment's outputs are collected in ``outputs``.
    """

    def __init__(self, n_hops: int,
                 links: Optional[Sequence[Optional[LinkProfile]]] = None,
                 clock=None, queue_capacity: int = 0,
                 segment_fn: Optional[Callable[[int, int, Any], Any]] = None,
                 batch_caps: Optional[Sequence[int]] = None,
                 pools=None, router=None, sink=None, migrate=None):
        assert n_hops >= 1
        self.n_hops = n_hops
        self.n_seg = n_hops + 1
        self.links = list(links) if links is not None else [None] * n_hops
        self.clock = clock if clock is not None else VirtualClock()
        self.capacity = queue_capacity
        self.segment_fn = segment_fn
        # per-tier continuous micro-batching caps (None / 1 = unbatched);
        # missing trailing tiers default to 1
        self.batch_caps = [1] * self.n_seg
        if batch_caps is not None:
            for k, c in enumerate(batch_caps[:self.n_seg]):
                assert int(c) >= 1, "batch caps must be >= 1"
                self.batch_caps[k] = int(c)
        # replicated tiers: per-tier PoolSpec + a router policy object
        # (repro.serving.routing); None = the classic 2n+1 chain
        self.pools = sim.as_pools(pools, self.n_seg) \
            if pools is not None else None
        if self.pools is not None:
            assert router is not None, "pool execution needs a router policy"
        self.router = router
        # span sink (``repro.obs.trace``): every worker emits the same
        # spans, at the same virtual instants, as the simulator's staged
        # replay — the differential pin extends to traces.  ``None``
        # (default) emits nothing and allocates nothing.
        self.sink = sink
        # online re-planning hook ``migrate(idx, k, tx_ready)`` (see
        # ``sim.simulate_stream``): consulted by each link worker at its
        # task's boundary-ready instant, so both engines evaluate it
        # with identical arguments and reach identical plan switches.
        self.migrate = migrate
        if migrate is not None:
            assert self.pools is None and all(
                c <= 1 for c in self.batch_caps), \
                "plan migration composes with the unbatched chain path only"
        self.outputs: dict = {}

    def run(self, plan_fn: Callable[[int, float], Any], n_tasks: int,
            arrivals: Optional[Sequence[float]],
            payloads: Optional[Sequence[Any]] = None,
            admit_fn: Optional[Callable] = None) -> sim.StreamResult:
        """Admit ``n_tasks`` tasks at ``arrivals`` and execute the chain.

        ``plan_fn(i, t_arr)`` is called *at enqueue time* (in task order,
        at the task's virtual arrival) and returns the task's
        ``sim.SimPlan`` (or a ``TaskPlan``, normalized here) — this is
        the hook where online decisions happen.

        ``admit_fn(q0, credits, record)`` replaces the built-in
        single-stream admission worker (multi-tenant admission lives in
        ``repro.serving.tenancy``).  It must put exactly ``n_tasks``
        ``_Msg``s with distinct ``idx`` in ``[0, n_tasks)`` into ``q0``
        followed by ``_STOP``, and call ``record(idx, arrival)`` for
        each.  ``credits`` is a clock-aware queue receiving one token
        every time the ingress compute worker (resource 0) is about to
        block on its input queue — i.e. exactly when it becomes free —
        so a policy admitter can gate dispatch on the shared ingress
        resource (and, through bounded hop queues, on downstream
        backpressure).  With ``admit_fn`` set, ``plan_fn``/``arrivals``/
        ``payloads`` are ignored.

        With ``pools=`` configured the run executes the replicated-tier
        topology instead and returns a ``sim.PoolStreamResult`` (per-
        replica timelines + routes); ``credits`` then receives one token
        whenever *any* tier-0 replica is about to block on its queue."""
        if self.pools is not None:
            return self._run_pool(plan_fn, n_tasks, arrivals, payloads,
                                  admit_fn)
        assert n_tasks > 0
        assert admit_fn is not None or (arrivals is not None
                                        and len(arrivals) >= n_tasks)
        clock = self.clock
        n_hops, n_seg = self.n_hops, self.n_seg
        comp_busy = [0.0] * n_seg
        link_busy = [0.0] * n_hops
        comp_iv: List[List[sim.Interval]] = [[] for _ in range(n_seg)]
        comp_bs: List[List[int]] = [[] for _ in range(n_seg)]
        link_iv: List[List[sim.Interval]] = [[] for _ in range(n_hops)]
        done = [0.0] * n_tasks
        exit_hops: List[Optional[int]] = [None] * n_tasks
        arrs = [0.0] * n_tasks if admit_fn is not None \
            else list(arrivals[:n_tasks])
        self.outputs = {}
        credits = HopQueue(clock) if admit_fn is not None else None
        sink = self.sink

        def record(idx: int, arrival: float):
            arrs[idx] = arrival

        async def admit(q0: HopQueue):
            emit = sink.span if sink is not None else None
            res0 = ("compute", 0)
            for i in range(n_tasks):
                arr = arrivals[i]
                await clock.sleep_until(arr)
                plan = plan_fn(i, arr)
                if isinstance(plan, TaskPlan):
                    plan = plan.as_sim_plan(n_hops)
                assert len(plan.tx) == n_hops, "plan/deployment hop mismatch"
                payload = payloads[i] if payloads is not None else None
                if emit is not None:
                    # put instant = running max of arrivals (serial admitter)
                    t = clock.now
                    emit((ENQUEUE, res0, t, t, i))
                await q0.put(_Msg(i, plan, ready_at=arr, data_done=arr,
                                  payload=payload))
            await q0.put(_STOP)

        async def compute_worker(k: int, qin: HopQueue,
                                 qout: Optional[HopQueue]):
            # span emission is on the hot path: prefix tuples + a bound
            # sink method, not Span(...) construction (see TraceRecorder)
            emit = sink.span if sink is not None else None
            res = ("compute", k, 0)
            cap = self.batch_caps[k]
            while True:
                if k == 0 and credits is not None:
                    await credits.put(None)
                msg = await qin.get()
                if msg is _STOP:
                    if qout is not None:
                        await qout.put(_STOP)
                    return
                if cap > 1:
                    # -------- continuous micro-batching (greedy drain) --
                    # membership is fixed against the queue state at the
                    # *wake* instant: settle() lets every same-instant
                    # put land, then snapshot() freezes the candidate
                    # set before we sleep toward the head's ready time
                    # (draining by len() after that sleep would admit
                    # later arrivals the simulator never sees)
                    await clock.settle()
                    cand = [msg]
                    for m in qin.snapshot():
                        if m is _STOP:
                            break
                        cand.append(m)
                    await clock.sleep_until(msg.ready_at)
                    s = clock.now             # = max(ready, wake)
                    n_b = sim.greedy_batch_size(
                        k, cap, s, [m.plan for m in cand],
                        [m.ready_at for m in cand])
                    if n_b > 1:
                        batch = [msg] + qin.drain(n_b - 1)
                        dur = sim.batched_service_time(
                            [m.plan for m in batch], k)
                        if self.segment_fn is not None:
                            for m in batch:
                                m.payload = self.segment_fn(
                                    k, m.idx, m.payload)
                        comp_busy[k] += dur
                        comp_iv[k].append((s, s + dur))
                        comp_bs[k].append(len(batch))
                        if emit is not None:
                            emit((SERVICE, res, s, s + dur, msg.idx,
                                  tuple(m.idx for m in batch),
                                  msg.ready_at, len(batch)))
                            for m in batch[1:]:
                                if s > m.ready_at:
                                    emit((BATCH_FORM, res, m.ready_at, s,
                                          m.idx))
                        await clock.sleep(dur)
                        # scatter completions in FIFO order; each member
                        # still gates on its own upstream data-done, and
                        # exit-hop members leave the batch at this tier
                        for m in batch:
                            await clock.sleep_until(m.data_done)
                            p = m.plan
                            if k == n_hops or (p.exit_hop is not None
                                               and k >= p.exit_hop):
                                done[m.idx] = clock.now
                                exit_hops[m.idx] = p.exit_hop
                                self.outputs[m.idx] = m.payload
                                if emit is not None \
                                        and p.exit_hop is not None:
                                    t = clock.now
                                    emit((EXIT_RELEASE, res, t, t, m.idx,
                                          None, None, None, p.exit_hop))
                            else:
                                await qout.put(_Msg(
                                    m.idx, p, ready_at=clock.now,
                                    data_done=clock.now,
                                    payload=m.payload))
                        continue
                await clock.sleep_until(msg.ready_at)
                start = clock.now                 # = max(ready, worker free)
                p = msg.plan
                comp = p.compute[k]
                if self.segment_fn is not None:
                    msg.payload = self.segment_fn(k, msg.idx, msg.payload)
                comp_busy[k] += comp
                comp_iv[k].append((start, start + comp))
                comp_bs[k].append(1)
                if emit is not None:
                    emit((SERVICE, res, start, start + comp, msg.idx,
                          (msg.idx,), msg.ready_at, 1))
                data_done = msg.data_done
                # a hop-level semantic exit at segment ``exit_hop``
                # terminates the task on this worker: nothing is ever
                # forwarded, so every downstream resource is released
                last = k == n_hops or \
                    (p.exit_hop is not None and k >= p.exit_hop)
                off = None if last else p.tx_offset[k]
                if last or off is None or off >= comp:   # serial stage
                    await clock.sleep(comp)
                    await clock.sleep_until(data_done)   # c_done gate
                    if last:
                        done[msg.idx] = clock.now
                        exit_hops[msg.idx] = p.exit_hop
                        self.outputs[msg.idx] = msg.payload
                        if emit is not None and p.exit_hop is not None:
                            t = clock.now
                            emit((EXIT_RELEASE, res, t, t, msg.idx,
                                  None, None, None, p.exit_hop))
                    else:
                        await qout.put(_Msg(msg.idx, p, ready_at=clock.now,
                                            data_done=clock.now,
                                            payload=msg.payload))
                else:                                    # Fig. 4 overlap
                    await clock.sleep(off)
                    await qout.put(_Msg(msg.idx, p, ready_at=clock.now,
                                        data_done=clock.now,
                                        payload=msg.payload))
                    await clock.sleep(comp - off)
                    await clock.sleep_until(data_done)

        async def link_worker(k: int, qin: HopQueue, qout: HopQueue):
            link = self.links[k] if k < len(self.links) else None
            emit = sink.span if sink is not None else None
            migrate = self.migrate
            lres = ("link", k)
            nres = ("compute", k + 1)
            while True:
                msg = await qin.get()
                if msg is _STOP:
                    await qout.put(_STOP)
                    return
                await clock.sleep_until(msg.ready_at)    # tx_ready
                if migrate is not None:
                    # the hook sees exactly the simulator's arguments
                    # (the task's own boundary-ready instant, never the
                    # clock), so both engines switch plans identically
                    newp = migrate(msg.idx, k, msg.ready_at)
                    if newp is not None:
                        assert len(newp.tx) == self.n_hops \
                            and newp.exit_hop == msg.plan.exit_hop, \
                            "migrated plan must preserve hop count " \
                            "and exit hop"
                        msg.plan = newp
                        if emit is not None:
                            emit((REPLAN, lres, msg.ready_at, msg.ready_at,
                                  msg.idx, None, None, None, k))
                t_start = clock.now
                dur = msg.plan.tx[k]
                if link is not None and link.trace is not None and dur > 0:
                    # re-integrate the planned bit volume at the actual start
                    bits = dur * link.bandwidth_bps
                    dur = link.transfer_time(bits, t_start)
                t_done = t_start + dur
                roff = msg.plan.rx_offset[k]
                c_ready = t_done if roff is None \
                    else max(t_start + roff, msg.ready_at)
                link_busy[k] += dur
                link_iv[k].append((t_start, t_done))
                if emit is not None:
                    emit((XFER, lres, t_start, t_done, msg.idx, None,
                          msg.ready_at))
                # hold the packet until the receiver may start, then forward
                # while (possibly) still transmitting the tail
                fwd = min(max(c_ready - t_start, 0.0), dur)
                await clock.sleep(fwd)
                if emit is not None:
                    t = clock.now
                    emit((ENQUEUE, nres, t, t, msg.idx))
                await qout.put(_Msg(msg.idx, msg.plan, ready_at=c_ready,
                                    data_done=t_done, payload=msg.payload))
                await clock.sleep(dur - fwd)

        async def main():
            # queue j feeds resource j in the alternating chain
            # compute_0, link_0, compute_1, ..., link_{n-1}, compute_n
            queues = [HopQueue(clock, self.capacity)
                      for _ in range(2 * n_hops + 1)]
            workers = [clock.spawn(admit_fn(queues[0], credits, record)
                                   if admit_fn is not None
                                   else admit(queues[0]))]
            for k in range(n_seg):
                qout = queues[2 * k + 1] if k < n_hops else None
                workers.append(clock.spawn(
                    compute_worker(k, queues[2 * k], qout)))
            for k in range(n_hops):
                workers.append(clock.spawn(
                    link_worker(k, queues[2 * k + 1], queues[2 * k + 2])))
            await asyncio.gather(*workers)

        self.clock.run(main())
        # batch sizes are only meaningful when batching is on; emit ()
        # otherwise so unbatched runs stay field-identical to the
        # legacy simulator output
        batching = any(c > 1 for c in self.batch_caps)
        return sim.StreamResult(
            arrivals=arrs, done=done,
            early_exit=[eh is not None for eh in exit_hops],
            makespan=max(done) - min(arrs),
            compute_busy=tuple(comp_busy), link_busy=tuple(link_busy),
            compute_intervals=tuple(tuple(iv) for iv in comp_iv),
            link_intervals=tuple(tuple(iv) for iv in link_iv),
            exit_hop=exit_hops,
            compute_batch_sizes=tuple(tuple(b) for b in comp_bs)
            if batching else ())

    def _run_pool(self, plan_fn, n_tasks: int,
                  arrivals: Optional[Sequence[float]],
                  payloads: Optional[Sequence[Any]] = None,
                  admit_fn: Optional[Callable] = None
                  ) -> sim.PoolStreamResult:
        """Replicated-tier topology: per tier one dispatcher worker, one
        worker per replica, and (before each hop link) one sequencer
        worker restoring admission order (see the module correspondence
        table).  Differentially pinned to ``sim.simulate_pool_stream``."""
        assert n_tasks > 0
        assert admit_fn is not None or (arrivals is not None
                                        and len(arrivals) >= n_tasks)
        clock = self.clock
        n_hops, n_seg = self.n_hops, self.n_seg
        pools, router = self.pools, self.router
        router.reset(pools)
        replica_busy: List[List[float]] = [[0.0] * p.m for p in pools]
        replica_iv: List[List[List[sim.Interval]]] = \
            [[[] for _ in range(p.m)] for p in pools]
        replica_bs: List[List[List[int]]] = \
            [[[] for _ in range(p.m)] for p in pools]
        link_busy = [0.0] * n_hops
        link_iv: List[List[sim.Interval]] = [[] for _ in range(n_hops)]
        done = [0.0] * n_tasks
        exit_hops: List[Optional[int]] = [None] * n_tasks
        routes: List[List[Optional[int]]] = \
            [[None] * n_seg for _ in range(n_tasks)]
        arrs = [0.0] * n_tasks if admit_fn is not None \
            else list(arrivals[:n_tasks])
        self.outputs = {}
        credits = HopQueue(clock) if admit_fn is not None else None
        sink = self.sink

        def record(idx: int, arrival: float):
            arrs[idx] = arrival

        async def admit(q0: HopQueue):
            emit = sink.span if sink is not None else None
            res0 = ("compute", 0)
            for i in range(n_tasks):
                arr = arrivals[i]
                await clock.sleep_until(arr)
                plan = plan_fn(i, arr)
                if isinstance(plan, TaskPlan):
                    plan = plan.as_sim_plan(n_hops)
                assert len(plan.tx) == n_hops, "plan/deployment hop mismatch"
                payload = payloads[i] if payloads is not None else None
                if emit is not None:
                    t = clock.now
                    emit((ENQUEUE, res0, t, t, i))
                await q0.put(_Msg(i, plan, ready_at=arr, data_done=arr,
                                  payload=payload))
            await q0.put(_STOP)

        async def dispatcher(k: int, qin: HopQueue,
                             rqs: Sequence[HopQueue]):
            # routes in strict queue (= admission) order; decisions read
            # only the message's carried ready time and the router's own
            # per-tier state, never the clock, so they match the staged
            # simulator's placements exactly
            seq = 0
            emit = sink.span if sink is not None else None
            while True:
                msg = await qin.get()
                if msg is _STOP:
                    for rq in rqs:
                        await rq.put(_STOP)
                    return
                r = router.route(k, msg.ready_at, msg.plan.compute[k],
                                 msg.tenant)
                routes[msg.idx][k] = r
                msg.seq = seq
                seq += 1
                if emit is not None:
                    # the placement is a function of the message, not the
                    # clock, so the span is stamped at the task's ready
                    # instant — identically to the staged dispatch
                    t = msg.ready_at
                    emit((ROUTE, ("compute", k, r), t, t, msg.idx, None,
                          t, None, None, r, msg.seq))
                await rqs[r].put(msg)

        async def replica_worker(k: int, r: int, qin: HopQueue,
                                 sq: Optional[HopQueue]):
            # the chain compute worker, speed-scaled; completions are
            # released to the pool's sequencer as (seq, msg | None)
            emit = sink.span if sink is not None else None
            res = ("compute", k, r)
            cap = self.batch_caps[k]
            speed = pools[k].speeds[r]
            while True:
                if k == 0 and credits is not None:
                    await credits.put(None)
                msg = await qin.get()
                if msg is _STOP:
                    if sq is not None:
                        await sq.put(_STOP)
                    return
                if cap > 1:
                    # membership against this replica's queue at the wake
                    # instant (same rule as the chain batching worker)
                    await clock.settle()
                    cand = [msg]
                    for m in qin.snapshot():
                        if m is _STOP:
                            break
                        cand.append(m)
                    await clock.sleep_until(msg.ready_at)
                    s = clock.now
                    n_b = sim.greedy_batch_size(
                        k, cap, s, [m.plan for m in cand],
                        [m.ready_at for m in cand], speed=speed)
                    if n_b > 1:
                        batch = [msg] + qin.drain(n_b - 1)
                        dur = speed * sim.batched_service_time(
                            [m.plan for m in batch], k)
                        if self.segment_fn is not None:
                            for m in batch:
                                m.payload = self.segment_fn(
                                    k, m.idx, m.payload)
                        replica_busy[k][r] += dur
                        replica_iv[k][r].append((s, s + dur))
                        replica_bs[k][r].append(len(batch))
                        if emit is not None:
                            emit((SERVICE, res, s, s + dur, msg.idx,
                                  tuple(m.idx for m in batch),
                                  msg.ready_at, len(batch)))
                            for m in batch[1:]:
                                if s > m.ready_at:
                                    emit((BATCH_FORM, res, m.ready_at, s,
                                          m.idx))
                        await clock.sleep(dur)
                        for m in batch:
                            await clock.sleep_until(m.data_done)
                            p = m.plan
                            if k == n_hops or (p.exit_hop is not None
                                               and k >= p.exit_hop):
                                done[m.idx] = clock.now
                                exit_hops[m.idx] = p.exit_hop
                                self.outputs[m.idx] = m.payload
                                if emit is not None \
                                        and p.exit_hop is not None:
                                    t = clock.now
                                    emit((EXIT_RELEASE, res, t, t, m.idx,
                                          None, None, None, p.exit_hop))
                                if sq is not None:
                                    await sq.put((m.seq, None))
                            else:
                                await sq.put((m.seq, _Msg(
                                    m.idx, p, ready_at=clock.now,
                                    data_done=clock.now,
                                    payload=m.payload, tenant=m.tenant)))
                        continue
                await clock.sleep_until(msg.ready_at)
                start = clock.now             # = max(ready, replica free)
                p = msg.plan
                comp = speed * p.compute[k]
                if self.segment_fn is not None:
                    msg.payload = self.segment_fn(k, msg.idx, msg.payload)
                replica_busy[k][r] += comp
                replica_iv[k][r].append((start, start + comp))
                replica_bs[k][r].append(1)
                if emit is not None:
                    emit((SERVICE, res, start, start + comp, msg.idx,
                          (msg.idx,), msg.ready_at, 1))
                data_done = msg.data_done
                last = k == n_hops or \
                    (p.exit_hop is not None and k >= p.exit_hop)
                off = None if last else p.tx_offset[k]
                if last or off is None or off >= comp:   # serial stage
                    await clock.sleep(comp)
                    await clock.sleep_until(data_done)   # c_done gate
                    if last:
                        done[msg.idx] = clock.now
                        exit_hops[msg.idx] = p.exit_hop
                        self.outputs[msg.idx] = msg.payload
                        if emit is not None and p.exit_hop is not None:
                            t = clock.now
                            emit((EXIT_RELEASE, res, t, t, msg.idx,
                                  None, None, None, p.exit_hop))
                        if sq is not None:
                            await sq.put((msg.seq, None))
                    else:
                        await sq.put((msg.seq, _Msg(
                            msg.idx, p, ready_at=clock.now,
                            data_done=clock.now, payload=msg.payload,
                            tenant=msg.tenant)))
                else:                                    # Fig. 4 overlap
                    await clock.sleep(off)
                    await sq.put((msg.seq, _Msg(
                        msg.idx, p, ready_at=clock.now,
                        data_done=clock.now, payload=msg.payload,
                        tenant=msg.tenant)))
                    await clock.sleep(comp - off)
                    await clock.sleep_until(data_done)

        async def sequencer(k: int, sq: HopQueue, qout: HopQueue, m: int):
            # restore admission order toward the serial hop link: buffer
            # out-of-order releases, forward strictly by seq (a terminal
            # release — (seq, None) — just advances the cursor); the
            # forward instant is therefore the running max of release
            # instants, the expression the simulator's sequencer stage
            # computes
            buf: dict = {}
            next_seq = 0
            stops = 0
            emit = sink.span if sink is not None else None
            lres = ("link", k)
            while True:
                item = await sq.get()
                if item is _STOP:
                    stops += 1
                    if stops == m:
                        assert not buf, "sequencer stopped with buffered " \
                            "tasks (replica lost a release)"
                        await qout.put(_STOP)
                        return
                    continue
                s_id, out = item
                # the get returns at the release's put instant (the
                # sequencer never sleeps between gets, so the clock
                # cannot advance past a queued release) — stamp it as
                # the release instant for the hold span
                buf[s_id] = (out, clock.now)
                while next_seq in buf:
                    nxt, rel = buf.pop(next_seq)
                    next_seq += 1
                    if nxt is not None:
                        # forward instant = running max of releases; any
                        # excess over this task's own release is the
                        # sequencer restoring admission order
                        if emit is not None and clock.now > rel:
                            emit((SEQ_HOLD, lres, rel, clock.now,
                                  nxt.idx))
                        await qout.put(nxt)

        async def link_worker(k: int, qin: HopQueue, qout: HopQueue):
            link = self.links[k] if k < len(self.links) else None
            emit = sink.span if sink is not None else None
            lres = ("link", k)
            nres = ("compute", k + 1)
            while True:
                msg = await qin.get()
                if msg is _STOP:
                    await qout.put(_STOP)
                    return
                await clock.sleep_until(msg.ready_at)    # tx_ready
                t_start = clock.now
                dur = msg.plan.tx[k]
                if link is not None and link.trace is not None and dur > 0:
                    bits = dur * link.bandwidth_bps
                    dur = link.transfer_time(bits, t_start)
                t_done = t_start + dur
                roff = msg.plan.rx_offset[k]
                c_ready = t_done if roff is None \
                    else max(t_start + roff, msg.ready_at)
                link_busy[k] += dur
                link_iv[k].append((t_start, t_done))
                if emit is not None:
                    emit((XFER, lres, t_start, t_done, msg.idx, None,
                          msg.ready_at))
                fwd = min(max(c_ready - t_start, 0.0), dur)
                await clock.sleep(fwd)
                if emit is not None:
                    t = clock.now
                    emit((ENQUEUE, nres, t, t, msg.idx))
                await qout.put(_Msg(msg.idx, msg.plan, ready_at=c_ready,
                                    data_done=t_done, payload=msg.payload,
                                    tenant=msg.tenant))
                await clock.sleep(dur - fwd)

        async def main():
            # per tier: pool input queue -> dispatcher -> replica queues
            # -> replicas -> sequencer -> hop link -> next pool input
            pin = [HopQueue(clock, self.capacity) for _ in range(n_seg)]
            workers = [clock.spawn(admit_fn(pin[0], credits, record)
                                   if admit_fn is not None
                                   else admit(pin[0]))]
            for k in range(n_seg):
                m = pools[k].m
                rqs = [HopQueue(clock, self.capacity) for _ in range(m)]
                sq = HopQueue(clock) if k < n_hops else None
                workers.append(clock.spawn(dispatcher(k, pin[k], rqs)))
                for r in range(m):
                    workers.append(clock.spawn(
                        replica_worker(k, r, rqs[r], sq)))
                if k < n_hops:
                    lq = HopQueue(clock, self.capacity)
                    workers.append(clock.spawn(sequencer(k, sq, lq, m)))
                    workers.append(clock.spawn(
                        link_worker(k, lq, pin[k + 1])))
            await asyncio.gather(*workers)

        self.clock.run(main())
        return sim.PoolStreamResult(
            arrivals=arrs, done=done,
            early_exit=[eh is not None for eh in exit_hops],
            exit_hop=exit_hops,
            makespan=max(done) - min(arrs),
            link_busy=tuple(link_busy),
            link_intervals=tuple(tuple(iv) for iv in link_iv),
            replica_busy=tuple(tuple(rb) for rb in replica_busy),
            replica_intervals=tuple(tuple(tuple(iv) for iv in tier)
                                    for tier in replica_iv),
            replica_batch_sizes=tuple(tuple(tuple(bs) for bs in tier)
                                      for tier in replica_bs),
            routes=tuple(tuple(rt) for rt in routes),
            pools=pools)


def run_pipeline_async(plans: Sequence[TaskPlan],
                       arrivals: Optional[Sequence[float]] = None,
                       arrival_period: float = 0.0,
                       link: Optional[LinkProfile] = None,
                       links: Optional[Sequence[Optional[LinkProfile]]] = None,
                       queue_capacity: int = 0,
                       clock=None,
                       segment_fn=None,
                       payloads: Optional[Sequence[Any]] = None,
                       batch_caps: Optional[Sequence[int]] = None,
                       pools=None, router=None, sink=None,
                       migrate=None) -> PipelineResult:
    """Async-executor counterpart of ``core.pipeline.run_pipeline``: same
    plan normalization and result type, but the stream is *executed* by
    per-resource workers instead of replayed by ``simulate_stream``.
    With ``queue_capacity = 0`` (unbounded) and a ``VirtualClock`` the
    two timelines agree to float precision (including per-tier
    micro-batching via ``batch_caps``).  ``pools`` + ``router`` spawn one
    worker per replica behind per-pool dispatchers and pin against
    ``sim.simulate_pool_stream`` instead.  ``sink`` (a
    ``repro.obs.trace`` span sink) records the executed timeline; the
    same call against ``core.pipeline.run_pipeline`` yields a matching
    trace (``assert_traces_match``).  ``migrate`` is the online
    re-planning hook (see ``sim.simulate_stream``); passing the same
    hook object (reset between runs) to both entry points keeps the
    differential pin across mid-stream plan switches."""
    n = len(plans)
    if arrivals is None:
        arrivals = [i * arrival_period for i in range(n)]
    if links is None:
        links = [link]
    n_hops = max(max(p.n_hops for p in plans), len(links))
    sps = [p.as_sim_plan(n_hops) for p in plans]
    pipe = AsyncHopPipeline(n_hops, links=links, clock=clock,
                            queue_capacity=queue_capacity,
                            segment_fn=segment_fn,
                            batch_caps=batch_caps,
                            pools=pools, router=router, sink=sink,
                            migrate=migrate)
    res = pipe.run(lambda i, _arr: sps[i], n, arrivals, payloads=payloads)
    if isinstance(res, sim.PoolStreamResult):
        from repro.core.pipeline import result_from_pool_stream
        return result_from_pool_stream(res)
    return result_from_stream(res)


# =================================================================== engine
class AsyncCoachEngine(EngineBase):
    """COACH engine on the async hop-queue executor.

    Identical decision sequence to the sync ``CoachEngine`` (decisions are
    made at enqueue time on the end worker, in task order), but the
    induced plans occupy real per-resource workers: with unbounded queues
    and the virtual clock the timeline is pinned to
    ``core.sim.simulate_stream``; ``cfg.queue_capacity`` bounds the hop
    queues (backpressure), ``cfg.per_hop_bits`` enables per-hop adaptive
    precision from per-hop bandwidth EMAs."""

    def run_stream(self, tasks, arrival_period: float, classify,
                   clock=None) -> EngineStats:
        tasks = list(tasks)
        n = len(tasks)
        n_hops = len(self.links)
        acc = {"exits": 0, "wire": 0.0, "bits": [], "correct": []}

        def admit(i: int, t_arr: float) -> TaskPlan:
            task = tasks[i]
            bw = self.link.bps_at(arrival_period * task.id)
            return self.admit_plan(task, bw, t_arr, classify, acc)

        pipe = AsyncHopPipeline(n_hops, links=self.links, clock=clock,
                                queue_capacity=self.cfg.queue_capacity,
                                batch_caps=self.batch_caps,
                                pools=self.pools, router=self.make_router(),
                                sink=self.cfg.trace,
                                migrate=self.cfg.migrate)
        res = pipe.run(admit, n, [i * arrival_period for i in range(n)])
        if isinstance(res, sim.PoolStreamResult):
            from repro.core.pipeline import result_from_pool_stream
            pr = result_from_pool_stream(res)
        else:
            pr = result_from_stream(res)
        return self._stats(pr, n, acc["exits"], acc["bits"], acc["wire"],
                           acc["correct"])
