"""Async hop-queue serving engine: real per-resource workers, pinned to
``core/sim``.

``core.sim.simulate_stream`` models a collaborative deployment as
``2n+1`` alternating serial FIFO resources.  This module *executes* that
model instead of replaying it: one asyncio worker per resource, chained
by bounded ``HopQueue``s, so segment ``k`` of task ``i`` genuinely runs
concurrently with segment ``k-1`` of task ``i+1`` and every hop's
transmission is an awaitable priced by its ``LinkProfile``.

Resource-worker <-> ``core/sim`` correspondence (the invariant the
differential test ``tests/test_async_engine.py`` pins):

  =====================  ==========================================
  ``simulate_stream``    ``AsyncHopPipeline``
  =====================  ==========================================
  ``compute_free[k]``    compute worker ``k``'s position in virtual
                         time (a serial worker is "free" exactly when
                         its loop returns to ``HopQueue.get``)
  ``link_free[k]``       link worker ``k``'s position in virtual time
  task admission order   FIFO order of the queue chain (each worker
                         processes and forwards in order)
  ``tx_ready``           ``_Msg.ready_at`` of the message the compute
                         worker forwards to its link queue (``prev_done``
                         for a serial plan, ``prev_start + tx_offset``
                         for an overlapped one)
  ``c_ready``            ``_Msg.ready_at`` the link worker stamps for
                         the downstream compute worker
                         (``t_done``, or ``t_start + rx_offset``)
  ``c_done = max(...)``  the downstream worker sleeps its compute time,
                         then ``sleep_until(data_done)`` — it cannot
                         finish before all data has arrived
  trace re-integration   the link worker reprices the planned bit
                         volume with ``LinkProfile.transfer_time`` at
                         the transfer's actual virtual start
  =====================  ==========================================

Timing comes from a pluggable clock: ``VirtualClock`` is a deterministic
discrete-event driver (timers fire only when every worker is blocked, so
a run is a bit-reproducible event simulation — this is what makes the
executor directly comparable to ``simulate_stream``); ``WallClock`` maps
the same awaits onto real ``asyncio.sleep``.  With unbounded queues the
virtual-clock timeline reproduces ``simulate_stream`` exactly; bounded
queues add admission/backpressure (an upstream worker stalls on ``put``
when its hop queue is full), which the pure simulator does not model.

``AsyncCoachEngine`` rides the online component on top: ``OnlineScheduler``
decisions (early exit Eq. 10, adaptive precision Eq. 11) are made at
enqueue time on the end worker, in task order — concurrency never changes
*decisions*, only timing — and per-hop adaptive bits pick a precision per
``WirePacket`` hop from per-hop bandwidth EMAs
(``OnlineScheduler.choose_hop_bits``).

Multi-tenant admission lives one layer up in ``repro.serving.tenancy``:
``AsyncHopPipeline.run`` accepts a pluggable admitter (``admit_fn``)
which is released by *ingress credits* — a token issued each time the
end worker is about to block on its input queue — so a policy scheduler
can gate per-tenant streams on the shared ingress resource.
"""

from __future__ import annotations

import asyncio
import collections
import contextlib
import dataclasses
import heapq
import itertools
from typing import Any, Callable, List, Optional, Sequence, Tuple

from repro.core import sim
from repro.core.costs import LinkProfile
from repro.core.pipeline import (PipelineResult, TaskPlan,
                                 result_from_stream)
from repro.serving.base import EngineBase, EngineStats

__all__ = ["VirtualClock", "WallClock", "HopQueue", "AsyncHopPipeline",
           "run_pipeline_async", "AsyncCoachEngine"]


# ==================================================================== clocks
class VirtualClock:
    """Deterministic virtual-time driver for a set of asyncio workers.

    Every blocking point of the executor (timed sleeps, queue gets/puts)
    registers with the clock.  A driver coroutine fires the earliest
    pending timer only when *all* registered workers are blocked, so the
    run is a discrete-event simulation: virtual time jumps from event to
    event and the interleaving is reproducible.
    """

    def __init__(self):
        self.now = 0.0
        # (when, seq, future, is_settle_sentinel); ordered by (when, seq)
        self._timers: List[Tuple[float, int, asyncio.Future, bool]] = []
        self._seq = itertools.count()
        self._blocked = 0   # workers suspended in a clock primitive
        self._live = 0      # workers spawned and not yet finished
        self._idle: Optional[asyncio.Event] = None

    # ---- bookkeeping shared with HopQueue
    def _maybe_idle(self):
        if self._idle is not None and self._blocked >= self._live:
            self._idle.set()

    async def _wait(self, fut: asyncio.Future):
        """Suspend the calling worker until ``_wake(fut)``."""
        self._blocked += 1
        self._maybe_idle()
        return await fut

    def _wake(self, fut: asyncio.Future, value: Any = None):
        self._blocked -= 1
        if not fut.done():
            fut.set_result(value)

    # ---- public interface
    async def sleep(self, dt: float):
        await self.sleep_until(self.now + dt)

    async def sleep_until(self, when: float):
        if when <= self.now:
            return
        fut = asyncio.get_event_loop().create_future()
        heapq.heappush(self._timers, (when, next(self._seq), fut, False))
        await self._wait(fut)

    async def settle(self):
        """Suspend until every event scheduled for the *current* virtual
        instant has fired.  A worker woken by a direct queue handoff may
        run while timers for the same instant are still pending in the
        heap; a sentinel timer pushed at ``now`` sorts after them (same
        ``when``, later seq), so awaiting it yields until the instant
        has fully played out.  Admission dispatchers use this before
        sampling queue state (``repro.serving.tenancy``), batching
        compute workers before snapshotting their hop queue.

        Only *real* timers count: another worker's settle sentinel is
        not pending work, and honouring it would livelock two settles
        at the same instant (each re-arming against the other's
        sentinel forever)."""
        while any(when <= self.now and not sentinel
                  for (when, _, _, sentinel) in self._timers):
            fut = asyncio.get_event_loop().create_future()
            heapq.heappush(self._timers,
                           (self.now, next(self._seq), fut, True))
            await self._wait(fut)

    def spawn(self, coro) -> "asyncio.Task":
        """Register + start a worker; only spawned workers count toward
        the quiescence check that gates timer firing."""
        self._live += 1

        async def wrapped():
            try:
                return await coro
            finally:
                self._live -= 1
                self._maybe_idle()

        return asyncio.ensure_future(wrapped())

    async def _drive(self):
        while True:
            await self._idle.wait()
            self._idle.clear()
            if self._live == 0:
                return
            if not self._timers:
                raise RuntimeError(
                    "virtual-clock deadlock: all workers blocked with no "
                    "pending timer")
            when, _, fut, _sentinel = heapq.heappop(self._timers)
            self.now = max(self.now, when)
            self._wake(fut)

    def run(self, main):
        """Run ``main`` (which spawns workers via ``spawn``) to completion
        under virtual time; returns its result."""
        return asyncio.run(self._run(main))

    async def _run(self, main):
        self._idle = asyncio.Event()
        driver = asyncio.ensure_future(self._drive())
        main_t = asyncio.ensure_future(main)
        try:
            await asyncio.wait({driver, main_t},
                               return_when=asyncio.FIRST_COMPLETED)
            if driver.done() and driver.exception() is not None:
                main_t.cancel()
                with contextlib.suppress(asyncio.CancelledError):
                    await main_t
                raise driver.exception()
            return await main_t
        finally:
            if not driver.done():
                driver.cancel()
                with contextlib.suppress(asyncio.CancelledError):
                    await driver


class WallClock:
    """Real-time realization of the same clock interface: sleeps map to
    ``asyncio.sleep`` and ``now`` is the loop clock relative to the start
    of the run (best effort — scheduling jitter is real here)."""

    def __init__(self):
        self._t0: Optional[float] = None

    @property
    def now(self) -> float:
        loop = asyncio.get_event_loop()
        if self._t0 is None:
            self._t0 = loop.time()
        return loop.time() - self._t0

    async def sleep(self, dt: float):
        if dt > 0:
            await asyncio.sleep(dt)

    async def sleep_until(self, when: float):
        await self.sleep(when - self.now)

    async def settle(self):
        """Best-effort wall-clock counterpart of ``VirtualClock.settle``:
        yield to the scheduler a few times so same-instant callbacks run."""
        for _ in range(4):
            await asyncio.sleep(0)

    async def _wait(self, fut: asyncio.Future):
        return await fut

    def _wake(self, fut: asyncio.Future, value: Any = None):
        if not fut.done():
            fut.set_result(value)

    def spawn(self, coro) -> "asyncio.Task":
        return asyncio.ensure_future(coro)

    def run(self, main):
        return asyncio.run(main)


# ==================================================================== queue
class HopQueue:
    """Bounded FIFO channel between two pipeline resources.

    Like ``asyncio.Queue`` but clock-aware: a worker blocked in ``get``
    (empty) or ``put`` (full) is registered with the clock so the virtual
    driver knows the pipeline is quiescent.  ``maxsize = 0`` means
    unbounded (the waiting room of ``core/sim``'s serial resources)."""

    def __init__(self, clock, maxsize: int = 0):
        self._clock = clock
        self._max = maxsize
        self._items = collections.deque()
        self._getters = collections.deque()
        self._putters = collections.deque()  # (future, item)

    def __len__(self):
        return len(self._items)

    async def put(self, item):
        if self._getters:                       # direct handoff
            self._clock._wake(self._getters.popleft(), item)
            return
        if self._max and len(self._items) >= self._max:
            fut = asyncio.get_event_loop().create_future()
            self._putters.append((fut, item))
            await self._clock._wait(fut)        # backpressure: stall upstream
            return
        self._items.append(item)

    def _admit_putter(self):
        if self._putters:                       # a slot freed up
            fut, pitem = self._putters.popleft()
            self._items.append(pitem)
            self._clock._wake(fut)

    async def get(self):
        if self._items:
            item = self._items.popleft()
            self._admit_putter()
            return item
        fut = asyncio.get_event_loop().create_future()
        self._getters.append(fut)
        return await self._clock._wait(fut)

    def get_nowait(self):
        """Pop the head item without blocking; raises
        ``asyncio.QueueEmpty`` when nothing is queued."""
        if not self._items:
            raise asyncio.QueueEmpty
        item = self._items.popleft()
        self._admit_putter()
        return item

    def drain(self, n: int) -> list:
        """Pop up to ``n`` items (FIFO), admitting one blocked putter per
        freed slot.  Never blocks; returns what was there.

        A batching worker must decide *membership* from ``snapshot()``
        taken at its wake instant, then ``drain`` exactly that many: the
        naive pattern of sizing the drain from ``len(queue)`` at drain
        time races with same-timeline producers — a worker that slept
        between waking and draining would observe items enqueued *after*
        its wake instant, diverging from the simulator's arithmetic rule
        (which gathers the queue state at the wake instant only)."""
        out = []
        while len(out) < n and self._items:
            out.append(self._items.popleft())
            self._admit_putter()
        return out

    def snapshot(self) -> tuple:
        """The queued items at this instant, in FIFO order, not removed.
        Take this at the wake instant (after ``clock.settle()`` so every
        same-instant put has landed) to fix a batch's candidate set."""
        return tuple(self._items)


# ================================================================= executor
@dataclasses.dataclass
class _Msg:
    """One task's in-flight state between two adjacent resources."""
    idx: int
    plan: sim.SimPlan
    ready_at: float     # earliest time the receiving resource may start it
    data_done: float    # when the upstream transfer fully lands (c_done gate)
    payload: Any = None


_STOP = object()


class AsyncHopPipeline:
    """``2n+1`` resource workers chained by hop queues (see module doc).

    ``segment_fn(k, idx, payload) -> payload`` optionally runs real
    compute (e.g. ``CollabRuntime.segment_handle(k)``) on each compute
    worker; the last segment's outputs are collected in ``outputs``.
    """

    def __init__(self, n_hops: int,
                 links: Optional[Sequence[Optional[LinkProfile]]] = None,
                 clock=None, queue_capacity: int = 0,
                 segment_fn: Optional[Callable[[int, int, Any], Any]] = None,
                 batch_caps: Optional[Sequence[int]] = None):
        assert n_hops >= 1
        self.n_hops = n_hops
        self.n_seg = n_hops + 1
        self.links = list(links) if links is not None else [None] * n_hops
        self.clock = clock if clock is not None else VirtualClock()
        self.capacity = queue_capacity
        self.segment_fn = segment_fn
        # per-tier continuous micro-batching caps (None / 1 = unbatched);
        # missing trailing tiers default to 1
        self.batch_caps = [1] * self.n_seg
        if batch_caps is not None:
            for k, c in enumerate(batch_caps[:self.n_seg]):
                assert int(c) >= 1, "batch caps must be >= 1"
                self.batch_caps[k] = int(c)
        self.outputs: dict = {}

    def run(self, plan_fn: Callable[[int, float], Any], n_tasks: int,
            arrivals: Optional[Sequence[float]],
            payloads: Optional[Sequence[Any]] = None,
            admit_fn: Optional[Callable] = None) -> sim.StreamResult:
        """Admit ``n_tasks`` tasks at ``arrivals`` and execute the chain.

        ``plan_fn(i, t_arr)`` is called *at enqueue time* (in task order,
        at the task's virtual arrival) and returns the task's
        ``sim.SimPlan`` (or a ``TaskPlan``, normalized here) — this is
        the hook where online decisions happen.

        ``admit_fn(q0, credits, record)`` replaces the built-in
        single-stream admission worker (multi-tenant admission lives in
        ``repro.serving.tenancy``).  It must put exactly ``n_tasks``
        ``_Msg``s with distinct ``idx`` in ``[0, n_tasks)`` into ``q0``
        followed by ``_STOP``, and call ``record(idx, arrival)`` for
        each.  ``credits`` is a clock-aware queue receiving one token
        every time the ingress compute worker (resource 0) is about to
        block on its input queue — i.e. exactly when it becomes free —
        so a policy admitter can gate dispatch on the shared ingress
        resource (and, through bounded hop queues, on downstream
        backpressure).  With ``admit_fn`` set, ``plan_fn``/``arrivals``/
        ``payloads`` are ignored."""
        assert n_tasks > 0
        assert admit_fn is not None or (arrivals is not None
                                        and len(arrivals) >= n_tasks)
        clock = self.clock
        n_hops, n_seg = self.n_hops, self.n_seg
        comp_busy = [0.0] * n_seg
        link_busy = [0.0] * n_hops
        comp_iv: List[List[sim.Interval]] = [[] for _ in range(n_seg)]
        comp_bs: List[List[int]] = [[] for _ in range(n_seg)]
        link_iv: List[List[sim.Interval]] = [[] for _ in range(n_hops)]
        done = [0.0] * n_tasks
        exit_hops: List[Optional[int]] = [None] * n_tasks
        arrs = [0.0] * n_tasks if admit_fn is not None \
            else list(arrivals[:n_tasks])
        self.outputs = {}
        credits = HopQueue(clock) if admit_fn is not None else None

        def record(idx: int, arrival: float):
            arrs[idx] = arrival

        async def admit(q0: HopQueue):
            for i in range(n_tasks):
                arr = arrivals[i]
                await clock.sleep_until(arr)
                plan = plan_fn(i, arr)
                if isinstance(plan, TaskPlan):
                    plan = plan.as_sim_plan(n_hops)
                assert len(plan.tx) == n_hops, "plan/deployment hop mismatch"
                payload = payloads[i] if payloads is not None else None
                await q0.put(_Msg(i, plan, ready_at=arr, data_done=arr,
                                  payload=payload))
            await q0.put(_STOP)

        async def compute_worker(k: int, qin: HopQueue,
                                 qout: Optional[HopQueue]):
            cap = self.batch_caps[k]
            while True:
                if k == 0 and credits is not None:
                    await credits.put(None)
                msg = await qin.get()
                if msg is _STOP:
                    if qout is not None:
                        await qout.put(_STOP)
                    return
                if cap > 1:
                    # -------- continuous micro-batching (greedy drain) --
                    # membership is fixed against the queue state at the
                    # *wake* instant: settle() lets every same-instant
                    # put land, then snapshot() freezes the candidate
                    # set before we sleep toward the head's ready time
                    # (draining by len() after that sleep would admit
                    # later arrivals the simulator never sees)
                    await clock.settle()
                    cand = [msg]
                    for m in qin.snapshot():
                        if m is _STOP:
                            break
                        cand.append(m)
                    await clock.sleep_until(msg.ready_at)
                    s = clock.now             # = max(ready, wake)
                    n_b = sim.greedy_batch_size(
                        k, cap, s, [m.plan for m in cand],
                        [m.ready_at for m in cand])
                    if n_b > 1:
                        batch = [msg] + qin.drain(n_b - 1)
                        dur = sim.batched_service_time(
                            [m.plan for m in batch], k)
                        if self.segment_fn is not None:
                            for m in batch:
                                m.payload = self.segment_fn(
                                    k, m.idx, m.payload)
                        comp_busy[k] += dur
                        comp_iv[k].append((s, s + dur))
                        comp_bs[k].append(len(batch))
                        await clock.sleep(dur)
                        # scatter completions in FIFO order; each member
                        # still gates on its own upstream data-done, and
                        # exit-hop members leave the batch at this tier
                        for m in batch:
                            await clock.sleep_until(m.data_done)
                            p = m.plan
                            if k == n_hops or (p.exit_hop is not None
                                               and k >= p.exit_hop):
                                done[m.idx] = clock.now
                                exit_hops[m.idx] = p.exit_hop
                                self.outputs[m.idx] = m.payload
                            else:
                                await qout.put(_Msg(
                                    m.idx, p, ready_at=clock.now,
                                    data_done=clock.now,
                                    payload=m.payload))
                        continue
                await clock.sleep_until(msg.ready_at)
                start = clock.now                 # = max(ready, worker free)
                p = msg.plan
                comp = p.compute[k]
                if self.segment_fn is not None:
                    msg.payload = self.segment_fn(k, msg.idx, msg.payload)
                comp_busy[k] += comp
                comp_iv[k].append((start, start + comp))
                comp_bs[k].append(1)
                data_done = msg.data_done
                # a hop-level semantic exit at segment ``exit_hop``
                # terminates the task on this worker: nothing is ever
                # forwarded, so every downstream resource is released
                last = k == n_hops or \
                    (p.exit_hop is not None and k >= p.exit_hop)
                off = None if last else p.tx_offset[k]
                if last or off is None or off >= comp:   # serial stage
                    await clock.sleep(comp)
                    await clock.sleep_until(data_done)   # c_done gate
                    if last:
                        done[msg.idx] = clock.now
                        exit_hops[msg.idx] = p.exit_hop
                        self.outputs[msg.idx] = msg.payload
                    else:
                        await qout.put(_Msg(msg.idx, p, ready_at=clock.now,
                                            data_done=clock.now,
                                            payload=msg.payload))
                else:                                    # Fig. 4 overlap
                    await clock.sleep(off)
                    await qout.put(_Msg(msg.idx, p, ready_at=clock.now,
                                        data_done=clock.now,
                                        payload=msg.payload))
                    await clock.sleep(comp - off)
                    await clock.sleep_until(data_done)

        async def link_worker(k: int, qin: HopQueue, qout: HopQueue):
            link = self.links[k] if k < len(self.links) else None
            while True:
                msg = await qin.get()
                if msg is _STOP:
                    await qout.put(_STOP)
                    return
                await clock.sleep_until(msg.ready_at)    # tx_ready
                t_start = clock.now
                dur = msg.plan.tx[k]
                if link is not None and link.trace is not None and dur > 0:
                    # re-integrate the planned bit volume at the actual start
                    bits = dur * link.bandwidth_bps
                    dur = link.transfer_time(bits, t_start)
                t_done = t_start + dur
                roff = msg.plan.rx_offset[k]
                c_ready = t_done if roff is None \
                    else max(t_start + roff, msg.ready_at)
                link_busy[k] += dur
                link_iv[k].append((t_start, t_done))
                # hold the packet until the receiver may start, then forward
                # while (possibly) still transmitting the tail
                fwd = min(max(c_ready - t_start, 0.0), dur)
                await clock.sleep(fwd)
                await qout.put(_Msg(msg.idx, msg.plan, ready_at=c_ready,
                                    data_done=t_done, payload=msg.payload))
                await clock.sleep(dur - fwd)

        async def main():
            # queue j feeds resource j in the alternating chain
            # compute_0, link_0, compute_1, ..., link_{n-1}, compute_n
            queues = [HopQueue(clock, self.capacity)
                      for _ in range(2 * n_hops + 1)]
            workers = [clock.spawn(admit_fn(queues[0], credits, record)
                                   if admit_fn is not None
                                   else admit(queues[0]))]
            for k in range(n_seg):
                qout = queues[2 * k + 1] if k < n_hops else None
                workers.append(clock.spawn(
                    compute_worker(k, queues[2 * k], qout)))
            for k in range(n_hops):
                workers.append(clock.spawn(
                    link_worker(k, queues[2 * k + 1], queues[2 * k + 2])))
            await asyncio.gather(*workers)

        self.clock.run(main())
        # batch sizes are only meaningful when batching is on; emit ()
        # otherwise so unbatched runs stay field-identical to the
        # legacy simulator output
        batching = any(c > 1 for c in self.batch_caps)
        return sim.StreamResult(
            arrivals=arrs, done=done,
            early_exit=[eh is not None for eh in exit_hops],
            makespan=max(done) - min(arrs),
            compute_busy=tuple(comp_busy), link_busy=tuple(link_busy),
            compute_intervals=tuple(tuple(iv) for iv in comp_iv),
            link_intervals=tuple(tuple(iv) for iv in link_iv),
            exit_hop=exit_hops,
            compute_batch_sizes=tuple(tuple(b) for b in comp_bs)
            if batching else ())


def run_pipeline_async(plans: Sequence[TaskPlan],
                       arrivals: Optional[Sequence[float]] = None,
                       arrival_period: float = 0.0,
                       link: Optional[LinkProfile] = None,
                       links: Optional[Sequence[Optional[LinkProfile]]] = None,
                       queue_capacity: int = 0,
                       clock=None,
                       segment_fn=None,
                       payloads: Optional[Sequence[Any]] = None,
                       batch_caps: Optional[Sequence[int]] = None
                       ) -> PipelineResult:
    """Async-executor counterpart of ``core.pipeline.run_pipeline``: same
    plan normalization and result type, but the stream is *executed* by
    per-resource workers instead of replayed by ``simulate_stream``.
    With ``queue_capacity = 0`` (unbounded) and a ``VirtualClock`` the
    two timelines agree to float precision (including per-tier
    micro-batching via ``batch_caps``)."""
    n = len(plans)
    if arrivals is None:
        arrivals = [i * arrival_period for i in range(n)]
    if links is None:
        links = [link]
    n_hops = max(max(p.n_hops for p in plans), len(links))
    sps = [p.as_sim_plan(n_hops) for p in plans]
    pipe = AsyncHopPipeline(n_hops, links=links, clock=clock,
                            queue_capacity=queue_capacity,
                            segment_fn=segment_fn,
                            batch_caps=batch_caps)
    res = pipe.run(lambda i, _arr: sps[i], n, arrivals, payloads=payloads)
    return result_from_stream(res)


# =================================================================== engine
class AsyncCoachEngine(EngineBase):
    """COACH engine on the async hop-queue executor.

    Identical decision sequence to the sync ``CoachEngine`` (decisions are
    made at enqueue time on the end worker, in task order), but the
    induced plans occupy real per-resource workers: with unbounded queues
    and the virtual clock the timeline is pinned to
    ``core.sim.simulate_stream``; ``cfg.queue_capacity`` bounds the hop
    queues (backpressure), ``cfg.per_hop_bits`` enables per-hop adaptive
    precision from per-hop bandwidth EMAs."""

    def run_stream(self, tasks, arrival_period: float, classify,
                   clock=None) -> EngineStats:
        tasks = list(tasks)
        n = len(tasks)
        n_hops = len(self.links)
        acc = {"exits": 0, "wire": 0.0, "bits": [], "correct": []}

        def admit(i: int, t_arr: float) -> TaskPlan:
            task = tasks[i]
            bw = self.link.bps_at(arrival_period * task.id)
            return self.admit_plan(task, bw, t_arr, classify, acc)

        pipe = AsyncHopPipeline(n_hops, links=self.links, clock=clock,
                                queue_capacity=self.cfg.queue_capacity,
                                batch_caps=self.batch_caps)
        res = pipe.run(admit, n, [i * arrival_period for i in range(n)])
        pr = result_from_stream(res)
        return self._stats(pr, n, acc["exits"], acc["bits"], acc["wire"],
                           acc["correct"])
