"""Autoregressive generation on top of prefill + decode_step — the serving
substrate's inner loop (greedy or temperature sampling), jitted once per
(batch, cache) shape.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig


def generate(params, cfg: ModelConfig, prompt, max_new_tokens: int,
             *, max_seq: Optional[int] = None, temperature: float = 0.0,
             key=None):
    """prompt: (B, S0) int32.  Returns (B, S0 + max_new_tokens) tokens."""
    assert cfg.supports_decode and not cfg.embed_inputs
    B, S0 = prompt.shape
    max_seq = max_seq or (S0 + max_new_tokens)

    logits, cache = jax.jit(
        functools.partial(M.prefill, cfg=cfg, max_seq=max_seq)
    )(params, inputs=prompt)

    step = jax.jit(functools.partial(M.decode_step, cfg=cfg))

    def pick(lg, k):
        if temperature <= 0.0:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        return jax.random.categorical(k, lg / temperature).astype(jnp.int32)

    key = key if key is not None else jax.random.PRNGKey(0)
    toks = prompt
    nxt = pick(logits, key)[:, None]
    for t in range(max_new_tokens):
        toks = jnp.concatenate([toks, nxt], axis=1)
        if t == max_new_tokens - 1:
            break
        logits, cache = step(params, cache=cache, inputs=nxt,
                             pos=jnp.int32(S0 + t))
        key, sub = jax.random.split(key)
        nxt = pick(logits, sub)[:, None]
    return toks
