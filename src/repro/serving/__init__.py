from repro.serving.async_engine import (AsyncCoachEngine, AsyncHopPipeline,
                                        HopQueue, VirtualClock, WallClock,
                                        run_pipeline_async)
from repro.serving.base import EngineConfig, EngineStats
from repro.serving.engine import CoachEngine
from repro.serving.generate import generate
from repro.serving.routing import (ROUTER_POLICIES, JoinShortestQueue,
                                   PowerOfTwoChoices, RandomRouter,
                                   RouterPolicy, TenantAffinity,
                                   make_router)
from repro.serving.tenancy import (ADMISSION_POLICIES, FifoAdmission,
                                   MultiTenantCoachEngine,
                                   MultiTenantHopPipeline,
                                   RoundRobinAdmission, TenantSpec,
                                   WeightedDeficitRoundRobin, make_policy,
                                   run_multitenant_async)
