from repro.serving.engine import CoachEngine, EngineConfig, EngineStats
from repro.serving.generate import generate
