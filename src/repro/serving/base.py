"""Shared machinery of the COACH serving engines.

``EngineBase`` owns everything that must be *identical* between the
synchronous reference engine (``repro.serving.engine.CoachEngine``) and
the async hop-queue engine (``repro.serving.async_engine``): offline
stage times, semantic cache + threshold calibration, the online
scheduler, per-task decision making, and TaskPlan construction.  The two
engines differ only in *how* the resulting plans occupy the ``2n+1``
resources — one task at a time through ``core.sim.simulate_stream``
(sync), or concurrently through per-resource asyncio workers (async) —
so concurrency can never change decisions, only timing.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core import online as ON
from repro.core.costs import DeviceProfile, LinkProfile
from repro.core.pipeline import PipelineResult, TaskPlan
from repro.core.schedule import StageTimes


@dataclasses.dataclass
class EngineConfig:
    bits_levels: Sequence[int] = (3, 4, 5, 6, 8)
    default_bits: int = 8
    update_centers: bool = True
    eps: float = 0.005
    # ---- async hop-queue engine knobs
    queue_capacity: int = 64   # bounded per-hop queue depth (0 = unbounded)
    per_hop_bits: bool = True  # per-hop adaptive precision from hop EMAs


@dataclasses.dataclass
class EngineStats:
    pipeline: PipelineResult
    exit_ratio: float
    mean_bits: float
    wire_kb_per_task: float
    accuracy: float


class EngineBase:
    """Offline plan + online decision layer shared by both engines."""

    def __init__(self, runtime, stage_times: StageTimes,
                 end_dev: DeviceProfile, link: LinkProfile,
                 cloud_dev: DeviceProfile, n_labels: int,
                 calib_feats: np.ndarray, calib_labels: np.ndarray,
                 cfg: Optional[EngineConfig] = None,
                 boundary_elems: Optional[int] = None,
                 links: Optional[Sequence[LinkProfile]] = None,
                 hop_bits_offline: Optional[Sequence[int]] = None):
        """``links`` (one per hop, first = the end device's uplink)
        activates the multi-hop path; omitting it keeps the classic
        end->link->cloud deployment with ``link`` as the only hop.

        ``hop_bits_offline`` is the offline partition's per-hop boundary
        precision (e.g. the mean of ``decision.all_hop_bits[k]``); it is
        what prices ``stage_times.link[k]`` back to a boundary element
        count, so per-hop adaptive bits retime the *true* wire volume.
        Defaults to ``cfg.default_bits`` on every hop.

        ``cfg`` defaults to a fresh ``EngineConfig`` per engine (a shared
        mutable default instance would leak config edits across engines).
        """
        self.rt = runtime
        self.st = stage_times
        self.links = list(links) if links is not None else [link]
        self.link = self.links[0]
        assert len(self.links) == stage_times.n_hops, \
            "need one link per stage-time hop"
        self.cfg = cfg if cfg is not None else EngineConfig()
        cfg = self.cfg
        dim = calib_feats.shape[1]
        self.cache = ON.SemanticCache(n_labels, dim)
        self.cache.warm_up(calib_feats, calib_labels)
        self.th = ON.calibrate_thresholds(self.cache, calib_feats,
                                          calib_labels, eps=cfg.eps,
                                          bit_levels=cfg.bits_levels)
        elems = boundary_elems or int(calib_feats.shape[1])
        offline_bits = list(hop_bits_offline) if hop_bits_offline is not None \
            else [cfg.default_bits] * self.st.n_hops
        assert len(offline_bits) == self.st.n_hops, \
            "need one offline precision per hop"
        # wire volume of hop k >= 1: the offline plan's occupation of link
        # k priced back to elements at that hop's offline precision
        hop_elems = [int(elems)] + [
            max(1, int(self.st.link[k] * self.links[k].bandwidth_bps
                       / offline_bits[k]))
            for k in range(1, self.st.n_hops)]
        self.sched = ON.OnlineScheduler(
            self.cache, self.th, elems, stage_times.T_e, stage_times.T_c,
            update_centers=cfg.update_centers,
            hop_elems=hop_elems, stage_compute=stage_times.compute)

    # ------------------------------------------------------------ decisions
    def decide(self, task, bw: float, classify):
        """One COACH online decision (Eq. 10/11).  ``classify(task) ->
        (features, predicted_label)``: the caller runs the real model
        (CollabRuntime) or a proxy.  Identical call sequence in both
        engines, so a seeded stream yields identical decisions."""
        feats, pred = classify(task)
        dec = self.sched.step(feats, bandwidth_bps=bw)
        return dec, feats, pred

    def plan_for(self, dec: ON.OnlineDecision, bw: float,
                 hop_bits: Optional[Sequence[int]] = None
                 ) -> Tuple[TaskPlan, float]:
        """Build the per-task pipeline plan from an online decision.

        Returns ``(plan, hop0_wire_bits)``.  Without ``hop_bits`` the
        adaptive precision retimes only the end device's uplink and the
        inner hops keep their offline-planned occupation (the sync
        reference semantics); with ``hop_bits`` every hop is retimed from
        its chosen precision and bandwidth EMA (per-hop adaptive bits)."""
        st = self.st
        if dec.early_exit:
            return TaskPlan(st.T_e, 0.0, 0.0, True), 0.0
        bits = dec.bits or self.cfg.default_bits
        wire_bits = self.sched.elems * bits
        t_tx = wire_bits / bw
        if st.n_hops == 1:
            return TaskPlan(
                st.T_e, t_tx, st.T_c,
                tx_offset=min(st.first_tx_offset, st.T_e),
                cloud_offset=st.cloud_start_offset), wire_bits
        if hop_bits is None:
            tx: Tuple[float, ...] = (t_tx,) + tuple(st.link[1:])
        else:
            assert len(hop_bits) == st.n_hops
            retimed: List[float] = [t_tx]
            for k in range(1, st.n_hops):
                bw_k = self.sched.hop_bandwidth(k) \
                    or self.links[k].bandwidth_bps
                retimed.append(self.sched.hop_elems[k] * hop_bits[k] / bw_k)
            tx = tuple(retimed)
        return TaskPlan.multihop(
            compute=st.compute, tx=tx,
            tx_offsets=tuple(min(st.tx_offsets[k], st.compute[k])
                             for k in range(st.n_hops)),
            rx_offsets=st.rx_offsets), wire_bits

    def admit_plan(self, task, bw: float, t_bw: float, classify,
                   acc: dict) -> TaskPlan:
        """One enqueue-time decision + plan, with shared accounting.

        ``bw`` prices the uplink for Eq. 11; ``t_bw`` is the wall/virtual
        time at which the per-hop bandwidths are observed (per-hop
        adaptive bits, when enabled).  ``acc`` accumulates the decision
        aggregates every engine reports: ``exits`` (int), ``wire``
        (float, bits), ``bits`` (list), ``correct`` (list).  Used by the
        async single-stream engine and per-tenant by the multi-tenant
        engine, so decision accounting can never diverge between them."""
        dec, feats, pred = self.decide(task, bw, classify)
        hop_bits = None
        if dec.early_exit:
            acc["exits"] += 1
            acc["correct"].append(dec.result == task.label)
        else:
            if self.cfg.per_hop_bits and self.st.n_hops > 1:
                for k in range(1, self.st.n_hops):
                    self.sched.observe_hop_bandwidth(
                        k, self.links[k].bps_at(t_bw))
                # hop 0 keeps the Eq. 11 choice already in dec.bits
                chosen = self.sched.choose_hop_bits(
                    dec.required_bits or self.cfg.default_bits)
                hop_bits = (dec.bits or self.cfg.default_bits,) + chosen[1:]
            acc["bits"].append(dec.bits or self.cfg.default_bits)
            acc["correct"].append(pred == task.label)
            self.sched.report_label(feats, task.label)
        plan, wire_bits = self.plan_for(dec, bw, hop_bits=hop_bits)
        acc["wire"] += wire_bits
        return plan

    # ------------------------------------------------------------ reporting
    def _stats(self, pipeline: PipelineResult, n: int, exits: int,
               bits_used: Sequence[int], wire_bits_total: float,
               correct: Sequence[bool]) -> EngineStats:
        return EngineStats(
            pipeline=pipeline,
            exit_ratio=exits / n,
            mean_bits=float(np.mean(bits_used)) if bits_used else 0.0,
            wire_kb_per_task=wire_bits_total / 8e3 / n,
            accuracy=float(np.mean(correct)),
        )
