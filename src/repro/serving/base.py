"""Shared machinery of the COACH serving engines.

``EngineBase`` owns everything that must be *identical* between the
synchronous reference engine (``repro.serving.engine.CoachEngine``) and
the async hop-queue engine (``repro.serving.async_engine``): offline
stage times, semantic cache + threshold calibration, the online
scheduler, per-task decision making, and TaskPlan construction.  The two
engines differ only in *how* the resulting plans occupy the ``2n+1``
resources — one task at a time through ``core.sim.simulate_stream``
(sync), or concurrently through per-resource asyncio workers (async) —
so concurrency can never change decisions, only timing.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import online as ON
from repro.core import sim
from repro.core.costs import DeviceProfile, LinkProfile
from repro.core.pipeline import PipelineResult, TaskPlan
from repro.core.schedule import StageTimes


@dataclasses.dataclass
class EngineConfig:
    bits_levels: Sequence[int] = (3, 4, 5, 6, 8)
    default_bits: int = 8
    update_centers: bool = True
    eps: float = 0.005
    # ---- async hop-queue engine knobs
    queue_capacity: int = 64   # bounded per-hop queue depth (0 = unbounded)
    per_hop_bits: bool = True  # per-hop adaptive precision from hop EMAs
    # ---- continuous micro-batching (compute workers drain their hop
    #      queue into dynamic batches; see serving.batching / core.sim)
    batch_caps: Optional[Sequence[int]] = None  # per-tier caps (None = off)
    batch_fixed: Optional[Sequence[float]] = None  # per-segment fixed secs
    batch_fixed_frac: float = 0.0  # or: fixed = frac * segment time
    batch_slack: Optional[float] = None  # staleness budget (s) past arrival;
    #                                also the auto-finder's SLO slack
    auto_batch: bool = False       # run the batch-size finder at build
    batch_cap_limit: int = 32      # auto-finder search ceiling
    ingress_cap: Optional[int] = None  # clamp tier-0 cap (MT engines: 1);
    #                                the auto finder redistributes a
    #                                hard-clamped tier's slack downstream
    # ---- replicated tiers (pool of replicas per tier + router policy;
    #      see core.sim.PoolSpec / serving.routing)
    pool_sizes: Optional[Sequence[int]] = None  # replicas per tier
    pool_speeds: Optional[Sequence[Sequence[float]]] = None  # per-replica
    #                                service-time multipliers (overrides
    #                                pool_sizes when both are given)
    router: str = "jsq"            # routing policy name (serving.routing)
    router_seed: int = 0           # seed for the router's RNG streams
    # ---- observability (repro.obs): both default to None = fully off,
    #      zero overhead (every emission site guards on ``is not None``)
    trace: Any = None    # span sink (e.g. obs.trace.TraceRecorder); the
    #                      engine's executor emits its timeline into it
    metrics: Any = None  # obs.metrics.MetricsRegistry; populated from the
    #                      run's result (and trace, when both are set)
    # ---- online re-planning (repro.scenarios): migrate(idx, k, tx_ready)
    #      hook consulted at every hop boundary; the same hook (reset
    #      between runs) drives the sim replay and the executor, so the
    #      differential pin extends across mid-stream plan switches.
    #      Chain path only (no pools, no micro-batching).
    migrate: Any = None


@dataclasses.dataclass
class EngineStats:
    pipeline: PipelineResult
    exit_ratio: float
    mean_bits: float
    wire_kb_per_task: float
    accuracy: float

    @property
    def exit_hops(self) -> dict:
        """``{segment: count}`` of hop-level semantic exits (segment 0 =
        the classic end-device exit; >= 1 = an intermediate tier)."""
        return self.pipeline.exit_hop_counts()


class EngineBase:
    """Offline plan + online decision layer shared by both engines."""

    def __init__(self, runtime, stage_times: StageTimes,
                 end_dev: DeviceProfile, link: LinkProfile,
                 cloud_dev: DeviceProfile, n_labels: int,
                 calib_feats: np.ndarray, calib_labels: np.ndarray,
                 cfg: Optional[EngineConfig] = None,
                 boundary_elems: Optional[int] = None,
                 links: Optional[Sequence[LinkProfile]] = None,
                 hop_bits_offline: Optional[Sequence[int]] = None,
                 hop_calib: Optional[Sequence[Tuple[np.ndarray,
                                                    np.ndarray]]] = None):
        """``links`` (one per hop, first = the end device's uplink)
        activates the multi-hop path; omitting it keeps the classic
        end->link->cloud deployment with ``link`` as the only hop.

        ``hop_calib`` activates hop-level semantic early exit: one
        ``(features, labels)`` calibration set per *intermediate* tier
        (segments ``1..n_hops-1``, e.g. ``make_hop_calibration_sets(
        stream, n, n_hops)[1:]``), each calibrating that boundary's own
        semantic cache and exit threshold.  Omitting it keeps the classic
        behavior: the only probe runs on the end device.

        ``hop_bits_offline`` is the offline partition's per-hop boundary
        precision (e.g. the mean of ``decision.all_hop_bits[k]``); it is
        what prices ``stage_times.link[k]`` back to a boundary element
        count, so per-hop adaptive bits retime the *true* wire volume.
        Defaults to ``cfg.default_bits`` on every hop.

        ``cfg`` defaults to a fresh ``EngineConfig`` per engine (a shared
        mutable default instance would leak config edits across engines).
        """
        self.rt = runtime
        self.st = stage_times
        self.links = list(links) if links is not None else [link]
        self.link = self.links[0]
        assert len(self.links) == stage_times.n_hops, \
            "need one link per stage-time hop"
        self.cfg = cfg if cfg is not None else EngineConfig()
        cfg = self.cfg
        dim = calib_feats.shape[1]
        self.cache = ON.SemanticCache(n_labels, dim)
        self.cache.warm_up(calib_feats, calib_labels)
        self.th = ON.calibrate_thresholds(self.cache, calib_feats,
                                          calib_labels, eps=cfg.eps,
                                          bit_levels=cfg.bits_levels)
        elems = boundary_elems or int(calib_feats.shape[1])
        offline_bits = list(hop_bits_offline) if hop_bits_offline is not None \
            else [cfg.default_bits] * self.st.n_hops
        assert len(offline_bits) == self.st.n_hops, \
            "need one offline precision per hop"
        # wire volume of hop k >= 1: the offline plan's occupation of link
        # k priced back to elements at that hop's offline precision
        hop_elems = [int(elems)] + [
            max(1, int(self.st.link[k] * self.links[k].bandwidth_bps
                       / offline_bits[k]))
            for k in range(1, self.st.n_hops)]
        hop_probes = None
        if hop_calib is not None:
            assert len(hop_calib) == self.st.n_hops - 1, \
                "need one calibration set per intermediate tier"
            hop_probes = ON.build_hop_probes(hop_calib, n_labels,
                                             eps=cfg.eps,
                                             bit_levels=cfg.bits_levels)
        self.sched = ON.OnlineScheduler(
            self.cache, self.th, elems, stage_times.T_e, stage_times.T_c,
            update_centers=cfg.update_centers,
            hop_elems=hop_elems, stage_compute=stage_times.compute,
            hop_probes=hop_probes)
        # ---- continuous micro-batching: calibrated per-segment fixed
        # costs + per-tier caps (explicit, or from the auto finder)
        stage_compute = list(stage_times.compute)
        if cfg.batch_fixed is not None:
            self.batch_fixed: Optional[List[float]] = \
                [float(f) for f in cfg.batch_fixed]
            assert len(self.batch_fixed) == len(stage_compute), \
                "need one fixed cost per compute segment"
        elif cfg.batch_fixed_frac > 0.0:
            assert cfg.batch_fixed_frac <= 1.0
            self.batch_fixed = [cfg.batch_fixed_frac * c
                                for c in stage_compute]
        else:
            self.batch_fixed = None
        self.batch_slack = cfg.batch_slack
        self.batch_caps: Optional[List[int]] = \
            [int(c) for c in cfg.batch_caps] \
            if cfg.batch_caps is not None else None
        if cfg.auto_batch and self.batch_caps is None:
            assert self.batch_fixed is not None, \
                "auto_batch needs a fixed-cost calibration " \
                "(batch_fixed / batch_fixed_frac)"
            assert self.batch_slack is not None, \
                "auto_batch needs an SLO slack (batch_slack)"
            from repro.serving.batching import auto_batch_caps
            self.batch_caps = auto_batch_caps(
                stage_compute, self.batch_fixed, self.batch_slack,
                cfg.batch_cap_limit, ingress_cap=cfg.ingress_cap)
        elif cfg.ingress_cap is not None and self.batch_caps:
            self.batch_caps[0] = min(self.batch_caps[0],
                                     int(cfg.ingress_cap))
        # ---- replicated tiers: per-tier replica pools from config
        # (None = the classic single-replica chain).  The engines hand
        # these to the executors together with a serving.routing router.
        if cfg.pool_speeds is not None:
            self.pools: Optional[Tuple[sim.PoolSpec, ...]] = sim.as_pools(
                [tuple(float(s) for s in sp) for sp in cfg.pool_speeds],
                len(stage_compute))
        elif cfg.pool_sizes is not None:
            self.pools = sim.as_pools(
                [int(m) for m in cfg.pool_sizes], len(stage_compute))
        else:
            self.pools = None

    def make_router(self):
        """Fresh router instance from the config (None when the engine
        runs the classic chain).  Fresh per call: router state is a replay
        log, so two runs must never share one instance."""
        if self.pools is None:
            return None
        from repro.serving.routing import make_router
        router = make_router(self.cfg.router, seed=self.cfg.router_seed)
        if self.cfg.metrics is not None:
            router.attach_metrics(self.cfg.metrics)
        return router

    # ------------------------------------------------------------ decisions
    @staticmethod
    def _hop_feats(feats) -> np.ndarray:
        """Normalize classify features to per-boundary rows: a 1-D vector
        becomes the single row every probe reuses; a 2-D array maps row
        ``k`` to the probe at segment ``k``."""
        f = np.asarray(feats)
        return f if f.ndim == 2 else f[None]

    def decide(self, task, bw: float, classify):
        """One COACH online decision (Eq. 10/11).  ``classify(task) ->
        (features, predicted_label)``: the caller runs the real model
        (CollabRuntime) or a proxy; ``features`` may be a single vector
        or a per-boundary ``(n_probes, dim)`` stack (hop-level exits).
        Identical call sequence in every engine, so a seeded stream
        yields identical decisions.

        A classifier on the fused boundary path returns a third element:
        ``(features, predicted_label, probes)``, where ``probes`` is one
        ``online.ProbeResult`` per boundary (or a single one for the
        classic end-only probe).  The scheduler then consumes the
        precomputed Eq. 8-10 outputs instead of re-deriving similarities
        from the features — the single HBM read that quantized the wire
        packet also decided the task."""
        out = classify(task)
        feats, pred = out[0], out[1]
        probes = out[2] if len(out) > 2 else None
        if probes is not None and isinstance(probes, ON.ProbeResult):
            probes = (probes,)
        hop_feats = self._hop_feats(feats)
        if self.sched.hop_probes:
            dec = self.sched.step_cascade(hop_feats, bandwidth_bps=bw,
                                          probes=probes)
        else:
            dec = self.sched.step(hop_feats[0], bandwidth_bps=bw,
                                  probe=probes[0] if probes else None)
        return dec, feats, pred

    def plan_for(self, dec: ON.OnlineDecision, bw: float,
                 hop_bits: Optional[Sequence[int]] = None
                 ) -> Tuple[TaskPlan, float]:
        """Build the per-task pipeline plan from an online decision.

        Returns ``(plan, hop0_wire_bits)``.  Without ``hop_bits`` the
        adaptive precision retimes only the end device's uplink and the
        inner hops keep their offline-planned occupation (the sync
        reference semantics); with ``hop_bits`` every hop is retimed from
        its chosen precision and bandwidth EMA (per-hop adaptive bits).
        A hop-level exit (``dec.exit_hop = k >= 1``) carries full-length
        stage durations plus the exit marker: the executors run compute
        ``0..k`` / links ``0..k-1`` and release everything downstream."""
        st = self.st
        bf = self.batch_fixed
        if dec.early_exit:
            return TaskPlan(st.T_e, 0.0, 0.0, True,
                            t_fixed=(bf[0],) if bf else ()), 0.0
        bits = dec.bits or self.cfg.default_bits
        wire_bits = self.sched.elems * bits
        t_tx = wire_bits / bw
        if st.n_hops == 1:
            return TaskPlan(
                st.T_e, t_tx, st.T_c,
                tx_offset=min(st.first_tx_offset, st.T_e),
                cloud_offset=st.cloud_start_offset,
                t_fixed=(bf[0], bf[-1]) if bf else ()), wire_bits
        if hop_bits is None:
            tx: Tuple[float, ...] = (t_tx,) + tuple(st.link[1:])
        else:
            assert len(hop_bits) == st.n_hops
            retimed: List[float] = [t_tx]
            for k in range(1, st.n_hops):
                bw_k = self.sched.hop_bandwidth(k) \
                    or self.links[k].bandwidth_bps
                retimed.append(self.sched.hop_elems[k] * hop_bits[k] / bw_k)
            tx = tuple(retimed)
        return TaskPlan.multihop(
            compute=st.compute, tx=tx,
            tx_offsets=tuple(min(st.tx_offsets[k], st.compute[k])
                             for k in range(st.n_hops)),
            rx_offsets=st.rx_offsets, exit_hop=dec.exit_hop,
            t_fixed=bf if bf else None), wire_bits

    def account(self, dec: ON.OnlineDecision, feats, pred, task,
                wire_bits: float, acc: dict) -> None:
        """Shared decision accounting + label feedback (identical in the
        sync, async, and multi-tenant engines, so the three can never
        diverge).  ``acc`` accumulates ``exits`` (int), ``wire`` (float,
        bits), ``bits`` (list), ``correct`` (list)."""
        hop_feats = self._hop_feats(feats)
        if dec.exit_hop == 0:         # classic end-device exit: no wire
            acc["exits"] += 1
            acc["correct"].append(dec.result == task.label)
            return
        acc["bits"].append(dec.bits or self.cfg.default_bits)
        acc["wire"] += wire_bits
        if dec.exit_hop is not None:  # exited at an intermediate tier
            acc["exits"] += 1
            acc["correct"].append(dec.result == task.label)
            # the tier's result flows back down: refresh the probes the
            # task crossed (the exiting tier already self-updated)
            self.sched.report_label_hops(hop_feats, dec.result,
                                         upto=dec.exit_hop)
        else:                         # full pipeline: true label feedback
            acc["correct"].append(pred == task.label)
            self.sched.report_label_hops(hop_feats, task.label)

    def admit_plan(self, task, bw: float, t_bw: float, classify,
                   acc: dict) -> TaskPlan:
        """One enqueue-time decision + plan, with shared accounting.

        ``bw`` prices the uplink for Eq. 11; ``t_bw`` is the wall/virtual
        time at which the per-hop bandwidths are observed (per-hop
        adaptive bits, when enabled).  ``acc`` accumulates the decision
        aggregates every engine reports: ``exits`` (int), ``wire``
        (float, bits), ``bits`` (list), ``correct`` (list).  Used by the
        async single-stream engine and per-tenant by the multi-tenant
        engine, so decision accounting can never diverge between them."""
        dec, feats, pred = self.decide(task, bw, classify)
        hop_bits = None
        if not dec.early_exit and self.cfg.per_hop_bits \
                and self.st.n_hops > 1:
            for k in range(1, self.st.n_hops):
                self.sched.observe_hop_bandwidth(
                    k, self.links[k].bps_at(t_bw))
            # hop 0 keeps the Eq. 11 choice already in dec.bits
            chosen = self.sched.choose_hop_bits(
                dec.required_bits or self.cfg.default_bits)
            hop_bits = (dec.bits or self.cfg.default_bits,) + chosen[1:]
        plan, wire_bits = self.plan_for(dec, bw, hop_bits=hop_bits)
        if self.batch_slack is not None:
            # staleness deadline from the stream's SLO slack: batch
            # formation never holds this task past it (sim.SimPlan)
            plan.deadline = t_bw + self.batch_slack
        self.account(dec, feats, pred, task, wire_bits, acc)
        return plan

    # ------------------------------------------------------------ reporting
    def _stats(self, pipeline: PipelineResult, n: int, exits: int,
               bits_used: Sequence[int], wire_bits_total: float,
               correct: Sequence[bool]) -> EngineStats:
        if self.cfg.metrics is not None:
            self._populate_metrics(pipeline)
        return EngineStats(
            pipeline=pipeline,
            exit_ratio=exits / n,
            mean_bits=float(np.mean(bits_used)) if bits_used else 0.0,
            wire_kb_per_task=wire_bits_total / 8e3 / n,
            accuracy=float(np.mean(correct)),
        )

    def _populate_metrics(self, pipeline: PipelineResult) -> None:
        """Fill ``cfg.metrics`` from the finished run: result gauges
        always; span-derived counters/histograms and per-cause bubble
        seconds when ``cfg.trace`` recorded the run."""
        from repro.obs.bubbles import attribute, chain_resources
        from repro.obs.metrics import (populate_from_attribution,
                                       populate_from_result,
                                       populate_from_trace)
        reg = self.cfg.metrics
        populate_from_result(reg, pipeline)
        trace = self.cfg.trace
        if trace is not None and len(getattr(trace, "spans", ())) > 0:
            populate_from_trace(reg, trace)
            att = attribute(trace, resources=chain_resources(
                pipeline.n_hops, pipeline.pool_sizes or None))
            populate_from_attribution(reg, att)
