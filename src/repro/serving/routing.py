"""Per-pool replica routing policies for replicated-tier pipelines.

A router places each task on one replica of a tier's pool
(``repro.core.sim.PoolSpec``) the instant the task is enqueued at that
tier.  Like the admission policies in ``repro.serving.tenancy``, the
policy object is a deterministic state machine shared verbatim between
the arithmetic simulator (``core.sim.simulate_pool_stream``, which
dispatches tier by tier) and the event-driven executor
(``serving.async_engine``, whose per-pool dispatcher workers interleave
tiers in wall time) — so the differential harness pins the *routing
semantics*, not the policy code.

Two rules make that sharing sound:

* **All state is per tier.**  Projected free times, backlog lists, RNG
  streams, and affinity maps are indexed by tier, and a ``route`` call
  for tier ``k`` touches only tier ``k``'s state.  The executor routes
  tier 1's task while tier 0 is still dispatching; the simulator routes
  all of tier 0, then all of tier 1.  Both orders make the *same*
  per-tier call sequences, so they reach identical decisions.
* **Decisions never read a clock.**  A ``route(k, ready, compute,
  tenant)`` call sees only the task's carried input-ready instant and
  the router's own projections (``free[r] -> max(free[r], ready) +
  speeds[r] * compute``); wall/virtual time never enters, so concurrency
  can change timing but never placement — the repo-wide invariant.

The projections deliberately ignore data-done gating, batching
amortization, and credit-gate hold times: they are a routing *score*,
not the timeline (the simulator owns that).  Both sides use the same
score, which is all the pinning needs.
"""

from __future__ import annotations

import random
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.sim import PoolSpec

__all__ = [
    "RouterPolicy", "JoinShortestQueue", "PowerOfTwoChoices",
    "RandomRouter", "TenantAffinity", "ROUTER_POLICIES", "make_router",
]


class RouterPolicy:
    """Base class: per-tier projection state + the ``route`` bookkeeping.

    Subclasses implement ``pick(k, ready, compute, tenant) -> replica``;
    ``route`` wraps it with the shared state update so every policy
    projects identically.  ``reset(pools)`` must be called (by the
    simulator, executor, or admission gate) before the first ``route``.
    """

    def __init__(self, seed: int = 0):
        self.seed = int(seed)
        self.pools: Tuple[PoolSpec, ...] = ()
        self._metrics = None

    def attach_metrics(self, registry) -> None:
        """Optional live counter hook (``obs.metrics.MetricsRegistry``):
        every ``route`` call increments ``router.tier{k}.r{r}``.  Counts
        raw ``route`` invocations — including any projection pre-run an
        admission gate performs before the replay resets the state — so
        for placement counts pinned across engines prefer the trace-
        derived ``tier{k}.route.r{r}`` counters
        (``obs.metrics.populate_from_trace``).  Survives ``reset``."""
        self._metrics = registry

    def reset(self, pools: Sequence[PoolSpec]) -> None:
        self.pools = tuple(pools)
        # projected replica free instants / outstanding completion lists,
        # one entry per (tier, replica); RNG + affinity state per tier
        self._free: List[List[float]] = [[0.0] * p.m for p in self.pools]
        self._fins: List[List[List[float]]] = \
            [[[] for _ in range(p.m)] for p in self.pools]
        self._rng = [random.Random(self.seed + k)
                     for k in range(len(self.pools))]
        self._affinity: List[Dict[int, int]] = [{} for _ in self.pools]

    # ------------------------------------------------------------- scoring
    def _backlog(self, k: int, r: int, ready: float) -> int:
        """Projected queue depth of replica ``r`` as seen by a task whose
        input is ready at ``ready``: outstanding routed tasks whose
        projected completion lies beyond ``ready``."""
        fins = self._fins[k][r]
        if fins and fins[0] <= ready:
            fins = [f for f in fins if f > ready]
            self._fins[k][r] = fins
        return len(fins)

    def _projected_fin(self, k: int, r: int, ready: float,
                       compute: float) -> float:
        return max(self._free[k][r], ready) \
            + self.pools[k].speeds[r] * compute

    def _shortest(self, k: int, ready: float, compute: float,
                  among: Optional[Sequence[int]] = None) -> int:
        """JSQ score: least backlog, then earliest projected finish, then
        lowest index — over ``among`` (default: the whole pool)."""
        cands = range(self.pools[k].m) if among is None else among
        return min(cands, key=lambda r: (self._backlog(k, r, ready),
                                         self._projected_fin(
                                             k, r, ready, compute), r))

    # ------------------------------------------------------------ interface
    def pick(self, k: int, ready: float, compute: float,
             tenant: Optional[int]) -> int:
        raise NotImplementedError

    def route(self, k: int, ready: float, compute: float,
              tenant: Optional[int] = None) -> int:
        """Place one task: delegate to ``pick``, then record the
        projection (identical bookkeeping for every policy)."""
        r = self.pick(k, float(ready), float(compute), tenant)
        fin = self._projected_fin(k, r, ready, compute)
        self._free[k][r] = fin
        self._fins[k][r].append(fin)
        if self._metrics is not None:
            self._metrics.inc(f"router.tier{k}.r{r}")
        return r


class JoinShortestQueue(RouterPolicy):
    """Route to the replica with the least projected backlog (ties by
    earliest projected finish, then index)."""

    def pick(self, k, ready, compute, tenant):
        return self._shortest(k, ready, compute)


class PowerOfTwoChoices(RouterPolicy):
    """Sample two distinct replicas from the tier's seeded RNG stream and
    keep the better one (classic load-balancing: near-JSQ balance at two
    probes' worth of state).  Degenerates to the single replica at
    ``m = 1``."""

    def pick(self, k, ready, compute, tenant):
        m = self.pools[k].m
        if m == 1:
            return 0
        rng = self._rng[k]
        a = rng.randrange(m)
        b = rng.randrange(m - 1)
        if b >= a:
            b += 1
        return self._shortest(k, ready, compute, among=(a, b))


class RandomRouter(RouterPolicy):
    """Uniform seeded random placement — the no-information baseline the
    routing bench compares JSQ/po2 against."""

    def pick(self, k, ready, compute, tenant):
        return self._rng[k].randrange(self.pools[k].m)


class TenantAffinity(RouterPolicy):
    """Sticky per-(tier, tenant) placement: a tenant's first task on a
    tier is placed JSQ-style and every later task follows it (warm
    per-tenant state: caches, sessions).  Untagged tasks fall back to
    plain JSQ per call."""

    def pick(self, k, ready, compute, tenant):
        if tenant is None:
            return self._shortest(k, ready, compute)
        amap = self._affinity[k]
        if tenant not in amap:
            amap[tenant] = self._shortest(k, ready, compute)
        return amap[tenant]


ROUTER_POLICIES = {
    "jsq": JoinShortestQueue,
    "po2": PowerOfTwoChoices,
    "random": RandomRouter,
    "affinity": TenantAffinity,
}


def make_router(policy, seed: int = 0) -> RouterPolicy:
    """Instantiate a router from a name in ``ROUTER_POLICIES`` (or pass a
    ``RouterPolicy`` instance through unchanged)."""
    if isinstance(policy, RouterPolicy):
        return policy
    try:
        return ROUTER_POLICIES[policy](seed=seed)
    except KeyError:
        raise ValueError(
            f"unknown router policy {policy!r}; "
            f"expected one of {sorted(ROUTER_POLICIES)}") from None
