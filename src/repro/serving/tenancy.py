"""Multi-tenant admission & fairness on the async hop-queue engine.

COACH's throughput story assumes a shared cloud tier serving many end
devices, but a single ``AsyncHopPipeline`` executes one task stream.
This module admits *several* per-tenant task streams through one shared
``2n+1`` resource chain:

  ``TenantSpec``            one tenant's workload contract: arrival
                            process, fairness weight, latency SLO.
  admission policies        pluggable schedulers deciding which tenant's
                            head task enters the shared chain next —
                            FIFO (global arrival order), round-robin,
                            and weighted deficit round-robin (WDRR).
  ``MultiTenantHopPipeline``  per-tenant admit workers (decisions happen
                            at each task's arrival instant) feeding one
                            policy dispatcher that is released by
                            *ingress credits*: the shared end worker
                            issues a credit exactly when it becomes
                            free, so admission is gated by the first
                            resource of the chain (and, with bounded
                            hop queues, by downstream backpressure).
  ``MultiTenantCoachEngine``  one COACH engine state per tenant (own
                            semantic cache, thresholds, per-hop
                            bandwidth EMAs) sharing the executor; co-
                            tenancy can never change a tenant's online
                            decisions, only its timing.

Differential contract (pinned by ``tests/test_tenancy.py``): with
unbounded queues and a ``VirtualClock``, the executor's admission order
and full resource timeline equal ``core.sim.simulate_multitenant_stream``
— which computes the same ingress gate arithmetically — to float
precision, for every admission policy.  The policy *state machines* are
shared between the two sides; the *gating semantics* (event-driven
credits vs. arithmetic ``free_0``) are implemented independently, which
is exactly what the harness pins.

Fairness-vs-bubble tradeoff: FIFO admits a bursty tenant's backlog ahead
of everyone — by work conservation it is minimax-optimal for *raw*
worst-tenant p99 (the burster's self-queueing floors that metric under
every policy), but it lets the burst blow tight-SLO tenants far outside
their targets.  WDRR interleaves per weight, so the *SLO-normalized*
worst tenant (``MultiTenantStats.worst_tenant_norm_p99``) and min SLO
attainment improve by large factors at near-identical bubble fractions
(``benchmarks/multitenant.py`` measures both sides).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import sim
from repro.core.pipeline import (PipelineResult, TaskPlan, TaskRecord,
                                 result_from_pool_stream,
                                 result_from_stream)
from repro.obs.trace import CREDIT_WAIT, ENQUEUE, Span
from repro.serving.async_engine import (AsyncHopPipeline, HopQueue,
                                        VirtualClock, _Msg, _STOP)
from repro.serving.base import EngineBase, EngineConfig, EngineStats

__all__ = ["TenantSpec", "AdmissionPolicy", "FifoAdmission",
           "RoundRobinAdmission", "WeightedDeficitRoundRobin",
           "ADMISSION_POLICIES", "make_policy", "task_count_cost",
           "service_time_cost", "MultiTenantHopPipeline",
           "run_multitenant_async", "tenant_pipeline_result",
           "TenantReport", "MultiTenantStats", "MultiTenantCoachEngine"]


# ==================================================================== specs
@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's workload contract.

    ``arrivals`` (explicit, non-decreasing) overrides the periodic
    process ``start + i * arrival_period``.  ``weight`` is the WDRR
    fairness share; ``slo_latency`` the per-task latency target used for
    SLO-attainment accounting (``None`` = no SLO)."""
    name: str
    n_tasks: int
    arrival_period: float = 0.0
    start: float = 0.0
    arrivals: Optional[Tuple[float, ...]] = None
    weight: float = 1.0
    slo_latency: Optional[float] = None

    def arrival_times(self) -> List[float]:
        if self.arrivals is not None:
            a = list(self.arrivals)
            assert len(a) == self.n_tasks, \
                f"tenant {self.name}: {len(a)} arrivals != {self.n_tasks}"
        else:
            a = [self.start + i * self.arrival_period
                 for i in range(self.n_tasks)]
        assert all(x0 <= x1 for x0, x1 in zip(a, a[1:])), \
            f"tenant {self.name}: arrivals must be non-decreasing"
        return a


# ================================================================= policies
def task_count_cost(plan: sim.SimPlan) -> float:
    """WDRR cost: every task costs one quantum unit (weighted fair task
    counts — robust when per-task service times are comparable)."""
    return 1.0


def service_time_cost(plan: sim.SimPlan) -> float:
    """WDRR cost: the task's total resource demand in seconds (heavier
    tasks consume proportionally more of their tenant's share).  A task
    exiting at segment ``e`` only demands compute ``0..e`` and links
    ``0..e-1``."""
    e = plan.exit_hop if plan.exit_hop is not None else len(plan.tx)
    return float(sum(plan.compute[:e + 1]) + sum(plan.tx[:e]))


class AdmissionPolicy:
    """Decides which candidate tenant's head task enters the shared
    chain next.

    The interface is shared by ``core.sim.multitenant_admission_order``
    (arithmetic ingress gate) and ``MultiTenantHopPipeline`` (event-
    driven ingress credits): ``reset(n_tenants)`` clears state, then
    ``pick(candidates, heads)`` is called once per admitted task with
    the tenants whose head task has arrived by the dispatch instant and
    ``heads[t] = (arrival, per-tenant index, SimPlan)``.  ``pick`` must
    return a candidate and be deterministic in its call sequence."""

    name = "abstract"

    def reset(self, n_tenants: int) -> None:
        self.n = n_tenants

    def pick(self, candidates: Sequence[int],
             heads: Dict[int, Tuple[float, int, sim.SimPlan]]) -> int:
        raise NotImplementedError


class FifoAdmission(AdmissionPolicy):
    """Global arrival order (ties break toward the lower tenant index):
    the single-queue baseline — a bursty tenant's backlog is served
    ahead of everything that arrived after it."""

    name = "fifo"

    def pick(self, candidates, heads):
        return min(candidates, key=lambda t: (heads[t][0], t))


class RoundRobinAdmission(AdmissionPolicy):
    """Cycle over tenants with a ready head task, one task per turn."""

    name = "rr"

    def reset(self, n_tenants):
        super().reset(n_tenants)
        self._last = n_tenants - 1

    def pick(self, candidates, heads):
        cset = set(candidates)
        for d in range(1, self.n + 1):
            t = (self._last + d) % self.n
            if t in cset:
                self._last = t
                return t
        raise AssertionError("no candidate tenant")


class WeightedDeficitRoundRobin(AdmissionPolicy):
    """Deficit round-robin (Shreedhar & Varghese) with per-tenant
    quanta proportional to ``weights``.

    Each visit to a tenant with a ready head tops up its deficit by
    ``weight * quantum`` once; the head is admitted while the deficit
    covers ``cost_fn(plan)`` (default: one unit per task, i.e. weighted
    fair task counts; ``service_time_cost`` charges seconds of resource
    demand instead).  A tenant with nothing ready forfeits its deficit —
    idle credit does not accumulate."""

    name = "wdrr"
    _EPS = 1e-12  # float slack for fractional-weight deficit sums

    def __init__(self, weights: Optional[Sequence[float]] = None,
                 quantum: float = 1.0,
                 cost_fn: Callable[[sim.SimPlan], float] = task_count_cost):
        self.weights = list(weights) if weights is not None else None
        self.quantum = quantum
        self.cost_fn = cost_fn

    def reset(self, n_tenants):
        super().reset(n_tenants)
        w = self.weights if self.weights is not None else [1.0] * n_tenants
        assert len(w) == n_tenants and all(x > 0 for x in w), \
            "need one positive weight per tenant"
        self._q = [x * self.quantum for x in w]
        self._deficit = [0.0] * n_tenants
        self._c = 0
        self._topped = False

    def pick(self, candidates, heads):
        cset = set(candidates)
        for t in range(self.n):
            if t not in cset:
                self._deficit[t] = 0.0
        while True:
            t = self._c
            if t in cset:
                cost = self.cost_fn(heads[t][2])
                if not self._topped:
                    self._deficit[t] += self._q[t]
                    self._topped = True
                if self._deficit[t] + self._EPS >= cost:
                    self._deficit[t] -= cost
                    return t
            self._c = (self._c + 1) % self.n
            self._topped = False


ADMISSION_POLICIES = {
    "fifo": FifoAdmission,
    "rr": RoundRobinAdmission,
    "wdrr": WeightedDeficitRoundRobin,
}


def make_policy(policy, weights: Optional[Sequence[float]] = None,
                **kwargs) -> AdmissionPolicy:
    """Resolve ``policy`` (name or instance) to a fresh policy object;
    ``weights``/``kwargs`` only apply to weighted policies."""
    if isinstance(policy, AdmissionPolicy):
        return policy
    cls = ADMISSION_POLICIES[policy]
    if cls is WeightedDeficitRoundRobin:
        return cls(weights=weights, **kwargs)
    return cls()


# ================================================================= executor
class MultiTenantHopPipeline:
    """Tagged multi-tenant admission over one shared ``AsyncHopPipeline``.

    One admit worker per tenant sleeps to each task's arrival and calls
    that tenant's ``plan_fn`` *at the arrival instant* (per-tenant
    decision order is therefore independent of co-tenants); a single
    dispatcher, released by ingress credits each time the shared end
    worker frees, picks the next tenant via the admission policy and
    forwards the head task into the chain.  See the module docstring for
    the differential contract with ``core.sim``."""

    def __init__(self, n_hops: int, links=None, clock=None,
                 queue_capacity: int = 0, segment_fn=None,
                 policy: AdmissionPolicy | str = "fifo",
                 weights: Optional[Sequence[float]] = None,
                 batch_caps: Optional[Sequence[int]] = None,
                 pools=None, router=None, sink=None, migrate=None):
        # tier 0 never batches under multi-tenancy: admission is credit-
        # gated one task at a time, so the ingress queue holds at most
        # one task and a tier-0 drain would diverge from the admission
        # gate (``sim.simulate_multitenant_stream`` applies the same
        # clamp to stay pinned).  With ``pools=`` the ingress credit
        # generalizes to *pool* ingress — every tier-0 replica issues a
        # credit when it frees, so up to ``m`` tasks are admitted into
        # the ingress pool at once (``sim.multitenant_pool_admission``
        # computes the same gate as a min-heap of completion instants)
        if batch_caps is not None:
            batch_caps = [1] + [int(c) for c in batch_caps[1:]]
        # the migration hook is keyed by the *global admission slot*
        # (``_Msg.idx``), the same index ``sim.simulate_multitenant_
        # stream`` replays the merged stream with
        self.pipe = AsyncHopPipeline(n_hops, links=links, clock=clock,
                                     queue_capacity=queue_capacity,
                                     segment_fn=segment_fn,
                                     batch_caps=batch_caps,
                                     pools=pools, router=router, sink=sink,
                                     migrate=migrate)
        self.policy = make_policy(policy, weights=weights)

    @property
    def outputs(self) -> dict:
        return self.pipe.outputs

    def run(self, plan_fns: Sequence[Callable[[int, float], Any]],
            arrivals_by_tenant: Sequence[Sequence[float]],
            payloads: Optional[Sequence[Sequence[Any]]] = None
            ) -> sim.MultiTenantStreamResult:
        """Admit every tenant's stream; ``plan_fns[t](i, t_arr)`` returns
        task ``i`` of tenant ``t``'s plan at its arrival."""
        clock = self.pipe.clock
        n_hops = self.pipe.n_hops
        n_t = len(plan_fns)
        arrivals_by_tenant = [list(a) for a in arrivals_by_tenant]
        assert len(arrivals_by_tenant) == n_t
        for a in arrivals_by_tenant:
            assert all(x0 <= x1 for x0, x1 in zip(a, a[1:])), \
                "per-tenant arrivals must be non-decreasing"
        total = sum(len(a) for a in arrivals_by_tenant)
        assert total > 0, "empty multi-tenant stream"
        policy = self.policy
        policy.reset(n_t)
        sink = self.pipe.sink
        ready: List[collections.deque] = [collections.deque()
                                          for _ in range(n_t)]
        served = [0] * n_t
        order: List[sim.TenantSlot] = []
        strict = isinstance(clock, VirtualClock)

        async def admit_fn(q0: HopQueue, credits: HopQueue, record):
            async def tenant_admit(t: int):
                for i, arr in enumerate(arrivals_by_tenant[t]):
                    await clock.sleep_until(arr)
                    plan = plan_fns[t](i, arr)
                    if isinstance(plan, TaskPlan):
                        plan = plan.as_sim_plan(n_hops)
                    assert len(plan.tx) == n_hops, \
                        "plan/deployment hop mismatch"
                    payload = payloads[t][i] if payloads is not None else None
                    ready[t].append((i, arr, plan, payload))

            async def dispatch():
                admitted = 0
                while admitted < total:
                    await credits.get()   # shared end worker became free
                    await clock.settle()
                    while True:
                        cands = [t for t in range(n_t) if ready[t]]
                        if cands:
                            break
                        future = [arrivals_by_tenant[t][served[t]]
                                  for t in range(n_t)
                                  if served[t] < len(arrivals_by_tenant[t])]
                        nxt = min(future)
                        if nxt <= clock.now:
                            if strict:
                                raise RuntimeError(
                                    "tenant admit worker failed to deposit "
                                    f"a task that arrived at {nxt}")
                            await clock.sleep(1e-4)  # wall clock: re-poll
                        else:
                            await clock.sleep_until(nxt)
                        await clock.settle()
                    heads = {t: (ready[t][0][1], ready[t][0][0],
                                 ready[t][0][2]) for t in cands}
                    t = policy.pick(cands, heads)
                    i, arr, plan, payload = ready[t].popleft()
                    served[t] += 1
                    idx = admitted
                    admitted += 1
                    order.append((t, i))
                    record(idx, arr)
                    if sink is not None:
                        # dispatch instant = the admission gate's t_d
                        # (``sim.multitenant_admission_order`` /
                        # ``multitenant_pool_admission`` compute the same
                        # instants arithmetically)
                        if clock.now > arr:
                            sink.span(Span(CREDIT_WAIT, ("compute", 0),
                                           arr, clock.now, task=idx))
                        sink.span(Span(ENQUEUE, ("compute", 0), clock.now,
                                       clock.now, task=idx))
                    await q0.put(_Msg(idx, plan, ready_at=arr, data_done=arr,
                                      payload=payload, tenant=t))
                await q0.put(_STOP)

            # children are clock-spawned workers; completion (and error
            # propagation) funnels through a clock-aware done queue so the
            # virtual driver's quiescence accounting stays exact
            done_q = HopQueue(clock)
            errs: List[BaseException] = []

            async def guarded(coro):
                try:
                    await coro
                except BaseException as e:  # noqa: BLE001 — re-raised below
                    errs.append(e)
                finally:
                    await done_q.put(None)

            for t in range(n_t):
                clock.spawn(guarded(tenant_admit(t)))
            clock.spawn(guarded(dispatch()))
            for _ in range(n_t + 1):
                await done_q.get()
            if errs:
                raise errs[0]

        res = self.pipe.run(None, total, None, admit_fn=admit_fn)
        if isinstance(res, sim.PoolStreamResult):
            return sim.MultiTenantPoolStreamResult(
                stream=res.as_stream_result(), order=tuple(order),
                n_tenants=n_t, pool=res)
        return sim.MultiTenantStreamResult(stream=res, order=tuple(order),
                                           n_tenants=n_t)


def run_multitenant_async(plans_by_tenant: Sequence[Sequence[TaskPlan]],
                          arrivals_by_tenant: Sequence[Sequence[float]],
                          policy: AdmissionPolicy | str = "fifo",
                          weights: Optional[Sequence[float]] = None,
                          links=None, queue_capacity: int = 0, clock=None,
                          segment_fn=None, payloads=None,
                          batch_caps: Optional[Sequence[int]] = None,
                          pools=None, router=None, sink=None, migrate=None
                          ) -> sim.MultiTenantStreamResult:
    """Async-executor counterpart of ``sim.simulate_multitenant_stream``
    (or, with ``pools=``, of ``sim.simulate_multitenant_pool_stream``):
    same plan normalization, same result type, but the merged stream is
    *executed* by per-resource workers behind a policy dispatcher.  With
    unbounded queues and a ``VirtualClock`` the two admission orders and
    timelines agree to float precision (including per-tier micro-batching
    via ``batch_caps``; tier 0 is clamped to cap 1 on both sides)."""
    if links is None:
        links = [None]
    n_hops = max(max(p.n_hops for ps in plans_by_tenant for p in ps),
                 len(links))
    sps = [[p.as_sim_plan(n_hops) for p in ps] for ps in plans_by_tenant]
    pipe = MultiTenantHopPipeline(n_hops, links=links, clock=clock,
                                  queue_capacity=queue_capacity,
                                  segment_fn=segment_fn, policy=policy,
                                  weights=weights, batch_caps=batch_caps,
                                  pools=pools, router=router, sink=sink,
                                  migrate=migrate)
    plan_fns = [(lambda t: lambda i, _arr: sps[t][i])(t)
                for t in range(len(sps))]
    return pipe.run(plan_fns, arrivals_by_tenant, payloads=payloads)


# ================================================================ reporting
def tenant_pipeline_result(mt: sim.MultiTenantStreamResult,
                           tenant: int) -> PipelineResult:
    """Slice one tenant's view out of a merged multi-tenant timeline:
    its task records plus its own occupation of every shared resource.
    ``makespan`` spans the tenant's own activity (first arrival to last
    completion), so per-tenant throughput is the tenant's service rate,
    not the global one."""
    s = mt.stream
    slots = mt.tenant_slots(tenant)
    arr, done, exits = mt.tenant_view(tenant)
    ehs = mt.tenant_exit_hops(tenant)
    recs = [TaskRecord(i, a, d, d - a, e, eh)
            for i, (a, d, e, eh) in enumerate(zip(arr, done, exits, ehs))]
    makespan = (max(done) - min(arr)) if done else 0.0
    n_seg = len(s.compute_busy)
    n_hops = len(s.link_busy)
    slotset = set(slots)
    comp_iv: List[List[sim.Interval]] = [[] for _ in range(n_seg)]
    link_iv: List[List[sim.Interval]] = [[] for _ in range(n_hops)]
    if s.compute_intervals:
        # a resource's interval list only contains the slots that occupy
        # it (a task exiting at segment e occupies compute 0..e and links
        # 0..e-1): map each tenant slot to its position in that per-
        # resource ordering.  Under micro-batching one compute interval
        # serves a consecutive run of occupying slots
        # (``compute_batch_sizes``); a shared batch interval is
        # attributed to *every* tenant with a member in it, so
        # per-tenant busy time counts a shared launch in full (links
        # never batch and stay 1:1)
        def _slice(intervals, occupies, sizes=None):
            occ = [j for j in range(len(mt.order))
                   if occupies(s.exit_hop[j])]
            if not sizes:
                sizes = [1] * len(intervals)
            out = []
            pos = 0
            for iv, n_b in zip(intervals, sizes):
                if any(j in slotset for j in occ[pos:pos + n_b]):
                    out.append(iv)
                pos += n_b
            return out

        for k in range(n_seg):
            comp_iv[k] = _slice(
                s.compute_intervals[k],
                lambda eh, k=k: sim.occupies_compute(eh, k),
                s.compute_batch_sizes[k]
                if s.compute_batch_sizes else None)
        for k in range(n_hops):
            link_iv[k] = _slice(s.link_intervals[k],
                                lambda eh, k=k: sim.occupies_link(eh, k))
    return PipelineResult(
        recs, makespan,
        compute_busy=tuple(sum(e - st for (st, e) in iv) for iv in comp_iv),
        link_busy_hops=tuple(sum(e - st for (st, e) in iv)
                             for iv in link_iv),
        compute_intervals=tuple(tuple(iv) for iv in comp_iv),
        link_intervals=tuple(tuple(iv) for iv in link_iv))


@dataclasses.dataclass
class TenantReport:
    """One tenant's outcome under contention."""
    spec: TenantSpec
    stats: EngineStats            # decisions + tenant-sliced pipeline
    slo_attainment: Optional[float]  # P(latency <= slo); None without SLO


@dataclasses.dataclass
class MultiTenantStats:
    """Outcome of one multi-tenant engine run."""
    pipeline: PipelineResult                  # merged shared-chain view
    order: Tuple[sim.TenantSlot, ...]         # admission sequence
    reports: List[TenantReport]
    policy: str
    plans: List[List[sim.SimPlan]]            # per-tenant decided plans
    arrivals: List[List[float]]               # per-tenant arrival times

    @property
    def worst_tenant_p99(self) -> float:
        """Raw worst per-tenant p99.  Note: for open arrivals through one
        work-conserving chain, FIFO essentially *minimizes* this (it is
        minimax-optimal for waiting time; a bursty tenant's self-queueing
        floors the metric under every policy), so fair policies tie or
        slightly exceed it — the fairness win lives in the SLO-normalized
        view below."""
        return max(r.stats.pipeline.p99_latency for r in self.reports)

    @property
    def worst_tenant_norm_p99(self) -> Optional[float]:
        """Worst SLO-normalized p99, ``max_t p99_t / slo_t`` — the
        multi-tenant fairness headline: heterogeneous-SLO tenants are
        only comparable after normalizing, and weighted-DRR keeps every
        tenant's p99 inside (or near) its own SLO while FIFO lets a
        bursty tenant blow the tight-SLO tenants far out of theirs.
        ``None`` when no tenant declares an SLO."""
        vals = [r.stats.pipeline.p99_latency / r.spec.slo_latency
                for r in self.reports if r.spec.slo_latency]
        return max(vals) if vals else None

    @property
    def min_slo_attainment(self) -> Optional[float]:
        vals = [r.slo_attainment for r in self.reports
                if r.slo_attainment is not None]
        return min(vals) if vals else None


# =================================================================== engine
class MultiTenantCoachEngine:
    """COACH serving engine for several tenants sharing one hop chain.

    Each tenant owns a full online state — semantic cache, calibrated
    thresholds, ``OnlineScheduler`` with its own uplink/per-hop bandwidth
    EMAs — built by a private ``EngineBase``; decisions happen at each
    task's arrival instant inside that tenant's admit worker, so a
    tenant's decision sequence is identical to what it would make running
    alone (co-tenancy changes timing, never decisions).  The admission
    policy then interleaves the decided plans into the shared
    ``MultiTenantHopPipeline``."""

    def __init__(self, runtime, stage_times, end_dev, link, cloud_dev,
                 n_labels: int, calib_feats: np.ndarray,
                 calib_labels: np.ndarray, tenants: Sequence[TenantSpec],
                 policy: AdmissionPolicy | str = "fifo",
                 cfg: Optional[EngineConfig] = None,
                 boundary_elems: Optional[int] = None,
                 links=None, hop_bits_offline=None, hop_calib=None):
        assert tenants, "need at least one tenant"
        self.tenants = list(tenants)
        self.cfg = cfg if cfg is not None else EngineConfig()
        if self.cfg.auto_batch and self.cfg.batch_slack is None:
            # derive the batch-size finder's staleness budget from the
            # tightest tenant SLO: the slack left after a single task's
            # unloaded latency is what batching may consume
            slos = [t.slo_latency for t in self.tenants
                    if t.slo_latency is not None]
            assert slos, "auto_batch needs batch_slack or a tenant SLO"
            self.cfg = dataclasses.replace(
                self.cfg,
                batch_slack=max(0.0, min(slos) - stage_times.latency))
        # one private engine state per tenant (fresh config copy each, so
        # a tenant-level config edit can never leak across tenants; each
        # tenant also calibrates its own hop probes from hop_calib, so
        # hop-level exit decisions stay tenant-isolated).  Credit-gated
        # admission holds the ingress queue at depth <= 1, so tier 0 can
        # never batch: pin ingress_cap = 1 so the auto batch-size finder
        # redistributes tier 0's slack share to tiers that can use it.
        # trace/metrics stay on the *shared* config only: the trace is a
        # whole-chain timeline, so per-tenant _stats must not re-populate
        # the registry once per tenant (run_streams fills it once).
        self.engines: List[EngineBase] = [
            EngineBase(runtime, stage_times, end_dev, link, cloud_dev,
                       n_labels, calib_feats, calib_labels,
                       cfg=dataclasses.replace(self.cfg, ingress_cap=1,
                                               trace=None, metrics=None),
                       boundary_elems=boundary_elems, links=links,
                       hop_bits_offline=hop_bits_offline,
                       hop_calib=hop_calib)
            for _ in self.tenants]
        self.links = self.engines[0].links
        # caps are config-derived, so every per-tenant engine agrees;
        # the pipeline clamps tier 0 to cap 1 (credit-gated ingress)
        self.batch_caps = self.engines[0].batch_caps
        # replicated tiers: one shared pool topology for the chain (the
        # tenants share the replicas; the router may still pin a tenant
        # to a replica via the "affinity" policy)
        self.pools = self.engines[0].pools
        self.policy = make_policy(policy,
                                  weights=[t.weight for t in self.tenants])

    def run_streams(self, tasks_by_tenant, classify, clock=None
                    ) -> MultiTenantStats:
        """Serve every tenant's task list through the shared chain.

        ``classify(task) -> (features, predicted_label)`` as in the
        single-stream engines.  Returns merged + per-tenant stats; the
        decided per-tenant ``SimPlan``s and arrivals are included so a
        differential harness can replay the exact run through
        ``core.sim.simulate_multitenant_stream``."""
        n_t = len(self.tenants)
        assert len(tasks_by_tenant) == n_t
        tasks_by_tenant = [list(ts) for ts in tasks_by_tenant]
        for spec, ts in zip(self.tenants, tasks_by_tenant):
            assert len(ts) == spec.n_tasks, \
                f"tenant {spec.name}: {len(ts)} tasks != spec {spec.n_tasks}"
        arrivals = [spec.arrival_times() for spec in self.tenants]
        n_hops = len(self.links)
        accs = [{"exits": 0, "wire": 0.0, "bits": [], "correct": [],
                 "plans": []} for _ in range(n_t)]

        batching = self.batch_caps is not None \
            and any(c > 1 for c in self.batch_caps)

        def tenant_plan_fn(t: int):
            eng, acc, tasks = self.engines[t], accs[t], tasks_by_tenant[t]
            spec = self.tenants[t]

            def plan_fn(i: int, t_arr: float) -> sim.SimPlan:
                # same shared decision/accounting path as the single-
                # stream engines; only the bandwidth timestamp (this
                # task's arrival) is tenant-specific
                task = tasks[i]
                bw = eng.link.bps_at(t_arr)
                plan = eng.admit_plan(task, bw, t_arr, classify, acc)
                sp = plan.as_sim_plan(n_hops)
                if batching and sp.deadline is None \
                        and spec.slo_latency is not None:
                    # per-tenant staleness deadline from the SLO: batch
                    # formation never holds this task past its target
                    sp.deadline = t_arr + spec.slo_latency
                acc["plans"].append(sp)
                return sp

            return plan_fn

        pipe = MultiTenantHopPipeline(
            n_hops, links=self.links, clock=clock,
            queue_capacity=self.cfg.queue_capacity, policy=self.policy,
            batch_caps=self.batch_caps, pools=self.pools,
            router=self.engines[0].make_router(), sink=self.cfg.trace)
        mt = pipe.run([tenant_plan_fn(t) for t in range(n_t)], arrivals)

        reports = []
        for t, spec in enumerate(self.tenants):
            acc = accs[t]
            pr = tenant_pipeline_result(mt, t)
            stats = self.engines[t]._stats(
                pr, spec.n_tasks, acc["exits"], acc["bits"], acc["wire"],
                acc["correct"])
            slo = None
            if spec.slo_latency is not None:
                slo = float(np.mean([rec.latency <= spec.slo_latency
                                     for rec in pr.tasks]))
            reports.append(TenantReport(spec=spec, stats=stats,
                                        slo_attainment=slo))
        if isinstance(mt, sim.MultiTenantPoolStreamResult) \
                and mt.pool is not None:
            merged = result_from_pool_stream(mt.pool)
        else:
            merged = result_from_stream(mt.stream)
        if self.cfg.metrics is not None:
            # once, from the merged chain view (the per-tenant engines
            # run with metrics=None — see __init__)
            from repro.obs.bubbles import attribute, chain_resources
            from repro.obs.metrics import (populate_from_attribution,
                                           populate_from_result,
                                           populate_from_trace)
            reg = self.cfg.metrics
            populate_from_result(reg, merged)
            trace = self.cfg.trace
            if trace is not None and len(getattr(trace, "spans", ())) > 0:
                populate_from_trace(reg, trace)
                populate_from_attribution(reg, attribute(
                    trace, resources=chain_resources(
                        merged.n_hops, merged.pool_sizes or None)))
        return MultiTenantStats(
            pipeline=merged, order=mt.order,
            reports=reports, policy=self.policy.name,
            plans=[accs[t]["plans"] for t in range(n_t)],
            arrivals=arrivals)
