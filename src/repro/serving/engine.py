"""COACH serving engine: the full online loop over a continuous task stream.

Wires together every subsystem:

  offline   partition + quantization (core.partitioner) on the model's cost
            graph -> a CollabRuntime split at the chosen group boundaries
            (one cut per hop; classic end->cloud is the single-cut case)
  frontend  task features from the end segment's boundary activation via
            the fused semantic-probe kernel (GAP + cosine + separability)
  online    early exit (Eq. 10) / adaptive precision (Eq. 11) per task
  pipeline  ``2n+1``-resource discrete-event accounting of the induced
            stream (latency / throughput / bubbles), with measured wire
            bytes; non-exit tasks carry one ``TaskPlan`` hop per link

The JAX compute is real (CollabRuntime executes both segments); the
*timing* comes from the calibrated device/link profiles, since this host
is not a Jetson + A6000 pair (DESIGN.md §2).

``CoachEngine`` here is the *synchronous reference*: tasks are decided
and accounted one at a time, with all overlap delegated to
``core.sim.simulate_stream``.  The executor whose real workers overlap
tasks the way the simulator models lives in
``repro.serving.async_engine``; both share ``repro.serving.base``.
"""

from __future__ import annotations

from typing import List

from repro.core.pipeline import run_pipeline
from repro.data.pipeline import Task
from repro.serving.base import EngineBase, EngineConfig, EngineStats

__all__ = ["CoachEngine", "EngineConfig", "EngineStats"]


class CoachEngine(EngineBase):
    """Synchronous reference engine (decision + plan per task, in order)."""

    def run_stream(self, tasks: List[Task], arrival_period: float,
                   classify) -> EngineStats:
        """classify(task) -> (features, predicted_label): the caller runs
        the real model (CollabRuntime) or a proxy; the engine makes the
        COACH decisions — including hop-level semantic exits when the
        engine was built with ``hop_calib`` — and accounts the pipeline
        (decision accounting shared with the async/multi-tenant engines
        via ``EngineBase.account``)."""
        plans = []
        acc = {"exits": 0, "wire": 0.0, "bits": [], "correct": []}
        for i, task in enumerate(tasks):
            bw = self.link.bps_at(arrival_period * task.id)
            dec, feats, pred = self.decide(task, bw, classify)
            plan, wire_bits = self.plan_for(dec, bw)
            if self.batch_slack is not None:
                # same staleness deadline the async engines attach in
                # admit_plan (arrival = i * period in every engine)
                plan.deadline = i * arrival_period + self.batch_slack
            plans.append(plan)
            self.account(dec, feats, pred, task, wire_bits, acc)
        pr = run_pipeline(plans, arrival_period=arrival_period,
                          links=self.links, batch_caps=self.batch_caps,
                          pools=self.pools, router=self.make_router(),
                          sink=self.cfg.trace, migrate=self.cfg.migrate)
        return self._stats(pr, len(tasks), acc["exits"], acc["bits"],
                           acc["wire"], acc["correct"])
