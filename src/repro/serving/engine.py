"""COACH serving engine: the full online loop over a continuous task stream.

Wires together every subsystem:

  offline   partition + quantization (core.partitioner) on the model's cost
            graph -> a CollabRuntime split at the chosen group boundaries
            (one cut per hop; classic end->cloud is the single-cut case)
  frontend  task features from the end segment's boundary activation via
            the fused semantic-probe kernel (GAP + cosine + separability)
  online    early exit (Eq. 10) / adaptive precision (Eq. 11) per task
  pipeline  ``2n+1``-resource discrete-event accounting of the induced
            stream (latency / throughput / bubbles), with measured wire
            bytes; non-exit tasks carry one ``TaskPlan`` hop per link

The JAX compute is real (CollabRuntime executes both segments); the
*timing* comes from the calibrated device/link profiles, since this host
is not a Jetson + A6000 pair (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core import online as ON
from repro.core.collab import CollabRuntime
from repro.core.costs import DeviceProfile, LinkProfile
from repro.core.pipeline import PipelineResult, TaskPlan, run_pipeline
from repro.core.schedule import StageTimes
from repro.data.pipeline import CorrelatedTaskStream, Task


@dataclasses.dataclass
class EngineConfig:
    bits_levels: Sequence[int] = (3, 4, 5, 6, 8)
    default_bits: int = 8
    update_centers: bool = True
    eps: float = 0.005


@dataclasses.dataclass
class EngineStats:
    pipeline: PipelineResult
    exit_ratio: float
    mean_bits: float
    wire_kb_per_task: float
    accuracy: float


class CoachEngine:
    def __init__(self, runtime: CollabRuntime, stage_times: StageTimes,
                 end_dev: DeviceProfile, link: LinkProfile,
                 cloud_dev: DeviceProfile, n_labels: int,
                 calib_feats: np.ndarray, calib_labels: np.ndarray,
                 cfg: EngineConfig = EngineConfig(),
                 boundary_elems: Optional[int] = None,
                 links: Optional[Sequence[LinkProfile]] = None):
        """``links`` (one per hop, first = the end device's uplink)
        activates the multi-hop path; omitting it keeps the classic
        end->link->cloud deployment with ``link`` as the only hop."""
        self.rt = runtime
        self.st = stage_times
        self.links = list(links) if links is not None else [link]
        self.link = self.links[0]
        assert len(self.links) == stage_times.n_hops, \
            "need one link per stage-time hop"
        self.cfg = cfg
        dim = calib_feats.shape[1]
        self.cache = ON.SemanticCache(n_labels, dim)
        self.cache.warm_up(calib_feats, calib_labels)
        self.th = ON.calibrate_thresholds(self.cache, calib_feats,
                                          calib_labels, eps=cfg.eps,
                                          bit_levels=cfg.bits_levels)
        elems = boundary_elems or int(calib_feats.shape[1])
        self.sched = ON.OnlineScheduler(
            self.cache, self.th, elems, stage_times.T_e, stage_times.T_c,
            update_centers=cfg.update_centers)

    def run_stream(self, tasks: List[Task], arrival_period: float,
                   classify) -> EngineStats:
        """classify(task) -> (features, predicted_label): the caller runs
        the real model (CollabRuntime) or a proxy; the engine makes the
        COACH decisions and accounts the pipeline."""
        plans, bits_used, correct = [], [], []
        exits = 0
        wire_bits_total = 0.0
        for task in tasks:
            bw = self.link.bps_at(arrival_period * task.id)
            feats, pred = classify(task)
            dec = self.sched.step(feats, bandwidth_bps=bw)
            if dec.early_exit:
                exits += 1
                plans.append(TaskPlan(self.st.T_e, 0.0, 0.0, True))
                correct.append(dec.result == task.label)
            else:
                bits = dec.bits or self.cfg.default_bits
                bits_used.append(bits)
                wire_bits = self.sched.elems * bits
                wire_bits_total += wire_bits
                t_tx = wire_bits / bw
                st = self.st
                if st.n_hops == 1:
                    plans.append(TaskPlan(
                        st.T_e, t_tx, st.T_c,
                        tx_offset=min(st.first_tx_offset, st.T_e),
                        cloud_offset=st.cloud_start_offset))
                else:
                    # adaptive precision retimes the end device's uplink;
                    # the inner hops keep their offline-planned occupation
                    # (per-hop adaptive bits: ROADMAP open item)
                    plans.append(TaskPlan.multihop(
                        compute=st.compute,
                        tx=(t_tx,) + tuple(st.link[1:]),
                        tx_offsets=tuple(min(st.tx_offsets[k], st.compute[k])
                                         for k in range(st.n_hops)),
                        rx_offsets=st.rx_offsets))
                correct.append(pred == task.label)
                self.sched.report_label(feats, task.label)
        pr = run_pipeline(plans, arrival_period=arrival_period,
                          links=self.links)
        n = len(tasks)
        return EngineStats(
            pipeline=pr,
            exit_ratio=exits / n,
            mean_bits=float(np.mean(bits_used)) if bits_used else 0.0,
            wire_kb_per_task=wire_bits_total / 8e3 / n,
            accuracy=float(np.mean(correct)),
        )
