"""Data pipeline: synthetic-but-structured streams.

``SyntheticLM``      — deterministic Zipf-ish token stream with Markov
                       structure (a model can actually learn it, so the
                       train examples show decreasing loss).
``CorrelatedTaskStream`` — classification-task stream with controllable
                       temporal correlation (the paper's low/medium/high
                       levels, §IV-B Table II) and Gaussian class clusters
                       whose spread controls quantization sensitivity
                       (the §II-B spatial-locality observation).
``make_calibration_set`` — the offline calibration set D used to warm up
                       semantic centers and thresholds.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np


class SyntheticLM:
    """Order-1 Markov token generator over a Zipf vocabulary."""

    def __init__(self, vocab_size: int, seed: int = 0, branch: int = 8):
        rng = np.random.default_rng(seed)
        self.vocab = vocab_size
        self.branch = branch
        # each token transitions to one of `branch` successors
        self.next_tok = rng.integers(0, vocab_size, size=(vocab_size, branch))
        zipf = 1.0 / np.arange(1, branch + 1)
        self.next_p = zipf / zipf.sum()
        self.rng = rng

    def batch(self, batch_size: int, seq_len: int) -> np.ndarray:
        out = np.empty((batch_size, seq_len), np.int32)
        cur = self.rng.integers(0, self.vocab, size=batch_size)
        for t in range(seq_len):
            out[:, t] = cur
            choice = self.rng.choice(self.branch, size=batch_size, p=self.next_p)
            cur = self.next_tok[cur, choice]
        return out

    def __iter__(self) -> Iterator[np.ndarray]:
        while True:
            yield self.batch(8, 256)


@dataclasses.dataclass
class Task:
    id: int
    label: int
    features: np.ndarray  # frontend features (the end segment's input)
    # per-boundary activations for hop-level probes: row k feeds the
    # semantic probe at segment k (row 0 == ``features``); None when the
    # stream models a single probe depth
    hop_features: Optional[np.ndarray] = None


class CorrelatedTaskStream:
    """Streams classification tasks with temporal locality.

    correlation:  "low"    — iid label draws (random frames)
                  "medium" — runs of ~5 same-label tasks (random videos)
                  "high"   — runs of ~20 (sequential videos)
    Class c's features ~ N(mu_c, sigma_c I); sigma varies per class so some
    tasks need higher quantization precision (Fig. 1b clusters).

    ``n_probe_depths > 1`` additionally emits per-boundary activations
    (``Task.hop_features``): depth ``k``'s features shrink the scene/noise
    displacement by ``depth_decay ** k`` — deeper layers concentrate class
    evidence (the SPINN-style progressive-inference observation), so
    deeper semantic probes separate tasks the shallow probe could not.
    Depth 0 is bit-identical to ``features`` and the rng draw sequence
    does not depend on ``n_probe_depths`` (seeded streams stay exactly
    reproducible across the classic and hop-level configurations).
    """

    RUN = {"low": 1, "medium": 5, "high": 20}

    def __init__(self, n_labels: int = 20, dim: int = 64,
                 correlation: str = "medium", seed: int = 0,
                 label_skew: float = 1.2, drift: float = 0.1,
                 n_probe_depths: int = 1, depth_decay: float = 0.5):
        rng = np.random.default_rng(seed)
        self.rng = rng
        self.n_labels = n_labels
        self.dim = dim
        self.mu = rng.normal(size=(n_labels, dim)) * 1.0
        self.mu0 = self.mu.copy()
        self.sigma = rng.uniform(1.5, 3.5, size=n_labels)
        # class centers drift (scene/lighting change through a video):
        # with temporal correlation the semantic cache tracks the drift and
        # stays separable; uncorrelated streams leave centers stale
        self.drift = drift
        assert n_probe_depths >= 1 and 0.0 < depth_decay <= 1.0
        self.n_probe_depths = n_probe_depths
        self.depth_decay = depth_decay
        self.run = self.RUN[correlation]
        w = 1.0 / np.arange(1, n_labels + 1) ** label_skew  # long-tail
        self.label_p = w / w.sum()
        self._cur_label: Optional[int] = None
        self._left = 0
        self._id = 0

    def _next_label(self) -> int:
        if self._left <= 0:
            self._cur_label = int(self.rng.choice(self.n_labels, p=self.label_p))
            self._left = max(1, int(self.rng.poisson(self.run)))
            # new "video": a scene offset shared by the whole run — frames
            # within a run are near-duplicates (Fig. 1a temporal locality)
            self._scene = self.rng.normal(size=self.dim) * self.sigma[self._cur_label]
        self._left -= 1
        return self._cur_label

    def next_task(self) -> Task:
        j = self._next_label()
        self._scene += self.rng.normal(size=self.dim) * self.drift  # pan/zoom
        disp = self._scene + self.rng.normal(size=self.dim) * 0.3 * self.sigma[j]
        f = self.mu[j] + disp
        hop_feats = None
        if self.n_probe_depths > 1:
            hop_feats = np.stack([
                (self.mu[j] + disp * self.depth_decay ** k).astype(np.float32)
                for k in range(self.n_probe_depths)])
        t = Task(self._id, j, f.astype(np.float32), hop_features=hop_feats)
        self._id += 1
        return t

    def tasks(self, n: int):
        return [self.next_task() for _ in range(n)]


def make_calibration_set(stream: CorrelatedTaskStream, n: int = 500,
                         seed: int = 1) -> Tuple[np.ndarray, np.ndarray]:
    """Offline calibration set D (features, labels) drawn iid."""
    saved = (stream._cur_label, stream._left)
    stream._cur_label, stream._left = None, 0
    rng = np.random.default_rng(seed)
    feats, labels = [], []
    for _ in range(n):
        j = int(rng.choice(stream.n_labels, p=stream.label_p))
        f = stream.mu0[j] + rng.normal(size=stream.dim) * stream.sigma[j]
        feats.append(f.astype(np.float32))
        labels.append(j)
    stream._cur_label, stream._left = saved
    return np.stack(feats), np.asarray(labels)


def make_hop_calibration_sets(stream: CorrelatedTaskStream, n: int = 500,
                              n_depths: Optional[int] = None, seed: int = 1):
    """Per-boundary calibration sets for hop-level probes: one
    ``(features, labels)`` pair per probe depth, drawn iid with the same
    depth attenuation the stream applies (depth 0 reproduces
    ``make_calibration_set`` exactly for the same seed, so the end
    device's classic calibration is the ``n_depths = 1`` special case)."""
    if n_depths is None:
        n_depths = stream.n_probe_depths
    assert n_depths >= 1
    rng = np.random.default_rng(seed)
    feats = [[] for _ in range(n_depths)]
    labels = []
    for _ in range(n):
        j = int(rng.choice(stream.n_labels, p=stream.label_p))
        disp = rng.normal(size=stream.dim) * stream.sigma[j]
        for k in range(n_depths):
            feats[k].append(
                (stream.mu0[j] + disp * stream.depth_decay ** k
                 ).astype(np.float32))
        labels.append(j)
    labels = np.asarray(labels)
    return [(np.stack(f), labels) for f in feats]
