from repro.data.pipeline import (SyntheticLM, CorrelatedTaskStream,
                                 make_calibration_set)
