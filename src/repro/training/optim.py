"""AdamW with cosine schedule — pure JAX, pytree-shaped like the params so
optimizer state inherits the parameter sharding (ZeRO-style under pjit).

``state_dtype`` controls the m/v moment precision: float32 for real
training (examples/train_small.py), bfloat16 for the 398B dry-run where
moment memory dominates the per-chip HBM budget (see EXPERIMENTS.md).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    state_dtype: Any = jnp.float32
    # dtype for the moment/update arithmetic; bfloat16 halves the optimizer
    # temp traffic for the >100B configs (paired with bf16 state)
    compute_dtype: Any = jnp.float32


def cosine_lr(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    z = lambda p: jnp.zeros_like(p, dtype=cfg.state_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(z, params), v=jax.tree.map(z, params))


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig):
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    ct = cfg.compute_dtype

    def new_m(g, m):
        return (jnp.asarray(cfg.b1, ct) * m.astype(ct)
                + jnp.asarray(1 - cfg.b1, ct) * g.astype(ct)).astype(cfg.state_dtype)

    def new_v(g, v):
        gc = g.astype(ct)
        return (jnp.asarray(cfg.b2, ct) * v.astype(ct)
                + jnp.asarray(1 - cfg.b2, ct) * gc * gc).astype(cfg.state_dtype)

    m2 = jax.tree.map(new_m, grads, state.m)
    v2 = jax.tree.map(new_v, grads, state.v)

    def new_p(p, m, v):
        upd = (m.astype(ct) / bc1.astype(ct)) / \
            (jnp.sqrt(v.astype(ct) / bc2.astype(ct)) + jnp.asarray(cfg.eps, ct))
        upd = upd + jnp.asarray(cfg.weight_decay, ct) * p.astype(ct)
        return (p.astype(ct) - lr.astype(ct) * upd).astype(p.dtype)

    p2 = jax.tree.map(new_p, params, m2, v2)
    return p2, AdamWState(step=step, m=m2, v=v2)
