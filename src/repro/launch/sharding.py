"""Parameter / activation / cache sharding rules for the production mesh.

Strategy (recorded in EXPERIMENTS.md §Perf as the paper-faithful baseline):

  - every >=2D weight is FSDP-sharded: dim_a over the data axes, dim_b over
    the model axis (when divisible) — this is what keeps the 398B Jamba
    within a v5e's HBM including optimizer moments;
  - MoE expert stacks (E, D, F) shard D over data, F over model;
  - 1D scales shard over model when divisible;
  - the leading scan-group stack dim is always replicated;
  - batch shards over ("pod","data"); decode KV caches shard the *sequence*
    axis over "model" (kv-head counts don't divide 16) and batch over data.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        return int(np.prod([_axis_size(mesh, n) for n in name]))
    return mesh.shape[name]


def _fit(dim: int, mesh: Mesh, axis) -> Optional[Any]:
    """axis if it divides dim else None."""
    if axis == () or axis is None:
        return None
    return axis if dim % _axis_size(mesh, axis) == 0 else None


# row-parallel matrices: contraction (input) dim is the one the activations
# arrive sharded on (model axis); output dim joins the data/FSDP axis.
_ROW_PARALLEL = ("w_down", "wo", "out_proj")


def param_spec(path: str, shape: Tuple[int, ...], mesh: Mesh,
               n_groups: int, serving: bool = False) -> P:
    """PartitionSpec for one parameter leaf (path = '/'-joined keys).

    Column-parallel (default): (in, out) -> (data, model), activations leave
    sharded on the model axis.  Row-parallel (w_down/wo/out_proj): (in, out)
    -> (model, data), consuming model-sharded activations with a psum.
    Both orientations FSDP-shard the other dim over data for HBM.

    ``serving=True`` drops the data-axis (FSDP) shardings: decode/prefill
    steps otherwise all-gather every weight once per step, which made small-
    model decode collective-bound (§Perf pair 2) — tensor-parallel over
    "model" only, weights replicated across data, is the serving layout
    whenever the model fits (params/16 within the HBM budget).
    """
    data = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if serving:
        data = ()
    stacked = shape[:1] == (n_groups,) and "groups" in path
    core = shape[1:] if stacked else shape
    lead = (None,) if stacked else ()
    row = any(path.endswith(r) for r in _ROW_PARALLEL)

    def spec(*parts):
        return P(*lead, *parts)

    if len(core) == 3:  # MoE expert stacks
        if row:  # w_down (E, F, D)
            return spec(None, _fit(core[1], mesh, "model"),
                        _fit(core[2], mesh, data))
        return spec(None, _fit(core[1], mesh, data),
                    _fit(core[2], mesh, "model"))
    if len(core) == 2:
        if row or path.endswith("embed"):
            # embed (V, D): V over model so tied-head logits come out
            # model-sharded, matching the "logits" activation constraint
            a = _fit(core[0], mesh, "model")
            b = _fit(core[1], mesh, data)
            return spec(a, b)
        a = _fit(core[0], mesh, data)
        b = _fit(core[1], mesh, "model")
        if a is None and b is None:
            a = _fit(core[0], mesh, "model")
            b = _fit(core[1], mesh, data) if a is not None else None
        return spec(a, b)
    if len(core) == 1:
        return spec(_fit(core[0], mesh, "model"))
    return spec(*([None] * len(core)))


def shard_params(params, mesh: Mesh, cfg: ModelConfig,
                 serving: bool = False):
    """NamedShardings pytree matching ``params`` structure."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        specs.append(NamedSharding(
            mesh, param_spec(pstr, leaf.shape, mesh, cfg.num_groups,
                             serving=serving)))
    return jax.tree_util.tree_unflatten(treedef, specs)


def serving_layout_fits(params, mesh: Mesh, budget_bytes: float = 8e9) -> bool:
    """True if model-parallel-only weights fit the per-chip budget."""
    total = sum(l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(params))
    return total / _axis_size(mesh, "model") <= budget_bytes


# ------------------------------------------------------------- activations
def batch_spec(mesh: Mesh, batch: int, extra_dims: int = 1) -> P:
    data = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    ax = data if batch % _axis_size(mesh, data) == 0 else (
        "data" if batch % _axis_size(mesh, "data") == 0 else None)
    return P(ax, *([None] * extra_dims))


def cache_spec(mesh: Mesh, cfg: ModelConfig, batch: int, leaf_shape) -> P:
    """Decode-cache leaf shardings.  Leaves (leading group dim G):
       attn k/v  (G, B, L, KV, hd) -> batch over data, seq L over model
       attn pos  (G, L)
       ssm state (G, B, H, P, N)   -> batch over data, heads over model
       ssm conv  (G, B, K-1, Dc)   -> batch over data, Dc over model
    """
    data = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    nd = len(leaf_shape)
    if nd == 5 and leaf_shape[3] == cfg.num_kv_heads \
            and leaf_shape[4] == cfg.head_dim:  # kv cache
        b_ax = _fit(leaf_shape[1], mesh, data) or _fit(leaf_shape[1], mesh, "data")
        s_ax = _fit(leaf_shape[2], mesh, "model")
        if b_ax is None:  # batch=1 long-context: shard seq over everything
            s_ax = _fit(leaf_shape[2], mesh, ("data", "model")) or s_ax
        return P(None, b_ax, s_ax, None, None)
    if nd == 5:  # ssm state (G,B,H,P,N)
        b_ax = _fit(leaf_shape[1], mesh, data) or _fit(leaf_shape[1], mesh, "data")
        return P(None, b_ax, _fit(leaf_shape[2], mesh, "model"), None, None)
    if nd == 4:  # ssm conv (G,B,K-1,Dc)
        b_ax = _fit(leaf_shape[1], mesh, data) or _fit(leaf_shape[1], mesh, "data")
        return P(None, b_ax, None, _fit(leaf_shape[3], mesh, "model"))
    if nd == 2:  # kv pos (G, L)
        return P(None, None)
    return P(*([None] * nd))


def shard_cache(cache, mesh: Mesh, cfg: ModelConfig, batch: int):
    return jax.tree.map(
        lambda leaf: NamedSharding(
            mesh, cache_spec(mesh, cfg, batch, leaf.shape)), cache)


def activation_specs(cfg: ModelConfig, mesh: Mesh, batch: int,
                     collab: bool = False):
    """PartitionSpecs for ``repro.models.shardctx`` constraint points.

    Model-parallel axes only apply when the dimension divides the axis size
    (e.g. qwen2-vl's 12 heads stay replicated on a 16-way model axis).
    ``collab=True`` builds specs for inside the pod-manual shard_map of the
    collaborative pipeline, where "pod" must not appear in auto specs."""
    data = ("pod", "data") if ("pod" in mesh.axis_names and not collab) \
        else ("data",)
    b = data if batch % _axis_size(mesh, data) == 0 else (
        "data" if batch % _axis_size(mesh, "data") == 0 else None)
    m = lambda dim: _fit(dim, mesh, "model")
    hd = cfg.head_dim
    return {
        "hidden": P(b, None, None),
        "q_heads": P(b, None, m(cfg.num_heads), None),
        "kv_heads": P(b, None, m(cfg.num_kv_heads), None),
        "attn_out": P(b, None, m(cfg.num_heads * hd)),
        "ffn": P(b, None, m(cfg.d_ff) if cfg.d_ff else None),
        "logits": P(b, None, m(cfg.vocab_size)),
        "ssm_heads": P(b, None, m(cfg.ssm_heads), None) if cfg.ssm_state else None,
        "ssm_inner": P(b, None, m(cfg.ssm_inner)) if cfg.ssm_state else None,
        "conv": P(b, None, m(cfg.ssm_inner + 2 * cfg.ssm_state))
            if cfg.ssm_state else None,
        # MoE dispatch: token groups over data, expert FFN width over model
        "moe_oh": P(b, None, None),
        "moe_buf": P(b, None, None, None),
        "moe_h": P(b, None, None, m(cfg.d_ff) if cfg.d_ff else None),
        # intra-chunk SSD tensors: shard the chunk axis over "model"
        "ssm_chunk_x": P(b, "model", None, None, None),
        "ssm_chunk_dt": P(b, "model", None, None),
        "ssm_chunk_bc": P(b, "model", None, None, None),
        "ssm_chunk_l": P(b, "model", None, None, None),
        "ssm_chunk_s": P(b, "model", None, None, None),
    }
