"""Training launcher.

CPU-scale real runs (examples) and the production-mesh entry point.

  python -m repro.launch.train --arch gemma2-2b --smoke --steps 50
  python -m repro.launch.train --arch qwen3-14b --production  # on a pod
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, latest_step, save_checkpoint
from repro.configs import ARCHS, get_config
from repro.data.pipeline import SyntheticLM
from repro.launch import steps as ST
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.sharding import shard_params
from repro.models import model as M
from repro.training.optim import AdamWConfig, adamw_init


def train(arch: str, *, smoke: bool = True, steps: int = 50,
          batch: int = 8, seq: int = 256, lr: float = 3e-4,
          ckpt_dir: str | None = None, ckpt_every: int = 100,
          microbatches: int = 1, log_every: int = 10, seed: int = 0,
          production: bool = False):
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.reduced()
    mesh = make_production_mesh() if production else make_host_mesh()
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=max(steps // 20, 5),
                          total_steps=steps)

    key = jax.random.PRNGKey(seed)
    params = M.init_params(cfg, key,
                           dtype=jnp.bfloat16 if production else jnp.float32)
    opt = adamw_init(params, opt_cfg)
    start = 0
    if ckpt_dir and (s := latest_step(ckpt_dir)) is not None:
        params = load_checkpoint(ckpt_dir, s, params)
        start = s

    step_fn = jax.jit(ST.make_train_step(cfg, opt_cfg,
                                         microbatches=microbatches),
                      donate_argnums=(0, 1))
    data = SyntheticLM(cfg.vocab_size, seed=seed)
    losses = []
    t0 = time.time()
    for i in range(start, steps):
        toks = jnp.asarray(data.batch(batch, seq))
        if cfg.embed_inputs:
            emb = jax.random.normal(jax.random.fold_in(key, i),
                                    (batch, seq, cfg.d_model)) * 0.3
            b = {"embeds": emb, "labels": toks}
        else:
            b = {"tokens": toks, "labels": toks}
        params, opt, loss, mets = step_fn(params, opt, b)
        losses.append(float(loss))
        if (i + 1) % log_every == 0:
            dt = (time.time() - t0) / log_every
            print(f"step {i+1:5d} loss {np.mean(losses[-log_every:]):.4f} "
                  f"({dt*1e3:.0f} ms/step)")
            t0 = time.time()
        if ckpt_dir and (i + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_dir, i + 1, params)
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--production", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    train(args.arch, smoke=not args.production, steps=args.steps,
          batch=args.batch, seq=args.seq, lr=args.lr,
          microbatches=args.microbatches, ckpt_dir=args.ckpt_dir,
          production=args.production)


if __name__ == "__main__":
    main()
