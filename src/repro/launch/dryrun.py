"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, and record memory/cost/collective analysis.

MUST be run as a module entry point (``python -m repro.launch.dryrun``):
the XLA host-device override below has to execute before jax initializes.

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""

# --- MUST be first, before ANY other import (jax locks device count) -------
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
    + " " + os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")).strip()
# ---------------------------------------------------------------------------

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config, shape_supported
from repro.launch import hlo_analysis as H
from repro.launch import hlo_cost as HC
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (activation_specs, batch_spec, shard_cache,
                                   shard_params)
from repro.models.shardctx import activation_sharding
from repro.training.optim import AdamWConfig


def lower_pair(arch: str, shape_name: str, multi_pod: bool,
               dtype=jnp.bfloat16):
    """Returns (lowered, compiled, report dict)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_supported(cfg, shape)
    if not ok:
        return None, None, {"arch": arch, "shape": shape_name,
                            "skipped": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    aparams = ST.abstract_params(cfg, dtype)
    # serving steps use the model-parallel-only weight layout when the model
    # fits (avoids per-step FSDP all-gathers; §Perf pairs 1-2)
    from repro.launch.sharding import serving_layout_fits
    serving = shape.kind != "train" and serving_layout_fits(aparams, mesh) \
        and os.environ.get("REPRO_SERVING_LAYOUT", "1") == "1"
    pshard = shard_params(aparams, mesh, cfg, serving=serving)
    specs = ST.input_specs(cfg, shape, dtype)
    repl = NamedSharding(mesh, P())
    aspecs = activation_specs(cfg, mesh, shape.global_batch)

    t0 = time.time()
    import contextlib
    ctx = contextlib.ExitStack()
    ctx.enter_context(mesh)
    ctx.enter_context(activation_sharding(aspecs))
    if shape.kind == "train":
        # bf16 moments for the >100B configs (HBM budget), f32 otherwise
        big = H._active_params(cfg) > 2e10 or cfg.num_experts > 0
        opt_cfg = AdamWConfig(
            state_dtype=jnp.bfloat16 if big else jnp.float32,
            compute_dtype=jnp.bfloat16 if big else jnp.float32)
        aopt = ST.abstract_opt_state(aparams, opt_cfg)
        # moments share the param tree structure => inherit param shardings
        oshard = shard_params(aopt.m, mesh, cfg)
        opt_shard = type(aopt)(step=repl, m=oshard, v=oshard)
        bshard = {k: NamedSharding(mesh, batch_spec(mesh, shape.global_batch,
                                                    v.ndim - 1))
                  for k, v in specs.items()}
        fn = ST.make_train_step(cfg, opt_cfg,
                                microbatches=int(os.environ.get(
                                    "REPRO_MICROBATCHES", "4")))
        jfn = jax.jit(fn, in_shardings=(pshard, opt_shard, bshard),
                      out_shardings=(pshard, opt_shard, repl, repl),
                      donate_argnums=(0, 1))
        lowered = jfn.lower(aparams, aopt, specs)
    elif shape.kind == "prefill":
        fn = ST.make_prefill_step(
            cfg, max_seq=shape.seq_len,
            batch_chunks=int(os.environ.get("REPRO_PREFILL_CHUNKS", "1")))
        bshard = {"inputs": NamedSharding(
            mesh, batch_spec(mesh, shape.global_batch,
                             specs["inputs"].ndim - 1))}
        jfn = jax.jit(fn, in_shardings=(pshard, bshard["inputs"]))
        lowered = jfn.lower(aparams, specs["inputs"])
    else:  # decode
        acache = ST.abstract_cache(cfg, shape.global_batch, shape.seq_len,
                                   dtype)
        cshard = shard_cache(acache, mesh, cfg, shape.global_batch)
        xshard = NamedSharding(
            mesh, batch_spec(mesh, shape.global_batch,
                             specs["inputs"].ndim - 1))
        fn = ST.make_serve_step(cfg)
        jfn = jax.jit(fn, in_shardings=(pshard, cshard, xshard, repl),
                      out_shardings=(NamedSharding(mesh, P()), cshard),
                      donate_argnums=(1,))
        lowered = jfn.lower(aparams, acache, specs["inputs"], specs["pos"])
    ctx.close()
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    hlo = compiled.as_text()
    # trip-count-aware analysis (XLA's cost_analysis counts while-loop
    # bodies once; see launch.hlo_cost)
    hc = HC.analyze(hlo)
    roof = H.Roofline(flops=hc.flops, hbm_bytes=hc.hbm_bytes,
                      coll_bytes=hc.coll_bytes)
    xla_cost = compiled.cost_analysis()
    if isinstance(xla_cost, list):
        xla_cost = xla_cost[0]
    coll = H.collective_bytes(hlo)
    mem = H.memory_stats(compiled)
    model_fl = H.model_flops_estimate(cfg, shape)
    n_dev = mesh.devices.size
    report = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem,
        "cost": {"flops_per_dev": roof.flops,
                 "hbm_bytes_per_dev": roof.hbm_bytes,
                 "xla_flops_raw": float(xla_cost.get("flops", 0.0)),
                 "xla_bytes_raw": float(xla_cost.get("bytes accessed", 0.0))},
        "collectives": coll,
        "roofline": roof.as_dict(),
        "model_flops_total": model_fl,
        "model_flops_per_dev": model_fl / n_dev,
        "useful_flop_frac": (model_fl / n_dev) / roof.flops if roof.flops else None,
    }
    return lowered, compiled, report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    pairs = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                pairs.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    failures = 0
    for (a, s) in pairs:
        tag = f"{a}_{s}_{'multi' if args.multi_pod else 'single'}"
        try:
            _, compiled, rep = lower_pair(a, s, args.multi_pod)
            if compiled is not None:
                print(f"[dryrun] {tag}: compile_s={rep['compile_s']} "
                      f"bottleneck={rep['roofline']['bottleneck']} "
                      f"mem={rep['memory'].get('total_nonalias_bytes', 0)/1e9:.2f}GB/dev")
                print(compiled.memory_analysis())
                ca = compiled.cost_analysis()
                print({k: ca[k] for k in sorted(ca)[:8]} if hasattr(ca, 'keys') else ca)
            else:
                print(f"[dryrun] {tag}: SKIP ({rep['skipped']})")
        except Exception as e:
            failures += 1
            rep = {"arch": a, "shape": s, "error": repr(e),
                   "traceback": traceback.format_exc()}
            print(f"[dryrun] {tag}: FAIL {e!r}")
        (outdir / f"{tag}.json").write_text(json.dumps(rep, indent=2))
    if failures:
        raise SystemExit(f"{failures} dry-run failures")


if __name__ == "__main__":
    main()
