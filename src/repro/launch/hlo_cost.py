"""Trip-count-aware cost analysis over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE, so any
scanned layer stack is undercounted by its trip count (verified: a
10-step lax.scan reports ~1/10 the flops of the unrolled loop).  This
module re-derives roofline quantities from ``compiled.as_text()``:

  flops            dot/convolution FLOPs, while-bodies multiplied by their
                   ``known_trip_count`` backend config
  hbm_bytes        materialized-buffer traffic: every top-level op's output
                   written once + read once per consumer reference
                   (fusion internals excluded — they stay in registers/VMEM)
  collective_bytes operand bytes of all-gather / all-reduce /
                   reduce-scatter / all-to-all / collective-permute

Shapes in post-SPMD HLO are per-device, so all quantities are per-chip.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 0.5, "u4": 0.5,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_CALL_RE = re.compile(r"(?:calls|body|condition|branch_computations)="
                      r"\{?(%[\w.\-]+(?:,\s*%[\w.\-]+)*)\}?")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _first_shape(text: str) -> Optional[Tuple[str, int]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    return m.group(1), _shape_elems(m.group(2))


def _all_shapes_bytes(text: str) -> float:
    return sum(_shape_elems(d) * _DTYPE_BYTES.get(t, 0)
               for t, d in _SHAPE_RE.findall(text))


@dataclasses.dataclass
class OpLine:
    name: str
    opcode: str
    out_bytes: float
    rhs: str
    operands: List[str]
    calls: List[str]
    trip: int = 1


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0

    def __iadd__(self, o):
        self.flops += o.flops
        self.hbm_bytes += o.hbm_bytes
        self.coll_bytes += o.coll_bytes
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.hbm_bytes * k, self.coll_bytes * k)


def _parse_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        s = line.rstrip()
        m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$", s)
        if cur is None and m and ("->" in s or s.startswith("ENTRY")):
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if s.strip() == "}":
                cur = None
            else:
                comps[cur].append(s)
    return comps


_OPCODE_RE = re.compile(r"^\(?[a-z0-9\[\],\s\{\}:*]*\)?\s*([a-z][\w\-]*)\(")


def _parse_op(line: str) -> Optional[OpLine]:
    m = _DEF_RE.match(line)
    if not m:
        return None
    name, rhs = m.groups()
    # rhs: "<type> <opcode>(<operands>), attrs..."
    tm = _SHAPE_RE.match(rhs) or _SHAPE_RE.search(rhs.split("(")[0] + "(")
    out_bytes = 0.0
    head = rhs.split("(", 1)[0]
    out_bytes = _all_shapes_bytes(head)
    om = re.search(r"\)?\s*([a-z][\w\-]*)\(", rhs)
    opcode = om.group(1) if om else ""
    paren = rhs[rhs.find("("):]
    # operands: up to the closing paren of the op call (crude but effective:
    # attrs follow after '), ')
    args = paren.split("), ")[0]
    operands = _OPERAND_RE.findall(args)
    calls = []
    for cm in _CALL_RE.finditer(rhs):
        calls += [c.strip().lstrip("%") for c in cm.group(1).split(",")]
    trip = 1
    tm2 = _TRIP_RE.search(rhs)
    if tm2:
        trip = int(tm2.group(1))
    return OpLine(name, opcode, out_bytes, rhs, operands, calls, trip)


def _dot_flops(op: OpLine, dims: Dict[str, Tuple[int, ...]],
               elems: Dict[str, int]) -> float:
    """FLOPs = 2 * out_elems * contraction_size (shapes resolved within the
    op's own computation — HLO value names are only unique per-computation)."""
    out = _first_shape(op.rhs.split(op.opcode)[0])
    if out is None:
        return 0.0
    out_elems = out[1]
    lhs = op.operands[0] if op.operands else None
    dims_m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rhs)
    lhs_shape = dims.get(lhs)
    if dims_m and lhs_shape:
        try:
            k = 1
            for d in dims_m.group(1).split(","):
                if d:
                    k *= lhs_shape[int(d)]
            return 2.0 * out_elems * k
        except (IndexError, ValueError):
            pass
    # fallback: approximate contraction via operand/output element ratio
    if lhs in elems and out_elems:
        return 2.0 * out_elems * max(elems[lhs] // max(out_elems, 1), 1)
    return 2.0 * out_elems


def analyze(text: str) -> Cost:
    comps = _parse_computations(text)
    parsed: Dict[str, List[OpLine]] = {}
    shapes_by_comp: Dict[str, Dict[str, float]] = {}
    elems_by_comp: Dict[str, Dict[str, int]] = {}
    dims_by_comp: Dict[str, Dict[str, Tuple[int, ...]]] = {}
    for cname, lines in comps.items():
        ops = []
        shp: Dict[str, float] = {}
        elm: Dict[str, int] = {}
        dms: Dict[str, Tuple[int, ...]] = {}
        for ln in lines:
            op = _parse_op(ln)
            if op is None:
                continue
            ops.append(op)
            shp[op.name] = op.out_bytes
            fs = _first_shape(op.rhs.split("(")[0])
            elm[op.name] = fs[1] if fs else 0
            m = _SHAPE_RE.match(op.rhs)
            if m:
                dms[op.name] = tuple(int(d) for d in m.group(2).split(",") if d)
        parsed[cname] = ops
        shapes_by_comp[cname] = shp
        elems_by_comp[cname] = elm
        dims_by_comp[cname] = dms

    memo: Dict[str, Cost] = {}

    def comp_cost(cname: str, top: bool) -> Cost:
        key = f"{cname}|{top}"
        if key in memo:
            return memo[key]
        memo[key] = Cost()  # cycle guard
        total = Cost()
        shp = shapes_by_comp.get(cname, {})
        for op in parsed.get(cname, []):
            sub = Cost()
            if op.opcode == "while" and op.calls:
                for c in op.calls:
                    if c in parsed:
                        sub += comp_cost(c, top)
                sub = sub.scaled(op.trip)
            elif op.opcode in ("fusion",):
                # fused internals: count flops/collectives, not HBM traffic
                for c in op.calls:
                    if c in parsed:
                        inner = comp_cost(c, False)
                        sub += Cost(inner.flops, 0.0, inner.coll_bytes)
            elif op.opcode in ("call", "conditional", "custom-call"):
                for c in op.calls:
                    if c in parsed:
                        sub += comp_cost(c, top)
            elif op.opcode in ("dot", "convolution"):
                sub.flops += _dot_flops(op, dims_by_comp[cname],
                                        elems_by_comp[cname])
            coll = next((c for c in _COLLECTIVES
                         if op.opcode.startswith(c)), None)
            if coll and not op.opcode.endswith("-done"):
                sub.coll_bytes += sum(
                    shp.get(o, 0.0) for o in op.operands) or op.out_bytes
            if top and op.opcode == "dynamic-update-slice":
                # in-place on TPU (loop-aliased buffers): traffic = the
                # updated region only, not the whole operand buffer
                upd = shp.get(op.operands[1], 0.0) if len(op.operands) > 1 \
                    else op.out_bytes
                sub.hbm_bytes += 2 * upd
            elif top and op.opcode == "dynamic-slice":
                sub.hbm_bytes += 2 * op.out_bytes  # read region + write out
            elif top and op.opcode not in ("parameter", "constant",
                                           "get-tuple-element", "tuple",
                                           "bitcast", "copy", "copy-start",
                                           "copy-done"):
                # (copies are loop-state bookkeeping the TPU backend elides
                # via in-place buffer aliasing — counting them double-charges
                # every while-carried weight per iteration)
                # materialized write + one read per consumer reference
                sub.hbm_bytes += op.out_bytes
                sub.hbm_bytes += sum(shp.get(o, 0.0) for o in op.operands)
            total += sub
        memo[key] = total
        return total

    entry = next((c for c in comps if "main" in c), None)
    if entry is None:
        entry = next(iter(comps), None)
    return comp_cost(entry, True) if entry else Cost()
