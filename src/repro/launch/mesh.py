"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialization).
"""

from __future__ import annotations

import os

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 v5e chips) or 2x16x16 multi-pod (512 chips).

    REPRO_MESH_SHAPE (e.g. "4,8" or "2,4,4") overrides the chip counts for
    fast debugging iterations; axis names follow the entry count.
    """
    env = os.environ.get("REPRO_MESH_SHAPE")
    if env:
        shape = tuple(int(x) for x in env.split(","))
        axes = ("pod", "data", "model")[-len(shape):]
        return jax.make_mesh(shape, axes)
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU smoke tests and examples."""
    return jax.make_mesh((1, 1), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Axes the global batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
