"""Step functions (train / prefill / decode) and abstract input specs for
every (architecture x input shape) pair — shared by the dry-run, the real
launchers, and the benchmarks.

All specs are ``jax.ShapeDtypeStruct`` stand-ins: weak-type-correct,
shardable, and never allocated (the 398B configs only ever exist as
abstract pytrees on this host).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import InputShape
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.training.optim import AdamWConfig, AdamWState, adamw_init, adamw_update


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    microbatches: int = 1):
    """One optimizer step.  ``microbatches > 1`` accumulates gradients over
    K sequential microbatches (lax.scan): activation temp memory scales 1/K
    while the params/optimizer footprint is unchanged — the lever that fits
    the MoE giants' train_4k on a 16GB v5e (EXPERIMENTS.md §Perf)."""
    grad_fn = jax.value_and_grad(M.forward_train, has_aux=True)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, cfg, batch)
        else:
            K = microbatches
            mb = jax.tree.map(
                lambda x: x.reshape((K, x.shape[0] // K) + x.shape[1:]),
                batch)

            def acc(carry, b):
                gsum, lsum = carry
                (loss, mets), g = grad_fn(params, cfg, b)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + loss), mets

            zeros = jax.tree.map(jnp.zeros_like, params)
            (grads, loss), metrics = jax.lax.scan(
                acc, (zeros, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: g / K, grads)
            loss = loss / K
            metrics = jax.tree.map(lambda m: jnp.mean(m), metrics)
        params, opt_state = adamw_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, loss, metrics
    return train_step


def make_prefill_step(cfg: ModelConfig, max_seq: int, batch_chunks: int = 1):
    """``batch_chunks > 1`` maps the prefill over batch sub-chunks
    sequentially (lax.map): activation temps scale ~1/chunks while the
    returned logits/caches are identical — the serving-side analogue of
    gradient-accumulation (per-chunk batch must still divide the data axes).
    """
    def prefill_step(params, inputs):
        if batch_chunks == 1:
            return M.prefill(params, cfg, inputs, max_seq)
        B = inputs.shape[0]
        assert B % batch_chunks == 0
        xs = inputs.reshape((batch_chunks, B // batch_chunks)
                            + inputs.shape[1:])
        logits, caches = jax.lax.map(
            lambda x: M.prefill(params, cfg, x, max_seq), xs)
        merge_l = logits.reshape((B,) + logits.shape[2:])
        # batched cache leaves are (chunks, G, b, ...) -> (G, B, ...);
        # batch-free leaves (kv "pos", (chunks, G, L)) are chunk-invariant
        merge_c = jax.tree.map(
            lambda t: jnp.moveaxis(t, 0, 1).reshape(
                (t.shape[1], B) + t.shape[3:]) if t.ndim >= 5 else t[0],
            caches)
        return merge_l, merge_c
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, tokens, pos):
        return M.decode_step(params, cfg, cache, tokens, pos)
    return serve_step


# ----------------------------------------------------------------- specs
def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0), dtype=dtype))


def abstract_opt_state(aparams, opt_cfg: AdamWConfig):
    return jax.eval_shape(lambda p: adamw_init(p, opt_cfg), aparams)


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int,
                   dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: M.init_cache(cfg, batch, max_seq, dtype=dtype))


def input_specs(cfg: ModelConfig, shape: InputShape,
                dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Abstract model inputs for one assigned input shape.

    train:   {"tokens"|"embeds", "labels"}
    prefill: {"inputs"}
    decode:  {"tokens"|"embeds" (B,1[,D]), "pos"} (+ cache built separately)
    """
    B, S = shape.global_batch, shape.seq_len
    tok = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
    emb = lambda *s: jax.ShapeDtypeStruct(s, dtype)
    if shape.kind == "train":
        x = {"embeds": emb(B, S, cfg.d_model)} if cfg.embed_inputs \
            else {"tokens": tok(B, S)}
        return {**x, "labels": tok(B, S)}
    if shape.kind == "prefill":
        return {"inputs": emb(B, S, cfg.d_model) if cfg.embed_inputs
                else tok(B, S)}
    if shape.kind == "decode":
        x = emb(B, 1, cfg.d_model) if cfg.embed_inputs else tok(B, 1)
        return {"inputs": x, "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    raise ValueError(shape.kind)
