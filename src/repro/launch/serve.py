"""Serving launcher: batched prefill + decode with the COACH collaborative
split (end pod / cloud pod) and the online scheduler in the loop.

  python -m repro.launch.serve --arch gemma2-2b --smoke --requests 64
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.core import online as ON
from repro.core.collab import CollabRuntime
from repro.core.costs import (A6000_SERVER, JETSON_NX, WIFI_5GHZ,
                              transformer_graph)
from repro.core.partitioner import coach_offline
from repro.data.pipeline import CorrelatedTaskStream
from repro.models import model as M
from repro.obs.bubbles import attribute, chain_resources
from repro.obs.export import text_summary
from repro.obs.trace import TraceRecorder
from repro.serving.engine import CoachEngine, EngineConfig


def serve(arch: str, *, smoke: bool = True, requests: int = 200,
          bandwidth_mbps: float = 50.0, correlation: str = "medium",
          seed: int = 0, verbose: bool = True):
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(seed)
    params = M.init_params(cfg, key)

    # ---- offline component: partition + precision on the cost graph
    graph = transformer_graph(cfg, batch=1, seq=128)
    link = WIFI_5GHZ(bandwidth_mbps)
    off = coach_offline(graph, JETSON_NX, A6000_SERVER, link)
    # map the layer cut to a group boundary (embed node is id 0)
    n_end_layers = sum(1 for i in off.decision.end_set
                       if 0 < i <= cfg.num_layers)
    cut_group = min(max(1, round(n_end_layers / cfg.group_size)),
                    cfg.num_groups - 1)
    rt = CollabRuntime(cfg, params, cut_group)

    # ---- online component: semantic cache keyed on *real* boundary GAP
    # features (the exact features the fused boundary pass emits), so the
    # fused probe's Eq. 8-10 outputs are consistent with the cache state
    stream = CorrelatedTaskStream(n_labels=16, dim=cfg.d_model,
                                  correlation=correlation, seed=seed)

    def task_input(task):
        if cfg.embed_inputs:
            return jnp.asarray(np.tile(task.features[None, None, :],
                                       (1, 8, 1)), jnp.float32)
        toks = (np.abs((task.features[:8] * 1000).astype(np.int64))
                % cfg.vocab_size).astype(np.int32)
        return jnp.asarray(toks)[None]

    calib_tasks = stream.tasks(300)
    calib_inp = jnp.concatenate([task_input(t) for t in calib_tasks], axis=0)
    h_calib = rt._seg_fns[0](rt.p_end, calib_inp)
    # same sum/seq_len GAP expression as kernels.boundary's epilogue
    feats = np.asarray(jnp.sum(h_calib.astype(jnp.float32), axis=1)
                       / h_calib.shape[1])
    labels = np.asarray([t.label for t in calib_tasks])
    rec = TraceRecorder()
    engine = CoachEngine(rt, off.times, JETSON_NX, link, A6000_SERVER,
                         n_labels=16, calib_feats=feats, calib_labels=labels,
                         boundary_elems=128 * cfg.d_model,
                         cfg=EngineConfig(trace=rec))

    def classify(task):
        # fused boundary path: the end segment's forward + quantize +
        # pack + semantic probe read the boundary activation once; the
        # probe outputs (against the cache's current trained centers)
        # feed the scheduler directly instead of a second GAP/cosine pass
        centers, valid = engine.sched.probe_centers()
        pkt, probe = rt.end_step_fused(
            task_input(task), jnp.asarray(centers, jnp.float32))
        logits = rt.cloud_step(pkt)
        pr = ON.ProbeResult.from_fused(
            probe.sims[0], probe.sep[0], probe.best[0], valid,
            n_labels=stream.n_labels)
        return (np.asarray(probe.feat[0]),
                int(np.argmax(logits[0]) % stream.n_labels), pr)

    tasks = stream.tasks(requests)
    t0 = time.time()
    stats = engine.run_stream(tasks, arrival_period=off.times.max_stage,
                              classify=classify)
    wall = time.time() - t0
    if verbose:
        pr = stats.pipeline
        print(f"arch={cfg.name} cut_group={cut_group}/{cfg.num_groups} "
              f"bits(offline)={sorted(set(off.decision.bits.values()))}")
        print(f"requests={requests} exit_ratio={stats.exit_ratio:.2%} "
              f"mean_bits={stats.mean_bits:.1f} "
              f"wire_kb/task={stats.wire_kb_per_task:.1f}")
        print(f"latency mean={pr.mean_latency*1e3:.2f}ms p99="
              f"{pr.p99_latency*1e3:.2f}ms thpt={pr.throughput:.1f} it/s "
              f"cloud_bubbles={pr.bubble_fraction('cloud'):.2%} "
              f"(wall {wall:.1f}s)")
        att = attribute(rec, resources=chain_resources(
            pr.n_hops, pr.pool_sizes or None))
        print("bubble attribution (why each resource idled):")
        print(text_summary(att))
    return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="gemma2-2b")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--bandwidth", type=float, default=50.0)
    ap.add_argument("--correlation", choices=("low", "medium", "high"),
                    default="medium")
    args = ap.parse_args()
    serve(args.arch, requests=args.requests,
          bandwidth_mbps=args.bandwidth, correlation=args.correlation)


if __name__ == "__main__":
    main()
