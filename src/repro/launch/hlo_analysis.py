"""Post-SPMD HLO analysis: collective byte accounting + roofline terms.

``cost_analysis()`` gives HLO FLOPs and bytes but not collective traffic,
so we parse the compiled module text and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
Shapes in post-partitioning HLO are per-device, so the resulting bytes are
per-chip — matching the per-chip link bandwidth in the roofline.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW_PER_LINK = 50e9  # ~50 GB/s/link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 0.5, "u4": 0.5,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    if dtype not in _DTYPE_BYTES:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum operand bytes per collective kind (per device)."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        kind = None
        for c in _COLLECTIVES:
            # match op name, including -start variants; skip -done (would
            # double count) and any fused-computation mentions
            if f" {c}(" in line or f" {c}-start(" in line:
                kind = c
                break
        if kind is None:
            continue
        # operand types appear inline inside the op's parens
        after = line.split(f" {kind}", 1)[1]
        shapes = _SHAPE_RE.findall(after)
        if not shapes:  # fall back to the def (output) shape
            head = line.split("=", 1)[0] + "=" + line.split("=", 1)[1]
            shapes = _SHAPE_RE.findall(line.split("=", 1)[1].split(kind)[0])
        out[kind] += sum(_shape_bytes(d, s) for d, s in shapes)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    ici_links: int = 4  # per-chip usable ICI links in a 2D torus

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (ICI_BW_PER_LINK * self.ici_links)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    def as_dict(self) -> Dict[str, float]:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
        }


def roofline_from_compiled(compiled, hlo_text: Optional[str] = None) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)["total"]
    return Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=coll)


def memory_stats(compiled) -> Dict[str, float]:
    try:
        m = compiled.memory_analysis()
    except Exception:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(m, k, None)
        if v is not None:
            out[k] = float(v)
    if out:
        out["total_nonalias_bytes"] = (
            out.get("argument_size_in_bytes", 0.0)
            + out.get("output_size_in_bytes", 0.0)
            + out.get("temp_size_in_bytes", 0.0)
            - out.get("alias_size_in_bytes", 0.0))
    return out


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N_active·D for training, 2·N_active·D for inference
    (per the roofline 'useful compute' convention)."""
    from repro.core.costs import transformer_graph
    n_active = _active_params(cfg)
    toks = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    return mult * n_active * toks


def _active_params(cfg) -> float:
    """Parameter count touched per token (MoE counts top-k + shared)."""
    d, f = cfg.d_model, cfg.d_ff
    total = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    for i in range(cfg.num_layers):
        spec = cfg.pattern[i % len(cfg.pattern)]
        if spec.mixer == "attn":
            total += d * cfg.head_dim * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
        else:
            di, n, h = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
            total += d * (2 * di + 2 * n + h) + di * d
        if f:
            k = cfg.experts_per_token if spec.moe else 1
            total += 3 * d * f * k
            if spec.moe and cfg.shared_expert:
                total += 3 * d * f
    return float(total)
