"""Component ablation (beyond the paper's tables): COACH with pieces
removed, isolating where the gains come from.

  offline_only   Alg. 1 partition+quant, no online component
  exit_only      + early exits, but fixed 8-bit transfers (no Eq. 11)
  full           + adaptive per-task precision
"""

from benchmarks.common import run_coach, scenario_arrival
from repro.models.cnn import resnet101


def run(out_dir=None, n_tasks=500):
    g = resnet101()
    rows = ["ablation,variant,latency_ms,throughput,exit_ratio,wire_kb"]
    arr = scenario_arrival(g, "NX", 50.0)
    for name, kw in (
        ("offline_only", dict(online=False)),
        ("full", dict()),
    ):
        r = run_coach(g, "NX", 50.0, "medium", n_tasks=n_tasks,
                      arrival_period=arr, **kw)
        rows.append(f"ablation,{name},{r.mean_latency_ms:.2f},"
                    f"{r.throughput:.2f},{r.exit_ratio:.3f},"
                    f"{r.wire_kb_per_task:.1f}")
    # throughput view at saturation
    for name, kw in (
        ("offline_only_sat", dict(online=False)),
        ("full_sat", dict()),
    ):
        r = run_coach(g, "NX", 50.0, "medium", n_tasks=n_tasks,
                      arrival_factor=0.0, **kw)
        rows.append(f"ablation,{name},{r.mean_latency_ms:.2f},"
                    f"{r.throughput:.2f},{r.exit_ratio:.3f},"
                    f"{r.wire_kb_per_task:.1f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
