"""2-hop vs 3-hop pipeline comparison on the generalized N-stage core.

For ResNet101/VGG16: partition end->cloud ("2-hop": Jetson NX + A6000 over
WiFi) and end->edge->cloud ("3-hop": AGX-Orin mid tier; WiFi uplink +
metro-ethernet backhaul) with the same multi-hop divide-and-conquer,
replay a steady task stream through ``run_pipeline``, and report latency /
throughput / per-resource bubble fractions side by side.  Also emits
``BENCH_pipeline.json`` (the perf-trajectory artifact) when an output
directory is given.
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.core.costs import (A6000_SERVER, EDGE_AGX_ORIN, ETH_LAN,
                              JETSON_NX, WIFI_5GHZ)
from repro.core.partitioner import coach_offline_multihop
from repro.core.pipeline import plan_from_stage_times, run_pipeline
from repro.models.cnn import resnet101, vgg16

MBPS_UPLINK = 50.0
N_TASKS = 400
ARRIVAL_SLACK = 1.05

# n_tiers -> (devices, links); links = n_tiers - 1
DEPLOYMENTS = {
    2: ((JETSON_NX, A6000_SERVER), (WIFI_5GHZ(MBPS_UPLINK),)),
    3: ((JETSON_NX, EDGE_AGX_ORIN, A6000_SERVER),
        (WIFI_5GHZ(MBPS_UPLINK), ETH_LAN())),
}


def _resource_names(n_links: int):
    comp = ["end"] + [f"edge{k}" for k in range(1, n_links)] + ["cloud"]
    return comp, [f"link{k}" for k in range(n_links)]


def run_deployment(graph, n_tiers: int, n_tasks: int = N_TASKS,
                   chain_stride: int = 1) -> dict:
    devices, links = DEPLOYMENTS[n_tiers]
    off = coach_offline_multihop(graph, devices, links,
                                 chain_stride=chain_stride)
    st = off.times
    plans = [plan_from_stage_times(st) for _ in range(n_tasks)]
    pr = run_pipeline(plans, arrival_period=st.max_stage * ARRIVAL_SLACK,
                      links=list(links))
    comp_names, link_names = _resource_names(len(links))
    bubbles = {name: pr.bubble_fraction(("compute", k))
               for k, name in enumerate(comp_names)}
    bubbles.update({name: pr.bubble_fraction(("link", k))
                    for k, name in enumerate(link_names)})
    return {
        "model": graph.name,
        "hops": n_tiers,
        "segments": [len(s) for s in off.decision.segments(graph)],
        "single_task_ms": st.latency * 1e3,
        "mean_latency_ms": pr.mean_latency * 1e3,
        "p99_latency_ms": pr.p99_latency * 1e3,
        "throughput_its": pr.throughput,
        "max_stage_ms": st.max_stage * 1e3,
        "objective_ms": off.objective * 1e3,
        "bubble_fraction": bubbles,
    }


def run(out_dir=None, n_tasks: int = N_TASKS):
    rows = ["multihop,model,hops,latency_ms,p99_ms,throughput_its,"
            "max_stage_ms,bubble_cloud,bubble_links"]
    payload = []
    for graph, stride in ((vgg16(), 1), (resnet101(), 4)):
        for n_tiers in (2, 3):
            r = run_deployment(graph, n_tiers, n_tasks=n_tasks,
                               chain_stride=stride)
            payload.append(r)
            bl = ";".join(f"{r['bubble_fraction'][f'link{k}']:.3f}"
                          for k in range(n_tiers - 1))
            rows.append(
                f"multihop,{r['model']},{r['hops']},"
                f"{r['mean_latency_ms']:.2f},{r['p99_latency_ms']:.2f},"
                f"{r['throughput_its']:.1f},{r['max_stage_ms']:.2f},"
                f"{r['bubble_fraction']['cloud']:.3f},{bl}")
    if out_dir is not None:
        path = Path(out_dir) / "BENCH_pipeline.json"
        path.write_text(json.dumps(payload, indent=2) + "\n")
        # perf-trajectory copy at the repo root (stable path across runs)
        root = Path(__file__).resolve().parent.parent / "BENCH_pipeline.json"
        root.write_text(json.dumps(payload, indent=2) + "\n")
    return rows


if __name__ == "__main__":
    print("\n".join(run(out_dir="experiments/bench")))
