"""2-hop vs 3-hop pipeline comparison on the generalized N-stage core.

For ResNet101/VGG16: partition end->cloud ("2-hop": Jetson NX + A6000 over
WiFi) and end->edge->cloud ("3-hop": AGX-Orin mid tier; WiFi uplink +
metro-ethernet backhaul) with the same multi-hop divide-and-conquer, then
run a steady task stream through both realizations of the ``2n+1``
resource chain:

  engine = "sim"    ``core.pipeline.run_pipeline`` (event simulator)
  engine = "async"  ``serving.async_engine.run_pipeline_async`` (per-
                    resource asyncio workers on the virtual clock, with
                    bounded hop queues — the served engine's defaults)

Every (model, deployment, engine) is measured twice, as a paired
``hop_exit`` on/off experiment: "off" streams every task through the
full chain; "on" runs the hop-level semantic-exit cascade (per-tier
probes calibrated on depth-attenuated boundary features of a correlated
task stream — the real Eq. 8-10 machinery, seeded) and terminates exited
tasks at their exit tier, releasing all downstream resources.  The pair
isolates the new measurable axis: bubble-fraction / p99 with and without
hop-level exits.  Also emits ``BENCH_pipeline.json`` (the perf-
trajectory artifact) when an output directory is given;
``benchmarks/validate_bench.py`` checks its schema — including the
on/off pairing — in CI.
"""

from __future__ import annotations

from benchmarks.bench_io import emit_pipeline_rows
from repro.core import online as ON
from repro.core.costs import (A6000_SERVER, EDGE_AGX_ORIN, ETH_LAN,
                              JETSON_NX, WIFI_5GHZ)
from repro.core.partitioner import coach_offline_multihop
from repro.core.pipeline import plan_from_stage_times, run_pipeline
from repro.data.pipeline import (CorrelatedTaskStream,
                                 make_hop_calibration_sets)
from repro.models.cnn import resnet101, vgg16
from repro.serving.async_engine import run_pipeline_async
from repro.serving.base import EngineConfig

MBPS_UPLINK = 50.0
N_TASKS = 400
ARRIVAL_SLACK = 1.05
SEED = 0
# bound the hop queues exactly the way the served engine does by default
ASYNC_QUEUE_CAPACITY = EngineConfig().queue_capacity

# n_tiers -> (devices, links); links = n_tiers - 1
DEPLOYMENTS = {
    2: ((JETSON_NX, A6000_SERVER), (WIFI_5GHZ(MBPS_UPLINK),)),
    3: ((JETSON_NX, EDGE_AGX_ORIN, A6000_SERVER),
        (WIFI_5GHZ(MBPS_UPLINK), ETH_LAN())),
}


def _resource_names(n_links: int):
    comp = ["end"] + [f"edge{k}" for k in range(1, n_links)] + ["cloud"]
    return comp, [f"link{k}" for k in range(n_links)]


def _row(graph, n_tiers, engine, pr, st, objective, hop_exit) -> dict:
    comp_names, link_names = _resource_names(n_tiers - 1)
    bubbles = {name: pr.bubble_fraction(("compute", k))
               for k, name in enumerate(comp_names)}
    bubbles.update({name: pr.bubble_fraction(("link", k))
                    for k, name in enumerate(link_names)})
    return {
        "model": graph.name,
        "hops": n_tiers,
        "engine": engine,
        "hop_exit": hop_exit,
        "exit_ratio": pr.exit_ratio,
        "exit_hops": {str(k): v for k, v in pr.exit_hop_counts().items()},
        "single_task_ms": st.latency * 1e3,
        "mean_latency_ms": pr.mean_latency * 1e3,
        "p99_latency_ms": pr.p99_latency * 1e3,
        "throughput_its": pr.throughput,
        "makespan_ms": pr.makespan * 1e3,
        "max_stage_ms": st.max_stage * 1e3,
        "objective_ms": objective * 1e3,
        "bubble_fraction": bubbles,
    }


def decide_exit_hops(n_hops: int, n_tasks: int, seed: int = SEED) -> list:
    """Per-task exit hops from the real hop-level semantic cascade: a
    seeded correlated task stream with depth-attenuated boundary
    features, one calibrated probe per tier (Eq. 8-10), first exit wins.
    Returns one ``exit_hop`` (or ``None``) per task."""
    # depth_decay 0.9: mild per-tier concentration, so the cascade keeps
    # a non-degenerate three-way mix (end exits / edge exits / cloud)
    stream = CorrelatedTaskStream(n_labels=20, dim=64, correlation="medium",
                                  seed=seed, n_probe_depths=max(n_hops, 1),
                                  depth_decay=0.9)
    sets = make_hop_calibration_sets(stream, 400, n_depths=max(n_hops, 1))
    probes = ON.build_hop_probes(sets, stream.n_labels)
    sched = ON.OnlineScheduler(probes[0].cache, probes[0].thresholds,
                               boundary_elems=1, T_e=1.0, T_c=1.0,
                               hop_probes=probes[1:])
    out = []
    for task in stream.tasks(n_tasks):
        feats = task.hop_features if task.hop_features is not None \
            else task.features[None]
        out.append(sched.step_cascade(feats, bandwidth_bps=1e6).exit_hop)
    return out


def run_deployment(graph, n_tiers: int, n_tasks: int = N_TASKS,
                   chain_stride: int = 1) -> list:
    devices, links = DEPLOYMENTS[n_tiers]
    off = coach_offline_multihop(graph, devices, links,
                                 chain_stride=chain_stride)
    st = off.times
    period = st.max_stage * ARRIVAL_SLACK
    exit_hops = decide_exit_hops(n_tiers - 1, n_tasks)
    rows = []
    for hop_exit in (False, True):
        plans = [plan_from_stage_times(st, exit_hop=eh if hop_exit else None)
                 for eh in exit_hops]
        pr = run_pipeline(plans, arrival_period=period, links=list(links))
        pa = run_pipeline_async(plans, arrival_period=period,
                                links=list(links),
                                queue_capacity=ASYNC_QUEUE_CAPACITY)
        rows += [_row(graph, n_tiers, "sim", pr, st, off.objective, hop_exit),
                 _row(graph, n_tiers, "async", pa, st, off.objective,
                      hop_exit)]
    seg = [len(s) for s in off.decision.segments(graph)]
    for r in rows:
        r["segments"] = seg
    return rows


def run(out_dir=None, n_tasks: int = N_TASKS):
    rows = ["multihop,engine,model,hops,hop_exit,exit_ratio,latency_ms,"
            "p99_ms,throughput_its,max_stage_ms,bubble_cloud,bubble_links"]
    payload = []
    # full-stride sweeps everywhere: the batched planner (core.plan_fast)
    # made chain_stride subsampling unnecessary even for ResNet101 3-hop
    for graph, stride in ((vgg16(), 1), (resnet101(), 1)):
        for n_tiers in (2, 3):
            for r in run_deployment(graph, n_tiers, n_tasks=n_tasks,
                                    chain_stride=stride):
                payload.append(r)
                bl = ";".join(f"{r['bubble_fraction'][f'link{k}']:.3f}"
                              for k in range(n_tiers - 1))
                rows.append(
                    f"multihop,{r['engine']},{r['model']},{r['hops']},"
                    f"{int(r['hop_exit'])},{r['exit_ratio']:.3f},"
                    f"{r['mean_latency_ms']:.2f},{r['p99_latency_ms']:.2f},"
                    f"{r['throughput_its']:.1f},{r['max_stage_ms']:.2f},"
                    f"{r['bubble_fraction']['cloud']:.3f},{bl}")
    if out_dir is not None:
        # one canonical artifact (out_dir); the repo-root copy is a
        # symlink maintained by the shared writer
        emit_pipeline_rows(out_dir, "multihop", payload)
    return rows


if __name__ == "__main__":
    print("\n".join(run(out_dir="experiments/bench")))
