"""Schema validator for ``BENCH_pipeline.json`` (CI smoke gate).

  python benchmarks/validate_bench.py [path/to/BENCH_pipeline.json]

The artifact is a non-empty list of rows of two kinds (merged by
``benchmarks.bench_io``):

``kind = "multihop"`` (default when the tag is absent, for artifacts
predating the tag): the 2-hop vs 3-hop perf trajectory — every
(model, hops) deployment must be reported by BOTH the event simulator
(``engine: "sim"``) and the async hop-queue executor (``engine:
"async"``), with sane bubble fractions, and as a paired ``hop_exit``
on/off experiment: every (model, hops, engine) needs one row with the
hop-level semantic-exit cascade enabled and one with it disabled, with
``exit_ratio`` in range (> 0 on the hop-exit rows, 0 on the off rows)
and an ``exit_hops`` histogram consistent with it.  The hop-exit checks
(field presence + pairing) only apply to rows carrying an explicit
``kind`` tag — untagged legacy rows predate ``hop_exit`` too and keep
the original schema.

``kind = "multitenant"``: per-tenant fairness rows — every
(hops, policy, tenant) must likewise carry BOTH engines (the executor
and the multi-tenant simulator replay of the same decided plans), with
policy in {fifo, rr, wdrr}, >= 2 tenants per (hops, policy, engine)
run, per-tenant SLO accounting in range, and shared-chain bubble
fractions.

``kind = "planner"``: offline-search throughput rows — naive-vs-fast
wall time and candidates/sec for the same full-stride sweep, with
``argmin_match`` required to be ``true`` (the fast scorer must return
the exact decision of the naive per-candidate simulation search) and a
positive throughput ``speedup``.

``kind = "batching"``: continuous micro-batching rows — every
(model, hops, engine) is a paired ``batched`` on/off experiment on the
same overloaded stream, with per-tier ``batch_caps``/``realized_batch``
lists of ``hops`` entries (all ones on the off rows, caps > 1 with
realized batch sizes > 1 somewhere on the on rows).  The perf gate:
each batched row must deliver >= 1.5x its unbatched partner's
throughput at equal-or-better p99 latency.

``kind = "routing"``: replicated-tier scale-out rows — every
(model, hops, policy) is a throughput-vs-m sweep on the same overloaded
stream, reported by BOTH engines (pool simulator and pool executor),
with ``policy`` in {jsq, po2, random}, ``m`` matching the ``pool_sizes``
list, and an ``m = 1`` baseline per sweep.  The perf gate applies to the
informed policies only: for jsq and po2 the ``m = 2`` row must deliver
>= 1.8x the ``m = 1`` throughput at equal-or-better p99 (random is the
no-information baseline and is reported ungated).

``kind = "bubbles"``: per-cause idle-attribution rows
(``benchmarks/bubbles.py``) — every (model, hops, config) cell carries
BOTH engines with matched span traces (``trace_match``), a per-resource
``busy_ms`` / ``bubble_causes_ms`` decomposition over the closed cause
set, and the conservation identity re-checked *from the row payload
alone*: ``busy + sum(causes) == horizon`` per resource.  Async rows
additionally carry ``trace_overhead_pct``, gated < 5% (the cost of
running the executor with a live recorder vs tracing disabled).

``kind = "kernels"``: microbenchmark rows from
``benchmarks/kernels_bench.py`` — each names the shared
``repro.kernels.ops`` entry point it timed, a positive ``us_per_call``,
and the dispatch ``path`` actually taken (``pallas`` on TPU hosts,
``ref`` elsewhere) plus the ``backend``.

``kind = "calibration"``: measured-vs-modeled stage times from
``benchmarks/calibration.py`` — every row carries a positive
``measured_s`` (real wall time), ``modeled_s`` (priced from
host-calibrated bandwidth/matmul primitives) and their ``ratio``
(re-derived here from the payload).  The gate: the ratio must stay
inside a configurable band (``COACH_CALIB_RATIO_MIN`` /
``COACH_CALIB_RATIO_MAX`` env overrides — wall time on shared runners
is noisy, so the default band is wide) on every runner that contributed
measured rows; an artifact with no calibration rows skips the gate
entirely.  The ``fused_boundary_*`` rows additionally carry the derived
HBM-traffic columns, gated: the fused single-pass boundary kernel must
move >= 1.5x fewer bytes than the unfused quantize-then-probe pair.

Rows of the engine-bearing kinds missing an explicit ``engine`` are
rejected outright (planner rows describe the search, not an executor,
and carry no engine; kernels/calibration rows time a host, not an
engine).
"""

from __future__ import annotations

import json
import os
import sys
from pathlib import Path

MULTIHOP_NUMERIC = (
    "single_task_ms", "mean_latency_ms", "p99_latency_ms",
    "throughput_its", "makespan_ms", "max_stage_ms", "objective_ms",
)
MULTITENANT_NUMERIC = (
    "mean_latency_ms", "p99_latency_ms", "throughput_its", "makespan_ms",
    "slo_ms", "norm_p99", "worst_tenant_p99_ms", "worst_tenant_norm_p99",
    "weight",
)
PLANNER_NUMERIC = (
    "candidates_naive", "candidates_fast", "naive_s", "fast_s",
    "cand_per_s_naive", "cand_per_s_fast", "speedup", "objective_ms",
)
BATCHING_NUMERIC = (
    "single_task_ms", "mean_latency_ms", "p99_latency_ms",
    "throughput_its", "makespan_ms", "max_stage_ms", "batch_slack_ms",
)
ROUTING_NUMERIC = (
    "single_task_ms", "mean_latency_ms", "p99_latency_ms",
    "throughput_its", "makespan_ms", "max_stage_ms",
)
#: batched throughput must beat the unbatched partner by this factor...
BATCH_SPEEDUP_MIN = 1.5
#: ...without giving up tail latency (equal-or-better p99)
BATCH_P99_TOL = 1 + 1e-9
#: informed-router (jsq/po2) m=2 throughput vs the m=1 baseline...
ROUTING_SPEEDUP_MIN = 1.8
#: ...again at equal-or-better p99
ROUTING_P99_TOL = 1 + 1e-9
#: enabled-tracing wall overhead gate on async bubbles rows, percent
BUBBLE_OVERHEAD_MAX = 5.0
#: the attribution engine's own conservation residual bound (seconds)
BUBBLE_CONS_TOL_S = 1e-9
#: the closed cause enum of ``repro.obs.bubbles`` (duplicated here so
#: the validator stays dependency-free)
BUBBLE_CAUSES = {
    "warmup", "drain", "upstream_starvation", "downstream_backpressure",
    "batch_formation", "sequencer_reorder", "ingress_credit",
    "exit_released", "replanning",
}
BUBBLE_CONFIGS = {"chain", "exits", "pool"}
#: dispatch paths a kernels microbenchmark row may have taken
KERNEL_PATHS = {"pallas", "ref", "xla", "async"}
#: measured/modeled wall-time band for ``calibration`` rows.  The model
#: is priced from host-calibrated primitives, but shared CI runners are
#: noisy and CPU backends are not bandwidth-shaped like a TPU, so the
#: default band is wide; tighten per runner via the env overrides.
CALIB_RATIO_MIN = float(os.environ.get("COACH_CALIB_RATIO_MIN", "0.02"))
CALIB_RATIO_MAX = float(os.environ.get("COACH_CALIB_RATIO_MAX", "50.0"))
#: the fused boundary pass must move this factor fewer HBM bytes than
#: the unfused quantize-then-probe pair it replaces (one activation
#: read instead of two)
CALIB_HBM_RATIO_MIN = 1.5
ENGINES = {"sim", "async"}
#: resilience storylines and their variants (see benchmarks.resilience)
RESILIENCE_STORYLINES = {"degrade": {"static", "replan"},
                         "churn": {"jsq-avail"}}
#: the scenario runner's differential tolerance on task completions
RESILIENCE_PIN_TOL_S = 1e-6
#: a degrade storyline must re-plan at least once and stay bounded
#: (a runaway detector thrashing the planner is a bug, not resilience)
RESILIENCE_REPLANS_MAX = 10
RESILIENCE_TPUT_TOL = 1 - 1e-9
POLICIES = {"fifo", "rr", "wdrr"}
ROUTER_POLICIES = {"jsq", "po2", "random"}
#: policies the m=2 scale-out gate applies to (random is the
#: no-information baseline the comparison exists for)
GATED_ROUTERS = {"jsq", "po2"}


def _check_common(i: int, row: dict) -> None:
    assert isinstance(row, dict), f"row {i}: not an object"
    assert isinstance(row.get("model"), str) and row["model"], f"row {i}"
    assert isinstance(row.get("hops"), int) and row["hops"] >= 2, \
        f"row {i}: bad hops"
    assert "engine" in row, f"row {i}: missing engine"
    assert row["engine"] in ENGINES, \
        f"row {i}: engine must be one of {sorted(ENGINES)}"
    bf = row.get("bubble_fraction")
    assert isinstance(bf, dict) and {"end", "cloud", "link0"} <= set(bf), \
        f"row {i}: bubble_fraction missing resources"
    assert all(isinstance(v, (int, float)) and -1e-9 <= v <= 1 + 1e-9
               for v in bf.values()), f"row {i}: bubble out of [0, 1]"
    # an n-tier deployment has n compute + (n-1) link resources
    assert len(bf) == 2 * row["hops"] - 1, \
        f"row {i}: expected {2 * row['hops'] - 1} resources"


def _check_numeric(i: int, row: dict, fields) -> None:
    for f in fields:
        v = row.get(f)
        assert isinstance(v, (int, float)) and v >= 0, \
            f"row {i}: bad {f}={v!r}"


def _require_both_engines(seen, label: str) -> None:
    keys = {k[:-1] for k in seen}
    for key in sorted(keys):
        missing = ENGINES - {e for (*k, e) in seen if tuple(k) == key}
        assert not missing, f"{label} {key}: missing engine rows {missing}"


def _check_planner(i: int, row: dict) -> None:
    assert isinstance(row.get("model"), str) and row["model"], f"row {i}"
    assert isinstance(row.get("hops"), int) and row["hops"] >= 2, \
        f"row {i}: bad hops"
    _check_numeric(i, row, PLANNER_NUMERIC)
    assert row["speedup"] > 0, f"row {i}: non-positive planner speedup"
    assert isinstance(row.get("chain_stride"), int) \
        and row["chain_stride"] >= 1, f"row {i}: bad chain_stride"
    # the fast scorer is a pure speedup: a mismatching argmin is a bug
    assert row.get("argmin_match") is True, \
        f"row {i}: planner argmin_match must be true"


def _check_kernels(i: int, row: dict) -> None:
    assert isinstance(row.get("name"), str) and row["name"], \
        f"row {i}: kernels row needs a name"
    us = row.get("us_per_call")
    assert isinstance(us, (int, float)) and us > 0, \
        f"row {i}: bad us_per_call={us!r}"
    assert row.get("path") in KERNEL_PATHS, \
        f"row {i}: path must be one of {sorted(KERNEL_PATHS)}"
    assert isinstance(row.get("backend"), str) and row["backend"], \
        f"row {i}: kernels row needs a backend"


def _check_calibration(i: int, row: dict) -> None:
    assert isinstance(row.get("name"), str) and row["name"], \
        f"row {i}: calibration row needs a name"
    assert isinstance(row.get("backend"), str) and row["backend"], \
        f"row {i}: calibration row needs a backend"
    assert row.get("path") in KERNEL_PATHS, \
        f"row {i}: path must be one of {sorted(KERNEL_PATHS)}"
    for f in ("measured_s", "modeled_s", "ratio"):
        v = row.get(f)
        assert isinstance(v, (int, float)) and v > 0, \
            f"row {i}: bad {f}={v!r}"
    # the ratio is re-derived from the payload, never trusted as stored
    expect = row["measured_s"] / row["modeled_s"]
    assert abs(row["ratio"] - expect) <= 1e-6 * max(expect, 1.0), \
        f"row {i}: ratio {row['ratio']!r} != measured/modeled {expect!r}"
    assert CALIB_RATIO_MIN <= expect <= CALIB_RATIO_MAX, \
        f"row {i}: {row['name']} measured/modeled ratio {expect:.3f} " \
        f"outside [{CALIB_RATIO_MIN}, {CALIB_RATIO_MAX}]"
    if "hbm_bytes_ratio" in row:
        fused = row.get("hbm_bytes_fused")
        unfused = row.get("hbm_bytes_unfused")
        for f, v in (("hbm_bytes_fused", fused),
                     ("hbm_bytes_unfused", unfused)):
            assert isinstance(v, (int, float)) and v > 0, \
                f"row {i}: bad {f}={v!r}"
        hr = row["hbm_bytes_ratio"]
        assert abs(hr - unfused / fused) <= 1e-6 * max(hr, 1.0), \
            f"row {i}: hbm_bytes_ratio inconsistent with byte counts"
        assert hr >= CALIB_HBM_RATIO_MIN, \
            f"row {i}: {row['name']} moves only {hr:.2f}x fewer HBM " \
            f"bytes than unfused (< {CALIB_HBM_RATIO_MIN}x)"


def _check_multihop_exit(i: int, row: dict) -> None:
    assert isinstance(row.get("hop_exit"), bool), \
        f"row {i}: multihop rows need a boolean hop_exit tag"
    ratio = row.get("exit_ratio")
    assert isinstance(ratio, (int, float)) and -1e-9 <= ratio <= 1 + 1e-9, \
        f"row {i}: exit_ratio out of [0, 1]"
    hist = row.get("exit_hops")
    assert isinstance(hist, dict) and all(
        isinstance(v, int) and v >= 0 for v in hist.values()), \
        f"row {i}: bad exit_hops histogram"
    if row["hop_exit"]:
        assert ratio > 0 and sum(hist.values()) > 0, \
            f"row {i}: hop_exit row without exits"
    else:
        assert ratio == 0 and not hist, \
            f"row {i}: hop_exit-off row reports exits"


def _check_batching(i: int, row: dict) -> None:
    assert isinstance(row.get("batched"), bool), \
        f"row {i}: batching rows need a boolean batched tag"
    _check_numeric(i, row, BATCHING_NUMERIC)
    caps = row.get("batch_caps")
    realized = row.get("realized_batch")
    n_seg = row["hops"]
    for name, vals in (("batch_caps", caps), ("realized_batch", realized)):
        assert isinstance(vals, list) and len(vals) == n_seg and all(
            isinstance(v, (int, float)) and v >= 1 - 1e-9 for v in vals), \
            f"row {i}: {name} must list {n_seg} per-tier values >= 1"
    assert isinstance(row.get("batch_cap"), int) \
        and row["batch_cap"] == max(caps), f"row {i}: bad batch_cap"
    if row["batched"]:
        assert max(caps) > 1, f"row {i}: batched row with all-ones caps"
        assert max(realized) > 1, \
            f"row {i}: batched row never formed a batch"
    else:
        assert all(c == 1 for c in caps), \
            f"row {i}: unbatched row with caps > 1"
        assert all(abs(b - 1) <= 1e-9 for b in realized), \
            f"row {i}: unbatched row reports realized batches"


def _check_routing(i: int, row: dict) -> None:
    assert row.get("policy") in ROUTER_POLICIES, \
        f"row {i}: routing policy must be one of {sorted(ROUTER_POLICIES)}"
    _check_numeric(i, row, ROUTING_NUMERIC)
    m = row.get("m")
    assert isinstance(m, int) and m >= 1, f"row {i}: bad replica count m"
    sizes = row.get("pool_sizes")
    assert isinstance(sizes, list) and len(sizes) == row["hops"] and all(
        isinstance(v, int) and v >= 1 for v in sizes), \
        f"row {i}: pool_sizes must list {row['hops']} replica counts >= 1"
    assert max(sizes) == m, f"row {i}: m must match pool_sizes"


def _check_bubbles(i: int, row: dict) -> None:
    assert isinstance(row.get("model"), str) and row["model"], f"row {i}"
    assert isinstance(row.get("hops"), int) and row["hops"] >= 2, \
        f"row {i}: bad hops"
    assert row.get("engine") in ENGINES, \
        f"row {i}: engine must be one of {sorted(ENGINES)}"
    assert row.get("config") in BUBBLE_CONFIGS, \
        f"row {i}: config must be one of {sorted(BUBBLE_CONFIGS)}"
    sizes = row.get("pool_sizes")
    assert isinstance(sizes, list) and len(sizes) == row["hops"] and all(
        isinstance(v, int) and v >= 1 for v in sizes), \
        f"row {i}: pool_sizes must list {row['hops']} replica counts >= 1"
    _check_numeric(i, row, ("makespan_ms", "horizon_ms"))
    busy = row.get("busy_ms")
    n_resources = sum(sizes) + row["hops"] - 1
    assert isinstance(busy, dict) and len(busy) == n_resources and all(
        isinstance(v, (int, float)) and v >= 0 for v in busy.values()), \
        f"row {i}: busy_ms must cover all {n_resources} resources"
    causes = row.get("bubble_causes_ms")
    assert isinstance(causes, dict) and set(causes) <= set(busy), \
        f"row {i}: bubble_causes_ms labels must be busy_ms labels"
    for label, cs in causes.items():
        assert isinstance(cs, dict) and set(cs) <= BUBBLE_CAUSES, \
            f"row {i}: unknown bubble cause in {label}: " \
            f"{sorted(set(cs) - BUBBLE_CAUSES)}"
        assert all(isinstance(v, (int, float)) and v > 0
                   for v in cs.values()), \
            f"row {i}: non-positive cause seconds in {label}"
    # conservation, re-derived from the payload alone: busy + attributed
    # bubbles must tile the horizon on every resource
    h = row["horizon_ms"]
    for label in busy:
        total = busy[label] + sum(causes.get(label, {}).values())
        assert abs(total - h) <= 1e-5 + 1e-9 * abs(h), \
            f"row {i}: conservation broken on {label}: " \
            f"busy+bubbles={total!r} horizon={h!r}"
    err = row.get("conservation_max_err_s")
    assert isinstance(err, (int, float)) and 0 <= err <= BUBBLE_CONS_TOL_S, \
        f"row {i}: conservation_max_err_s {err!r} > {BUBBLE_CONS_TOL_S}"
    assert isinstance(row.get("n_spans"), int) and row["n_spans"] > 0, \
        f"row {i}: bad n_spans"
    assert row.get("trace_match") is True, \
        f"row {i}: trace_match must be true (sim/async span pin)"
    if row["engine"] == "async":
        ov = row.get("trace_overhead_pct")
        assert isinstance(ov, (int, float)) and \
            0 <= ov <= BUBBLE_OVERHEAD_MAX, \
            f"row {i}: trace_overhead_pct {ov!r} outside " \
            f"[0, {BUBBLE_OVERHEAD_MAX}]"


def _check_resilience(i: int, row: dict) -> None:
    assert isinstance(row.get("model"), str) and row["model"], f"row {i}"
    assert isinstance(row.get("hops"), int) and row["hops"] >= 2, \
        f"row {i}: bad hops"
    assert row.get("engine") in ENGINES, \
        f"row {i}: engine must be one of {sorted(ENGINES)}"
    story = row.get("storyline")
    assert story in RESILIENCE_STORYLINES, \
        f"row {i}: storyline must be one of {sorted(RESILIENCE_STORYLINES)}"
    assert row.get("variant") in RESILIENCE_STORYLINES[story], \
        f"row {i}: variant {row.get('variant')!r} invalid for {story}"
    _check_numeric(i, row, ("n_tasks", "p50_ms", "p99_ms",
                            "throughput_its", "makespan_ms"))
    w = row.get("window")
    assert isinstance(w, list) and len(w) == 2 and 0 <= w[0] < w[1], \
        f"row {i}: bad window {w!r}"
    for f in ("n_replans", "n_migrations"):
        assert isinstance(row.get(f), int) and row[f] >= 0, \
            f"row {i}: bad {f}"
    # the pin evidence: traces matched and completions agreed to 1e-6
    assert row.get("trace_match") is True, \
        f"row {i}: trace_match must be true (sim/async span pin)"
    d = row.get("max_done_delta_s")
    assert isinstance(d, (int, float)) and \
        0 <= d <= RESILIENCE_PIN_TOL_S, \
        f"row {i}: max_done_delta_s {d!r} > {RESILIENCE_PIN_TOL_S}"
    err = row.get("conservation_max_err_s")
    assert isinstance(err, (int, float)) and \
        0 <= err <= BUBBLE_CONS_TOL_S, \
        f"row {i}: conservation_max_err_s {err!r} > {BUBBLE_CONS_TOL_S}"
    causes = row.get("bubble_causes_ms")
    assert isinstance(causes, dict), f"row {i}: missing bubble_causes_ms"
    for label, cs in causes.items():
        assert isinstance(cs, dict) and set(cs) <= BUBBLE_CAUSES, \
            f"row {i}: unknown bubble cause in {label}: " \
            f"{sorted(set(cs) - BUBBLE_CAUSES)}"
    if row["variant"] == "replan":
        assert 1 <= row["n_replans"] <= RESILIENCE_REPLANS_MAX, \
            f"row {i}: replan variant with n_replans={row['n_replans']}"
        assert row["n_migrations"] >= 1, \
            f"row {i}: replan variant migrated no in-flight task"
        p99w = row.get("p99_window_ms")
        assert isinstance(p99w, (int, float)) and p99w > 0, \
            f"row {i}: bad p99_window_ms"
    else:
        assert row["n_replans"] == 0 and row["n_migrations"] == 0, \
            f"row {i}: static/churn variant must not re-plan"


def _check_resilience_pairs(rows: dict) -> None:
    """The resilience gate: per (model, hops, engine) degrade pair,
    online re-planning must deliver strictly better p99 through the
    degraded window at equal-or-better throughput than the static
    plan riding the identical traced links."""
    for key, variants in sorted(rows.items()):
        assert set(variants) == {"static", "replan"}, \
            f"resilience {key}: needs paired static/replan rows " \
            f"(got {sorted(variants)})"
        st, rp = variants["static"], variants["replan"]
        assert rp["p99_window_ms"] < st["p99_window_ms"], \
            f"resilience {key}: replan p99 {rp['p99_window_ms']:.2f}ms " \
            f"not better than static {st['p99_window_ms']:.2f}ms"
        assert rp["throughput_its"] >= \
            st["throughput_its"] * RESILIENCE_TPUT_TOL, \
            f"resilience {key}: replan throughput " \
            f"{rp['throughput_its']:.2f}/s below static " \
            f"{st['throughput_its']:.2f}/s"


def _check_routing_sweeps(rows: dict) -> None:
    """The scale-out gate: for the informed policies, m = 2 must deliver
    >= 1.8x the m = 1 throughput at equal-or-better p99, per
    (model, hops, policy, engine) sweep.  Every sweep needs its m = 1
    baseline; the random baseline is reported but not perf-gated."""
    for key, by_m in sorted(rows.items()):
        (_model, _hops, policy, _engine) = key
        assert 1 in by_m, f"routing {key}: missing m=1 baseline row"
        if policy not in GATED_ROUTERS or 2 not in by_m:
            continue
        base, scaled = by_m[1], by_m[2]
        speedup = scaled["throughput_its"] / \
            max(base["throughput_its"], 1e-12)
        assert speedup >= ROUTING_SPEEDUP_MIN, \
            f"routing {key}: m=2 throughput speedup {speedup:.2f}x " \
            f"< {ROUTING_SPEEDUP_MIN}x"
        assert scaled["p99_latency_ms"] <= \
            base["p99_latency_ms"] * ROUTING_P99_TOL, \
            f"routing {key}: m=2 p99 {scaled['p99_latency_ms']:.2f}ms " \
            f"worse than m=1 {base['p99_latency_ms']:.2f}ms"


def _check_batching_pairs(rows: dict) -> None:
    """The perf gate: >= 1.5x throughput at equal-or-better p99, for
    every (model, hops, engine) batched/unbatched pair."""
    for key, variants in sorted(rows.items()):
        assert set(variants) == {False, True}, \
            f"batching {key}: needs paired batched on/off rows " \
            f"(got {sorted(variants)})"
        off, on = variants[False], variants[True]
        speedup = on["throughput_its"] / max(off["throughput_its"], 1e-12)
        assert speedup >= BATCH_SPEEDUP_MIN, \
            f"batching {key}: throughput speedup {speedup:.2f}x " \
            f"< {BATCH_SPEEDUP_MIN}x"
        assert on["p99_latency_ms"] <= \
            off["p99_latency_ms"] * BATCH_P99_TOL, \
            f"batching {key}: batched p99 {on['p99_latency_ms']:.2f}ms " \
            f"worse than unbatched {off['p99_latency_ms']:.2f}ms"


def validate(path: Path) -> list:
    data = json.loads(path.read_text())
    assert isinstance(data, list) and data, "payload must be a non-empty list"
    mh_seen, mt_seen, bt_seen, rt_seen = set(), set(), set(), set()
    bb_seen, rs_seen = set(), set()
    mh_exit = {}
    mt_runs = {}
    bt_pairs = {}
    rt_sweeps = {}
    rs_pairs = {}
    for i, row in enumerate(data):
        assert isinstance(row, dict), f"row {i}: not an object"
        kind = row.get("kind", "multihop")
        # fail on unknown kinds: a producer emitting rows the validator
        # does not understand must extend the validator, not slip past it
        assert kind in ("multihop", "multitenant", "planner", "batching",
                        "routing", "bubbles", "kernels", "calibration",
                        "resilience"), \
            f"row {i}: unknown row kind {kind!r} in merged artifact"
        if kind == "planner":
            _check_planner(i, row)
            continue
        if kind == "kernels":
            _check_kernels(i, row)
            continue
        if kind == "calibration":
            _check_calibration(i, row)
            continue
        if kind == "bubbles":
            _check_bubbles(i, row)
            bb_seen.add((row["model"], row["hops"], row["config"],
                         row["engine"]))
            continue
        if kind == "resilience":
            _check_resilience(i, row)
            key = (row["model"], row["hops"], row["storyline"],
                   row["variant"], row["engine"])
            assert key not in rs_seen, \
                f"row {i}: duplicate resilience row for {key}"
            rs_seen.add(key)
            if row["storyline"] == "degrade":
                pkey = (row["model"], row["hops"], row["engine"])
                rs_pairs.setdefault(pkey, {})[row["variant"]] = row
            continue
        _check_common(i, row)
        if kind == "routing":
            _check_routing(i, row)
            key = (row["model"], row["hops"], row["policy"], row["engine"])
            assert row["m"] not in rt_sweeps.setdefault(key, {}), \
                f"row {i}: duplicate routing row for {key} m={row['m']}"
            rt_sweeps[key][row["m"]] = row
            rt_seen.add((row["model"], row["hops"], row["policy"],
                         row["m"], row["engine"]))
            continue
        if kind == "batching":
            _check_batching(i, row)
            key = (row["model"], row["hops"], row["engine"])
            assert row["batched"] not in bt_pairs.setdefault(key, {}), \
                f"row {i}: duplicate batching row for {key}"
            bt_pairs[key][row["batched"]] = row
            bt_seen.add(key)
            continue
        if kind == "multihop":
            _check_numeric(i, row, MULTIHOP_NUMERIC)
            # untagged rows predate the hop_exit pairing (see docstring)
            if "kind" in row:
                _check_multihop_exit(i, row)
                mh_exit.setdefault(
                    (row["model"], row["hops"], row["engine"]), set()).add(
                    row["hop_exit"])
            mh_seen.add((row["model"], row["hops"], row["engine"]))
            continue
        _check_numeric(i, row, MULTITENANT_NUMERIC)
        assert row.get("policy") in POLICIES, \
            f"row {i}: policy must be one of {sorted(POLICIES)}"
        assert isinstance(row.get("tenant"), str) and row["tenant"], \
            f"row {i}: missing tenant"
        att = row.get("slo_attainment")
        assert isinstance(att, (int, float)) and -1e-9 <= att <= 1 + 1e-9, \
            f"row {i}: slo_attainment out of [0, 1]"
        assert row["weight"] > 0, f"row {i}: non-positive weight"
        mt_seen.add((row["hops"], row["policy"], row["tenant"],
                     row["engine"]))
        mt_runs.setdefault(
            (row["hops"], row["policy"], row["engine"]), set()).add(
            row["tenant"])
    if mh_seen:
        _require_both_engines(mh_seen, "multihop")
        for key, variants in sorted(mh_exit.items()):
            assert variants == {False, True}, \
                f"multihop {key}: needs paired hop_exit on/off rows " \
                f"(got {sorted(variants)})"
    if mt_seen:
        _require_both_engines(mt_seen, "multitenant")
        for key, tenants in sorted(mt_runs.items()):
            assert len(tenants) >= 2, \
                f"multitenant {key}: fewer than 2 tenants ({tenants})"
    if bt_seen:
        _require_both_engines(bt_seen, "batching")
        _check_batching_pairs(bt_pairs)
    if rt_seen:
        _require_both_engines(rt_seen, "routing")
        _check_routing_sweeps(rt_sweeps)
    if bb_seen:
        _require_both_engines(bb_seen, "bubbles")
    if rs_seen:
        _require_both_engines(rs_seen, "resilience")
        _check_resilience_pairs(rs_pairs)
    return data


def main() -> None:
    path = Path(sys.argv[1]) if len(sys.argv) > 1 \
        else Path("experiments/bench/BENCH_pipeline.json")
    rows = validate(path)
    kinds = {}
    for r in rows:
        kinds[r.get("kind", "multihop")] = \
            kinds.get(r.get("kind", "multihop"), 0) + 1
    detail = ", ".join(f"{k}: {n}" for k, n in sorted(kinds.items()))
    print(f"{path}: OK ({len(rows)} rows; {detail})")


if __name__ == "__main__":
    main()
