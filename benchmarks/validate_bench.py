"""Schema validator for ``BENCH_pipeline.json`` (CI smoke gate).

  python benchmarks/validate_bench.py [path/to/BENCH_pipeline.json]

Checks that the perf-trajectory artifact is a non-empty list of rows,
each carrying the required typed fields, with every (model, hops)
deployment reported by BOTH the event simulator ("sim") and the async
hop-queue executor ("async"), and that bubble fractions are sane.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

REQUIRED_NUMERIC = (
    "single_task_ms", "mean_latency_ms", "p99_latency_ms",
    "throughput_its", "makespan_ms", "max_stage_ms", "objective_ms",
)
ENGINES = {"sim", "async"}


def validate(path: Path) -> list:
    data = json.loads(path.read_text())
    assert isinstance(data, list) and data, "payload must be a non-empty list"
    seen = set()
    for i, row in enumerate(data):
        assert isinstance(row, dict), f"row {i}: not an object"
        assert isinstance(row.get("model"), str) and row["model"], f"row {i}"
        assert isinstance(row.get("hops"), int) and row["hops"] >= 2, \
            f"row {i}: bad hops"
        assert row.get("engine") in ENGINES, \
            f"row {i}: engine must be one of {sorted(ENGINES)}"
        for f in REQUIRED_NUMERIC:
            v = row.get(f)
            assert isinstance(v, (int, float)) and v >= 0, \
                f"row {i}: bad {f}={v!r}"
        bf = row.get("bubble_fraction")
        assert isinstance(bf, dict) and {"end", "cloud", "link0"} <= set(bf), \
            f"row {i}: bubble_fraction missing resources"
        assert all(isinstance(v, (int, float)) and -1e-9 <= v <= 1 + 1e-9
                   for v in bf.values()), f"row {i}: bubble out of [0, 1]"
        # an n-tier deployment has n compute + (n-1) link resources
        assert len(bf) == 2 * row["hops"] - 1, \
            f"row {i}: expected {2 * row['hops'] - 1} resources"
        seen.add((row["model"], row["hops"], row["engine"]))
    deployments = {(m, h) for (m, h, _e) in seen}
    for m, h in sorted(deployments):
        missing = ENGINES - {e for (mm, hh, e) in seen if (mm, hh) == (m, h)}
        assert not missing, f"{m}@{h}-hop: missing engine rows {missing}"
    return data


def main() -> None:
    path = Path(sys.argv[1]) if len(sys.argv) > 1 \
        else Path("experiments/bench/BENCH_pipeline.json")
    rows = validate(path)
    print(f"{path}: OK ({len(rows)} rows, "
          f"{len({(r['model'], r['hops']) for r in rows})} deployments x "
          f"{len({r['engine'] for r in rows})} engines)")


if __name__ == "__main__":
    main()
