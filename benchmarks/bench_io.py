"""Canonical writer for the ``BENCH_pipeline.json`` perf-trajectory
artifact.

Exactly one file is ever written: ``<out_dir>/BENCH_pipeline.json``
(canonical, normally ``experiments/bench/``).  The repo-root
``BENCH_pipeline.json`` is maintained as a symlink to the canonical file
(derived, never written independently), so the two can no longer drift.

Rows are tagged with a ``kind`` (``"multihop"``, ``"multitenant"``,
``"planner"``) and merged by kind: a producer replaces its own rows and
preserves every other producer's, so ``benchmarks/run.py --only
multihop``, ``--only multitenant`` and ``--only planner`` compose into
one artifact.
``benchmarks/validate_bench.py`` gates the merged schema in CI.
"""

from __future__ import annotations

import json
import os
from pathlib import Path
from typing import List

ARTIFACT = "BENCH_pipeline.json"
REPO_ROOT = Path(__file__).resolve().parent.parent


def emit_pipeline_rows(out_dir, kind: str, rows: List[dict]) -> Path:
    """Merge ``rows`` into the canonical artifact under ``out_dir``,
    replacing existing rows of the same ``kind`` and keeping the rest;
    refresh the repo-root symlink when the canonical file lives inside
    the repo.  Returns the canonical path."""
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    # write through a symlink's target (e.g. --out pointed at the repo
    # root, which is itself a symlink to the canonical file) so existing
    # other-kind rows are read back rather than clobbered
    path = (out / ARTIFACT).resolve() if (out / ARTIFACT).is_symlink() \
        else out / ARTIFACT
    existing: List[dict] = []
    if path.is_file():
        try:
            existing = [r for r in json.loads(path.read_text())
                        if isinstance(r, dict)
                        and r.get("kind", "multihop") != kind]
        except (ValueError, OSError) as e:
            # do not fail the producer, but never *silently* drop the
            # other producers' merged rows
            print(f"[bench_io] WARNING: could not read existing {path} "
                  f"({e}); rewriting artifact with only kind={kind!r} rows")
    for r in rows:
        r["kind"] = kind
    payload = existing + list(rows)
    path.write_text(json.dumps(payload, indent=2) + "\n")

    root = REPO_ROOT / ARTIFACT
    canonical = path.resolve()
    if canonical == root.resolve() and not root.is_symlink():
        return path
    try:
        canonical.relative_to(REPO_ROOT)
    except ValueError:
        return path  # out_dir outside the repo: leave the root pointer alone
    try:
        if root.is_symlink() or root.exists():
            root.unlink()
        os.symlink(os.path.relpath(canonical, root.parent), root)
    except OSError:
        # filesystem without symlinks: fall back to a derived copy
        root.write_text(json.dumps(payload, indent=2) + "\n")
    return path
