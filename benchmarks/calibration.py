"""Wall-clock calibration bench: measured vs modeled stage times.

Every other bench in this harness prices stages with the *analytic* cost
model (FLOPs / bytes through device and link profiles) and replays them
on virtual clocks.  This module closes the loop: it times the real fused
boundary pass, the unfused quantize+probe pair it replaces, a real model
segment forward, and a real ``WallClock`` pipeline run, and compares
each measurement against a prediction priced from *host-calibrated*
primitives (a memory-bandwidth probe and a matmul-rate probe run on this
machine, so the modeled times are in this host's units rather than the
paper devices').

Rows are emitted as ``kind = "calibration"`` into ``BENCH_pipeline.json``
via ``bench_io`` and gated by ``benchmarks/validate_bench.py``: every row
carries ``measured_s`` / ``modeled_s`` / ``ratio``, the ratio must stay
inside a configurable band (``COACH_CALIB_RATIO_MIN`` /
``COACH_CALIB_RATIO_MAX`` — wall time on shared CI runners is noisy, so
the default band is wide and per-runner overridable), and the fused
boundary rows carry the derived HBM-traffic column: the fused single-pass
kernel must move >= 1.5x fewer boundary bytes than the unfused
quantize-then-probe pair (which reads the (B, S, D) activation twice).

Set ``COACH_CALIBRATION_SKIP=1`` to emit no rows at all (the validator
skips the calibration gate when a runner contributed no measured rows).
"""

import os
import time

import jax
import jax.numpy as jnp

from benchmarks.bench_io import emit_pipeline_rows
from repro.configs import get_config
from repro.core.collab import CollabRuntime
from repro.core.pipeline import TaskPlan
from repro.kernels import ops, ref
from repro.models import model as M
from repro.serving.async_engine import (VirtualClock, WallClock,
                                        run_pipeline_async)

HEADER = "calibration,name,measured_s,modeled_s,ratio,hbm_bytes_ratio"

# fused-boundary bench shape: (B, S, D) activation probed against L centers
B, S, D, L = 8, 512, 256, 64


def _time(fn, *args, iters: int = 10) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _host_rates():
    """Calibrate this host's streaming bandwidth (bytes/s, via a jitted
    elementwise copy) and dense matmul rate (flops/s)."""
    a = jax.random.normal(jax.random.PRNGKey(0), (32 * 1024 * 1024,))
    t = _time(jax.jit(lambda x: x * 1.0), a)
    bw = 2 * a.size * 4 / t
    m = jax.random.normal(jax.random.PRNGKey(1), (1024, 1024))
    t = _time(jax.jit(lambda x: x @ x), m)
    rate = 2 * 1024 ** 3 / t
    return bw, rate


def _boundary_bytes(bits: int):
    """Analytic HBM traffic of one boundary hop.  The unfused pair reads
    the (B, S, D) activation twice (quantize pass + probe pass); the
    fused kernel reads it once.  Both write the same wire payload and
    probe outputs."""
    p = (D + 1) // 2 if bits == 4 else D
    act = B * S * D * 4
    centers = L * D * 4
    wire = B * S * p + 2 * B * S * 4            # packed + scale/zp
    probe_out = B * D * 4 + B * L * 4 + 2 * B * 4  # feat + sims + sep/best
    fused = act + centers + wire + probe_out
    unfused = 2 * act + centers + wire + probe_out
    return fused, unfused


def _boundary_flops():
    quant = 6 * B * S * D                # min/max/scale/div/round/clip
    probe = 2 * B * S * D + 2 * B * D * L  # GAP + normalize + cosine dot
    return quant + probe


def _row(name, measured, modeled, **extra):
    d = {"name": name, "backend": jax.default_backend(),
         "measured_s": measured, "modeled_s": modeled,
         "ratio": measured / max(modeled, 1e-300)}
    d.update(extra)
    return d


def _boundary_rows(bw, rate):
    on_tpu = jax.default_backend() == "tpu"
    path = "pallas" if on_tpu else "ref"
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (B, S, D))
    centers = jax.random.normal(jax.random.PRNGKey(3), (L, D))
    probe = ops.probe_cache if on_tpu else jax.jit(ref.semantic_probe_ref)
    flops = _boundary_flops()
    rows = []
    for bits in (4, 8):
        fused_b, unfused_b = _boundary_bytes(bits)
        meas = _time(lambda t, c, b=bits: ops.boundary_pass(t, c, b),
                     x, centers)
        rows.append(_row(
            f"fused_boundary_b{bits}", meas, fused_b / bw + flops / rate,
            path=path, bits=bits, shape=f"{B}x{S}x{D}xL{L}",
            hbm_bytes_fused=fused_b, hbm_bytes_unfused=unfused_b,
            hbm_bytes_ratio=unfused_b / fused_b))
        meas = (_time(lambda t, b=bits:
                      ops.quantize_activation(t, b, use_kernel=on_tpu), x)
                + _time(probe, x, centers))
        rows.append(_row(
            f"unfused_boundary_b{bits}", meas,
            unfused_b / bw + flops / rate,
            path=path, bits=bits, shape=f"{B}x{S}x{D}xL{L}"))
    return rows


def _segment_row(bw, rate):
    cfg = get_config("gemma2-2b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rt = CollabRuntime(cfg, params, cut_group=1)
    seq = 8
    if cfg.embed_inputs:
        inp = jax.random.normal(jax.random.PRNGKey(4), (B, seq, cfg.d_model))
    else:
        inp = jax.random.randint(jax.random.PRNGKey(4), (B, seq),
                                 0, cfg.vocab_size, jnp.int32)
    meas = _time(lambda t: rt._seg_fns[0](rt.p_end, t), inp)
    n_params = sum(int(p.size) for p in jax.tree_util.tree_leaves(rt.p_end))
    modeled = 2 * n_params * B * seq / rate + n_params * 4 / bw
    return _row("segment_forward_end", meas, modeled,
                path="xla", shape=f"{B}x{seq}x{cfg.d_model}",
                model=cfg.name)


def _pipeline_row():
    """Real-time executor vs its own virtual-clock event model: the same
    plans on ``WallClock`` (actual ``asyncio.sleep``) and ``VirtualClock``
    (discrete events).  The ratio is the executor's wall fidelity."""
    plans = [TaskPlan.multihop((0.004, 0.004), (0.002,))
             for _ in range(12)]
    modeled = run_pipeline_async(plans, arrival_period=0.004,
                                 clock=VirtualClock()).makespan
    meas = run_pipeline_async(plans, arrival_period=0.004,
                              clock=WallClock()).makespan
    return _row("pipeline_wall", meas, modeled, path="async",
                shape="12tasks_2hops")


def run(out_dir=None):
    rows_csv = [HEADER]
    if os.environ.get("COACH_CALIBRATION_SKIP"):
        rows_csv.append("# skipped (COACH_CALIBRATION_SKIP set)")
        return rows_csv
    bw, rate = _host_rates()
    rows = _boundary_rows(bw, rate)
    rows.append(_segment_row(bw, rate))
    rows.append(_pipeline_row())
    for r in rows:
        hr = r.get("hbm_bytes_ratio")
        rows_csv.append(
            f"calibration,{r['name']},{r['measured_s']:.6f},"
            f"{r['modeled_s']:.6f},{r['ratio']:.3f},"
            + (f"{hr:.3f}" if hr is not None else ""))
    if out_dir is not None:
        emit_pipeline_rows(out_dir, "calibration", rows)
    return rows_csv


if __name__ == "__main__":
    print("\n".join(run()))
