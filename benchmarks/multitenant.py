"""Multi-tenant fairness-vs-bubbles benchmark on the shared hop chain.

Three tenants with heterogeneous workloads and SLOs share one VGG16
collaborative deployment (2-tier end->cloud and 3-tier end->edge->cloud;
the VGG16 partition is *ingress-bound* — the end device is the binding
stage — which is exactly where admission policy matters):

  interactive   sparse periodic arrivals, tight SLO, weight 4
  batch         periodic bursts of back-to-back tasks, loose SLO, weight 1
  steady        medium periodic arrivals, medium SLO, weight 2

Each (deployment, admission policy) pair runs through the
``MultiTenantCoachEngine`` executor (``engine = "async"``) and through
``core.sim.simulate_multitenant_stream`` replaying the identical decided
plans (``engine = "sim"``) — the same paired-row differential protocol
the multihop bench uses.  Per-tenant rows report latency (mean/p99),
throughput, SLO attainment, SLO-normalized p99, and the shared chain's
per-resource bubble fractions.

Reading the fairness tradeoff: raw worst-tenant p99 is FIFO-favored by
work conservation (the batch tenant's self-queued burst floors it, and
FIFO is minimax for waiting time), while the *SLO-normalized* worst
tenant — the headline metric, ``worst_tenant_norm_p99`` — flips hard
toward weighted-DRR: FIFO lets a batch burst push the interactive tenant
far outside its SLO; WDRR keeps every tenant inside (or near) its own.
Bubble fractions quantify what fairness costs the pipeline: admission
interleaving barely moves them (the chain stays work-conserving), which
is itself a finding — near bubble-free pipelining and tenant isolation
are not in conflict at these loads.
"""

from __future__ import annotations

import numpy as np

from benchmarks.bench_io import emit_pipeline_rows
# share the deployment table and resource naming with the multihop bench
# so the two row kinds in the merged artifact can never disagree
from benchmarks.multihop import DEPLOYMENTS, _resource_names
from repro.core import sim
from repro.core.partitioner import coach_offline_multihop
from repro.core.pipeline import result_from_stream
from repro.data.pipeline import CorrelatedTaskStream, make_calibration_set
from repro.models.cnn import vgg16
from repro.serving.tenancy import (MultiTenantCoachEngine, TenantSpec,
                                   make_policy, tenant_pipeline_result)

POLICIES = ("fifo", "rr", "wdrr")
N_LABELS = 30
FEAT_DIM = 48


def _tenants(st, scale: float):
    """Arrival processes scaled off the deployment's ingress stage.

    Steady-state ingress load ~0.85 (interactive 0.25 + steady 0.33 +
    batch 0.25 amortized), so the chain is stable between batch bursts;
    each burst transiently overloads the ingress, which is exactly when
    FIFO sacrifices the tight-SLO tenants and WDRR does not."""
    ingress = st.compute[0]
    single = st.latency
    n_i = max(8, int(40 * scale))
    n_s = max(8, int(30 * scale))
    chunks, chunk = max(2, int(4 * scale)), max(6, int(20 * scale))
    burst = tuple(np.repeat(np.arange(chunks) * (chunk * ingress * 4.0),
                            chunk))
    return [
        TenantSpec("interactive", n_i, arrival_period=4.0 * ingress,
                   weight=4.0, slo_latency=3.0 * single),
        TenantSpec("batch", len(burst), arrivals=burst, weight=1.0,
                   slo_latency=60.0 * single),
        TenantSpec("steady", n_s, arrival_period=3.0 * ingress, weight=2.0,
                   slo_latency=8.0 * single),
    ]


def _bubbles(pr, n_tiers):
    comp_names, link_names = _resource_names(n_tiers - 1)
    b = {name: pr.bubble_fraction(("compute", k))
         for k, name in enumerate(comp_names)}
    b.update({name: pr.bubble_fraction(("link", k))
              for k, name in enumerate(link_names)})
    return b


def _tenant_rows(model, n_tiers, policy, engine, reports_pr, tenants,
                 merged_pr, extra):
    """One row per tenant; shared-chain bubbles and run-level fairness
    aggregates are repeated on each row so rows are self-contained."""
    bub = _bubbles(merged_pr, n_tiers)
    norm = [pr.p99_latency / spec.slo_latency
            for pr, spec in zip(reports_pr, tenants)]
    worst_raw = max(pr.p99_latency for pr in reports_pr)
    rows = []
    for spec, pr, nrm in zip(tenants, reports_pr, norm):
        att = float(np.mean([r.latency <= spec.slo_latency
                             for r in pr.tasks]))
        rows.append({
            "model": model, "hops": n_tiers, "engine": engine,
            "policy": policy, "tenant": spec.name, "weight": spec.weight,
            "n_tasks": spec.n_tasks,
            "mean_latency_ms": pr.mean_latency * 1e3,
            "p99_latency_ms": pr.p99_latency * 1e3,
            "throughput_its": pr.throughput,
            "makespan_ms": merged_pr.makespan * 1e3,
            "slo_ms": spec.slo_latency * 1e3,
            "slo_attainment": att,
            "norm_p99": nrm,
            "worst_tenant_p99_ms": worst_raw * 1e3,
            "worst_tenant_norm_p99": max(norm),
            "bubble_fraction": bub,
            **extra,
        })
    return rows


def run_deployment(graph, n_tiers: int, scale: float = 1.0, seed: int = 0):
    devices, links = DEPLOYMENTS[n_tiers]
    off = coach_offline_multihop(graph, devices, links)
    st = off.times
    tenants = _tenants(st, scale)
    hop_bits = [int(np.mean(list(b.values()))) if b else 8
                for b in off.decision.all_hop_bits]
    # boundary sized so the offline uplink occupation is reproduced at
    # the default precision (the engine then retimes it per task)
    elems = max(1, int(st.link[0] * links[0].bandwidth_bps / 8))
    stream = CorrelatedTaskStream(n_labels=N_LABELS, dim=FEAT_DIM,
                                  correlation="medium", seed=seed)
    feats, labels = make_calibration_set(stream, 400)

    def classify(task):
        d = np.linalg.norm(stream.mu - task.features[None], axis=1)
        return task.features, int(np.argmin(d))

    rows = []
    for policy in POLICIES:
        eng = MultiTenantCoachEngine(
            None, st, devices[0], links[0], devices[-1], N_LABELS,
            feats, labels, tenants, policy=policy, boundary_elems=elems,
            links=list(links), hop_bits_offline=hop_bits)
        tasks = [stream.tasks(t.n_tasks) for t in tenants]
        mt = eng.run_streams([list(ts) for ts in tasks], classify)
        extra = {"exit_ratio": float(np.mean(
            [r.stats.exit_ratio for r in mt.reports]))}
        rows += _tenant_rows(
            graph.name, n_tiers, policy, "async",
            [r.stats.pipeline for r in mt.reports], tenants, mt.pipeline,
            extra)
        # paired differential row set: identical decided plans replayed
        # by the extended multi-tenant event simulator
        ref = sim.simulate_multitenant_stream(
            mt.plans, mt.arrivals,
            make_policy(policy, weights=[t.weight for t in tenants]),
            links=list(links))
        rows += _tenant_rows(
            graph.name, n_tiers, policy, "sim",
            [tenant_pipeline_result(ref, t) for t in range(len(tenants))],
            tenants, result_from_stream(ref.stream), extra)
    return rows


def run(out_dir=None, scale: float = 1.0):
    rows = ["multitenant,engine,model,hops,policy,tenant,p99_ms,"
            "slo_attainment,norm_p99,worst_norm_p99,bubble_end"]
    payload = []
    graph = vgg16()
    for n_tiers in (2, 3):
        for r in run_deployment(graph, n_tiers, scale=scale):
            payload.append(r)
            rows.append(
                f"multitenant,{r['engine']},{r['model']},{r['hops']},"
                f"{r['policy']},{r['tenant']},{r['p99_latency_ms']:.2f},"
                f"{r['slo_attainment']:.3f},{r['norm_p99']:.2f},"
                f"{r['worst_tenant_norm_p99']:.2f},"
                f"{r['bubble_fraction']['end']:.3f}")
    if out_dir is not None:
        emit_pipeline_rows(out_dir, "multitenant", payload)
    return rows


if __name__ == "__main__":
    print("\n".join(run(out_dir="experiments/bench")))
