"""Table I: average inference latency (ms) for COACH and baselines across
ResNet101/VGG16 x Jetson NX/TX2 (medium-correlation ImageNet-100-like
stream, averaged over 20/50/100 Mbps like the paper's 2-100 Mbps range)."""

import numpy as np

from benchmarks.common import run_baseline, run_coach, scenario_arrival
from repro.models.cnn import resnet101, vgg16

BANDWIDTHS = (20.0, 50.0, 100.0)
METHODS = ("NS", "DADS", "SPINN", "JPS")


def run(out_dir=None, n_tasks=400):
    rows = ["table1,model,device,method,latency_ms,accuracy"]
    for gname, g in (("resnet101", resnet101()), ("vgg16", vgg16())):
        for dev in ("NX", "TX2"):
            lat = {m: [] for m in METHODS + ("COACH",)}
            acc = {m: [] for m in METHODS + ("COACH",)}
            for mbps in BANDWIDTHS:
                arr = scenario_arrival(g, dev, mbps)
                r = run_coach(g, dev, mbps, "medium", n_tasks=n_tasks,
                              arrival_period=arr)
                lat["COACH"].append(r.mean_latency_ms)
                acc["COACH"].append(r.accuracy)
                for m in METHODS:
                    rb = run_baseline(m, g, dev, mbps, "medium",
                                      n_tasks=n_tasks, arrival_period=arr)
                    lat[m].append(rb.mean_latency_ms)
                    acc[m].append(rb.accuracy)
            for m in METHODS + ("COACH",):
                rows.append(f"table1,{gname},{dev},{m},"
                            f"{np.mean(lat[m]):.2f},{np.mean(acc[m]):.3f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
