"""COACH's offline component applied to every ASSIGNED architecture
(beyond the paper's two CNNs): the layer-cost chain of each arch is
partitioned on the TPU end/cloud profiles, demonstrating
§Arch-applicability (DESIGN.md §4) with concrete cuts and precisions.

End = one v5e chip (weak edge accelerator), cloud = a v5e pod slice,
link = 10 GbE-class egress (the end-cloud setting COACH targets; serving
one request, batch=1, seq=512).
"""

from repro.configs import ARCHS, get_config
from repro.core.costs import (DeviceProfile, LinkProfile, transformer_graph)
from repro.core.partitioner import coach_offline

EDGE = DeviceProfile("edge-v5e", 197e12, efficiency=0.3)
CLOUD = DeviceProfile("cloud-pod-slice", 197e12 * 8, efficiency=0.4)
LINK = LinkProfile("egress", 10e9)


def run(out_dir=None):
    rows = ["arch_partition,arch,layers_on_end,total_nodes,bits,"
            "T_e_ms,T_t_ms,T_c_ms,objective_ms,feasible"]
    for arch in ARCHS:
        cfg = get_config(arch)
        g = transformer_graph(cfg, batch=1, seq=512)
        r = coach_offline(g, EDGE, CLOUD, LINK)
        bits = sorted(set(r.decision.bits.values())) or ["-"]
        t = r.times
        rows.append(
            f"arch_partition,{arch},{len(r.decision.end_set)},{len(g)},"
            f"{'/'.join(map(str, bits))},{t.T_e*1e3:.3f},{t.T_t*1e3:.3f},"
            f"{t.T_c*1e3:.3f},{r.objective*1e3:.3f},{r.feasible}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
