"""Fig. 1 (the motivating observation): temporal + spatial locality of the
task stream.

(a) temporal locality: lag-k cosine autocorrelation of task features —
    high-correlation streams stay similar over short intervals.
(b) spatial locality: per-class optimal quantization precision (dichotomous
    search against a measured nearest-center accuracy oracle) vs the
    class's distance from the global center — diffuse classes need more
    bits (the paper's 3/4/5-bit clusters).
"""

import numpy as np

from repro.core import online as ON
from repro.core.quant import uaq_roundtrip
from repro.data.pipeline import CorrelatedTaskStream, make_calibration_set

import jax.numpy as jnp


def run(out_dir=None):
    rows = ["fig1a,correlation,lag1_cos,lag5_cos,lag20_cos"]
    for corr in ("low", "medium", "high"):
        st = CorrelatedTaskStream(n_labels=20, dim=48, correlation=corr,
                                  seed=0)
        feats = np.stack([t.features for t in st.tasks(400)])
        def lag_cos(k):
            a, b = feats[:-k], feats[k:]
            num = (a * b).sum(1)
            den = np.linalg.norm(a, axis=1) * np.linalg.norm(b, axis=1)
            return float(np.mean(num / den))
        rows.append(f"fig1a,{corr},{lag_cos(1):.3f},{lag_cos(5):.3f},"
                    f"{lag_cos(20):.3f}")

    # (b) optimal bits per class via measured accuracy oracle
    st = CorrelatedTaskStream(n_labels=12, dim=48, correlation="low", seed=1)
    feats, labels = make_calibration_set(st, 600)
    def class_acc(f):
        d = np.linalg.norm(st.mu0[None] - f[:, None], axis=2)
        return (np.argmin(d, 1) == labels).mean()
    base = class_acc(feats)
    rows.append("fig1b,class,sigma,optimal_bits")
    for j in range(12):
        mask = labels == j
        if mask.sum() < 10:
            continue
        best = 16
        for bits in (3, 4, 5, 6, 8):
            fq = feats.copy()
            fq[mask] = np.asarray(uaq_roundtrip(jnp.asarray(feats[mask]),
                                                bits))
            if base - class_acc(fq) <= 0.005:
                best = bits
                break
        rows.append(f"fig1b,{j},{st.sigma[j]:.2f},{best}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
