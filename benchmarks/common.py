"""Shared benchmark scenario: the paper's testbed (ResNet101/VGG16 on
Jetson NX/TX2 + shared A6000 over WiFi) built from this repo's subsystems.

``run_coach``    — offline partition (Alg. 1) + online semantic cache on a
                   correlated task stream + 3-stage pipeline accounting.
``run_baseline`` — NS / DADS / SPINN / JPS on the same cost model & stream
                   (SPINN gets its fixed-threshold early exit).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np

from repro.core import baselines as BL
from repro.core import online as ON
from repro.core.costs import (A6000_SERVER, JETSON_NX, JETSON_TX2, LinkProfile,
                              ModelGraph, WIFI_5GHZ)
from repro.core.partitioner import coach_offline
from repro.core.pipeline import TaskPlan, run_pipeline
from repro.core.schedule import StageTimes
from repro.data.pipeline import CorrelatedTaskStream, make_calibration_set
from repro.obs.bubbles import attribute, chain_resources
from repro.obs.trace import TraceRecorder

DEVICES = {"NX": JETSON_NX, "TX2": JETSON_TX2}
N_LABELS = 30
FEAT_DIM = 48


@dataclasses.dataclass
class RunResult:
    mean_latency_ms: float
    p99_latency_ms: float
    throughput: float
    exit_ratio: float
    wire_kb_per_task: float
    accuracy: float
    cloud_bubbles: float
    link_bubbles: float
    max_stage_ms: float
    # full per-resource, per-cause idle decomposition from obs.bubbles
    # ({label: {cause: seconds}}, zero causes pruned); the scalar
    # cloud_bubbles/link_bubbles keys above stay for schema compatibility
    bubble_causes: Dict[str, Dict[str, float]] = \
        dataclasses.field(default_factory=dict)


def _boundary_elems(graph: ModelGraph, end_set) -> int:
    elems = 0
    for (u, v) in graph.boundary_edges(end_set):
        elems += graph.node(u).out_elems if u >= 0 else graph.input_elems
    return max(elems, 1)


def _stream(correlation: str, seed: int):
    stream = CorrelatedTaskStream(n_labels=N_LABELS, dim=FEAT_DIM,
                                  correlation=correlation, seed=seed)
    feats, labels = make_calibration_set(stream, 400)
    return stream, feats, labels


def _proxy_classifier(stream, quant_bits: Optional[int] = None):
    """Cloud-side classifier: nearest (undrifted) class center; optional
    feature quantization noise ties precision to accuracy."""
    def f(feat):
        x = feat
        if quant_bits is not None:
            lo, hi = x.min(), x.max()
            scale = max(hi - lo, 1e-8) / ((1 << quant_bits) - 1)
            x = np.round((x - lo) / scale) * scale + lo
        d = np.linalg.norm(stream.mu - x[None], axis=1)
        return int(np.argmin(d))
    return f


def _pipeline_result(plans, correct, arrival_period, link, exits) -> RunResult:
    rec = TraceRecorder()
    pr = run_pipeline(plans, arrival_period=arrival_period, link=link,
                      sink=rec)
    att = attribute(rec, resources=chain_resources(
        pr.n_hops, pr.pool_sizes or None))
    causes = {label: {c: s for c, s in cs.items() if s > 0.0}
              for label, cs in att.by_label().items()}
    tx = [p.t_tx for p in plans if not p.early_exit]
    return RunResult(
        mean_latency_ms=pr.mean_latency * 1e3,
        p99_latency_ms=pr.p99_latency * 1e3,
        throughput=pr.throughput,
        exit_ratio=exits / len(plans),
        wire_kb_per_task=float(np.sum([t * link.bandwidth_bps for t in tx])
                               / 8e3 / len(plans)),
        accuracy=float(np.mean(correct)),
        cloud_bubbles=pr.bubble_fraction("cloud"),
        link_bubbles=pr.bubble_fraction("link"),
        max_stage_ms=max(max(p.t_end, p.t_tx, p.t_cloud) for p in plans) * 1e3,
        bubble_causes=causes,
    )


def scenario_arrival(graph: ModelGraph, device: str, mbps: float,
                     slack: float = 1.1) -> float:
    """Shared task arrival period for one scenario: every method (COACH +
    baselines) must be stable, so latency comparisons are like-for-like."""
    end_dev = DEVICES[device]
    link = WIFI_5GHZ(mbps)
    stages = [coach_offline(graph, end_dev, A6000_SERVER, link).times]
    stages += [fn(graph, end_dev, A6000_SERVER, link).times
               for fn in BL.BASELINES.values()]
    return slack * max(s.max_stage for s in stages)


def run_coach(graph: ModelGraph, device="NX", mbps: float = 50.0,
              correlation: str = "medium", n_tasks: int = 600,
              seed: int = 0, trace: Optional[Callable] = None,
              arrival_factor: float = 1.0,
              arrival_period: Optional[float] = None,
              online: bool = True) -> RunResult:
    end_dev = DEVICES[device]
    link = WIFI_5GHZ(mbps)
    if trace is not None:
        link = LinkProfile("wifi-dyn", mbps * 1e6, trace=trace)
    # Eq. 3 latency budget: tasks must not exceed 1.5x the best single-task
    # latency any baseline achieves (the paper's latency-tolerance input)
    off = coach_offline(graph, end_dev, A6000_SERVER, link,
                        T_max=1.5 * BL.neurosurgeon(
                            graph, end_dev, A6000_SERVER, link).times.latency)
    st_ = off.times
    elems = _boundary_elems(graph, off.decision.end_set)

    stream, feats, labels = _stream(correlation, seed)
    cache = ON.SemanticCache(N_LABELS, FEAT_DIM)
    cache.warm_up(feats, labels)
    th = ON.calibrate_thresholds(cache, feats, labels)
    sched = ON.OnlineScheduler(cache, th, elems, st_.T_e, st_.T_c)

    arrival = arrival_period if arrival_period is not None \
        else st_.max_stage * arrival_factor
    plans, correct = [], []
    exits = 0
    for task in stream.tasks(n_tasks):
        bw = link.bps_at(arrival * task.id)
        if online:
            dec = sched.step(task.features, bandwidth_bps=bw)
        else:
            dec = ON.OnlineDecision(False, None, 0.0, None, None)
        if dec.early_exit:
            exits += 1
            plans.append(TaskPlan(st_.T_e, 0.0, 0.0, True))
            correct.append(dec.result == task.label)
        else:
            bits = dec.bits if dec.bits else \
                int(np.mean(list(off.decision.bits.values())) or 8)
            t_tx = elems * bits / link.bandwidth_bps
            plans.append(TaskPlan(st_.T_e, t_tx, st_.T_c,
                                  tx_offset=min(st_.first_tx_offset, st_.T_e),
                                  cloud_offset=st_.cloud_start_offset))
            pred = _proxy_classifier(stream, bits)(task.features)
            correct.append(pred == task.label)
            sched.report_label(task.features, task.label)
    return _pipeline_result(plans, correct, arrival, link, exits)


def run_baseline(name: str, graph: ModelGraph, device="NX",
                 mbps: float = 50.0, correlation: str = "medium",
                 n_tasks: int = 600, seed: int = 0,
                 trace: Optional[Callable] = None,
                 arrival_factor: float = 1.0,
                 arrival_period: Optional[float] = None) -> RunResult:
    end_dev = DEVICES[device]
    link = WIFI_5GHZ(mbps)
    if trace is not None:
        link = LinkProfile("wifi-dyn", mbps * 1e6, trace=trace)
    b = BL.BASELINES[name](graph, end_dev, A6000_SERVER, link)
    st_ = b.times
    elems = _boundary_elems(graph, b.decision.end_set)
    bits = {"ns": 32, "dads": 32, "spinn": 8, "jps": 8}[b.decision.name]

    stream, feats, labels = _stream(correlation, seed)
    # SPINN: fixed-threshold early exit (uncalibrated, conservative)
    spinn_th = None
    cache = None
    if name == "SPINN":
        cache = ON.SemanticCache(N_LABELS, FEAT_DIM)
        cache.warm_up(feats, labels)
        seps = [ON.separability(cache.similarities(f)) for f in feats]
        spinn_th = float(np.quantile(seps, 0.9))

    arrival = arrival_period if arrival_period is not None \
        else st_.max_stage * arrival_factor
    plans, correct = [], []
    exits = 0
    clf = _proxy_classifier(stream, bits if bits < 32 else None)
    for task in stream.tasks(n_tasks):
        if spinn_th is not None:
            sims = cache.similarities(task.features)
            if ON.separability(sims) > spinn_th:
                exits += 1
                plans.append(TaskPlan(st_.T_e, 0.0, 0.0, True))
                correct.append(int(np.argmax(sims)) == task.label)
                cache.update(task.features, int(np.argmax(sims)))
                continue
        # the offline evaluation already priced the boundary (incl. 8-bit
        # raw input for all-cloud cuts); baselines don't adapt per task
        t_tx = st_.T_t
        plans.append(TaskPlan(st_.T_e, t_tx, st_.T_c,
                              tx_offset=min(st_.first_tx_offset, st_.T_e),
                              cloud_offset=st_.cloud_start_offset))
        correct.append(clf(task.features) == task.label)
        if cache is not None:
            cache.update(task.features, task.label)
    return _pipeline_result(plans, correct, arrival, link, exits)


def csv_row(tag: str, r: RunResult) -> str:
    return (f"{tag},{r.mean_latency_ms:.2f},{r.throughput:.1f},"
            f"{r.exit_ratio:.3f},{r.wire_kb_per_task:.1f},{r.accuracy:.3f},"
            f"{r.cloud_bubbles:.3f},{r.max_stage_ms:.2f}")


CSV_HEADER = ("tag,latency_ms,throughput_its,exit_ratio,wire_kb,accuracy,"
              "cloud_bubbles,max_stage_ms")
