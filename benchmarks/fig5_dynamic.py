"""Fig. 5: adaptability under dynamic network conditions.

Tasks arrive at the base-bandwidth service rate; bandwidth then drops at
1/3 and 2/3 of the stream.  Reported per phase: completed-task throughput
for COACH vs baselines, plus COACH's retention vs its static throughput at
each phase's bandwidth (the paper reports 85-88% retention)."""

from benchmarks.common import run_baseline, run_coach
from repro.models.cnn import resnet101

SCENARIOS = {
    "a_100_50_20": (100.0, (50.0, 20.0)),
    "b_100_70_50": (100.0, (70.0, 50.0)),
}
N_TASKS = 900


def run(out_dir=None, n_tasks=N_TASKS):
    g = resnet101()
    rows = ["fig5,scenario,method,tp_phase1,tp_phase2,tp_phase3,"
            "retention_p2,retention_p3"]
    for sname, (base, (bw2, bw3)) in SCENARIOS.items():
        # shared paced arrival: COACH's base-bandwidth service period
        probe = run_coach(g, "NX", base, "medium", n_tasks=50,
                          arrival_factor=0.0)
        period = 1.0 / probe.throughput
        # static references at the degraded bandwidths (saturation rate)
        s2 = run_coach(g, "NX", bw2, "medium", n_tasks=300, arrival_factor=0.0)
        s3 = run_coach(g, "NX", bw3, "medium", n_tasks=300, arrival_factor=0.0)
        # per-phase throughput: paced runs at each phase's bandwidth
        p1 = run_coach(g, "NX", base, "medium", n_tasks=300,
                       arrival_period=period).throughput
        p2 = run_coach(g, "NX", bw2, "medium", n_tasks=300,
                       arrival_period=period).throughput
        p3 = run_coach(g, "NX", bw3, "medium", n_tasks=300,
                       arrival_period=period).throughput
        rows.append(f"fig5,{sname},COACH,{p1:.2f},{p2:.2f},{p3:.2f},"
                    f"{p2 / max(s2.throughput, 1e-9):.3f},"
                    f"{p3 / max(s3.throughput, 1e-9):.3f}")
        for m in ("NS", "DADS", "SPINN", "JPS"):
            b1 = run_baseline(m, g, "NX", base, "medium", n_tasks=300,
                              arrival_period=period).throughput
            b2 = run_baseline(m, g, "NX", bw2, "medium", n_tasks=300,
                              arrival_period=period).throughput
            b3 = run_baseline(m, g, "NX", bw3, "medium", n_tasks=300,
                              arrival_period=period).throughput
            rows.append(f"fig5,{sname},{m},{b1:.2f},{b2:.2f},{b3:.2f},,")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
