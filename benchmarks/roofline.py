"""§Roofline report: reads the dry-run artifacts (experiments/dryrun/*.json)
and prints the three-term roofline per (arch x shape x mesh):

  compute    = HLO_FLOPs / peak_FLOPs            (per chip)
  memory     = HLO_bytes / HBM_bw                (per chip)
  collective = collective_bytes / (links x bw)   (per chip)

plus the dominant term and MODEL_FLOPS / HLO_FLOPs (useful-compute ratio).
"""

import json
from pathlib import Path

DEFAULT_DIR = "experiments/dryrun"


def run(out_dir=None, dryrun_dir=DEFAULT_DIR):
    rows = ["roofline,arch,shape,mesh,t_compute_s,t_memory_s,"
            "t_collective_s,bottleneck,useful_flop_frac,mem_gb_per_dev"]
    d = Path(dryrun_dir)
    if not d.exists():
        rows.append("roofline,NO_DRYRUN_ARTIFACTS_RUN_dryrun_first,,,,,,,,")
        return rows
    for f in sorted(d.glob("*.json")):
        rep = json.loads(f.read_text())
        if "skipped" in rep or "error" in rep:
            continue
        r = rep["roofline"]
        mem = rep.get("memory", {}).get("total_nonalias_bytes", 0) / 1e9
        frac = rep.get("useful_flop_frac")
        rows.append(
            f"roofline,{rep['arch']},{rep['shape']},{rep['mesh']},"
            f"{r['t_compute_s']:.3e},{r['t_memory_s']:.3e},"
            f"{r['t_collective_s']:.3e},{r['bottleneck']},"
            f"{frac if frac is None else round(frac, 4)},{mem:.2f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
