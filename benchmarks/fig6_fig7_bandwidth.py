"""Figs. 6-7: latency and throughput vs bandwidth (5-100 Mbps sweep) for
COACH and all baselines on ResNet101/VGG16 (UCF101-like medium stream)."""

from benchmarks.common import run_baseline, run_coach, scenario_arrival
from repro.models.cnn import resnet101, vgg16

BANDWIDTHS = (5.0, 10.0, 20.0, 50.0, 70.0, 100.0)
METHODS = ("NS", "DADS", "SPINN", "JPS")


def run(out_dir=None, n_tasks=300):
    rows = ["fig67,model,mbps,method,latency_ms,throughput"]
    for gname, g in (("resnet101", resnet101()), ("vgg16", vgg16())):
        for mbps in BANDWIDTHS:
            arr = scenario_arrival(g, "NX", mbps)
            rl = run_coach(g, "NX", mbps, "medium", n_tasks=n_tasks,
                           arrival_period=arr)
            rt = run_coach(g, "NX", mbps, "medium", n_tasks=n_tasks,
                           arrival_factor=0.0)
            rows.append(f"fig67,{gname},{mbps},COACH,"
                        f"{rl.mean_latency_ms:.2f},{rt.throughput:.2f}")
            for m in METHODS:
                bl = run_baseline(m, g, "NX", mbps, "medium",
                                  n_tasks=n_tasks, arrival_period=arr)
                bt = run_baseline(m, g, "NX", mbps, "medium",
                                  n_tasks=n_tasks, arrival_factor=0.0)
                rows.append(f"fig67,{gname},{mbps},{m},"
                            f"{bl.mean_latency_ms:.2f},{bt.throughput:.2f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
