"""Continuous micro-batching: paired batched/unbatched throughput-vs-p99.

ResNet101 is partitioned with the real offline planner onto the 2-tier
(Jetson NX + A6000) and 3-tier (+ AGX-Orin mid) deployments, each
segment's service time is split into its per-launch fixed part and
per-sample marginal (``core.costs.segment_batch_split`` — ResNet's low
attainment makes the fixed fraction large, which is exactly the regime
the paper's bubble analysis targets), and the same overloaded task
stream (arrival period = ``max_stage / OVERLOAD``) is run twice per
engine:

  batched = False  every tier serves tasks one at a time (today's path)
  batched = True   per-tier caps from the auto batch-size finder
                   (``serving.batching.auto_batch_caps``): compute
                   workers drain their hop queue into dynamic
                   micro-batches priced ``t_fixed + n * t_marginal``

Both engines run each pair: ``engine = "sim"`` is the arithmetic staged
replay (``core.pipeline.run_pipeline``), ``engine = "async"`` the
event-driven asyncio executor on the virtual clock with the served
engine's bounded hop queues.  The pairing isolates the new measurable
axis — batched throughput against tail latency at fixed offered load.
``benchmarks/validate_bench.py`` gates the artifact: batched throughput
must be >= 1.5x unbatched at equal-or-better p99 on every pair.

Both tiersets run over 10 GbE rack fabric (the co-located edge-cluster
deployment): batching amortizes compute launches only, so the chain
must be compute-bound for the axis to be measurable.  Over the 50 Mbps
WiFi uplink of the multihop benchmark ResNet's boundary tensor makes
the chain wire-bound, and even over gigabit LAN the bubble-balancing
planner parks the saturated stage on the wire — regimes where batching
(correctly) shows no gain and the pair would measure the link, not the
subsystem under test.  The hop queues are unbounded here so the two
engines face identical queueing dynamics (the differential contract's
setting); bounded-queue backpressure is the multi-tenant benchmark's
axis.
"""

from __future__ import annotations

from benchmarks.bench_io import emit_pipeline_rows
from benchmarks.multihop import _resource_names
from repro.core.costs import (A6000_SERVER, EDGE_AGX_ORIN, JETSON_NX,
                              LinkProfile, segment_batch_split)
from repro.core.partitioner import coach_offline_multihop
from repro.core.pipeline import plan_from_stage_times, run_pipeline
from repro.models.cnn import resnet101
from repro.serving.async_engine import run_pipeline_async
from repro.serving.batching import auto_batch_caps, realized_batch_sizes

N_TASKS = 300
#: arrival period = max_stage * OVERLOAD — offered load is 2x the
#: unbatched service rate, so the unbatched pair saturates and batching
#: has a backlog to amortize
OVERLOAD = 0.5
#: staleness slack handed to the auto finder, in units of max_stage
#: (split evenly across tiers inside ``auto_batch_caps``)
SLACK_STAGES = 2.0
CAP_LIMIT = 16

ETH_10G = lambda: LinkProfile("eth-10g", 10e9)  # noqa: E731

DEPLOYMENTS = {
    2: ((JETSON_NX, A6000_SERVER), (ETH_10G(),)),
    3: ((JETSON_NX, EDGE_AGX_ORIN, A6000_SERVER),
        (ETH_10G(), ETH_10G())),
}


def _row(graph, n_tiers, engine, pr, st, batched, caps, slack) -> dict:
    comp_names, link_names = _resource_names(n_tiers - 1)
    bubbles = {name: pr.bubble_fraction(("compute", k))
               for k, name in enumerate(comp_names)}
    bubbles.update({name: pr.bubble_fraction(("link", k))
                    for k, name in enumerate(link_names)})
    return {
        "model": graph.name,
        "hops": n_tiers,
        "engine": engine,
        "batched": batched,
        "batch_cap": max(caps),
        "batch_caps": list(caps),
        "realized_batch": [round(b, 3) for b in realized_batch_sizes(pr)],
        "batch_slack_ms": slack * 1e3,
        "single_task_ms": st.latency * 1e3,
        "mean_latency_ms": pr.mean_latency * 1e3,
        "p99_latency_ms": pr.p99_latency * 1e3,
        "throughput_its": pr.throughput,
        "makespan_ms": pr.makespan * 1e3,
        "max_stage_ms": st.max_stage * 1e3,
        "bubble_fraction": bubbles,
    }


def run_deployment(graph, n_tiers: int, n_tasks: int = N_TASKS) -> list:
    devices, links = DEPLOYMENTS[n_tiers]
    off = coach_offline_multihop(graph, devices, links)
    st = off.times
    # calibrated per-segment (fixed, marginal) split of the chosen cut
    t_fixed = tuple(
        segment_batch_split(devices[k],
                            [graph.node(i) for i in sorted(seg)])[0]
        for k, seg in enumerate(off.decision.segments(graph)))
    slack = st.max_stage * SLACK_STAGES
    caps = auto_batch_caps(st.compute, t_fixed, slack, CAP_LIMIT)
    period = st.max_stage * OVERLOAD
    plans = [plan_from_stage_times(st) for _ in range(n_tasks)]
    for p in plans:
        p.t_fixed = t_fixed
    rows = []
    for batched in (False, True):
        bc = list(caps) if batched else [1] * (n_tiers)
        pr = run_pipeline(plans, arrival_period=period, links=list(links),
                          batch_caps=bc)
        pa = run_pipeline_async(plans, arrival_period=period,
                                links=list(links), batch_caps=bc)
        rows += [_row(graph, n_tiers, "sim", pr, st, batched, bc, slack),
                 _row(graph, n_tiers, "async", pa, st, batched, bc, slack)]
    return rows


def run(out_dir=None, n_tasks: int = N_TASKS):
    rows = ["batching,engine,model,hops,batched,batch_caps,realized,"
            "p99_ms,throughput_its,makespan_ms"]
    payload = []
    for n_tiers in (2, 3):
        for r in run_deployment(resnet101(), n_tiers, n_tasks=n_tasks):
            payload.append(r)
            rows.append(
                f"batching,{r['engine']},{r['model']},{r['hops']},"
                f"{int(r['batched'])},"
                f"{'/'.join(str(c) for c in r['batch_caps'])},"
                f"{'/'.join(f'{b:.2f}' for b in r['realized_batch'])},"
                f"{r['p99_latency_ms']:.2f},{r['throughput_its']:.1f},"
                f"{r['makespan_ms']:.2f}")
    if out_dir is not None:
        emit_pipeline_rows(out_dir, "batching", payload)
    return rows


if __name__ == "__main__":
    print("\n".join(run(out_dir="experiments/bench")))
