"""Table II: context-aware acceleration across data-correlation levels
(UCF101-like stream): early-exit ratio, latency, transmission cost, vs the
NoAdjust ablation (COACH offline partition, no online component)."""

from benchmarks.common import run_coach, scenario_arrival
from repro.models.cnn import resnet101, vgg16

MBPS = 50.0


def run(out_dir=None, n_tasks=500):
    rows = ["table2,model,level,exit_ratio,latency_ms,trans_kb,accuracy"]
    for gname, g in (("resnet101", resnet101()), ("vgg16", vgg16())):
        arr = scenario_arrival(g, "NX", MBPS)
        base = run_coach(g, "NX", MBPS, "medium", n_tasks=n_tasks,
                         arrival_period=arr, online=False)
        rows.append(f"table2,{gname},NoAdjust,-,"
                    f"{base.mean_latency_ms:.2f},"
                    f"{base.wire_kb_per_task:.1f},{base.accuracy:.3f}")
        for level in ("low", "medium", "high"):
            r = run_coach(g, "NX", MBPS, level, n_tasks=n_tasks,
                          arrival_period=arr)
            rows.append(f"table2,{gname},{level},{r.exit_ratio:.3f},"
                        f"{r.mean_latency_ms:.2f},"
                        f"{r.wire_kb_per_task:.1f},{r.accuracy:.3f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
