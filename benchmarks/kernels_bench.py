"""Kernel microbenchmarks: us/call for the shared wire/probe entry
points in ``repro.kernels.ops`` — the *same* dispatchers the runtime
uses, so on a TPU host these rows time the Pallas kernels and elsewhere
they time the jitted jnp references (each row is tagged with the
``path`` it actually took).  The fused single-pass boundary hop
(``ops.boundary_pass``) is benched next to the unfused
quantize-then-probe pair it replaces.

Rows are also emitted as ``kind = "kernels"`` into the canonical
``BENCH_pipeline.json`` via ``bench_io`` and schema-checked by
``benchmarks/validate_bench.py``."""

import time

import jax
import jax.numpy as jnp

from benchmarks.bench_io import emit_pipeline_rows
from repro.kernels import ops, ref

HEADER = "kernels,name,us_per_call,path,derived"


def _bench(fn, *args, iters=20) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(out_dir=None):
    on_tpu = jax.default_backend() == "tpu"
    path = "pallas" if on_tpu else "ref"
    backend = jax.default_backend()
    rows_csv = [HEADER]
    rows = []

    def add(name, us, derived=""):
        rows_csv.append(f"kernels,{name},{us:.1f},{path},{derived}")
        rows.append({"name": name, "us_per_call": us, "path": path,
                     "backend": backend, "derived": derived})

    key = jax.random.PRNGKey(0)
    for (m, n) in ((1024, 2304), (4096, 2304)):
        x = jax.random.normal(key, (m, n))
        for bits in (4, 8):
            q = jax.jit(lambda t, b=bits:
                        ops.quantize_activation(t, b, use_kernel=on_tpu))
            us = _bench(q, x)
            gbps = x.size * 4 / (us / 1e6) / 1e9
            add(f"uaq_quant_{m}x{n}_b{bits}", us, f"{gbps:.2f}GB/s")
            p, s, z = q(x)
            dq = jax.jit(lambda pp, ss, zz, b=bits: ops.dequantize_activation(
                pp, ss, zz, b, use_kernel=on_tpu, channels=n))
            us = _bench(dq, p, s, z)
            add(f"uaq_dequant_{m}x{n}_b{bits}", us)
    xb = jax.random.normal(key, (16, 512, 256))
    c = jax.random.normal(key, (100, 256))
    probe = ops.probe_cache if on_tpu else jax.jit(ref.semantic_probe_ref)
    us = _bench(probe, xb, c)
    add("semantic_probe_16x512x256_L100", us)
    for bits in (4, 8):
        us = _bench(lambda t, cc, b=bits: ops.boundary_pass(t, cc, b), xb, c)
        add(f"fused_boundary_16x512x256_L100_b{bits}", us)
    if out_dir is not None:
        emit_pipeline_rows(out_dir, "kernels", rows)
    return rows_csv


if __name__ == "__main__":
    print("\n".join(run()))
