"""Kernel microbenchmarks: us/call for the UAQ quantize/dequantize and
semantic-probe paths (jnp reference semantics jitted on this host; the
Pallas TPU kernels are validated in interpret mode and bench-able on real
TPUs with the same entry points)."""

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _bench(fn, *args, iters=20) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(out_dir=None):
    rows = ["kernels,name,us_per_call,derived"]
    key = jax.random.PRNGKey(0)
    for (m, n) in ((1024, 2304), (4096, 2304)):
        x = jax.random.normal(key, (m, n))
        for bits in (4, 8):
            q = jax.jit(lambda t, b=bits: ref.uaq_quantize_ref(t, b))
            us = _bench(q, x)
            gbps = x.size * 4 / (us / 1e6) / 1e9
            rows.append(f"kernels,uaq_quant_{m}x{n}_b{bits},{us:.1f},"
                        f"{gbps:.2f}GB/s")
            p, s, z = q(x)
            dq = jax.jit(lambda pp, ss, zz, b=bits:
                         ref.uaq_dequantize_ref(pp, ss, zz, b))
            us = _bench(dq, p, s, z)
            rows.append(f"kernels,uaq_dequant_{m}x{n}_b{bits},{us:.1f},")
    xb = jax.random.normal(key, (16, 512, 256))
    c = jax.random.normal(key, (100, 256))
    probe = jax.jit(ref.semantic_probe_ref)
    us = _bench(probe, xb, c)
    rows.append(f"kernels,semantic_probe_16x512x256_L100,{us:.1f},")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
