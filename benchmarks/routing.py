"""Replicated-tier scale-out: throughput vs replica count at bounded p99.

ResNet101 is partitioned with the real offline planner onto the 2-tier
(Jetson NX + A6000) and 3-tier (+ AGX-Orin mid) deployments, then every
compute tier is replicated ``m``-fold (``core.sim.PoolSpec``) behind a
router policy (``serving.routing``) and the same overloaded task stream
(arrival period = ``max_stage / OVERLOAD_X``, i.e. 4x the single-replica
bottleneck's service rate) is replayed per (policy, m):

  policy in {jsq, po2, random}   join-shortest-queue, power-of-two-
                                 choices, and the no-information random
                                 baseline the informed policies must beat
  m in {1, 2, 4}                 replicas per compute tier (m = 1 is the
                                 classic serial chain)

Both engines run every cell: ``engine = "sim"`` is the staged pool
replay (``sim.simulate_pool_stream`` via ``core.pipeline.run_pipeline``),
``engine = "async"`` the per-replica asyncio workers behind per-pool
dispatchers on the virtual clock.  ``benchmarks/validate_bench.py``
gates the artifact: for jsq and po2 the ``m = 2`` row must deliver
>= 1.8x the ``m = 1`` throughput at equal-or-better p99 (random is
reported but not gated — its load imbalance is the point of the
comparison).

The deployments run over 40 GbE rack fabric: replication amortizes
*compute* service only, so the serial links must not bind before the
replicated tiers have scaled — on 10 GbE the ResNet boundary tensor
(~2 ms on the wire) caps 3-tier scale-out below the gate.  With the
wire at ~0.5 ms the chain stays compute-bound through m = 2 and the
wire (correctly) becomes the ceiling at m = 4, which is the honest
scale-out story: near-linear until the serial resource binds.
"""

from __future__ import annotations

from benchmarks.bench_io import emit_pipeline_rows
from benchmarks.multihop import _resource_names
from repro.core.costs import (A6000_SERVER, EDGE_AGX_ORIN, JETSON_NX,
                              LinkProfile)
from repro.core.partitioner import coach_offline_multihop
from repro.core.pipeline import plan_from_stage_times, run_pipeline
from repro.models.cnn import resnet101
from repro.serving.async_engine import run_pipeline_async
from repro.serving.routing import make_router

N_TASKS = 240
#: arrival period = max_stage / OVERLOAD_X — offered load is 4x the
#: single-replica bottleneck, so every m in M_SWEEP stays backlogged and
#: throughput measures service capacity, not the arrival process
OVERLOAD_X = 4.0
M_SWEEP = (1, 2, 4)
POLICIES = ("jsq", "po2", "random")
ROUTER_SEED = 0

ETH_40G = lambda: LinkProfile("eth-40g", 40e9)  # noqa: E731

DEPLOYMENTS = {
    2: ((JETSON_NX, A6000_SERVER), (ETH_40G(),)),
    3: ((JETSON_NX, EDGE_AGX_ORIN, A6000_SERVER),
        (ETH_40G(), ETH_40G())),
}


def _row(graph, n_tiers, engine, policy, m, pr, st) -> dict:
    comp_names, link_names = _resource_names(n_tiers - 1)
    bubbles = {name: pr.bubble_fraction(("compute", k))
               for k, name in enumerate(comp_names)}
    bubbles.update({name: pr.bubble_fraction(("link", k))
                    for k, name in enumerate(link_names)})
    return {
        "model": graph.name,
        "hops": n_tiers,
        "engine": engine,
        "policy": policy,
        "m": m,
        "pool_sizes": [m] * n_tiers,
        "single_task_ms": st.latency * 1e3,
        "mean_latency_ms": pr.mean_latency * 1e3,
        "p99_latency_ms": pr.p99_latency * 1e3,
        "throughput_its": pr.throughput,
        "makespan_ms": pr.makespan * 1e3,
        "max_stage_ms": st.max_stage * 1e3,
        "bubble_fraction": bubbles,
    }


def run_deployment(graph, n_tiers: int, n_tasks: int = N_TASKS) -> list:
    devices, links = DEPLOYMENTS[n_tiers]
    off = coach_offline_multihop(graph, devices, links)
    st = off.times
    period = st.max_stage / OVERLOAD_X
    plans = [plan_from_stage_times(st) for _ in range(n_tasks)]
    rows = []
    for policy in POLICIES:
        for m in M_SWEEP:
            pools = [m] * n_tiers
            pr = run_pipeline(
                plans, arrival_period=period, links=list(links),
                pools=pools, router=make_router(policy, seed=ROUTER_SEED))
            pa = run_pipeline_async(
                plans, arrival_period=period, links=list(links),
                pools=pools, router=make_router(policy, seed=ROUTER_SEED))
            rows += [_row(graph, n_tiers, "sim", policy, m, pr, st),
                     _row(graph, n_tiers, "async", policy, m, pa, st)]
    return rows


def run(out_dir=None, n_tasks: int = N_TASKS):
    rows = ["routing,engine,model,hops,policy,m,"
            "p99_ms,throughput_its,makespan_ms"]
    payload = []
    for n_tiers in (2, 3):
        for r in run_deployment(resnet101(), n_tiers, n_tasks=n_tasks):
            payload.append(r)
            rows.append(
                f"routing,{r['engine']},{r['model']},{r['hops']},"
                f"{r['policy']},{r['m']},"
                f"{r['p99_latency_ms']:.2f},{r['throughput_its']:.1f},"
                f"{r['makespan_ms']:.2f}")
    if out_dir is not None:
        emit_pipeline_rows(out_dir, "routing", payload)
    return rows


if __name__ == "__main__":
    print("\n".join(run(out_dir="experiments/bench")))
