"""Offline-planner candidate throughput: batched fast scorer vs naive
per-candidate simulation.

For VGG16/ResNet101 on the 2-tier (end->cloud) and 3-tier
(end->edge->cloud) deployments, run the *same* full-stride multi-cut
search twice:

  naive   ``coach_offline_multihop(fast=False)`` — every candidate pays
          a full event simulation times the relax ladder (the
          pre-refactor path, kept as the ground-truth baseline)
  fast    ``coach_offline_multihop(fast=True)`` — the batched
          prefix-sum scorer of ``repro.core.plan_fast`` plus top-K
          event-sim rescoring

and report wall time, candidates/sec and the throughput speedup, with
an ``argmin_match`` flag asserting the two searches returned the same
``PartitionDecision`` (cuts + per-hop bits) and objective (1e-9) — the
fast path is a pure speedup, not an approximation.  Rows are merged
into ``BENCH_pipeline.json`` as ``kind: "planner"`` via
``benchmarks.bench_io`` and validated by ``benchmarks/validate_bench.py``
in CI.
"""

from __future__ import annotations

import time

from benchmarks.bench_io import emit_pipeline_rows
from benchmarks.multihop import DEPLOYMENTS
from repro.core.partitioner import coach_offline_multihop
from repro.models.cnn import resnet101, vgg16

OBJ_RTOL = 1e-9


def _search(graph, devices, links, fast: bool):
    t0 = time.perf_counter()
    off = coach_offline_multihop(graph, devices, links, chain_stride=1,
                                 fast=fast)
    return off, time.perf_counter() - t0


def run_case(graph, n_tiers: int) -> dict:
    devices, links = DEPLOYMENTS[n_tiers]
    naive, naive_s = _search(graph, devices, links, fast=False)
    fast, fast_s = _search(graph, devices, links, fast=True)
    argmin_match = (
        naive.decision.cuts == fast.decision.cuts
        and naive.decision.all_hop_bits == fast.decision.all_hop_bits
        and abs(naive.objective - fast.objective)
        <= OBJ_RTOL * max(1.0, naive.objective))
    cps_naive = naive.candidates / max(naive_s, 1e-12)
    cps_fast = fast.candidates / max(fast_s, 1e-12)
    return {
        "model": graph.name,
        "hops": n_tiers,
        "chain_stride": 1,
        "candidates_naive": naive.candidates,
        "candidates_fast": fast.candidates,
        "naive_s": naive_s,
        "fast_s": fast_s,
        "cand_per_s_naive": cps_naive,
        "cand_per_s_fast": cps_fast,
        "speedup": cps_fast / max(cps_naive, 1e-12),
        "argmin_match": bool(argmin_match),
        "objective_ms": fast.objective * 1e3,
        "segments": [len(s) for s in fast.decision.segments(graph)],
    }


def run(out_dir=None):
    rows = ["planner,model,hops,candidates,naive_s,fast_s,"
            "cand_per_s_naive,cand_per_s_fast,speedup,argmin_match"]
    payload = []
    for graph in (vgg16(), resnet101()):
        for n_tiers in (2, 3):
            r = run_case(graph, n_tiers)
            payload.append(r)
            rows.append(
                f"planner,{r['model']},{r['hops']},{r['candidates_fast']},"
                f"{r['naive_s']:.3f},{r['fast_s']:.3f},"
                f"{r['cand_per_s_naive']:.0f},{r['cand_per_s_fast']:.0f},"
                f"{r['speedup']:.1f},{r['argmin_match']}")
    if out_dir is not None:
        emit_pipeline_rows(out_dir, "planner", payload)
    return rows


if __name__ == "__main__":
    print("\n".join(run(out_dir="experiments/bench")))
