"""Resilience bench: storyline dynamics under the differential pin.

Two storylines, both executed on *both* engines through the scenario
runner (so every row is backed by a 1e-6 span-trace pin):

``degrade``
    A serial-chain deployment rides through a scripted mid-stream link
    degradation window (nominal -> DEGRADED_MBPS -> recovery).  Two
    variants share the identical traced links: ``static`` keeps the
    nominal plan throughout; ``replan`` runs the online re-planner
    (bandwidth-EMA regime detection, warm-started planner tables,
    hop-boundary migration with a precision drop on the degraded hop).
    The bench *gate* lives here: through the degraded window the
    ``replan`` variant must achieve strictly better p99 than ``static``
    at equal-or-better throughput (``validate_bench`` re-checks it from
    the artifact).

``churn``
    A replicated-pool deployment with scripted replica dropout/rejoin,
    routed by the availability-aware router.  Downtime manifests only
    through routing, so these rows are pinned (trace match +
    conservation) but carry no p99 gate.

Row schema (per engine x storyline x variant): identity
(``model, hops, engine, storyline, variant``), stream shape
(``n_tasks, window``), re-planning counters (``n_replans,
n_migrations``), latency/throughput (``p50_ms, p99_ms, p99_window_ms,
throughput_its, makespan_ms``), and the pin evidence
(``trace_match, max_done_delta_s, conservation_max_err_s,
bubble_causes_ms`` incl. the ``replanning`` cause).
"""

from __future__ import annotations

import numpy as np

from benchmarks.bench_io import emit_pipeline_rows
from repro.core.costs import (A6000_SERVER, EDGE_AGX_ORIN, ETH_LAN,
                              JETSON_NX, WIFI_5GHZ)
from repro.core.sim import PoolSpec
from repro.models.cnn import resnet101
from repro.obs.bubbles import attribute, chain_resources
from repro.scenarios import (LinkShift, ReplicaDown, ReplicaUp, Timeline,
                             run_chain_scenario, run_churn_scenario)
from repro.scenarios.replan import replan_timeline

N_TASKS = 140
ARRIVAL_SLACK = 1.05

DEPLOYMENTS = {
    2: ((JETSON_NX, A6000_SERVER), (WIFI_5GHZ(50.0),)),
    3: ((JETSON_NX, EDGE_AGX_ORIN, A6000_SERVER),
        (WIFI_5GHZ(50.0), ETH_LAN())),
}

# degradation window in arrival periods, and the degraded hop-0 rate
WINDOW = (30, 90)
DEGRADED_MBPS = 12.0
DEGRADED_TX_SCALE = 0.5
MIN_GAP_PERIODS = 10

# churn storyline: (tier, replica, down period, up period)
CHURN_EVENTS = ((1, 0, 15, 55), (0, 1, 30, 70))
POOL_SIZES = (2, 3)


def _latency_stats(pr, window):
    lat = np.array([t.latency for t in pr.tasks]) * 1e3
    in_w = np.array([t.latency for t in pr.tasks
                     if window[0] <= t.arrival < window[1]]) * 1e3
    return {
        "p50_ms": float(np.percentile(lat, 50)),
        "p99_ms": float(np.percentile(lat, 99)),
        "p99_window_ms": (float(np.percentile(in_w, 99))
                          if in_w.size else float("nan")),
        "throughput_its": len(pr.tasks) / pr.makespan,
        "makespan_ms": pr.makespan * 1e3,
    }


def _row(graph, n_tiers, engine, storyline, variant, res, pr, rec,
         window) -> dict:
    att = attribute(rec, resources=chain_resources(
        pr.n_hops, pr.pool_sizes or None))
    causes = {label: {c: s * 1e3 for c, s in cs.items() if s > 0.0}
              for label, cs in att.by_label().items()}
    row = {
        "model": graph.name,
        "hops": n_tiers,
        "engine": engine,
        "storyline": storyline,
        "variant": variant,
        "n_tasks": len(pr.tasks),
        "window": list(window),
        "n_replans": res.n_replans,
        "n_migrations": res.n_migrations,
        "bubble_causes_ms": causes,
        "conservation_max_err_s": att.max_conservation_error(),
        "trace_match": True,
        "max_done_delta_s": res.max_done_delta,
    }
    row.update(_latency_stats(pr, window))
    return row


def _rows_for(graph, n_tiers, storyline, variant, res, window) -> list:
    pr_s, pr_a = res.sim, res.async_
    rec_s, rec_a = res.traces
    return [
        _row(graph, n_tiers, "sim", storyline, variant, res, pr_s,
             rec_s, window),
        _row(graph, n_tiers, "async", storyline, variant, res, pr_a,
             rec_a, window),
    ]


def run_degrade(graph, n_tiers: int, n_tasks: int = N_TASKS) -> list:
    """The gated storyline: static vs online-replanned ride through the
    same degradation window; the replanned variant must win p99 through
    the window at equal-or-better throughput."""
    devices, links = DEPLOYMENTS[n_tiers]
    versions, _ = replan_timeline(graph, devices, list(links),
                                  arrivals=[])
    period = versions[0].times.max_stage * ARRIVAL_SLACK
    t_deg, t_rec = WINDOW[0] * period, WINDOW[1] * period
    tl = Timeline([LinkShift(t_deg, 0, DEGRADED_MBPS),
                   LinkShift(t_rec, 0, links[0].bandwidth_bps / 1e6)],
                  horizon=(n_tasks + 5) * period)
    window = (t_deg, t_rec)

    res_s = run_chain_scenario(graph, devices, links, tl, n_tasks,
                               slack=ARRIVAL_SLACK, replan=False)
    res_r = run_chain_scenario(graph, devices, links, tl, n_tasks,
                               slack=ARRIVAL_SLACK, replan=True,
                               min_gap=MIN_GAP_PERIODS * period,
                               degraded_tx_scale=DEGRADED_TX_SCALE)
    assert res_r.n_replans >= 1, "degradation window went undetected"
    rows = (_rows_for(graph, n_tiers, "degrade", "static", res_s, window)
            + _rows_for(graph, n_tiers, "degrade", "replan", res_r,
                        window))
    # the bench asserts its own gate before emitting: online re-planning
    # must buy p99 through the window without giving up throughput
    p99_s = rows[0]["p99_window_ms"]
    p99_r = rows[2]["p99_window_ms"]
    assert p99_r < p99_s, \
        f"replan p99 {p99_r:.2f} ms not better than static {p99_s:.2f} ms"
    assert (rows[2]["throughput_its"]
            >= rows[0]["throughput_its"] * (1 - 1e-9)), \
        "replan gave up throughput"
    return rows


def run_churn(graph, n_tiers: int, n_tasks: int = N_TASKS) -> list:
    """The pinned (ungated) storyline: replica dropout/rejoin on
    replicated pools, availability-aware routing on both engines."""
    devices, links = DEPLOYMENTS[n_tiers]
    versions, _ = replan_timeline(graph, devices, list(links),
                                  arrivals=[])
    period = versions[0].times.max_stage * ARRIVAL_SLACK
    pools = [PoolSpec((1.0,) * POOL_SIZES[min(k, len(POOL_SIZES) - 1)])
             for k in range(n_tiers)]
    events = []
    for (tier, rep, d, u) in CHURN_EVENTS:
        if tier < n_tiers and rep < len(pools[tier].speeds):
            events += [ReplicaDown(d * period, tier, rep),
                       ReplicaUp(u * period, tier, rep)]
    tl = Timeline(events, horizon=(n_tasks + 5) * period)
    res = run_churn_scenario([versions[0].plan], tl, period, pools,
                             links=list(links), n_tasks=n_tasks)
    window = (CHURN_EVENTS[0][2] * period, CHURN_EVENTS[0][3] * period)
    return _rows_for(graph, n_tiers, "churn", "jsq-avail", res, window)


def run(out_dir=None, n_tasks: int = N_TASKS):
    rows = ["resilience,engine,model,hops,storyline,variant,replans,"
            "migrations,p99_window_ms,tput_its,delta_s"]
    payload = []
    for n_tiers in (2, 3):
        graph = resnet101()
        for r in (run_degrade(graph, n_tiers, n_tasks=n_tasks)
                  + run_churn(graph, n_tiers, n_tasks=n_tasks)):
            payload.append(r)
            rows.append(
                f"resilience,{r['engine']},{r['model']},{r['hops']},"
                f"{r['storyline']},{r['variant']},{r['n_replans']},"
                f"{r['n_migrations']},{r['p99_window_ms']:.2f},"
                f"{r['throughput_its']:.2f},{r['max_done_delta_s']:.2e}")
    if out_dir is not None:
        emit_pipeline_rows(out_dir, "resilience", payload)
    return rows


if __name__ == "__main__":
    print("\n".join(run(out_dir="experiments/bench")))
