"""Bubble-attribution bench: per-cause idle rows, pinned and gated.

ResNet101 is partitioned with the real offline planner onto the 2-tier
and 3-tier deployments (same device/link profiles as ``multihop``), then
three stream shapes exercise the attribution engine (``repro.obs``):

  config = "chain"   the plain steady stream (warmup/drain/starvation)
  config = "exits"   the hop-level semantic-exit cascade on the same
                     stream (adds ``exit_released`` bubbles)
  config = "pool"    every compute tier replicated 2x behind a JSQ
                     router (adds per-replica accounting and sequencer
                     reordering)

Every (model, hops, config) cell is traced by BOTH engines —
``engine = "sim"`` (``run_pipeline`` + ``TraceRecorder``) and
``engine = "async"`` (``run_pipeline_async`` on the virtual clock with
unbounded queues, the pinned regime) — and the bench itself asserts the
two span timelines agree at 1e-6 before emitting rows.  Each row carries
the full per-resource busy/cause decomposition plus the conservation
residual ``|busy + sum(bubbles) - horizon|``;
``benchmarks/validate_bench.py`` re-checks conservation from the row
payload alone and gates the tracing overhead: async rows report
``trace_overhead_pct``, the min-of-repeats wall-time cost of running the
executor with a live ``TraceRecorder`` vs ``sink=None`` (one
measurement per deployment, on an amplified chain stream — see
``_overhead_pct``), and the gate is < 5% (the disabled path is a single
``is not None`` test per event, so the enabled path has to stay cheap
too).
"""

from __future__ import annotations

import gc
import time

from benchmarks.bench_io import emit_pipeline_rows
from benchmarks.multihop import DEPLOYMENTS, decide_exit_hops
from repro.core.partitioner import coach_offline_multihop
from repro.core.pipeline import plan_from_stage_times, run_pipeline
from repro.models.cnn import resnet101
from repro.obs.bubbles import attribute, chain_resources
from repro.obs.trace import TraceRecorder, assert_traces_match
from repro.serving.async_engine import run_pipeline_async
from repro.serving.routing import make_router

N_TASKS = 160
ARRIVAL_SLACK = 1.05
ROUTER_SEED = 0
#: wall-clock repeats for the tracing-overhead measurement; min-of-N
#: rejects scheduler noise, which a CI runner has plenty of
OVERHEAD_REPEATS = 5
#: the overhead cell replays the chain stream this many times longer so
#: the ~1-2% tracing signal is not swamped by timer jitter on a ~30ms run
OVERHEAD_AMPLIFY = 4

CONFIGS = ("chain", "exits", "pool")


def _plans_for(config: str, st, n_tiers: int, n_tasks: int):
    if config == "exits":
        ehs = decide_exit_hops(n_tiers - 1, n_tasks)
        return [plan_from_stage_times(st, exit_hop=eh) for eh in ehs]
    return [plan_from_stage_times(st) for _ in range(n_tasks)]


def _run_traced(engine: str, plans, period, links, pools, router_name):
    rec = TraceRecorder()
    router = make_router(router_name, seed=ROUTER_SEED) if pools else None
    runner = run_pipeline if engine == "sim" else run_pipeline_async
    pr = runner(plans, arrival_period=period, links=list(links),
                pools=pools, router=router, sink=rec)
    return pr, rec


def _overhead_pct(plans, period, links) -> float:
    """Enabled-tracing wall overhead of the async executor, percent.

    One measurement per deployment, on an ``OVERHEAD_AMPLIFY``-times
    longer chain stream.  Three noise controls, each of which the ~2%
    signal needs: CPU time (``process_time``) instead of wall time so a
    preempted run does not read as overhead; the collector parked during
    each timed run — span emission allocates, and letting gen-0
    collections land inside one arm but not the other turns the signal
    into double-digit noise; and interleaved min-of-repeats (off, on,
    off, on, ...) after a discarded warmup pair so machine-load drift
    hits both arms alike.  Negative residual noise clamps to 0.
    """
    long_plans = list(plans) * OVERHEAD_AMPLIFY

    def once(sink):
        gc.collect()
        gc.disable()
        try:
            t0 = time.process_time()
            run_pipeline_async(long_plans, arrival_period=period,
                               links=list(links), sink=sink)
            return time.process_time() - t0
        finally:
            gc.enable()

    def estimate():
        once(None), once(TraceRecorder())      # warmup pair, discarded
        offs, ons = [], []
        for _ in range(OVERHEAD_REPEATS):
            offs.append(once(None))
            ons.append(once(TraceRecorder()))
        return max(0.0, (min(ons) - min(offs)) / min(offs) * 100.0)

    # keep the smallest of up to three estimates: both arms share every
    # systematic cost, so residual noise (frequency drift, CPU steal)
    # can only inflate an estimate, never shrink the true overhead out
    # of it — the smallest estimate is the most accurate one
    best = estimate()
    for _ in range(2):
        if best < 2.5:
            break
        best = min(best, estimate())
    return best


def _row(graph, n_tiers, engine, config, pools, pr, rec) -> dict:
    att = attribute(rec, resources=chain_resources(
        pr.n_hops, pr.pool_sizes or None))
    causes = {label: {c: s * 1e3 for c, s in cs.items() if s > 0.0}
              for label, cs in att.by_label().items()}
    return {
        "model": graph.name,
        "hops": n_tiers,
        "engine": engine,
        "config": config,
        "pool_sizes": list(pools) if pools else [1] * n_tiers,
        "makespan_ms": pr.makespan * 1e3,
        "horizon_ms": att.horizon_s * 1e3,
        "busy_ms": {lb: s * 1e3 for lb, s in att.busy_by_label().items()},
        "bubble_causes_ms": causes,
        "conservation_max_err_s": att.max_conservation_error(),
        "n_spans": len(rec),
        "trace_match": True,
    }


def run_deployment(graph, n_tiers: int, n_tasks: int = N_TASKS) -> list:
    devices, links = DEPLOYMENTS[n_tiers]
    off = coach_offline_multihop(graph, devices, links)
    st = off.times
    period = st.max_stage * ARRIVAL_SLACK
    overhead = _overhead_pct(
        _plans_for("chain", st, n_tiers, n_tasks), period, links)
    rows = []
    for config in CONFIGS:
        pools = [2] * n_tiers if config == "pool" else None
        router_name = "jsq" if pools else None
        plans = _plans_for(config, st, n_tiers, n_tasks)
        pr_s, rec_s = _run_traced("sim", plans, period, links, pools,
                                  router_name)
        pr_a, rec_a = _run_traced("async", plans, period, links, pools,
                                  router_name)
        # the differential pin, extended to span timelines (1e-6)
        assert_traces_match(rec_s, rec_a, tol=1e-6)
        row_s = _row(graph, n_tiers, "sim", config, pools, pr_s, rec_s)
        row_a = _row(graph, n_tiers, "async", config, pools, pr_a, rec_a)
        row_a["trace_overhead_pct"] = overhead
        rows += [row_s, row_a]
    return rows


def run(out_dir=None, n_tasks: int = N_TASKS):
    rows = ["bubbles,engine,model,hops,config,spans,cons_err,"
            "bubble_ms_total,overhead_pct"]
    payload = []
    for n_tiers in (2, 3):
        for r in run_deployment(resnet101(), n_tiers, n_tasks=n_tasks):
            payload.append(r)
            total = sum(s for cs in r["bubble_causes_ms"].values()
                        for s in cs.values())
            ov = r.get("trace_overhead_pct")
            rows.append(
                f"bubbles,{r['engine']},{r['model']},{r['hops']},"
                f"{r['config']},{r['n_spans']},"
                f"{r['conservation_max_err_s']:.2e},{total:.2f},"
                f"{'' if ov is None else f'{ov:.2f}'}")
    if out_dir is not None:
        emit_pipeline_rows(out_dir, "bubbles", payload)
    return rows


if __name__ == "__main__":
    print("\n".join(run(out_dir="experiments/bench")))
