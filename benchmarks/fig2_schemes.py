"""Fig. 2 sanity: the four schemes' bubble/throughput accounting.

Scheme 1: latency-min partition (stages 1,1,4 time units)
Scheme 2: bubble-min partition (3,1,3) — max stage 4 -> 3 (25% gain)
Scheme 3: + adaptive quantization       — max stage -> 2
Scheme 4: + early exits (temporal locality)
"""

from repro.core.pipeline import TaskPlan, run_pipeline


def run(out_dir=None):
    n = 200
    period = 0.0  # saturated stream: steady-state pipeline rates
    schemes = {
        "scheme1_latency_min": [TaskPlan(1, 1, 4)] * n,
        "scheme2_bubble_min": [TaskPlan(3, 1, 3)] * n,
        "scheme3_adaptive_quant": [TaskPlan(2, 2, 2)] * n,
        "scheme4_early_exit": [TaskPlan(2, 2, 2) if i % 2 else
                               TaskPlan(2, 0, 0, early_exit=True)
                               for i in range(n)],
    }
    rows = ["fig2,scheme,throughput,mean_latency,cloud_bubble_frac"]
    base = None
    for name, plans in schemes.items():
        r = run_pipeline(plans, arrival_period=period)
        if base is None:
            base = r.throughput
        rows.append(f"fig2,{name},{r.throughput:.3f},{r.mean_latency:.2f},"
                    f"{r.bubble_fraction('cloud'):.3f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
