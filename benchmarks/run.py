"""Benchmark harness: one module per paper table/figure.

  python -m benchmarks.run [--only table1,fig5] [--out experiments/bench]

Prints every module's CSV and writes it under --out.
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import (ablation, arch_partition, batching, bubbles,
                        calibration, fig1_locality, fig2_schemes,
                        fig5_dynamic, fig6_fig7_bandwidth, kernels_bench,
                        multihop, multitenant, planner, resilience,
                        roofline, routing, table1_latency, table2_context)

MODULES = {
    "fig1": fig1_locality,
    "fig2": fig2_schemes,
    "table1": table1_latency,
    "table2": table2_context,
    "fig5": fig5_dynamic,
    "fig67": fig6_fig7_bandwidth,
    "ablation": ablation,
    "arch_partition": arch_partition,
    "kernels": kernels_bench,    # us/call of the shared ops entry points
    "calibration": calibration,  # measured-vs-modeled stage times, gated
    # multihop + multitenant + planner merge their rows into one
    # canonical BENCH_pipeline.json via benchmarks.bench_io
    "multihop": multihop,        # 2-hop vs 3-hop paired sim/async rows
    "multitenant": multitenant,  # per-tenant fairness-vs-bubble rows
    "planner": planner,          # offline-search candidate throughput
    "batching": batching,        # micro-batched vs unbatched paired rows
    "routing": routing,          # replicated-tier throughput-vs-m sweeps
    "bubbles": bubbles,          # per-cause idle attribution, pinned+gated
    "resilience": resilience,    # churn/degrade storylines, replan gated
    "roofline": roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(MODULES))
    ap.add_argument("--out", default="experiments/bench")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(MODULES)
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    for name in names:
        t0 = time.time()
        rows = MODULES[name].run(out_dir=str(out))
        dt = time.time() - t0
        text = "\n".join(rows)
        print(text)
        print(f"# {name}: {dt:.1f}s")
        (out / f"{name}.csv").write_text(text + "\n")


if __name__ == "__main__":
    main()
