"""Prefill -> decode consistency for every decode-capable architecture
(exercises KV ring buffers, SSM state handoff, MoE decode grouping)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import model as M

DECODE_ARCHS = [a for a in ARCHS if get_config(a).supports_decode]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.num_experts:  # dropless so grouping differences don't bite
        cfg = dataclasses.replace(cfg, capacity_factor=100.0)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    B, S, MAX = 2, 33, 64
    if cfg.embed_inputs:
        x = jax.random.normal(key, (B, S, cfg.d_model)) * 0.5
    else:
        x = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    h, _, _ = M.forward(params, cfg, x)
    ref = M._lm_head(params, cfg, h[:, -1])
    logits_p, cache = M.prefill(params, cfg, x[:, :-1], MAX)
    out, cache2 = M.decode_step(params, cfg, cache, x[:, -1:], jnp.int32(S - 1))
    rel = float(jnp.max(jnp.abs(out - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < 1e-4, f"{arch}: rel={rel}"
    # prefill last logits match the forward at position S-2
    ref_p = M._lm_head(params, cfg, h[:, -2])
    # (prefill ran on x[:, :-1]; its own forward differs only by the last tok)
    assert logits_p.shape == (B, cfg.vocab_size)


@pytest.mark.parametrize("arch", ["gemma2-2b", "mixtral-8x7b", "mamba2-130m"])
def test_multi_step_decode(arch):
    """Greedy-decode 8 tokens; every step must match the growing forward."""
    cfg = get_config(arch).reduced()
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=100.0)
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key)
    B, S0, MAX = 1, 12, 64
    x = jax.random.randint(key, (B, S0), 0, cfg.vocab_size)
    _, cache = M.prefill(params, cfg, x, MAX)
    toks = x
    for t in range(8):
        nxt = jax.random.randint(jax.random.fold_in(key, t), (B, 1), 0,
                                 cfg.vocab_size)
        out, cache = M.decode_step(params, cfg, cache, nxt,
                                   jnp.int32(S0 + t))
        toks = jnp.concatenate([toks, nxt], axis=1)
        h, _, _ = M.forward(params, cfg, toks)
        ref = M._lm_head(params, cfg, h[:, -1])
        rel = float(jnp.max(jnp.abs(out - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
        assert rel < 2e-4, f"{arch} step {t}: rel={rel}"


def test_decode_beyond_sliding_window():
    """Ring buffers must stay correct once positions wrap the window."""
    cfg = get_config("h2o-danube-3-4b").reduced(sliding_window=16)
    key = jax.random.PRNGKey(2)
    params = M.init_params(cfg, key)
    B, S = 1, 40  # 2.5x window
    x = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    cache = M.init_cache(cfg, B, max_seq=S)
    outs = []
    for t in range(S):
        out, cache = M.decode_step(params, cfg, cache, x[:, t:t + 1],
                                   jnp.int32(t))
        outs.append(out)
    h, _, _ = M.forward(params, cfg, x)
    ref = M._lm_head(params, cfg, h)
    for t in (20, 30, 39):  # all beyond the window
        rel = float(jnp.max(jnp.abs(outs[t] - ref[:, t]))
                    / (jnp.max(jnp.abs(ref[:, t])) + 1e-9))
        assert rel < 2e-4, f"pos {t}: rel={rel}"


def test_greedy_generate_matches_full_forward():
    """serving.generate greedy continuation == argmax over fresh full
    forwards at every step (end-to-end decode-loop correctness)."""
    from repro.serving.generate import generate
    cfg = get_config("qwen3-14b").reduced()
    key = jax.random.PRNGKey(7)
    params = M.init_params(cfg, key)
    prompt = jax.random.randint(key, (2, 9), 0, cfg.vocab_size)
    out = generate(params, cfg, prompt, max_new_tokens=6, max_seq=32)
    assert out.shape == (2, 15)
    toks = prompt
    for _ in range(6):
        h, _, _ = M.forward(params, cfg, toks)
        nxt = jnp.argmax(M._lm_head(params, cfg, h[:, -1]), -1)[:, None]
        toks = jnp.concatenate([toks, nxt.astype(toks.dtype)], axis=1)
    assert bool(jnp.array_equal(out, toks)), (out, toks)
