"""Differential harness: the async hop-queue executor pinned to
``core.sim.simulate_stream``.

The async executor (one worker per ``2n+1`` resource, virtual clock,
unbounded hop queues) must reproduce the event simulator's timeline —
per-task completion times, per-resource busy time / intervals, bubble
fractions — to 1e-6, on the seed single-hop scenario, multi-hop chains,
and dynamic-bandwidth traces.  On top of that: decision determinism
(async == sync EngineStats), bounded-queue backpressure sanity, real
segment execution through worker handles, and the EngineConfig
mutable-default regression.
"""

import numpy as np
import pytest

from repro.core.costs import DeviceProfile, LinkProfile
from repro.core.pipeline import (TaskPlan, bandwidth_step_trace,
                                 plan_from_stage_times, run_pipeline)
from repro.core.schedule import PartitionDecision, StageTimes, \
    evaluate_partition
from repro.data.pipeline import (CorrelatedTaskStream, make_calibration_set,
                                 make_hop_calibration_sets)
from repro.serving.async_engine import (AsyncCoachEngine, AsyncHopPipeline,
                                        VirtualClock, run_pipeline_async)
from repro.serving.base import EngineConfig
from repro.serving.engine import CoachEngine

TOL = 1e-6

END = DeviceProfile("end", 1e9)
CLOUD = DeviceProfile("cloud", 8e9)


# ----------------------------------------------------------------- helpers
def _random_single_hop_plans(seed, n=40):
    rng = np.random.RandomState(seed)
    plans = []
    for _ in range(n):
        t_end = rng.uniform(1e-3, 5e-3)
        if rng.rand() < 0.2:
            plans.append(TaskPlan(t_end, 0.0, 0.0, True))
            continue
        t_tx = rng.uniform(0.5e-3, 4e-3)
        t_cloud = rng.uniform(1e-3, 5e-3)
        tx_off = rng.uniform(0, t_end) if rng.rand() < 0.5 else None
        cl_off = rng.uniform(0, t_tx) if rng.rand() < 0.5 else None
        plans.append(TaskPlan(t_end, t_tx, t_cloud,
                              tx_offset=tx_off, cloud_offset=cl_off))
    return plans


def _random_multihop_plans(seed, n=40, n_hops=2, hop_exits=True):
    """Random streams mixing full-pipeline tasks, classic end-device
    exits, and (for ``n_hops >= 2``) hop-level semantic exits at every
    intermediate segment."""
    rng = np.random.RandomState(seed)
    plans = []
    for _ in range(n):
        comp = rng.uniform(1e-3, 4e-3, n_hops + 1)
        tx = rng.uniform(0.2e-3, 3e-3, n_hops)
        if rng.rand() < 0.15:
            plans.append(TaskPlan(comp[0], 0.0, 0.0, True))
            continue
        txo = [rng.uniform(0, comp[k]) if rng.rand() < 0.5 else None
               for k in range(n_hops)]
        rxo = [rng.uniform(0, tx[k]) if rng.rand() < 0.5 else None
               for k in range(n_hops)]
        exit_hop = None
        if hop_exits and n_hops >= 2 and rng.rand() < 0.25:
            exit_hop = int(rng.randint(1, n_hops))  # mid-pipeline exit
        plans.append(TaskPlan.multihop(comp, tx, txo, rxo,
                                       exit_hop=exit_hop))
    return plans


def _assert_timelines_agree(pr_sim, pr_async, tol=TOL):
    assert abs(pr_sim.makespan - pr_async.makespan) < tol
    assert len(pr_sim.tasks) == len(pr_async.tasks)
    for a, b in zip(pr_sim.tasks, pr_async.tasks):
        assert a.id == b.id and a.early_exit == b.early_exit
        assert a.exit_hop == b.exit_hop, a.id
        assert abs(a.done - b.done) < tol, a.id
        assert abs(a.latency - b.latency) < tol, a.id
    assert len(pr_sim.compute_busy) == len(pr_async.compute_busy)
    for k in range(len(pr_sim.compute_busy)):
        assert abs(pr_sim.compute_busy[k] - pr_async.compute_busy[k]) < tol
        assert abs(pr_sim.bubble_fraction(("compute", k))
                   - pr_async.bubble_fraction(("compute", k))) < tol
    for k in range(len(pr_sim.link_busy_hops)):
        assert abs(pr_sim.link_busy_hops[k]
                   - pr_async.link_busy_hops[k]) < tol
        assert abs(pr_sim.bubble_fraction(("link", k))
                   - pr_async.bubble_fraction(("link", k))) < tol
    # raw busy intervals, resource by resource, task by task
    for ivs, ivr in zip(pr_sim.compute_intervals, pr_async.compute_intervals):
        assert len(ivs) == len(ivr)
        for (s0, e0), (s1, e1) in zip(ivs, ivr):
            assert abs(s0 - s1) < tol and abs(e0 - e1) < tol
    for ivs, ivr in zip(pr_sim.link_intervals, pr_async.link_intervals):
        assert len(ivs) == len(ivr)
        for (s0, e0), (s1, e1) in zip(ivs, ivr):
            assert abs(s0 - s1) < tol and abs(e0 - e1) < tol


# ----------------------------------------------------- differential: plans
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_differential_single_hop_seed_scenario(seed):
    """The n_hops = 1 seed scenario: executor == simulator to 1e-6."""
    plans = _random_single_hop_plans(seed)
    pr_sim = run_pipeline(plans, arrival_period=2.5e-3)
    pr_async = run_pipeline_async(plans, arrival_period=2.5e-3)
    _assert_timelines_agree(pr_sim, pr_async)


@pytest.mark.parametrize("seed", [0, 1])
def test_differential_single_hop_with_bandwidth_trace(seed):
    link = LinkProfile("dyn", 50e6, trace=bandwidth_step_trace(
        [(0.0, 50.0), (0.03, 8.0), (0.1, 80.0)]))
    plans = _random_single_hop_plans(seed + 10)
    pr_sim = run_pipeline(plans, arrival_period=2.5e-3, link=link)
    pr_async = run_pipeline_async(plans, arrival_period=2.5e-3, link=link)
    _assert_timelines_agree(pr_sim, pr_async)


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("n_hops", [2, 3])
def test_differential_multihop_chain(seed, n_hops):
    """3-tier and 4-tier chains (2/3 links), early exits included."""
    plans = _random_multihop_plans(seed, n_hops=n_hops)
    period = 2e-3
    pr_sim = run_pipeline(plans, arrival_period=period)
    pr_async = run_pipeline_async(plans, arrival_period=period)
    _assert_timelines_agree(pr_sim, pr_async)


def test_differential_multihop_with_traced_uplink():
    uplink = LinkProfile("dyn", 40e6, trace=bandwidth_step_trace(
        [(0.0, 40.0), (0.02, 6.0), (0.08, 60.0)]))
    backhaul = LinkProfile("bh", 900e6)
    plans = _random_multihop_plans(5, n_hops=2)
    pr_sim = run_pipeline(plans, arrival_period=2e-3,
                          links=[uplink, backhaul])
    pr_async = run_pipeline_async(plans, arrival_period=2e-3,
                                  links=[uplink, backhaul])
    _assert_timelines_agree(pr_sim, pr_async)


def test_differential_irregular_arrivals():
    rng = np.random.RandomState(42)
    plans = _random_multihop_plans(7, n_hops=2, n=30)
    arrivals = np.cumsum(rng.uniform(0, 4e-3, len(plans))).tolist()
    pr_sim = run_pipeline(plans, arrivals=arrivals)
    pr_async = run_pipeline_async(plans, arrivals=arrivals)
    _assert_timelines_agree(pr_sim, pr_async)


# ------------------------------------------------- hop-level semantic exit
def test_differential_exit_at_hop_1_of_3_hop_chain():
    """Acceptance: tasks exiting at hop 1 of a 3-hop chain — executor ==
    simulator at 1e-6, and the exit releases every downstream resource
    (links >= 1 and computes >= 2 never see the exited tasks)."""
    rng = np.random.RandomState(13)
    plans = []
    for i in range(36):
        comp = rng.uniform(1e-3, 4e-3, 4)
        tx = rng.uniform(0.2e-3, 3e-3, 3)
        plans.append(TaskPlan.multihop(
            comp, tx, exit_hop=1 if i % 3 == 0 else None))
    pr_sim = run_pipeline(plans, arrival_period=2e-3)
    pr_async = run_pipeline_async(plans, arrival_period=2e-3)
    _assert_timelines_agree(pr_sim, pr_async)
    n_exit = sum(1 for p in plans if p.exit_hop == 1)
    assert n_exit > 0
    for pr in (pr_sim, pr_async):
        assert pr.exit_hop_counts() == {1: n_exit}
        # exited tasks occupy compute 0-1 and link 0 only
        assert len(pr.compute_intervals[0]) == len(plans)
        assert len(pr.compute_intervals[1]) == len(plans)
        assert len(pr.link_intervals[0]) == len(plans)
        for k in (2, 3):
            assert len(pr.compute_intervals[k]) == len(plans) - n_exit
        for k in (1, 2):
            assert len(pr.link_intervals[k]) == len(plans) - n_exit


def test_exit_hop_releases_downstream_and_cuts_bubbles():
    """The point of hop-level exit: on a stream where half the tasks
    terminate at the edge tier, the cloud's busy time drops by exactly
    the exited tasks' cloud occupation, and every exited task finishes
    no later than its full-pipeline twin."""
    comp, tx = (2e-3, 1.5e-3, 2e-3), (1e-3, 1e-3)
    full = [TaskPlan.multihop(comp, tx) for _ in range(40)]
    mixed = [TaskPlan.multihop(comp, tx, exit_hop=1 if i % 2 else None)
             for i in range(40)]
    pf = run_pipeline(full, arrival_period=2.2e-3)
    pm = run_pipeline(mixed, arrival_period=2.2e-3)
    n_exit = sum(1 for p in mixed if p.exit_hop is not None)
    assert abs(pf.compute_busy[2] - pm.compute_busy[2]
               - n_exit * comp[2]) < TOL
    assert abs(pf.link_busy_hops[1] - pm.link_busy_hops[1]
               - n_exit * tx[1]) < TOL
    for a, b in zip(pf.tasks, pm.tasks):
        assert b.done <= a.done + TOL
    assert pm.makespan < pf.makespan - TOL


# ------------------------------------------- overlap on a benchmark stream
def test_async_overlap_on_two_tier_benchmark_stream():
    """2-hop (end->cloud) stream from a real model cost graph: the
    executor overlaps stages (makespan < serial latency sum) and still
    matches the simulator to 1e-6."""
    from repro.models.cnn import vgg16

    g = vgg16()
    n = len(g)
    cut = n // 2
    dec = PartitionDecision(frozenset(range(cut)), {(cut - 1, cut): 8})
    link = LinkProfile("wifi", 50e6)
    st = evaluate_partition(g, dec, DeviceProfile("jetson", 3.5e12),
                            DeviceProfile("a6000", 25e12), link)
    plans = [plan_from_stage_times(st) for _ in range(40)]
    period = st.max_stage * 1.05
    pr_async = run_pipeline_async(plans, arrival_period=period,
                                  links=[link])
    serial_sum = sum(t.latency for t in pr_async.tasks)
    assert pr_async.makespan < serial_sum - TOL, \
        "no stage overlap: executor is serializing tasks"
    pr_sim = run_pipeline(plans, arrival_period=period, links=[link])
    _assert_timelines_agree(pr_sim, pr_async)


# --------------------------------------------------- bounded-queue policy
def test_bounded_queues_complete_in_order_with_backpressure():
    plans = _random_multihop_plans(3, n_hops=2, n=30)
    free = run_pipeline_async(plans, arrival_period=0.0)
    tight = run_pipeline_async(plans, arrival_period=0.0, queue_capacity=1)
    # every task completes, in admission order on the final resource
    ids = [t.id for t in tight.tasks]
    assert ids == sorted(ids) and len(ids) == len(plans)
    full_done = [t.done for t in tight.tasks if not t.early_exit]
    assert full_done == sorted(full_done)
    # backpressure can only delay completion, never accelerate it
    assert tight.makespan >= free.makespan - TOL
    for a, b in zip(free.tasks, tight.tasks):
        assert b.done >= a.done - TOL


@pytest.mark.slow
def test_wall_clock_driver_smoke():
    """WallClock is the only driver without a differential pin (real
    scheduling jitter makes exact times unreproducible); this real-time
    smoke run asserts the *completion set* — every task id, its early-
    exit flag, and full-pipeline completion order — matches a
    VirtualClock run of the same stream (~100 ms of wall time)."""
    from repro.serving.async_engine import WallClock

    plans = _random_multihop_plans(9, n_hops=2, n=16)
    arrivals = [i * 1.5e-3 for i in range(len(plans))]
    ref = run_pipeline_async(plans, arrivals=arrivals)
    wall = run_pipeline_async(plans, arrivals=arrivals, clock=WallClock())
    assert [t.id for t in wall.tasks] == [t.id for t in ref.tasks]
    assert [t.early_exit for t in wall.tasks] == \
        [t.early_exit for t in ref.tasks]
    # per-resource interval counts match (every task visited every
    # resource it was planned to)
    for ivw, ivr in zip(wall.compute_intervals, ref.compute_intervals):
        assert len(ivw) == len(ivr)
    for ivw, ivr in zip(wall.link_intervals, ref.link_intervals):
        assert len(ivw) == len(ivr)
    # full-pipeline tasks complete in admission order on the wall clock
    full = [t.done for t in wall.tasks if not t.early_exit]
    assert full == sorted(full)
    assert wall.makespan > 0


def test_virtual_clock_deadlock_detected():
    clock = VirtualClock()

    async def main():
        from repro.serving.async_engine import HopQueue
        q = HopQueue(clock)
        w = clock.spawn(q.get())   # nothing will ever put
        import asyncio
        await asyncio.gather(w)

    with pytest.raises(RuntimeError, match="deadlock"):
        clock.run(main())


# --------------------------------------------- decisions: async == sync
def _mk_engines(n_hops, seed=0, hop_exit=False, **cfg_kw):
    if n_hops == 1:
        st = StageTimes(T_e=2e-3, T_t=3e-3, T_c=2e-3, T_t_par=0,
                        T_c_par=0, latency=7e-3, first_tx_offset=2e-3,
                        cloud_start_offset=3e-3)
        links = None
    else:
        st = StageTimes(
            T_e=2e-3, T_t=4e-3, T_c=2e-3, T_t_par=0.0, T_c_par=0.0,
            latency=9e-3, first_tx_offset=2e-3, cloud_start_offset=3e-3,
            compute=(2e-3, 1.5e-3, 2e-3), link=(3e-3, 1e-3),
            link_par=(0.0, 0.0), compute_par=(0.0, 0.0),
            tx_offsets=(2e-3, 1.5e-3), rx_offsets=(3e-3, 1e-3))
        links = [LinkProfile("uplink", 20e6), LinkProfile("backhaul", 900e6)]
    depths = n_hops if hop_exit else 1
    stream = CorrelatedTaskStream(n_labels=30, dim=48,
                                  correlation="medium", seed=seed,
                                  n_probe_depths=depths)
    hop_calib = None
    if hop_exit:
        sets = make_hop_calibration_sets(stream, 400, n_depths=n_hops)
        feats, labels = sets[0]
        hop_calib = sets[1:]
    else:
        feats, labels = make_calibration_set(stream, 400)
    mk = lambda cls, cfg: cls(
        None, st, END, LinkProfile("wifi", 20e6), CLOUD, n_labels=30,
        calib_feats=feats, calib_labels=labels, boundary_elems=50_000,
        links=links, cfg=cfg, hop_calib=hop_calib)
    sync = mk(CoachEngine, None)
    async_ = mk(AsyncCoachEngine, EngineConfig(**cfg_kw) if cfg_kw else None)
    return sync, async_, stream


def _classify(stream):
    def f(task):
        d = np.linalg.norm(stream.mu - task.features[None], axis=1)
        feats = task.hop_features if task.hop_features is not None \
            else task.features
        return feats, int(np.argmin(d))
    return f


@pytest.mark.parametrize("n_hops", [1, 2])
def test_async_engine_decisions_identical_to_sync(n_hops):
    """Concurrency never changes decisions, only timing: a seeded stream
    yields identical EngineStats decision aggregates."""
    sync, async_, stream = _mk_engines(n_hops, seed=4)
    tasks = stream.tasks(300)
    s = sync.run_stream(list(tasks), arrival_period=3e-3,
                        classify=_classify(stream))
    a = async_.run_stream(list(tasks), arrival_period=3e-3,
                          classify=_classify(stream))
    assert a.exit_ratio == s.exit_ratio
    assert a.mean_bits == s.mean_bits
    assert a.accuracy == s.accuracy


@pytest.mark.parametrize("n_hops", [1, 2])
def test_async_engine_timeline_matches_sync_reference(n_hops):
    """With per-hop retiming off and unbounded queues the async engine's
    virtual-clock timeline equals the sync engine's simulated one."""
    sync, async_, stream = _mk_engines(
        n_hops, seed=6, per_hop_bits=False, queue_capacity=0)
    tasks = stream.tasks(250)
    s = sync.run_stream(list(tasks), arrival_period=3e-3,
                        classify=_classify(stream))
    a = async_.run_stream(list(tasks), arrival_period=3e-3,
                          classify=_classify(stream))
    _assert_timelines_agree(s.pipeline, a.pipeline)
    assert abs(a.wire_kb_per_task - s.wire_kb_per_task) < 1e-9


def test_hop_exit_engine_decisions_identical_sync_async():
    """With per-hop probes calibrated, a seeded stream exits tasks at the
    intermediate tier — and the sync and async engines still make bit-
    identical decisions (exit hops included)."""
    sync, async_, stream = _mk_engines(2, seed=4, hop_exit=True)
    tasks = stream.tasks(300)
    s = sync.run_stream(list(tasks), arrival_period=3e-3,
                        classify=_classify(stream))
    a = async_.run_stream(list(tasks), arrival_period=3e-3,
                          classify=_classify(stream))
    assert a.exit_ratio == s.exit_ratio
    assert a.mean_bits == s.mean_bits
    assert a.accuracy == s.accuracy
    assert a.exit_hops == s.exit_hops
    # the new axis is real: some tasks exited at the edge tier (hop 1),
    # on top of the classic end-device exits
    assert s.exit_hops.get(1, 0) > 0, s.exit_hops
    assert s.exit_hops.get(0, 0) > 0, s.exit_hops


def test_hop_exit_engine_timeline_matches_sync_reference():
    """Acceptance (engine level): with hop probes active, per-hop
    retiming off and unbounded queues, the async engine's virtual-clock
    timeline — mid-pipeline exits included — equals the sync engine's
    simulated one at 1e-6."""
    sync, async_, stream = _mk_engines(
        2, seed=6, hop_exit=True, per_hop_bits=False, queue_capacity=0)
    tasks = stream.tasks(250)
    s = sync.run_stream(list(tasks), arrival_period=3e-3,
                        classify=_classify(stream))
    a = async_.run_stream(list(tasks), arrival_period=3e-3,
                          classify=_classify(stream))
    _assert_timelines_agree(s.pipeline, a.pipeline)
    assert abs(a.wire_kb_per_task - s.wire_kb_per_task) < 1e-9
    assert s.pipeline.exit_hop_counts().get(1, 0) > 0


def test_hop_exit_engine_releases_downstream_resources():
    """Engine level resource release: the cloud serves exactly the tasks
    no probe exited, the backhaul carries exactly those too, and the
    uplink additionally carries the hop-1 exits (they were transmitted
    once, then terminated at the edge tier)."""
    _, hop, stream = _mk_engines(2, seed=11, hop_exit=True,
                                 per_hop_bits=False)
    n = 250
    tasks = stream.tasks(n)
    h = hop.run_stream(list(tasks), arrival_period=3e-3,
                       classify=_classify(stream))
    e0 = h.exit_hops.get(0, 0)
    e1 = h.exit_hops.get(1, 0)
    assert e0 > 0 and e1 > 0, h.exit_hops
    pr = h.pipeline
    assert len(pr.compute_intervals[0]) == n
    assert len(pr.link_intervals[0]) == n - e0
    assert len(pr.compute_intervals[1]) == n - e0
    assert len(pr.link_intervals[1]) == n - e0 - e1
    assert len(pr.compute_intervals[2]) == n - e0 - e1


def test_async_engine_per_hop_bits_retimes_inner_hop():
    """With per-hop adaptive bits on, the inner hop's occupation follows
    its own (fast backhaul) EMA instead of the offline-planned time:
    Eq. 11 fills the idle backhaul up toward the adjacent compute ceiling
    with extra precision (free accuracy margin), so the hop-1 busy time
    moves off the planned value, toward ``n_full * ceiling``."""
    _, async_, stream = _mk_engines(2, seed=8, queue_capacity=0)
    st = async_.st
    tasks = stream.tasks(200)
    a = async_.run_stream(list(tasks), arrival_period=3e-3,
                          classify=_classify(stream))
    n_full = sum(1 for t in a.pipeline.tasks if not t.early_exit)
    assert n_full > 0
    planned = n_full * st.link[1]
    ceiling = max(st.compute[1], st.compute[2])
    got = a.pipeline.link_busy_hops[1]
    assert abs(got - planned) > TOL, "inner hop was not retimed"
    # retimed occupation chases the per-hop Eq. 11 target
    assert abs(got - n_full * ceiling) < n_full * ceiling * 0.35


def test_hop_elems_priced_at_offline_precision():
    """Regression: the inner hop's element count must be derived from the
    offline partition's per-hop precision, not ``cfg.default_bits`` — a
    4-bit offline boundary at the same planned link time carries twice
    the elements of an 8-bit one."""
    _, eight, stream = _mk_engines(2, seed=1)
    four = AsyncCoachEngine(
        None, eight.st, END, eight.links[0], CLOUD, n_labels=30,
        calib_feats=stream.mu.astype(np.float32),
        calib_labels=np.arange(30), boundary_elems=50_000,
        links=eight.links, hop_bits_offline=(8, 4))
    assert four.sched.hop_elems[1] == 2 * eight.sched.hop_elems[1]
    # hop 0 stays the boundary feature count either way
    assert four.sched.hop_elems[0] == eight.sched.hop_elems[0] == 50_000


# ----------------------------------------------- real compute in workers
def test_segment_handles_execute_real_model_through_workers():
    """CollabRuntime segment handles invoked by the compute workers yield
    the same logits as the monolithic multi-hop forward."""
    jax = pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.core.collab import CollabRuntime
    from repro.models import model as M

    cfg = get_config("gemma2-2b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rt = CollabRuntime(cfg, params, cut_group=1)
    xs = [jax.random.randint(jax.random.PRNGKey(i), (1, 8), 0,
                             cfg.vocab_size) for i in range(3)]
    handles = [rt.segment_handle(k) for k in range(rt.n_segments)]
    plans = [TaskPlan(1e-3, 1e-3, 1e-3) for _ in xs]
    pipe = AsyncHopPipeline(
        1, clock=VirtualClock(),
        segment_fn=lambda k, idx, payload: handles[k](payload))
    res = pipe.run(lambda i, _arr: plans[i].as_sim_plan(1), len(xs),
                   [0.0, 1e-3, 2e-3], payloads=xs)
    assert not any(res.early_exit)
    for i, x in enumerate(xs):
        ref, _ = rt.run(x)
        np.testing.assert_allclose(np.asarray(pipe.outputs[i]),
                                   np.asarray(ref), rtol=1e-5, atol=1e-5)


# --------------------------------------------- EngineConfig regression
def test_engine_config_default_is_not_shared():
    """Regression: ``cfg`` used to default to a single module-level
    ``EngineConfig()`` instance shared by every engine, so mutating one
    engine's config silently reconfigured all others."""
    stream = CorrelatedTaskStream(n_labels=5, dim=16, seed=0)
    feats, labels = make_calibration_set(stream, 50)
    st = StageTimes(T_e=1e-3, T_t=1e-3, T_c=1e-3, T_t_par=0, T_c_par=0,
                    latency=3e-3, first_tx_offset=1e-3,
                    cloud_start_offset=1e-3)
    mk = lambda: CoachEngine(None, st, END, LinkProfile("l", 1e7), CLOUD,
                             n_labels=5, calib_feats=feats,
                             calib_labels=labels, boundary_elems=100)
    e1, e2 = mk(), mk()
    assert e1.cfg is not e2.cfg
    e1.cfg.default_bits = 3
    assert e2.cfg.default_bits == 8
    # and the dataclass default itself was never mutated
    assert EngineConfig().default_bits == 8
