"""Checkpoint IO: roundtrip (incl. bf16, nested tuples), latest_step."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.models import model as M


def test_roundtrip_model_params(tmp_path):
    cfg = get_config("gemma2-2b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16)
    save_checkpoint(str(tmp_path), 7, params)
    assert latest_step(str(tmp_path)) == 7
    like = jax.tree.map(jnp.zeros_like, params)
    restored = load_checkpoint(str(tmp_path), 7, like)
    ok = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)), params,
                      restored)
    assert all(jax.tree.leaves(ok))
    assert jax.tree.leaves(restored)[0].dtype == jnp.bfloat16


def test_multiple_steps_and_overwrite(tmp_path):
    tree = {"a": jnp.arange(5.0), "b": (jnp.ones((2, 2)),)}
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 2, jax.tree.map(lambda x: x * 2, tree))
    assert latest_step(str(tmp_path)) == 2
    r = load_checkpoint(str(tmp_path), 2, tree)
    np.testing.assert_allclose(r["a"], np.arange(5.0) * 2)
    # overwrite same step
    save_checkpoint(str(tmp_path), 2, tree)
    r = load_checkpoint(str(tmp_path), 2, tree)
    np.testing.assert_allclose(r["a"], np.arange(5.0))
