"""Scenario engine (repro.scenarios): timeline compilation, the
dual-engine pin across dynamic regimes, and plan-migration invariants.

The load-bearing properties:

* a zero-dynamics timeline is *bit-identical* to the plain static run
  on both engines (the scenario layer adds no arithmetic),
* the 1e-6 differential pin holds across a mid-stream regime shift with
  online re-planning and in-flight migration (and is in fact bit-exact
  here),
* migration conserves work: every task completes exactly once, every
  resource's busy + attributed bubbles tile the horizon (including the
  ``replanning`` cause), and both engines migrate the same tasks.
"""

import math

import pytest

from repro.core.costs import (A6000_SERVER, JETSON_NX, LinkProfile,
                              WIFI_5GHZ)
from repro.core.pipeline import run_pipeline
from repro.core.sim import PoolSpec
from repro.models.cnn import vgg16
from repro.obs.bubbles import REPLANNING, attribute, chain_resources
from repro.scenarios import (LinkShift, LoadScale, ReplicaDown, ReplicaUp,
                             TenantArrive, TenantDepart, Timeline,
                             run_chain_scenario, run_churn_scenario)
from repro.scenarios.replan import (PlanSchedule, PlanVersion,
                                    RegimeDetector, replan_timeline)

DEVICES = (JETSON_NX, A6000_SERVER)
LINKS = (WIFI_5GHZ(50.0),)


@pytest.fixture(scope="module")
def base():
    """Shared base plan + period for the 2-tier vgg16 deployment."""
    graph = vgg16()
    versions, _ = replan_timeline(graph, DEVICES, list(LINKS),
                                  arrivals=[])
    period = versions[0].times.max_stage * 1.05
    return graph, versions[0], period


# ------------------------------------------------------------ compilation
def test_timeline_link_profiles_only_trace_shifted_hops():
    tl = Timeline([LinkShift(1.0, 0, 10.0)], horizon=5.0)
    nominal = [LinkProfile("a", 50e6), LinkProfile("b", 400e6)]
    out = tl.link_profiles(nominal)
    assert out[0].trace is not None and out[1] is nominal[1]
    assert out[0].bps_at(0.5) == 50e6 and out[0].bps_at(1.5) == 10e6


def test_timeline_availability_windows():
    tl = Timeline([ReplicaDown(1.0, 0, 1), ReplicaUp(2.0, 0, 1),
                   ReplicaDown(3.0, 1, 0)], horizon=4.0)
    av = tl.availability()
    assert av[(0, 1)] == [(1.0, 2.0)]
    assert av[(1, 0)] == [(3.0, 4.0)]  # no rejoin: down to horizon


def test_timeline_load_scale_changes_arrival_density():
    tl = Timeline([LoadScale(1.0, 2.0)], horizon=4.0)
    arr = tl.arrivals(0.5)
    # 0.5 s spacing before t=1, 1.0 s spacing after
    assert arr[:3] == [0.0, 0.5, 1.0]
    assert arr[3] - arr[2] == pytest.approx(1.0)


def test_timeline_tenant_streams():
    tl = Timeline([TenantArrive(0.0, 0, 1.0), TenantArrive(2.0, 1, 0.5),
                   TenantDepart(4.0, 1)], horizon=6.0)
    per = tl.tenant_arrivals()
    assert per[0][0] == 0.0 and len(per[0]) == 6
    assert per[1][0] == 2.0 and all(t < 4.0 for t in per[1])


# ---------------------------------------------------------- zero dynamics
def test_zero_dynamics_bit_identical_to_static(base):
    graph, v0, period = base
    n = 30
    tl0 = Timeline([], horizon=(n + 5) * period)
    res = run_chain_scenario(graph, DEVICES, LINKS, tl0, n_tasks=n)
    assert res.n_replans == 0 and res.n_migrations == 0
    assert res.max_done_delta == 0.0
    direct = run_pipeline([v0.plan] * n, arrivals=tl0.arrivals(period, n),
                          links=[LINKS[0]])
    for pr in (res.sim, res.async_):
        assert all(a.done == b.done
                   for a, b in zip(direct.tasks, pr.tasks))


# ----------------------------------------------- regime shift + migration
@pytest.fixture(scope="module")
def degraded(base):
    graph, _v0, period = base
    n = 90
    tl = Timeline([LinkShift(20 * period, 0, 12.0),
                   LinkShift(60 * period, 0, 50.0)],
                  horizon=(n + 5) * period)
    res = run_chain_scenario(graph, DEVICES, LINKS, tl, n_tasks=n,
                             min_gap=10 * period, degraded_tx_scale=0.5)
    return res


def test_pin_holds_across_regime_shift(degraded):
    res = degraded
    assert res.n_replans >= 1 and res.n_migrations >= 1
    assert res.max_done_delta <= 1e-6  # run_dual asserts this too


def test_migration_conserves_tasks_and_horizon(degraded):
    res = degraded
    ids_s = sorted(t.id for t in res.sim.tasks)
    ids_a = sorted(t.id for t in res.async_.tasks)
    assert ids_s == ids_a == list(range(len(ids_s)))  # once each, no loss
    for rec in res.traces:
        att = attribute(rec, resources=chain_resources(res.sim.n_hops))
        assert att.max_conservation_error() <= 1e-9
        causes = {c for cs in att.by_label().values() for c in cs}
        assert REPLANNING in causes


def test_replanned_variant_beats_static_in_window(base, degraded):
    graph, _v0, period = base
    n = 90
    tl = Timeline([LinkShift(20 * period, 0, 12.0),
                   LinkShift(60 * period, 0, 50.0)],
                  horizon=(n + 5) * period)
    static = run_chain_scenario(graph, DEVICES, LINKS, tl, n_tasks=n,
                                replan=False)
    lo, hi = 20 * period, 60 * period

    def p99(pr):
        lat = sorted(t.latency for t in pr.tasks
                     if lo <= t.arrival < hi)
        return lat[min(len(lat) - 1, int(math.ceil(0.99 * len(lat))))]

    assert p99(degraded.sim) < p99(static.sim)


# ------------------------------------------------------------------ churn
def test_churn_scenario_pinned_on_pools(base):
    graph, v0, period = base
    pools = [PoolSpec((1.0, 1.0)), PoolSpec((1.0, 1.0, 1.0))]
    tl = Timeline([ReplicaDown(10 * period, 1, 0),
                   ReplicaUp(40 * period, 1, 0),
                   ReplicaDown(20 * period, 0, 1),
                   ReplicaUp(35 * period, 0, 1)],
                  horizon=70 * period)
    res = run_churn_scenario([v0.plan], tl, period, pools,
                             links=[LINKS[0]], n_tasks=60)
    assert res.max_done_delta <= 1e-6
    assert len(res.sim.tasks) == 60


# ------------------------------------------------------ schedule invariants
def test_plan_schedule_splices_relative_to_admission(base):
    _graph, v0, _period = base
    n_hops = len(LINKS) + 1
    base_v = PlanVersion(-math.inf, v0.plan, (1.0,) * v0.times.n_hops)
    late_v = PlanVersion(0.5, v0.plan, (0.5,) * v0.times.n_hops)
    sched = PlanSchedule([base_v, late_v], arrivals=[0.0, 0.7],
                         n_hops=n_hops)
    # task 0 admitted under v0: migrating at t=0.6 halves its volumes
    p0 = sched(0, 0, 0.6)
    assert p0.tx[0] == pytest.approx(sched.sim_plans[0].tx[0] * 0.5)
    # consulted again: no further migration (version already applied)
    assert sched(0, 0, 0.8) is None
    # task 1 admitted under the late version: nothing to migrate to
    assert sched(1, 0, 0.9) is None
    assert sched.n_migrations == 1
    sched.reset()
    assert sched.n_migrations == 0 and sched(0, 0, 0.6) is not None


def test_regime_detector_drift_and_rebase():
    det = RegimeDetector([50e6], alpha=0.5, threshold=0.25)
    assert not det.observe(0, 50e6)
    assert det.observe(0, 12e6)  # ema 31e6, drift 19e6 > 12.5e6
    det.rebase()
    assert not det.observe(0, det.ema[0])  # re-based: no drift at ema
