"""Non-divisible block-size handling of the fused semantic-probe kernel.

The batch / sequence axes are zero-padded up to block multiples and the
pad rows sliced off; the GAP epilogue divides by the *true* sequence
length, so padding must be bit-exact against both the unpadded kernel
and the pure-jnp oracle.  (Lives outside test_kernels.py so it also runs
where hypothesis — which test_kernels imports — is unavailable.)
"""

import jax
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.semantic_cache import semantic_probe


@pytest.mark.parametrize("B,S,D,L", [
    (6, 100, 128, 10),    # B % 8 != 0, S % 512 != 0
    (13, 700, 64, 7),     # both axes ragged, odd batch
    (1, 1, 32, 3),        # degenerate single-row, single-step
    (8, 512, 64, 5),      # exactly divisible control
])
def test_semantic_probe_padded_matches_ref(B, S, D, L):
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, D))
    c = jax.random.normal(jax.random.PRNGKey(1), (L, D))
    sep, best, sims = semantic_probe(x, c, interpret=True)
    assert sep.shape == (B,) and best.shape == (B,) and sims.shape == (B, L)
    sep_r, best_r, sims_r = ref.semantic_probe_ref(x, c)
    np.testing.assert_array_equal(best, best_r)
    np.testing.assert_allclose(sims, sims_r, atol=1e-5)
    np.testing.assert_allclose(sep, sep_r, rtol=1e-4, atol=1e-5)


def test_semantic_probe_padding_is_exact():
    """Padding must not perturb the unpadded rows: a ragged batch equals
    the same rows probed with block sizes that divide evenly."""
    x = jax.random.normal(jax.random.PRNGKey(2), (10, 96, 64))
    c = jax.random.normal(jax.random.PRNGKey(3), (6, 64))
    sep_a, best_a, sims_a = semantic_probe(x, c, block_b=8, block_s=512,
                                           interpret=True)
    sep_b, best_b, sims_b = semantic_probe(x, c, block_b=2, block_s=32,
                                           interpret=True)
    np.testing.assert_allclose(sep_a, sep_b, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(best_a, best_b)
    np.testing.assert_allclose(sims_a, sims_b, rtol=1e-5, atol=1e-6)
