"""Per-architecture smoke tests: REDUCED same-family variants (<=2 groups,
d_model<=256, <=4 experts) run one forward + one train step on CPU and
assert output shapes + finite values.  The FULL configs are exercised only
through the dry-run (abstract, no allocation)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, all_pairs, get_config, shape_supported
from repro.launch import steps as ST
from repro.models import model as M
from repro.training.optim import AdamWConfig, adamw_init


@pytest.fixture(scope="module", params=sorted(ARCHS))
def arch_setup(request):
    cfg = get_config(request.param).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return request.param, cfg, params


def _batch(cfg, B=2, S=32, key=jax.random.PRNGKey(1)):
    labels = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.embed_inputs:
        return {"embeds": jax.random.normal(key, (B, S, cfg.d_model)) * 0.3,
                "labels": labels}
    return {"tokens": labels, "labels": labels}


def test_full_config_matches_assignment(arch_setup):
    arch, _, _ = arch_setup
    full = get_config(arch)
    spec = {
        "mamba2-130m": dict(num_layers=24, d_model=768, vocab_size=50280),
        "qwen2-vl-2b": dict(num_layers=28, d_model=1536, num_heads=12,
                            num_kv_heads=2, d_ff=8960, vocab_size=151936),
        "llama4-scout-17b-a16e": dict(num_layers=48, d_model=5120,
                                      num_heads=40, num_kv_heads=8,
                                      d_ff=8192, vocab_size=202048,
                                      num_experts=16, experts_per_token=1),
        "jamba-1.5-large-398b": dict(num_layers=72, d_model=8192,
                                     num_heads=64, num_kv_heads=8,
                                     d_ff=24576, vocab_size=65536,
                                     num_experts=16, experts_per_token=2),
        "gemma2-2b": dict(num_layers=26, d_model=2304, num_heads=8,
                          num_kv_heads=4, d_ff=9216, vocab_size=256000),
        "h2o-danube-3-4b": dict(num_layers=24, d_model=3840, num_heads=32,
                                num_kv_heads=8, d_ff=10240, vocab_size=32000),
        "gemma-7b": dict(num_layers=28, d_model=3072, num_heads=16,
                         num_kv_heads=16, d_ff=24576, vocab_size=256000),
        "mixtral-8x7b": dict(num_layers=32, d_model=4096, num_heads=32,
                             num_kv_heads=8, d_ff=14336, vocab_size=32000,
                             num_experts=8, experts_per_token=2),
        "hubert-xlarge": dict(num_layers=48, d_model=1280, num_heads=16,
                              num_kv_heads=16, d_ff=5120, vocab_size=504),
        "qwen3-14b": dict(num_layers=40, d_model=5120, num_heads=40,
                          num_kv_heads=8, d_ff=17408, vocab_size=151936),
    }[arch]
    for k, v in spec.items():
        assert getattr(full, k) == v, (arch, k)
    assert full.citation


def test_forward_shapes_and_finite(arch_setup):
    arch, cfg, params = arch_setup
    b = _batch(cfg)
    inputs = b.get("tokens", b.get("embeds"))
    h, _, aux = M.forward(params, cfg, inputs)
    assert h.shape == (2, 32, cfg.d_model)
    logits = M._lm_head(params, cfg, h)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_one_train_step(arch_setup):
    arch, cfg, params = arch_setup
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    step = jax.jit(ST.make_train_step(cfg, opt_cfg))
    opt = adamw_init(params, opt_cfg)
    b = _batch(cfg)
    p2, o2, loss, mets = step(params, opt, b)
    assert bool(jnp.isfinite(loss))
    # params actually moved
    moved = jax.tree.map(lambda a, b_: float(jnp.max(jnp.abs(a - b_))),
                         params, p2)
    assert max(jax.tree.leaves(moved)) > 0


def test_microbatched_step_close_to_full(arch_setup):
    arch, cfg, params = arch_setup
    if cfg.num_experts:  # capacity drops differ between groupings
        cfg = dataclasses.replace(cfg, capacity_factor=100.0)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    b = _batch(cfg, B=4)
    opt = adamw_init(params, opt_cfg)
    _, _, l1, _ = jax.jit(ST.make_train_step(cfg, opt_cfg))(params, opt, b)
    _, _, l2, _ = jax.jit(ST.make_train_step(cfg, opt_cfg, microbatches=2))(
        params, opt, b)
    assert abs(float(l1) - float(l2)) < 5e-2


def test_pair_matrix_counts():
    pairs = all_pairs()
    runnable = [p for p in pairs if p[2]]
    skipped = [p for p in pairs if not p[2]]
    assert len(pairs) == 40
    assert len(runnable) == 35
    assert {(a, s) for a, s, _, _ in skipped} == {
        ("qwen2-vl-2b", "long_500k"), ("gemma-7b", "long_500k"),
        ("qwen3-14b", "long_500k"), ("hubert-xlarge", "decode_32k"),
        ("hubert-xlarge", "long_500k")}


def test_batch_chunked_prefill_identical():
    """lax.map-chunked prefill must return identical logits and caches."""
    import numpy as np
    from repro.launch import steps as ST
    cfg = get_config("gemma2-2b").reduced()
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.randint(jax.random.PRNGKey(1), (4, 24), 0, cfg.vocab_size)
    l1, c1 = ST.make_prefill_step(cfg, 32)(p, x)
    l2, c2 = ST.make_prefill_step(cfg, 32, batch_chunks=2)(p, x)
    np.testing.assert_allclose(l1, l2, rtol=2e-5, atol=2e-5)
    ok = jax.tree.map(lambda a, b: bool(np.allclose(a, b, rtol=2e-5,
                                                    atol=2e-5)), c1, c2)
    assert all(jax.tree.leaves(ok))
