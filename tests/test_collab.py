"""Collaborative executor: split == monolithic (up to quant error), wire
format compression, multi-pod pipeline execution on 2 emulated devices."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.collab import CollabRuntime, split_params
from repro.models import model as M


@pytest.fixture(scope="module")
def rt():
    cfg = get_config("gemma2-2b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params, CollabRuntime(cfg, params, cut_group=1)


def test_split_params_partitions_groups(rt):
    cfg, params, r = rt
    ge = jax.tree.leaves(r.p_end["groups"])[0].shape[0]
    gc = jax.tree.leaves(r.p_cloud["groups"])[0].shape[0]
    assert ge == 1 and ge + gc == cfg.num_groups


@pytest.mark.parametrize("bits,tol", [(8, 0.02), (4, 0.25)])
def test_split_matches_monolithic(rt, bits, tol):
    cfg, params, r = rt
    x = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)
    pkt, h = r.end_step(x, bits=bits)
    out = r.cloud_step(pkt)
    ref = r.monolithic(params, x)
    rel = float(jnp.max(jnp.abs(out - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < tol, rel
    # wire compression: 8-bit ~4x, 4-bit ~8x vs fp32
    assert pkt.wire_bytes < h.size * 4 / (32 // bits) * 1.1


def test_lossless_at_32bits_equivalent(rt):
    """Un-quantized handoff (manual) must be bit-exact."""
    cfg, params, r = rt
    x = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab_size)
    h = r._end_fn(r.p_end, x)
    out = r._cloud_fn(r.p_cloud, h)
    ref = r.monolithic(params, x)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_probe_on_boundary(rt):
    cfg, params, r = rt
    x = jax.random.randint(jax.random.PRNGKey(3), (4, 32), 0, cfg.vocab_size)
    _, h = r.end_step(x)
    centers = jax.random.normal(jax.random.PRNGKey(4), (7, cfg.d_model))
    sep, best, sims = r.probe(h.astype(jnp.float32), centers)
    assert sep.shape == (4,) and sims.shape == (4, 7)
    assert bool(jnp.all(sep >= 0))


_PIPELINE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.configs import get_config
from repro.models import model as M
from repro.core.collab import make_collab_pipeline_step
mesh = jax.make_mesh((2,), ("pod",))
cfg = get_config("qwen3-14b").reduced()
key = jax.random.PRNGKey(0)
params = M.init_params(cfg, key)
step = make_collab_pipeline_step(cfg, mesh, bits=8, n_micro=2)
tokens = jax.random.randint(key, (2, 4, 32), 0, cfg.vocab_size)
pspec = jax.tree.map(lambda x: NamedSharding(mesh, P()), params)
pspec["groups"] = jax.tree.map(lambda x: NamedSharding(mesh, P("pod")),
                               params["groups"])
with mesh:
    out = jax.jit(step, in_shardings=(pspec, NamedSharding(mesh, P())))(
        params, tokens)
for i in range(2):
    h, _, _ = M.forward(params, cfg, tokens[i])
    ref = M._lm_head(params, cfg, h)[:, -1]
    rel = float(jnp.max(jnp.abs(out[i] - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < 0.05, (i, rel)
print("PIPELINE_OK")
"""


def test_multipod_pipeline_subprocess():
    """The pod-sharded software pipeline executes on 2 emulated devices and
    matches the monolithic model within 8-bit quantization error."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _PIPELINE_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=420)
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr
