"""Hypothesis properties for multi-tenant admission scheduling.

Conservation across all three admission policies: for any tenant mix
(task counts, arrival processes, service times, weights), no task is
lost or duplicated, and per-tenant FIFO order is preserved — both in the
admission order and in the replayed per-tenant completion times.
(Module is collect-ignored by ``conftest.py`` when hypothesis is not
installed.)
"""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import sim
from repro.serving.tenancy import make_policy


@st.composite
def tenant_mixes(draw):
    n_hops = draw(st.integers(1, 3))
    n_tenants = draw(st.integers(1, 4))
    plans, arrivals = [], []
    for _ in range(n_tenants):
        n = draw(st.integers(0, 12))
        gaps = draw(st.lists(
            st.floats(0.0, 5e-3, allow_nan=False, allow_infinity=False),
            min_size=n, max_size=n))
        start = draw(st.floats(0.0, 10e-3))
        arr = list(start + np.cumsum([0.0] + gaps[:-1])) if n else []
        ps = []
        for i in range(n):
            comp = tuple(
                draw(st.floats(1e-4, 5e-3)) for _ in range(n_hops + 1))
            tx = tuple(draw(st.floats(0.0, 3e-3)) for _ in range(n_hops))
            # exit anywhere in the chain (post_init normalizes exit_hop
            # == n_hops back to a full run, and early_exit to exit_hop=0)
            ps.append(sim.SimPlan(
                compute=comp, tx=tx, early_exit=draw(st.booleans()),
                exit_hop=draw(st.one_of(st.none(),
                                        st.integers(0, n_hops)))))
        plans.append(ps)
        arrivals.append(arr)
    weights = [draw(st.floats(0.1, 8.0)) for _ in range(n_tenants)]
    return plans, arrivals, weights


@settings(max_examples=60, deadline=None)
@given(mix=tenant_mixes(), policy=st.sampled_from(["fifo", "rr", "wdrr"]))
def test_admission_order_conserves_tasks_and_fifo(mix, policy):
    plans, arrivals, weights = mix
    order = sim.multitenant_admission_order(
        plans, arrivals, make_policy(policy, weights=weights))
    expected = {(t, i) for t in range(len(plans))
                for i in range(len(plans[t]))}
    # no task lost, none duplicated
    assert len(order) == len(expected)
    assert set(order) == expected
    # per-tenant FIFO preserved
    for t in range(len(plans)):
        idxs = [i for (tt, i) in order if tt == t]
        assert idxs == sorted(idxs)


@settings(max_examples=30, deadline=None)
@given(mix=tenant_mixes(), policy=st.sampled_from(["fifo", "rr", "wdrr"]))
def test_replayed_stream_conserves_per_tenant_completions(mix, policy):
    plans, arrivals, weights = mix
    if not any(plans):
        return  # nothing to replay
    mt = sim.simulate_multitenant_stream(
        plans, arrivals, make_policy(policy, weights=weights))
    assert len(mt.stream.done) == sum(len(p) for p in plans)
    for t in range(len(plans)):
        arr, done, exits = mt.tenant_view(t)
        assert len(done) == len(plans[t])
        # completions never precede arrivals + own end-segment compute
        for a, d, (i, p) in zip(arr, done, enumerate(plans[t])):
            assert d >= a + p.compute[0] - 1e-9
        # per-tenant full-pipeline completions are FIFO-ordered
        full = [d for d, e in zip(done, exits) if not e]
        assert all(d0 <= d1 + 1e-9 for d0, d1 in zip(full, full[1:]))
