"""Hypothesis properties for multi-tenant admission scheduling.

Conservation across all three admission policies: for any tenant mix
(task counts, arrival processes, service times, weights), no task is
lost or duplicated, and per-tenant FIFO order is preserved — both in the
admission order and in the replayed per-tenant completion times.
(Module is collect-ignored by ``conftest.py`` when hypothesis is not
installed.)
"""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import sim
from repro.serving.tenancy import make_policy


@st.composite
def tenant_mixes(draw):
    n_hops = draw(st.integers(1, 3))
    n_tenants = draw(st.integers(1, 4))
    plans, arrivals = [], []
    for _ in range(n_tenants):
        n = draw(st.integers(0, 12))
        gaps = draw(st.lists(
            st.floats(0.0, 5e-3, allow_nan=False, allow_infinity=False),
            min_size=n, max_size=n))
        start = draw(st.floats(0.0, 10e-3))
        arr = list(start + np.cumsum([0.0] + gaps[:-1])) if n else []
        ps = []
        for i in range(n):
            comp = tuple(
                draw(st.floats(1e-4, 5e-3)) for _ in range(n_hops + 1))
            tx = tuple(draw(st.floats(0.0, 3e-3)) for _ in range(n_hops))
            # exit anywhere in the chain (post_init normalizes exit_hop
            # == n_hops back to a full run, and early_exit to exit_hop=0)
            ps.append(sim.SimPlan(
                compute=comp, tx=tx, early_exit=draw(st.booleans()),
                exit_hop=draw(st.one_of(st.none(),
                                        st.integers(0, n_hops)))))
        plans.append(ps)
        arrivals.append(arr)
    weights = [draw(st.floats(0.1, 8.0)) for _ in range(n_tenants)]
    return plans, arrivals, weights


@settings(max_examples=60, deadline=None)
@given(mix=tenant_mixes(), policy=st.sampled_from(["fifo", "rr", "wdrr"]))
def test_admission_order_conserves_tasks_and_fifo(mix, policy):
    plans, arrivals, weights = mix
    order = sim.multitenant_admission_order(
        plans, arrivals, make_policy(policy, weights=weights))
    expected = {(t, i) for t in range(len(plans))
                for i in range(len(plans[t]))}
    # no task lost, none duplicated
    assert len(order) == len(expected)
    assert set(order) == expected
    # per-tenant FIFO preserved
    for t in range(len(plans)):
        idxs = [i for (tt, i) in order if tt == t]
        assert idxs == sorted(idxs)


@settings(max_examples=30, deadline=None)
@given(mix=tenant_mixes(), policy=st.sampled_from(["fifo", "rr", "wdrr"]))
def test_replayed_stream_conserves_per_tenant_completions(mix, policy):
    plans, arrivals, weights = mix
    if not any(plans):
        return  # nothing to replay
    mt = sim.simulate_multitenant_stream(
        plans, arrivals, make_policy(policy, weights=weights))
    assert len(mt.stream.done) == sum(len(p) for p in plans)
    for t in range(len(plans)):
        arr, done, exits = mt.tenant_view(t)
        assert len(done) == len(plans[t])
        # completions never precede arrivals + own end-segment compute
        for a, d, (i, p) in zip(arr, done, enumerate(plans[t])):
            assert d >= a + p.compute[0] - 1e-9
        # per-tenant full-pipeline completions are FIFO-ordered
        full = [d for d, e in zip(done, exits) if not e]
        assert all(d0 <= d1 + 1e-9 for d0, d1 in zip(full, full[1:]))


# ------------------------------------------------ micro-batching properties
@st.composite
def batched_mixes(draw):
    """A tenant mix plus per-tier batch caps, per-plan fixed launch
    fractions and optional staleness deadlines — the knobs of the greedy
    drain-up-to-cap-or-deadline batch formation rule."""
    plans, arrivals, weights = draw(tenant_mixes())
    n_hops = max((p.n_hops for ps in plans for p in ps), default=1)
    caps = [draw(st.integers(1, 4)) for _ in range(n_hops + 1)]
    for ps, arr in zip(plans, arrivals):
        for p, a in zip(ps, arr):
            frac = draw(st.floats(0.0, 1.0, allow_nan=False))
            p.t_fixed = tuple(c * frac for c in p.compute)
            if draw(st.booleans()):
                p.deadline = a + draw(st.floats(1e-3, 80e-3))
    return plans, arrivals, weights, caps


@settings(max_examples=40, deadline=None)
@given(mix=batched_mixes(), policy=st.sampled_from(["fifo", "rr", "wdrr"]))
def test_batched_multitenant_conserves_tasks_and_stream_order(mix, policy):
    """Whatever the caps, fixed fractions and deadlines: no task is lost
    or duplicated, batching never reorders completions within one
    tenant's stream (per exit tier), and every resource timeline stays
    sorted and disjoint."""
    plans, arrivals, weights, caps = mix
    if not any(plans):
        return
    mt = sim.simulate_multitenant_stream(
        plans, arrivals, make_policy(policy, weights=weights),
        batch_caps=caps)
    expected = {(t, i) for t in range(len(plans))
                for i in range(len(plans[t]))}
    assert len(mt.order) == len(expected)
    assert set(mt.order) == expected
    assert len(mt.stream.done) == len(expected)
    for t in range(len(plans)):
        _, done, _ = mt.tenant_view(t)
        by_tier = {}
        for d, eh in zip(done, mt.tenant_exit_hops(t)):
            by_tier.setdefault(eh, []).append(d)
        for ds in by_tier.values():
            assert all(d0 <= d1 + 1e-9 for d0, d1 in zip(ds, ds[1:]))
    for iv in (mt.stream.compute_intervals + mt.stream.link_intervals):
        assert sim._sorted_disjoint(iv)


@settings(max_examples=30, deadline=None)
@given(mix=batched_mixes(), policy=st.sampled_from(["fifo", "rr", "wdrr"]))
def test_cap_one_multitenant_is_decision_identical(mix, policy):
    """All-ones caps route to the untouched legacy replay: admission
    order and timelines are *bitwise* equal to running without caps
    (policies are stateful, so each run gets a fresh instance)."""
    plans, arrivals, weights, caps = mix
    if not any(plans):
        return
    a = sim.simulate_multitenant_stream(
        plans, arrivals, make_policy(policy, weights=weights))
    b = sim.simulate_multitenant_stream(
        plans, arrivals, make_policy(policy, weights=weights),
        batch_caps=[1] * len(caps))
    assert a.order == b.order
    assert a.stream.done == b.stream.done
    assert a.stream.compute_intervals == b.stream.compute_intervals
    assert a.stream.link_intervals == b.stream.link_intervals


@st.composite
def batched_streams(draw):
    """A single admission-ordered stream with caps, fixed fractions and
    deadlines (tier-0 batching requires non-decreasing arrivals, which
    cumulative gaps give by construction)."""
    n_hops = draw(st.integers(1, 3))
    n = draw(st.integers(1, 20))
    gaps = draw(st.lists(
        st.floats(0.0, 5e-3, allow_nan=False, allow_infinity=False),
        min_size=n, max_size=n))
    arr = list(np.cumsum([0.0] + gaps[:-1]))
    plans = []
    for i in range(n):
        comp = tuple(
            draw(st.floats(1e-4, 5e-3)) for _ in range(n_hops + 1))
        frac = draw(st.floats(0.0, 1.0, allow_nan=False))
        dl = arr[i] + draw(st.floats(1e-3, 80e-3)) \
            if draw(st.booleans()) else None
        plans.append(sim.SimPlan(
            compute=comp, tx=tuple(draw(st.floats(0.0, 3e-3))
                                   for _ in range(n_hops)),
            early_exit=draw(st.booleans()),
            exit_hop=draw(st.one_of(st.none(), st.integers(0, n_hops))),
            t_fixed=tuple(c * frac for c in comp), deadline=dl))
    caps = [draw(st.integers(1, 4)) for _ in range(n_hops + 1)]
    return plans, arr, caps


@settings(max_examples=50, deadline=None)
@given(stream=batched_streams())
def test_batched_stream_conserves_orders_and_counts_batches(stream):
    """Single-stream form of the conservation/no-reordering property,
    plus the ``compute_batch_sizes`` bookkeeping: batch sizes respect
    the caps and jointly account for exactly the tasks that occupy each
    compute tier."""
    plans, arr, caps = stream
    res = sim.simulate_stream(plans, arr, batch_caps=caps)
    assert len(res.done) == len(plans)
    by_tier = {}
    for d, eh in zip(res.done, res.exit_hop):
        by_tier.setdefault(eh, []).append(d)
    for ds in by_tier.values():
        assert all(d0 <= d1 + 1e-9 for d0, d1 in zip(ds, ds[1:]))
    for iv in (res.compute_intervals + res.link_intervals):
        assert sim._sorted_disjoint(iv)
    if res.compute_batch_sizes:
        for k, (ivs, bs) in enumerate(zip(res.compute_intervals,
                                          res.compute_batch_sizes)):
            assert len(ivs) == len(bs)
            occ = sum(1 for eh in res.exit_hop
                      if sim.occupies_compute(eh, k))
            assert sum(bs) == occ
            assert all(1 <= b <= caps[k] for b in bs)
    # every link transfer stays per-task (links never batch)
    for k, ivs in enumerate(res.link_intervals):
        occ = sum(1 for eh in res.exit_hop if sim.occupies_link(eh, k))
        assert len(ivs) == occ
