"""Replicated-tier pools: differential pins + unit coverage.

The tentpole contract of the pool DAG: per-tier replica pools
(``sim.PoolSpec``, heterogeneous speeds allowed) behind a pluggable
router (``serving.routing``) must time identically in the arithmetic
simulator (``sim.simulate_pool_stream``: staged dispatch -> per-replica
replay -> sequencer) and the event-driven executor
(``AsyncHopPipeline(pools=...)``: dispatcher / replica / sequencer
workers under the virtual clock) — completion times, routes, and
per-replica busy intervals to 1e-6, across every router policy and
``m in {1, 2, 4}``.  An ``m = 1`` pool must reduce *bit-identically* to
the legacy serial chain.  Micro-batching (per-tier caps) composes with
replication on both sides.

Deterministic regression tests for the two ``core.online`` bugfixes ride
along here (the hypothesis versions live in ``test_pool_props.py``):
``gap_features`` layout handling and the cold-cache separability /
exit-eligibility rule.
"""

import numpy as np
import pytest

from repro.core import online as ON
from repro.core import sim
from repro.core.pipeline import (TaskPlan, result_from_pool_stream,
                                 run_pipeline)
from repro.serving.async_engine import (AsyncCoachEngine, AsyncHopPipeline,
                                        VirtualClock, run_pipeline_async)
from repro.serving.base import EngineConfig
from repro.serving.engine import CoachEngine
from repro.serving.routing import (ROUTER_POLICIES, RouterPolicy,
                                   make_router)
from repro.serving.tenancy import MultiTenantHopPipeline, make_policy
from tests.test_async_engine import (_random_multihop_plans,
                                     _random_single_hop_plans)
from tests.test_batching import _batched_plans

TOL = 1e-6

POLICIES = sorted(ROUTER_POLICIES)


# ----------------------------------------------------------------- helpers
def _sim_plans(plans, n_hops):
    return [p.as_sim_plan(n_hops) for p in plans]


def _pin_pool(plans, arrivals, pools, policy, n_hops, seed=0, links=None,
              batch_caps=None, tol=TOL):
    """Run both sides on identical inputs and assert the timelines and
    placements agree to ``tol``."""
    sps = _sim_plans(plans, n_hops)
    ps = sim.simulate_pool_stream(sps, arrivals, pools,
                                  make_router(policy, seed=seed),
                                  links=links, batch_caps=batch_caps)
    pipe = AsyncHopPipeline(n_hops, links=links, clock=VirtualClock(),
                            pools=pools,
                            router=make_router(policy, seed=seed),
                            batch_caps=batch_caps)
    pa = pipe.run(lambda i, _a: sps[i], len(sps), arrivals)
    assert isinstance(pa, sim.PoolStreamResult)
    assert ps.routes == pa.routes
    for a, b in zip(ps.done, pa.done):
        assert abs(a - b) <= tol
    for k in range(n_hops + 1):
        for r in range(len(ps.replica_intervals[k])):
            ia = ps.replica_intervals[k][r]
            ib = pa.replica_intervals[k][r]
            assert len(ia) == len(ib)
            for (s1, e1), (s2, e2) in zip(ia, ib):
                assert abs(s1 - s2) <= tol and abs(e1 - e2) <= tol
            assert abs(ps.replica_busy[k][r] - pa.replica_busy[k][r]) <= tol
    for a, b in zip(ps.link_busy, pa.link_busy):
        assert abs(a - b) <= tol
    return ps, pa


# ------------------------------------------------------------ pool basics
def test_pool_spec_and_as_pools_normalization():
    p = sim.PoolSpec((1.0, 2.0, 0.5))
    assert p.m == 3
    # ints, speed tuples, and PoolSpec instances normalize; a missing
    # tail pads with single unit replicas
    pools = sim.as_pools([2, (1.0, 1.5), p], 5)
    assert [q.m for q in pools] == [2, 2, 3, 1, 1]
    assert pools[0].speeds == (1.0, 1.0)
    assert pools[1].speeds == (1.0, 1.5)
    with pytest.raises(AssertionError):
        sim.PoolSpec((1.0, -2.0))


def test_make_router_names_and_passthrough():
    for name in POLICIES:
        r = make_router(name, seed=3)
        assert isinstance(r, RouterPolicy)
        assert make_router(r) is r
    with pytest.raises(ValueError):
        make_router("least-loaded")


# --------------------------------------------------- m = 1 chain identity
@pytest.mark.parametrize("n_hops", [1, 2, 3])
def test_m1_pools_reduce_bitwise_to_chain(n_hops):
    """Single-replica pools are the serial chain, *bit-identically*: the
    staged pool replay takes the same float expressions (``1.0 * x`` is
    exact), and one serial replica's release instants are monotone, so
    the sequencer never delays a forward."""
    plans = _random_multihop_plans(11, n=40, n_hops=n_hops) if n_hops > 1 \
        else _random_single_hop_plans(11, n=40)
    sps = _sim_plans(plans, n_hops)
    arr = [i * 1.5e-3 for i in range(len(sps))]
    ref = sim.simulate_stream(sps, arr)
    for policy in POLICIES:
        res = sim.simulate_pool_stream(sps, arr, [1] * (n_hops + 1),
                                       make_router(policy))
        sr = res.as_stream_result()
        assert sr.done == ref.done                      # bitwise
        assert sr.compute_busy == ref.compute_busy
        assert sr.link_busy == ref.link_busy
        assert sr.compute_intervals == ref.compute_intervals
        assert sr.link_intervals == ref.link_intervals
        # every tier a task reached placed it on the only replica
        assert all(r in (None, 0) for rt in res.routes for r in rt)


# ------------------------------------------------- differential pinning
@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("m", [1, 2, 4])
def test_differential_pool_executor_pinned(policy, m):
    """Acceptance: executor == simulator at 1e-6 for every router policy
    and m in {1, 2, 4} on the bottleneck (middle) tier."""
    plans = _random_multihop_plans(23, n=40, n_hops=2)
    arr = [i * 1.0e-3 for i in range(len(plans))]
    _pin_pool(plans, arr, [1, m, 1], policy, n_hops=2, seed=5)


@pytest.mark.parametrize("policy", POLICIES)
def test_differential_heterogeneous_pools_pinned(policy):
    """Replicas with different speeds (service = speed * segment time)
    stay pinned — including a pool on every tier at once."""
    plans = _random_multihop_plans(31, n=36, n_hops=2)
    arr = [i * 0.8e-3 for i in range(len(plans))]
    pools = [2, (1.0, 1.7, 0.6), (0.5, 2.0)]
    ps, _ = _pin_pool(plans, arr, pools, policy, n_hops=2, seed=9)
    # heterogeneity actually exercised: some task landed off replica 0
    assert any(r not in (None, 0) for rt in ps.routes for r in rt)


def test_differential_pool_with_traced_links_pinned():
    """Dynamic per-hop bandwidth (trace repricing at the transfer's
    actual start) composes with pools on both sides."""
    from repro.core.costs import LinkProfile
    from repro.core.pipeline import bandwidth_step_trace
    plans = _random_multihop_plans(41, n=30, n_hops=2, hop_exits=True)
    arr = [i * 1.2e-3 for i in range(len(plans))]
    links = [LinkProfile("uplink", 20e6,
                         trace=bandwidth_step_trace([(0.0, 20.0),
                                                     (15e-3, 6.0)])),
             LinkProfile("backhaul", 900e6)]
    _pin_pool(plans, arr, [1, 2, 2], "jsq", n_hops=2, links=links)


@pytest.mark.parametrize("policy", ["jsq", "po2"])
def test_differential_batched_pools_pinned(policy):
    """Micro-batching (PR 6) composes with replication: per-replica
    greedy batch formation at speed-scaled service times pins at 1e-6,
    and real multi-task batches form."""
    plans = _batched_plans(7, n_hops=2, n=40, deadline_slack=30e-3)
    arr = [i * 0.6e-3 for i in range(len(plans))]
    ps, pa = _pin_pool(plans, arr, [2, 2, 2], policy, n_hops=2, seed=1,
                       batch_caps=[2, 4, 3])
    assert ps.replica_batch_sizes == pa.replica_batch_sizes
    assert max(b for tier in ps.replica_batch_sizes
               for rep in tier for b in rep) > 1


def test_pool_throughput_scales_on_bottleneck_tier():
    """Replicating the bottleneck tier raises throughput: m = 2 on a
    dominant middle tier must cut the makespan materially (near 2x when
    that tier is the only bottleneck)."""
    n = 60
    sps = [sim.SimPlan(compute=(0.2e-3, 4e-3, 0.2e-3),
                       tx=(0.05e-3, 0.05e-3),
                       tx_offset=(None, None), rx_offset=(None, None))
           for _ in range(n)]
    arr = [i * 0.1e-3 for i in range(n)]
    t1 = sim.simulate_pool_stream(sps, arr, [1, 1, 1],
                                  make_router("jsq")).makespan
    t2 = sim.simulate_pool_stream(sps, arr, [1, 2, 1],
                                  make_router("jsq")).makespan
    assert t1 / t2 >= 1.8


# -------------------------------------------------- result-type plumbing
def test_pool_stream_result_tier_view_and_bubbles():
    plans = _random_multihop_plans(3, n=30, n_hops=2)
    arr = [i * 0.5e-3 for i in range(len(plans))]
    res = sim.simulate_pool_stream(_sim_plans(plans, 2), arr, [1, 2, 1],
                                   make_router("jsq"))
    # tier busy = sum of its replicas
    for k in range(3):
        assert abs(res.compute_busy[k] - sum(res.replica_busy[k])) < 1e-12
    pr = result_from_pool_stream(res)
    assert pr.pool_sizes == (1, 2, 1)
    # utilization judged against m * makespan keeps bubbles in [0, 1]
    for k in range(3):
        assert 0.0 <= pr.bubble_fraction(("compute", k)) <= 1.0
    assert 0.0 <= pr.bubble_fraction("cloud") <= 1.0


def test_run_pipeline_pool_path_matches_pool_sim():
    plans = _random_multihop_plans(5, n=24, n_hops=2)
    arr = [i * 1e-3 for i in range(len(plans))]
    pr = run_pipeline(plans, arrivals=arr, links=[None, None],
                      pools=[1, 2, 1], router=make_router("po2", seed=2))
    ref = sim.simulate_pool_stream(_sim_plans(plans, 2), arr, [1, 2, 1],
                                   make_router("po2", seed=2))
    assert pr.pool_sizes == (1, 2, 1)
    assert abs(pr.makespan - ref.makespan) < 1e-12
    assert [t.done for t in pr.tasks] == list(ref.done)


def test_run_pipeline_async_pool_path_pinned_to_sync():
    plans = _random_multihop_plans(13, n=24, n_hops=2)
    arr = [i * 1e-3 for i in range(len(plans))]
    pr_s = run_pipeline(plans, arrivals=arr, links=[None, None],
                        pools=[2, 2, 1], router=make_router("jsq"))
    pr_a = run_pipeline_async(plans, arrivals=arr, links=[None, None],
                              clock=VirtualClock(), pools=[2, 2, 1],
                              router=make_router("jsq"))
    assert pr_a.pool_sizes == (2, 2, 1)
    assert abs(pr_s.makespan - pr_a.makespan) < TOL
    for a, b in zip(pr_s.tasks, pr_a.tasks):
        assert abs(a.done - b.done) < TOL


# --------------------------------------------------------- multi-tenant
@pytest.mark.parametrize("policy", ["fifo", "rr", "wdrr"])
def test_differential_multitenant_pool_pinned(policy):
    """Pool ingress credits (a token whenever *any* tier-0 replica
    frees) generalize the single-replica credit gate: executor ==
    ``simulate_multitenant_pool_stream`` on order + merged timeline."""
    rng = np.random.RandomState(29)
    n_hops, weights = 2, [1.0, 2.5, 0.5]
    plans, arrs = [], []
    for t in range(3):
        n = int(rng.randint(8, 14))
        ps, ar = [], []
        tt = float(rng.uniform(0, 1e-3))
        for _ in range(n):
            comp = tuple(rng.uniform(1e-4, 4e-3, n_hops + 1))
            tx = tuple(rng.uniform(0.0, 2e-3, n_hops))
            ps.append(TaskPlan.multihop(comp, tx).as_sim_plan(n_hops))
            ar.append(tt)
            tt += float(rng.uniform(0, 1e-3))
        plans.append(ps)
        arrs.append(ar)
    pools = [2, 2, 1]
    mt_sim = sim.simulate_multitenant_pool_stream(
        plans, arrs, make_policy(policy, weights=weights), pools,
        make_router("jsq", seed=4))
    pipe = MultiTenantHopPipeline(
        n_hops, clock=VirtualClock(),
        policy=make_policy(policy, weights=weights), pools=pools,
        router=make_router("jsq", seed=4))
    mt_ex = pipe.run([(lambda t: (lambda i, _a: plans[t][i]))(t)
                      for t in range(3)], arrs)
    assert isinstance(mt_ex, sim.MultiTenantPoolStreamResult)
    assert mt_ex.order == mt_sim.order
    for a, b in zip(mt_sim.stream.done, mt_ex.stream.done):
        assert abs(a - b) <= TOL


def test_multitenant_pool_affinity_keeps_tenants_sticky():
    """The affinity router pins each tenant to one replica per tier."""
    n_hops = 1
    plans = [[sim.SimPlan(compute=(1e-3, 2e-3), tx=(0.1e-3,),
                          tx_offset=(None,), rx_offset=(None,))
              for _ in range(8)] for _ in range(2)]
    arrs = [[i * 0.4e-3 for i in range(8)],
            [0.1e-3 + i * 0.4e-3 for i in range(8)]]
    res = sim.simulate_multitenant_pool_stream(
        plans, arrs, make_policy("rr"), [1, 2], make_router("affinity"))
    pool = res.pool
    assert pool is not None
    by_tenant = {}
    for (t, _i), rt in zip(res.order, pool.routes):
        by_tenant.setdefault(t, set()).add(rt[1])
    assert all(len(reps) == 1 for reps in by_tenant.values())
    assert by_tenant[0] != by_tenant[1]   # JSQ seeding spread them


# --------------------------------------------------------- engine level
def _mk_pool_engines(**cfg_kw):
    from repro.core.costs import DeviceProfile, LinkProfile
    from repro.core.schedule import StageTimes
    from repro.data.pipeline import (CorrelatedTaskStream,
                                     make_calibration_set)
    st = StageTimes(
        T_e=2e-3, T_t=4e-3, T_c=2e-3, T_t_par=0.0, T_c_par=0.0,
        latency=9e-3, first_tx_offset=2e-3, cloud_start_offset=3e-3,
        compute=(2e-3, 1.5e-3, 2e-3), link=(3e-3, 1e-3),
        link_par=(0.0, 0.0), compute_par=(0.0, 0.0),
        tx_offsets=(2e-3, 1.5e-3), rx_offsets=(3e-3, 1e-3))
    links = [LinkProfile("uplink", 20e6), LinkProfile("backhaul", 900e6)]
    stream = CorrelatedTaskStream(n_labels=30, dim=48,
                                  correlation="medium", seed=2)
    feats, labels = make_calibration_set(stream, 400)
    mk = lambda cls: cls(
        None, st, DeviceProfile("end", 1e9), links[0],
        DeviceProfile("cloud", 8e9), n_labels=30, calib_feats=feats,
        calib_labels=labels, boundary_elems=50_000, links=links,
        cfg=EngineConfig(**cfg_kw))

    def classify(task):
        d = np.linalg.norm(stream.mu - task.features[None], axis=1)
        return task.features, int(np.argmin(d))

    return mk(CoachEngine), mk(AsyncCoachEngine), stream, classify


def test_engine_pool_config_sync_equals_async():
    """EngineConfig pool knobs plumb end to end: the sync engine (pool
    simulator) and async engine (pool executor) agree on the timeline
    and both report the pool topology."""
    sync_e, async_e, stream, classify = _mk_pool_engines(
        per_hop_bits=False, pool_sizes=[1, 2, 2], router="jsq",
        router_seed=3)
    assert sync_e.pools is not None
    tasks = list(stream.tasks(40))
    ss = sync_e.run_stream(list(tasks), 2e-3, classify)
    sa = async_e.run_stream(list(tasks), 2e-3, classify,
                            clock=VirtualClock())
    assert ss.pipeline.pool_sizes == (1, 2, 2)
    assert sa.pipeline.pool_sizes == (1, 2, 2)
    assert abs(ss.pipeline.makespan - sa.pipeline.makespan) < TOL
    for a, b in zip(ss.pipeline.tasks, sa.pipeline.tasks):
        assert abs(a.done - b.done) < TOL
    assert ss.exit_ratio == sa.exit_ratio
    assert ss.accuracy == sa.accuracy


def test_engine_pool_speeds_override_sizes():
    sync_e, _, _, _ = _mk_pool_engines(
        pool_sizes=[2, 2, 2], pool_speeds=[[1.0], [1.0, 1.5], [1.0]])
    assert tuple(p.speeds for p in sync_e.pools) == \
        ((1.0,), (1.0, 1.5), (1.0,))


# ------------------------------------------ online bugfix regressions
def test_gap_features_layout_explicit_and_heuristic():
    """Regression (``core.online.gap_features``): the shape heuristic
    misclassifies deep channels-first maps — ``(512, 7, 7)`` pooled over
    its channel axis yields 7 spatial means.  The explicit ``layout``
    parameter fixes it; ``None`` keeps the documented legacy fallback."""
    rng = np.random.RandomState(0)
    shallow = rng.rand(64, 112, 112)       # heuristic: CHW (correct)
    deep = rng.rand(512, 7, 7)             # heuristic: HWC (WRONG)
    deep_hwc = rng.rand(7, 7, 512)         # heuristic: CHW (WRONG axis!)
    # explicit layout: channel-dimension outputs
    assert ON.gap_features(shallow, layout="CHW").shape == (64,)
    assert ON.gap_features(deep, layout="CHW").shape == (512,)
    assert ON.gap_features(deep_hwc, layout="HWC").shape == (512,)
    np.testing.assert_allclose(ON.gap_features(deep, layout="CHW"),
                               deep.mean(axis=(1, 2)))
    np.testing.assert_allclose(ON.gap_features(deep_hwc, layout="HWC"),
                               deep_hwc.mean(axis=(0, 1)))
    # the documented fallback reproduces the legacy (buggy) behavior
    assert ON.gap_features(shallow).shape == (64,)
    assert ON.gap_features(deep).shape == (7,)        # former misbehavior
    # batched maps: legacy default assumed (B,C,H,W)
    b = rng.rand(4, 16, 8, 8)
    assert ON.gap_features(b).shape == (4, 16)
    assert ON.gap_features(rng.rand(4, 8, 8, 16),
                           layout="HWC").shape == (4, 16)
    with pytest.raises(ValueError):
        ON.gap_features(deep, layout="CWH")


def test_cold_cache_never_exits_below_two_warm_labels():
    """Regression (cold-cache separability): with exactly one warmed
    label every untrained center contributes similarity 0.0, so t_SH is
    an artificial 0 and Eq. 9 blows up through ``t_H / max(t_SH,
    1e-12)`` — the legacy scheduler exited warm-up tasks spuriously.
    Eq. 9 now runs over trained centers only and exit eligibility
    requires >= 2 warmed labels."""
    rng = np.random.RandomState(1)
    cache = ON.SemanticCache(n_labels=8, dim=16)
    assert cache.n_warm == 0
    f = rng.rand(16)
    # one warmed label: similarity vector has exactly one nonzero entry
    cache.update(f, 3)
    assert cache.n_warm == 1
    sims = cache.similarities(f)
    assert np.count_nonzero(sims) == 1
    # trained-centers-only Eq. 9: no second-highest degree -> 0, where
    # the legacy full-vector statistic blew up past any threshold
    assert ON.separability(sims, cache.counts) == 0.0
    assert ON.separability(sims) > 1e6            # former misbehavior
    th = ON.Thresholds(s_ext=0.5, s_adj=((0.9, 3), (0.0, 8)))
    sched = ON.OnlineScheduler(cache, th, boundary_elems=1000,
                               T_e=1e-3, T_c=1e-3)
    dec = sched.step(f, bandwidth_bps=1e6)
    assert not dec.early_exit            # a cold cache never terminates
    # two warmed labels: eligibility restored, statistic finite
    cache.update(rng.rand(16), 5)
    assert cache.n_warm == 2
    dec2 = sched.step(f, bandwidth_bps=1e6)
    s2 = ON.separability(cache.similarities(f), cache.counts)
    assert np.isfinite(s2)
    if dec2.early_exit:
        assert s2 > th.s_ext


def test_cold_cache_rule_applies_to_hop_probes():
    rng = np.random.RandomState(2)
    cache = ON.SemanticCache(4, 8)
    cache.warm_up(rng.rand(12, 8), rng.randint(0, 4, 12))
    th = ON.Thresholds(s_ext=float("inf"), s_adj=((0.0, 8),))
    probe_cache = ON.SemanticCache(4, 8)
    probe_cache.update(rng.rand(8), 0)   # single warm label at the tier
    probe = ON.HopProbe(cache=probe_cache,
                        thresholds=ON.Thresholds(s_ext=0.0,
                                                 s_adj=((0.0, 8),)))
    sched = ON.OnlineScheduler(cache, th, 1000, 1e-3, 1e-3,
                               hop_elems=[1000, 1000],
                               stage_compute=[1e-3, 1e-3, 1e-3],
                               hop_probes=[probe])
    dec = sched.probe_hop(1, rng.rand(8))
    assert dec.exit_hop is None          # cold tier probe never exits


def test_warm_cache_separability_unchanged_by_fix():
    """A fully warmed cache is unaffected: every center is trained, so
    the trained-centers restriction is the identity."""
    rng = np.random.RandomState(3)
    cache = ON.SemanticCache(6, 12)
    cache.warm_up(rng.rand(60, 12), rng.randint(0, 6, 60))
    assert cache.n_warm == 6
    for _ in range(10):
        sims = cache.similarities(rng.rand(12))
        assert ON.separability(sims, cache.counts) == \
            ON.separability(sims)
