"""Differential harness for the multi-tenant admission subsystem.

Pins ``serving.tenancy`` (per-tenant admit workers + policy dispatcher
released by ingress credits, on the virtual clock) to the extended
multi-tenant simulator ``core.sim.simulate_multitenant_stream`` (the same
ingress gate computed arithmetically): admission order, per-task
completions, per-resource busy intervals, bubble fractions, and
per-tenant latencies must agree to 1e-6 for >= 2 tenants on 2- and 3-hop
chains under all three admission policies — at the plan level and
through ``MultiTenantCoachEngine``.  On top of that: conservation (no
task lost/duplicated, per-tenant FIFO preserved), decision isolation
(co-tenancy never changes a tenant's decisions), WDRR weight semantics,
bounded-queue backpressure, and the fairness-vs-FIFO tradeoff the bench
reports (FIFO is minimax for raw worst-tenant p99 — work conservation —
while WDRR wins the SLO-normalized worst-tenant view by a wide margin).
"""

import numpy as np
import pytest

from repro.core import sim
from repro.core.costs import DeviceProfile, LinkProfile
from repro.core.pipeline import TaskPlan, bandwidth_step_trace, \
    result_from_stream
from repro.core.schedule import StageTimes
from repro.data.pipeline import (CorrelatedTaskStream, make_calibration_set,
                                 make_hop_calibration_sets)
from repro.serving.tenancy import (MultiTenantCoachEngine, TenantSpec,
                                   WeightedDeficitRoundRobin, make_policy,
                                   run_multitenant_async, service_time_cost,
                                   tenant_pipeline_result)
from tests.test_async_engine import _assert_timelines_agree

TOL = 1e-6
POLICIES = ("fifo", "rr", "wdrr")

END = DeviceProfile("end", 1e9)
CLOUD = DeviceProfile("cloud", 8e9)


# ----------------------------------------------------------------- helpers
def _rand_plans(seed, n, n_hops):
    rng = np.random.RandomState(seed)
    plans = []
    for _ in range(n):
        comp = rng.uniform(1e-3, 4e-3, n_hops + 1)
        tx = rng.uniform(0.2e-3, 3e-3, n_hops)
        if rng.rand() < 0.15:
            plans.append(TaskPlan(comp[0], 0.0, 0.0, True))
            continue
        txo = [rng.uniform(0, comp[k]) if rng.rand() < 0.5 else None
               for k in range(n_hops)]
        rxo = [rng.uniform(0, tx[k]) if rng.rand() < 0.5 else None
               for k in range(n_hops)]
        exit_hop = None
        if n_hops >= 2 and rng.rand() < 0.25:
            exit_hop = int(rng.randint(1, n_hops))  # hop-level exit
        plans.append(TaskPlan.multihop(comp, tx, txo, rxo,
                                       exit_hop=exit_hop))
    return plans


def _tenant_mix(seed, n_hops, n_tenants=3):
    """Irregular arrivals for most tenants plus one all-at-once burst
    tenant (the regime where admission policies actually differ)."""
    rng = np.random.RandomState(seed)
    sizes = rng.randint(8, 30, n_tenants)
    plans = [_rand_plans(seed + 10 * t, sizes[t], n_hops)
             for t in range(n_tenants)]
    arrs = [np.cumsum(rng.uniform(0, 3e-3, sizes[t])).tolist()
            for t in range(n_tenants)]
    arrs[-1] = [0.0] * sizes[-1]  # burst tenant
    weights = rng.uniform(0.5, 4.0, n_tenants).tolist()
    return plans, arrs, weights


def _assert_mt_agree(mt_exec, mt_sim, tol=TOL):
    assert mt_exec.order == mt_sim.order
    _assert_timelines_agree(result_from_stream(mt_sim.stream),
                            result_from_stream(mt_exec.stream), tol=tol)
    for t in range(mt_sim.n_tenants):
        la = mt_exec.tenant_latencies(t)
        lb = mt_sim.tenant_latencies(t)
        assert len(la) == len(lb)
        assert all(abs(a - b) < tol for a, b in zip(la, lb)), f"tenant {t}"


# -------------------------------------------- differential: plan level
@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("n_hops", [2, 3])
@pytest.mark.parametrize("seed", [0, 1])
def test_differential_multitenant_plan_level(policy, n_hops, seed):
    """Executor == simulator to 1e-6: admission order, merged timeline,
    per-tenant latencies; 3 tenants (one bursty), 2- and 3-hop chains."""
    plans, arrs, weights = _tenant_mix(seed, n_hops)
    mt_exec = run_multitenant_async(plans, arrs, policy=policy,
                                    weights=weights)
    sps = [[p.as_sim_plan(n_hops) for p in ps] for ps in plans]
    mt_sim = sim.simulate_multitenant_stream(
        sps, arrs, make_policy(policy, weights=weights))
    _assert_mt_agree(mt_exec, mt_sim)


@pytest.mark.parametrize("policy", POLICIES)
def test_differential_multitenant_with_traced_uplink(policy):
    uplink = LinkProfile("dyn", 40e6, trace=bandwidth_step_trace(
        [(0.0, 40.0), (0.02, 6.0), (0.08, 60.0)]))
    backhaul = LinkProfile("bh", 900e6)
    plans, arrs, weights = _tenant_mix(7, n_hops=2)
    links = [uplink, backhaul]
    mt_exec = run_multitenant_async(plans, arrs, policy=policy,
                                    weights=weights, links=links)
    sps = [[p.as_sim_plan(2) for p in ps] for ps in plans]
    mt_sim = sim.simulate_multitenant_stream(
        sps, arrs, make_policy(policy, weights=weights), links=links)
    _assert_mt_agree(mt_exec, mt_sim)


def test_differential_two_tenants_service_cost_wdrr():
    """WDRR with the service-time cost model (heavier tasks charge more
    deficit) still pins executor to simulator."""
    plans, arrs, _ = _tenant_mix(3, n_hops=2, n_tenants=2)
    pol = lambda: WeightedDeficitRoundRobin(
        weights=[1.0, 3.0], quantum=2e-3, cost_fn=service_time_cost)
    mt_exec = run_multitenant_async(plans, arrs, policy=pol())
    sps = [[p.as_sim_plan(2) for p in ps] for ps in plans]
    mt_sim = sim.simulate_multitenant_stream(sps, arrs, pol())
    _assert_mt_agree(mt_exec, mt_sim)


# ------------------------------------------------ conservation / ordering
@pytest.mark.parametrize("policy", POLICIES)
def test_admission_conserves_tasks_and_tenant_fifo(policy):
    """No task lost or duplicated; per-tenant order strictly FIFO — in
    both the executor's recorded order and the simulator's."""
    for seed in range(4):
        plans, arrs, weights = _tenant_mix(seed + 20, n_hops=2)
        mt = run_multitenant_async(plans, arrs, policy=policy,
                                   weights=weights)
        expected = {(t, i) for t in range(len(plans))
                    for i in range(len(plans[t]))}
        assert set(mt.order) == expected
        assert len(mt.order) == len(expected)
        for t in range(len(plans)):
            idxs = [i for (tt, i) in mt.order if tt == t]
            assert idxs == sorted(idxs)


def test_single_tenant_any_policy_matches_plain_stream():
    """With one tenant every admission policy degenerates to the plain
    single-stream pipeline."""
    from repro.serving.async_engine import run_pipeline_async

    plans = _rand_plans(11, 25, 2)
    arrs = np.cumsum(np.random.RandomState(11).uniform(
        0, 2e-3, len(plans))).tolist()
    ref = run_pipeline_async(plans, arrivals=arrs)
    for policy in POLICIES:
        mt = run_multitenant_async([plans], [arrs], policy=policy)
        _assert_timelines_agree(ref, result_from_stream(mt.stream))


def test_wdrr_weight_shares_under_backlog():
    """Two permanently backlogged tenants with weights 3:1 are served
    ~3:1 within any admission-order window."""
    n = 80
    plans = [[TaskPlan(1e-3, 0.5e-3, 1e-3) for _ in range(n)]
             for _ in range(2)]
    arrs = [[0.0] * n, [0.0] * n]
    mt = run_multitenant_async(plans, arrs, policy="wdrr",
                               weights=[3.0, 1.0])
    window = mt.order[:40]  # both tenants still backlogged here
    n0 = sum(1 for (t, _) in window if t == 0)
    assert 27 <= n0 <= 33, f"expected ~3:1 service split, tenant0={n0}/40"


def test_bounded_queues_multitenant_backpressure():
    """Bounded hop queues: every task still completes exactly once, in
    per-tenant FIFO order, and backpressure can only delay completions."""
    plans, arrs, weights = _tenant_mix(5, n_hops=2)
    free = run_multitenant_async(plans, arrs, policy="rr", weights=weights)
    tight = run_multitenant_async(plans, arrs, policy="rr",
                                  weights=weights, queue_capacity=1)
    assert set(tight.order) == set(free.order)
    for t in range(len(plans)):
        da = free.tenant_view(t)[1]
        _, db, exits = tight.tenant_view(t)
        assert all(x1 >= x0 - TOL for x0, x1 in zip(da, db))
        # full-pipeline tasks finish in per-tenant FIFO order (an early
        # exit may legitimately complete before an earlier full task)
        full = [d for d, e in zip(db, exits) if not e]
        assert full == sorted(full)


# -------------------------------------------------- engine level
def _stage_times(n_hops):
    if n_hops == 1:
        # fast uplink: the end device stays the binding stage, so the
        # admission gate (not the link) shapes contention
        return StageTimes(T_e=2e-3, T_t=0.8e-3, T_c=1.2e-3, T_t_par=0,
                          T_c_par=0, latency=4e-3, first_tx_offset=2e-3,
                          cloud_start_offset=0.8e-3), \
            [LinkProfile("uplink", 200e6)]
    if n_hops == 2:
        st = StageTimes(
            T_e=2e-3, T_t=4e-3, T_c=2e-3, T_t_par=0.0, T_c_par=0.0,
            latency=9e-3, first_tx_offset=2e-3, cloud_start_offset=3e-3,
            compute=(2e-3, 1.5e-3, 2e-3), link=(3e-3, 1e-3),
            link_par=(0.0, 0.0), compute_par=(0.0, 0.0),
            tx_offsets=(2e-3, 1.5e-3), rx_offsets=(3e-3, 1e-3))
        links = [LinkProfile("uplink", 20e6), LinkProfile("backhaul", 900e6)]
        return st, links
    st = StageTimes(
        T_e=2e-3, T_t=5e-3, T_c=1.5e-3, T_t_par=0.0, T_c_par=0.0,
        latency=12e-3, first_tx_offset=2e-3, cloud_start_offset=3e-3,
        compute=(2e-3, 1.2e-3, 1.0e-3, 1.5e-3), link=(3e-3, 1e-3, 1e-3),
        link_par=(0.0, 0.0, 0.0), compute_par=(0.0, 0.0, 0.0),
        tx_offsets=(2e-3, 1.2e-3, 1.0e-3), rx_offsets=(3e-3, 1e-3, 1e-3))
    links = [LinkProfile("uplink", 20e6), LinkProfile("mid", 400e6),
             LinkProfile("backhaul", 900e6)]
    return st, links


def _mk_stream(seed):
    stream = CorrelatedTaskStream(n_labels=30, dim=48,
                                  correlation="medium", seed=seed)
    feats, labels = make_calibration_set(stream, 400)

    def classify(task):
        d = np.linalg.norm(stream.mu - task.features[None], axis=1)
        return task.features, int(np.argmin(d))

    return stream, feats, labels, classify


def _mk_mt_engine(n_hops, tenants, policy, seed=4, hop_exit=False):
    st, links = _stage_times(n_hops)
    if hop_exit:
        stream = CorrelatedTaskStream(n_labels=30, dim=48,
                                      correlation="medium", seed=seed,
                                      n_probe_depths=n_hops)
        sets = make_hop_calibration_sets(stream, 400, n_depths=n_hops)
        feats, labels = sets[0]
        hop_calib = sets[1:]

        def classify(task):
            d = np.linalg.norm(stream.mu - task.features[None], axis=1)
            return task.hop_features, int(np.argmin(d))
    else:
        stream, feats, labels, classify = _mk_stream(seed)
        hop_calib = None
    eng = MultiTenantCoachEngine(
        None, st, END, links[0], CLOUD, n_labels=30, calib_feats=feats,
        calib_labels=labels, tenants=tenants, policy=policy,
        boundary_elems=50_000, links=links, hop_calib=hop_calib)
    return eng, stream, classify


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("n_hops", [2, 3])
def test_engine_timeline_pinned_to_multitenant_simulator(policy, n_hops):
    """Acceptance: MultiTenantCoachEngine's virtual-clock timeline —
    per-task completions, busy intervals, bubble fractions, per-tenant
    latencies — equals the extended core/sim multi-tenant simulator at
    1e-6, for 3 tenants on 2- and 3-hop chains, under all policies."""
    tenants = [
        TenantSpec("interactive", 50, arrival_period=4e-3, weight=4.0),
        TenantSpec("burst", 60, arrivals=(0.0,) * 60, weight=1.0),
        TenantSpec("steady", 40, arrival_period=6e-3, weight=2.0),
    ]
    eng, stream, classify = _mk_mt_engine(n_hops, tenants, policy)
    tasks = [stream.tasks(t.n_tasks) for t in tenants]
    mt = eng.run_streams([list(ts) for ts in tasks], classify)
    ref = sim.simulate_multitenant_stream(
        mt.plans, mt.arrivals,
        make_policy(policy, weights=[t.weight for t in tenants]),
        links=eng.links)
    assert mt.order == ref.order
    _assert_timelines_agree(result_from_stream(ref.stream), mt.pipeline)
    for t in range(len(tenants)):
        la = [rec.latency for rec in mt.reports[t].stats.pipeline.tasks]
        lb = ref.tenant_latencies(t)
        assert all(abs(a - b) < TOL for a, b in zip(la, lb))
        # and the tenant-sliced pipeline view agrees with re-slicing
        pr = tenant_pipeline_result(ref, t)
        _assert_timelines_agree(pr, mt.reports[t].stats.pipeline)


@pytest.mark.parametrize("policy", POLICIES)
def test_mt_engine_hop_exit_pinned_to_simulator(policy):
    """Acceptance: with per-hop probes calibrated per tenant, tasks exit
    at hop 1 of the 3-hop chain and the multi-tenant engine's timeline
    still equals the extended simulator replay at 1e-6 — per-resource
    intervals (which now skip slots per-resource, not uniformly) and
    per-tenant latencies included."""
    tenants = [
        TenantSpec("interactive", 50, arrival_period=4e-3, weight=4.0),
        TenantSpec("burst", 50, arrivals=(0.0,) * 50, weight=1.0),
    ]
    eng, stream, classify = _mk_mt_engine(2, tenants, policy, seed=4,
                                          hop_exit=True)
    tasks = [stream.tasks(t.n_tasks) for t in tenants]
    mt = eng.run_streams([list(ts) for ts in tasks], classify)
    # the merged stream contains genuine mid-pipeline exits
    hist = mt.pipeline.exit_hop_counts()
    assert hist.get(1, 0) > 0, hist
    ref = sim.simulate_multitenant_stream(
        mt.plans, mt.arrivals,
        make_policy(policy, weights=[t.weight for t in tenants]),
        links=eng.links)
    assert mt.order == ref.order
    _assert_timelines_agree(result_from_stream(ref.stream), mt.pipeline)
    merged = {}
    for t in range(len(tenants)):
        la = [rec.latency for rec in mt.reports[t].stats.pipeline.tasks]
        lb = ref.tenant_latencies(t)
        assert all(abs(a - b) < TOL for a, b in zip(la, lb))
        pr = tenant_pipeline_result(ref, t)
        _assert_timelines_agree(pr, mt.reports[t].stats.pipeline)
        assert mt.reports[t].stats.exit_hops == pr.exit_hop_counts()
        for k, v in mt.reports[t].stats.exit_hops.items():
            merged[k] = merged.get(k, 0) + v
    # per-tenant exit histograms are real (not vacuously empty) and sum
    # to the merged chain's histogram
    assert merged == hist and merged.get(1, 0) > 0


@pytest.mark.parametrize("policy", ["fifo", "wdrr"])
def test_cotenancy_never_changes_decisions(policy):
    """Decision isolation: a tenant's decision sequence (exit ratio,
    bits, accuracy, wire volume) under contention equals its solo run —
    co-tenancy can only move timing."""
    tenants = [
        TenantSpec("a", 80, arrival_period=3e-3, weight=1.0),
        TenantSpec("b", 60, arrivals=(0.0,) * 60, weight=2.0),
    ]
    eng, stream, classify = _mk_mt_engine(2, tenants, policy, seed=6)
    tasks = [stream.tasks(t.n_tasks) for t in tenants]
    mt = eng.run_streams([list(ts) for ts in tasks], classify)
    for t, spec in enumerate(tenants):
        solo_eng, _, _ = _mk_mt_engine(2, [spec], policy, seed=6)
        solo = solo_eng.run_streams([list(tasks[t])], classify)
        a, b = mt.reports[t].stats, solo.reports[0].stats
        assert a.exit_ratio == b.exit_ratio
        assert a.mean_bits == b.mean_bits
        assert a.accuracy == b.accuracy
        assert abs(a.wire_kb_per_task - b.wire_kb_per_task) < 1e-9


def test_wdrr_protects_tight_slo_tenant_against_burst():
    """The bench's fairness story: a bursty batch tenant blows the
    interactive tenant's p99 under FIFO; WDRR keeps every tenant inside
    its own SLO (worst SLO-normalized p99 measurably better), while raw
    worst-tenant p99 stays FIFO-favored (work conservation: the burst's
    self-queueing floors it)."""
    single = 4e-3
    burst = tuple(np.repeat(np.arange(5) * 120e-3, 25))
    tenants = [
        TenantSpec("interactive", 40, arrival_period=15e-3, weight=4.0,
                   slo_latency=4 * single),
        TenantSpec("batch", len(burst), arrivals=burst, weight=1.0,
                   slo_latency=100 * single),
        TenantSpec("steady", 60, arrival_period=10e-3, weight=2.0,
                   slo_latency=12 * single),
    ]
    stats = {}
    for policy in ("fifo", "wdrr"):
        eng, stream, classify = _mk_mt_engine(1, tenants, policy, seed=4)
        tasks = [stream.tasks(t.n_tasks) for t in tenants]
        stats[policy] = eng.run_streams([list(ts) for ts in tasks], classify)
    f, w = stats["fifo"], stats["wdrr"]
    # interactive tenant rescued: raw p99 improves by > 2x
    assert w.reports[0].stats.pipeline.p99_latency \
        < 0.5 * f.reports[0].stats.pipeline.p99_latency
    # worst SLO-normalized p99 measurably better under WDRR
    assert w.worst_tenant_norm_p99 < 0.5 * f.worst_tenant_norm_p99
    assert f.worst_tenant_norm_p99 > 1.0  # FIFO actually violates an SLO
    assert w.worst_tenant_norm_p99 < 1.0  # WDRR meets every SLO here
    assert w.min_slo_attainment >= f.min_slo_attainment
    # work conservation: the batch tenant's self-inflicted p99 floors the
    # raw worst-tenant view, which FIFO minimizes
    assert w.worst_tenant_p99 >= f.worst_tenant_p99 - TOL


def test_engine_run_is_deterministic():
    tenants = [TenantSpec("a", 30, arrival_period=3e-3),
               TenantSpec("b", 30, arrivals=(0.0,) * 30)]
    runs = []
    for _ in range(2):
        eng, stream, classify = _mk_mt_engine(2, tenants, "wdrr", seed=9)
        tasks = [stream.tasks(t.n_tasks) for t in tenants]
        runs.append(eng.run_streams([list(ts) for ts in tasks], classify))
    assert runs[0].order == runs[1].order
    d0 = [r.done for r in runs[0].pipeline.tasks]
    d1 = [r.done for r in runs[1].pipeline.tasks]
    assert d0 == d1
