"""CoachEngine integration: offline + online + pipeline over a task stream."""

import numpy as np
import pytest

from repro.core.costs import (A6000_SERVER, JETSON_NX, WIFI_5GHZ,
                              transformer_graph)
from repro.core.partitioner import coach_offline
from repro.core.schedule import StageTimes
from repro.data.pipeline import CorrelatedTaskStream, make_calibration_set
from repro.serving.engine import CoachEngine


def _engine(correlation="medium", mbps=20.0, seed=0):
    st = StageTimes(T_e=2e-3, T_t=3e-3, T_c=2e-3, T_t_par=0, T_c_par=0,
                    latency=7e-3, first_tx_offset=2e-3, cloud_start_offset=3e-3)
    stream = CorrelatedTaskStream(n_labels=30, dim=48,
                                  correlation=correlation, seed=seed)
    feats, labels = make_calibration_set(stream, 400)
    eng = CoachEngine(None, st, JETSON_NX, WIFI_5GHZ(mbps), A6000_SERVER,
                      n_labels=30, calib_feats=feats, calib_labels=labels,
                      boundary_elems=50_000)
    return eng, stream


def _classify(stream):
    def f(task):
        # proxy cloud classifier: nearest true (undrifted) class center
        d = np.linalg.norm(stream.mu - task.features[None], axis=1)
        return task.features, int(np.argmin(d))
    return f


def test_engine_runs_and_accounts():
    eng, stream = _engine()
    stats = eng.run_stream(stream.tasks(300), arrival_period=3e-3,
                           classify=_classify(stream))
    assert 0 <= stats.exit_ratio <= 1
    assert stats.accuracy > 0.7
    assert stats.pipeline.throughput > 0
    assert stats.pipeline.mean_latency > 0


def test_exit_ratio_ordering_across_correlation():
    rs = {}
    for corr in ("low", "medium", "high"):
        eng, stream = _engine(corr, seed=3)
        stats = eng.run_stream(stream.tasks(500), arrival_period=3e-3,
                               classify=_classify(stream))
        rs[corr] = stats.exit_ratio
    assert rs["low"] < rs["medium"] < rs["high"]


def test_higher_correlation_lowers_latency_and_wire():
    eng_l, stream_l = _engine("low", seed=5)
    eng_h, stream_h = _engine("high", seed=5)
    s_l = eng_l.run_stream(stream_l.tasks(400), 3e-3, _classify(stream_l))
    s_h = eng_h.run_stream(stream_h.tasks(400), 3e-3, _classify(stream_h))
    assert s_h.pipeline.mean_latency < s_l.pipeline.mean_latency
    assert s_h.wire_kb_per_task < s_l.wire_kb_per_task


def test_bandwidth_drop_raises_bits_pressure():
    """At lower bandwidth Eq. 11 picks fewer bits (link is the bottleneck)."""
    eng_hi, st_hi = _engine(mbps=100.0, seed=7)
    eng_lo, st_lo = _engine(mbps=5.0, seed=7)
    s_hi = eng_hi.run_stream(st_hi.tasks(300), 3e-3, _classify(st_hi))
    s_lo = eng_lo.run_stream(st_lo.tasks(300), 3e-3, _classify(st_lo))
    assert s_lo.mean_bits <= s_hi.mean_bits
