"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes / dtypes / bit-widths, plus hypothesis properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.boundary import fused_boundary
from repro.kernels.uaq import uaq_dequantize, uaq_quantize
from repro.kernels.semantic_cache import semantic_probe

SHAPES = [(8, 128), (256, 256), (512, 768), (64, 260), (1024, 130 * 2)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_uaq_kernel_matches_ref(bits, shape, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(0), shape) * 3 + 1).astype(dtype)
    p, s, z = uaq_quantize(x, bits, interpret=True)
    pr, sr, zr = ref.uaq_quantize_ref(x, bits)
    # scale may differ by 1 ulp -> allow off-by-one quanta on the exact .5
    # rounding ties; bf16's coarse mantissa hits ties ~10x more often
    q = ref.unpack4_ref(p) if bits == 4 else p
    qr = ref.unpack4_ref(pr) if bits == 4 else pr
    diff = np.abs(q.astype(np.int32) - qr.astype(np.int32))
    # a 1-ulp scale difference can shift zp by 1 AND flip a rounding tie
    assert diff.max() <= (2 if dtype == jnp.bfloat16 else 1)
    assert (diff != 0).mean() < (1e-2 if dtype == jnp.bfloat16 else 1e-3)
    np.testing.assert_allclose(s, sr, rtol=1e-6)
    # roundtrip against the kernel dequant
    y = uaq_dequantize(p, s, z, bits, interpret=True)
    yr = ref.uaq_dequantize_ref(p, s, z, bits)
    np.testing.assert_allclose(y, yr, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("bits", [4, 8])
def test_uaq_roundtrip_error_bound(bits):
    x = jax.random.normal(jax.random.PRNGKey(1), (128, 512))
    p, s, z = uaq_quantize(x, bits, interpret=True)
    y = uaq_dequantize(p, s, z, bits, interpret=True)
    # UAQ error bounded by half a quantum per element
    err = jnp.abs(y - x)
    assert float(jnp.max(err / s)) <= 0.5 + 1e-3


@given(st.integers(1, 6), st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_pack4_roundtrip_property(rows_p, cols_p, seed):
    rows, cols = rows_p * 4, cols_p * 2
    q = jax.random.randint(jax.random.PRNGKey(seed), (rows, cols), 0, 16
                           ).astype(jnp.uint8)
    packed = ref.pack4_ref(q)
    assert packed.shape == (rows, cols // 2)
    np.testing.assert_array_equal(ref.unpack4_ref(packed), q)


@pytest.mark.parametrize("B,S,D,L", [(4, 64, 128, 10), (16, 1024, 128, 100),
                                     (8, 512, 256, 37),
                                     # non-divisible B / S: exercised via
                                     # zero-padding (exact, see kernel doc)
                                     (6, 100, 128, 10), (13, 700, 64, 7),
                                     (1, 1, 32, 3)])
def test_semantic_probe_matches_ref(B, S, D, L):
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, D))
    c = jax.random.normal(jax.random.PRNGKey(1), (L, D))
    sep, best, sims = semantic_probe(x, c, interpret=True)
    sep_r, best_r, sims_r = ref.semantic_probe_ref(x, c)
    np.testing.assert_array_equal(best, best_r)
    np.testing.assert_allclose(sims, sims_r, atol=1e-5)
    np.testing.assert_allclose(sep, sep_r, rtol=1e-4, atol=1e-5)


def test_ops_wrappers_nd():
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 32, 256))
    p, s, z = ops.quantize_activation(x, 8)
    assert p.shape == (4, 32, 256) and s.shape == (4, 32, 1)
    y = ops.dequantize_activation(p, s, z, 8)
    assert y.shape == x.shape
    assert float(jnp.max(jnp.abs(y - x))) < float(jnp.max(s)) * 0.51


def test_probe_sims_in_range():
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 128, 64))
    c = jax.random.normal(jax.random.PRNGKey(4), (12, 64))
    _, _, sims = ops.probe_cache(x, c)
    assert float(jnp.min(sims)) >= -1e-6 and float(jnp.max(sims)) <= 1 + 1e-6


@given(st.integers(1, 32), st.integers(1, 129), st.sampled_from([4, 8]),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=40, deadline=None)
def test_uaq_roundtrip_error_property(m, n, bits, seed):
    """Quantize -> dequantize through the shared entry points stays
    within half a quantum per element, for random shapes including odd
    channel counts at int4 (zero-nibble pad + true-N slice)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, n)) * 5.0
    p, s, z = ops.quantize_activation(x, bits, use_kernel=False)
    assert p.shape == (m, (n + 1) // 2 if bits == 4 else n)
    y = ops.dequantize_activation(p, s, z, bits, use_kernel=False,
                                  channels=n)
    assert y.shape == x.shape
    err = np.abs(np.asarray(y) - np.asarray(x))
    # degenerate (constant) rows hit the 1e-8 scale floor, where zp's
    # float32 rounding granularity dominates — hence the absolute slack
    bound = np.asarray(s) * 0.5 * (1 + 1e-3) + 1e-6
    assert (err <= bound).all()


@pytest.mark.parametrize("N", [5, 129, 255])
def test_uaq_int4_odd_channels_kernel(N):
    """Regression: the int4 wire kernel accepts odd channel counts (pad
    lives in the packed payload only; scale/zp are exact on the true N)."""
    x = jax.random.normal(jax.random.PRNGKey(7), (16, N)) * 2.0
    p, s, z = uaq_quantize(x, 4, interpret=True)
    assert p.shape == (16, (N + 1) // 2)
    pr, sr, zr = ref.uaq_quantize_ref(x, 4)
    np.testing.assert_allclose(s, sr, rtol=1e-6)
    np.testing.assert_allclose(z, zr, atol=1)
    y = uaq_dequantize(p, s, z, 4, n=N, interpret=True)
    assert y.shape == x.shape
    err = np.abs(np.asarray(y) - np.asarray(x))
    assert (err <= np.asarray(s) * 0.5 * (1 + 1e-3)).all()


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("B,S,D,L", [(4, 64, 128, 10), (8, 512, 256, 37),
                                     (2, 100, 65, 5), (1, 1, 32, 3)])
def test_fused_boundary_equals_composition(B, S, D, L, bits):
    """The single-pass fused boundary kernel reproduces the two-pass
    composition (uaq_quantize over tokens + semantic_probe over the
    activation) it replaces, in interpret mode."""
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, D))
    c = jax.random.normal(jax.random.PRNGKey(1), (L, D))
    payload, scale, zp, feat, sep, best, sims = \
        fused_boundary(x, c, bits, interpret=True)
    # --- wire half: per-token UAQ quantize + pack
    p_u, s_u, z_u = uaq_quantize(x.reshape(B * S, D), bits, interpret=True)
    np.testing.assert_allclose(scale.reshape(-1, 1), s_u, rtol=1e-6)
    q = ref.unpack4_ref(payload.reshape(B * S, -1)) if bits == 4 \
        else payload.reshape(B * S, -1)
    q_u = ref.unpack4_ref(p_u) if bits == 4 else p_u
    diff = np.abs(q.astype(np.int32) - q_u.astype(np.int32))
    assert diff.max() <= 1  # 1-ulp scale ties, as in the unfused sweep
    assert (diff != 0).mean() < 1e-3
    # --- probe half: GAP + cosine + top-2 separability
    sep_p, best_p, sims_p = semantic_probe(x, c, interpret=True)
    np.testing.assert_array_equal(best, best_p)
    np.testing.assert_allclose(sims, sims_p, atol=1e-5)
    np.testing.assert_allclose(sep, sep_p, rtol=1e-4, atol=1e-5)
    # --- and bit-for-bit against the jitted exact reference on the wire
    # fields (the runtime's off-TPU fallback path)
    jref = jax.jit(lambda a, b: ref.fused_boundary_ref(a, b, bits))
    pr, sr, zr, fr, sep_r, best_r, sims_r = jref(x, c)
    np.testing.assert_array_equal(np.asarray(payload), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(scale), np.asarray(sr))
    np.testing.assert_array_equal(np.asarray(zp), np.asarray(zr))
    np.testing.assert_array_equal(np.asarray(best), np.asarray(best_r))
    if S <= 512:  # single S block: GAP accumulation order matches too
        np.testing.assert_array_equal(np.asarray(feat), np.asarray(fr))
        np.testing.assert_array_equal(np.asarray(sims), np.asarray(sims_r))
        np.testing.assert_array_equal(np.asarray(sep), np.asarray(sep_r))
