"""Online component: Eq. 7-11 math, threshold calibration, exit behavior."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import online as ON
from repro.data.pipeline import CorrelatedTaskStream, make_calibration_set


def test_eq7_running_mean():
    c = ON.SemanticCache(2, 3, max_count=None)
    feats = np.array([[1., 0, 0], [0, 1, 0], [0, 0, 1]])
    for f in feats:
        c.update(f, 0)
    np.testing.assert_allclose(c.centers[0], feats.mean(0))
    assert c.counts[0] == 3


def test_eq7_bounded_window_tracks_drift():
    cu = ON.SemanticCache(1, 2, max_count=None)
    cb = ON.SemanticCache(1, 2, max_count=8)
    for t in range(200):
        f = np.array([t / 10.0, 0.0])
        cu.update(f, 0)
        cb.update(f, 0)
    # bounded cache stays near the recent values; unbounded lags at the mean
    assert abs(cb.centers[0][0] - 19.9) < 1.0
    assert abs(cu.centers[0][0] - 19.9) > 5.0


@given(st.integers(0, 1000), st.integers(2, 30))
@settings(max_examples=30, deadline=None)
def test_separability_properties(seed, n):
    rng = np.random.default_rng(seed)
    sims = rng.uniform(0, 1, n)
    s = ON.separability(sims)
    assert s >= 0
    # identical top-2 => zero separability
    sims[:2] = 0.7
    t = np.sort(sims)[::-1]
    if t[0] == t[1]:
        assert ON.separability(sims) == 0.0


def test_separability_higher_for_cleaner_argmax():
    base = np.full(10, 0.4)
    weak = base.copy(); weak[3] = 0.45
    strong = base.copy(); strong[3] = 0.9
    assert ON.separability(strong) > ON.separability(weak)


def test_cosine_range_and_selfsim():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(5, 8))
    sims = ON.cosine(a, a)
    assert np.all(sims >= -1e-9) and np.all(sims <= 1 + 1e-9)
    np.testing.assert_allclose(np.diag(sims), 1.0, atol=1e-9)


def test_calibration_exit_error_bound():
    stream = CorrelatedTaskStream(n_labels=20, dim=48, correlation="medium",
                                  seed=0)
    feats, labels = make_calibration_set(stream, 500)
    cache = ON.SemanticCache(20, 48)
    cache.warm_up(feats, labels)
    th = ON.calibrate_thresholds(cache, feats, labels, eps=0.005)
    # on the calibration set itself, exits above s_ext err <= eps
    wrong = total = 0
    for f, y in zip(feats, labels):
        sims = cache.similarities(f)
        if ON.separability(sims) > th.s_ext:
            total += 1
            wrong += int(np.argmax(sims) != y)
    assert total == 0 or wrong / total <= 0.005 + 1e-9


@given(st.integers(3, 8), st.floats(1e5, 1e8), st.floats(1e-4, 1e-1),
       st.floats(1e-4, 1e-1))
@settings(max_examples=50, deadline=None)
def test_choose_bits_eq11(q_r, bw, t_e, t_c):
    elems = 100_000
    b = ON.choose_bits(q_r, elems, bw, t_e, t_c)
    assert b >= q_r
    # optimality among levels: distance to the non-transmission bound
    levels = [x for x in (3, 4, 5, 6, 8, 12, 16) if x >= q_r]
    obj = lambda bb: abs(elems * bb / bw - max(t_e, t_c))
    assert obj(b) <= min(obj(x) for x in levels) + 1e-12


@given(st.integers(3, 8), st.floats(1e5, 1e8), st.floats(1e5, 1e8),
       st.floats(1e-4, 1e-1), st.floats(1e-4, 1e-1))
@settings(max_examples=60, deadline=None)
def test_choose_bits_monotone_in_bandwidth(q_r, bw_a, bw_b, t_e, t_c):
    """Target-chasing is monotone: more bandwidth never picks fewer bits
    (the Eq. 11 optimum tracks target * bw / elems over a fixed grid)."""
    bw_lo, bw_hi = sorted((bw_a, bw_b))
    elems = 100_000
    assert (ON.choose_bits(q_r, elems, bw_lo, t_e, t_c)
            <= ON.choose_bits(q_r, elems, bw_hi, t_e, t_c))


def _hop_sched(hop_elems, stage_compute):
    cache = ON.SemanticCache(2, 4)
    th = ON.Thresholds(s_ext=float("inf"), s_adj=((0.0, 8),))
    return ON.OnlineScheduler(cache, th, hop_elems[0], stage_compute[0],
                              stage_compute[-1], hop_elems=hop_elems,
                              stage_compute=stage_compute)


@given(st.integers(3, 8), st.floats(1e5, 1e8), st.floats(1e5, 1e8))
@settings(max_examples=40, deadline=None)
def test_choose_hop_bits_degrades_gracefully_without_hop_ema(q_r, bw0, bw1):
    """A hop whose EMA is missing falls back to the end uplink's EMA (the
    only measurement the classic engine takes); once observed, the hop
    chases its own estimate.  Every hop's choice respects Q_c >= Q_r."""
    sched = _hop_sched((10_000, 5_000), (1e-3, 1.5e-3, 1e-3))
    sched.observe_bandwidth(bw0)
    missing = sched.choose_hop_bits(q_r)
    assert len(missing) == 2 and all(b >= q_r for b in missing)
    assert missing[1] == ON.choose_bits(q_r, 5_000, bw0, 1.5e-3, 1e-3)
    sched.observe_hop_bandwidth(1, bw1)
    with_ema = sched.choose_hop_bits(q_r)
    assert with_ema[1] == ON.choose_bits(
        q_r, 5_000, sched.hop_bw_ema[1], 1.5e-3, 1e-3)
    # hop 0 is untouched by hop-1 observations
    assert with_ema[0] == missing[0]


@given(st.integers(0, 1000), st.integers(2, 6), st.integers(4, 32))
@settings(max_examples=40, deadline=None)
def test_cache_centers_stay_unit_scale_under_drift(seed, n_labels, dim):
    """Eq. 7 with a bounded window is a convex combination, so centers
    never leave the scale of the (drifting) feature stream."""
    rng = np.random.default_rng(seed)
    c = ON.SemanticCache(n_labels, dim, max_count=16)
    max_norm = 0.0
    drift = rng.normal(size=dim) * 0.05
    for t in range(200):
        f = rng.normal(size=dim) + drift * t   # random walk of the scene
        max_norm = max(max_norm, float(np.linalg.norm(f)))
        c.update(f, int(rng.integers(n_labels)))
    for j in range(n_labels):
        assert np.linalg.norm(c.centers[j]) <= max_norm + 1e-9


def test_exit_ratio_increases_with_correlation():
    ratios = {}
    for corr in ("low", "medium", "high"):
        stream = CorrelatedTaskStream(n_labels=30, dim=48, correlation=corr,
                                      seed=3)
        feats, labels = make_calibration_set(stream, 400)
        cache = ON.SemanticCache(30, 48)
        cache.warm_up(feats, labels)
        th = ON.calibrate_thresholds(cache, feats, labels)
        sched = ON.OnlineScheduler(cache, th, 10_000, 1e-3, 1e-3)
        ex = 0
        for t in stream.tasks(600):
            d = sched.step(t.features, bandwidth_bps=20e6)
            if d.early_exit:
                ex += 1
            else:
                sched.report_label(t.features, t.label)
        ratios[corr] = ex / 600
    assert ratios["low"] < ratios["medium"] < ratios["high"]


def test_required_bits_decreasing_in_separability():
    th = ON.Thresholds(s_ext=10.0, s_adj=((0.8, 3), (0.5, 4), (0.2, 6)))
    assert th.required_bits(0.9) == 3
    assert th.required_bits(0.6) == 4
    assert th.required_bits(0.3) == 6
    assert th.required_bits(0.05) == 8  # default
