"""Continuous micro-batching: differential pins + unit coverage.

The tentpole contract: with per-tier ``batch_caps``, the event-driven
executor (``AsyncHopPipeline``, virtual clock) and the arithmetic
simulator (``sim.simulate_stream`` -> staged batched replay) apply the
SAME greedy drain-up-to-cap-or-deadline batch formation rule — shared
helpers ``sim.greedy_batch_size`` / ``sim.batched_service_time`` make
the float arithmetic identical — so their timelines agree to 1e-6 on
2-/3-hop chains, caps {1, 2, 4, mixed}, mid-pipeline exits, staleness
deadlines, and dynamic-bandwidth links.  ``cap = 1`` must reproduce the
unbatched replay bit-identically (singleton batches fall through to the
legacy code paths on both sides).

On top of that: ``HopQueue.get_nowait/drain/snapshot`` semantics
(including the drain-must-snapshot-at-wake race the batching worker
fixes), the auto batch-size finder (geometric-then-binary probe) against
brute force, engine-level sync == async pins with batching configured,
and the multi-tenant engines (tier 0 clamped to cap 1 on both sides).
"""

import asyncio

import numpy as np
import pytest

from repro.core import sim
from repro.core.costs import DeviceProfile, LinkProfile
from repro.core.pipeline import (TaskPlan, bandwidth_step_trace,
                                 result_from_stream, run_pipeline)
from repro.core.schedule import StageTimes
from repro.data.pipeline import CorrelatedTaskStream, make_calibration_set
from repro.serving.async_engine import (AsyncCoachEngine, HopQueue,
                                        VirtualClock, run_pipeline_async)
from repro.serving.base import EngineConfig
from repro.serving.batching import (auto_batch_caps, find_batch_cap,
                                    realized_batch_sizes)
from repro.serving.engine import CoachEngine
from repro.serving.tenancy import (MultiTenantCoachEngine, TenantSpec,
                                   make_policy, run_multitenant_async)
from tests.test_async_engine import _assert_timelines_agree

TOL = 1e-6

END = DeviceProfile("end", 1e9)
CLOUD = DeviceProfile("cloud", 8e9)


# ----------------------------------------------------------------- helpers
def _batched_plans(seed, n_hops=2, n=40, fixed_frac=0.7, deadline_slack=None,
                   offsets=True):
    """Random multi-hop streams with per-segment fixed costs, mixed
    mid-pipeline exits, optional Fig. 4 overlap offsets, and optional
    per-task staleness deadlines (``arrival + deadline_slack``)."""
    rng = np.random.RandomState(seed)
    plans = []
    for i in range(n):
        comp = rng.uniform(1e-3, 4e-3, n_hops + 1)
        tx = rng.uniform(0.2e-3, 3e-3, n_hops)
        t_fixed = tuple(fixed_frac * c for c in comp)
        deadline = None if deadline_slack is None \
            else i * 2e-3 + deadline_slack
        if rng.rand() < 0.15:
            plans.append(TaskPlan(comp[0], 0.0, 0.0, True,
                                  t_fixed=(t_fixed[0],), deadline=deadline))
            continue
        txo = rxo = None
        if offsets:
            txo = [rng.uniform(0, comp[k]) if rng.rand() < 0.5 else None
                   for k in range(n_hops)]
            rxo = [rng.uniform(0, tx[k]) if rng.rand() < 0.5 else None
                   for k in range(n_hops)]
        exit_hop = None
        if n_hops >= 2 and rng.rand() < 0.25:
            exit_hop = int(rng.randint(1, n_hops))
        plans.append(TaskPlan.multihop(comp, tx, txo, rxo, exit_hop=exit_hop,
                                       t_fixed=t_fixed, deadline=deadline))
    return plans


def _caps(n_hops, variant):
    n_seg = n_hops + 1
    return {
        "all2": [2] * n_seg,
        "all4": [4] * n_seg,
        "mixed": [1, 4] + [2] * (n_seg - 2),
    }[variant]


# ------------------------------------------------ differential: plan level
@pytest.mark.parametrize("variant", ["all2", "all4", "mixed"])
@pytest.mark.parametrize("n_hops", [2, 3])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_differential_batched_chain(variant, n_hops, seed):
    """Acceptance: batched executor == batched simulator at 1e-6 on 2-
    and 3-hop chains, caps {2, 4, mixed}, mid-pipeline exits included."""
    plans = _batched_plans(seed, n_hops=n_hops)
    caps = _caps(n_hops, variant)
    pr_sim = run_pipeline(plans, arrival_period=2e-3, batch_caps=caps)
    pr_async = run_pipeline_async(plans, arrival_period=2e-3,
                                  batch_caps=caps)
    _assert_timelines_agree(pr_sim, pr_async)


@pytest.mark.parametrize("seed", [0, 1])
def test_differential_batched_with_deadlines(seed):
    """Staleness deadlines gate batch formation identically on both
    sides (the deadline check runs inside the shared greedy rule)."""
    plans = _batched_plans(seed, n_hops=2, deadline_slack=3e-3)
    caps = [4, 4, 4]
    pr_sim = run_pipeline(plans, arrival_period=2e-3, batch_caps=caps)
    pr_async = run_pipeline_async(plans, arrival_period=2e-3,
                                  batch_caps=caps)
    _assert_timelines_agree(pr_sim, pr_async)


def test_differential_batched_with_traced_uplink():
    """Dynamic-bandwidth repricing composes with batching: the link
    stage re-integrates each transfer at its actual start on both
    sides, and the retimed hand-off instants still form identical
    batches downstream."""
    uplink = LinkProfile("dyn", 40e6, trace=bandwidth_step_trace(
        [(0.0, 40.0), (0.02, 6.0), (0.08, 60.0)]))
    backhaul = LinkProfile("bh", 900e6)
    plans = _batched_plans(5, n_hops=2)
    caps = [2, 4, 4]
    pr_sim = run_pipeline(plans, arrival_period=2e-3,
                          links=[uplink, backhaul], batch_caps=caps)
    pr_async = run_pipeline_async(plans, arrival_period=2e-3,
                                  links=[uplink, backhaul], batch_caps=caps)
    _assert_timelines_agree(pr_sim, pr_async)


def test_differential_batched_burst_arrivals():
    """All-at-once arrivals (deepest queues -> largest batches): the
    executor's wake-instant snapshot equals the simulator's candidate
    prefix even when every queue is saturated."""
    plans = _batched_plans(11, n_hops=2, n=30)
    arrivals = [0.0] * len(plans)
    caps = [4, 4, 4]
    pr_sim = run_pipeline(plans, arrivals=arrivals, batch_caps=caps)
    pr_async = run_pipeline_async(plans, arrivals=arrivals, batch_caps=caps)
    _assert_timelines_agree(pr_sim, pr_async)
    # saturation makes real multi-task batches: fewer busy intervals
    # than tasks on the batched downstream tiers
    n_t1 = sum(1 for p in plans
               if sim.occupies_compute(p.as_sim_plan(2).exit_hop, 1))
    assert len(pr_sim.compute_intervals[1]) < n_t1


# --------------------------------------------------- cap = 1 bit-identity
@pytest.mark.parametrize("n_hops", [2, 3])
def test_cap_one_is_bit_identical_to_unbatched(n_hops):
    """Acceptance: ``batch_caps`` of all ones reproduces today's
    timelines *bit-identically* (not 1e-6) — the batched entry point
    routes to the untouched legacy replay."""
    for seed in range(3):
        plans = _batched_plans(seed, n_hops=n_hops)
        a = run_pipeline(plans, arrival_period=2e-3)
        b = run_pipeline(plans, arrival_period=2e-3,
                         batch_caps=[1] * (n_hops + 1))
        assert [t.done for t in a.tasks] == [t.done for t in b.tasks]
        assert a.compute_intervals == b.compute_intervals
        assert a.link_intervals == b.link_intervals
        assert a.makespan == b.makespan
        ae = run_pipeline_async(plans, arrival_period=2e-3)
        be = run_pipeline_async(plans, arrival_period=2e-3,
                                batch_caps=[1] * (n_hops + 1))
        assert [t.done for t in ae.tasks] == [t.done for t in be.tasks]
        assert ae.compute_intervals == be.compute_intervals


def test_staged_replay_all_ones_matches_legacy_bitwise():
    """The staged tier-by-tier batched replay with every cap at 1 uses
    the same float expressions as the classic interleaved loop: the
    timelines are equal with ``==`` on the seeds pinned here."""
    for seed in range(3):
        plans = [p.as_sim_plan(2)
                 for p in _batched_plans(seed + 20, n_hops=2)]
        arrivals = [i * 2e-3 for i in range(len(plans))]
        a = sim.simulate_stream(plans, arrivals)
        b = sim._simulate_stream_batched(plans, arrivals, None, [1, 1, 1])
        assert a.done == b.done
        assert a.compute_intervals == b.compute_intervals
        assert a.link_intervals == b.link_intervals


# ---------------------------------------------- batching actually batches
def test_batching_compresses_busy_intervals_and_cuts_makespan():
    """On an overloaded stream with a large fixed fraction, batching
    amortizes the launch cost: fewer busy intervals, smaller makespan,
    conserved task set."""
    plans = _batched_plans(3, n_hops=2, n=40, fixed_frac=0.85,
                           offsets=False)
    arrivals = [i * 0.5e-3 for i in range(len(plans))]
    un = run_pipeline(plans, arrivals=arrivals)
    ba = run_pipeline(plans, arrivals=arrivals, batch_caps=[4, 4, 4])
    assert len(ba.tasks) == len(un.tasks)
    assert [t.exit_hop for t in ba.tasks] == [t.exit_hop for t in un.tasks]
    assert ba.makespan < un.makespan - TOL
    assert sum(len(iv) for iv in ba.compute_intervals) < \
        sum(len(iv) for iv in un.compute_intervals)
    rb = realized_batch_sizes(ba)
    ru = realized_batch_sizes(un)
    assert all(abs(r - 1.0) < 1e-12 for r in ru)
    assert max(rb) > 1.0
    # batch members forward serially, so per-resource FIFO survives:
    # busy intervals stay sorted and disjoint on every resource
    for iv in list(ba.compute_intervals) + list(ba.link_intervals):
        assert sim._sorted_disjoint(iv)


def test_deadline_excludes_overrunning_follower():
    """The staleness gate, white-box: two same-instant tasks on a cap-2
    tier batch together for ``fixed + 2 * marginal`` — unless the
    follower's deadline can't absorb the batched finish, in which case
    it runs solo.  Executor and simulator agree either way."""
    def plans(follower_deadline):
        mk = lambda dl: TaskPlan.multihop(
            (4e-3, 1e-3), (0.5e-3,), t_fixed=(3e-3, 0.0), deadline=dl)
        return [mk(None), mk(follower_deadline)]

    for dl, expected_iv0 in ((5.5e-3, 1), (4.5e-3, 2)):
        pr_sim = run_pipeline(plans(dl), arrivals=[0.0, 0.0],
                              batch_caps=[2, 1])
        pr_async = run_pipeline_async(plans(dl), arrivals=[0.0, 0.0],
                                      batch_caps=[2, 1])
        _assert_timelines_agree(pr_sim, pr_async)
        # batch of 2 costs 3 + 2*1 = 5 ms: a 5.5 ms deadline admits the
        # follower (one tier-0 interval), a 4.5 ms one excludes it (two)
        assert len(pr_sim.compute_intervals[0]) == expected_iv0, dl
        if expected_iv0 == 1:
            s, e = pr_sim.compute_intervals[0][0]
            assert abs((e - s) - 5e-3) < 1e-12
            assert e <= dl + 1e-12


# --------------------------------------------------- shared greedy rule
def _plan(comp, fixed, deadline=None):
    return sim.SimPlan(compute=tuple(comp), tx=(0.0,) * (len(comp) - 1),
                       t_fixed=tuple(fixed), deadline=deadline)


def test_batched_service_time_semantics():
    p1 = _plan([4e-3, 2e-3], [3e-3, 1e-3])
    p2 = _plan([6e-3, 2e-3], [5e-3, 0.5e-3])
    # singleton: exactly compute[k] (bit-identity by construction)
    assert sim.batched_service_time([p1], 0) == p1.compute[0]
    # pair: max fixed + sum of marginals
    got = sim.batched_service_time([p1, p2], 0)
    assert abs(got - (5e-3 + 1e-3 + 1e-3)) < 1e-15
    # batching a pair is cheaper than serial, dearer than one task
    assert p2.compute[0] < got < p1.compute[0] + p2.compute[0]


def test_greedy_batch_size_cap_ready_and_deadline_gates():
    p = lambda dl=None: _plan([4e-3, 1e-3], [3e-3, 0.0], deadline=dl)
    plans = [p(), p(), p(), p()]
    ready = [0.0, 0.0, 0.0, 0.0]
    # cap gate
    assert sim.greedy_batch_size(0, 1, 0.0, plans, ready) == 1
    assert sim.greedy_batch_size(0, 3, 0.0, plans, ready) == 3
    assert sim.greedy_batch_size(0, 8, 0.0, plans, ready) == 4
    # ready gate: formation stops at the first not-yet-ready follower
    # (FIFO prefix — even though plans[3] is ready, it cannot jump ahead)
    assert sim.greedy_batch_size(0, 8, 0.0, plans,
                                 [0.0, 0.0, 1e-6, 0.0]) == 2
    # deadline gate: an n-batch costs 3 + n ms.  A 6 ms follower
    # deadline admits the 3-batch (exactly 6 ms) but blocks the fourth
    # member (7 ms); tightened to 5.5 ms it refuses to join at all
    tight = [p(), p(), p(6e-3), p()]
    assert sim.greedy_batch_size(0, 8, 0.0, tight, ready) == 3
    tighter = [p(), p(), p(5.5e-3), p()]
    assert sim.greedy_batch_size(0, 8, 0.0, tighter, ready) == 2
    # the head itself is never deadline-gated (it must run regardless)
    late = [p(1e-6), p(), p(), p()]
    assert sim.greedy_batch_size(0, 8, 0.0, late, ready) >= 1
    # ... and its (blown) deadline still gates followers
    assert sim.greedy_batch_size(0, 8, 0.0, late, ready) == 1


# ------------------------------------------------------- HopQueue API
def test_hop_queue_get_nowait_and_snapshot():
    clock = VirtualClock()
    q = HopQueue(clock)

    async def main():
        await q.put("a")
        await q.put("b")
        assert q.snapshot() == ("a", "b")   # non-destructive
        assert len(q) == 2
        assert q.get_nowait() == "a"
        assert q.get_nowait() == "b"
        with pytest.raises(asyncio.QueueEmpty):
            q.get_nowait()

    clock.run(main())


def test_hop_queue_drain_is_fifo_and_respects_n():
    clock = VirtualClock()
    q = HopQueue(clock)

    async def main():
        for i in range(5):
            await q.put(i)
        assert q.drain(3) == [0, 1, 2]
        assert q.snapshot() == (3, 4)
        assert q.drain(99) == [3, 4]     # never blocks: takes what's there
        assert q.drain(2) == []

    clock.run(main())


def test_hop_queue_drain_admits_blocked_putters():
    """Each slot freed by ``drain``/``get_nowait`` admits one blocked
    putter, preserving FIFO across the bound."""
    clock = VirtualClock()
    q = HopQueue(clock, maxsize=2)
    landed = []

    async def producer(i):
        await q.put(i)     # producers 2, 3 block (queue holds 0, 1)
        landed.append(i)

    async def consumer():
        await clock.sleep(1.0)          # let all four producers run/block
        assert q.snapshot() == (0, 1)
        assert q.drain(2) == [0, 1]
        # draining freed two slots: both blocked putters were admitted
        assert q.snapshot() == (2, 3)
        assert q.get_nowait() == 2
        assert q.get_nowait() == 3

    async def main():
        ws = [clock.spawn(producer(i)) for i in range(4)]
        ws.append(clock.spawn(consumer()))
        await asyncio.gather(*ws)

    clock.run(main())
    assert sorted(landed) == [0, 1, 2, 3]


def test_hop_queue_snapshot_fixes_membership_against_later_puts():
    """The race ``drain`` documents: items enqueued after the wake
    instant must not join the batch.  A consumer that snapshots, sleeps,
    then drains by the *snapshot* size never sees the late item; sizing
    the drain by ``len(queue)`` at drain time would."""
    clock = VirtualClock()
    q = HopQueue(clock)
    got = {}

    async def early_producer():
        await q.put("early-0")
        await q.put("early-1")

    async def late_producer():
        await clock.sleep(0.5)
        await q.put("late")

    async def consumer():
        await clock.settle()
        n_wake = len(q.snapshot())       # membership fixed at wake: 2
        await clock.sleep(1.0)           # late item lands mid-sleep
        got["len_at_drain"] = len(q)     # the racy size would be 3
        got["batch"] = q.drain(n_wake)

    async def main():
        ws = [clock.spawn(early_producer()), clock.spawn(late_producer()),
              clock.spawn(consumer())]
        await asyncio.gather(*ws)

    clock.run(main())
    assert got["len_at_drain"] == 3
    assert got["batch"] == ["early-0", "early-1"]


# -------------------------------------------------- auto batch-size finder
def _brute_cap(measure, slack, cap_limit):
    base = measure(1)
    best = 1
    for n in range(2, cap_limit + 1):
        if measure(n) - base <= slack:
            best = n
        else:
            break
    return best


@pytest.mark.parametrize("fixed,marginal,slack,cap_limit", [
    (9e-3, 1e-3, 5e-3, 32),    # boundary mid-range
    (9e-3, 1e-3, 0.0, 32),     # no slack -> 1
    (9e-3, 1e-3, 1e-3, 32),    # exactly one extra member
    (5e-3, 0.0, 1e-9, 32),     # free members -> cap_limit
    (9e-3, 1e-3, 5e-3, 1),     # cap_limit = 1 short-circuits
    (9e-3, 1e-3, 4.5e-3, 7),   # non-power-of-two limit
    (1e-3, 3e-3, 7e-3, 16),    # marginal-dominated
])
def test_find_batch_cap_matches_brute_force(fixed, marginal, slack,
                                            cap_limit):
    measure = lambda n: fixed + n * marginal
    assert find_batch_cap(measure, slack, cap_limit) == \
        _brute_cap(measure, slack, cap_limit)


def test_find_batch_cap_probe_count_is_logarithmic():
    """Geometric-then-binary: far fewer probes than the exhaustive
    sweep (the point of the Lightning-style finder)."""
    calls = []
    measure = lambda n: (calls.append(n), 1e-3 * n)[1]
    cap = find_batch_cap(measure, 20e-3, 1024)
    assert cap == _brute_cap(lambda n: 1e-3 * n, 20e-3, 1024) == 21
    assert len(calls) <= 2 * 10 + 2      # ~2 log2(1024), not ~1024


def test_find_batch_cap_general_monotone_measure():
    """Only monotonicity is assumed: a measured (non-affine) profile
    with a sharp knee still lands exactly on the knee."""
    measure = lambda n: 1e-3 * n if n <= 5 else 1e-3 * n + 50e-3
    assert find_batch_cap(measure, 10e-3, 32) == 5


def test_auto_batch_caps_per_tier_split_and_ingress_clamp():
    compute = [4e-3, 4e-3, 4e-3]
    fixed = [3.6e-3, 3.6e-3, 0.0]     # tier 2 has no amortizable part
    # slack 6.1 ms -> ~2.03 ms per tier -> ~5 extra members at 0.4 ms
    # marginal on the high-fixed tiers; the all-marginal tier (4 ms
    # marginal) can't batch at all
    caps = auto_batch_caps(compute, fixed, slack=6.1e-3, cap_limit=32)
    assert caps == [6, 6, 1]
    # a hard ingress clamp (cap <= 1) excludes tier 0 from the split:
    # its unusable 1/3 share is redistributed, so tier 1's budget grows
    # from ~2.03 ms to ~3.05 ms (-> 8 members at 0.4 ms marginal).  The
    # former even split silently wasted the clamped share ([1, 6, 1]).
    caps = auto_batch_caps(compute, fixed, slack=6.1e-3, cap_limit=32,
                           ingress_cap=1)
    assert caps == [1, 8, 1]
    # zero / negative slack: unbatched everywhere
    assert auto_batch_caps(compute, fixed, slack=0.0) == [1, 1, 1]
    assert auto_batch_caps(compute, fixed, slack=-1.0) == [1, 1, 1]


def test_auto_batch_caps_redistribution_is_monotone_downstream():
    """Excluding a clamped ingress from the split can only grow the
    downstream tiers' budgets: every unclamped cap under ``ingress_cap=1``
    is >= its naive even-split counterpart (``find_batch_cap`` is
    monotone in its slack budget)."""
    rng = np.random.RandomState(7)
    for _ in range(50):
        n_seg = int(rng.randint(2, 6))
        compute = rng.uniform(1e-3, 6e-3, n_seg)
        fixed = compute * rng.uniform(0.0, 0.95, n_seg)
        slack = float(rng.uniform(0.0, 20e-3))
        naive = auto_batch_caps(list(compute), list(fixed), slack)
        redis = auto_batch_caps(list(compute), list(fixed), slack,
                                ingress_cap=1)
        assert redis[0] == 1
        for k in range(1, n_seg):
            assert redis[k] >= naive[k]
    # ingress_cap > 1 still clamps but does NOT exclude tier 0 from the
    # split (it can spend some slack), so downstream caps are unchanged
    compute, fixed = [4e-3, 4e-3, 4e-3], [3.6e-3, 3.6e-3, 0.0]
    assert auto_batch_caps(compute, fixed, slack=6.1e-3,
                           ingress_cap=2) == [2, 6, 1]


# ------------------------------------------------------- engine level
def _mk_engine_pair(n_hops, seed=0, **cfg_kw):
    """Sync + async engines sharing one batching-enabled EngineConfig
    (unlike ``test_async_engine._mk_engines``, the sync side gets the
    same config — the batched timelines must agree)."""
    if n_hops == 1:
        st = StageTimes(T_e=2e-3, T_t=3e-3, T_c=2e-3, T_t_par=0,
                        T_c_par=0, latency=7e-3, first_tx_offset=2e-3,
                        cloud_start_offset=3e-3)
        links = None
    else:
        st = StageTimes(
            T_e=2e-3, T_t=4e-3, T_c=2e-3, T_t_par=0.0, T_c_par=0.0,
            latency=9e-3, first_tx_offset=2e-3, cloud_start_offset=3e-3,
            compute=(2e-3, 1.5e-3, 2e-3), link=(3e-3, 1e-3),
            link_par=(0.0, 0.0), compute_par=(0.0, 0.0),
            tx_offsets=(2e-3, 1.5e-3), rx_offsets=(3e-3, 1e-3))
        links = [LinkProfile("uplink", 20e6), LinkProfile("backhaul", 900e6)]
    stream = CorrelatedTaskStream(n_labels=30, dim=48,
                                  correlation="medium", seed=seed)
    feats, labels = make_calibration_set(stream, 400)
    mk = lambda cls: cls(
        None, st, END, LinkProfile("wifi", 20e6), CLOUD, n_labels=30,
        calib_feats=feats, calib_labels=labels, boundary_elems=50_000,
        links=links, cfg=EngineConfig(**cfg_kw))

    def classify(task):
        d = np.linalg.norm(stream.mu - task.features[None], axis=1)
        return task.features, int(np.argmin(d))

    return mk(CoachEngine), mk(AsyncCoachEngine), stream, classify


def test_engine_batched_timeline_sync_equals_async():
    """Acceptance (engine level): a batching-configured AsyncCoachEngine
    stays differentially pinned to the sync reference (which replays the
    same plans through ``core.sim``) at 1e-6."""
    sync, async_, stream, classify = _mk_engine_pair(
        2, seed=6, per_hop_bits=False, queue_capacity=0,
        batch_caps=[2, 4, 4], batch_fixed_frac=0.75, batch_slack=30e-3)
    tasks = stream.tasks(250)
    s = sync.run_stream(list(tasks), arrival_period=1e-3,
                        classify=classify)
    a = async_.run_stream(list(tasks), arrival_period=1e-3,
                          classify=classify)
    _assert_timelines_agree(s.pipeline, a.pipeline)
    # decisions are batching-invariant
    assert a.exit_ratio == s.exit_ratio and a.mean_bits == s.mean_bits
    # the stream is overloaded enough that batches actually formed
    assert max(realized_batch_sizes(a.pipeline)) > 1.0


def test_engine_batching_preserves_decisions_and_cap1_timeline():
    """``batch_caps`` of ones with a fixed-cost calibration is exactly
    the unbatched engine: identical timeline (the t_fixed annotations
    alone change nothing)."""
    _, base, stream, classify = _mk_engine_pair(
        2, seed=3, per_hop_bits=False, queue_capacity=0)
    _, ones, _, _ = _mk_engine_pair(
        2, seed=3, per_hop_bits=False, queue_capacity=0,
        batch_caps=[1, 1, 1], batch_fixed_frac=0.75)
    tasks = stream.tasks(150)
    b = base.run_stream(list(tasks), arrival_period=2e-3,
                        classify=classify)
    o = ones.run_stream(list(tasks), arrival_period=2e-3,
                        classify=classify)
    assert [t.done for t in o.pipeline.tasks] == \
        [t.done for t in b.pipeline.tasks]
    assert o.pipeline.compute_intervals == b.pipeline.compute_intervals


def test_engine_auto_batch_finder_plumbed_through_config():
    """``auto_batch = True`` runs the finder at engine build: the caps
    equal a direct ``auto_batch_caps`` call on the engine's calibrated
    stage times, and a high fixed fraction + generous slack yields real
    (> 1) caps."""
    _, eng, _, _ = _mk_engine_pair(
        2, seed=0, auto_batch=True, batch_fixed_frac=0.9,
        batch_slack=12e-3, batch_cap_limit=16)
    expect = auto_batch_caps(list(eng.st.compute), eng.batch_fixed,
                             12e-3, 16)
    assert eng.batch_caps == expect
    assert max(eng.batch_caps) > 1
    # explicit caps win over the finder
    _, expl, _, _ = _mk_engine_pair(
        2, seed=0, auto_batch=True, batch_caps=[1, 2, 3],
        batch_fixed_frac=0.9, batch_slack=12e-3)
    assert expl.batch_caps == [1, 2, 3]


# ------------------------------------------------------- multi-tenant
@pytest.mark.parametrize("policy", ["fifo", "rr", "wdrr"])
def test_differential_multitenant_batched_plan_level(policy):
    """Batched multi-tenant executor == batched multi-tenant simulator:
    admission order, merged timeline, busy intervals — tier 0 clamped to
    cap 1 on both sides (credit-gated ingress)."""
    rng = np.random.RandomState(17)
    n_hops, caps, weights = 2, [8, 4, 2], [1.0, 2.5, 0.5]
    plans, arrs = [], []
    for t in range(3):
        n = int(rng.randint(6, 14))
        ps, ar = [], []
        tt = float(rng.uniform(0, 2e-3))
        for _ in range(n):
            comp = tuple(rng.uniform(1e-4, 4e-3, n_hops + 1))
            tx = tuple(rng.uniform(0.0, 2e-3, n_hops))
            eh = None if rng.rand() < 0.75 else int(rng.randint(1, n_hops))
            ps.append(TaskPlan.multihop(
                comp, tx, exit_hop=eh,
                t_fixed=tuple(0.7 * c for c in comp), deadline=tt + 8e-3))
            ar.append(tt)
            tt += float(rng.uniform(0, 1.2e-3))
        plans.append(ps)
        arrs.append(ar)
    mt_exec = run_multitenant_async(plans, arrs, policy=policy,
                                    weights=weights, links=[None, None],
                                    batch_caps=caps)
    sps = [[p.as_sim_plan(n_hops) for p in ps] for ps in plans]
    mt_sim = sim.simulate_multitenant_stream(
        sps, arrs, make_policy(policy, weights=weights), batch_caps=caps)
    assert mt_exec.order == mt_sim.order
    _assert_timelines_agree(result_from_stream(mt_sim.stream),
                            result_from_stream(mt_exec.stream))
    for t in range(3):
        la = mt_exec.tenant_latencies(t)
        lb = mt_sim.tenant_latencies(t)
        assert all(abs(a - b) < TOL for a, b in zip(la, lb))


def test_mt_engine_batched_timeline_pinned_to_simulator():
    """Acceptance (engine level): a batching-configured
    MultiTenantCoachEngine stays pinned to
    ``simulate_multitenant_stream(batch_caps=...)`` at 1e-6, and the
    burst tenant's queue depth produces real multi-task batches."""
    tenants = [
        TenantSpec("interactive", 40, arrival_period=4e-3, weight=4.0,
                   slo_latency=200e-3),
        TenantSpec("burst", 50, arrivals=(0.0,) * 50, weight=1.0,
                   slo_latency=1.0),
    ]
    # downstream-heavy deployment: a fast ingress feeding slow edge /
    # cloud tiers, so the burst builds real queue depth where batching
    # is allowed (tier 0 is clamped to cap 1 by the credit gate)
    st = StageTimes(
        T_e=1e-3, T_t=2e-3, T_c=3.5e-3, T_t_par=0.0, T_c_par=0.0,
        latency=10.5e-3, first_tx_offset=1e-3, cloud_start_offset=2e-3,
        compute=(1e-3, 3e-3, 3.5e-3), link=(2e-3, 1e-3),
        link_par=(0.0, 0.0), compute_par=(0.0, 0.0),
        tx_offsets=(1e-3, 3e-3), rx_offsets=(2e-3, 1e-3))
    # fast links so the slow compute tiers (not the wire) are the
    # bottleneck where queue depth accumulates
    links = [LinkProfile("uplink", 400e6), LinkProfile("backhaul", 900e6)]
    stream = CorrelatedTaskStream(n_labels=30, dim=48,
                                  correlation="medium", seed=4)
    feats, labels = make_calibration_set(stream, 400)

    def classify(task):
        d = np.linalg.norm(stream.mu - task.features[None], axis=1)
        return task.features, int(np.argmin(d))

    cfg = EngineConfig(per_hop_bits=False, queue_capacity=0,
                       batch_caps=[4, 4, 4], batch_fixed_frac=0.75,
                       batch_slack=150e-3)
    eng = MultiTenantCoachEngine(
        None, st, END, links[0], CLOUD, n_labels=30, calib_feats=feats,
        calib_labels=labels, tenants=tenants, policy="wdrr", cfg=cfg,
        boundary_elems=50_000, links=links)
    tasks = [stream.tasks(t.n_tasks) for t in tenants]
    mt = eng.run_streams([list(ts) for ts in tasks], classify)
    ref = sim.simulate_multitenant_stream(
        mt.plans, mt.arrivals,
        make_policy("wdrr", weights=[t.weight for t in tenants]),
        links=eng.links, batch_caps=eng.batch_caps)
    assert mt.order == ref.order
    _assert_timelines_agree(result_from_stream(ref.stream), mt.pipeline)
    assert max(realized_batch_sizes(mt.pipeline)) > 1.0
    # tier 0 was clamped: ingress ran strictly one task per slot
    assert len(mt.pipeline.compute_intervals[0]) == sum(
        t.n_tasks for t in tenants)
