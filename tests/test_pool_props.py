"""Hypothesis properties for replicated-tier pools.

The main property is the PR's acceptance invariant at generative scale:
for any drawn stream (task count, service times, hop exits), pool shape
(replica counts, heterogeneous speeds), and router policy, the async
pool executor under the virtual clock reproduces
``sim.simulate_pool_stream`` — completions, routes, per-replica busy
intervals — to 1e-6; single-replica pools reduce bit-identically to the
serial chain.  The cold-cache exit rule is also pinned generatively: no
scheduler configuration may terminate a task while fewer than two labels
are warm.  (Module is collect-ignored by ``conftest.py`` when hypothesis
is not installed.)
"""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import online as ON
from repro.core import sim
from repro.serving.async_engine import AsyncHopPipeline, VirtualClock
from repro.serving.routing import ROUTER_POLICIES, make_router

TOL = 1e-6


@st.composite
def pool_scenarios(draw):
    n_hops = draw(st.integers(1, 3))
    n = draw(st.integers(1, 16))
    plans, arr, t = [], [], 0.0
    for _ in range(n):
        comp = tuple(draw(st.floats(1e-4, 5e-3)) for _ in range(n_hops + 1))
        tx = tuple(draw(st.floats(1e-5, 3e-3)) for _ in range(n_hops))
        exit_hop = draw(st.one_of(st.none(), st.integers(0, n_hops - 1))) \
            if n_hops > 1 else None
        plans.append(sim.SimPlan(compute=comp, tx=tx,
                                 tx_offset=(None,) * n_hops,
                                 rx_offset=(None,) * n_hops,
                                 exit_hop=exit_hop))
        arr.append(t)
        # strictly positive gaps: zero-duration event chains are the
        # executor's known settle() blind spot (same exposure as the
        # chain/batching differential suites)
        t += draw(st.floats(1e-5, 3e-3))
    pools = []
    for _ in range(n_hops + 1):
        m = draw(st.integers(1, 4))
        pools.append(tuple(draw(st.floats(0.3, 2.5))
                           for _ in range(m)))
    policy = draw(st.sampled_from(sorted(ROUTER_POLICIES)))
    seed = draw(st.integers(0, 5))
    return plans, arr, pools, policy, seed


@settings(max_examples=40, deadline=None)
@given(sc=pool_scenarios())
def test_pool_executor_pinned_to_simulator(sc):
    plans, arr, pools, policy, seed = sc
    n_hops = len(plans[0].tx)
    ps = sim.simulate_pool_stream(plans, arr, pools,
                                  make_router(policy, seed=seed))
    pipe = AsyncHopPipeline(n_hops, clock=VirtualClock(), pools=pools,
                            router=make_router(policy, seed=seed))
    pa = pipe.run(lambda i, _a: plans[i], len(plans), arr)
    assert ps.routes == pa.routes
    for a, b in zip(ps.done, pa.done):
        assert abs(a - b) <= TOL
    for k in range(n_hops + 1):
        for r in range(len(pools[k])):
            ia, ib = ps.replica_intervals[k][r], pa.replica_intervals[k][r]
            assert len(ia) == len(ib)
            for (s1, e1), (s2, e2) in zip(ia, ib):
                assert abs(s1 - s2) <= TOL and abs(e1 - e2) <= TOL


@settings(max_examples=40, deadline=None)
@given(sc=pool_scenarios())
def test_m1_pool_is_bitwise_chain(sc):
    plans, arr, pools, policy, _seed = sc
    m1 = [1] * len(pools)
    ref = sim.simulate_stream(plans, arr)
    res = sim.simulate_pool_stream(plans, arr, m1, make_router(policy))
    sr = res.as_stream_result()
    assert sr.done == ref.done
    assert sr.compute_intervals == ref.compute_intervals
    assert sr.link_intervals == ref.link_intervals


@settings(max_examples=40, deadline=None)
@given(sc=pool_scenarios())
def test_pool_routes_are_valid_and_conserving(sc):
    """Every reached tier places the task on exactly one in-range
    replica; tiers past a hop exit are never routed; replica interval
    counts sum to the tier's task load."""
    plans, arr, pools, policy, seed = sc
    res = sim.simulate_pool_stream(plans, arr, pools,
                                   make_router(policy, seed=seed))
    for p, rt in zip(plans, res.routes):
        for k, r in enumerate(rt):
            if sim.occupies_compute(p.exit_hop, k):
                assert r is not None and 0 <= r < len(pools[k])
            else:
                assert r is None


@settings(max_examples=60, deadline=None)
@given(
    n_labels=st.integers(2, 10),
    dim=st.integers(2, 24),
    warm_label=st.integers(0, 9),
    n_updates=st.integers(1, 6),
    s_ext=st.floats(0.0, 5.0, allow_nan=False),
    seed=st.integers(0, 1000),
)
def test_no_exit_with_fewer_than_two_warm_labels(n_labels, dim, warm_label,
                                                 n_updates, s_ext, seed):
    """Cold-cache acceptance property: however the cache, thresholds,
    and feature stream are drawn, a scheduler whose cache has fewer than
    two warmed labels never terminates a task (Eq. 9 over trained
    centers only + the >= 2 warm-label eligibility rule)."""
    rng = np.random.RandomState(seed)
    cache = ON.SemanticCache(n_labels, dim)
    label = warm_label % n_labels
    th = ON.Thresholds(s_ext=s_ext, s_adj=((0.0, 8),))
    sched = ON.OnlineScheduler(cache, th, boundary_elems=100,
                               T_e=1e-3, T_c=1e-3,
                               update_centers=False)
    # zero warm labels, then exactly one (updated repeatedly)
    for _ in range(3):
        dec = sched.step(rng.rand(dim), bandwidth_bps=1e6)
        assert not dec.early_exit
        assert dec.separability == 0.0
    for _ in range(n_updates):
        cache.update(rng.rand(dim), label)
    assert cache.n_warm == 1
    for _ in range(5):
        dec = sched.step(rng.rand(dim), bandwidth_bps=1e6)
        assert not dec.early_exit
