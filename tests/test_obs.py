"""Observability layer: span traces, bubble attribution, export, metrics.

Deterministic coverage of the PR's invariants: (1) the differential pin
extends to span timelines — the async executor's trace of a stream
matches the simulator's at 1e-6 across chain / exits / batched / pool /
multi-tenant configs; (2) every idle interval on every resource is
attributed to exactly one cause from the closed enum and the
conservation identity ``busy + sum(bubbles) = horizon`` holds at 1e-9;
(3) each non-trivial cause is *reachable* (a scenario that provably
produces it); (4) the disabled-sink path changes nothing; plus the
``bubble_fraction`` normalization regressions (aggregate ``"link"``
view on multi-hop chains, heterogeneous-speed replica pools).
"""

import json

from repro.core import sim as S
from repro.core.pipeline import TaskPlan, run_pipeline
from repro.core.sim import PoolSpec
from repro.obs.bubbles import CAUSES, attribute, chain_resources
from repro.obs.export import text_summary, to_chrome_trace, write_chrome_trace
from repro.obs.metrics import (MetricsRegistry, populate_from_attribution,
                               populate_from_result, populate_from_trace)
from repro.obs.trace import (SERVICE, Span, TraceRecorder,
                             assert_traces_match, resource_label)
from repro.serving.async_engine import VirtualClock, run_pipeline_async
from repro.serving.routing import make_router
from repro.serving.tenancy import make_policy, run_multitenant_async

CONS_TOL = 1e-9
PIN_TOL = 1e-6


def _traced_pair(plans, arrivals=None, period=0.0, batch_caps=None,
                 pools=None, router_name=None):
    """Run both engines with live recorders; pin the traces; return the
    sim result + its attribution."""
    ts, ta = TraceRecorder(), TraceRecorder()
    r1 = make_router(router_name, seed=1) if router_name else None
    r2 = make_router(router_name, seed=1) if router_name else None
    pr_s = run_pipeline(plans, arrivals=arrivals, arrival_period=period,
                        batch_caps=batch_caps, pools=pools, router=r1,
                        sink=ts)
    pr_a = run_pipeline_async(plans, arrivals=arrivals,
                              arrival_period=period, clock=VirtualClock(),
                              batch_caps=batch_caps, pools=pools,
                              router=r2, sink=ta)
    assert abs(pr_s.makespan - pr_a.makespan) <= PIN_TOL
    assert_traces_match(ts, ta, tol=PIN_TOL)
    att = attribute(ts, resources=chain_resources(
        pr_s.n_hops, pr_s.pool_sizes or None))
    assert att.max_conservation_error() <= CONS_TOL
    assert {b.cause for b in att.bubbles} <= set(CAUSES)
    return pr_s, ts, att


PLANS3 = [TaskPlan.multihop([2.0, 1.0, 3.0], [0.5, 0.7]) for _ in range(6)]


def test_chain_trace_pinned_and_conserving():
    pr, rec, att = _traced_pair(PLANS3, period=1.0)
    # the steady chain exercises the baseline causes
    assert att.total(cause="warmup") > 0
    assert att.total(cause="drain") > 0
    assert att.total(cause="upstream_starvation") > 0
    # unbounded pinned runs never see backpressure (documented invariant)
    assert att.total(cause="downstream_backpressure") == 0.0


def test_exit_cascade_releases_downstream():
    plans = [TaskPlan.multihop([2.0, 1.0, 3.0], [0.5, 0.7],
                               exit_hop=(i % 3 if i % 2 else None))
             for i in range(8)]
    _, _, att = _traced_pair(plans, period=0.8)
    assert att.total(cause="exit_released") > 0


def test_batched_trace_pinned_and_batch_formation():
    plans = [TaskPlan.multihop([0.1, 1.0, 0.1], [0.05, 0.4],
                               t_fixed=[0.0, 0.6, 0.0]) for _ in range(8)]
    _, _, att = _traced_pair(plans, period=0.15, batch_caps=[1, 4, 1])
    assert att.total(cause="batch_formation") > 0


def test_pool_trace_pinned_heterogeneous_speeds():
    pools = [PoolSpec(speeds=(1.0, 2.0)), PoolSpec(speeds=(1.0,)),
             PoolSpec(speeds=(0.5, 1.5, 1.0))]
    pr, rec, att = _traced_pair(PLANS3, period=0.5, pools=pools,
                                router_name="jsq")
    # per-replica accounting: every replica of every tier has a row
    labels = set(att.by_label())
    assert "compute0/r0" in labels and "compute0/r1" in labels
    assert "compute2/r2" in labels and "link0" in labels
    assert len(labels) == (2 + 1 + 3) + 2


def test_sequencer_reorder_reachable():
    # a slow replica's terminal (exit) release blocks the sequencer,
    # holding a later fast-replica task past a link idle gap
    plans = [TaskPlan.multihop([0.2, 0.1], [0.05]),
             TaskPlan.multihop([1.0, 0.1], [0.05], exit_hop=0),
             TaskPlan.multihop([0.2, 0.1], [0.05])]
    _, _, att = _traced_pair(
        plans, arrivals=[0.0, 0.0, 0.0],
        pools=[PoolSpec(speeds=(1.0, 5.0)), PoolSpec(speeds=(1.0,))],
        router_name="jsq")
    assert att.total(cause="sequencer_reorder") > 0


def test_multitenant_trace_pinned():
    mk = [TaskPlan.multihop([1.0, 2.0], [0.4]) for _ in range(4)]
    arr = [[0.0, 0.5, 1.0, 1.5], [0.2, 0.9, 1.6, 2.3]]
    for pol in ("fifo", "wdrr"):
        ts, ta = TraceRecorder(), TraceRecorder()
        ms = S.simulate_multitenant_stream(
            [[p.as_sim_plan(1) for p in mk] for _ in range(2)], arr,
            policy=make_policy(pol), sink=ts)
        ma = run_multitenant_async([list(mk), list(mk)], arr, policy=pol,
                                   clock=VirtualClock(), sink=ta)
        assert ms.order == ma.order
        assert_traces_match(ts, ta, tol=PIN_TOL)
        att = attribute(ts, resources=chain_resources(1))
        assert att.max_conservation_error() <= CONS_TOL


def test_multitenant_pool_ingress_credit_reachable():
    # a slow ingress replica makes admitted tasks wait on credits
    mk = [TaskPlan.multihop([1.0, 0.1], [0.05]) for _ in range(6)]
    pools = [PoolSpec(speeds=(0.2, 1.0)), PoolSpec(speeds=(1.0,))]
    arr = [[0.0] * 6]
    ts, ta = TraceRecorder(), TraceRecorder()
    S.simulate_multitenant_pool_stream(
        [[p.as_sim_plan(1) for p in mk]], arr, policy=make_policy("fifo"),
        pools=pools, router=make_router("jsq", seed=0), sink=ts)
    run_multitenant_async([list(mk)], arr, policy="fifo",
                          clock=VirtualClock(), pools=pools,
                          router=make_router("jsq", seed=0), sink=ta)
    assert_traces_match(ts, ta, tol=PIN_TOL)
    att = attribute(ts, resources=chain_resources(1, [2, 1]))
    assert att.max_conservation_error() <= CONS_TOL
    assert att.total(cause="ingress_credit") > 0


def test_disabled_sink_is_inert():
    pr0 = run_pipeline(PLANS3, arrival_period=1.0)
    rec = TraceRecorder()
    pr1 = run_pipeline(PLANS3, arrival_period=1.0, sink=rec)
    assert pr0.makespan == pr1.makespan
    assert [t.done for t in pr0.tasks] == [t.done for t in pr1.tasks]
    assert len(rec) > 0
    pa0 = run_pipeline_async(PLANS3, arrival_period=1.0,
                             clock=VirtualClock())
    assert abs(pa0.makespan - pr0.makespan) <= PIN_TOL


def test_recorder_accepts_prefix_tuples():
    rec = TraceRecorder()
    rec.span((SERVICE, ("compute", 0, 0), 0.0, 1.0, 7))
    rec.span(Span(SERVICE, ("compute", 0, 0), 1.0, 2.0, task=8,
                  tasks=(8,), ready=0.5, batch=1))
    a, b = rec.spans
    assert isinstance(a, Span) and a.task == 7
    assert a.tasks is None and a.ready is None and a.seq is None
    assert b.ready == 0.5
    # the lazy cache tracks appends after a read
    rec.span((SERVICE, ("link", 0), 2.0, 3.0, 9))
    assert len(rec.spans) == 3 and len(rec) == 3


def test_chrome_trace_structure(tmp_path):
    _, rec, att = _traced_pair(PLANS3, period=1.0)
    doc = to_chrome_trace(rec, att)
    events = doc["traceEvents"]
    names = {e["args"]["name"] for e in events
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"compute0/r0", "link0", "compute1/r0", "link1",
            "compute2/r0"} <= names
    busy = [e for e in events if e.get("cat") == "service"]
    assert busy and all(e["ph"] == "X" and e["dur"] >= 0 for e in busy)
    bubbles = [e for e in events if e.get("cat") == "bubble"]
    assert bubbles and {e["name"] for e in bubbles} <= set(CAUSES)
    json.dumps(doc)  # serializable
    out = tmp_path / "trace.json"
    assert write_chrome_trace(out, rec, att) == str(out)
    assert json.loads(out.read_text())["traceEvents"]


def test_text_summary_mentions_every_resource():
    pr, _, att = _traced_pair(PLANS3, period=1.0)
    txt = text_summary(att)
    for res in att.resources():
        assert resource_label(res) in txt
    assert "horizon" in txt


def test_metrics_registry_roundtrip():
    reg = MetricsRegistry()
    reg.inc("a"), reg.inc("a", 2.0)
    reg.set_gauge("g", 0.5)
    for v in (1.0, 3.0, 2.0):
        reg.observe("h", v)
    snap = reg.snapshot()
    assert snap["counters"]["a"] == 3.0
    assert snap["gauges"]["g"] == 0.5
    h = reg.histogram("h")
    assert h["count"] == 3 and h["p50"] == 2.0 and h["max"] == 3.0
    assert "counter a = 3" in reg.render()


def test_metrics_populated_from_run():
    pr, rec, att = _traced_pair(PLANS3, period=1.0)
    reg = MetricsRegistry()
    populate_from_trace(reg, rec)
    populate_from_attribution(reg, att)
    populate_from_result(reg, pr)
    assert reg.counter("tier0.batches") == len(PLANS3)
    assert reg.counter("link0.xfers") == len(PLANS3)
    # busy counters agree with the attribution's busy seconds
    for label, busy in att.busy_by_label().items():
        assert abs(reg.counter(f"busy_s.{label.split('/r')[0]}"
                               if label.startswith("link") else
                               f"busy_s.{label}") - busy) <= 1e-9
    assert reg.gauges["horizon_s"] == att.horizon_s
    assert reg.gauges["makespan_s"] == pr.makespan
    # per-cause bubble seconds sum back to the attribution total
    tot = sum(v for k, v in reg.counters.items()
              if k.startswith("bubble_s."))
    assert abs(tot - att.total()) <= 1e-9


def test_link_bubble_fraction_aggregate_normalization():
    """``bubble_fraction("link")`` on a multi-hop chain: ``link_busy``
    sums every hop, so the capacity must be ``n_hops * makespan``."""
    pr = run_pipeline(PLANS3, arrival_period=1.0)
    assert pr.n_hops == 2
    frac = pr.bubble_fraction("link")
    assert 0.0 <= frac <= 1.0
    expect = 1.0 - pr.link_busy / (pr.n_hops * pr.makespan)
    assert abs(frac - expect) <= 1e-12
    per_hop = [pr.bubble_fraction(("link", k)) for k in range(pr.n_hops)]
    assert all(0.0 <= f <= 1.0 for f in per_hop)


def test_pool_bubble_fraction_heterogeneous_normalization():
    """Replicated-tier normalization: capacity is ``m * makespan`` per
    tier, with *no* speed rescaling (busy time is wall seconds on each
    replica), so heterogeneous pools stay in ``[0, 1]`` and agree with
    the attribution's per-replica busy sums."""
    pools = [PoolSpec(speeds=(1.0, 2.0)), PoolSpec(speeds=(1.0,)),
             PoolSpec(speeds=(0.5, 1.5, 1.0))]
    rec = TraceRecorder()
    pr = run_pipeline(PLANS3, arrival_period=0.5, pools=pools,
                      router=make_router("jsq", seed=1), sink=rec)
    att = attribute(rec, resources=chain_resources(pr.n_hops,
                                                   pr.pool_sizes))
    busy = att.busy_by_label()
    for k, m in enumerate(pr.pool_sizes):
        frac = pr.bubble_fraction(("compute", k))
        assert 0.0 <= frac <= 1.0
        tier_busy = sum(busy[f"compute{k}/r{r}"] for r in range(m))
        assert abs(frac - (1.0 - tier_busy / (m * pr.makespan))) <= 1e-9
    assert 0.0 <= pr.bubble_fraction("end") <= 1.0
    assert 0.0 <= pr.bubble_fraction("cloud") <= 1.0
    assert 0.0 <= pr.bubble_fraction("link") <= 1.0
