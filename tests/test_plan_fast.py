"""Batched offline-planner scorer (core.plan_fast): differential pin
against the event simulator, sweep-representative equivalence, argmin
equality of the fast planner vs the naive per-candidate simulation
search, and the quantization memoization.

Seeded random series-parallel graphs (no hypothesis dependency: these
run in every environment) exercise virtual blocks, skip edges, relayed
boundary tensors and degenerate (empty-segment) cuts.
"""

import itertools
import math

import numpy as np
import pytest

from repro.core import plan_fast
from repro.core.costs import (DeviceProfile, LinkProfile, LayerNode,
                              ModelGraph, chain_graph)
from repro.core.pipeline import bandwidth_step_trace
from repro.core.partitioner import (QuantCache, _quantize_boundary,
                                    _relax_bits, analytic_acc_loss,
                                    brute_force, chain_flow, chain_prefixes,
                                    coach_offline, coach_offline_multihop,
                                    strided_positions)
from repro.core.schedule import PartitionDecision, evaluate_multihop
from repro.models.cnn import resnet101, vgg16

END = DeviceProfile("end", 1e11)
MID = DeviceProfile("mid", 4e11)
MID2 = DeviceProfile("mid2", 6e11)
CLOUD = DeviceProfile("cloud", 1e12)
L1 = LinkProfile("l1", 50e6)
L2 = LinkProfile("l2", 400e6)
L3 = LinkProfile("l3", 900e6)

DEPLOYMENTS = {
    1: ((END, CLOUD), (L1,)),
    2: ((END, MID, CLOUD), (L1, L2)),
    3: ((END, MID, MID2, CLOUD), (L1, L2, L3)),
}


# --------------------------------------------------------------- generators
def rand_sp_graph(seed: int, n_blocks: int = 3) -> ModelGraph:
    """Random series-parallel DAG: chain runs, 1-3 branch blocks of 1-3
    nodes, optional skip edges — the structures Alg. 1 clusters into
    virtual blocks."""
    rng = np.random.default_rng(seed)
    nodes = []
    nid = 0

    def add(name, deps):
        nonlocal nid
        nodes.append(LayerNode(
            nid, name, float(rng.uniform(1e7, 5e8)),
            int(rng.integers(2_000, 120_000)), tuple(deps),
            sensitivity=float(rng.uniform(0.004, 0.08)),
            util=float(rng.uniform(0.3, 1.0))))
        nid += 1
        return nid - 1

    prev = add("in", ())
    for b in range(n_blocks):
        for _ in range(int(rng.integers(0, 3))):
            prev = add(f"c{nid}", (prev,))
        entry = prev
        tails = []
        for j in range(int(rng.integers(1, 4))):
            cur = entry
            for _ in range(int(rng.integers(1, 4))):
                cur = add(f"b{b}_{j}_{nid}", (cur,))
            tails.append(cur)
        if rng.random() < 0.5:
            tails.append(entry)  # skip edge straight to the join
        prev = add(f"join{b}", tuple(tails))
    add("head", (prev,))
    return ModelGraph(f"sp{seed}", nodes)


def rand_nested_frontiers(rng, graph: ModelGraph, n_hops: int):
    """Random nested downward-closed frontier tuples (not restricted to
    chain prefixes — exercises the general scorer)."""
    def close_down(s):
        s = set(s)
        changed = True
        while changed:
            changed = False
            for i in list(s):
                for d in graph.node(i).deps:
                    if d not in s:
                        s.add(d)
                        changed = True
        return s

    frontiers = []
    cur: set = set()
    for _ in range(n_hops):
        pick = [i for i in range(len(graph)) if rng.random() < 0.4]
        cur = close_down(cur | set(pick)) if rng.random() < 0.8 else set(cur)
        frontiers.append(frozenset(cur))
    return frontiers


def rand_hop_bits(rng, graph: ModelGraph, frontiers):
    """Random explicit bit maps; ~20% of boundary edges omitted to hit
    the simulator's fp32 default pricing."""
    out = []
    for f in frontiers:
        bits = {}
        for (u, v) in graph.boundary_edges(f):
            if u >= 0 and rng.random() < 0.8:
                bits[(u, v)] = int(rng.integers(2, 17))
        out.append(bits)
    return out


def build_tables(graph, devices, links, eps=0.005):
    qc = QuantCache(graph, eps, analytic_acc_loss)
    prefixes = chain_prefixes(graph)
    return plan_fast.build_tables(
        graph, devices, links, qc.node_bits,
        pref_counts=[len(p) for p in prefixes]), qc, prefixes


STAGE_FIELDS = ("compute", "link", "link_par", "compute_par", "tx_offsets",
                "rx_offsets", "latency", "T_e", "T_t", "T_c", "T_t_par",
                "T_c_par", "first_tx_offset", "cloud_start_offset")


def assert_stage_times_close(a, b, rtol=1e-9):
    for f in STAGE_FIELDS:
        va = np.atleast_1d(np.asarray(getattr(a, f), dtype=float))
        vb = np.atleast_1d(np.asarray(getattr(b, f), dtype=float))
        np.testing.assert_allclose(va, vb, rtol=rtol, atol=1e-12,
                                   err_msg=f"field {f}")
    assert math.isclose(a.objective(), b.objective(),
                        rel_tol=rtol, abs_tol=1e-12)
    assert a.satisfies_parallel_constraint() == \
        b.satisfies_parallel_constraint()


# ------------------------------------------------- differential: exactness
@pytest.mark.parametrize("seed", range(6))
def test_chain_scorer_matches_simulator(seed):
    """Fast chain-cut scoring == evaluate_multihop on random SP graphs,
    including repeated positions (empty segments => relayed tensors)."""
    g = rand_sp_graph(seed)
    n_hops = 1 + seed % 3
    devices, links = DEPLOYMENTS[n_hops]
    tables, qc, prefixes = build_tables(g, devices, links)
    rng = np.random.default_rng(seed + 100)
    combos = list(itertools.combinations_with_replacement(
        range(len(prefixes)), n_hops))
    rng.shuffle(combos)
    for combo in combos[:12]:
        for extra in (0, 1, 8):
            frontiers = [frozenset(prefixes[i]) for i in combo]
            hop_bits = [{e: min(16, b + extra)
                         for e, b in qc.boundary_bits(f).items()}
                        for f in frontiers]
            ref = evaluate_multihop(
                g, PartitionDecision.multihop(frontiers, hop_bits),
                devices, links)
            assert_stage_times_close(
                ref, plan_fast.stage_times_chain(tables, combo, extra))
            assert_stage_times_close(
                ref, plan_fast.stage_times_frontiers(
                    tables, frontiers, extra=extra))


@pytest.mark.parametrize("seed", range(6))
def test_frontier_scorer_matches_simulator(seed):
    """General nested-frontier scoring == evaluate_multihop under random
    downward-closed cuts and random (partially missing) bit maps."""
    g = rand_sp_graph(seed, n_blocks=2)
    rng = np.random.default_rng(seed + 500)
    n_hops = 1 + seed % 3
    devices, links = DEPLOYMENTS[n_hops]
    tables, _, _ = build_tables(g, devices, links)
    for _ in range(8):
        frontiers = rand_nested_frontiers(rng, g, n_hops)
        hop_bits = rand_hop_bits(rng, g, frontiers)
        ref = evaluate_multihop(
            g, PartitionDecision.multihop(frontiers, hop_bits),
            devices, links)
        assert_stage_times_close(
            ref, plan_fast.stage_times_frontiers(tables, frontiers,
                                                 hop_bits=hop_bits))


def test_seed_models_scorer_matches_simulator():
    """Spot-check the seed evaluation models (chain + bottleneck DAG)."""
    for g in (vgg16(), resnet101()):
        devices, links = DEPLOYMENTS[2]
        tables, qc, prefixes = build_tables(g, devices, links)
        rng = np.random.default_rng(0)
        combos = list(itertools.combinations_with_replacement(
            range(len(prefixes)), 2))
        rng.shuffle(combos)
        for combo in combos[:15]:
            frontiers = [frozenset(prefixes[i]) for i in combo]
            hop_bits = [dict(qc.boundary_bits(f)) for f in frontiers]
            ref = evaluate_multihop(
                g, PartitionDecision.multihop(frontiers, hop_bits),
                devices, links)
            assert_stage_times_close(
                ref, plan_fast.stage_times_chain(tables, combo, 0))


# ------------------------------------------- sweep representatives + argmin
def test_chain_sweep_matches_naive_relax_representatives():
    """chain_sweep's per-tuple (objective, feasible) representatives ==
    the naive _relax_bits funnel, for every tuple of the sweep (pins the
    vectorized serial path, the lean overlap replay and the level
    pruning)."""
    g = rand_sp_graph(3)
    devices, links = DEPLOYMENTS[2]
    tables, qc, prefixes = build_tables(g, devices, links)
    positions = list(range(len(prefixes)))
    res = plan_fast.chain_sweep(tables, positions, n_hops=2)
    # all non-decreasing pairs minus those whose first frontier is the
    # empty prefix (min_end_nodes=1)
    n_pos = len(positions)
    assert len(res.combos) == n_pos * (n_pos + 1) // 2 - n_pos
    for ti, combo in enumerate(res.combos):
        frontiers = [frozenset(prefixes[i]) for i in combo]
        bits_min = [qc.boundary_bits(f) for f in frontiers]
        (dec, st, obj, feas), _ = _relax_bits(
            g, frontiers, bits_min, devices, links, math.inf)
        assert math.isclose(res.objective[ti], obj, rel_tol=1e-9,
                            abs_tol=1e-12), combo
        assert bool(res.feasible[ti]) == feas, combo


@pytest.mark.parametrize("n_hops", [1, 2, 3])
def test_fast_planner_argmin_equals_naive_vgg(n_hops):
    """Acceptance: the fast planner returns the same PartitionDecision
    and objective (1e-9) as the pre-refactor search on the seed chain
    model at 1/2/3 hops."""
    devices, links = DEPLOYMENTS[n_hops]
    g = vgg16()
    naive = coach_offline_multihop(g, devices, links, fast=False)
    fast = coach_offline_multihop(g, devices, links, fast=True)
    assert fast.decision.cuts == naive.decision.cuts
    assert fast.decision.all_hop_bits == naive.decision.all_hop_bits
    assert math.isclose(fast.objective, naive.objective, rel_tol=1e-9)
    assert fast.feasible == naive.feasible


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fast_planner_argmin_equals_naive_blocks(seed):
    """Same argmin equality on random block-structured graphs at 2 hops
    (exercises the block-recursion refinement shortlist)."""
    g = rand_sp_graph(seed)
    devices, links = DEPLOYMENTS[2]
    naive = coach_offline_multihop(g, devices, links, fast=False)
    fast = coach_offline_multihop(g, devices, links, fast=True)
    assert fast.decision.cuts == naive.decision.cuts
    assert fast.decision.all_hop_bits == naive.decision.all_hop_bits
    assert math.isclose(fast.objective, naive.objective, rel_tol=1e-9)


def test_fast_planner_argmin_equals_naive_resnet():
    g = resnet101()
    devices, links = DEPLOYMENTS[1]
    naive = coach_offline_multihop(g, devices, links, fast=False)
    fast = coach_offline_multihop(g, devices, links, fast=True)
    assert fast.decision.cuts == naive.decision.cuts
    assert fast.decision.all_hop_bits == naive.decision.all_hop_bits
    assert math.isclose(fast.objective, naive.objective, rel_tol=1e-9)


def test_fast_planner_respects_chain_stride():
    g = vgg16()
    devices, links = DEPLOYMENTS[2]
    naive = coach_offline_multihop(g, devices, links, chain_stride=3,
                                   fast=False)
    fast = coach_offline_multihop(g, devices, links, chain_stride=3,
                                  fast=True)
    assert fast.decision.cuts == naive.decision.cuts
    assert math.isclose(fast.objective, naive.objective, rel_tol=1e-9)
    # the strided grid is the documented subsampling
    positions = strided_positions(len(chain_prefixes(g)), 3)
    assert positions[-1] == len(chain_prefixes(g)) - 1


def test_traced_link_fast_path_small_chain():
    """Links with a bandwidth trace are priced per-candidate by the
    sparse replay (the vectorized closed forms are invalid under
    traces); fast=True must still produce the naive result exactly."""
    g = chain_graph("c", [1e8] * 6, [30_000] * 6)
    trace = LinkProfile("traced", 50e6, trace=lambda t: 50e6)
    naive = coach_offline_multihop(g, (END, CLOUD), (trace,), fast=False)
    fast = coach_offline_multihop(g, (END, CLOUD), (trace,), fast=True)
    assert fast.decision.cuts == naive.decision.cuts
    assert math.isclose(fast.objective, naive.objective, rel_tol=1e-12)


def _step_trace(nominal_bps: float):
    """A genuinely time-varying trace: nominal until 5 ms, then 40%."""
    return bandwidth_step_trace([(0.0, nominal_bps / 1e6),
                                 (0.005, 0.4 * nominal_bps / 1e6)])


@pytest.mark.parametrize("n_hops", [1, 2])
def test_traced_argmin_equals_naive(n_hops):
    """The traced fast funnel (chain_sweep -> frontier_shortlist, every
    candidate scored by exact replay) must return the naive argmin on
    graphs large enough to actually engage it — cuts, bits and
    objective, under a trace that changes rate mid-candidate."""
    g = vgg16()
    devices, links = DEPLOYMENTS[n_hops]
    traced = tuple(LinkProfile(lk.name, lk.bandwidth_bps,
                               trace=_step_trace(lk.bandwidth_bps))
                   for lk in links)
    naive = coach_offline_multihop(g, devices, traced, fast=False)
    fast = coach_offline_multihop(g, devices, traced, fast=True)
    assert fast.decision.cuts == naive.decision.cuts
    assert fast.decision.all_hop_bits == naive.decision.all_hop_bits
    assert math.isclose(fast.objective, naive.objective, rel_tol=1e-9)
    assert fast.feasible == naive.feasible


def test_traced_retimed_tables_warm_start():
    """retime_tables re-links warm tables to new (possibly traced)
    profiles without re-pricing the oracle; planning with them must
    equal a cold run against the same links."""
    g = vgg16()
    devices, links = DEPLOYMENTS[1]
    qc = QuantCache(g, 0.005, analytic_acc_loss)
    tables = plan_fast.build_tables(
        g, devices, links, qc.node_bits,
        pref_counts=[len(p) for p in chain_prefixes(g)])
    for new_links in (
            (LinkProfile("slow", 12e6),),
            (LinkProfile("dyn", 50e6, trace=_step_trace(50e6)),)):
        warm = plan_fast.retime_tables(tables, new_links)
        assert warm.bw == tuple(lk.bandwidth_bps for lk in new_links)
        hot = coach_offline_multihop(g, devices, new_links, tables=warm)
        cold = coach_offline_multihop(g, devices, new_links)
        assert hot.decision.cuts == cold.decision.cuts
        assert hot.decision.all_hop_bits == cold.decision.all_hop_bits
        assert math.isclose(hot.objective, cold.objective, rel_tol=1e-12)


def test_warm_tables_reject_mismatched_links():
    """Stale warm tables (wrong nominal rates for the links being
    planned) must be rejected, not silently misprice the search."""
    g = chain_graph("c", [1e8] * 6, [30_000] * 6)
    qc = QuantCache(g, 0.005, analytic_acc_loss)
    tables = plan_fast.build_tables(
        g, (END, CLOUD), (L1,), qc.node_bits,
        pref_counts=[len(p) for p in chain_prefixes(g)])
    with pytest.raises(AssertionError):
        coach_offline_multihop(g, (END, CLOUD), (L2,), tables=tables)


def test_brute_force_traced_fast_equals_naive():
    rng = np.random.default_rng(3)
    g = chain_graph("c3", rng.uniform(1e7, 1e9, 9),
                    rng.integers(1e3, 3e5, 9))
    traced = LinkProfile("dyn", 50e6, trace=_step_trace(50e6))
    naive = brute_force(g, END, CLOUD, traced, fast=False)
    fast = brute_force(g, END, CLOUD, traced, fast=True)
    assert fast.decision.end_set == naive.decision.end_set
    assert fast.decision.bits == naive.decision.bits
    assert math.isclose(fast.objective, naive.objective, rel_tol=1e-9)


def test_brute_force_fast_equals_naive():
    for seed in (0, 7):
        rng = np.random.default_rng(seed)
        g = chain_graph(f"c{seed}", rng.uniform(1e7, 1e9, 9),
                        rng.integers(1e3, 3e5, 9))
        naive = brute_force(g, END, CLOUD, L1, fast=False)
        fast = brute_force(g, END, CLOUD, L1, fast=True)
        assert fast.decision.end_set == naive.decision.end_set
        assert fast.decision.bits == naive.decision.bits
        assert math.isclose(fast.objective, naive.objective, rel_tol=1e-9)
    # coach (fast) still matches the exponential oracle on the SP DAG
    g = rand_sp_graph(11, n_blocks=2)
    if len(g) <= 18:
        r1 = coach_offline(g, END, CLOUD, L1)
        r2 = brute_force(g, END, CLOUD, L1)
        assert r1.objective <= r2.objective * 1.25


# ----------------------------------------------------- quant memoization
def test_quant_cache_memoizes_dichotomous_search():
    g = vgg16()
    calls = [0]

    def counting_oracle(node, bits):
        calls[0] += 1
        return analytic_acc_loss(node, bits)

    qc = QuantCache(g, 0.005, counting_oracle)
    prefixes = chain_prefixes(g)
    frontiers = [frozenset(p) for p in prefixes[1:]]
    for f in frontiers:
        qc.boundary_bits(f)
    first_pass = calls[0]
    for f in frontiers:  # every frontier + node already memoized
        qc.boundary_bits(f)
    assert calls[0] == first_pass
    # at most one dichotomous search (<= log2(16-2)+2 evals) per producer
    assert first_pass <= 6 * len(g)
    # cache agrees with the direct search
    for f in frontiers[::3]:
        assert qc.boundary_bits(f) == _quantize_boundary(
            g, f, 0.005, counting_oracle)
        assert _quantize_boundary(g, f, 0.005, counting_oracle,
                                  cache=qc) is qc.boundary_bits(f)


def test_tables_price_edges_lazily():
    """The Eq. 1 oracle search runs only for producers whose edges can
    actually cross a swept cut (matching the naive search's on-demand
    quantization — an expensive oracle is not paid for interior edges)."""
    g = resnet101()
    priced = set()

    def counting_bits(u):
        priced.add(u)
        return 8

    tables = plan_fast.build_tables(
        g, *DEPLOYMENTS[1], counting_bits,
        pref_counts=[len(p) for p in chain_prefixes(g)])
    grid_priced = len(priced)
    # block-interior producers (e.g. the first 1x1 conv of a bottleneck)
    # never cross a chain position, so they are not priced up front
    assert grid_priced < len(g) - 1
    # refining inside a block prices the newly exposed producers on demand
    elems = chain_flow(g)
    block = next(e for e in elems if e.is_block and e.branches)
    inner = block.branches[0][0]
    assert inner not in priced
    frontier = frozenset(range(inner + 1))
    plan_fast.stage_times_frontiers(tables, [frontier], extra=0)
    assert inner in priced and len(priced) > grid_priced
    # explicit bit maps never need the oracle
    before = len(priced)
    plan_fast.stage_times_frontiers(
        tables, [frozenset(range(block.block_nodes[-1] + 1))],
        hop_bits=[{}])
    assert len(priced) == before


def test_quant_cache_rejects_mismatched_search_config():
    g = vgg16()
    qc = QuantCache(g, 0.005, analytic_acc_loss)
    f = frozenset(range(4))
    with pytest.raises(AssertionError):
        _quantize_boundary(g, f, 0.02, analytic_acc_loss, cache=qc)
    with pytest.raises(AssertionError):
        _quantize_boundary(g, f, 0.005, analytic_acc_loss, hi_bits=12,
                           cache=qc)


def test_chain_flow_position_map_consistent():
    """The id->position map + hoisted block set (hot-spot fix) keep
    chain_flow's covering/clustering semantics on id-subset inputs."""
    g = rand_sp_graph(4)
    elems = chain_flow(g)
    ids = [i for e in elems for i in e.ids()]
    assert sorted(ids) == list(range(len(g)))
    # restricting to a suffix of ids still walks via the position map
    sub = list(range(len(g) // 2, len(g)))
    sub_elems = chain_flow(g, ids=sub)
    sub_ids = [i for e in sub_elems for i in e.ids()]
    assert sorted(sub_ids) == sub


# ------------------------------------------------- lower-bound pruning
@pytest.mark.parametrize("seed", [0, 1, 3, 5])
def test_chain_sweep_pruning_keeps_argmin_exact(seed):
    """chain_sweep(prune=True) skips dominated non-serial replays but
    (a) scores the exhaustive argmin (and its whole near-tie band)
    exactly, (b) assigns every pruned tuple a true lower bound /
    feasibility upper bound, so the shortlist -> event-sim rescore still
    returns the naive winner."""
    g = rand_sp_graph(seed)
    devices, links = DEPLOYMENTS[2]
    tables, qc, prefixes = build_tables(g, devices, links)
    positions = list(range(len(prefixes)))
    full = plan_fast.chain_sweep(tables, positions, n_hops=2)
    pruned = plan_fast.chain_sweep(tables, positions, n_hops=2,
                                   prune=True)
    assert pruned.combos == full.combos
    assert full.n_pruned == 0
    assert 0 <= pruned.n_pruned < len(full.combos)

    # pruned values never overstate the objective or understate
    # infeasibility: bound semantics hold tuple by tuple
    assert np.all(pruned.objective
                  <= full.objective * (1 + 1e-9) + 1e-12)
    assert np.all(pruned.feasible >= full.feasible)

    def argsort(res):
        return np.lexsort((np.arange(len(res.objective)),
                           res.objective, ~res.feasible))

    best = int(argsort(full)[0])
    # the exhaustive winner is exactly scored under pruning...
    assert math.isclose(pruned.objective[best], full.objective[best],
                        rel_tol=1e-9, abs_tol=1e-12)
    assert bool(pruned.feasible[best]) == bool(full.feasible[best])
    # ...and stays the winner (same index: pruned bounds sort strictly
    # after the incumbent, so the tie-break cannot move)
    assert int(argsort(pruned)[0]) == best
    # the rescoring shortlist drawn from the pruned sweep contains it
    pick = plan_fast._shortlist(pruned.objective, pruned.feasible,
                                top_k=8)
    assert best in set(int(i) for i in pick)


def test_chain_sweep_pruning_actually_prunes():
    """On a sweep with many dominated non-serial tuples the bound skips
    a nonzero tail (otherwise the satellite is a no-op) and
    chain_shortlist reports the same candidates' winner either way."""
    g = resnet101()
    devices, links = DEPLOYMENTS[2]
    tables, qc, prefixes = build_tables(g, devices, links)
    positions = list(range(len(prefixes)))
    pruned = plan_fast.chain_sweep(tables, positions, n_hops=2,
                                   prune=True)
    assert pruned.n_pruned > 0
