"""Discrete-event pipeline executor: invariants and Fig. 2 scheme sanity."""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.costs import LinkProfile
from repro.core.pipeline import (PipelineResult, TaskPlan,
                                 bandwidth_step_trace, run_pipeline)


def _plan(e, t, c, **kw):
    return TaskPlan(e, t, c, **kw)


@given(st.lists(st.tuples(st.floats(0.001, 0.1), st.floats(0.0, 0.1),
                          st.floats(0.001, 0.1)), min_size=1, max_size=40),
       st.floats(0.0, 0.05))
@settings(max_examples=40, deadline=None)
def test_pipeline_invariants(stages, period):
    plans = [_plan(e, t, c) for (e, t, c) in stages]
    r = run_pipeline(plans, arrival_period=period)
    # per-task latency >= own stage sum
    for rec, p in zip(r.tasks, plans):
        assert rec.latency >= p.t_end + p.t_tx + p.t_cloud - 1e-9
    # makespan >= busy time of any single resource
    assert r.makespan >= r.end_busy - 1e-9
    assert r.makespan >= r.link_busy - 1e-9
    assert r.makespan >= r.cloud_busy - 1e-9
    # throughput bounded by the busiest resource's total work
    busiest = max(sum(p.t_end for p in plans), sum(p.t_tx for p in plans),
                  sum(p.t_cloud for p in plans))
    assert r.throughput <= len(plans) / busiest + 1e-6


def test_fig2_scheme1_vs_scheme2():
    """Scheme 1: stages (1,1,4) latency-min but max stage 4.  Scheme 2:
    (3,1,3) latency 7 but max stage 3 -> higher throughput (25% gain)."""
    n = 50
    s1 = run_pipeline([_plan(1, 1, 4)] * n, arrival_period=2.0)
    s2 = run_pipeline([_plan(3, 1, 3)] * n, arrival_period=2.0)
    assert s2.throughput > s1.throughput
    assert s1.tasks[0].latency < s2.tasks[0].latency  # scheme1 wins 1-task latency
    # paper: max stage 4 -> 3 is ~25% efficiency gain at saturation
    assert s2.throughput / s1.throughput > 1.15


def test_early_exit_skips_link_and_cloud():
    plans = [_plan(1, 5, 5, early_exit=True)] * 10
    r = run_pipeline(plans, arrival_period=1.0)
    assert r.link_busy == 0.0 and r.cloud_busy == 0.0
    assert r.exit_ratio == 1.0
    assert all(math.isclose(t.latency, 1.0) for t in r.tasks)


def test_tx_offset_overlaps_transmission():
    """With tx_offset < t_end the link starts before end-compute finishes
    (Fig. 4 layer-parallel overlap) -> lower latency."""
    no_ov = run_pipeline([_plan(2, 2, 0.1)], arrival_period=0)
    ov = run_pipeline([_plan(2, 2, 0.1, tx_offset=0.5)], arrival_period=0)
    assert ov.tasks[0].latency < no_ov.tasks[0].latency - 0.9


def test_dynamic_bandwidth_trace_slows_tasks():
    trace = bandwidth_step_trace([(0.0, 20.0), (1.0, 5.0)])
    link = LinkProfile("w", 20e6, trace=trace)
    # each task pushes 20e6*0.5 bits = 0.5s at 20Mbps, 2s at 5Mbps
    plans = [_plan(0.1, 0.5, 0.05)] * 8
    r = run_pipeline(plans, arrival_period=0.0, link=link)
    early = r.tasks[0].latency
    late = r.tasks[-1].latency
    assert late > early  # bandwidth drop queues tasks up


def test_bubble_fraction_accounting():
    # unbalanced stages starve the cloud -> large cloud bubbles
    r = run_pipeline([_plan(1.0, 0.1, 0.1)] * 20, arrival_period=0.0)
    assert r.bubble_fraction("cloud") > 0.8
    # balanced stages keep the cloud mostly busy
    r2 = run_pipeline([_plan(0.3, 0.3, 0.3)] * 50, arrival_period=0.0)
    assert r2.bubble_fraction("cloud") < 0.15
