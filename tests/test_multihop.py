"""Multi-hop generalization, end to end: offline multi-cut search,
3-segment CollabRuntime with per-hop wire packets, and the serving engine
over a 3-tier deployment."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.collab import CollabRuntime, split_params_multi
from repro.core.costs import (DeviceProfile, LinkProfile, chain_graph)
from repro.core.partitioner import coach_offline, coach_offline_multihop
from repro.core.schedule import StageTimes
from repro.data.pipeline import CorrelatedTaskStream, make_calibration_set
from repro.models import model as M
from repro.serving.engine import CoachEngine

END = DeviceProfile("end", 1e9)
EDGE = DeviceProfile("edge", 3e9)
CLOUD = DeviceProfile("cloud", 8e9)
UPLINK = LinkProfile("uplink", 100e6)
BACKHAUL = LinkProfile("backhaul", 900e6)


# ------------------------------------------------------- offline multi-cut
def _graph(seed=0, n=12):
    rng = np.random.RandomState(seed)
    return chain_graph(f"g{seed}", rng.uniform(1e6, 5e7, n),
                       rng.randint(1_000, 200_000, n))


def test_multihop_offline_produces_nested_feasible_cut():
    g = _graph()
    res = coach_offline_multihop(g, (END, EDGE, CLOUD), (UPLINK, BACKHAUL))
    dec = res.decision
    assert dec.n_hops == 2
    f1, f2 = dec.cuts
    assert f1 <= f2 and g.valid_end_set(f1) and g.valid_end_set(f2)
    segs = dec.segments(g)
    assert len(segs) == 3
    assert frozenset().union(*segs) == frozenset(nd.id for nd in g.nodes)
    assert res.feasible
    assert res.times.n_hops == 2


def test_multihop_offline_no_worse_than_pinning_edge_to_end_cut():
    """The 2D sweep includes every (c, c) pair, so its objective can never
    exceed the classic 1-cut search evaluated on the 3-tier deployment."""
    g = _graph(3)
    res2 = coach_offline(g, END, CLOUD, UPLINK)
    res3 = coach_offline_multihop(g, (END, EDGE, CLOUD),
                                  (UPLINK, BACKHAUL))
    # same machinery at n_hops=1 reproduces the classic result
    res1 = coach_offline_multihop(g, (END, CLOUD), (UPLINK,))
    assert abs(res1.objective - res2.objective) < 1e-12
    assert res3.objective <= res2.objective + 1e-9 or res3.feasible


# --------------------------------------------------- 3-segment CollabRuntime
@pytest.fixture(scope="module")
def rt3():
    cfg = get_config("gemma2-2b").reduced(num_layers=8)  # 4 groups
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params, CollabRuntime(cfg, params, cut_group=(1, 3),
                                      default_bits=(8, 8))


def test_split_params_multi_partitions_groups(rt3):
    cfg, params, r = rt3
    segs = split_params_multi(params, cfg, (1, 3))
    sizes = [jax.tree.leaves(s["groups"])[0].shape[0] for s in segs]
    assert sizes == [1, 2, 1]
    assert "embed" in segs[0] and "final_norm" in segs[-1]
    assert r.n_hops == 2 and r.n_segments == 3


def test_three_segment_matches_monolithic(rt3):
    cfg, params, r = rt3
    x = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    logits, packets = r.run(x)
    assert [p.hop for p in packets] == [0, 1]
    assert all(p.bits == 8 for p in packets)
    ref = r.monolithic(params, x)
    rel = float(jnp.max(jnp.abs(logits - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < 0.05, rel  # two 8-bit quantization hops


def test_cloud_step_relays_remaining_hops(rt3):
    cfg, params, r = rt3
    x = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab_size)
    logits, packets = r.run(x)
    relayed = r.cloud_step(packets[0])  # from the end's uplink packet
    np.testing.assert_allclose(np.asarray(relayed), np.asarray(logits),
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------- engine 3-tier
def _multihop_stage_times():
    return StageTimes(
        T_e=2e-3, T_t=4e-3, T_c=2e-3, T_t_par=0.0, T_c_par=0.0,
        latency=9e-3, first_tx_offset=2e-3, cloud_start_offset=3e-3,
        compute=(2e-3, 1.5e-3, 2e-3), link=(3e-3, 1e-3),
        link_par=(0.0, 0.0), compute_par=(0.0, 0.0),
        tx_offsets=(2e-3, 1.5e-3), rx_offsets=(3e-3, 1e-3))


def test_engine_accounts_three_tier_stream():
    st = _multihop_stage_times()
    stream = CorrelatedTaskStream(n_labels=30, dim=48,
                                  correlation="medium", seed=0)
    feats, labels = make_calibration_set(stream, 400)
    eng = CoachEngine(None, st, END, UPLINK, CLOUD, n_labels=30,
                      calib_feats=feats, calib_labels=labels,
                      boundary_elems=50_000, links=[UPLINK, BACKHAUL])

    def classify(task):
        d = np.linalg.norm(stream.mu - task.features[None], axis=1)
        return task.features, int(np.argmin(d))

    stats = eng.run_stream(stream.tasks(300), arrival_period=3.2e-3,
                           classify=classify)
    pr = stats.pipeline
    assert pr.n_hops == 2
    assert len(pr.compute_busy) == 3
    assert pr.throughput > 0 and stats.accuracy > 0.7
    for k in range(3):
        assert pr.compute_busy[k] <= pr.makespan + 1e-9
    # the backhaul carried the inner hop for every non-exited task
    n_full = sum(1 for t in pr.tasks if not t.early_exit)
    assert abs(pr.link_busy_hops[1] - n_full * st.link[1]) < 1e-9


def test_all_early_exit_stream_keeps_deployment_resources():
    """A 3-tier stream where every task early-exits must still account
    all 2n+1 deployment resources (regression: hop count was inferred
    from the plans alone and collapsed to 1)."""
    from repro.core.pipeline import TaskPlan, run_pipeline

    plans = [TaskPlan(1e-3, 0.0, 0.0, True) for _ in range(5)]
    pr = run_pipeline(plans, arrival_period=1e-3,
                      links=[UPLINK, BACKHAUL])
    assert pr.n_hops == 2 and len(pr.compute_busy) == 3
    assert pr.compute_busy[1] == pr.compute_busy[2] == 0.0
    assert pr.bubble_fraction(("compute", 2)) == 1.0
    assert pr.bubble_fraction(("link", 1)) == 1.0


def test_engine_rejects_link_hop_mismatch():
    st = _multihop_stage_times()
    stream = CorrelatedTaskStream(n_labels=5, dim=16, seed=0)
    feats, labels = make_calibration_set(stream, 50)
    with pytest.raises(AssertionError):
        CoachEngine(None, st, END, UPLINK, CLOUD, n_labels=5,
                    calib_feats=feats, calib_labels=labels,
                    boundary_elems=1000)  # 1 link for 2-hop stage times
