"""Hop-level semantic early exit: probe cascade, per-boundary
calibration, exit_hop plan semantics, and resource release in the event
simulator (the serving-layer differentials live in test_async_engine /
test_tenancy)."""

import numpy as np
import pytest

from repro.core import online as ON
from repro.core import sim
from repro.core.pipeline import TaskPlan, run_pipeline
from repro.data.pipeline import (CorrelatedTaskStream, make_calibration_set,
                                 make_hop_calibration_sets)


def _stream(n_depths, seed=0):
    return CorrelatedTaskStream(n_labels=12, dim=32, correlation="medium",
                                seed=seed, n_probe_depths=n_depths)


def _sched(n_depths, seed=0, elems=10_000):
    st = _stream(n_depths, seed)
    sets = make_hop_calibration_sets(st, 300, n_depths=n_depths)
    feats, labels = sets[0]
    cache = ON.SemanticCache(st.n_labels, st.dim)
    cache.warm_up(feats, labels)
    th = ON.calibrate_thresholds(cache, feats, labels)
    probes = ON.build_hop_probes(sets[1:], st.n_labels)
    sched = ON.OnlineScheduler(cache, th, elems, T_e=2e-3, T_c=2e-3,
                               hop_elems=[elems] * n_depths,
                               stage_compute=[2e-3] * (n_depths + 1),
                               hop_probes=probes)
    return sched, st


# -------------------------------------------------------------- data layer
def test_hop_features_depth0_identical_to_classic_stream():
    """The rng draw sequence must not depend on n_probe_depths: a seeded
    stream yields bit-identical depth-0 features (and labels) whether or
    not it also emits deeper boundaries."""
    a = _stream(1, seed=7)
    b = _stream(3, seed=7)
    for _ in range(50):
        ta, tb = a.next_task(), b.next_task()
        assert ta.label == tb.label
        np.testing.assert_array_equal(ta.features, tb.features)
        np.testing.assert_array_equal(tb.hop_features[0], tb.features)
        assert tb.hop_features.shape == (3, b.dim)


def test_hop_calibration_depth0_matches_classic_set():
    st = _stream(2, seed=3)
    sets = make_hop_calibration_sets(st, 200, n_depths=2, seed=1)
    feats, labels = make_calibration_set(st, 200, seed=1)
    np.testing.assert_array_equal(sets[0][0], feats)
    np.testing.assert_array_equal(sets[0][1], labels)
    np.testing.assert_array_equal(sets[1][1], labels)


def test_deeper_calibration_features_more_separable():
    """Depth attenuation concentrates class evidence: mean separability
    against per-depth centers rises monotonically with depth."""
    st = _stream(3, seed=5)
    sets = make_hop_calibration_sets(st, 300, n_depths=3)
    probes = ON.build_hop_probes(sets, st.n_labels)
    mean_sep = []
    for (feats, labels), probe in zip(sets, probes):
        seps = [ON.separability(probe.cache.similarities(f)) for f in feats]
        mean_sep.append(float(np.mean(seps)))
    assert mean_sep[0] < mean_sep[1] < mean_sep[2], mean_sep


# ------------------------------------------------------------ probe cascade
def test_cascade_first_exit_wins_and_carries_uplink_bits():
    sched, st = _sched(3, seed=2)
    n = {0: 0, 1: 0, 2: 0, None: 0}
    for task in st.tasks(300):
        dec = sched.step_cascade(task.hop_features, bandwidth_bps=40e6)
        n[dec.exit_hop] += 1
        if dec.exit_hop == 0:
            assert dec.early_exit and dec.bits is None
        elif dec.exit_hop is not None:
            # transmitted over the uplink, then exited at a deeper tier
            assert not dec.early_exit
            assert dec.bits is not None and dec.result is not None
        else:
            assert dec.result is None
    assert n[1] + n[2] > 0, n    # mid-pipeline exits actually happen
    assert n[None] + n[0] > 0, n


def test_cascade_without_probes_equals_classic_step():
    sched, st = _sched(2, seed=9)
    classic = ON.OnlineScheduler(sched.cache, sched.th, sched.elems,
                                 T_e=2e-3, T_c=2e-3,
                                 update_centers=False)
    sched.update_centers = False
    for task in st.tasks(50):
        a = sched.step_cascade([task.hop_features[0]], bandwidth_bps=40e6)
        b = classic.step(task.hop_features[0], bandwidth_bps=40e6)
        # probes beyond hop 0 see the shallow feature only when the
        # cascade runs; with update_centers off the hop-0 outcome is
        # shared state-free, so exit/bits agree whenever hop 0 decides
        if a.exit_hop in (0, None):
            assert (a.early_exit, a.bits) == (b.early_exit, b.bits)


def test_probe_hop_requires_calibrated_probe():
    sched, _ = _sched(2)
    with pytest.raises(AssertionError):
        sched.probe_hop(2, np.zeros(32))  # only segment 1 is calibrated


def test_report_label_hops_upto_updates_crossed_tiers_only():
    sched, st = _sched(3, seed=4)
    c0 = sched.cache.counts.copy()
    c1 = sched.hop_probes[0].cache.counts.copy()
    c2 = sched.hop_probes[1].cache.counts.copy()
    f = st.next_task().hop_features
    sched.report_label_hops(f, 3, upto=2)   # exited at segment 2
    assert sched.cache.counts[3] == c0[3] + 1
    assert sched.hop_probes[0].cache.counts[3] == c1[3] + 1
    assert sched.hop_probes[1].cache.counts[3] == c2[3]  # exiting tier: no
    sched.report_label_hops(f, 3)           # full pipeline: all tiers
    assert sched.hop_probes[1].cache.counts[3] == c2[3] + 1
    sched.report_label_hops(f, 3, upto=0)   # exited on the end device
    assert sched.cache.counts[3] == c0[3] + 2  # (two reports above)


# ------------------------------------------------------------ plan semantics
def test_sim_plan_exit_hop_normalization():
    p = sim.SimPlan(compute=(1.0, 1.0, 1.0), tx=(1.0, 1.0), early_exit=True)
    assert p.exit_hop == 0 and p.early_exit and p.n_stages == 1
    p = sim.SimPlan(compute=(1.0, 1.0, 1.0), tx=(1.0, 1.0), exit_hop=1)
    assert p.early_exit and p.n_stages == 2
    # exiting at the last segment is just a full run
    p = sim.SimPlan(compute=(1.0, 1.0, 1.0), tx=(1.0, 1.0), exit_hop=2)
    assert p.exit_hop is None and not p.early_exit and p.n_stages == 3
    with pytest.raises(AssertionError):
        sim.SimPlan(compute=(1.0, 1.0), tx=(1.0,), exit_hop=5)


def test_occupancy_helpers():
    assert sim.occupies_compute(None, 3) and sim.occupies_link(None, 3)
    assert sim.occupies_compute(1, 0) and sim.occupies_compute(1, 1)
    assert not sim.occupies_compute(1, 2)
    assert sim.occupies_link(1, 0) and not sim.occupies_link(1, 1)


def test_all_hop1_exit_stream_releases_downstream():
    """A stream that exits entirely at segment 1 of a 3-hop deployment
    still accounts all 7 resources, but only the first three carry busy
    time."""
    plans = [TaskPlan.multihop((1e-3, 2e-3, 1e-3, 1e-3),
                               (0.5e-3, 0.5e-3, 0.5e-3), exit_hop=1)
             for _ in range(10)]
    pr = run_pipeline(plans, arrival_period=1e-3)
    assert pr.n_hops == 3
    assert pr.compute_busy[0] > 0 and pr.compute_busy[1] > 0
    assert pr.link_busy_hops[0] > 0
    assert pr.compute_busy[2] == pr.compute_busy[3] == 0.0
    assert pr.link_busy_hops[1] == pr.link_busy_hops[2] == 0.0
    assert pr.exit_hop_counts() == {1: 10}
    assert pr.exit_ratio == 1.0
    # done at segment 1: serialized on the slow edge tier
    assert abs(pr.makespan - (1e-3 + 0.5e-3 + 10 * 2e-3 - 0e-3)) < 1e-9


def test_stream_result_exit_hop_backfill():
    """StreamResult built without exit_hop (legacy constructors) derives
    it from the early_exit booleans."""
    r = sim.StreamResult(arrivals=[0.0], done=[1.0], early_exit=[True],
                        makespan=1.0, compute_busy=(1.0,), link_busy=())
    assert r.exit_hop == [0]
