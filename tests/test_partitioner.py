"""Offline component: Algorithm 1 vs brute force, virtual blocks,
dichotomous quant search, Eq. 4/5/6 semantics."""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.costs import (DeviceProfile, LinkProfile, LayerNode,
                              ModelGraph, chain_graph)
from repro.core.partitioner import (analytic_acc_loss, brute_force,
                                    chain_flow, coach_offline,
                                    dichotomous_bits)
from repro.core.schedule import PartitionDecision, evaluate_partition
from repro.models.cnn import resnet101, vgg16

END = DeviceProfile("end", 1e11, efficiency=1.0)
CLOUD = DeviceProfile("cloud", 1e12, efficiency=1.0)
LINK = LinkProfile("l", 50e6)


def _rand_chain(seed, n=10):
    rng = np.random.default_rng(seed)
    return chain_graph(f"c{seed}", rng.uniform(1e7, 1e9, n),
                       rng.integers(1e3, 3e5, n),
                       rng.uniform(0.005, 0.08, n).tolist())


@given(st.integers(0, 500))
@settings(max_examples=25, deadline=None)
def test_chain_matches_brute_force(seed):
    g = _rand_chain(seed, n=9)
    r1 = coach_offline(g, END, CLOUD, LINK)
    r2 = brute_force(g, END, CLOUD, LINK)
    assert r1.objective <= r2.objective * (1 + 1e-9), \
        f"coach {r1.objective} worse than brute {r2.objective}"


def test_dag_close_to_brute_force():
    # small series-parallel DAG: 0 -> (1,2 | 3) -> 4 -> 5
    nodes = [
        LayerNode(0, "a", 2e8, 40_000),
        LayerNode(1, "b1", 3e8, 30_000, (0,)),
        LayerNode(2, "b2", 3e8, 20_000, (1,)),
        LayerNode(3, "c1", 4e8, 25_000, (0,)),
        LayerNode(4, "join", 1e8, 20_000, (2, 3)),
        LayerNode(5, "head", 2e8, 1_000, (4,)),
    ]
    g = ModelGraph("sp", nodes)
    r1 = coach_offline(g, END, CLOUD, LINK)
    r2 = brute_force(g, END, CLOUD, LINK)
    # D&C explores a restricted set of DAG cuts: allow small optimality gap
    assert r1.objective <= r2.objective * 1.25


def test_virtual_blocks_resnet():
    g = resnet101()
    elems = chain_flow(g)
    blocks = [e for e in elems if e.is_block]
    assert len(blocks) == 33  # one per bottleneck
    # projection blocks have 2 branches, identity blocks 1
    br = sorted(set(len(b.branches) for b in blocks))
    assert br == [1, 2]
    # block contents + chain nodes cover the graph exactly once
    ids = [i for e in elems for i in e.ids()]
    assert sorted(ids) == list(range(len(g)))


def test_vgg_is_chain():
    g = vgg16()
    assert g.is_chain()
    assert all(not e.is_block for e in chain_flow(g))


@given(st.floats(0.001, 0.05), st.floats(0.005, 0.1))
@settings(max_examples=30, deadline=None)
def test_dichotomous_bits_minimal(eps, sens):
    node = LayerNode(0, "x", 1e8, 1000, sensitivity=sens)
    b = dichotomous_bits(node, eps, analytic_acc_loss)
    assert analytic_acc_loss(node, b) <= eps or b == 16
    if b > 2:
        assert analytic_acc_loss(node, b - 1) > eps  # minimality


def test_quant_meets_accuracy_constraint():
    g = resnet101()
    r = coach_offline(g, END, CLOUD, LINK, eps=0.005)
    for (u, v), bits in r.decision.bits.items():
        assert analytic_acc_loss(g.node(u), bits) <= 0.005 + 1e-12


def test_eq4_parallel_constraint_holds():
    g = resnet101()
    r = coach_offline(g, END, CLOUD, LINK)
    assert r.times.satisfies_parallel_constraint()
    assert r.feasible


def test_objective_is_eq6():
    g = _rand_chain(7)
    r = coach_offline(g, END, CLOUD, LINK)
    t = r.times
    assert math.isclose(r.objective, t.B_c + t.B_t + t.max_stage,
                        rel_tol=1e-12)


def test_evaluate_partition_stage_times_consistent():
    g = _rand_chain(3)
    end = frozenset(range(5))
    bits = {e: 8 for e in g.boundary_edges(end) if e[0] >= 0}
    st_ = evaluate_partition(g, PartitionDecision(end, bits), END, CLOUD, LINK)
    # T_e = sum of end layer times
    te = sum(END.layer_time(g.node(i).flops) for i in end)
    assert math.isclose(st_.T_e, te, rel_tol=1e-9)
    # latency >= each stage
    assert st_.latency >= max(st_.T_e, st_.T_t, st_.T_c) - 1e-12
    # overlaps bounded by busy times
    assert st_.T_t_par <= st_.T_t + 1e-12
    assert st_.T_c_par <= st_.T_c + 1e-12


def test_downward_closure_enforced():
    g = _rand_chain(4)
    bad = frozenset({3, 5})  # 5 requires 4
    with pytest.raises(AssertionError):
        evaluate_partition(g, PartitionDecision(bad, {}), END, CLOUD, LINK)


def test_min_end_nodes_respected():
    g = _rand_chain(5)
    r = coach_offline(g, END, CLOUD, LINK, min_end_nodes=1)
    assert len(r.decision.end_set) >= 1
