"""Hypothesis properties for the observability layer.

Two generative invariants over random streams (task counts, service
times, hop exits), shapes (serial chains, heterogeneous replica pools,
micro-batching caps), and router policies:

1. *Trace pin* — the async executor under the virtual clock emits the
   same span timeline as the arithmetic simulator, to 1e-6 (the repo's
   differential-pin invariant extended from latencies to traces).
2. *Conservation* — ``repro.obs.bubbles.attribute`` partitions every
   resource's horizon into busy intervals and attributed gaps:
   ``busy + sum(bubbles) = horizon`` per resource at 1e-9, every gap
   carries exactly one cause from the closed enum, and pinned
   unbounded-queue runs never produce ``downstream_backpressure``.

(Module is collect-ignored by ``conftest.py`` when hypothesis is not
installed.)
"""

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.pipeline import TaskPlan, run_pipeline
from repro.core.sim import PoolSpec
from repro.obs.bubbles import CAUSES, attribute, chain_resources
from repro.obs.trace import TraceRecorder, assert_traces_match
from repro.serving.async_engine import VirtualClock, run_pipeline_async
from repro.serving.routing import ROUTER_POLICIES, make_router

CONS_TOL = 1e-9
PIN_TOL = 1e-6


@st.composite
def traced_scenarios(draw):
    n_hops = draw(st.integers(1, 3))
    n = draw(st.integers(1, 10))
    batched = draw(st.booleans())
    # t_fixed must stay within every drawn segment compute time (>= 1e-4)
    t_fixed = [draw(st.floats(0.0, 1e-4)) for _ in range(n_hops + 1)] \
        if batched else None
    plans, arr, t = [], [], 0.0
    for _ in range(n):
        comp = [draw(st.floats(1e-4, 5e-3)) for _ in range(n_hops + 1)]
        tx = [draw(st.floats(1e-5, 3e-3)) for _ in range(n_hops)]
        exit_hop = draw(st.one_of(st.none(), st.integers(0, n_hops - 1))) \
            if n_hops > 1 else None
        plans.append(TaskPlan.multihop(comp, tx, exit_hop=exit_hop,
                                       t_fixed=t_fixed))
        arr.append(t)
        # strictly positive gaps: zero-duration event chains are the
        # executor's known settle() blind spot (same exposure as the
        # chain/batching/pool differential suites)
        t += draw(st.floats(1e-5, 3e-3))
    caps = [draw(st.integers(1, 3)) for _ in range(n_hops + 1)] \
        if batched else None
    pools = policy = None
    seed = 0
    if draw(st.booleans()):
        pools = [PoolSpec(speeds=tuple(
            draw(st.floats(0.3, 2.5))
            for _ in range(draw(st.integers(1, 3)))))
            for _ in range(n_hops + 1)]
        policy = draw(st.sampled_from(sorted(ROUTER_POLICIES)))
        seed = draw(st.integers(0, 5))
    return plans, arr, caps, pools, policy, seed


def _run(engine, plans, arr, caps, pools, policy, seed):
    rec = TraceRecorder()
    router = make_router(policy, seed=seed) if pools else None
    kw = dict(arrivals=arr, batch_caps=caps, pools=pools, router=router,
              sink=rec)
    pr = run_pipeline(plans, **kw) if engine == "sim" else \
        run_pipeline_async(plans, clock=VirtualClock(), **kw)
    return pr, rec


@settings(max_examples=40, deadline=None)
@given(sc=traced_scenarios())
def test_trace_pin_extends_to_span_timelines(sc):
    pr_s, rec_s = _run("sim", *sc)
    pr_a, rec_a = _run("async", *sc)
    assert abs(pr_s.makespan - pr_a.makespan) <= PIN_TOL
    assert_traces_match(rec_s, rec_a, tol=PIN_TOL)


@settings(max_examples=40, deadline=None)
@given(sc=traced_scenarios())
def test_attribution_conserves_and_closes(sc):
    pr, rec = _run("sim", *sc)
    att = attribute(rec, resources=chain_resources(
        pr.n_hops, pr.pool_sizes or None))
    assert att.max_conservation_error() <= CONS_TOL
    for b in att.bubbles:
        assert b.cause in CAUSES
        assert b.dur > 0.0
        assert -CONS_TOL <= b.t0 and b.t1 <= att.horizon_s + CONS_TOL
    assert att.total(cause="downstream_backpressure") == 0.0
    # independent re-derivation of the identity, per resource
    busy = att.busy_by_label()
    for label, causes in att.by_label().items():
        assert abs(busy[label] + sum(causes.values()) - att.horizon_s) \
            <= CONS_TOL
