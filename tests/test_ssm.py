"""SSD correctness: the chunked dual form must equal the sequential
recurrence exactly, for any chunk size and with state handoff."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import ssm as SSM
from repro.models.config import ModelConfig, LayerSpec


def _cfg(chunk=8):
    return get_config("mamba2-130m").reduced(ssm_chunk=chunk)


def _sequential_ssd(x, dt, A, Bm, Cm, h0=None):
    """Reference: step-by-step recurrence h' = h*exp(dt*A) + dt*B x."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    rep = H // Bm.shape[2]
    Bh = np.repeat(np.asarray(Bm), rep, 2)
    Ch = np.repeat(np.asarray(Cm), rep, 2)
    x, dt, A = np.asarray(x), np.asarray(dt), np.asarray(A)
    h = np.zeros((Bsz, H, P, N)) if h0 is None else np.array(h0)
    ys = np.zeros((Bsz, S, H, P))
    for t in range(S):
        dA = np.exp(dt[:, t] * A)  # (B,H)
        dBx = np.einsum("bh,bhn,bhp->bhpn", dt[:, t], Bh[:, t], x[:, t])
        h = h * dA[..., None, None] + dBx
        ys[:, t] = np.einsum("bhn,bhpn->bhp", Ch[:, t], h)
    return ys, h


@pytest.mark.parametrize("S,chunk", [(16, 8), (24, 8), (7, 8), (32, 4)])
def test_ssd_chunked_equals_sequential(S, chunk):
    cfg = _cfg(chunk)
    key = jax.random.PRNGKey(0)
    B, H, P, N = 2, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    x = jax.random.normal(key, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.PRNGKey(3), (B, S, 1, N)) * 0.5
    Cm = jax.random.normal(jax.random.PRNGKey(4), (B, S, 1, N)) * 0.5
    y, hT = SSM.ssd_chunked(cfg, x, dt, A, Bm, Cm)
    y_ref, h_ref = _sequential_ssd(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(hT, h_ref, rtol=2e-4, atol=2e-4)


def test_ssd_state_handoff():
    """Running [0:S1] then [S1:S] with the carried state == one pass."""
    cfg = _cfg(4)
    key = jax.random.PRNGKey(5)
    B, S, S1 = 2, 16, 8
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    x = jax.random.normal(key, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(6), (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(7), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.PRNGKey(8), (B, S, 1, N)) * 0.5
    Cm = jax.random.normal(jax.random.PRNGKey(9), (B, S, 1, N)) * 0.5
    y_full, h_full = SSM.ssd_chunked(cfg, x, dt, A, Bm, Cm)
    y1, h1 = SSM.ssd_chunked(cfg, x[:, :S1], dt[:, :S1], A, Bm[:, :S1], Cm[:, :S1])
    y2, h2 = SSM.ssd_chunked(cfg, x[:, S1:], dt[:, S1:], A, Bm[:, S1:], Cm[:, S1:], h0=h1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h2, h_full, rtol=2e-4, atol=2e-4)


@given(st.integers(0, 10_000), st.integers(3, 24))
@settings(max_examples=10, deadline=None)
def test_mamba_decode_matches_forward(seed, S):
    """Token-by-token decode must reproduce the full forward pass."""
    cfg = _cfg(8)
    key = jax.random.PRNGKey(seed)
    p = SSM.init_mamba(cfg, key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, S, cfg.d_model)) * 0.5
    y_full = SSM.mamba_forward(p, x, cfg)
    cache = SSM.init_mamba_cache(cfg, 2)
    ys = []
    for t in range(S):
        y, cache = SSM.mamba_decode(p, x[:, t:t + 1], cache, cfg)
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_dec, y_full, rtol=3e-4, atol=3e-4)


def test_mamba_prefill_cache_continues_decode():
    cfg = _cfg(8)
    key = jax.random.PRNGKey(11)
    p = SSM.init_mamba(cfg, key)
    x = jax.random.normal(key, (2, 13, cfg.d_model)) * 0.5
    y_full = SSM.mamba_forward(p, x, cfg)
    _, cache = SSM.mamba_forward(p, x[:, :9], cfg, return_cache=True)
    y = None
    for t in range(9, 13):
        y, cache = SSM.mamba_decode(p, x[:, t:t + 1], cache, cfg)
    np.testing.assert_allclose(y[:, 0], y_full[:, -1], rtol=3e-4, atol=3e-4)
